//! Ablation: the frequency-exchange epoch length Δ (paper §IV-B / §V-A:
//! "our version can theoretically benefit from larger Δ values"; the
//! paper fixes Δ = 100 = every connectivity update).
//!
//! Sweeps Δ and reports (a) spike-transfer time, (b) bytes moved by the
//! spike path, (c) modeled communication time on the paper's
//! InfiniBand-class network (the counters re-priced — see
//! `metrics::netmodel`), and (d) a quality proxy: mean |Ca − target| at
//! the end of a §V-D-style homeostasis run. Expectation: cost falls
//! ~1/Δ, quality degrades only slowly (response lag), Δ=100 is a sweet
//! spot — which is why the paper chose it.

#[path = "common/mod.rs"]
mod common;
use common::*;
use ilmi::config::{SimConfig, SpikeAlg};
use ilmi::coordinator::run_simulation;
use ilmi::metrics::NetModel;

fn main() {
    figure_header("Ablation", "frequency-exchange epoch length (delta)");
    let net = NetModel::hdr100();

    println!(
        "\n{:>7} {:>12} {:>12} {:>14} {:>16}",
        "delta", "xfer [s]", "sent [B]", "net-model [s]", "|Ca - target|"
    );

    // Old algorithm reference row (per-step ids == \"delta 1\", exact).
    {
        let mut cfg = timing_cfg();
        cfg.spike_alg = SpikeAlg::OldIds;
        let report = run_simulation(&cfg).unwrap();
        let q = quality_offset(&quality_cfg(1, SpikeAlg::OldIds));
        println!(
            "{:>7} {:>12.6} {:>12} {:>14.6} {:>16.4}   (old per-step ids)",
            "exact",
            report.phase_max(ilmi::metrics::Phase::SpikeExchange),
            report.total_bytes_sent(),
            net.price_run(&report.ranks.iter().map(|r| r.comm).collect::<Vec<_>>()),
            q
        );
    }

    for delta in [10usize, 50, 100, 200, 500] {
        let mut cfg = timing_cfg();
        cfg.delta = delta;
        let report = run_simulation(&cfg).unwrap();
        let q = quality_offset(&quality_cfg(delta, SpikeAlg::NewFrequency));
        println!(
            "{:>7} {:>12.6} {:>12} {:>14.6} {:>16.4}",
            delta,
            report.phase_max(ilmi::metrics::Phase::SpikeExchange),
            report.total_bytes_sent(),
            net.price_run(&report.ranks.iter().map(|r| r.comm).collect::<Vec<_>>()),
            q
        );
    }
    println!("\n(paper picks delta = 100 — every connectivity update)");
}

fn timing_cfg() -> SimConfig {
    let mut cfg = paper_cfg(8, 512, 0.3);
    cfg.spike_alg = SpikeAlg::NewFrequency;
    cfg
}

fn quality_cfg(delta: usize, alg: SpikeAlg) -> SimConfig {
    let mut cfg = SimConfig::paper_quality(20_000);
    cfg.ranks = 16;
    cfg.delta = delta.max(1);
    cfg.spike_alg = alg;
    cfg
}

/// Mean |Ca − target| over neurons at the end of a homeostasis run.
fn quality_offset(cfg: &SimConfig) -> f64 {
    let report = run_simulation(cfg).unwrap();
    let target = cfg.neuron.eps_target_ca as f64;
    let mut acc = 0.0;
    for r in &report.ranks {
        acc += (r.mean_calcium - target).abs();
    }
    acc / report.ranks.len() as f64
}
