//! Fig. 3 — weak scaling of the connectivity update: old (RMA
//! download) vs new (location-aware) Barnes–Hut, one panel per
//! neurons-per-rank value, θ ∈ {0.2, 0.3, 0.4}.
//!
//! Paper shape to check: identical at 1 rank; the gap opens with rank
//! count (paper: up to 6–10x at 512–1024 ranks); larger θ is faster for
//! both.

#[path = "common/mod.rs"]
mod common;
use common::*;

fn main() {
    figure_header(
        "Fig. 3",
        "connectivity-update time [s], old vs new Barnes-Hut (weak scaling)",
    );
    for npr in npr_axis() {
        println!("\n--- panel: {npr} neurons per rank ---");
        println!(
            "{:>6} {:>6} {:>12} {:>12} {:>8}",
            "ranks", "theta", "old [s]", "new [s]", "old/new"
        );
        for theta in THETAS {
            for &ranks in &rank_axis() {
                let base = paper_cfg(ranks, npr, theta);
                let old = measure(&with_algs(&base, OLD.0, OLD.1));
                let new = measure(&with_algs(&base, NEW.0, NEW.1));
                println!(
                    "{:>6} {:>6.1} {:>12} {:>12} {:>8}",
                    ranks,
                    theta,
                    s(old.conn_s),
                    s(new.conn_s),
                    ratio(old.conn_s, new.conn_s)
                );
            }
        }
    }
}
