//! Fig. 11 — phase breakdown of the largest simulation, old vs new
//! (paper: 1024 ranks × 65,536 neurons, θ = 0.2; 617 s -> 131 s,
//! a 78.8% wall-clock reduction).
//!
//! Shape to check: with the new algorithms, per-neuron compute
//! (activity + elements) and Barnes–Hut dominate; communication phases
//! shrink to a small share.

#[path = "common/mod.rs"]
mod common;
use common::*;
use ilmi::metrics::{ALL_PHASES};

fn main() {
    let (ranks, npr) = if full_grid() { (32, 2048) } else { (16, 1024) };
    figure_header(
        "Fig. 11",
        &format!("phase breakdown at the largest local scale ({ranks} ranks x {npr} neurons, theta=0.2)"),
    );
    let base = paper_cfg(ranks, npr, 0.2);
    let old_report =
        ilmi::coordinator::run_simulation(&with_algs(&base, OLD.0, OLD.1)).unwrap();
    let new_report =
        ilmi::coordinator::run_simulation(&with_algs(&base, NEW.0, NEW.1)).unwrap();

    println!(
        "\n{:<18} {:>12} {:>7} {:>12} {:>7}",
        "phase", "old [s]", "old %", "new [s]", "new %"
    );
    let old_total: f64 = ALL_PHASES.iter().map(|&p| old_report.phase_max(p)).sum();
    let new_total: f64 = ALL_PHASES.iter().map(|&p| new_report.phase_max(p)).sum();
    for p in ALL_PHASES {
        let o = old_report.phase_max(p);
        let n = new_report.phase_max(p);
        println!(
            "{:<18} {:>12.4} {:>6.1}% {:>12.4} {:>6.1}%",
            p.name(),
            o,
            100.0 * o / old_total,
            n,
            100.0 * n / new_total
        );
    }
    println!(
        "{:<18} {:>12.4} {:>7} {:>12.4}",
        "sum(max-per-phase)", old_total, "", new_total
    );
    println!(
        "wall clock: {:.3} s -> {:.3} s ({:.1}% reduction; paper: 78.8%)",
        old_report.wall_seconds,
        new_report.wall_seconds,
        100.0 * (1.0 - new_report.wall_seconds / old_report.wall_seconds)
    );
}
