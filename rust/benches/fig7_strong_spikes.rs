//! Fig. 7 — STRONG scaling of the new frequency-transfer algorithm:
//! total neuron count fixed, rank count varies.

#[path = "common/mod.rs"]
mod common;
use common::*;

fn main() {
    figure_header(
        "Fig. 7",
        "frequency transfer time [s], new algorithm (strong scaling)",
    );
    let totals: &[usize] = if full_grid() { &[8192, 65536] } else { &[4096, 16384] };
    for &total in totals {
        println!("\n--- panel: {total} total neurons ---");
        println!("{:>6} {:>8} {:>12} {:>12}", "ranks", "npr", "freqs [s]", "lookup [s]");
        for &ranks in &rank_axis() {
            if total / ranks < 32 {
                continue;
            }
            let base = paper_cfg(ranks, total / ranks, 0.3);
            let new = measure(&with_algs(&base, NEW.0, NEW.1));
            println!(
                "{:>6} {:>8} {:>12} {:>12}",
                ranks,
                total / ranks,
                s(new.spike_s),
                s(new.lookup_s)
            );
        }
    }
}
