//! Fig. 10 — performance model of the new location-aware algorithm:
//! least-squares fit over the basis {1, log₂n, log₂²n} (the family
//! Extra-P reports: O(log² n) with per-θ coefficients), extrapolated
//! beyond the measured range exactly as the paper does.

#[path = "common/mod.rs"]
mod common;
use common::*;
use ilmi::metrics::model::{fit_log_model, r_squared};

fn main() {
    figure_header(
        "Fig. 10",
        "performance model of the new algorithm (fit + extrapolation)",
    );
    let npr = if full_grid() { 1024 } else { 512 };
    for theta in THETAS {
        let mut samples = Vec::new();
        for &ranks in &rank_axis() {
            let base = paper_cfg(ranks, npr, theta);
            let cell = measure(&with_algs(&base, NEW.0, NEW.1));
            let total = (ranks * npr) as f64;
            samples.push((total, cell.conn_s));
        }
        let model = fit_log_model(&samples).expect("fit needs >= 3 scales");
        let r2 = r_squared(&model, &samples);
        println!("\ntheta = {theta}: t(n) = {}", model.formula());
        println!("R^2 = {r2:.4} over measured n = {:?}", samples
            .iter()
            .map(|&(n, _)| n as usize)
            .collect::<Vec<_>>());
        println!("{:>12} {:>14} {:>14}", "n", "measured [s]", "model [s]");
        for &(n, y) in &samples {
            println!("{:>12} {:>14.6} {:>14.6}", n as usize, y, model.eval(n));
        }
        // Extrapolate like the paper ("fitted the trend line and
        // extrapolated it beyond our tests").
        for mult in [4usize, 16, 64] {
            let n = samples.last().unwrap().0 * mult as f64;
            println!("{:>12} {:>14} {:>14.6}", n as usize, "-", model.eval(n));
        }
    }
}
