//! §Perf opt 8 — per-step remote-lookup cost: O(1) slot-interned reads
//! through the epoch-compiled `DeliveryPlan` vs the per-edge O(log P)
//! binary search the naive delivery loop paid (P = remote partners).
//!
//! Two parts:
//!
//! 1. **Differential oracle**: rebuild the naive delivery loop inline
//!    (division + per-edge search, exactly what `spikes::deliver_input`
//!    does) and assert the plan produces bit-identical `i_syn` and the
//!    identical lookup count on a random topology — the bench refuses
//!    to print numbers for a plan that changed semantics.
//! 2. **Microbench**: per-lookup nanoseconds of binary search over a
//!    P-entry sparse table vs one indexed load from the slot-aligned
//!    array, across partner counts. The search column grows with
//!    log₂ P; the slot column stays flat — that gap, multiplied by
//!    (remote edges × steps), is what the plan removes from every run.

#[path = "common/mod.rs"]
mod common;

use std::time::Instant;

use common::figure_header;
use ilmi::config::SimConfig;
use ilmi::neuron::Population;
use ilmi::plasticity::SynapseStore;
use ilmi::spikes::{spike_weight, DeliveryPlan, PartnerFreqs};
use ilmi::util::{Rng, Vec3};

/// The naive oracle, reproduced from the pre-plan delivery loop: per
/// edge per step, one u64 division, one nested-list chase, and the
/// caller's per-id lookup.
fn naive_deliver(
    pop: &mut Population,
    store: &SynapseStore,
    neurons_per_rank: u64,
    my_rank: usize,
    mut remote_spiked: impl FnMut(u64) -> bool,
) -> u64 {
    let mut lookups = 0;
    let first = pop.first_id;
    for local in 0..pop.len() {
        let mut acc = 0.0f32;
        for e in &store.in_edges[local] {
            let src_rank = (e.source / neurons_per_rank) as usize;
            let spiked = if src_rank == my_rank {
                pop.fired[(e.source - first) as usize]
            } else {
                lookups += 1;
                remote_spiked(e.source)
            };
            if spiked {
                acc += spike_weight(e.source_exc);
            }
        }
        pop.i_syn[local] = acc;
    }
    lookups
}

fn oracle_check() {
    let n = 64usize;
    let cfg = SimConfig { neurons_per_rank: n, ..SimConfig::default() };
    let mut rng = Rng::new(2024);
    let mut pop = Population::init(&cfg, 1, Vec3::ZERO, Vec3::splat(10.0), &mut rng);
    let mut store = SynapseStore::new(n, n as u64);
    for _ in 0..n * 8 {
        store.add_in(rng.next_below(n), rng.next_below(4 * n) as u64, rng.bernoulli(0.6));
    }
    for f in pop.fired.iter_mut() {
        *f = rng.bernoulli(0.4);
    }
    let fired = |id: u64| id % 3 == 0; // deterministic stand-in lookup
    let naive = naive_deliver(&mut pop, &store, n as u64, 1, fired);
    let want: Vec<u32> = pop.i_syn.iter().map(|x| x.to_bits()).collect();
    let plan = DeliveryPlan::compile(&store, n as u64);
    plan.check_against(&store).expect("plan must cross-validate");
    let planned = plan.deliver(&mut pop, |slot| fired(plan.remote_ids()[slot]));
    let got: Vec<u32> = pop.i_syn.iter().map(|x| x.to_bits()).collect();
    assert_eq!(naive, planned, "lookup counts diverged");
    assert_eq!(want, got, "i_syn bit patterns diverged");
    println!(
        "oracle check: OK ({} edges, {} remote over {} slots, i_syn bit-identical)",
        plan.edge_count(),
        plan.remote_edge_count(),
        plan.slot_count()
    );
}

fn main() {
    figure_header(
        "Perf opt 8",
        "remote-lookup cost: O(log P) binary search vs O(1) slot read",
    );
    oracle_check();

    let lookups_per_round = 1 << 16;
    println!(
        "\n{:>10} {:>16} {:>16} {:>8}",
        "partners", "search [ns/op]", "slot [ns/op]", "ratio"
    );
    for p in [256usize, 1024, 4096, 16384, 65536] {
        // Sparse table with P entries (every 3rd id, like a real rank's
        // scattered remote partners) and its slot-aligned mirror.
        let mut table = PartnerFreqs::new();
        table.install_epoch((0..p).map(|i| (3 * i as u64, 0.25f32)));
        let slot_ids: Vec<u64> = (0..p).map(|i| 3 * i as u64).collect();
        let mut slots = Vec::new();
        table.fill_slot_thrs(&slot_ids, &mut slots);

        // Pre-draw the access pattern so both sides pay identical
        // index-generation cost.
        let mut rng = Rng::new(p as u64);
        let picks: Vec<usize> = (0..lookups_per_round).map(|_| rng.next_below(p)).collect();

        let t0 = Instant::now();
        let mut acc = 0.0f64;
        for &k in &picks {
            acc += table.get_thr(slot_ids[k]); // binary search per lookup
        }
        let search_ns = t0.elapsed().as_nanos() as f64 / picks.len() as f64;

        let t1 = Instant::now();
        let mut acc2 = 0.0f64;
        for &k in &picks {
            acc2 += slots[k]; // one indexed load
        }
        let slot_ns = t1.elapsed().as_nanos() as f64 / picks.len() as f64;
        assert_eq!(acc.to_bits(), acc2.to_bits(), "lookup paths must agree");

        println!(
            "{:>10} {:>16.2} {:>16.2} {:>8}",
            p,
            search_ns,
            slot_ns,
            common::ratio(search_ns, slot_ns)
        );
    }
    println!("\n(search grows ~log2 P; slot reads stay flat — the per-edge gap the plan removes)");
}
