//! Fig. 4 — weak scaling of spike transmission: per-step spike-id
//! exchange (old) vs Δ-epoch frequency exchange (new).
//!
//! Paper shape to check: old grows super-linearly with rank count
//! (synchronization + channel setup dominate); new stays virtually
//! constant in rank count and is orders of magnitude cheaper (paper:
//! 23 s vs 169 ms at the largest scale).

#[path = "common/mod.rs"]
mod common;
use common::*;
use ilmi::config::ConnectivityAlg;

fn main() {
    figure_header("Fig. 4", "spike/frequency transfer time [s] (weak scaling)");
    for npr in npr_axis() {
        println!("\n--- panel: {npr} neurons per rank ---");
        println!(
            "{:>6} {:>12} {:>12} {:>8}",
            "ranks", "spikes [s]", "freqs [s]", "old/new"
        );
        for &ranks in &rank_axis() {
            // Connectivity algorithm fixed to the new one so only the
            // spike path differs.
            let base = paper_cfg(ranks, npr, 0.3);
            let old = measure(&with_algs(
                &base,
                ConnectivityAlg::NewLocationAware,
                ilmi::config::SpikeAlg::OldIds,
            ));
            let new = measure(&with_algs(
                &base,
                ConnectivityAlg::NewLocationAware,
                ilmi::config::SpikeAlg::NewFrequency,
            ));
            println!(
                "{:>6} {:>12} {:>12} {:>8}",
                ranks,
                s(old.spike_s),
                s(new.spike_s),
                ratio(old.spike_s, new.spike_s)
            );
        }
    }
}
