//! Fig. 5 — remote-spike look-up time: binary search over received id
//! lists (old) vs PRNG reconstruction from frequencies (new).
//!
//! Paper shape to check: both essentially flat in rank count; the PRNG
//! path is somewhat SLOWER (paper: 13 s vs 9.5 s — a 1.5x premium that
//! §VI calls "an insignificant cost compared to the gains").

#[path = "common/mod.rs"]
mod common;
use common::*;
use ilmi::config::{ConnectivityAlg, SpikeAlg};

fn main() {
    figure_header("Fig. 5", "remote spike look-up time [s]: binary search vs PRNG");
    for npr in npr_axis() {
        println!("\n--- panel: {npr} neurons per rank ---");
        println!(
            "{:>6} {:>12} {:>12} {:>10}",
            "ranks", "search [s]", "PRNG [s]", "PRNG/srch"
        );
        for &ranks in &rank_axis() {
            let base = paper_cfg(ranks, npr, 0.3);
            let old = measure(&with_algs(
                &base,
                ConnectivityAlg::NewLocationAware,
                SpikeAlg::OldIds,
            ));
            let new = measure(&with_algs(
                &base,
                ConnectivityAlg::NewLocationAware,
                SpikeAlg::NewFrequency,
            ));
            println!(
                "{:>6} {:>12} {:>12} {:>10}",
                ranks,
                s(old.lookup_s),
                s(new.lookup_s),
                ratio(new.lookup_s, old.lookup_s)
            );
        }
    }
}
