//! Tables I and II — bytes sent (and remotely accessed) over the whole
//! simulation, old algorithms vs new algorithms, across the
//! (ranks × neurons-per-rank) grid.
//!
//! Paper shape to check: at 1 rank nothing crosses the wire in either
//! version except bookkeeping; the old version's RMA traffic explodes
//! with scale (Table I lower entries); the new version sends a bounded,
//! frequency-independent volume (Table II) — overall a ~21x reduction
//! in transferred information at the paper's largest scale.

#[path = "common/mod.rs"]
mod common;
use common::*;
use ilmi::util::format_bytes;

fn main() {
    figure_header("Tables I + II", "transferred bytes over the whole simulation (theta=0.2)");
    let ranks_axis = rank_axis();
    let nprs = npr_axis();

    println!("\nTable I — OLD algorithms: bytes sent (upper) / remotely accessed (lower)");
    print!("{:>8}", "ranks");
    for npr in &nprs {
        print!(" {:>12}", format!("npr {npr}"));
    }
    println!();
    let mut old_cells = Vec::new();
    for &ranks in &ranks_axis {
        let mut sent_row = format!("{ranks:>6} r.");
        let mut rma_row = format!("{:>8}", "");
        for &npr in &nprs {
            let cell = measure(&with_algs(&paper_cfg(ranks, npr, 0.2), OLD.0, OLD.1));
            sent_row.push_str(&format!(" {:>12}", format_bytes(cell.bytes_sent)));
            rma_row.push_str(&format!(" {:>12}", format_bytes(cell.bytes_rma)));
            old_cells.push(cell);
        }
        println!("{sent_row}");
        println!("{rma_row}");
    }

    println!("\nTable II — NEW algorithms: bytes sent (no RMA by construction)");
    print!("{:>8}", "ranks");
    for npr in &nprs {
        print!(" {:>12}", format!("npr {npr}"));
    }
    println!();
    let mut new_cells = Vec::new();
    for &ranks in &ranks_axis {
        let mut sent_row = format!("{ranks:>6} r.");
        for &npr in &nprs {
            let cell = measure(&with_algs(&paper_cfg(ranks, npr, 0.2), NEW.0, NEW.1));
            assert_eq!(cell.bytes_rma, 0, "new algorithms must not RMA");
            sent_row.push_str(&format!(" {:>12}", format_bytes(cell.bytes_sent)));
            new_cells.push(cell);
        }
        println!("{sent_row}");
    }

    // Reduction factor at the largest measured cell (paper: 21x).
    let old_last = old_cells.last().unwrap();
    let new_last = new_cells.last().unwrap();
    let old_total = old_last.bytes_sent + old_last.bytes_rma;
    println!(
        "\nlargest cell ({} ranks x {} npr): old {} (sent+rma) vs new {} -> {:.1}x reduction",
        old_last.ranks,
        old_last.npr,
        format_bytes(old_total),
        format_bytes(new_last.bytes_sent),
        old_total as f64 / new_last.bytes_sent.max(1) as f64
    );
}
