//! §Perf opt 9 — cache-blocked activity update: the fixed-width
//! (`BLOCK_WIDTH` = 64 lane) SoA walk with branchless spike/reset
//! selects vs the straight-line scalar loop.
//!
//! Two parts:
//!
//! 1. **Differential oracle**: run the scalar and blocked kernels over
//!    the same seeded population for hundreds of steps (with a
//!    non-multiple-of-64 size, so the tail block is exercised) and
//!    assert every state array is bit-identical and the model RNG
//!    streams stayed aligned — the bench refuses to print numbers for
//!    a blocked loop that changed semantics.
//! 2. **Microbench**: per-neuron-step nanoseconds of both kernels
//!    across population sizes. Small populations fit L1/L2 either way;
//!    the gap opens where the eight state arrays stop fitting cache and
//!    the blocked walk's reuse (and autovectorized selects) pay off.
//!
//! The companion delivery-side blocking (EDGE_BLOCK chunking of
//! `DeliveryPlan::deliver`, §Perf opt 10) keeps the same accumulation
//! order, so it shares opt 8's oracle rather than needing its own.

#[path = "common/mod.rs"]
mod common;

use std::time::Instant;

use common::figure_header;
use ilmi::config::{KernelKind, SimConfig};
use ilmi::neuron::{make_kernel, NeuronKernel, Population, BLOCK_WIDTH};
use ilmi::util::{Rng, Vec3};

/// A seeded population plus the forked model RNG its kernel consumes.
fn seeded_pop(n: usize, seed: u64) -> (SimConfig, Population, Rng) {
    let cfg = SimConfig { neurons_per_rank: n, ..SimConfig::default() };
    let mut rng = Rng::new(seed);
    let pop = Population::init(&cfg, 1, Vec3::ZERO, Vec3::splat(10.0), &mut rng);
    (cfg, pop, rng)
}

fn kernel_for(cfg: &SimConfig, kind: KernelKind) -> Box<dyn NeuronKernel> {
    let mut c = cfg.clone();
    c.kernel = kind;
    make_kernel(&c, None)
}

fn oracle_check() {
    // 1000 neurons: 15 full blocks + a 40-lane tail.
    let n = 1000usize;
    assert_ne!(n % BLOCK_WIDTH, 0, "the oracle must exercise the tail block");
    let (cfg, mut pop_s, mut rng_s) = seeded_pop(n, 2024);
    let (_, mut pop_b, mut rng_b) = seeded_pop(n, 2024);
    let mut scalar = kernel_for(&cfg, KernelKind::Scalar);
    let mut blocked = kernel_for(&cfg, KernelKind::Blocked);
    assert_eq!(scalar.name(), "scalar");
    assert_eq!(blocked.name(), "blocked");
    for step in 0..300 {
        // The driver's activity phase in miniature: fresh noise, a
        // synthetic synaptic input, one kernel step.
        pop_s.draw_noise(&cfg, &mut rng_s);
        pop_b.draw_noise(&cfg, &mut rng_b);
        for i in 0..n {
            let syn = ((i + step) % 7) as f32;
            pop_s.i_syn[i] = syn;
            pop_b.i_syn[i] = syn;
        }
        scalar.step(&mut pop_s, &cfg, &mut rng_s).unwrap();
        blocked.step(&mut pop_b, &cfg, &mut rng_b).unwrap();
    }
    let spikes: u32 = pop_s.epoch_spikes.iter().sum();
    assert!(spikes > 0, "the oracle workload must actually fire");
    let bits = |xs: &[f32]| xs.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
    assert_eq!(bits(&pop_s.v), bits(&pop_b.v), "v diverged");
    assert_eq!(bits(&pop_s.u), bits(&pop_b.u), "u diverged");
    assert_eq!(bits(&pop_s.ca), bits(&pop_b.ca), "ca diverged");
    assert_eq!(bits(&pop_s.z_ax), bits(&pop_b.z_ax), "z_ax diverged");
    assert_eq!(bits(&pop_s.z_den_exc), bits(&pop_b.z_den_exc), "z_den_exc diverged");
    assert_eq!(bits(&pop_s.z_den_inh), bits(&pop_b.z_den_inh), "z_den_inh diverged");
    assert_eq!(pop_s.fired, pop_b.fired, "fired diverged");
    assert_eq!(pop_s.epoch_spikes, pop_b.epoch_spikes, "epoch_spikes diverged");
    assert_eq!(rng_s.state(), rng_b.state(), "model RNG streams diverged");
    println!(
        "oracle check: OK (300 steps x {n} neurons incl. tail block, {spikes} spikes, \
         all eight state arrays bit-identical)"
    );
}

/// Time `steps` kernel invocations and return ns per neuron-step.
fn time_kernel(
    kernel: &mut dyn NeuronKernel,
    pop: &mut Population,
    cfg: &SimConfig,
    rng: &mut Rng,
    steps: usize,
) -> f64 {
    let t0 = Instant::now();
    for _ in 0..steps {
        kernel.step(pop, cfg, rng).unwrap();
    }
    t0.elapsed().as_nanos() as f64 / (steps * pop.len()) as f64
}

fn main() {
    figure_header(
        "Perf opt 9",
        "cache-blocked activity update: scalar loop vs 64-lane blocked walk",
    );
    oracle_check();

    println!(
        "\n{:>10} {:>8} {:>16} {:>16} {:>8}",
        "neurons", "steps", "scalar [ns/op]", "blocked [ns/op]", "ratio"
    );
    let sizes: &[usize] = if common::full_grid() {
        &[256, 1024, 4096, 16384, 65536, 262144]
    } else {
        &[256, 1024, 4096, 16384, 65536]
    };
    // ILMI_BENCH_STEPS scales the per-size neuron-step budget (default
    // 1000 => 4M neuron-steps per column), so CI can run a quick pass.
    let budget = 4_000 * common::bench_steps();
    for &n in sizes {
        // Same per-size work budget either way, so rows take comparable
        // wall time; noise is drawn once — the kernels only read it.
        let steps = (budget / n).max(4);
        let (cfg, mut pop_s, mut rng_s) = seeded_pop(n, n as u64);
        let (_, mut pop_b, mut rng_b) = seeded_pop(n, n as u64);
        pop_s.draw_noise(&cfg, &mut rng_s);
        pop_b.draw_noise(&cfg, &mut rng_b);
        let mut scalar = kernel_for(&cfg, KernelKind::Scalar);
        let mut blocked = kernel_for(&cfg, KernelKind::Blocked);
        // Warm the caches/branch predictor once per column.
        scalar.step(&mut pop_s, &cfg, &mut rng_s).unwrap();
        blocked.step(&mut pop_b, &cfg, &mut rng_b).unwrap();
        let scalar_ns = time_kernel(&mut *scalar, &mut pop_s, &cfg, &mut rng_s, steps);
        let blocked_ns = time_kernel(&mut *blocked, &mut pop_b, &cfg, &mut rng_b, steps);
        // The timed trajectories must agree too — identical inputs,
        // identical kernels, so any divergence is a semantics bug.
        assert_eq!(
            pop_s.v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>(),
            pop_b.v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>(),
            "timed runs diverged at n = {n}"
        );
        println!(
            "{:>10} {:>8} {:>16.2} {:>16.2} {:>8}",
            n,
            steps,
            scalar_ns,
            blocked_ns,
            common::ratio(scalar_ns, blocked_ns)
        );
    }
    println!(
        "\n(both columns are bit-identical by construction; the gap is pure cache/\
         vectorization — multiply by neurons x steps for the per-run saving)"
    );
}
