//! Shared bench harness. `criterion` is not in the offline crate set, so
//! each bench target is a `harness = false` binary that runs the paper's
//! workload (§V-B: 1000 steps, 10 plasticity updates, no initial
//! connectivity, 1.1–1.5 vacant elements) across a parameter grid and
//! prints the same rows/series the paper's figure reports.
//!
//! Environment knobs:
//!   ILMI_BENCH_FULL=1    use the full grid (ranks up to 32, npr 4096)
//!   ILMI_BENCH_STEPS=N   override the 1000-step workload length

#![allow(dead_code)]

use ilmi::config::{ConnectivityAlg, SimConfig, SpikeAlg};
use ilmi::coordinator::run_simulation;
use ilmi::metrics::{Phase, SimReport};

/// One measured cell of a figure/table.
#[derive(Clone, Copy, Debug)]
pub struct Cell {
    pub ranks: usize,
    pub npr: usize,
    pub theta: f64,
    /// Connectivity-update time: target search + request/response
    /// exchanges (what Fig. 3/6 plot).
    pub conn_s: f64,
    /// Spike/frequency transfer time (Fig. 4/7).
    pub spike_s: f64,
    /// Remote look-up time: binary search / PRNG (Fig. 5).
    pub lookup_s: f64,
    pub bytes_sent: u64,
    pub bytes_rma: u64,
    pub wall_s: f64,
    pub synapses: usize,
}

pub fn full_grid() -> bool {
    std::env::var("ILMI_BENCH_FULL").map(|v| v == "1").unwrap_or(false)
}

pub fn bench_steps() -> usize {
    std::env::var("ILMI_BENCH_STEPS").ok().and_then(|v| v.parse().ok()).unwrap_or(1000)
}

/// Weak-scaling rank axis (paper: 1..1024; scaled to this box).
pub fn rank_axis() -> Vec<usize> {
    if full_grid() {
        vec![1, 2, 4, 8, 16, 32]
    } else {
        vec![1, 2, 4, 8, 16]
    }
}

/// Neurons-per-rank axis (paper: 1024..65,536; scaled).
pub fn npr_axis() -> Vec<usize> {
    if full_grid() {
        vec![256, 1024, 4096]
    } else {
        vec![256, 1024]
    }
}

pub const THETAS: [f64; 3] = [0.2, 0.3, 0.4];

pub fn paper_cfg(ranks: usize, npr: usize, theta: f64) -> SimConfig {
    let mut cfg = SimConfig::paper_timing(ranks, npr, theta);
    cfg.steps = bench_steps();
    cfg
}

/// Run one configuration and extract the figure quantities.
pub fn measure(cfg: &SimConfig) -> Cell {
    let report = run_simulation(cfg).expect("bench simulation failed");
    cell_from(cfg, &report)
}

pub fn cell_from(cfg: &SimConfig, report: &SimReport) -> Cell {
    Cell {
        ranks: cfg.ranks,
        npr: cfg.neurons_per_rank,
        theta: cfg.theta,
        conn_s: report.phase_max(Phase::BarnesHut) + report.phase_max(Phase::SynapseExchange),
        spike_s: report.phase_max(Phase::SpikeExchange),
        lookup_s: report.phase_max(Phase::SpikeLookup),
        bytes_sent: report.total_bytes_sent(),
        bytes_rma: report.total_bytes_rma(),
        wall_s: report.wall_seconds,
        synapses: report.total_synapses(),
    }
}

pub fn with_algs(cfg: &SimConfig, conn: ConnectivityAlg, spikes: SpikeAlg) -> SimConfig {
    SimConfig { connectivity_alg: conn, spike_alg: spikes, ..cfg.clone() }
}

pub const OLD: (ConnectivityAlg, SpikeAlg) = (ConnectivityAlg::OldRma, SpikeAlg::OldIds);
pub const NEW: (ConnectivityAlg, SpikeAlg) =
    (ConnectivityAlg::NewLocationAware, SpikeAlg::NewFrequency);

/// Print a figure header in a consistent format.
pub fn figure_header(name: &str, what: &str) {
    println!("==========================================================");
    println!("{name}: {what}");
    println!("workload: {} steps, {} plasticity updates, no initial connectivity",
        bench_steps(), bench_steps() / 100);
    println!("==========================================================");
}

/// Seconds with µs resolution.
pub fn s(x: f64) -> String {
    format!("{x:.6}")
}

/// Ratio formatted as "x.xx".
pub fn ratio(old: f64, new: f64) -> String {
    if new <= 0.0 {
        "inf".into()
    } else {
        format!("{:.2}", old / new)
    }
}
