//! Fig. 6 — STRONG scaling of the new location-aware Barnes–Hut: total
//! neuron count fixed (paper: 65,536 and 1,048,576; scaled here),
//! rank count varies.

#[path = "common/mod.rs"]
mod common;
use common::*;

fn main() {
    figure_header(
        "Fig. 6",
        "connectivity-update time [s], new algorithm (strong scaling)",
    );
    let totals: &[usize] = if full_grid() { &[8192, 65536] } else { &[4096, 16384] };
    for &total in totals {
        println!("\n--- panel: {total} total neurons ---");
        println!("{:>6} {:>8} {:>6} {:>12}", "ranks", "npr", "theta", "new [s]");
        for theta in THETAS {
            for &ranks in &rank_axis() {
                if total / ranks < 32 {
                    continue;
                }
                let base = paper_cfg(ranks, total / ranks, theta);
                let new = measure(&with_algs(&base, NEW.0, NEW.1));
                println!(
                    "{:>6} {:>8} {:>6.1} {:>12}",
                    ranks,
                    total / ranks,
                    theta,
                    s(new.conn_s)
                );
            }
        }
    }
}
