//! `ilmi` — leader entrypoint.
//!
//! Subcommands:
//!   simulate   run one simulation and print the phase/byte report;
//!              `--checkpoint-every N --checkpoint-dir D` writes a
//!              resumable snapshot every N steps (both flags required
//!              together)
//!   resume     continue (or branch) a simulation from a snapshot file:
//!              `resume --from FILE` or `resume --dir D` (newest
//!              snapshot in D). The snapshot embeds its config
//!              (`--config FILE` overrides it); `--steps T` raises the
//!              total schedule, `--set`/`--xla` override further.
//!              Resume is bit-exact and refuses a
//!              config whose dynamics fingerprint differs from the
//!              snapshot's; pass `--branch` to deliberately fork a new
//!              scenario (e.g. changed background input) from the
//!              saved brain instead
//!   compare    run old vs new algorithms on the same workload, print
//!              the speedups (the paper's headline numbers, scaled)
//!   bench      run a scenario matrix ({old,new} x ranks x neurons x
//!              delta x firing regime), write a versioned BENCH_*.json
//!              (per-phase medians, bytes, collective counts) plus a
//!              markdown table; `--baseline FILE` diffs against a prior
//!              report and fails on regressions beyond `--threshold`
//!   quality    the §V-D calcium-quality experiment (Figs. 8/9), CSV out
//!   inspect    load + exercise the AOT artifacts through PJRT
//!   status     render the live fleet table from the status.json a
//!              supervised socket run maintains under `--status-dir`
//!
//! Common flags: --config FILE, --set section.key=value (repeatable),
//! --csv PATH, --xla (use the AOT artifacts for the neuron update),
//! --kernel scalar|blocked|xla (which `NeuronKernel` backend executes
//! the activity update; bit-identical, DESIGN.md §12).
//! `--trace-out FILE` (simulate/resume) records the epoch-granular
//! telemetry ring and exports a Chrome trace JSON plus a JSONL time
//! series at run end; `--trace-every`/`--trace-capacity` tune cadence
//! and ring depth.

use anyhow::{anyhow, bail, Result};

use ilmi::cli::Args;
use ilmi::config::{Backend, CommBackend, ConnectivityAlg, SimConfig, SpikeAlg};
use ilmi::coordinator::{
    branch_simulation_with_xla, resume_simulation, resume_simulation_with_xla, run_simulation,
    run_simulation_with_xla,
};
use ilmi::runtime::spawn_service;
use ilmi::snapshot::{latest_snapshot_in, Snapshot};

fn main() {
    // Socket-backend rank processes re-exec this binary; when the
    // rendezvous env vars are present this call runs the rank body and
    // exits instead of falling through to the CLI.
    #[cfg(unix)]
    ilmi::comm::proc::maybe_run_child(ilmi::coordinator::SOCKET_ENTRIES);
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(argv: &[String]) -> Result<()> {
    // `status` takes a positional directory, which the flag grammar
    // rejects; dispatch it before Args::parse.
    if argv.first().map(String::as_str) == Some("status") {
        return cmd_status(&argv[1..]);
    }
    let args = Args::parse(argv).map_err(anyhow::Error::msg)?;
    match args.subcommand.as_str() {
        "simulate" => cmd_simulate(&args),
        "resume" => cmd_resume(&args),
        "compare" => cmd_compare(&args),
        "bench" => cmd_bench(&args),
        "quality" => cmd_quality(&args),
        "inspect" => cmd_inspect(&args),
        "" | "help" | "-h" | "--help" => {
            print!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown subcommand {other:?}; try `ilmi help`"),
    }
}

const HELP: &str = "\
ilmi - I Like To Move It: structural-plasticity brain simulation
usage: ilmi <simulate|resume|compare|bench|quality|inspect|status> [flags]
  simulate  --config FILE --set k=v ... [--csv PATH] [--xla]
            [--kernel scalar|blocked|xla]
              neuron-kernel backend for the activity update: scalar
              reference loop (default), cache-blocked SoA loop, or the
              staged XLA path (needs --xla artifacts). All three are
              bit-identical (DESIGN.md SS12) - the flag trades speed,
              never trajectory
            [--comm thread|socket]
              communication backend: in-process threads (default) or
              one OS process per rank over Unix domain sockets; both
              produce bit-identical results (DESIGN.md SS11). The
              socket backend excludes --xla
            [--checkpoint-every N --checkpoint-dir D]
              write a resumable snapshot every N steps into D
              (both flags are required together)
            [--checkpoint-keep K]
              retain only the newest K complete snapshots (plus any
              part-file scraps newer than them); 0 = keep all
            [--fault SPEC ...] [--max-recoveries R]
              deterministic fault injection (socket backend only):
              kill:rank=R,step=S / frame_truncate:rank=R,nth=N,keep=B /
              frame_delay:rank=R,nth=N,ms=M / rma_stall:rank=R,nth=N,ms=M /
              ckpt_fail:step=S / ckpt_corrupt:step=S, each optionally
              suffixed ,attempt=A (default 0: first launch only);
              repeat --fault to combine. --max-recoveries R arms the
              supervisor: when a rank process dies, the fleet is
              killed, reaped, and relaunched from the newest VALID
              checkpoint (falling back past corrupt ones), at most R
              times, bit-identically (DESIGN.md SS13)
            [--balance-every N] [--balance-threshold X]
              migrate neurons between ranks whenever max/mean step
              cost exceeds X, checked every N steps (N must be a
              multiple of the plasticity interval; 0 = off). The
              initial skew, move budget and cell split come from
              --set balance.init_cells=.. / balance.max_moves=..
            [--trace-out FILE] [--trace-every N] [--trace-capacity C]
              sample per-rank phase/comm/plasticity deltas every N
              steps (default: the plasticity interval) into a ring of
              C samples per rank, then export FILE (Chrome trace JSON,
              open in Perfetto) plus the FILE.jsonl time series
            [--telemetry-every N] [--watchdog-misses K] [--status-dir D]
              socket backend only: every rank streams a health frame
              (step, phase/comm deltas, rss) to the supervisor every N
              steps over the control socket. K missed beats trip the
              hang watchdog, which routes the stalled fleet into the
              checkpoint-restart recovery loop (needs --max-recoveries
              and checkpointing). D aggregates the beats into an
              atomically-rewritten status.json that `ilmi status D`
              renders while the run is live. Observation only: on or
              off, final snapshots are byte-identical (DESIGN.md SS14)
  resume    (--from FILE | --dir D) [--steps T] [--config FILE]
            [--set k=v ...] [--csv PATH] [--xla] [--branch]
            [--kernel scalar|blocked|xla]
              kernels are excluded from the dynamics fingerprint, so a
              snapshot may resume under a different kernel bit-exactly
            [--comm thread|socket]
              socket resume ships the snapshot PATH to the rank fleet,
              which restores bit-exactly (excludes --branch and --xla)
            [--checkpoint-every N --checkpoint-dir D]
            [--trace-out FILE] [--trace-every N] [--trace-capacity C]
              trace the resumed segment (the snapshot's trace knobs
              never carry over; samples cover post-resume steps only)
              continue a run from a snapshot, bit-exactly. The snapshot
              embeds its config (--config FILE overrides it); --steps T
              sets the TOTAL schedule length (must exceed the
              snapshot's completed steps). --dir D picks the newest
              snapshot in D. A config whose dynamics differ from the
              snapshot's is refused unless --branch is given, which
              forks a new scenario (same brain, different protocol)
              from the saved state.
  compare   --set k=v ... (runs old-vs-new on the same workload)
  bench     [--preset smoke|smoke8|smoke-skew|quick|full] [--name NAME] [--out FILE]
            [--steps N] [--warmup N] [--reps N] [--seed S]
            [--comm thread|socket] [--kernel scalar|blocked]
              run every cell on the given neuron-kernel backend; the
              drift-checked counters are kernel-independent, so kernel
              reports compare cell-for-cell (ids gain a _k suffix)
            [--md FILE] [--baseline FILE] [--threshold PCT]
              run the scenario matrix ({old,new} x ranks x neurons x
              delta x regime) and write BENCH_<name>.json (per-phase
              median/min/max seconds, bytes, collective counts) plus a
              markdown table (--md saves it). --baseline diffs against
              a prior report of the SAME matrix (fingerprint-checked)
              and exits nonzero on timing regressions beyond
              --threshold percent (default 20) or any counter drift.
              See EXPERIMENTS.md SSBench.
  quality   [--steps N] [--csv PATH] [--old] (paper SS V-D, Figs 8/9)
  inspect   [--artifacts DIR] (load artifacts, run one batch through PJRT)
  status    <status-dir>
              print the per-rank fleet table (state, step, beats, rss,
              comm deltas, imbalance) from the status.json a supervised
              run maintains under --status-dir; safe to run repeatedly
              while the fleet is live (reads are atomic via rename)
";

fn build_config(args: &Args) -> Result<SimConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => SimConfig::from_file(path).map_err(anyhow::Error::msg)?,
        None => SimConfig::default(),
    };
    apply_set_flags(&mut cfg, args)?;
    if args.get_bool("xla") {
        cfg.backend = Backend::Xla;
    }
    apply_kernel_flag(&mut cfg, args)?;
    apply_comm_flag(&mut cfg, args)?;
    apply_checkpoint_flags(&mut cfg, args)?;
    apply_fault_flags(&mut cfg, args)?;
    apply_balance_flags(&mut cfg, args)?;
    apply_trace_flags(&mut cfg, args)?;
    apply_telemetry_flags(&mut cfg, args)?;
    cfg.validate().map_err(anyhow::Error::msg)?;
    Ok(cfg)
}

/// Map `--fault SPEC` (repeatable; specs join into one `;`-separated
/// plan), `--checkpoint-keep K`, and `--max-recoveries R` into the
/// config. All three are execution-robustness knobs, never dynamics:
/// none is part of the snapshot fingerprint, and `to_ini` never embeds
/// the fault plan, so a faulted run's checkpoints are byte-identical to
/// a clean run's (DESIGN.md §13).
fn apply_fault_flags(cfg: &mut SimConfig, args: &Args) -> Result<()> {
    let faults = args.get_all("fault");
    if !faults.is_empty() {
        cfg.fault_plan = faults.join(";");
    }
    if let Some(keep) = args.get_parse::<usize>("checkpoint-keep").map_err(anyhow::Error::msg)? {
        cfg.checkpoint_keep = keep;
    }
    if let Some(max) = args.get_parse::<usize>("max-recoveries").map_err(anyhow::Error::msg)? {
        cfg.max_recoveries = max;
    }
    Ok(())
}

/// Map `--kernel scalar|blocked|xla` onto `compute.kernel` — the
/// `NeuronKernel` backend executing the activity update. Execution
/// strategy, not dynamics: all three are bit-identical (DESIGN.md §12),
/// so the flag is free to vary between a checkpoint and its resume.
fn apply_kernel_flag(cfg: &mut SimConfig, args: &Args) -> Result<()> {
    if let Some(kernel) = args.get("kernel") {
        cfg.apply_kv("compute.kernel", kernel).map_err(anyhow::Error::msg)?;
    }
    Ok(())
}

/// Map `--comm thread|socket` onto `topology.comm` — the communication
/// backend: in-process threads (default) or one OS process per rank
/// over Unix domain sockets (DESIGN.md §11).
fn apply_comm_flag(cfg: &mut SimConfig, args: &Args) -> Result<()> {
    if let Some(backend) = args.get("comm") {
        cfg.apply_kv("topology.comm", backend).map_err(anyhow::Error::msg)?;
    }
    Ok(())
}

/// Map `--balance-every N` / `--balance-threshold X` into the config
/// (the remaining balance knobs go through `--set balance.*`).
fn apply_balance_flags(cfg: &mut SimConfig, args: &Args) -> Result<()> {
    if let Some(every) = args.get_parse::<usize>("balance-every").map_err(anyhow::Error::msg)? {
        cfg.balance_every = every;
    }
    if let Some(thr) = args.get_parse::<f64>("balance-threshold").map_err(anyhow::Error::msg)? {
        cfg.balance_threshold = thr;
    }
    Ok(())
}

/// Map `--trace-out FILE` / `--trace-every N` / `--trace-capacity N`
/// into the config. Giving only `--trace-out` turns tracing on at the
/// natural cadence — one sample per plasticity epoch — so the common
/// "just record a trace" invocation needs a single flag.
fn apply_trace_flags(cfg: &mut SimConfig, args: &Args) -> Result<()> {
    if let Some(out) = args.get("trace-out") {
        cfg.trace_out = out.to_string();
    }
    if let Some(every) = args.get_parse::<usize>("trace-every").map_err(anyhow::Error::msg)? {
        cfg.trace_every = every;
    }
    if let Some(cap) = args.get_parse::<usize>("trace-capacity").map_err(anyhow::Error::msg)? {
        cfg.trace_capacity = cap;
    }
    if !cfg.trace_out.is_empty() && cfg.trace_every == 0 {
        cfg.trace_every = cfg.plasticity_interval;
    }
    Ok(())
}

/// Write the Chrome-trace JSON and JSONL time series next to each other
/// when the run was configured with a trace output path.
fn write_trace_exports(cfg: &SimConfig, report: &ilmi::metrics::SimReport) -> Result<()> {
    if cfg.trace_out.is_empty() {
        return Ok(());
    }
    let (chrome_path, jsonl_path) = ilmi::trace::export_paths(&cfg.trace_out);
    std::fs::write(&chrome_path, ilmi::trace::chrome_trace(report))?;
    std::fs::write(&jsonl_path, ilmi::trace::trace_jsonl(report))?;
    println!(
        "wrote {chrome_path} ({} events; load in Perfetto / chrome://tracing) and {jsonl_path}",
        report.trace_events()
    );
    Ok(())
}

/// Apply every repeated `--set section.key=value` override.
fn apply_set_flags(cfg: &mut SimConfig, args: &Args) -> Result<()> {
    for kv in args.get_all("set") {
        let (k, v) = kv
            .split_once('=')
            .ok_or_else(|| anyhow!("--set expects section.key=value, got {kv:?}"))?;
        cfg.apply_kv(k.trim(), v.trim()).map_err(anyhow::Error::msg)?;
    }
    Ok(())
}

/// Map `--checkpoint-every N` / `--checkpoint-dir D` into the config,
/// rejecting the combination `validate` cannot express a CLI-worded
/// error for.
fn apply_checkpoint_flags(cfg: &mut SimConfig, args: &Args) -> Result<()> {
    if let Some(every) = args.get_parse::<usize>("checkpoint-every").map_err(anyhow::Error::msg)? {
        cfg.checkpoint_every = every;
    }
    if let Some(dir) = args.get("checkpoint-dir") {
        cfg.checkpoint_dir = dir.to_string();
    }
    if cfg.checkpoint_every > 0 && cfg.checkpoint_dir.is_empty() {
        bail!(
            "--checkpoint-every needs --checkpoint-dir: snapshots must have a \
             directory to be written to"
        );
    }
    Ok(())
}

/// Map `--telemetry-every N` / `--watchdog-misses K` / `--status-dir D`
/// into the config. Pure observation: none of the three is serialized
/// into snapshots, counted in `CommCounters`, or part of the dynamics
/// fingerprint (DESIGN.md §14).
fn apply_telemetry_flags(cfg: &mut SimConfig, args: &Args) -> Result<()> {
    if let Some(every) = args.get_parse::<u64>("telemetry-every").map_err(anyhow::Error::msg)? {
        cfg.telemetry_every = every;
    }
    if let Some(k) = args.get_parse::<u32>("watchdog-misses").map_err(anyhow::Error::msg)? {
        cfg.telemetry_watchdog_misses = k;
    }
    if let Some(dir) = args.get("status-dir") {
        cfg.status_dir = dir.to_string();
    }
    Ok(())
}

/// `ilmi status <dir>`: render the status.json a supervised run
/// maintains under `--status-dir` as a per-rank table. Read-only — it
/// never touches the run it observes.
fn cmd_status(rest: &[String]) -> Result<()> {
    let [dir] = rest else {
        bail!("usage: ilmi status <status-dir>  (the --status-dir of a live run)");
    };
    let text = ilmi::telemetry::render_status(std::path::Path::new(dir))
        .map_err(anyhow::Error::msg)?;
    print!("{text}");
    Ok(())
}

/// Socket-backend resume: the rank fleet restores from the on-disk
/// snapshot file (processes cannot share the in-memory one).
#[cfg(unix)]
fn resume_socket(cfg: &SimConfig, path: &std::path::Path) -> Result<ilmi::metrics::SimReport> {
    ilmi::coordinator::resume_simulation_socket(cfg, path)
}

#[cfg(not(unix))]
fn resume_socket(_cfg: &SimConfig, _path: &std::path::Path) -> Result<ilmi::metrics::SimReport> {
    bail!("the socket backend requires Unix domain sockets; use the thread backend")
}

fn run_with_backend(cfg: &SimConfig) -> Result<ilmi::metrics::SimReport> {
    if cfg.backend == Backend::Xla {
        let handle = spawn_service(&cfg.artifacts_dir)?;
        let report = run_simulation_with_xla(cfg, Some(handle.clone()));
        handle.shutdown();
        report
    } else {
        run_simulation(cfg)
    }
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let cfg = build_config(args)?;
    println!(
        "simulate: {} ranks x {} neurons, {} steps, theta={}, conn={:?}, spikes={:?}, backend={:?}",
        cfg.ranks, cfg.neurons_per_rank, cfg.steps, cfg.theta, cfg.connectivity_alg,
        cfg.spike_alg, cfg.backend
    );
    let report = run_with_backend(&cfg)?;
    print!("{}", report.phase_table());
    if let Some(path) = args.get("csv") {
        std::fs::write(path, report.to_csv())?;
        println!("wrote {path}");
    }
    write_trace_exports(&cfg, &report)?;
    Ok(())
}

fn cmd_resume(args: &Args) -> Result<()> {
    let path = match (args.get("from"), args.get("dir")) {
        (Some(file), None) => std::path::PathBuf::from(file),
        (None, Some(dir)) => latest_snapshot_in(dir).map_err(anyhow::Error::msg)?,
        (Some(_), Some(_)) => bail!("pass either --from FILE or --dir D, not both"),
        (None, None) => bail!("resume needs --from FILE or --dir D; see `ilmi help`"),
    };
    let snap = Snapshot::read_file(&path).map_err(anyhow::Error::msg)?;
    // The snapshot embeds its config; an explicit --config FILE takes
    // precedence (needed when the original run used parameters that are
    // not INI-expressible, which the embedded config cannot reproduce).
    let mut cfg = match args.get("config") {
        Some(file) => SimConfig::from_file(file).map_err(anyhow::Error::msg)?,
        None => {
            let mut cfg = snap.config().map_err(anyhow::Error::msg)?;
            // Checkpointing, tracing, and fault/recovery settings of
            // the original run do not auto-carry over: resuming into
            // the same directory (or overwriting the original trace
            // file, or re-injecting faults) is opt-in via the flags
            // below.
            cfg.checkpoint_every = 0;
            cfg.checkpoint_dir = String::new();
            cfg.checkpoint_keep = 0;
            cfg.trace_every = 0;
            cfg.trace_out = String::new();
            cfg.fault_plan = String::new();
            cfg.max_recoveries = 0;
            cfg
        }
    };
    apply_set_flags(&mut cfg, args)?;
    if let Some(steps) = args.get_parse::<usize>("steps").map_err(anyhow::Error::msg)? {
        cfg.steps = steps;
    }
    if args.get_bool("xla") {
        cfg.backend = Backend::Xla;
    }
    apply_kernel_flag(&mut cfg, args)?;
    apply_comm_flag(&mut cfg, args)?;
    apply_checkpoint_flags(&mut cfg, args)?;
    apply_fault_flags(&mut cfg, args)?;
    apply_balance_flags(&mut cfg, args)?;
    apply_trace_flags(&mut cfg, args)?;
    apply_telemetry_flags(&mut cfg, args)?;
    cfg.validate().map_err(anyhow::Error::msg)?;

    let branch = args.get_bool("branch");
    println!(
        "resume: {} (step {} of {}), {} ranks x {} neurons, conn={:?}, spikes={:?}{}",
        path.display(),
        snap.next_step(),
        cfg.steps,
        cfg.ranks,
        cfg.neurons_per_rank,
        cfg.connectivity_alg,
        cfg.spike_alg,
        if branch { " [BRANCH: dynamics may differ from the snapshot]" } else { "" },
    );
    let report = if cfg.comm_backend == CommBackend::Socket {
        if branch {
            bail!(
                "the socket backend cannot --branch: branching deliberately relaxes \
                 the fingerprint check, which the rank fleet re-validates strictly; \
                 use the thread backend to fork scenarios"
            );
        }
        resume_socket(&cfg, &path)?
    } else if cfg.backend == Backend::Xla {
        let handle = spawn_service(&cfg.artifacts_dir)?;
        let report = if branch {
            branch_simulation_with_xla(&cfg, &snap, Some(handle.clone()))
        } else {
            resume_simulation_with_xla(&cfg, &snap, Some(handle.clone()))
        };
        handle.shutdown();
        report?
    } else if branch {
        branch_simulation_with_xla(&cfg, &snap, None)?
    } else {
        resume_simulation(&cfg, &snap)?
    };
    print!("{}", report.phase_table());
    if let Some(csv) = args.get("csv") {
        std::fs::write(csv, report.to_csv())?;
        println!("wrote {csv}");
    }
    write_trace_exports(&cfg, &report)?;
    Ok(())
}

fn cmd_compare(args: &Args) -> Result<()> {
    let base = build_config(args)?;

    let mut old_cfg = base.clone();
    old_cfg.connectivity_alg = ConnectivityAlg::OldRma;
    old_cfg.spike_alg = SpikeAlg::OldIds;
    let mut new_cfg = base.clone();
    new_cfg.connectivity_alg = ConnectivityAlg::NewLocationAware;
    new_cfg.spike_alg = SpikeAlg::NewFrequency;

    println!(
        "compare: {} ranks x {} neurons, {} steps, theta={}",
        base.ranks, base.neurons_per_rank, base.steps, base.theta
    );
    println!("-- old algorithms (RMA Barnes-Hut + per-step spike ids) --");
    let old = run_with_backend(&old_cfg)?;
    print!("{}", old.phase_table());
    println!("-- new algorithms (location-aware + frequency approximation) --");
    let new = run_with_backend(&new_cfg)?;
    print!("{}", new.phase_table());

    use ilmi::metrics::Phase;
    let conn_old = old.phase_max(Phase::BarnesHut) + old.phase_max(Phase::SynapseExchange);
    let conn_new = new.phase_max(Phase::BarnesHut) + new.phase_max(Phase::SynapseExchange);
    let spike_old = old.phase_max(Phase::SpikeExchange);
    let spike_new = new.phase_max(Phase::SpikeExchange);
    let bytes_old = old.total_bytes_sent() + old.total_bytes_rma();
    let bytes_new = new.total_bytes_sent() + new.total_bytes_rma();
    println!("== speedups (old/new) ==");
    println!("connectivity update: {:.2}x", conn_old / conn_new.max(1e-12));
    println!("spike transmission:  {:.2}x", spike_old / spike_new.max(1e-12));
    println!(
        "transferred data:    {:.2}x ({} -> {})",
        bytes_old as f64 / bytes_new.max(1) as f64,
        ilmi::util::format_bytes(bytes_old),
        ilmi::util::format_bytes(bytes_new)
    );
    println!("wall clock:          {:.2}x", old.wall_seconds / new.wall_seconds.max(1e-12));

    // Re-price the counted communication on cluster-class networks
    // (see metrics::netmodel): what the byte/message/RMA accounting
    // would cost on the paper's testbed rather than shared memory.
    for (name, model) in [
        ("HDR100 (paper-class)", ilmi::metrics::NetModel::hdr100()),
        ("25GbE", ilmi::metrics::NetModel::ethernet25g()),
    ] {
        let price = |r: &ilmi::metrics::SimReport| {
            model.price_run(&r.ranks.iter().map(|x| x.comm).collect::<Vec<_>>())
        };
        let (po, pn) = (price(&old), price(&new));
        println!(
            "modeled comm on {name}: {po:.4}s -> {pn:.4}s ({:.1}x)",
            po / pn.max(1e-12)
        );
    }
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    let preset_name = args.get("preset").unwrap_or("quick");
    let (mut spec, mut settings) =
        ilmi::bench::preset(preset_name).map_err(anyhow::Error::msg)?;
    if let Some(v) = args.get_parse::<usize>("steps").map_err(anyhow::Error::msg)? {
        settings.steps = v;
    }
    if let Some(v) = args.get_parse::<usize>("warmup").map_err(anyhow::Error::msg)? {
        settings.warmup = v;
    }
    if let Some(v) = args.get_parse::<usize>("reps").map_err(anyhow::Error::msg)? {
        settings.reps = v;
    }
    if let Some(v) = args.get_parse::<u64>("seed").map_err(anyhow::Error::msg)? {
        settings.seed = v;
    }
    if let Some(kernel) = args.get("kernel") {
        let kind = ilmi::config::KernelKind::from_name(kernel)
            .ok_or_else(|| anyhow!("--kernel expects scalar or blocked, got {kernel:?}"))?;
        if kind == ilmi::config::KernelKind::Xla {
            bail!(
                "bench --kernel xla is not supported: bench cells run without an XLA \
                 executor handle, so the xla kernel would silently fall back to scalar \
                 and mislabel every cell (use scalar or blocked)"
            );
        }
        spec.kernels = vec![kind];
    }
    let name = args.get("name").unwrap_or(preset_name).to_string();
    let out = args
        .get("out")
        .map(String::from)
        .unwrap_or_else(|| format!("BENCH_{name}.json"));
    let threshold =
        args.get_parse::<f64>("threshold").map_err(anyhow::Error::msg)?.unwrap_or(20.0) / 100.0;

    // Load the baseline BEFORE any write: --out may name the same file
    // (the "diff, then update the baseline in place" workflow), and the
    // diff must run against the old content, never the fresh report.
    let baseline = match args.get("baseline") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .map_err(|e| anyhow!("cannot read baseline {path}: {e}"))?;
            let parsed = ilmi::bench::BenchReport::from_json(&text)
                .map_err(|e| anyhow!("baseline {path}: {e}"))?;
            Some((path.to_string(), parsed))
        }
        None => None,
    };
    let out_is_baseline = baseline
        .as_ref()
        .is_some_and(|(path, _)| std::path::Path::new(path) == std::path::Path::new(&out));

    let backend = match args.get("comm") {
        None | Some("thread") => ilmi::config::CommBackend::Thread,
        Some("socket") => ilmi::config::CommBackend::Socket,
        Some(other) => bail!("--comm expects thread or socket, got {other:?}"),
    };
    let report =
        ilmi::bench::run_matrix_with_backend(&name, &spec, &settings, backend, |msg| {
            println!("{msg}")
        })?;
    let json = report.to_json();
    // Self-check: the emitted document must parse back under the schema
    // (which requires all seven phases per scenario) and reproduce its
    // own fingerprint — this is what the CI smoke run relies on.
    ilmi::bench::BenchReport::from_json(&json)
        .map_err(|e| anyhow!("emitted report fails its own schema: {e}"))?;
    let write_out = || -> Result<()> {
        std::fs::write(&out, &json)?;
        println!(
            "wrote {out} ({} scenarios, fingerprint {:016x})",
            report.results.len(),
            report.fingerprint()
        );
        Ok(())
    };
    if !out_is_baseline {
        write_out()?;
    }
    let md = report.markdown_table();
    print!("{md}");
    if let Some(path) = args.get("md") {
        std::fs::write(path, &md)?;
        println!("wrote {path}");
    }
    if let Some((baseline_path, baseline)) = &baseline {
        let diff = report.diff(baseline, threshold).map_err(anyhow::Error::msg)?;
        print!("{}", diff.render());
        if diff.regressions() > 0 {
            bail!(
                "{} regression(s) against {baseline_path} (threshold {:.0}%){}",
                diff.regressions(),
                threshold * 100.0,
                if out_is_baseline { "; baseline file left untouched" } else { "" }
            );
        }
    }
    if out_is_baseline {
        // Clean diff: now it is safe to roll the baseline forward.
        write_out()?;
    }
    Ok(())
}

fn cmd_quality(args: &Args) -> Result<()> {
    let steps = args.get_parse::<usize>("steps").map_err(anyhow::Error::msg)?.unwrap_or(20_000);
    let mut cfg = SimConfig::paper_quality(steps);
    if args.get_bool("old") {
        cfg.spike_alg = SpikeAlg::OldIds;
        cfg.connectivity_alg = ConnectivityAlg::OldRma;
    }
    apply_set_flags(&mut cfg, args)?;
    let report = run_simulation(&cfg)?;
    print!("{}", report.phase_table());
    // CSV: step, ca_0..ca_31 (one column per neuron; one neuron per rank).
    if let Some(path) = args.get("csv") {
        let mut csv = String::from("step");
        for r in 0..cfg.ranks {
            csv.push_str(&format!(",ca_{r}"));
        }
        csv.push('\n');
        let steps_recorded = report.ranks[0].calcium_trace.len();
        for k in 0..steps_recorded {
            csv.push_str(&report.ranks[0].calcium_trace[k].0.to_string());
            for r in &report.ranks {
                csv.push_str(&format!(",{:.5}", r.calcium_trace[k].1[0]));
            }
            csv.push('\n');
        }
        std::fs::write(path, csv)?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let dir = args.get("artifacts").unwrap_or("artifacts");
    let handle = spawn_service(dir)?;
    println!("artifacts loaded from {dir}; neuron batches: {:?}", handle.neuron_batches()?);
    // Push one batch through the whole PJRT path as a liveness check.
    let n = 256;
    let zeros = vec![0.0f32; n];
    let noise = vec![1000.0f32; n]; // everyone fires
    let params = ilmi::neuron::NeuronParams::default().to_vec();
    let out = handle.neuron_update(ilmi::runtime::NeuronInputs {
        v: vec![-65.0; n],
        u: vec![-13.0; n],
        ca: zeros.clone(),
        z_ax: zeros.clone(),
        z_de: zeros.clone(),
        z_di: zeros.clone(),
        i_syn: zeros.clone(),
        noise,
        params,
    })?;
    let fired: usize = out.fired.iter().filter(|&&f| f > 0.5).count();
    println!("executed neuron_update(b>=256): {fired}/{n} fired (expect {n})");
    handle.shutdown();
    if fired != n {
        bail!("artifact sanity check failed");
    }
    println!("inspect OK");
    Ok(())
}
