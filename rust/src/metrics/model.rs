//! Least-squares performance-model fit over the basis {1, log₂n, log₂²n}
//! — the functional family Extra-P reports for the new location-aware
//! algorithm in the paper's Fig. 10 (O(log² n) with per-θ coefficients).

/// Fitted model `t(n) = a + b·log₂(n) + c·log₂²(n)`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LogModel {
    pub a: f64,
    pub b: f64,
    pub c: f64,
}

impl LogModel {
    pub fn eval(&self, n: f64) -> f64 {
        let l = n.log2();
        self.a + self.b * l + self.c * l * l
    }

    /// Human-readable form used by the Fig. 10 bench output.
    pub fn formula(&self) -> String {
        format!("{:.4e} + {:.4e}*log2(n) + {:.4e}*log2(n)^2", self.a, self.b, self.c)
    }
}

/// Fit by solving the 3×3 normal equations with Gaussian elimination.
/// Needs at least 3 distinct sample sizes.
pub fn fit_log_model(samples: &[(f64, f64)]) -> Option<LogModel> {
    if samples.len() < 3 {
        return None;
    }
    // Design matrix rows: [1, l, l^2]; accumulate A^T A and A^T y.
    let mut ata = [[0.0f64; 3]; 3];
    let mut aty = [0.0f64; 3];
    for &(n, y) in samples {
        let l = n.log2();
        let row = [1.0, l, l * l];
        for i in 0..3 {
            for j in 0..3 {
                ata[i][j] += row[i] * row[j];
            }
            aty[i] += row[i] * y;
        }
    }
    solve3(ata, aty).map(|x| LogModel { a: x[0], b: x[1], c: x[2] })
}

/// Gaussian elimination with partial pivoting for a 3×3 system.
fn solve3(mut a: [[f64; 3]; 3], mut b: [f64; 3]) -> Option<[f64; 3]> {
    for col in 0..3 {
        // Pivot.
        let pivot = (col..3).max_by(|&i, &j| {
            a[i][col].abs().partial_cmp(&a[j][col].abs()).unwrap()
        })?;
        if a[pivot][col].abs() < 1e-12 {
            return None;
        }
        a.swap(col, pivot);
        b.swap(col, pivot);
        for row in col + 1..3 {
            let f = a[row][col] / a[col][col];
            for k in col..3 {
                a[row][k] -= f * a[col][k];
            }
            b[row] -= f * b[col];
        }
    }
    let mut x = [0.0; 3];
    for row in (0..3).rev() {
        let mut s = b[row];
        for k in row + 1..3 {
            s -= a[row][k] * x[k];
        }
        x[row] = s / a[row][row];
    }
    Some(x)
}

/// Coefficient of determination R² of a fit over the samples.
pub fn r_squared(model: &LogModel, samples: &[(f64, f64)]) -> f64 {
    let mean = samples.iter().map(|&(_, y)| y).sum::<f64>() / samples.len() as f64;
    let ss_tot: f64 = samples.iter().map(|&(_, y)| (y - mean).powi(2)).sum();
    let ss_res: f64 =
        samples.iter().map(|&(n, y)| (y - model.eval(n)).powi(2)).sum();
    if ss_tot == 0.0 {
        1.0
    } else {
        1.0 - ss_res / ss_tot
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recovers_exact_log2_model() {
        let truth = LogModel { a: 2.0, b: -0.5, c: 0.25 };
        let samples: Vec<(f64, f64)> =
            [16.0, 64.0, 256.0, 1024.0, 4096.0].iter().map(|&n| (n, truth.eval(n))).collect();
        let fit = fit_log_model(&samples).unwrap();
        assert!((fit.a - truth.a).abs() < 1e-8);
        assert!((fit.b - truth.b).abs() < 1e-8);
        assert!((fit.c - truth.c).abs() < 1e-8);
        assert!(r_squared(&fit, &samples) > 0.999999);
    }

    #[test]
    fn needs_three_samples() {
        assert!(fit_log_model(&[(2.0, 1.0), (4.0, 2.0)]).is_none());
    }

    #[test]
    fn degenerate_identical_sizes_rejected() {
        let samples = [(8.0, 1.0), (8.0, 1.1), (8.0, 0.9), (8.0, 1.0)];
        assert!(fit_log_model(&samples).is_none());
    }

    #[test]
    fn fits_noisy_data_reasonably() {
        let truth = LogModel { a: 1.0, b: 0.1, c: 0.02 };
        let mut rng = crate::util::Rng::new(3);
        let samples: Vec<(f64, f64)> = (4..14)
            .map(|k| {
                let n = (1usize << k) as f64;
                (n, truth.eval(n) * (1.0 + 0.01 * rng.normal()))
            })
            .collect();
        let fit = fit_log_model(&samples).unwrap();
        assert!(r_squared(&fit, &samples) > 0.98);
        assert!((fit.c - truth.c).abs() < 0.02);
    }
}
