//! Phase timing, byte accounting, report rendering, and the Extra-P
//! style performance-model fit (paper Fig. 10).

pub mod histogram;
pub mod model;
pub mod netmodel;
pub mod report;

pub use histogram::{CommHistSnapshot, CommHists, HistSnapshot, LatencyHistogram, HIST_BUCKETS};
pub use netmodel::NetModel;
pub use report::{RankReport, SimReport};

use std::time::{Duration, Instant};

/// Simulation phases, named after the paper's Fig. 11 breakdown.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// "Spike exchange" — moving fired ids (old) / frequencies (new).
    SpikeExchange,
    /// "Input distant" — looking up remote spikes (binary search / PRNG).
    SpikeLookup,
    /// "Actual activity update" + "Update of synaptic elements" —
    /// fused in our L1 kernel by design.
    ActivityUpdate,
    /// "Delete synapses".
    DeleteSynapses,
    /// Octree vacancy aggregation + branch exchange + window publish.
    OctreeUpdate,
    /// "Barnes–Hut" — target-search compute (incl. RMA waits for old).
    BarnesHut,
    /// "Synapse exchange" — formation request/response all-to-alls.
    SynapseExchange,
}

pub const ALL_PHASES: [Phase; 7] = [
    Phase::SpikeExchange,
    Phase::SpikeLookup,
    Phase::ActivityUpdate,
    Phase::DeleteSynapses,
    Phase::OctreeUpdate,
    Phase::BarnesHut,
    Phase::SynapseExchange,
];

impl Phase {
    pub fn index(self) -> usize {
        match self {
            Phase::SpikeExchange => 0,
            Phase::SpikeLookup => 1,
            Phase::ActivityUpdate => 2,
            Phase::DeleteSynapses => 3,
            Phase::OctreeUpdate => 4,
            Phase::BarnesHut => 5,
            Phase::SynapseExchange => 6,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Phase::SpikeExchange => "spike_exchange",
            Phase::SpikeLookup => "spike_lookup",
            Phase::ActivityUpdate => "activity_update",
            Phase::DeleteSynapses => "delete_synapses",
            Phase::OctreeUpdate => "octree_update",
            Phase::BarnesHut => "barnes_hut",
            Phase::SynapseExchange => "synapse_exchange",
        }
    }
}

/// Per-rank accumulated phase timings.
#[derive(Clone, Debug, Default)]
pub struct PhaseTimers {
    acc: [Duration; ALL_PHASES.len()],
}

impl PhaseTimers {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure under `phase`.
    #[inline]
    pub fn time<R>(&mut self, phase: Phase, f: impl FnOnce() -> R) -> R {
        let t0 = Instant::now();
        let r = f();
        self.acc[phase.index()] += t0.elapsed();
        r
    }

    /// Add an externally measured duration.
    pub fn add(&mut self, phase: Phase, d: Duration) {
        self.acc[phase.index()] += d;
    }

    pub fn get(&self, phase: Phase) -> Duration {
        self.acc[phase.index()]
    }

    pub fn total(&self) -> Duration {
        self.acc.iter().sum()
    }

    /// Per-phase seconds, in `ALL_PHASES` order.
    pub fn seconds(&self) -> [f64; ALL_PHASES.len()] {
        let mut out = [0.0; ALL_PHASES.len()];
        for (o, d) in out.iter_mut().zip(&self.acc) {
            *o = d.as_secs_f64();
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_accumulates() {
        let mut t = PhaseTimers::new();
        let x = t.time(Phase::BarnesHut, || {
            std::thread::sleep(Duration::from_millis(5));
            42
        });
        assert_eq!(x, 42);
        assert!(t.get(Phase::BarnesHut) >= Duration::from_millis(5));
        assert_eq!(t.get(Phase::SpikeExchange), Duration::ZERO);
        t.add(Phase::BarnesHut, Duration::from_millis(1));
        assert!(t.total() >= Duration::from_millis(6));
    }

    #[test]
    fn phase_indices_are_dense_and_unique() {
        let mut seen = [false; ALL_PHASES.len()];
        for p in ALL_PHASES {
            assert!(!seen[p.index()]);
            seen[p.index()] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
