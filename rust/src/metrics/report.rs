//! Per-rank and aggregated simulation reports.

use crate::balance::RankCost;
use crate::barnes_hut::FormationStats;
use crate::comm::CounterSnapshot;
use crate::plasticity::DeletionStats;
use crate::trace::EpochSample;
use crate::util::format_bytes;
use crate::util::wire::{put_f32, put_f64, put_u32, put_u64, put_u8, Cursor};

use super::histogram::{CommHistSnapshot, HistSnapshot};
use super::{Phase, ALL_PHASES};

/// Everything one rank reports after a run.
#[derive(Clone, Debug, Default)]
pub struct RankReport {
    pub rank: usize,
    /// Per-phase seconds, `ALL_PHASES` order.
    pub phase_seconds: [f64; ALL_PHASES.len()],
    pub comm: CounterSnapshot,
    pub formation: FormationStats,
    pub deletion: DeletionStats,
    /// Remote spike look-ups performed (Fig. 5 quantity).
    pub spike_lookups: u64,
    /// Bytes of spike-exchange reconstruction state held at run end:
    /// 12 B per installed remote partner under the new algorithm, 0
    /// under the old. O(local remote partners), never the former
    /// 4·total_neurons dense table (EXPERIMENTS.md §Perf, opt 7).
    pub spike_state_bytes: u64,
    /// Delivery-plan recompiles in this process segment (initial
    /// compile included): one per plasticity phase that edited the
    /// in-edge set. Per-segment bookkeeping like `phase_seconds` — a
    /// resumed run reports its own segment's count
    /// (EXPERIMENTS.md §Perf, opt 8).
    pub plan_rebuilds: u64,
    pub synapses_out: usize,
    pub synapses_in: usize,
    /// Local population size at run end. With load balancing this can
    /// differ from `neurons_per_rank` (neurons migrate between ranks).
    pub neurons: usize,
    /// Stored edges, both sides (`synapses_in + synapses_out`) — the
    /// per-rank load term structural plasticity drifts.
    pub local_edges: u64,
    /// Distinct remote in-partners (the delivery plan's slot count):
    /// the exchange-state/lookup share of the rank's load.
    pub remote_partners: u64,
    /// Neuron migrations applied on this rank's segment (0 when load
    /// balancing is off).
    pub migrations: u64,
    /// Cache blocks covered by this segment's activity updates
    /// (`neuron::blocks_per_step` summed over steps). Deterministic
    /// work metric, counted by the driver — identical across kernel
    /// backends by construction, which is exactly what the bench
    /// harness drift-checks (BENCH schema v6).
    pub kernel_blocks: u64,
    /// Supervised fleet relaunches that preceded this rank's segment
    /// (DESIGN.md §13). Stamped by the socket supervisor after decode —
    /// a child process cannot know how many attempts came before it —
    /// so it is 0 on the wire and for the thread backend. Like
    /// `phase_seconds`, per-segment: the counters above describe only
    /// the surviving attempt, not work lost to killed fleets.
    pub recoveries: u64,
    pub mean_calcium: f64,
    /// Optional calcium trace: (step, per-local-neuron calcium).
    pub calcium_trace: Vec<(usize, Vec<f32>)>,
    /// Epoch-granular telemetry samples (`instrumentation.trace_every`
    /// boundaries; empty when tracing is off). Segment-scoped like
    /// `phase_seconds` — never stored in ILMISNAP — and bounded by
    /// `trace_capacity` (DESIGN.md §10).
    pub trace: Vec<crate::trace::EpochSample>,
    /// Tracer ring evictions this segment: samples recorded but pushed
    /// out of the bounded ring before the run ended. Non-zero means
    /// `trace` holds the *suffix* of the segment, not all of it —
    /// previously a silent loss, now surfaced here, in the phase table,
    /// and in the JSONL export (DESIGN.md §14).
    pub trace_dropped: u64,
    /// Comm-latency histograms around `all_to_all` / `rma_get` /
    /// `barrier` on this rank's communicator (DESIGN.md §14). Bucket
    /// *totals* are deterministic trait-level call counts (what BENCH
    /// schema v8 drift-checks); the per-bucket spread is wall-clock
    /// observability, which is why the cross-backend differential
    /// compares them collapsed.
    pub comm_hists: CommHistSnapshot,
}

fn put_counters(out: &mut Vec<u8>, c: &CounterSnapshot) {
    put_u64(out, c.bytes_sent);
    put_u64(out, c.bytes_recv);
    put_u64(out, c.bytes_rma);
    put_u64(out, c.msgs_sent);
    put_u64(out, c.collectives);
    put_u64(out, c.rma_gets);
}

fn read_counters(c: &mut Cursor<'_>) -> Result<CounterSnapshot, String> {
    Ok(CounterSnapshot {
        bytes_sent: c.u64("bytes_sent")?,
        bytes_recv: c.u64("bytes_recv")?,
        bytes_rma: c.u64("bytes_rma")?,
        msgs_sent: c.u64("msgs_sent")?,
        collectives: c.u64("collectives")?,
        rma_gets: c.u64("rma_gets")?,
    })
}

fn put_hist(out: &mut Vec<u8>, h: &HistSnapshot) {
    for b in h.counts {
        put_u64(out, b);
    }
}

fn read_hist(c: &mut Cursor<'_>) -> Result<HistSnapshot, String> {
    let mut h = HistSnapshot::default();
    for slot in h.counts.iter_mut() {
        *slot = c.u64("hist bucket")?;
    }
    Ok(h)
}

fn read_phases(c: &mut Cursor<'_>) -> Result<[f64; ALL_PHASES.len()], String> {
    let mut out = [0.0; ALL_PHASES.len()];
    for slot in &mut out {
        *slot = c.f64("phase_seconds")?;
    }
    Ok(out)
}

impl RankReport {
    /// Encode for the socket backend's result channel: a child rank
    /// process sends this back to the launcher, which reassembles the
    /// `SimReport`. Little-endian, fields in declaration order;
    /// `decode` is the checked inverse (truncation is an error, never
    /// a panic).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        put_u64(&mut out, self.rank as u64);
        for s in self.phase_seconds {
            put_f64(&mut out, s);
        }
        put_counters(&mut out, &self.comm);
        put_u64(&mut out, self.formation.searches);
        put_u64(&mut out, self.formation.failed_searches);
        put_u64(&mut out, self.formation.proposals);
        put_u64(&mut out, self.formation.formed);
        put_u64(&mut out, self.formation.declined);
        put_u64(&mut out, self.formation.compute_nanos);
        put_u64(&mut out, self.formation.exchange_nanos);
        put_u64(&mut out, self.deletion.axonal_retractions);
        put_u64(&mut out, self.deletion.dendritic_retractions);
        put_u64(&mut out, self.deletion.notifications_sent);
        put_u64(&mut out, self.spike_lookups);
        put_u64(&mut out, self.spike_state_bytes);
        put_u64(&mut out, self.plan_rebuilds);
        put_u64(&mut out, self.synapses_out as u64);
        put_u64(&mut out, self.synapses_in as u64);
        put_u64(&mut out, self.neurons as u64);
        put_u64(&mut out, self.local_edges);
        put_u64(&mut out, self.remote_partners);
        put_u64(&mut out, self.migrations);
        put_u64(&mut out, self.kernel_blocks);
        put_u64(&mut out, self.recoveries);
        put_f64(&mut out, self.mean_calcium);
        put_u32(&mut out, self.calcium_trace.len() as u32);
        for (step, row) in &self.calcium_trace {
            put_u64(&mut out, *step as u64);
            put_u32(&mut out, row.len() as u32);
            for v in row {
                put_f32(&mut out, *v);
            }
        }
        put_u32(&mut out, self.trace.len() as u32);
        for s in &self.trace {
            put_u64(&mut out, s.step);
            put_u8(&mut out, s.boundaries);
            put_f64(&mut out, s.ts_micros);
            for p in s.phase_seconds {
                put_f64(&mut out, p);
            }
            put_counters(&mut out, &s.comm);
            put_u64(&mut out, s.spikes);
            put_u64(&mut out, s.formed);
            put_u64(&mut out, s.retractions);
            put_u64(&mut out, s.plan_rebuilds);
            put_u64(&mut out, s.migrations);
            put_u64(&mut out, s.cost.neurons);
            put_u64(&mut out, s.cost.local_edges);
            put_u64(&mut out, s.cost.remote_partners);
            put_u64(&mut out, s.cost.nanos);
        }
        put_u64(&mut out, self.trace_dropped);
        put_hist(&mut out, &self.comm_hists.a2a);
        put_hist(&mut out, &self.comm_hists.rma);
        put_hist(&mut out, &self.comm_hists.barrier);
        out
    }

    /// Checked inverse of [`encode`](Self::encode).
    pub fn decode(buf: &[u8]) -> Result<RankReport, String> {
        let mut c = Cursor::new(buf, "rank report");
        let mut r = RankReport {
            rank: c.u64("rank")? as usize,
            phase_seconds: read_phases(&mut c)?,
            comm: read_counters(&mut c)?,
            ..RankReport::default()
        };
        r.formation = FormationStats {
            searches: c.u64("searches")?,
            failed_searches: c.u64("failed_searches")?,
            proposals: c.u64("proposals")?,
            formed: c.u64("formed")?,
            declined: c.u64("declined")?,
            compute_nanos: c.u64("compute_nanos")?,
            exchange_nanos: c.u64("exchange_nanos")?,
        };
        r.deletion = DeletionStats {
            axonal_retractions: c.u64("axonal_retractions")?,
            dendritic_retractions: c.u64("dendritic_retractions")?,
            notifications_sent: c.u64("notifications_sent")?,
        };
        r.spike_lookups = c.u64("spike_lookups")?;
        r.spike_state_bytes = c.u64("spike_state_bytes")?;
        r.plan_rebuilds = c.u64("plan_rebuilds")?;
        r.synapses_out = c.u64("synapses_out")? as usize;
        r.synapses_in = c.u64("synapses_in")? as usize;
        r.neurons = c.u64("neurons")? as usize;
        r.local_edges = c.u64("local_edges")?;
        r.remote_partners = c.u64("remote_partners")?;
        r.migrations = c.u64("migrations")?;
        r.kernel_blocks = c.u64("kernel_blocks")?;
        r.recoveries = c.u64("recoveries")?;
        r.mean_calcium = c.f64("mean_calcium")?;
        let n_ca = c.u32("calcium_trace count")? as usize;
        r.calcium_trace = Vec::with_capacity(n_ca);
        for _ in 0..n_ca {
            let step = c.u64("calcium step")? as usize;
            let n = c.u32("calcium row len")? as usize;
            let mut row = Vec::with_capacity(n);
            for _ in 0..n {
                row.push(c.f32("calcium value")?);
            }
            r.calcium_trace.push((step, row));
        }
        let n_tr = c.u32("trace count")? as usize;
        r.trace = Vec::with_capacity(n_tr);
        for _ in 0..n_tr {
            r.trace.push(EpochSample {
                step: c.u64("trace step")?,
                boundaries: c.u8("trace boundaries")?,
                ts_micros: c.f64("trace ts_micros")?,
                phase_seconds: read_phases(&mut c)?,
                comm: read_counters(&mut c)?,
                spikes: c.u64("trace spikes")?,
                formed: c.u64("trace formed")?,
                retractions: c.u64("trace retractions")?,
                plan_rebuilds: c.u64("trace plan_rebuilds")?,
                migrations: c.u64("trace migrations")?,
                cost: RankCost {
                    neurons: c.u64("cost neurons")?,
                    local_edges: c.u64("cost local_edges")?,
                    remote_partners: c.u64("cost remote_partners")?,
                    nanos: c.u64("cost nanos")?,
                },
            });
        }
        r.trace_dropped = c.u64("trace_dropped")?;
        r.comm_hists = CommHistSnapshot {
            a2a: read_hist(&mut c)?,
            rma: read_hist(&mut c)?,
            barrier: read_hist(&mut c)?,
        };
        c.finish("rank report")?;
        Ok(r)
    }
}

/// Aggregated view over all ranks of one simulation.
#[derive(Clone, Debug, Default)]
pub struct SimReport {
    pub ranks: Vec<RankReport>,
    pub wall_seconds: f64,
    /// Supervised fleet relaunches performed by the socket supervisor
    /// to produce this report (DESIGN.md §13); 0 when nothing failed
    /// and always 0 on the thread backend. BENCH schema v7's
    /// drift-checked `recoveries` field.
    pub recoveries: u64,
    /// Evidence-based lower bound on simulation steps re-executed
    /// because of recoveries: for each recovery, the newest checkpoint
    /// step the dying fleet provably reached minus the step actually
    /// resumed from. Steps past the last checkpoint leave no trace, so
    /// the true loss can only be larger.
    pub lost_steps: u64,
    /// Wall seconds the supervisor spent between fleet death and
    /// relaunch (backoff plus checkpoint scan), summed over
    /// recoveries. Included in `wall_seconds`.
    pub recovery_seconds: f64,
}

impl SimReport {
    /// MPI-style phase time: the maximum across ranks (the slowest rank
    /// gates every synchronization point).
    pub fn phase_max(&self, phase: Phase) -> f64 {
        self.ranks
            .iter()
            .map(|r| r.phase_seconds[phase.index()])
            .fold(0.0, f64::max)
    }

    pub fn phase_mean(&self, phase: Phase) -> f64 {
        if self.ranks.is_empty() {
            return 0.0;
        }
        self.ranks.iter().map(|r| r.phase_seconds[phase.index()]).sum::<f64>()
            / self.ranks.len() as f64
    }

    /// All communication counters merged over ranks (what one
    /// `bench` scenario records per cell).
    pub fn total_comm(&self) -> CounterSnapshot {
        self.ranks
            .iter()
            .fold(CounterSnapshot::default(), |acc, r| acc.merge(&r.comm))
    }

    /// Total bytes sent by all ranks (Table I upper / Table II value).
    pub fn total_bytes_sent(&self) -> u64 {
        self.ranks.iter().map(|r| r.comm.bytes_sent).sum()
    }

    /// Total bytes remotely accessed by all ranks (Table I lower value).
    pub fn total_bytes_rma(&self) -> u64 {
        self.ranks.iter().map(|r| r.comm.bytes_rma).sum()
    }

    pub fn total_synapses(&self) -> usize {
        self.ranks.iter().map(|r| r.synapses_out).sum()
    }

    pub fn total_lookups(&self) -> u64 {
        self.ranks.iter().map(|r| r.spike_lookups).sum()
    }

    /// Delivery-plan recompiles summed over ranks (this process
    /// segment; see `RankReport::plan_rebuilds`).
    pub fn total_plan_rebuilds(&self) -> u64 {
        self.ranks.iter().map(|r| r.plan_rebuilds).sum()
    }

    /// Largest per-rank spike-exchange state (the worst rank is the
    /// memory bound that matters when scaling; what `bench` records as
    /// `spike_state_bytes`).
    pub fn max_spike_state_bytes(&self) -> u64 {
        self.ranks.iter().map(|r| r.spike_state_bytes).max().unwrap_or(0)
    }

    pub fn mean_calcium(&self) -> f64 {
        if self.ranks.is_empty() {
            return 0.0;
        }
        self.ranks.iter().map(|r| r.mean_calcium).sum::<f64>() / self.ranks.len() as f64
    }

    /// Load-imbalance factor at run end: max/mean per-rank step cost
    /// (`balance::step_cost` over neurons, stored edges, and remote
    /// partners). 1.0 is perfectly balanced; the slowest rank gates
    /// every collective, so this multiplies synchronized step time.
    /// The quantity the load balancer drives down (BENCH schema v4's
    /// drift-checked `imbalance` field).
    pub fn imbalance(&self) -> f64 {
        let costs: Vec<f64> = self
            .ranks
            .iter()
            .map(|r| {
                crate::balance::step_cost(r.neurons as u64, r.local_edges, r.remote_partners)
            })
            .collect();
        crate::balance::imbalance(&costs)
    }

    /// Total neuron migrations applied across ranks.
    pub fn total_migrations(&self) -> u64 {
        self.ranks.iter().map(|r| r.migrations).sum()
    }

    /// Total activity-update cache blocks across ranks (this process
    /// segment; see `RankReport::kernel_blocks`). BENCH schema v6's
    /// drift-checked `kernel_blocks` field.
    pub fn total_kernel_blocks(&self) -> u64 {
        self.ranks.iter().map(|r| r.kernel_blocks).sum()
    }

    /// Deterministic count of Chrome trace events the report's samples
    /// export (`trace::event_count`): what BENCH schema v5
    /// drift-checks as `trace_events`. 0 when tracing is off.
    pub fn trace_events(&self) -> u64 {
        crate::trace::event_count(self)
    }

    /// Comm-latency histograms merged over ranks. The three totals are
    /// deterministic call counts (BENCH schema v8's drift-checked
    /// `comm_hist_*` fields); bucket spread is wall-clock.
    pub fn total_comm_hists(&self) -> CommHistSnapshot {
        self.ranks
            .iter()
            .fold(CommHistSnapshot::default(), |acc, r| acc.merge(&r.comm_hists))
    }

    /// Tracer ring evictions summed over ranks (see
    /// `RankReport::trace_dropped`).
    pub fn total_trace_dropped(&self) -> u64 {
        self.ranks.iter().map(|r| r.trace_dropped).sum()
    }

    /// Merged formation stats.
    pub fn formation(&self) -> FormationStats {
        self.ranks.iter().fold(FormationStats::default(), |acc, r| acc.merge(&r.formation))
    }

    /// Render the Fig. 11-style phase table.
    pub fn phase_table(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:<18} {:>12} {:>12}\n",
            "phase", "max [s]", "mean [s]"
        ));
        for p in ALL_PHASES {
            out.push_str(&format!(
                "{:<18} {:>12.4} {:>12.4}\n",
                p.name(),
                self.phase_max(p),
                self.phase_mean(p)
            ));
        }
        out.push_str(&format!(
            "{:<18} {:>12.4}\n",
            "wall_clock", self.wall_seconds
        ));
        out.push_str(&format!(
            "bytes sent {} | rma {} | spike state {}/rank | plan rebuilds {} | \
             synapses {} | mean Ca {:.3}\n",
            format_bytes(self.total_bytes_sent()),
            format_bytes(self.total_bytes_rma()),
            format_bytes(self.max_spike_state_bytes()),
            self.total_plan_rebuilds(),
            self.total_synapses(),
            self.mean_calcium(),
        ));
        out.push_str(&format!(
            "imbalance {:.3} (max/mean step cost) | migrations {}\n",
            self.imbalance(),
            self.total_migrations(),
        ));
        if self.recoveries > 0 {
            out.push_str(&format!(
                "recoveries {} | lost steps >= {} | recovery wall {:.3} s\n",
                self.recoveries, self.lost_steps, self.recovery_seconds,
            ));
        }
        let dropped = self.total_trace_dropped();
        if dropped > 0 {
            out.push_str(&format!(
                "trace dropped {dropped} sample(s): ring full — older epochs evicted \
                 (raise instrumentation.trace_capacity)\n"
            ));
        }
        out
    }

    /// One CSV row per rank (machine-readable output).
    pub fn to_csv(&self) -> String {
        let mut out = String::from("rank,");
        out.push_str(
            &ALL_PHASES.iter().map(|p| p.name().to_string()).collect::<Vec<_>>().join(","),
        );
        out.push_str(
            ",bytes_sent,bytes_rma,msgs,synapses_out,mean_ca,spike_lookups,spike_state_bytes,\
             plan_rebuilds,neurons,local_edges,remote_partners,migrations,kernel_blocks,\
             recoveries,trace_dropped,comm_hist_a2a,comm_hist_rma,comm_hist_barrier\n",
        );
        for r in &self.ranks {
            out.push_str(&format!("{},", r.rank));
            out.push_str(
                &r.phase_seconds.iter().map(|s| format!("{s:.6}")).collect::<Vec<_>>().join(","),
            );
            out.push_str(&format!(
                ",{},{},{},{},{:.4},{},{},{},{},{},{},{},{},{},{},{},{},{}\n",
                r.comm.bytes_sent,
                r.comm.bytes_rma,
                r.comm.msgs_sent,
                r.synapses_out,
                r.mean_calcium,
                r.spike_lookups,
                r.spike_state_bytes,
                r.plan_rebuilds,
                r.neurons,
                r.local_edges,
                r.remote_partners,
                r.migrations,
                r.kernel_blocks,
                r.recoveries,
                r.trace_dropped,
                r.comm_hists.a2a.total(),
                r.comm_hists.rma.total(),
                r.comm_hists.barrier.total(),
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report_with(phase: Phase, secs: f64, sent: u64, rma: u64) -> RankReport {
        let mut r = RankReport::default();
        r.phase_seconds[phase.index()] = secs;
        r.comm.bytes_sent = sent;
        r.comm.bytes_rma = rma;
        r
    }

    #[test]
    fn max_and_mean_aggregation() {
        let sim = SimReport {
            ranks: vec![
                report_with(Phase::BarnesHut, 1.0, 100, 50),
                report_with(Phase::BarnesHut, 3.0, 200, 0),
            ],
            wall_seconds: 3.5,
            ..Default::default()
        };
        assert_eq!(sim.phase_max(Phase::BarnesHut), 3.0);
        assert_eq!(sim.phase_mean(Phase::BarnesHut), 2.0);
        assert_eq!(sim.total_bytes_sent(), 300);
        assert_eq!(sim.total_bytes_rma(), 50);
    }

    #[test]
    fn spike_state_aggregates_as_max_across_ranks() {
        let a = RankReport { spike_state_bytes: 24, ..Default::default() };
        let b = RankReport { spike_state_bytes: 120, ..Default::default() };
        let sim = SimReport { ranks: vec![a, b], ..Default::default() };
        assert_eq!(sim.max_spike_state_bytes(), 120);
        assert_eq!(SimReport::default().max_spike_state_bytes(), 0);
    }

    #[test]
    fn plan_rebuilds_aggregate_as_sum() {
        let a = RankReport { plan_rebuilds: 3, ..Default::default() };
        let b = RankReport { plan_rebuilds: 4, ..Default::default() };
        let sim = SimReport { ranks: vec![a, b], ..Default::default() };
        assert_eq!(sim.total_plan_rebuilds(), 7);
        assert!(sim.phase_table().contains("plan rebuilds 7"));
    }

    #[test]
    fn imbalance_is_max_over_mean_step_cost() {
        let a = RankReport { neurons: 48, ..Default::default() };
        let b = RankReport { neurons: 16, ..Default::default() };
        let sim = SimReport { ranks: vec![a, b], ..Default::default() };
        assert!((sim.imbalance() - 1.5).abs() < 1e-12);
        // Empty / degenerate reports read as balanced.
        assert_eq!(SimReport::default().imbalance(), 1.0);
        assert!(sim.phase_table().contains("imbalance 1.500"));
    }

    #[test]
    fn csv_header_and_rows_have_matching_columns() {
        let mut loaded = RankReport {
            rank: 1,
            spike_lookups: 7,
            spike_state_bytes: 24,
            plan_rebuilds: 3,
            neurons: 48,
            local_edges: 120,
            remote_partners: 5,
            migrations: 2,
            kernel_blocks: 60,
            recoveries: 1,
            trace_dropped: 4,
            ..Default::default()
        };
        loaded.comm_hists.a2a.counts[3] = 9;
        loaded.comm_hists.barrier.counts[0] = 2;
        let sim =
            SimReport { ranks: vec![RankReport::default(), loaded], ..Default::default() };
        let csv = sim.to_csv();
        let mut lines = csv.lines();
        let header: Vec<&str> = lines.next().unwrap().split(',').collect();
        let rows: Vec<Vec<&str>> = lines.map(|l| l.split(',').collect()).collect();
        assert_eq!(rows.len(), 2);
        for row in &rows {
            assert_eq!(row.len(), header.len(), "row/header column mismatch");
        }
        // Every load/observability column is present and lands in the
        // right place.
        let col = |name: &str| header.iter().position(|h| *h == name).unwrap_or_else(|| {
            panic!("missing column {name}")
        });
        assert_eq!(rows[1][col("spike_lookups")], "7");
        assert_eq!(rows[1][col("spike_state_bytes")], "24");
        assert_eq!(rows[1][col("plan_rebuilds")], "3");
        assert_eq!(rows[1][col("neurons")], "48");
        assert_eq!(rows[1][col("local_edges")], "120");
        assert_eq!(rows[1][col("remote_partners")], "5");
        assert_eq!(rows[1][col("migrations")], "2");
        assert_eq!(rows[1][col("kernel_blocks")], "60");
        assert_eq!(rows[1][col("recoveries")], "1");
        assert_eq!(rows[1][col("trace_dropped")], "4");
        assert_eq!(rows[1][col("comm_hist_a2a")], "9");
        assert_eq!(rows[1][col("comm_hist_rma")], "0");
        assert_eq!(rows[1][col("comm_hist_barrier")], "2");
    }

    #[test]
    fn recovery_line_renders_only_after_a_recovery() {
        let quiet = SimReport::default();
        assert!(!quiet.phase_table().contains("recoveries"));
        let sim = SimReport {
            ranks: vec![RankReport::default()],
            recoveries: 2,
            lost_steps: 37,
            recovery_seconds: 0.25,
            ..Default::default()
        };
        let t = sim.phase_table();
        assert!(t.contains("recoveries 2"), "{t}");
        assert!(t.contains("lost steps >= 37"), "{t}");
    }

    #[test]
    fn kernel_blocks_aggregate_as_sum() {
        let a = RankReport { kernel_blocks: 60, ..Default::default() };
        let b = RankReport { kernel_blocks: 60, ..Default::default() };
        let sim = SimReport { ranks: vec![a, b], ..Default::default() };
        assert_eq!(sim.total_kernel_blocks(), 120);
    }

    #[test]
    fn rank_report_wire_roundtrip() {
        let mut r = RankReport {
            rank: 3,
            spike_lookups: 11,
            spike_state_bytes: 36,
            plan_rebuilds: 2,
            synapses_out: 40,
            synapses_in: 38,
            neurons: 32,
            local_edges: 78,
            remote_partners: 5,
            migrations: 1,
            kernel_blocks: 17,
            recoveries: 2,
            mean_calcium: 0.625,
            calcium_trace: vec![(50, vec![0.5, 0.75]), (100, vec![])],
            trace_dropped: 6,
            ..Default::default()
        };
        r.comm_hists.a2a.counts[5] = 3;
        r.comm_hists.rma.counts[31] = 1;
        r.comm_hists.barrier.counts[0] = 7;
        r.phase_seconds[0] = 1.25;
        r.comm.bytes_sent = 1024;
        r.comm.collectives = 7;
        r.formation.searches = 9;
        r.formation.formed = 4;
        r.deletion.axonal_retractions = 2;
        let mut sample = crate::trace::EpochSample::default();
        sample.step = 50;
        sample.boundaries = 3;
        sample.comm.bytes_recv = 99;
        sample.cost.neurons = 32;
        r.trace.push(sample);

        let bytes = r.encode();
        let back = RankReport::decode(&bytes).unwrap();
        // Byte-identical re-encode pins every field without needing
        // PartialEq on the nested stats structs.
        assert_eq!(back.encode(), bytes);
        assert_eq!(back.rank, 3);
        assert_eq!(back.calcium_trace, r.calcium_trace);
        assert_eq!(back.trace.len(), 1);
        assert_eq!(back.trace[0].comm.bytes_recv, 99);
        assert_eq!(back.trace_dropped, 6);
        assert_eq!(back.comm_hists, r.comm_hists);
    }

    #[test]
    fn comm_hists_and_trace_dropped_aggregate_over_ranks() {
        let mut a = RankReport { trace_dropped: 2, ..Default::default() };
        a.comm_hists.a2a.counts[1] = 5;
        let mut b = RankReport { trace_dropped: 3, ..Default::default() };
        b.comm_hists.a2a.counts[2] = 5;
        b.comm_hists.rma.counts[0] = 4;
        let sim = SimReport { ranks: vec![a, b], ..Default::default() };
        let total = sim.total_comm_hists();
        assert_eq!(total.a2a.total(), 10);
        assert_eq!(total.rma.total(), 4);
        assert_eq!(sim.total_trace_dropped(), 5);
        // The phase table surfaces the formerly-silent eviction; quiet
        // runs stay quiet.
        assert!(sim.phase_table().contains("trace dropped 5"));
        assert!(!SimReport::default().phase_table().contains("trace dropped"));
    }

    #[test]
    fn rank_report_decode_rejects_truncation_and_trailing() {
        let bytes = RankReport::default().encode();
        let err = RankReport::decode(&bytes[..bytes.len() - 1]).unwrap_err();
        assert!(err.contains("truncated"), "{err}");
        let mut extra = bytes.clone();
        extra.push(0);
        let err = RankReport::decode(&extra).unwrap_err();
        assert!(err.contains("trailing"), "{err}");
    }

    #[test]
    fn tables_render() {
        let sim = SimReport {
            ranks: vec![report_with(Phase::SpikeExchange, 0.5, 1024, 0)],
            wall_seconds: 1.0,
            ..Default::default()
        };
        let t = sim.phase_table();
        assert!(t.contains("spike_exchange"));
        assert!(t.contains("wall_clock"));
        let csv = sim.to_csv();
        assert!(csv.lines().count() == 2);
        assert!(csv.contains("bytes_sent"));
    }
}
