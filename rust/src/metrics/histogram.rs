//! Fixed-bucket log2 latency histograms for communication primitives.
//!
//! Each histogram has [`HIST_BUCKETS`] power-of-two buckets over
//! nanoseconds: bucket `b` counts latencies in `[2^b, 2^(b+1))` ns
//! (bucket 0 additionally absorbs 0–1 ns, the last bucket absorbs
//! everything from ~2.1 s up). The *spread* across buckets is
//! wall-clock-dependent and therefore observability-only, exactly like
//! phase seconds (PR 5 convention) — but the *total* sample count is a
//! deterministic count of comm calls, identical across backends and
//! reps, and is drift-checked in the BENCH schema (v8) and in the
//! cross-backend differential harness (after [`HistSnapshot::collapse`]
//! folds the nondeterministic spread away).
//!
//! Recording is a single relaxed atomic increment; when nobody reads the
//! histogram the cost is two `Instant::now()` calls per comm op, which
//! is noise next to a socket round-trip and invisible next to the
//! dynamics (the histograms never feed back into the simulation).

use std::sync::atomic::{AtomicU64, Ordering};

/// Number of log2 buckets. 32 covers 1 ns .. ~4.3 s per-op latency,
/// beyond which the socket launch timeout would fire anyway.
pub const HIST_BUCKETS: usize = 32;

/// Bucket index for a latency of `nanos`: `floor(log2(nanos))`, clamped
/// to the bucket range. 0 and 1 ns land in bucket 0.
#[inline]
pub fn bucket_of(nanos: u64) -> usize {
    if nanos <= 1 {
        0
    } else {
        ((63 - nanos.leading_zeros()) as usize).min(HIST_BUCKETS - 1)
    }
}

/// Shared-writer histogram: relaxed atomic bumps, snapshot on demand.
#[derive(Debug, Default)]
pub struct LatencyHistogram {
    counts: [AtomicU64; HIST_BUCKETS],
}

impl LatencyHistogram {
    pub fn record(&self, nanos: u64) {
        self.counts[bucket_of(nanos)].fetch_add(1, Ordering::Relaxed);
    }

    /// Time a closure and record its elapsed nanos. Returns the
    /// closure's value unchanged — callers wrap a comm primitive.
    #[inline]
    pub fn time<R>(&self, f: impl FnOnce() -> R) -> R {
        let t0 = std::time::Instant::now();
        let r = f();
        self.record(t0.elapsed().as_nanos().min(u128::from(u64::MAX)) as u64);
        r
    }

    pub fn snapshot(&self) -> HistSnapshot {
        let mut counts = [0u64; HIST_BUCKETS];
        for (out, c) in counts.iter_mut().zip(&self.counts) {
            *out = c.load(Ordering::Relaxed);
        }
        HistSnapshot { counts }
    }
}

/// A plain-data copy of one histogram at a point in time.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct HistSnapshot {
    pub counts: [u64; HIST_BUCKETS],
}

impl HistSnapshot {
    /// Total samples — a deterministic call count (see module docs).
    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Elementwise sum (aggregating over ranks or reps).
    pub fn merge(&self, other: &HistSnapshot) -> HistSnapshot {
        let mut counts = [0u64; HIST_BUCKETS];
        for (out, (a, b)) in counts.iter_mut().zip(self.counts.iter().zip(&other.counts)) {
            *out = a + b;
        }
        HistSnapshot { counts }
    }

    /// Fold the wall-clock-dependent spread away: every sample moves to
    /// bucket 0, preserving the deterministic total. The cross-backend
    /// differential harness compares collapsed histograms byte-for-byte.
    pub fn collapse(&self) -> HistSnapshot {
        let mut counts = [0u64; HIST_BUCKETS];
        counts[0] = self.total();
        HistSnapshot { counts }
    }
}

/// One histogram per instrumented comm primitive. Owned by a backend
/// handle; snapshotted into a [`CommHistSnapshot`] for reports.
#[derive(Debug, Default)]
pub struct CommHists {
    pub a2a: LatencyHistogram,
    pub rma: LatencyHistogram,
    pub barrier: LatencyHistogram,
}

impl CommHists {
    pub fn snapshot(&self) -> CommHistSnapshot {
        CommHistSnapshot {
            a2a: self.a2a.snapshot(),
            rma: self.rma.snapshot(),
            barrier: self.barrier.snapshot(),
        }
    }
}

/// Plain-data comm latency histograms, as carried in `RankReport`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CommHistSnapshot {
    pub a2a: HistSnapshot,
    pub rma: HistSnapshot,
    pub barrier: HistSnapshot,
}

impl CommHistSnapshot {
    pub fn merge(&self, other: &CommHistSnapshot) -> CommHistSnapshot {
        CommHistSnapshot {
            a2a: self.a2a.merge(&other.a2a),
            rma: self.rma.merge(&other.rma),
            barrier: self.barrier.merge(&other.barrier),
        }
    }

    pub fn collapse(&self) -> CommHistSnapshot {
        CommHistSnapshot {
            a2a: self.a2a.collapse(),
            rma: self.rma.collapse(),
            barrier: self.barrier.collapse(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::forall;
    use crate::util::Rng;

    #[test]
    fn bucket_boundaries_are_powers_of_two() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 0);
        assert_eq!(bucket_of(2), 1);
        assert_eq!(bucket_of(3), 1);
        assert_eq!(bucket_of(4), 2);
        assert_eq!(bucket_of(1023), 9);
        assert_eq!(bucket_of(1024), 10);
        assert_eq!(bucket_of((1 << 31) - 1), 30);
        assert_eq!(bucket_of(1 << 31), 31);
        // Everything past the last boundary clamps into the last bucket.
        assert_eq!(bucket_of(1 << 40), 31);
        assert_eq!(bucket_of(u64::MAX), 31);
    }

    #[test]
    fn prop_every_sample_lands_in_its_halfopen_bucket() {
        forall(
            "bucket_of(n) puts n in [2^b, 2^(b+1))",
            500,
            |rng| rng.next_u64() >> (rng.next_u64() % 64),
            |&n| {
                let b = bucket_of(n);
                let lo = 1u64 << b;
                if n >= 2 && n < lo {
                    return Err(format!("{n} below bucket {b} floor {lo}"));
                }
                if b + 1 < HIST_BUCKETS && n >= lo << 1 {
                    return Err(format!("{n} at/above bucket {b} ceiling {}", lo << 1));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn record_time_and_snapshot() {
        let h = LatencyHistogram::default();
        h.record(0);
        h.record(1);
        h.record(1024);
        let x = h.time(|| 42);
        assert_eq!(x, 42);
        let s = h.snapshot();
        assert_eq!(s.total(), 4);
        assert_eq!(s.counts[0], 2);
        assert_eq!(s.counts[10], 1);
    }

    fn arb_hist(rng: &mut Rng) -> HistSnapshot {
        let mut counts = [0u64; HIST_BUCKETS];
        for c in counts.iter_mut() {
            *c = rng.next_u64() % 1000;
        }
        HistSnapshot { counts }
    }

    #[test]
    fn prop_merge_is_commutative_and_associative() {
        forall(
            "merge commutes and associates, totals add",
            200,
            |rng| (arb_hist(rng), arb_hist(rng), arb_hist(rng)),
            |(a, b, c)| {
                if a.merge(b) != b.merge(a) {
                    return Err("merge not commutative".into());
                }
                if a.merge(b).merge(c) != a.merge(&b.merge(c)) {
                    return Err("merge not associative".into());
                }
                if a.merge(b).total() != a.total() + b.total() {
                    return Err("totals do not add".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_collapse_preserves_total_and_identity_on_merge() {
        forall(
            "collapse keeps the total, zeroes the spread",
            200,
            |rng| (arb_hist(rng), arb_hist(rng)),
            |(a, b)| {
                let c = a.collapse();
                if c.total() != a.total() || c.counts[0] != a.total() {
                    return Err("collapse changed the total".into());
                }
                if c.counts[1..].iter().any(|&n| n != 0) {
                    return Err("collapse left samples outside bucket 0".into());
                }
                // Collapse distributes over merge — what lets the
                // differential harness collapse per-rank before merging.
                if a.merge(b).collapse() != a.collapse().merge(&b.collapse()) {
                    return Err("collapse does not distribute over merge".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn comm_hists_snapshot_and_merge() {
        let h = CommHists::default();
        h.a2a.record(100);
        h.rma.record(5);
        h.rma.record(1 << 20);
        let s = h.snapshot();
        assert_eq!(s.a2a.total(), 1);
        assert_eq!(s.rma.total(), 2);
        assert_eq!(s.barrier.total(), 0);
        let doubled = s.merge(&s);
        assert_eq!(doubled.rma.total(), 4);
        assert_eq!(doubled.collapse().rma.counts[0], 4);
    }
}
