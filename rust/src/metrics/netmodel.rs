//! Analytic network-cost model.
//!
//! Our simulated-MPI substrate moves bytes at shared-memory speed, so
//! wall-clock alone understates what the two algorithm generations
//! would cost on the paper's cluster (InfiniBand HDR100). This model
//! re-prices a run's *counted* communication — collectives, messages,
//! bytes, RMA gets — under configurable network constants, turning
//! Tables I/II-style accounting into predicted communication time. The
//! `compare` CLI and the ablation bench report it next to measured
//! wall-clock.

use crate::comm::CounterSnapshot;

/// Cost constants of a modeled interconnect.
#[derive(Clone, Copy, Debug)]
pub struct NetModel {
    /// Cost of one collective synchronization (latency of the slowest
    /// path through the all-to-all), seconds.
    pub collective_latency: f64,
    /// Per-message overhead (injection + matching), seconds.
    pub message_overhead: f64,
    /// Per-byte transfer cost, seconds (1 / bandwidth).
    pub per_byte: f64,
    /// One-sided get latency (passive-target RMA round trip), seconds.
    pub rma_latency: f64,
}

impl NetModel {
    /// InfiniBand HDR100-class constants (the paper's testbed):
    /// ~1.5 µs small-message latency, ~100 Gbit/s ≈ 12.5 GB/s,
    /// collectives ~5 µs at moderate rank counts, RMA get ~2 µs.
    pub fn hdr100() -> NetModel {
        NetModel {
            collective_latency: 5e-6,
            message_overhead: 1.5e-6,
            per_byte: 1.0 / 12.5e9,
            rma_latency: 2e-6,
        }
    }

    /// Ethernet-class constants (25 GbE, ~10 µs latency): the regime
    /// where communication structure matters even more.
    pub fn ethernet25g() -> NetModel {
        NetModel {
            collective_latency: 30e-6,
            message_overhead: 10e-6,
            per_byte: 1.0 / 3.1e9,
            rma_latency: 15e-6,
        }
    }

    /// Predicted communication seconds for one rank's counters.
    pub fn price(&self, c: &CounterSnapshot) -> f64 {
        c.collectives as f64 * self.collective_latency
            + c.msgs_sent as f64 * self.message_overhead
            + (c.bytes_sent + c.bytes_rma) as f64 * self.per_byte
            + c.rma_gets as f64 * self.rma_latency
    }

    /// Predicted communication seconds for a whole run: the maximum
    /// over ranks (synchronized phases are gated by the slowest rank).
    pub fn price_run(&self, per_rank: &[CounterSnapshot]) -> f64 {
        per_rank.iter().map(|c| self.price(c)).fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(collectives: u64, msgs: u64, bytes: u64, rma: u64) -> CounterSnapshot {
        CounterSnapshot {
            bytes_sent: bytes,
            bytes_recv: bytes,
            bytes_rma: 0,
            msgs_sent: msgs,
            collectives,
            rma_gets: rma,
        }
    }

    #[test]
    fn pricing_is_linear_in_counters() {
        let m = NetModel::hdr100();
        let a = m.price(&snap(10, 5, 1000, 2));
        let b = m.price(&snap(20, 10, 2000, 4));
        assert!((b - 2.0 * a).abs() < 1e-12);
    }

    #[test]
    fn run_price_takes_slowest_rank() {
        let m = NetModel::hdr100();
        let ranks = vec![snap(1, 1, 100, 0), snap(1000, 1, 100, 0)];
        assert_eq!(m.price_run(&ranks), m.price(&ranks[1]));
    }

    #[test]
    fn collective_heavy_old_spikes_cost_more() {
        // 1000 per-step collectives (old) vs 10 epoch collectives (new)
        // with identical byte volume: the old path must price higher on
        // any latency-bearing network.
        for m in [NetModel::hdr100(), NetModel::ethernet25g()] {
            let old = m.price(&snap(1000, 1000, 10_000, 0));
            let new = m.price(&snap(10, 10, 10_000, 0));
            assert!(old > 50.0 * new, "{old} vs {new}");
        }
    }

    #[test]
    fn rma_heavy_old_connectivity_costs_more() {
        let m = NetModel::hdr100();
        // Old: few collectives but thousands of 89 B RMA gets.
        let old = m.price(&CounterSnapshot {
            bytes_sent: 17_000,
            bytes_recv: 17_000,
            bytes_rma: 89 * 5_000,
            msgs_sent: 100,
            collectives: 20,
            rma_gets: 5_000,
        });
        // New: the same work as 42 B requests, no RMA.
        let new = m.price(&CounterSnapshot {
            bytes_sent: 42_000,
            bytes_recv: 42_000,
            bytes_rma: 0,
            msgs_sent: 100,
            collectives: 20,
            rma_gets: 0,
        });
        assert!(old > 3.0 * new, "{old} vs {new}");
    }
}
