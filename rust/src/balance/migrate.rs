//! The migration wire format: everything a moving neuron *is*, packed
//! for the all-to-all.
//!
//! A [`NeuronRecord`] carries the full per-neuron state — Izhikevich
//! membrane state, calcium, synaptic-element counts, the per-step
//! scratch that must survive mid-step semantics (`i_syn`, `fired`,
//! `epoch_spikes`), and both edge lists. A [`MigrationBatch`] is what
//! one rank ships to one destination: the records of every neuron
//! moving there (ascending by id) plus the sender-side
//! `PartnerFreqs` entries for the moving neurons' in-edge sources, so
//! the new owner keeps reconstructing spikes mid-epoch instead of
//! silently reading 0.0 until the next boundary.
//!
//! Derived state deliberately does NOT travel: connected-element
//! counters are recomputed from the edge lists, the octree is rebuilt
//! from positions, the delivery plan is recompiled, and the routing
//! tables re-derive in `SynapseStore::from_parts` — same philosophy as
//! the ILMISNAP format (store ground truth, rebuild acceleration
//! structures).
//!
//! Encoding reuses the `util::wire` primitives; decoding goes through
//! the checked `Cursor`, so a malformed batch surfaces as a
//! descriptive error at the receiving rank instead of garbage state.

use crate::neuron::GlobalNeuronId;
use crate::util::wire::{put_f32, put_f64, put_u32, put_u64, put_u8, Cursor};
use crate::util::Vec3;

/// One migrating neuron's complete state.
#[derive(Clone, Debug, PartialEq)]
pub struct NeuronRecord {
    pub id: GlobalNeuronId,
    pub pos: Vec3,
    pub is_excitatory: bool,
    pub v: f32,
    pub u: f32,
    pub ca: f32,
    pub z_ax: f32,
    pub z_den_exc: f32,
    pub z_den_inh: f32,
    pub i_syn: f32,
    pub noise: f32,
    pub fired: bool,
    pub epoch_spikes: u32,
    /// Axonal side: targets of outgoing synapses.
    pub out_edges: Vec<GlobalNeuronId>,
    /// Dendritic side: (source id, source is excitatory).
    pub in_edges: Vec<(GlobalNeuronId, bool)>,
}

impl NeuronRecord {
    fn encode(&self, out: &mut Vec<u8>) {
        put_u64(out, self.id);
        put_f64(out, self.pos.x);
        put_f64(out, self.pos.y);
        put_f64(out, self.pos.z);
        put_u8(out, u8::from(self.is_excitatory));
        for x in [
            self.v,
            self.u,
            self.ca,
            self.z_ax,
            self.z_den_exc,
            self.z_den_inh,
            self.i_syn,
            self.noise,
        ] {
            put_f32(out, x);
        }
        put_u8(out, u8::from(self.fired));
        put_u32(out, self.epoch_spikes);
        put_u32(out, self.out_edges.len() as u32);
        for &tgt in &self.out_edges {
            put_u64(out, tgt);
        }
        put_u32(out, self.in_edges.len() as u32);
        for &(src, exc) in &self.in_edges {
            put_u64(out, src);
            put_u8(out, u8::from(exc));
        }
    }

    fn decode(c: &mut Cursor<'_>) -> Result<NeuronRecord, String> {
        let id = c.u64("migrating neuron id")?;
        let x = c.f64("neuron position")?;
        let y = c.f64("neuron position")?;
        let z = c.f64("neuron position")?;
        let is_excitatory = c.u8("neuron type")? != 0;
        let v = c.f32("membrane state")?;
        let u = c.f32("membrane state")?;
        let ca = c.f32("calcium")?;
        let z_ax = c.f32("elements")?;
        let z_den_exc = c.f32("elements")?;
        let z_den_inh = c.f32("elements")?;
        let i_syn = c.f32("synaptic input")?;
        let noise = c.f32("noise")?;
        let fired = c.u8("fired flag")? != 0;
        let epoch_spikes = c.u32("epoch spikes")?;
        let n_out = c.u32("out-edge count")? as usize;
        let mut out_edges = Vec::with_capacity(n_out.min(c.remaining() / 8));
        for _ in 0..n_out {
            out_edges.push(c.u64("out edge")?);
        }
        let n_in = c.u32("in-edge count")? as usize;
        let mut in_edges = Vec::with_capacity(n_in.min(c.remaining() / 9));
        for _ in 0..n_in {
            let src = c.u64("in edge")?;
            let exc = c.u8("in edge kind")? != 0;
            in_edges.push((src, exc));
        }
        Ok(NeuronRecord {
            id,
            pos: Vec3::new(x, y, z),
            is_excitatory,
            v,
            u,
            ca,
            z_ax,
            z_den_exc,
            z_den_inh,
            i_syn,
            noise,
            fired,
            epoch_spikes,
            out_edges,
            in_edges,
        })
    }
}

/// Everything one rank ships to one destination during a migration.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MigrationBatch {
    /// Moving neurons, ascending by id.
    pub records: Vec<NeuronRecord>,
    /// Sender-side frequency entries for the moving neurons' in-edge
    /// sources (ascending by id; only sources that HAVE an installed
    /// entry). The receiver merges these into its own table so
    /// mid-epoch reconstruction continues seamlessly.
    pub freq_entries: Vec<(u64, f32)>,
}

impl MigrationBatch {
    pub fn is_empty(&self) -> bool {
        self.records.is_empty() && self.freq_entries.is_empty()
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        put_u32(&mut out, self.records.len() as u32);
        for r in &self.records {
            r.encode(&mut out);
        }
        put_u32(&mut out, self.freq_entries.len() as u32);
        for &(id, f) in &self.freq_entries {
            put_u64(&mut out, id);
            put_f32(&mut out, f);
        }
        out
    }

    pub fn decode(buf: &[u8]) -> Result<MigrationBatch, String> {
        let mut c = Cursor::new(buf, "migration batch");
        let n_rec = c.u32("record count")? as usize;
        let mut records = Vec::with_capacity(n_rec.min(c.remaining() / 66));
        for _ in 0..n_rec {
            records.push(NeuronRecord::decode(&mut c)?);
        }
        let n_ent = c.u32("frequency entry count")? as usize;
        let mut freq_entries = Vec::with_capacity(n_ent.min(c.remaining() / 12));
        for _ in 0..n_ent {
            let id = c.u64("frequency entry id")?;
            let f = c.f32("frequency entry")?;
            freq_entries.push((id, f));
        }
        c.finish("migration batch")?;
        for w in records.windows(2) {
            if w[0].id >= w[1].id {
                return Err(format!(
                    "migration records not ascending: id {} then {}",
                    w[0].id, w[1].id
                ));
            }
        }
        crate::spikes::PartnerFreqs::check_ascending(&freq_entries)?;
        Ok(MigrationBatch { records, freq_entries })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_record(id: u64) -> NeuronRecord {
        NeuronRecord {
            id,
            pos: Vec3::new(1.25, -2.5, 7.75),
            is_excitatory: id % 2 == 0,
            v: -65.5,
            u: -13.25,
            ca: 0.5,
            z_ax: 1.25,
            z_den_exc: 1.375,
            z_den_inh: 1.5,
            i_syn: -2.0,
            noise: 4.75,
            fired: id % 3 == 0,
            epoch_spikes: 7,
            out_edges: vec![id + 10, id + 20],
            in_edges: vec![(id + 1, true), (id + 2, false)],
        }
    }

    #[test]
    fn batch_roundtrips_bit_exactly() {
        let batch = MigrationBatch {
            records: vec![sample_record(3), sample_record(9)],
            freq_entries: vec![(4, 0.25), (13, 0.5)],
        };
        let back = MigrationBatch::decode(&batch.encode()).unwrap();
        assert_eq!(back, batch);
        let empty = MigrationBatch::default();
        assert!(empty.is_empty());
        assert_eq!(MigrationBatch::decode(&empty.encode()).unwrap(), empty);
    }

    #[test]
    fn decode_rejects_disorder_and_truncation() {
        let batch = MigrationBatch {
            records: vec![sample_record(9), sample_record(3)],
            freq_entries: Vec::new(),
        };
        let err = MigrationBatch::decode(&batch.encode()).unwrap_err();
        assert!(err.contains("ascending"), "{err}");

        let batch = MigrationBatch {
            records: vec![sample_record(1)],
            freq_entries: vec![(9, 0.5), (2, 0.25)],
        };
        let err = MigrationBatch::decode(&batch.encode()).unwrap_err();
        assert!(err.contains("ascending"), "{err}");

        let good = MigrationBatch { records: vec![sample_record(1)], freq_entries: vec![] };
        let buf = good.encode();
        let err = MigrationBatch::decode(&buf[..buf.len() - 3]).unwrap_err();
        assert!(err.contains("truncated"), "{err}");
        // Trailing garbage is rejected too (finish).
        let mut long = buf.clone();
        long.push(0);
        assert!(MigrationBatch::decode(&long).is_err());
    }
}
