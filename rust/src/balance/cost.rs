//! The load-balancing cost model and the deterministic rebalance
//! decision.
//!
//! Each rank measures a [`RankCost`] — population size, stored edges,
//! distinct remote in-partners, and the phase-timer nanoseconds spent
//! so far — and all ranks `gather_all` the vector at every balance
//! epoch. The *decision* uses only the structural terms
//! ([`step_cost`]): they are seed-deterministic, so identically-seeded
//! runs migrate identically (wall-clock nanoseconds ride along for
//! observability and post-hoc analysis, but feeding them into the
//! decision would make trajectories machine-dependent).
//!
//! [`plan_rebalance`] is a greedy boundary-shift: while the imbalance
//! factor (max/mean cost) exceeds the configured threshold, ship one
//! boundary Morton cell of the busiest rank to its cheaper adjacent
//! neighbor — the only move that preserves the contiguous
//! cell-run/id-range invariant ([`Partition`]'s). Cost transfers are
//! estimated proportionally to the moved cell's neuron count. Every
//! rank runs the identical pure function over the identical inputs, so
//! no coordinator or consensus round is needed.

use crate::util::wire::{get_u64, put_u64, Wire};

use super::Partition;

/// One rank's measured load, exchanged at balance epochs.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RankCost {
    /// Local population size.
    pub neurons: u64,
    /// Stored edges, both sides (`total_in + total_out`).
    pub local_edges: u64,
    /// Distinct remote in-partners (the delivery plan's slot count).
    pub remote_partners: u64,
    /// Phase-timer nanoseconds accumulated this segment. Observability
    /// only — never feeds the decision (see module docs).
    pub nanos: u64,
}

impl RankCost {
    /// The deterministic step cost the decision ranks by.
    pub fn cost(&self) -> f64 {
        step_cost(self.neurons, self.local_edges, self.remote_partners)
    }
}

impl Wire for RankCost {
    const SIZE: usize = 32;

    fn write(&self, out: &mut Vec<u8>) {
        put_u64(out, self.neurons);
        put_u64(out, self.local_edges);
        put_u64(out, self.remote_partners);
        put_u64(out, self.nanos);
    }

    fn read(buf: &[u8]) -> Self {
        RankCost {
            neurons: get_u64(buf, 0),
            local_edges: get_u64(buf, 8),
            remote_partners: get_u64(buf, 16),
            nanos: get_u64(buf, 24),
        }
    }
}

/// Structural per-step cost of one rank: every neuron is integrated
/// every step, every stored edge is walked by delivery/plasticity, and
/// every remote partner costs exchange state and slot lookups. Unit
/// weights keep the model dimensionless and deterministic.
pub fn step_cost(neurons: u64, local_edges: u64, remote_partners: u64) -> f64 {
    neurons as f64 + local_edges as f64 + remote_partners as f64
}

/// Imbalance factor: max/mean cost across ranks. 1.0 is perfectly
/// balanced; the slowest rank gates every collective, so this is a
/// direct multiplier on synchronized step time. Degenerate inputs
/// (no ranks, all-zero cost) read as balanced.
pub fn imbalance(costs: &[f64]) -> f64 {
    if costs.is_empty() {
        return 1.0;
    }
    let mean = costs.iter().sum::<f64>() / costs.len() as f64;
    if mean <= 0.0 {
        return 1.0;
    }
    costs.iter().copied().fold(0.0, f64::max) / mean
}

/// Decide a new partition, or `None` when the measured imbalance is at
/// or below `threshold` (or no admissible move improves it). Moves up
/// to `max_moves` boundary cells, each from the currently-busiest rank
/// to whichever adjacent neighbor yields the lower resulting pair
/// maximum, requiring a strict improvement of the busiest rank's cost
/// ceiling. Pure and deterministic: every rank derives the identical
/// partition from the identical gathered costs.
pub fn plan_rebalance(
    part: &Partition,
    costs: &[RankCost],
    threshold: f64,
    max_moves: usize,
) -> Option<Partition> {
    let ranks = part.ranks();
    assert_eq!(costs.len(), ranks, "one cost record per rank");
    if ranks < 2 {
        return None;
    }
    let mut est: Vec<f64> = costs.iter().map(|c| c.cost()).collect();
    let mut neurons: Vec<f64> = costs.iter().map(|c| c.neurons as f64).collect();
    if imbalance(&est) <= threshold {
        return None;
    }
    let mut p = part.clone();
    let mut moved = 0usize;
    while moved < max_moves {
        // Busiest rank; strict comparison keeps the lowest index on
        // ties (determinism).
        let mut r = 0usize;
        for i in 1..ranks {
            if est[i] > est[r] {
                r = i;
            }
        }
        // Candidate moves: the boundary cells of r. A move must keep
        // r at least one cell AND at least one neuron (migrating a
        // rank empty would help nothing and complicates every layer).
        // (direction, resulting pair max, cost transfer, neuron count)
        let mut best: Option<(bool, f64, f64, f64)> = None;
        if r + 1 < ranks && p.cells_of_rank(r).len() > 1 {
            let cell = p.cell_start[r + 1] - 1;
            let k = p.cell_counts[cell] as f64;
            if k > 0.0 && neurons[r] > k {
                let t = est[r] * k / neurons[r];
                let pair = (est[r] - t).max(est[r + 1] + t);
                best = Some((true, pair, t, k));
            }
        }
        if r > 0 && p.cells_of_rank(r).len() > 1 {
            let cell = p.cell_start[r];
            let k = p.cell_counts[cell] as f64;
            if k > 0.0 && neurons[r] > k {
                let t = est[r] * k / neurons[r];
                let pair = (est[r] - t).max(est[r - 1] + t);
                let better = match best {
                    None => true,
                    Some((_, best_pair, _, _)) => pair < best_pair,
                };
                if better {
                    best = Some((false, pair, t, k));
                }
            }
        }
        let Some((to_right, pair, t, k)) = best else { break };
        // Strict improvement of the busiest rank's ceiling, or stop.
        if pair >= est[r] {
            break;
        }
        let nbr = if to_right { r + 1 } else { r - 1 };
        if to_right {
            p.cell_start[r + 1] -= 1;
        } else {
            p.cell_start[r] += 1;
        }
        est[r] -= t;
        est[nbr] += t;
        neurons[r] -= k;
        neurons[nbr] += k;
        moved += 1;
        if imbalance(&est) <= threshold {
            break;
        }
    }
    if moved == 0 {
        None
    } else {
        Some(p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cost(neurons: u64, edges: u64) -> RankCost {
        RankCost { neurons, local_edges: edges, remote_partners: 0, nanos: 7 }
    }

    #[test]
    fn rank_cost_wire_is_32_bytes() {
        let c = RankCost { neurons: 1, local_edges: 2, remote_partners: 3, nanos: 4 };
        let mut buf = Vec::new();
        c.write(&mut buf);
        assert_eq!(buf.len(), RankCost::SIZE);
        assert_eq!(RankCost::read(&buf), c);
    }

    #[test]
    fn imbalance_is_max_over_mean() {
        assert_eq!(imbalance(&[]), 1.0);
        assert_eq!(imbalance(&[0.0, 0.0]), 1.0);
        assert_eq!(imbalance(&[2.0, 2.0]), 1.0);
        assert_eq!(imbalance(&[3.0, 1.0]), 1.5);
    }

    #[test]
    fn balanced_load_plans_nothing() {
        let p = Partition::uniform(2, 32);
        assert!(plan_rebalance(&p, &[cost(32, 100), cost(32, 100)], 1.2, 4).is_none());
        // Single rank: nothing to move to.
        let solo = Partition::uniform(1, 32);
        assert!(plan_rebalance(&solo, &[cost(32, 0)], 1.0, 4).is_none());
    }

    #[test]
    fn skew_moves_boundary_cells_toward_the_light_rank() {
        // 48/16 neurons over 6+2 cells (8 per cell): one move ships the
        // busy rank's LAST cell right.
        let p = Partition {
            cell_counts: vec![8; 8],
            cell_start: vec![0, 6, 8],
        };
        let new = plan_rebalance(&p, &[cost(48, 0), cost(16, 0)], 1.1, 1).unwrap();
        assert_eq!(new.cell_start, vec![0, 5, 8]);
        assert_eq!(new.rank_starts(), vec![0, 40, 64]);
        // Two moves fully even it out (32/32 -> imbalance 1.0 <= 1.1).
        let new2 = plan_rebalance(&p, &[cost(48, 0), cost(16, 0)], 1.1, 8).unwrap();
        assert_eq!(new2.rank_starts(), vec![0, 32, 64]);
        assert_eq!(new2.ownership(), super::super::OwnershipMap::stride(32));
    }

    #[test]
    fn middle_rank_ships_to_the_cheaper_side() {
        // 3 ranks, 1 cell... need >1 cell to move: give rank 1 two
        // cells and overload it; left neighbor is cheaper than right.
        let p = Partition {
            cell_counts: vec![4, 4, 20, 20, 4, 4, 4, 4],
            cell_start: vec![0, 2, 4, 8],
        };
        let costs = [cost(8, 0), cost(40, 0), cost(16, 0)];
        let new = plan_rebalance(&p, &costs, 1.1, 1).unwrap();
        // Rank 1's first cell (20 neurons) goes LEFT to the cheapest
        // neighbor: pair max 8+20=28 beats right's 16+20=36.
        assert_eq!(new.cell_start, vec![0, 3, 4, 8]);
    }

    #[test]
    fn no_admissible_move_returns_none() {
        // The busy rank owns a single cell: it cannot give it away.
        let p = Partition {
            cell_counts: vec![30, 1, 1, 1, 1, 1, 1, 1],
            cell_start: vec![0, 1, 8],
        };
        assert!(plan_rebalance(&p, &[cost(30, 0), cost(7, 0)], 1.1, 4).is_none());
    }

    #[test]
    fn decision_ignores_wall_clock_nanos() {
        let p = Partition { cell_counts: vec![8; 8], cell_start: vec![0, 6, 8] };
        let a = plan_rebalance(
            &p,
            &[cost(48, 0), cost(16, 0)],
            1.1,
            1,
        );
        let mut noisy = [cost(48, 0), cost(16, 0)];
        noisy[0].nanos = 999_999_999;
        noisy[1].nanos = 1;
        let b = plan_rebalance(&p, &noisy, 1.1, 1);
        assert_eq!(a, b, "timers must never steer the (deterministic) decision");
    }

    #[test]
    fn never_empties_a_rank() {
        // Rank 0: two cells but all neurons in one; moving the loaded
        // cell would empty it — only the empty boundary cell could
        // move, which improves nothing.
        let p = Partition {
            cell_counts: vec![10, 0, 1, 1, 1, 1, 1, 1],
            cell_start: vec![0, 2, 8],
        };
        let out = plan_rebalance(&p, &[cost(10, 0), cost(6, 0)], 1.05, 4);
        if let Some(new) = out {
            let starts = new.rank_starts();
            assert!(starts[1] > starts[0] && starts[2] > starts[1], "{starts:?}");
        }
    }
}
