//! Dynamic load balancing: neuron ownership as an explicit, movable
//! subsystem ("move the computation" applied to the partitioning itself).
//!
//! The seed reproduction pinned every neuron to a rank forever through
//! the implicit stride `global_id / neurons_per_rank`, hard-coded in the
//! synapse store's routing tables, both spike paths, the delivery plan's
//! slot interning, and the snapshot layout. Structural plasticity makes
//! load *drift* — formation/deletion skews per-rank edge counts and
//! firing activity, so the slowest rank gates every collective. This
//! module turns that implicit constant into three explicit parts:
//!
//! * [`OwnershipMap`] — who owns a global neuron id. A `Stride` variant
//!   is bit-compatible with the historical layout (one division); the
//!   `Ranges` variant holds contiguous Morton-ordered global-id ranges
//!   per rank and answers `rank_of` in O(log R) via a range table.
//! * [`Partition`] — the cell-level ground truth the map derives from:
//!   per-Morton-cell neuron counts plus the rank → cell assignment.
//!   The invariant that makes migration tractable is that **global id
//!   order equals Morton cell order**: each cell owns one contiguous id
//!   block, each rank owns a consecutive run of cells, hence a
//!   contiguous id range. Migration moves whole boundary cells between
//!   adjacent ranks, which moves contiguous id blocks between adjacent
//!   ranges — ids never renumber, and the spatial octree stays
//!   consistent because a neuron's cell travels with it.
//! * [`cost`] — the per-rank cost model (neurons + edges + remote
//!   partners, with phase-timer nanoseconds carried for observability)
//!   and the deterministic greedy [`plan_rebalance`] decision.
//! * [`migrate`] — the wire format a moving neuron's full state packs
//!   into ([`NeuronRecord`] / [`MigrationBatch`]); the driver's
//!   migration protocol in `coordinator` exchanges these through the
//!   existing all-to-all.
//!
//! The decision inputs are gathered with one `gather_all` per balance
//! epoch, so every rank computes the identical new partition — there is
//! no coordinator rank.

pub mod cost;
pub mod migrate;

pub use cost::{imbalance, plan_rebalance, step_cost, RankCost};
pub use migrate::{MigrationBatch, NeuronRecord};

use crate::config::SimConfig;
use crate::neuron::GlobalNeuronId;
use crate::octree::DomainDecomposition;
use crate::util::wire::{put_u32, put_u64, Cursor};

/// Who owns a global neuron id.
///
/// `Stride` is the historical fixed layout (`id / neurons_per_rank`),
/// kept as a fast path that is bit-compatible decision-for-decision
/// with a uniform `Ranges` map (property-tested). `Ranges` stores the
/// per-rank range starts (`starts[r]..starts[r+1]` = rank r's ids,
/// length R+1); `rank_of` is a binary search, O(log R).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum OwnershipMap {
    /// Fixed blocks: rank = `id / neurons_per_rank`.
    Stride { neurons_per_rank: u64 },
    /// Contiguous per-rank id ranges; `starts` is non-decreasing with
    /// `starts[0] == 0` (equal adjacent entries = an empty rank).
    Ranges { starts: Vec<u64> },
}

impl OwnershipMap {
    /// The historical fixed-block layout.
    pub fn stride(neurons_per_rank: u64) -> OwnershipMap {
        assert!(neurons_per_rank > 0, "stride must be positive");
        OwnershipMap::Stride { neurons_per_rank }
    }

    /// An explicit range table (`starts[r]..starts[r+1]` per rank).
    pub fn ranges(starts: Vec<u64>) -> Result<OwnershipMap, String> {
        if starts.len() < 2 {
            return Err("ownership ranges need at least one rank".to_string());
        }
        if starts[0] != 0 {
            return Err(format!("ownership ranges must start at id 0, got {}", starts[0]));
        }
        for w in starts.windows(2) {
            if w[0] > w[1] {
                return Err(format!(
                    "ownership range starts must be non-decreasing: {} then {}",
                    w[0], w[1]
                ));
            }
        }
        Ok(OwnershipMap::Ranges { starts })
    }

    /// Which rank owns `id`. The one computation every routing layer
    /// shares; `Stride` is a single division, `Ranges` an O(log R)
    /// search over the range table.
    #[inline]
    pub fn rank_of(&self, id: GlobalNeuronId) -> u32 {
        match self {
            OwnershipMap::Stride { neurons_per_rank } => (id / neurons_per_rank) as u32,
            OwnershipMap::Ranges { starts } => {
                debug_assert!(
                    id < *starts.last().unwrap(),
                    "id {id} beyond the owned id space"
                );
                (starts.partition_point(|&s| s <= id) - 1) as u32
            }
        }
    }

    /// First global id of `rank`'s contiguous range.
    #[inline]
    pub fn first_id(&self, rank: usize) -> GlobalNeuronId {
        match self {
            OwnershipMap::Stride { neurons_per_rank } => rank as u64 * neurons_per_rank,
            OwnershipMap::Ranges { starts } => starts[rank],
        }
    }

    /// Number of neurons `rank` owns.
    #[inline]
    pub fn count(&self, rank: usize) -> u64 {
        match self {
            OwnershipMap::Stride { neurons_per_rank } => *neurons_per_rank,
            OwnershipMap::Ranges { starts } => starts[rank + 1] - starts[rank],
        }
    }

    /// Is this the historical fixed layout?
    pub fn is_stride(&self) -> bool {
        matches!(self, OwnershipMap::Stride { .. })
    }
}

/// The cell-level partition the ownership map derives from (replicated
/// identically on every rank; migration replaces it wholesale).
///
/// Invariants (checked by [`Partition::validate`]):
/// * `cell_counts[c]` = neurons whose ids form the c-th contiguous id
///   block (ids ascend with Morton cell index across the whole domain);
/// * `cell_start[r]..cell_start[r+1]` = the consecutive Morton cells of
///   rank r (every rank keeps at least one cell);
/// * rank r's id range is therefore the prefix-sum window of its cells.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Partition {
    /// Neurons per Morton cell, in Morton order.
    pub cell_counts: Vec<u64>,
    /// `cell_start[r]..cell_start[r+1]` = cells of rank r; length R+1.
    pub cell_start: Vec<usize>,
}

impl Partition {
    pub fn ranks(&self) -> usize {
        self.cell_start.len() - 1
    }

    pub fn num_cells(&self) -> usize {
        self.cell_counts.len()
    }

    pub fn total_neurons(&self) -> u64 {
        self.cell_counts.iter().sum()
    }

    /// Morton cells of `rank`.
    pub fn cells_of_rank(&self, rank: usize) -> std::ops::Range<usize> {
        self.cell_start[rank]..self.cell_start[rank + 1]
    }

    /// The default uniform partition: the cell assignment of
    /// `DomainDecomposition::new`, with each rank's `neurons_per_rank`
    /// neurons spread near-evenly over its own cells. Its ownership map
    /// normalizes to `Stride`, so a run that never balances is
    /// bit-identical to the historical layout.
    pub fn uniform(ranks: usize, neurons_per_rank: u64) -> Partition {
        let decomp = DomainDecomposition::new(ranks, 1.0);
        let cell_start = decomp.cell_partition();
        let mut cell_counts = vec![0u64; decomp.num_cells];
        for r in 0..ranks {
            let cells = cell_start[r]..cell_start[r + 1];
            let n_cells = cells.len() as u64;
            let base = neurons_per_rank / n_cells;
            let extra = neurons_per_rank % n_cells;
            for (k, c) in cells.enumerate() {
                cell_counts[c] = base + u64::from((k as u64) < extra);
            }
        }
        Partition { cell_counts, cell_start }
    }

    /// Build the initial partition a config describes: uniform unless
    /// `balance.init_cells` names an explicit per-rank cell split
    /// (comma-separated cell counts summing to the 8^b Morton cells),
    /// in which case the total neuron population is spread near-evenly
    /// over ALL cells — ranks owning more cells own more neurons, which
    /// is exactly the skew the rebalancer then irons out.
    pub fn from_config(cfg: &SimConfig) -> Result<Partition, String> {
        if cfg.ranks == 0 || cfg.neurons_per_rank == 0 {
            return Err("balance: topology must have ranks > 0 and neurons_per_rank > 0".into());
        }
        if cfg.balance_init_cells.is_empty() {
            return Ok(Partition::uniform(cfg.ranks, cfg.neurons_per_rank as u64));
        }
        let num_cells = DomainDecomposition::new(cfg.ranks, 1.0).num_cells;
        let mut per_rank = Vec::with_capacity(cfg.ranks);
        for part in cfg.balance_init_cells.split(',') {
            let n: usize = part.trim().parse().map_err(|_| {
                format!("balance.init_cells: {:?} is not a cell count", part.trim())
            })?;
            if n == 0 {
                return Err("balance.init_cells: every rank needs at least one cell".into());
            }
            per_rank.push(n);
        }
        if per_rank.len() != cfg.ranks {
            return Err(format!(
                "balance.init_cells lists {} ranks but topology.ranks is {}",
                per_rank.len(),
                cfg.ranks
            ));
        }
        let sum: usize = per_rank.iter().sum();
        if sum != num_cells {
            return Err(format!(
                "balance.init_cells cells sum to {sum} but the {}-rank domain has \
                 {num_cells} Morton cells",
                cfg.ranks
            ));
        }
        let mut cell_start = Vec::with_capacity(cfg.ranks + 1);
        let mut at = 0usize;
        for &n in &per_rank {
            cell_start.push(at);
            at += n;
        }
        cell_start.push(at);
        let total = (cfg.ranks * cfg.neurons_per_rank) as u64;
        let base = total / num_cells as u64;
        let extra = total % num_cells as u64;
        let cell_counts: Vec<u64> =
            (0..num_cells).map(|c| base + u64::from((c as u64) < extra)).collect();
        let partition = Partition { cell_counts, cell_start };
        // Every layer assumes a rank owns at least one neuron (its
        // contiguous id range anchors routing and the octree); a split
        // this sparse cannot seed one.
        let starts = partition.rank_starts();
        for r in 0..cfg.ranks {
            if starts[r + 1] == starts[r] {
                return Err(format!(
                    "balance.init_cells leaves rank {r} with zero neurons ({} neurons \
                     over {num_cells} cells are too few for this split)",
                    total
                ));
            }
        }
        Ok(partition)
    }

    /// Per-rank id range starts (length R+1): the prefix sums of the
    /// cell counts sampled at the rank boundaries.
    pub fn rank_starts(&self) -> Vec<u64> {
        let mut prefix = Vec::with_capacity(self.num_cells() + 1);
        prefix.push(0u64);
        for &c in &self.cell_counts {
            prefix.push(prefix.last().unwrap() + c);
        }
        self.cell_start.iter().map(|&c| prefix[c]).collect()
    }

    /// First global id of `cell`'s contiguous block.
    pub fn first_id_of_cell(&self, cell: usize) -> u64 {
        self.cell_counts[..cell].iter().sum()
    }

    /// The id-routing view of this partition. Uniform per-rank counts
    /// normalize to the bit-compatible `Stride` fast path; anything
    /// else is a `Ranges` table.
    pub fn ownership(&self) -> OwnershipMap {
        let starts = self.rank_starts();
        let ranks = self.ranks();
        let first = starts[1] - starts[0];
        if first > 0 && (0..ranks).all(|r| starts[r + 1] - starts[r] == first) {
            OwnershipMap::stride(first)
        } else {
            OwnershipMap::ranges(starts).expect("prefix sums are monotone")
        }
    }

    /// The spatial decomposition this partition's cell assignment
    /// induces.
    pub fn decomposition(&self, domain_size: f64) -> DomainDecomposition {
        DomainDecomposition::with_cells(domain_size, self.cell_start.clone())
    }

    /// Structural validation (used when a partition arrives from a
    /// snapshot): rank/total agreement plus the cell-run invariants.
    pub fn validate(&self, ranks: usize, total_neurons: u64) -> Result<(), String> {
        if self.ranks() != ranks {
            return Err(format!(
                "partition describes {} ranks, expected {ranks}",
                self.ranks()
            ));
        }
        if self.cell_start[0] != 0 || *self.cell_start.last().unwrap() != self.num_cells() {
            return Err("partition cell runs must cover all Morton cells".to_string());
        }
        for w in self.cell_start.windows(2) {
            if w[0] >= w[1] {
                return Err("every rank must keep at least one Morton cell".to_string());
            }
        }
        if !self.num_cells().is_power_of_two() || self.num_cells().trailing_zeros() % 3 != 0 {
            return Err(format!(
                "partition has {} cells; Morton domains have 8^b",
                self.num_cells()
            ));
        }
        if self.total_neurons() != total_neurons {
            return Err(format!(
                "partition holds {} neurons, simulation has {total_neurons}",
                self.total_neurons()
            ));
        }
        Ok(())
    }

    /// Encode for the snapshot header (little-endian, counted arrays).
    pub fn encode(&self, out: &mut Vec<u8>) {
        put_u32(out, self.num_cells() as u32);
        for &c in &self.cell_counts {
            put_u64(out, c);
        }
        put_u32(out, self.cell_start.len() as u32);
        for &s in &self.cell_start {
            put_u32(out, s as u32);
        }
    }

    /// Decode a snapshot header's partition section.
    pub fn decode(c: &mut Cursor<'_>) -> Result<Partition, String> {
        let cells = c.u32("partition cell count")? as usize;
        let mut cell_counts = Vec::with_capacity(cells.min(c.remaining() / 8));
        for _ in 0..cells {
            cell_counts.push(c.u64("partition cell neurons")?);
        }
        let starts = c.u32("partition rank count")? as usize;
        if starts < 2 {
            return Err("partition needs at least one rank".to_string());
        }
        let mut cell_start = Vec::with_capacity(starts.min(c.remaining() / 4));
        for _ in 0..starts {
            cell_start.push(c.u32("partition cell start")? as usize);
        }
        Ok(Partition { cell_counts, cell_start })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::forall;
    use crate::util::Rng;

    #[test]
    fn stride_and_uniform_ranges_agree_everywhere() {
        // The tentpole equivalence: a uniform Ranges map must be
        // decision-for-decision identical to Stride over the whole id
        // space, and at every range boundary.
        forall(
            "uniform Ranges ≡ Stride (rank_of/first_id/count)",
            50,
            |rng| (1 + rng.next_below(16), 1 + rng.next_below(512) as u64),
            |&(ranks, npr)| {
                let stride = OwnershipMap::stride(npr);
                let starts: Vec<u64> = (0..=ranks as u64).map(|r| r * npr).collect();
                let ranges = OwnershipMap::ranges(starts).unwrap();
                for rank in 0..ranks {
                    if stride.first_id(rank) != ranges.first_id(rank) {
                        return Err(format!("first_id({rank}) differs"));
                    }
                    if stride.count(rank) != ranges.count(rank) {
                        return Err(format!("count({rank}) differs"));
                    }
                }
                let total = ranks as u64 * npr;
                let mut rng = Rng::new(npr ^ ranks as u64);
                for _ in 0..200 {
                    let id = rng.next_below(total as usize) as u64;
                    if stride.rank_of(id) != ranges.rank_of(id) {
                        return Err(format!("rank_of({id}) differs"));
                    }
                }
                for rank in 0..ranks {
                    let lo = rank as u64 * npr;
                    for id in [lo, lo + npr - 1] {
                        if ranges.rank_of(id) != rank as u32 {
                            return Err(format!("boundary id {id} misrouted"));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn ranges_rejects_bad_tables_and_allows_empty_ranks() {
        assert!(OwnershipMap::ranges(vec![0]).is_err());
        assert!(OwnershipMap::ranges(vec![1, 5]).is_err());
        assert!(OwnershipMap::ranges(vec![0, 5, 3]).is_err());
        // An empty middle rank (equal adjacent starts) routes around it.
        let m = OwnershipMap::ranges(vec![0, 5, 5, 10]).unwrap();
        assert_eq!(m.count(1), 0);
        assert_eq!(m.rank_of(4), 0);
        assert_eq!(m.rank_of(5), 2);
        assert_eq!(m.rank_of(9), 2);
    }

    #[test]
    fn uniform_partition_normalizes_to_stride() {
        let p = Partition::uniform(4, 32);
        assert_eq!(p.total_neurons(), 128);
        assert_eq!(p.rank_starts(), vec![0, 32, 64, 96, 128]);
        assert_eq!(p.ownership(), OwnershipMap::stride(32));
        p.validate(4, 128).unwrap();
        // Cell assignment matches the decomposition's.
        let d = DomainDecomposition::new(4, 1.0);
        assert_eq!(p.cell_start, d.cell_partition());
    }

    #[test]
    fn uniform_partition_splits_odd_counts_within_the_rank() {
        // 2 ranks x 5 neurons over 4 cells each: 2,1,1,1 per rank —
        // totals stay exactly neurons_per_rank (stride compatibility).
        let p = Partition::uniform(2, 5);
        assert_eq!(p.cell_counts, vec![2, 1, 1, 1, 2, 1, 1, 1]);
        assert_eq!(p.ownership(), OwnershipMap::stride(5));
    }

    #[test]
    fn skewed_config_partition_is_ranges() {
        let cfg = SimConfig {
            ranks: 2,
            neurons_per_rank: 32,
            balance_init_cells: "6,2".to_string(),
            ..SimConfig::default()
        };
        let p = Partition::from_config(&cfg).unwrap();
        assert_eq!(p.cell_start, vec![0, 6, 8]);
        assert_eq!(p.total_neurons(), 64);
        assert_eq!(p.rank_starts(), vec![0, 48, 64]);
        match p.ownership() {
            OwnershipMap::Ranges { starts } => assert_eq!(starts, vec![0, 48, 64]),
            other => panic!("expected Ranges, got {other:?}"),
        }
        p.validate(2, 64).unwrap();
    }

    #[test]
    fn from_config_rejects_malformed_init_cells() {
        let mut cfg = SimConfig { ranks: 2, neurons_per_rank: 8, ..SimConfig::default() };
        for bad in ["6,x", "6", "6,2,0", "0,8", "5,2"] {
            cfg.balance_init_cells = bad.to_string();
            assert!(Partition::from_config(&cfg).is_err(), "{bad:?} must be rejected");
        }
        cfg.balance_init_cells = "4,4".to_string();
        Partition::from_config(&cfg).unwrap();
        // A population too sparse for the split would leave a rank with
        // zero neurons — rejected up front.
        cfg.neurons_per_rank = 2; // 4 neurons over 8 cells
        cfg.balance_init_cells = "6,2".to_string();
        let err = Partition::from_config(&cfg).unwrap_err();
        assert!(err.contains("zero neurons"), "{err}");
    }

    #[test]
    fn explicit_uniform_init_cells_equals_default_partition() {
        // "4,4" with a cell-divisible population IS the default uniform
        // partition — same cells, same counts, same (Stride) map. This
        // is what lets the config fingerprint hash the canonical
        // partition instead of the raw string.
        let cfg = SimConfig {
            ranks: 2,
            neurons_per_rank: 32,
            balance_init_cells: "4,4".to_string(),
            ..SimConfig::default()
        };
        assert_eq!(Partition::from_config(&cfg).unwrap(), Partition::uniform(2, 32));
    }

    #[test]
    fn partition_encode_decode_roundtrip() {
        let p = Partition::from_config(&SimConfig {
            ranks: 2,
            neurons_per_rank: 32,
            balance_init_cells: "6,2".to_string(),
            ..SimConfig::default()
        })
        .unwrap();
        let mut buf = Vec::new();
        p.encode(&mut buf);
        let back = Partition::decode(&mut Cursor::new(&buf, "partition")).unwrap();
        assert_eq!(back, p);
        // Truncation errors instead of panicking.
        let err =
            Partition::decode(&mut Cursor::new(&buf[..buf.len() / 2], "partition"))
                .unwrap_err();
        assert!(err.contains("truncated"), "{err}");
    }

    #[test]
    fn validate_catches_structural_corruption() {
        let mut p = Partition::uniform(2, 8);
        p.validate(2, 16).unwrap();
        assert!(p.validate(3, 16).is_err());
        assert!(p.validate(2, 17).is_err());
        p.cell_start[1] = p.cell_start[2]; // rank 1 left with zero cells
        assert!(p.validate(2, 16).is_err());
    }

    #[test]
    fn first_id_of_cell_tracks_prefix_sums() {
        let p = Partition::uniform(2, 6); // 4 cells/rank: 2,2,1,1 each
        assert_eq!(p.first_id_of_cell(0), 0);
        assert_eq!(p.first_id_of_cell(1), 2);
        assert_eq!(p.first_id_of_cell(4), 6);
        assert_eq!(p.first_id_of_cell(7), 11);
    }
}
