//! Checkpoint/restore: versioned binary snapshots of the complete
//! per-rank simulation state, with deterministic (bit-exact) resume and
//! scenario branching.
//!
//! The paper's motivating use cases — predicting brain changes after
//! learning, lesions, or development (§I, §VI) — all need long runs
//! that reach equilibrium before the interesting protocol starts. This
//! subsystem turns the reproduction into a restartable, branchable
//! simulation service: grow a brain once, snapshot it, then fan out
//! lesion / stimulus / parameter-sweep scenarios from the same saved
//! state instead of regrowing the connectome per scenario.
//!
//! * [`format`] — the versioned little-endian file format (magic +
//!   version + config fingerprint + per-rank sections) and what exactly
//!   is captured for bit-exact resume. See `DESIGN.md` §6 for the spec.
//! * [`writer`] — single-file assembly, atomic writes, the in-run
//!   checkpoint sinks ([`CheckpointSink`] for rank threads, [`PartSink`]
//!   for rank processes, both behind [`SectionSink`]) and the
//!   `checkpoint_keep` retention ring.
//! * [`reader`] — parsing plus layered validation: structural fit,
//!   exact fingerprint match for resume, relaxed structural-only checks
//!   for deliberate scenario branches, and [`scan_for_recovery`], the
//!   supervisor's fall-back-past-corruption checkpoint scan.
//!
//! Determinism contract: running `2N` steps straight produces a
//! `SimReport` identical (synapse counts, calcium, transferred bytes)
//! to running `N` steps, checkpointing, and resuming for `N` more —
//! the coordinator's tests assert this for both the old and the new
//! algorithm pairs. Checkpoint I/O never touches the simulated-MPI
//! communicator, so the paper's byte accounting is unaffected.

pub mod format;
pub mod reader;
pub mod writer;

pub use format::{
    config_fingerprint, config_fingerprint_for_version, content_checksum, peek_version,
    RankSection, SnapshotHeader, FORMAT_VERSION, MAGIC, MIN_FORMAT_VERSION,
};
pub use reader::{latest_snapshot_in, scan_for_recovery, RecoveryScan, Snapshot};
pub use writer::{
    prune_checkpoint_ring, snapshot_file_name, step_of_file_name, write_snapshot,
    write_snapshot_sections, write_snapshot_with_partition, CheckpointSink, PartSink,
    SectionSink,
};
