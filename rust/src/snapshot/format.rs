//! The versioned binary snapshot format (little-endian throughout).
//!
//! Layout of one snapshot file:
//!
//! ```text
//! header:
//!   magic              8 B   b"ILMISNAP"
//!   format_version     u32   = 5 (this build also reads versions 1-4)
//!   config_fingerprint u64   FNV-1a over the dynamics-relevant config
//!   next_step          u64   first step index the resumed run executes
//!   ranks              u32
//!   neurons_per_rank   u32
//!   config_ini_len     u32
//!   config_ini         ..    the full config, `SimConfig::to_ini` text
//!   ownership (v4+):
//!     tag              u8    0 = uniform stride (reconstruct from the
//!                            config), 1 = explicit partition follows
//!     partition        ..    `balance::Partition::encode` when tag = 1
//! sections (one per rank, in rank order):
//!   rank               u32
//!   section_len        u64
//!   section            ..    see `RankSection::encode`
//! trailer (v5+):
//!   content_checksum   u64   FNV-1a over every preceding byte
//! ```
//!
//! A rank section captures everything `RankState::restore` needs for a
//! bit-exact resume: the `Population` arrays, the full `SynapseStore`,
//! all three PRNG streams (including the cached polar-method spare
//! normal), the `FrequencyExchange` sparse entries, and the report
//! baselines (communication counters, formation/deletion statistics,
//! calcium trace) so a resumed run's final `SimReport` equals the
//! straight run's. The octree is NOT stored — it is rebuilt from
//! positions on load, and its per-update aggregates are recomputed from
//! scratch at every plasticity phase anyway.
//!
//! **Version history.** v1 stored the frequency table as a dense
//! `total_neurons × f32` array on every rank; v2 stores the sparse
//! (id, frequency) entries the exchange actually holds — O(local
//! remote partners) per section instead of O(total neurons)
//! (EXPERIMENTS.md §Perf, opt 7). v1 sections still decode: the dense
//! table converts to sparse entries, dropping zeros (a zero frequency
//! and a missing entry are behaviorally identical — neither ever draws
//! the reconstruction PRNG). v3 was reserved (never emitted) to keep
//! the snapshot and BENCH schema generations aligned. v4 adds the
//! header's ownership section: the load-balancing `Partition`
//! (per-cell neuron counts + rank → cell assignment) a rebalanced run
//! must restore with; readers map v1–v3 files — and v4 files with the
//! uniform tag — to the historical `Stride` ownership. Rank sections
//! are unchanged since v2 (per-rank neuron counts may now differ; the
//! expected count per section comes from the partition). v5 appends a
//! whole-file FNV-1a content checksum so *any* corruption — including
//! payload bit-rot the structural checks cannot see — is a checked
//! read error, which the checkpoint-recovery scan (DESIGN.md §13)
//! relies on to fall back past a damaged newest checkpoint.
//!
//! The encoding deliberately reuses the `util::wire` primitives used by
//! the inter-rank message codecs; decoding goes through the checked
//! `wire::Cursor` so truncated or corrupt files produce descriptive
//! errors instead of panics.

use crate::balance::Partition;
use crate::barnes_hut::FormationStats;
use crate::comm::CounterSnapshot;
use crate::config::{ConnectivityAlg, NeuronModel, SimConfig, SpikeAlg};
use crate::plasticity::DeletionStats;
use crate::util::wire::{put_f32, put_f64, put_u32, put_u64, put_u8, Cursor};
use crate::util::{RngState, Vec3};

/// File magic: identifies an ILMI snapshot.
pub const MAGIC: [u8; 8] = *b"ILMISNAP";

/// Current snapshot format version (what this build writes). Bump on
/// any layout change.
pub const FORMAT_VERSION: u32 = 5;

/// Oldest snapshot format version this build still reads.
pub const MIN_FORMAT_VERSION: u32 = 1;

/// File extension snapshots are written with.
pub const SNAPSHOT_EXT: &str = "ilmisnap";

fn fnv1a(hash: u64, bytes: &[u8]) -> u64 {
    let mut h = hash;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// The v5+ whole-file content checksum: FNV-1a over every byte before
/// the 8-byte little-endian trailer that stores it. Not cryptographic —
/// it defends against truncation and bit-rot, not an adversary.
pub fn content_checksum(bytes: &[u8]) -> u64 {
    fnv1a(0xcbf2_9ce4_8422_2325, bytes)
}

/// Read the format version from the fixed-offset field right after the
/// magic, without parsing the variable-length header — the reader needs
/// it up front to know whether a content-checksum trailer is present.
/// `None` when the buffer is too short or the magic is wrong (full
/// header decoding then produces the descriptive error).
pub fn peek_version(buf: &[u8]) -> Option<u32> {
    if buf.len() < 12 || buf[..8] != MAGIC {
        return None;
    }
    Some(u32::from_le_bytes(buf[8..12].try_into().unwrap()))
}

/// Fingerprint of every config field that influences the simulation
/// *dynamics*. Two configs with equal fingerprints produce identical
/// trajectories from identical state, so resuming under a mismatched
/// fingerprint is refused (unless explicitly branching). Schedule
/// length, backend and instrumentation are excluded: changing them does
/// not invalidate saved state.
pub fn config_fingerprint(cfg: &SimConfig) -> u64 {
    config_fingerprint_for_version(cfg, FORMAT_VERSION)
}

/// `config_fingerprint` as the build that wrote format `version`
/// computed it. v1–v3 builds hashed no balance bytes; recomputing
/// their exact hash is what keeps their snapshots resumable under
/// `validate_for` instead of failing with a misleading
/// dynamics-mismatch error.
pub fn config_fingerprint_for_version(cfg: &SimConfig, version: u32) -> u64 {
    let mut buf = Vec::with_capacity(256);
    put_u64(&mut buf, cfg.ranks as u64);
    put_u64(&mut buf, cfg.neurons_per_rank as u64);
    put_f64(&mut buf, cfg.domain_size);
    put_u64(&mut buf, cfg.seed);
    put_u64(&mut buf, cfg.plasticity_interval as u64);
    put_u64(&mut buf, cfg.delta as u64);
    put_u8(
        &mut buf,
        match cfg.connectivity_alg {
            ConnectivityAlg::OldRma => 0,
            ConnectivityAlg::NewLocationAware => 1,
            ConnectivityAlg::Direct => 2,
        },
    );
    put_u8(
        &mut buf,
        match cfg.spike_alg {
            SpikeAlg::OldIds => 0,
            SpikeAlg::NewFrequency => 1,
        },
    );
    put_u8(
        &mut buf,
        match cfg.neuron_model {
            NeuronModel::Izhikevich => 0,
            NeuronModel::Poisson => 1,
        },
    );
    put_f64(&mut buf, cfg.theta);
    put_f64(&mut buf, cfg.sigma);
    put_f64(&mut buf, cfg.frac_excitatory);
    put_f64(&mut buf, cfg.init_elements_lo);
    put_f64(&mut buf, cfg.init_elements_hi);
    put_f64(&mut buf, cfg.bg_mean);
    put_f64(&mut buf, cfg.bg_std);
    for p in cfg.neuron.to_vec() {
        put_f32(&mut buf, p);
    }
    // Load balancing changes trajectories, so its knobs are
    // dynamics-relevant (v4+ only: pre-v4 builds hashed none of this,
    // and their snapshots must keep verifying). The initial partition
    // is hashed in CANONICAL form (the parsed cell counts +
    // assignment, not the raw `init_cells` string), so spellings that
    // describe the identical partition — e.g. an explicit uniform
    // "4,4" vs the empty default — fingerprint identically. An
    // unparseable split falls back to the raw string;
    // `SimConfig::validate` rejects such configs anyway.
    if version >= 4 {
        put_u64(&mut buf, cfg.balance_every as u64);
        put_f64(&mut buf, cfg.balance_threshold);
        put_u64(&mut buf, cfg.balance_max_moves as u64);
        match Partition::from_config(cfg) {
            Ok(p) => p.encode(&mut buf),
            Err(_) => buf.extend_from_slice(cfg.balance_init_cells.as_bytes()),
        }
    }
    fnv1a(0xcbf2_9ce4_8422_2325, &buf)
}

/// Parsed snapshot header (everything before the rank sections).
#[derive(Clone, Debug)]
pub struct SnapshotHeader {
    pub version: u32,
    pub fingerprint: u64,
    /// First step index the resumed run executes (= steps completed).
    pub next_step: u64,
    pub ranks: u32,
    pub neurons_per_rank: u32,
    /// The originating config, serialized with `SimConfig::to_ini`.
    pub config_ini: String,
    /// The ownership partition at capture time (v4+). `None` = the
    /// uniform stride layout (also what every v1–v3 file maps to);
    /// `Some` = an explicitly skewed or migrated partition the restore
    /// must reproduce.
    pub partition: Option<Partition>,
}

impl SnapshotHeader {
    pub fn for_config(cfg: &SimConfig, next_step: u64) -> SnapshotHeader {
        SnapshotHeader {
            version: FORMAT_VERSION,
            fingerprint: config_fingerprint(cfg),
            next_step,
            ranks: cfg.ranks as u32,
            neurons_per_rank: cfg.neurons_per_rank as u32,
            config_ini: cfg.to_ini(),
            partition: None,
        }
    }

    /// `for_config`, recording the run's CURRENT partition: stored
    /// explicitly unless it is exactly the uniform default (which every
    /// reader reconstructs from the config).
    pub fn for_run(cfg: &SimConfig, next_step: u64, partition: &Partition) -> SnapshotHeader {
        let mut hdr = Self::for_config(cfg, next_step);
        if *partition != Partition::uniform(cfg.ranks, cfg.neurons_per_rank as u64) {
            hdr.partition = Some(partition.clone());
        }
        hdr
    }

    pub fn encode(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&MAGIC);
        put_u32(out, self.version);
        put_u64(out, self.fingerprint);
        put_u64(out, self.next_step);
        put_u32(out, self.ranks);
        put_u32(out, self.neurons_per_rank);
        put_u32(out, self.config_ini.len() as u32);
        out.extend_from_slice(self.config_ini.as_bytes());
        if self.version >= 4 {
            match &self.partition {
                None => put_u8(out, 0),
                Some(p) => {
                    put_u8(out, 1);
                    p.encode(out);
                }
            }
        }
    }

    pub fn decode(c: &mut Cursor<'_>) -> Result<SnapshotHeader, String> {
        let magic = c.bytes(8, "magic")?;
        if magic != MAGIC {
            return Err(format!(
                "not an ILMI snapshot: bad magic {:02x?} (expected {:02x?})",
                magic, MAGIC
            ));
        }
        let version = c.u32("format version")?;
        if !(MIN_FORMAT_VERSION..=FORMAT_VERSION).contains(&version) {
            return Err(format!(
                "unsupported snapshot format version {version}: this build reads \
                 versions {MIN_FORMAT_VERSION}..={FORMAT_VERSION} only"
            ));
        }
        let fingerprint = c.u64("config fingerprint")?;
        let next_step = c.u64("step counter")?;
        let ranks = c.u32("rank count")?;
        let neurons_per_rank = c.u32("neurons per rank")?;
        let ini_len = c.u32("config length")? as usize;
        let ini = c.bytes(ini_len, "config text")?;
        let config_ini = String::from_utf8(ini.to_vec())
            .map_err(|_| "snapshot: embedded config is not valid UTF-8".to_string())?;
        let partition = if version >= 4 {
            match c.u8("ownership tag")? {
                0 => None,
                1 => {
                    let p = Partition::decode(c)?;
                    p.validate(ranks as usize, ranks as u64 * neurons_per_rank as u64)
                        .map_err(|e| format!("snapshot ownership partition: {e}"))?;
                    Some(p)
                }
                other => {
                    return Err(format!("snapshot: unknown ownership tag {other}"));
                }
            }
        } else {
            None
        };
        Ok(SnapshotHeader {
            version,
            fingerprint,
            next_step,
            ranks,
            neurons_per_rank,
            config_ini,
            partition,
        })
    }
}

fn put_rng(out: &mut Vec<u8>, st: &RngState) {
    for w in st.s {
        put_u64(out, w);
    }
    match st.spare_normal {
        Some(z) => {
            put_u8(out, 1);
            put_f64(out, z);
        }
        None => {
            put_u8(out, 0);
            put_f64(out, 0.0);
        }
    }
}

fn read_rng(c: &mut Cursor<'_>, what: &str) -> Result<RngState, String> {
    let mut s = [0u64; 4];
    for w in s.iter_mut() {
        *w = c.u64(what)?;
    }
    let has_spare = c.u8(what)?;
    let spare = c.f64(what)?;
    Ok(RngState {
        s,
        spare_normal: if has_spare != 0 { Some(spare) } else { None },
    })
}

/// One rank's complete captured state.
#[derive(Clone, Debug)]
pub struct RankSection {
    // -- population -----------------------------------------------------
    pub first_id: u64,
    pub positions: Vec<Vec3>,
    pub is_excitatory: Vec<bool>,
    pub v: Vec<f32>,
    pub u: Vec<f32>,
    pub ca: Vec<f32>,
    pub z_ax: Vec<f32>,
    pub z_den_exc: Vec<f32>,
    pub z_den_inh: Vec<f32>,
    pub i_syn: Vec<f32>,
    pub noise: Vec<f32>,
    pub fired: Vec<bool>,
    pub epoch_spikes: Vec<u32>,
    // -- synapse store --------------------------------------------------
    pub out_edges: Vec<Vec<u64>>,
    /// (source id, source_exc) pairs per local target.
    pub in_edges: Vec<Vec<(u64, bool)>>,
    pub connected_ax: Vec<u32>,
    pub connected_den_exc: Vec<u32>,
    pub connected_den_inh: Vec<u32>,
    // -- PRNG streams ---------------------------------------------------
    pub rng_model: RngState,
    pub rng_conn: RngState,
    /// The `FrequencyExchange` reconstruction stream.
    pub rng_spikes: RngState,
    /// The `FrequencyExchange` sparse state: (sender id, frequency)
    /// entries in strictly ascending id order — O(local remote
    /// partners), not O(total neurons). Decoding a v1 section converts
    /// its dense table into this form (zeros dropped).
    pub freq_entries: Vec<(u64, f32)>,
    // -- report baselines (so a resumed SimReport equals a straight run)
    pub baseline_comm: CounterSnapshot,
    pub spike_lookups: u64,
    pub deletion: DeletionStats,
    pub formation: FormationStats,
    pub calcium_trace: Vec<(u64, Vec<f32>)>,
}

impl RankSection {
    /// Number of local neurons this section describes.
    pub fn len(&self) -> usize {
        self.positions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.positions.is_empty()
    }

    /// Cross-check the synapse arrays without building a
    /// `SynapseStore`: bound-element counters vs edge lists (mirrors
    /// `SynapseStore::check_invariants`) plus every edge id being a
    /// valid global neuron id below `total_neurons` — a corrupt id
    /// would otherwise pass counter checks and index out of bounds
    /// deep inside the spike exchange. Lets callers reject a corrupt
    /// section before any simulation state is constructed.
    pub fn check_synapse_consistency(&self, total_neurons: u64) -> Result<(), String> {
        for i in 0..self.len() {
            if self.out_edges[i].len() != self.connected_ax[i] as usize {
                return Err(format!("neuron {i}: out edges vs connected_ax mismatch"));
            }
            if let Some(&tgt) = self.out_edges[i].iter().find(|&&t| t >= total_neurons) {
                return Err(format!(
                    "neuron {i}: out-edge target {tgt} out of range (total neurons \
                     {total_neurons})"
                ));
            }
            let exc = self.in_edges[i].iter().filter(|(_, exc)| *exc).count();
            let inh = self.in_edges[i].len() - exc;
            if exc != self.connected_den_exc[i] as usize {
                return Err(format!("neuron {i}: exc in-edges mismatch"));
            }
            if inh != self.connected_den_inh[i] as usize {
                return Err(format!("neuron {i}: inh in-edges mismatch"));
            }
            if let Some(&(src, _)) = self.in_edges[i].iter().find(|&&(s, _)| s >= total_neurons) {
                return Err(format!(
                    "neuron {i}: in-edge source {src} out of range (total neurons \
                     {total_neurons})"
                ));
            }
        }
        Ok(())
    }

    /// Validate the sparse frequency entries: strictly ascending ids
    /// (the binary-search lookup invariant) that are valid global
    /// neuron ids. Run by the driver before any state is built, so
    /// `FrequencyExchange::from_parts` cannot fail afterwards.
    pub fn check_freq_entries(&self, total_neurons: u64) -> Result<(), String> {
        for &(id, _) in &self.freq_entries {
            if id >= total_neurons {
                return Err(format!(
                    "frequency entry id {id} out of range (total neurons {total_neurons})"
                ));
            }
        }
        crate::spikes::PartnerFreqs::check_ascending(&self.freq_entries)
    }

    /// Everything before the frequency state, shared by both layouts.
    fn encode_prefix(&self) -> Vec<u8> {
        let n = self.len();
        let mut out = Vec::with_capacity(64 + n * 64);
        put_u64(&mut out, self.first_id);
        put_u32(&mut out, n as u32);
        for p in &self.positions {
            put_f64(&mut out, p.x);
            put_f64(&mut out, p.y);
            put_f64(&mut out, p.z);
        }
        for &e in &self.is_excitatory {
            put_u8(&mut out, u8::from(e));
        }
        for arr in [
            &self.v,
            &self.u,
            &self.ca,
            &self.z_ax,
            &self.z_den_exc,
            &self.z_den_inh,
            &self.i_syn,
            &self.noise,
        ] {
            for &x in arr.iter() {
                put_f32(&mut out, x);
            }
        }
        for &f in &self.fired {
            put_u8(&mut out, u8::from(f));
        }
        for &s in &self.epoch_spikes {
            put_u32(&mut out, s);
        }
        for edges in &self.out_edges {
            put_u32(&mut out, edges.len() as u32);
            for &tgt in edges {
                put_u64(&mut out, tgt);
            }
        }
        for edges in &self.in_edges {
            put_u32(&mut out, edges.len() as u32);
            for &(src, exc) in edges {
                put_u64(&mut out, src);
                put_u8(&mut out, u8::from(exc));
            }
        }
        for arr in [&self.connected_ax, &self.connected_den_exc, &self.connected_den_inh] {
            for &c in arr.iter() {
                put_u32(&mut out, c);
            }
        }
        put_rng(&mut out, &self.rng_model);
        put_rng(&mut out, &self.rng_conn);
        put_rng(&mut out, &self.rng_spikes);
        out
    }

    /// Everything after the frequency state, shared by both layouts.
    fn encode_suffix(&self, out: &mut Vec<u8>) {
        for c in [
            self.baseline_comm.bytes_sent,
            self.baseline_comm.bytes_recv,
            self.baseline_comm.bytes_rma,
            self.baseline_comm.msgs_sent,
            self.baseline_comm.collectives,
            self.baseline_comm.rma_gets,
        ] {
            put_u64(out, c);
        }
        put_u64(out, self.spike_lookups);
        put_u64(out, self.deletion.axonal_retractions);
        put_u64(out, self.deletion.dendritic_retractions);
        put_u64(out, self.deletion.notifications_sent);
        put_u64(out, self.formation.searches);
        put_u64(out, self.formation.failed_searches);
        put_u64(out, self.formation.proposals);
        put_u64(out, self.formation.formed);
        put_u64(out, self.formation.declined);
        put_u64(out, self.formation.compute_nanos);
        put_u64(out, self.formation.exchange_nanos);
        put_u32(out, self.calcium_trace.len() as u32);
        for (step, cas) in &self.calcium_trace {
            put_u64(out, *step);
            for &ca in cas {
                put_f32(out, ca);
            }
        }
    }

    /// Encode in the current (v2) layout: the frequency state is the
    /// sparse entry list, `u32 count + count × (u64 id, f32 freq)`.
    pub fn encode(&self) -> Vec<u8> {
        self.encode_with_freqs(self.freq_entries.iter().copied())
    }

    /// `encode`, with the frequency entries streamed from `freqs`
    /// instead of `self.freq_entries`. This is the checkpoint writer
    /// path: `RankState::capture` runs inside the step loop and feeds
    /// the `FrequencyExchange`'s borrowing iterator here, so no
    /// per-capture entry `Vec` is allocated. The entries must be
    /// strictly ascending by id (the decoder re-validates).
    pub fn encode_with_freqs(
        &self,
        freqs: impl ExactSizeIterator<Item = (u64, f32)>,
    ) -> Vec<u8> {
        let mut out = self.encode_prefix();
        put_u32(&mut out, freqs.len() as u32);
        for (id, f) in freqs {
            put_u64(&mut out, id);
            put_f32(&mut out, f);
        }
        self.encode_suffix(&mut out);
        out
    }

    /// Encode in the **v1** layout: the frequency state is a dense
    /// `total_neurons × f32` table with the sparse entries scattered
    /// into it. Kept so the v1-compatibility tests can manufacture
    /// old-format files; pair it with a `SnapshotHeader` whose
    /// `version` is 1.
    pub fn encode_v1(&self, total_neurons: usize) -> Vec<u8> {
        let mut out = self.encode_prefix();
        put_u32(&mut out, total_neurons as u32);
        let mut dense = vec![0.0f32; total_neurons];
        for &(id, f) in &self.freq_entries {
            dense[id as usize] = f;
        }
        for &f in &dense {
            put_f32(&mut out, f);
        }
        self.encode_suffix(&mut out);
        out
    }

    /// Decode one rank section written by format `version`. `expect_n`
    /// is the per-rank neuron count from the snapshot header (every
    /// array length must match it); `expect_total` the whole
    /// simulation's neuron count (ranks × per-rank), which a v1
    /// section's dense frequency table must be sized to exactly.
    ///
    /// All `Vec` capacities are clamped to what the remaining bytes
    /// could possibly hold: length prefixes are untrusted input, and a
    /// corrupt count must produce the per-element truncation error, not
    /// a multi-gigabyte up-front allocation.
    pub fn decode(
        buf: &[u8],
        expect_n: usize,
        expect_total: usize,
        version: u32,
    ) -> Result<RankSection, String> {
        fn cap(count: usize, elem_bytes: usize, remaining: usize) -> usize {
            count.min(remaining / elem_bytes.max(1))
        }
        let mut c = Cursor::new(buf, "snapshot rank section");
        let first_id = c.u64("first neuron id")?;
        let n = c.u32("neuron count")? as usize;
        if n != expect_n {
            return Err(format!(
                "rank section holds {n} neurons but the header says {expect_n} per rank"
            ));
        }
        let mut positions = Vec::with_capacity(cap(n, 24, c.remaining()));
        for _ in 0..n {
            let x = c.f64("positions")?;
            let y = c.f64("positions")?;
            let z = c.f64("positions")?;
            positions.push(Vec3::new(x, y, z));
        }
        let mut is_excitatory = Vec::with_capacity(cap(n, 1, c.remaining()));
        for _ in 0..n {
            is_excitatory.push(c.u8("is_excitatory")? != 0);
        }
        let mut f32_array = |what: &'static str| -> Result<Vec<f32>, String> {
            let mut xs = Vec::with_capacity(cap(n, 4, c.remaining()));
            for _ in 0..n {
                xs.push(c.f32(what)?);
            }
            Ok(xs)
        };
        let v = f32_array("v")?;
        let u = f32_array("u")?;
        let ca = f32_array("ca")?;
        let z_ax = f32_array("z_ax")?;
        let z_den_exc = f32_array("z_den_exc")?;
        let z_den_inh = f32_array("z_den_inh")?;
        let i_syn = f32_array("i_syn")?;
        let noise = f32_array("noise")?;
        let mut fired = Vec::with_capacity(cap(n, 1, c.remaining()));
        for _ in 0..n {
            fired.push(c.u8("fired")? != 0);
        }
        let mut epoch_spikes = Vec::with_capacity(cap(n, 4, c.remaining()));
        for _ in 0..n {
            epoch_spikes.push(c.u32("epoch_spikes")?);
        }
        let mut out_edges = Vec::with_capacity(cap(n, 4, c.remaining()));
        for _ in 0..n {
            let len = c.u32("out-edge count")? as usize;
            let mut edges = Vec::with_capacity(cap(len, 8, c.remaining()));
            for _ in 0..len {
                edges.push(c.u64("out edge")?);
            }
            out_edges.push(edges);
        }
        let mut in_edges = Vec::with_capacity(cap(n, 4, c.remaining()));
        for _ in 0..n {
            let len = c.u32("in-edge count")? as usize;
            let mut edges = Vec::with_capacity(cap(len, 9, c.remaining()));
            for _ in 0..len {
                let src = c.u64("in edge")?;
                let exc = c.u8("in edge kind")? != 0;
                edges.push((src, exc));
            }
            in_edges.push(edges);
        }
        let mut u32_array = |what: &'static str| -> Result<Vec<u32>, String> {
            let mut xs = Vec::with_capacity(cap(n, 4, c.remaining()));
            for _ in 0..n {
                xs.push(c.u32(what)?);
            }
            Ok(xs)
        };
        let connected_ax = u32_array("connected_ax")?;
        let connected_den_exc = u32_array("connected_den_exc")?;
        let connected_den_inh = u32_array("connected_den_inh")?;
        let rng_model = read_rng(&mut c, "model rng")?;
        let rng_conn = read_rng(&mut c, "connectivity rng")?;
        let rng_spikes = read_rng(&mut c, "spike rng")?;
        let freq_entries = if version >= 2 {
            let count = c.u32("frequency entry count")? as usize;
            let mut entries = Vec::with_capacity(cap(count, 12, c.remaining()));
            for _ in 0..count {
                let id = c.u64("frequency entry id")?;
                let f = c.f32("frequency entry")?;
                entries.push((id, f));
            }
            crate::spikes::PartnerFreqs::check_ascending(&entries)?;
            entries
        } else {
            // v1: dense table indexed by global neuron id. Nonzero
            // entries become sparse records; zeros are dropped (a zero
            // frequency and a missing entry behave identically — the
            // reconstruction PRNG is never drawn for either).
            let len = c.u32("frequency table length")? as usize;
            if len != expect_total {
                return Err(format!(
                    "frequency table size mismatch: v1 snapshot has {len}, simulation \
                     expects {expect_total}"
                ));
            }
            let mut entries = Vec::new();
            for i in 0..len {
                let f = c.f32("frequency table")?;
                if f != 0.0 {
                    entries.push((i as u64, f));
                }
            }
            entries
        };
        let baseline_comm = CounterSnapshot {
            bytes_sent: c.u64("comm counters")?,
            bytes_recv: c.u64("comm counters")?,
            bytes_rma: c.u64("comm counters")?,
            msgs_sent: c.u64("comm counters")?,
            collectives: c.u64("comm counters")?,
            rma_gets: c.u64("comm counters")?,
        };
        let spike_lookups = c.u64("spike lookups")?;
        let deletion = DeletionStats {
            axonal_retractions: c.u64("deletion stats")?,
            dendritic_retractions: c.u64("deletion stats")?,
            notifications_sent: c.u64("deletion stats")?,
        };
        let formation = FormationStats {
            searches: c.u64("formation stats")?,
            failed_searches: c.u64("formation stats")?,
            proposals: c.u64("formation stats")?,
            formed: c.u64("formation stats")?,
            declined: c.u64("formation stats")?,
            compute_nanos: c.u64("formation stats")?,
            exchange_nanos: c.u64("formation stats")?,
        };
        let trace_len = c.u32("calcium trace length")? as usize;
        let mut calcium_trace = Vec::with_capacity(cap(trace_len, 8 + 4 * n, c.remaining()));
        for _ in 0..trace_len {
            let step = c.u64("calcium trace step")?;
            let mut cas = Vec::with_capacity(cap(n, 4, c.remaining()));
            for _ in 0..n {
                cas.push(c.f32("calcium trace")?);
            }
            calcium_trace.push((step, cas));
        }
        c.finish("rank section")?;
        Ok(RankSection {
            first_id,
            positions,
            is_excitatory,
            v,
            u,
            ca,
            z_ax,
            z_den_exc,
            z_den_inh,
            i_syn,
            noise,
            fired,
            epoch_spikes,
            out_edges,
            in_edges,
            connected_ax,
            connected_den_exc,
            connected_den_inh,
            rng_model,
            rng_conn,
            rng_spikes,
            freq_entries,
            baseline_comm,
            spike_lookups,
            deletion,
            formation,
            calcium_trace,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn sample_section(n: usize, seed: u64) -> RankSection {
        let mut rng = Rng::new(seed);
        let mut model = Rng::new(seed + 1);
        model.normal(); // leave a spare normal cached
        RankSection {
            first_id: 3 * n as u64,
            positions: (0..n)
                .map(|_| Vec3::new(rng.uniform(0.0, 9.0), rng.uniform(0.0, 9.0), rng.next_f64()))
                .collect(),
            is_excitatory: (0..n).map(|i| i % 3 != 0).collect(),
            v: (0..n).map(|_| rng.next_f32()).collect(),
            u: (0..n).map(|_| rng.next_f32()).collect(),
            ca: (0..n).map(|_| rng.next_f32()).collect(),
            z_ax: (0..n).map(|_| rng.next_f32()).collect(),
            z_den_exc: (0..n).map(|_| rng.next_f32()).collect(),
            z_den_inh: (0..n).map(|_| rng.next_f32()).collect(),
            i_syn: (0..n).map(|_| rng.next_f32()).collect(),
            noise: (0..n).map(|_| rng.next_f32()).collect(),
            fired: (0..n).map(|i| i % 2 == 0).collect(),
            epoch_spikes: (0..n).map(|i| i as u32).collect(),
            out_edges: (0..n).map(|i| (0..i % 4).map(|k| k as u64).collect()).collect(),
            in_edges: (0..n)
                .map(|i| (0..i % 3).map(|k| (10 + k as u64, k % 2 == 0)).collect())
                .collect(),
            // Counters derived from the edge lists above so the
            // consistency checks hold: out_edges[i] has i % 4 entries;
            // in_edges[i] has i % 3 entries alternating exc/inh
            // starting with exc (k % 2 == 0).
            connected_ax: (0..n).map(|i| (i % 4) as u32).collect(),
            connected_den_exc: (0..n).map(|i| ((i % 3) as u32 + 1) / 2).collect(),
            connected_den_inh: (0..n).map(|i| (i % 3) as u32 / 2).collect(),
            rng_model: model.state(),
            rng_conn: Rng::new(seed + 2).state(),
            rng_spikes: Rng::new(seed + 3).state(),
            // Sparse entries, strictly ascending ids.
            freq_entries: (0..n)
                .map(|i| ((n + 2 * i) as u64, 0.01 + rng.next_f32() * 0.9))
                .collect(),
            baseline_comm: CounterSnapshot {
                bytes_sent: 123,
                bytes_recv: 456,
                bytes_rma: 7,
                msgs_sent: 8,
                collectives: 9,
                rma_gets: 1,
            },
            spike_lookups: 42,
            deletion: DeletionStats {
                axonal_retractions: 1,
                dendritic_retractions: 2,
                notifications_sent: 3,
            },
            formation: FormationStats {
                searches: 4,
                failed_searches: 5,
                proposals: 6,
                formed: 7,
                declined: 8,
                compute_nanos: 9,
                exchange_nanos: 10,
            },
            calcium_trace: vec![(0, vec![0.5; n]), (100, vec![0.25; n])],
        }
    }

    #[test]
    fn rank_section_roundtrips_bit_exactly() {
        let sec = sample_section(13, 99);
        let buf = sec.encode();
        let back = RankSection::decode(&buf, 13, 64, FORMAT_VERSION).unwrap();
        assert_eq!(back.first_id, sec.first_id);
        assert_eq!(back.positions, sec.positions);
        assert_eq!(back.is_excitatory, sec.is_excitatory);
        for (a, b) in [
            (&back.v, &sec.v),
            (&back.u, &sec.u),
            (&back.ca, &sec.ca),
            (&back.z_ax, &sec.z_ax),
            (&back.z_den_exc, &sec.z_den_exc),
            (&back.z_den_inh, &sec.z_den_inh),
            (&back.i_syn, &sec.i_syn),
            (&back.noise, &sec.noise),
        ] {
            let a_bits: Vec<u32> = a.iter().map(|x| x.to_bits()).collect();
            let b_bits: Vec<u32> = b.iter().map(|x| x.to_bits()).collect();
            assert_eq!(a_bits, b_bits);
        }
        assert_eq!(back.fired, sec.fired);
        assert_eq!(back.epoch_spikes, sec.epoch_spikes);
        assert_eq!(back.out_edges, sec.out_edges);
        assert_eq!(back.in_edges, sec.in_edges);
        assert_eq!(back.connected_ax, sec.connected_ax);
        assert_eq!(back.connected_den_exc, sec.connected_den_exc);
        assert_eq!(back.connected_den_inh, sec.connected_den_inh);
        assert_eq!(back.rng_model, sec.rng_model);
        assert_eq!(back.rng_conn, sec.rng_conn);
        assert_eq!(back.rng_spikes, sec.rng_spikes);
        assert_eq!(back.freq_entries, sec.freq_entries);
        assert_eq!(back.baseline_comm, sec.baseline_comm);
        assert_eq!(back.spike_lookups, sec.spike_lookups);
        assert_eq!(back.deletion, sec.deletion);
        assert_eq!(back.formation, sec.formation);
        assert_eq!(back.calcium_trace, sec.calcium_trace);
    }

    #[test]
    fn streamed_freq_encoding_is_byte_identical_to_owned() {
        // The writer path (borrowing iterator) and the owned-Vec path
        // must produce the same bytes — the capture refactor changes
        // allocation, never the format.
        let sec = sample_section(9, 21);
        let streamed = {
            let mut empty = sec.clone();
            let entries = std::mem::take(&mut empty.freq_entries);
            empty.encode_with_freqs(entries.iter().copied())
        };
        assert_eq!(streamed, sec.encode());
    }

    #[test]
    fn v1_dense_layout_decodes_to_sparse_entries() {
        let mut sec = sample_section(6, 11);
        // A zero entry proves dense zeros are dropped on conversion.
        sec.freq_entries = vec![(3, 0.5), (7, 0.0), (20, 0.25)];
        let buf = sec.encode_v1(24);
        let back = RankSection::decode(&buf, 6, 24, 1).unwrap();
        // A dense table whose length disagrees with the simulation's
        // total neuron count is rejected, as it was pre-v2.
        let err = RankSection::decode(&buf, 6, 25, 1).unwrap_err();
        assert!(err.contains("size mismatch"), "{err}");
        assert_eq!(back.freq_entries, vec![(3, 0.5), (20, 0.25)]);
        // Everything around the frequency state decodes unchanged.
        assert_eq!(back.out_edges, sec.out_edges);
        assert_eq!(back.in_edges, sec.in_edges);
        assert_eq!(back.rng_spikes, sec.rng_spikes);
        assert_eq!(back.calcium_trace, sec.calcium_trace);
        // The v2 encoding of the SAME state is smaller than the dense
        // v1 one whenever partners < total neurons (the §Perf opt 7
        // snapshot win).
        assert!(sec.encode().len() < buf.len());
    }

    #[test]
    fn unsorted_freq_entries_are_rejected() {
        let mut sec = sample_section(4, 5);
        sec.freq_entries = vec![(9, 0.1), (3, 0.2)];
        let err = RankSection::decode(&sec.encode(), 4, 64, FORMAT_VERSION).unwrap_err();
        assert!(err.contains("ascending"), "{err}");
        sec.freq_entries = vec![(9, 0.1), (9, 0.2)];
        let err = RankSection::decode(&sec.encode(), 4, 64, FORMAT_VERSION).unwrap_err();
        assert!(err.contains("ascending"), "{err}");
    }

    #[test]
    fn check_freq_entries_validates_order_and_bounds() {
        let mut sec = sample_section(4, 6);
        sec.freq_entries = vec![(1, 0.5), (2, 0.25)];
        sec.check_freq_entries(1_000).unwrap();
        let err = sec.check_freq_entries(2).unwrap_err();
        assert!(err.contains("out of range"), "{err}");
        sec.freq_entries = vec![(5, 0.5), (5, 0.25)];
        let err = sec.check_freq_entries(1_000).unwrap_err();
        assert!(err.contains("ascending"), "{err}");
    }

    #[test]
    fn synapse_consistency_checks_counters_and_id_bounds() {
        let sec = sample_section(5, 7);
        // sample ids are all small: valid against a generous total.
        sec.check_synapse_consistency(1_000).unwrap();

        // Counter mismatch.
        let mut bad = sec.clone();
        bad.connected_ax[1] += 1;
        assert!(bad.check_synapse_consistency(1_000).unwrap_err().contains("connected_ax"));

        // Out-of-range target id with counters left consistent.
        let mut bad = sec.clone();
        if bad.out_edges[1].is_empty() {
            bad.out_edges[1].push(0);
            bad.connected_ax[1] += 1;
        }
        bad.out_edges[1][0] = 999_999;
        let err = bad.check_synapse_consistency(1_000).unwrap_err();
        assert!(err.contains("out of range"), "{err}");

        // Out-of-range source id on the dendritic side.
        let mut bad = sec.clone();
        if bad.in_edges[2].is_empty() {
            bad.in_edges[2].push((0, true));
            bad.connected_den_exc[2] += 1;
        }
        bad.in_edges[2][0].0 = 999_999;
        let err = bad.check_synapse_consistency(1_000).unwrap_err();
        assert!(err.contains("out of range"), "{err}");
    }

    #[test]
    fn corrupt_length_prefix_errors_without_huge_allocation() {
        let n = 4usize;
        let sec = sample_section(n, 3);
        let mut buf = sec.encode();
        // Offset of out_edges[0]'s length prefix: first_id(8) + n(4) +
        // positions(24n) + is_excitatory(n) + 8 f32 arrays(32n) +
        // fired(n) + epoch_spikes(4n).
        let off = 12 + 62 * n;
        assert_eq!(
            u32::from_le_bytes(buf[off..off + 4].try_into().unwrap()),
            sec.out_edges[0].len() as u32,
            "layout offset drifted; update this test"
        );
        buf[off..off + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        // Must come back as a truncation error, not an abort on a
        // ~32 GB up-front allocation.
        let err = RankSection::decode(&buf, n, 64, FORMAT_VERSION).unwrap_err();
        assert!(err.contains("truncated"), "{err}");
    }

    #[test]
    fn truncated_section_is_a_descriptive_error() {
        let sec = sample_section(5, 7);
        let buf = sec.encode();
        let err =
            RankSection::decode(&buf[..buf.len() / 2], 5, 64, FORMAT_VERSION).unwrap_err();
        assert!(err.contains("truncated"), "{err}");
    }

    #[test]
    fn neuron_count_mismatch_rejected() {
        let sec = sample_section(5, 7);
        let err = RankSection::decode(&sec.encode(), 6, 64, FORMAT_VERSION).unwrap_err();
        assert!(err.contains("6 per rank"), "{err}");
    }

    #[test]
    fn header_roundtrip_and_magic_check() {
        let cfg = SimConfig::default();
        let hdr = SnapshotHeader::for_config(&cfg, 500);
        let mut buf = Vec::new();
        hdr.encode(&mut buf);
        let mut c = Cursor::new(&buf, "snapshot");
        let back = SnapshotHeader::decode(&mut c).unwrap();
        assert_eq!(back.version, FORMAT_VERSION);
        assert_eq!(back.fingerprint, config_fingerprint(&cfg));
        assert_eq!(back.next_step, 500);
        assert_eq!(back.ranks, cfg.ranks as u32);
        assert_eq!(back.neurons_per_rank, cfg.neurons_per_rank as u32);
        assert_eq!(back.config_ini, cfg.to_ini());
        assert!(back.partition.is_none(), "default layout stores the uniform tag");

        let mut bad = buf.clone();
        bad[0] = b'X';
        let err = SnapshotHeader::decode(&mut Cursor::new(&bad, "snapshot")).unwrap_err();
        assert!(err.contains("bad magic"), "{err}");
    }

    #[test]
    fn v1_headers_are_still_accepted() {
        let cfg = SimConfig::default();
        let mut hdr = SnapshotHeader::for_config(&cfg, 10);
        hdr.version = 1;
        let mut buf = Vec::new();
        hdr.encode(&mut buf);
        let back = SnapshotHeader::decode(&mut Cursor::new(&buf, "snapshot")).unwrap();
        assert_eq!(back.version, 1);
    }

    #[test]
    fn wrong_version_rejected_descriptively() {
        let cfg = SimConfig::default();
        let hdr = SnapshotHeader::for_config(&cfg, 0);
        let mut buf = Vec::new();
        hdr.encode(&mut buf);
        // Version field sits right after the 8-byte magic.
        buf[8] = 99;
        let err = SnapshotHeader::decode(&mut Cursor::new(&buf, "snapshot")).unwrap_err();
        assert!(err.contains("version 99"), "{err}");
        assert!(err.contains("1..=5"), "{err}");
        // Version 0 (below the supported floor) is rejected too.
        buf[8] = 0;
        let err = SnapshotHeader::decode(&mut Cursor::new(&buf, "snapshot")).unwrap_err();
        assert!(err.contains("version 0"), "{err}");
    }

    #[test]
    fn migrated_partition_rides_in_the_header() {
        use crate::balance::Partition;
        let cfg = SimConfig { ranks: 2, neurons_per_rank: 32, ..SimConfig::default() };
        // A migrated (non-uniform) partition is stored explicitly...
        let skew = Partition { cell_counts: vec![8; 8], cell_start: vec![0, 5, 8] };
        let hdr = SnapshotHeader::for_run(&cfg, 100, &skew);
        let mut buf = Vec::new();
        hdr.encode(&mut buf);
        let back = SnapshotHeader::decode(&mut Cursor::new(&buf, "snapshot")).unwrap();
        assert_eq!(back.partition.as_ref(), Some(&skew));
        // ...while the exact uniform default collapses to the tag byte.
        let uniform = Partition::uniform(2, 32);
        let hdr = SnapshotHeader::for_run(&cfg, 100, &uniform);
        assert!(hdr.partition.is_none());
        // A corrupt partition is rejected at decode time.
        let mut bad = SnapshotHeader::for_run(&cfg, 100, &skew);
        bad.partition = Some(Partition {
            cell_counts: vec![8; 8],
            cell_start: vec![0, 8, 8], // rank 1 left with no cells
        });
        let mut buf = Vec::new();
        bad.encode(&mut buf);
        let err = SnapshotHeader::decode(&mut Cursor::new(&buf, "snapshot")).unwrap_err();
        assert!(err.contains("ownership partition"), "{err}");
    }

    #[test]
    fn peek_version_and_checksum_basics() {
        let cfg = SimConfig::default();
        let hdr = SnapshotHeader::for_config(&cfg, 0);
        let mut buf = Vec::new();
        hdr.encode(&mut buf);
        assert_eq!(peek_version(&buf), Some(FORMAT_VERSION));
        assert_eq!(peek_version(&buf[..11]), None, "too short for the version field");
        let mut bad = buf.clone();
        bad[0] = b'X';
        assert_eq!(peek_version(&bad), None, "bad magic");
        // The checksum is sensitive to every byte (FNV-1a absorbs each
        // input byte into the running hash).
        let c0 = content_checksum(&buf);
        buf[13] ^= 0x40;
        assert_ne!(c0, content_checksum(&buf));
    }

    #[test]
    fn fingerprint_tracks_dynamics_fields_only() {
        let base = SimConfig::default();
        let f0 = config_fingerprint(&base);

        let mut steps = base.clone();
        steps.steps += 1000;
        assert_eq!(f0, config_fingerprint(&steps), "steps must not affect fingerprint");

        let mut instr = base.clone();
        instr.record_calcium_every = 7;
        instr.checkpoint_every = 100;
        instr.checkpoint_dir = "x".into();
        instr.trace_every = 50;
        instr.trace_capacity = 8;
        instr.trace_out = "trace.json".into();
        assert_eq!(f0, config_fingerprint(&instr), "instrumentation must not affect it");

        // The neuron-kernel backend is execution strategy, not dynamics
        // (all kernels are bit-identical), so a snapshot taken under one
        // kernel must resume under another without --branch.
        let mut kern = base.clone();
        kern.kernel = crate::config::KernelKind::Blocked;
        assert_eq!(f0, config_fingerprint(&kern), "kernel must not affect fingerprint");
        kern.kernel = crate::config::KernelKind::Xla;
        assert_eq!(f0, config_fingerprint(&kern), "kernel must not affect fingerprint");

        let mut seed = base.clone();
        seed.seed += 1;
        assert_ne!(f0, config_fingerprint(&seed));

        let mut sigma = base.clone();
        sigma.sigma += 1.0;
        assert_ne!(f0, config_fingerprint(&sigma));

        let mut alg = base.clone();
        alg.connectivity_alg = ConnectivityAlg::OldRma;
        assert_ne!(f0, config_fingerprint(&alg));

        let mut params = base.clone();
        params.neuron.a += 0.001;
        assert_ne!(f0, config_fingerprint(&params), "neuron params are fingerprinted");

        // Balancing knobs are dynamics: they change trajectories.
        let mut bal = base.clone();
        bal.balance_every = base.plasticity_interval;
        assert_ne!(f0, config_fingerprint(&bal));
        let mut thr = base.clone();
        thr.balance_threshold += 0.5;
        assert_ne!(f0, config_fingerprint(&thr));
        let mut skew = base.clone();
        skew.balance_init_cells = "6,2".to_string();
        assert_ne!(f0, config_fingerprint(&skew));
    }

    #[test]
    fn pre_v4_fingerprints_ignore_balance_knobs() {
        // A pre-v4 build hashed no balance bytes; recomputing its hash
        // for an old snapshot must be insensitive to the new knobs, so
        // those files keep resuming.
        let base = SimConfig::default();
        let mut bal = base.clone();
        bal.balance_every = base.plasticity_interval;
        bal.balance_threshold = 2.0;
        assert_eq!(
            config_fingerprint_for_version(&base, 1),
            config_fingerprint_for_version(&bal, 3)
        );
        assert_ne!(
            config_fingerprint_for_version(&base, 4),
            config_fingerprint_for_version(&bal, 4)
        );
        assert_eq!(config_fingerprint(&base), config_fingerprint_for_version(&base, 4));
    }

    #[test]
    fn fingerprint_hashes_the_canonical_partition_not_the_string() {
        // An explicit uniform split is the SAME partition as the empty
        // default — snapshots from one resume under the other.
        let base = SimConfig { ranks: 2, neurons_per_rank: 32, ..SimConfig::default() };
        let mut explicit = base.clone();
        explicit.balance_init_cells = "4,4".to_string();
        assert_eq!(config_fingerprint(&base), config_fingerprint(&explicit));
    }
}
