//! Snapshot reading and validation.
//!
//! `Snapshot::read_file` parses and structurally validates a snapshot;
//! rank sections stay raw until `section(rank)` decodes them. (The
//! driver deliberately decodes and validates every section up front on
//! one thread before spawning ranks — an error inside a rank thread
//! would strand its siblings at a collective barrier; the transient
//! extra memory is the price of failing with a message instead of a
//! deadlock.) Loading is defensive throughout: bad magic, unknown
//! versions, truncation, oversized length prefixes, section/rank
//! mismatches and config-fingerprint drift all produce descriptive
//! errors instead of garbage state.

use std::path::{Path, PathBuf};

use super::format::{
    config_fingerprint_for_version, content_checksum, peek_version, RankSection, SnapshotHeader,
    FORMAT_VERSION, SNAPSHOT_EXT,
};
use crate::balance::Partition;
use crate::config::SimConfig;
use crate::util::wire::Cursor;

/// A parsed snapshot: header plus raw (undecoded) per-rank sections.
pub struct Snapshot {
    header: SnapshotHeader,
    sections: Vec<Vec<u8>>,
}

impl Snapshot {
    /// Parse a snapshot from raw bytes.
    pub fn from_bytes(buf: &[u8]) -> Result<Snapshot, String> {
        // v5+ files end in a whole-file checksum; verify it before
        // parsing anything so every kind of damage — header, section
        // bytes, truncation anywhere — surfaces as this one checked
        // error. Unknown FUTURE versions skip the check and fall through
        // to the header decode's descriptive "unsupported version".
        let buf = match peek_version(buf) {
            Some(v) if (5..=FORMAT_VERSION).contains(&v) => {
                let Some(body_len) = buf.len().checked_sub(8) else {
                    return Err("snapshot is corrupt or truncated: no room for the \
                                content-checksum trailer"
                        .to_string());
                };
                let stored = u64::from_le_bytes(buf[body_len..].try_into().unwrap());
                if content_checksum(&buf[..body_len]) != stored {
                    return Err(format!(
                        "snapshot is corrupt or truncated: content checksum mismatch \
                         over {body_len} bytes"
                    ));
                }
                &buf[..body_len]
            }
            _ => buf,
        };
        let mut c = Cursor::new(buf, "snapshot");
        let header = SnapshotHeader::decode(&mut c)?;
        let ranks = header.ranks as usize;
        // The ranks field is untrusted input: clamp the capacity to what
        // the remaining bytes could hold (each section needs >= 12 B of
        // framing) so a corrupt header errors on decode instead of
        // triggering a huge up-front allocation.
        let mut sections = Vec::with_capacity(ranks.min(c.remaining() / 12));
        for expect_rank in 0..ranks {
            let rank = c.u32("section rank id")? as usize;
            if rank != expect_rank {
                return Err(format!(
                    "snapshot sections out of order: found rank {rank} where rank \
                     {expect_rank} was expected"
                ));
            }
            let len = c.u64("section length")? as usize;
            sections.push(c.bytes(len, "rank section")?.to_vec());
        }
        c.finish("snapshot")?;
        Ok(Snapshot { header, sections })
    }

    /// Read and parse a snapshot file.
    ///
    /// # Examples
    ///
    /// ```no_run
    /// use ilmi::snapshot::Snapshot;
    ///
    /// let snap = Snapshot::read_file("ckpts/step_0000000500.ilmisnap").unwrap();
    /// println!("{} ranks, resumes at step {}", snap.ranks(), snap.next_step());
    /// // Snapshots are self-describing: the embedded config is
    /// // cross-checked against the stored fingerprint on extraction.
    /// let cfg = snap.config().unwrap();
    /// assert_eq!(cfg.ranks, snap.ranks());
    /// ```
    pub fn read_file(path: impl AsRef<Path>) -> Result<Snapshot, String> {
        let path = path.as_ref();
        let buf = std::fs::read(path)
            .map_err(|e| format!("reading snapshot {}: {e}", path.display()))?;
        Self::from_bytes(&buf)
            .map_err(|e| format!("{}: {e}", path.display()))
    }

    /// First step index a resumed run executes (= steps completed when
    /// the snapshot was taken).
    pub fn next_step(&self) -> usize {
        self.header.next_step as usize
    }

    pub fn ranks(&self) -> usize {
        self.header.ranks as usize
    }

    pub fn neurons_per_rank(&self) -> usize {
        self.header.neurons_per_rank as usize
    }

    pub fn fingerprint(&self) -> u64 {
        self.header.fingerprint
    }

    /// Format version the file was written with (v1 sections decode
    /// through the dense-to-sparse frequency conversion).
    pub fn version(&self) -> u32 {
        self.header.version
    }

    /// The embedded config INI text (as written by `SimConfig::to_ini`).
    pub fn config_ini(&self) -> &str {
        &self.header.config_ini
    }

    /// The explicit ownership partition stored in a v4 header, if any
    /// (`None` = uniform stride, which is also what every pre-v4 file
    /// maps to).
    pub fn partition(&self) -> Option<&Partition> {
        self.header.partition.as_ref()
    }

    /// The partition a resume/branch must rebuild rank state with:
    /// the stored one, or the uniform default when the snapshot was
    /// taken under the historical stride layout.
    pub fn partition_for_resume(&self) -> Partition {
        match &self.header.partition {
            Some(p) => p.clone(),
            None => Partition::uniform(self.ranks(), self.neurons_per_rank() as u64),
        }
    }

    /// Neurons rank `rank`'s section must hold: the partition's share
    /// (per-rank counts differ after a migration), or the uniform
    /// `neurons_per_rank`.
    fn expected_n(&self, rank: usize) -> usize {
        match &self.header.partition {
            Some(p) => p.ownership().count(rank) as usize,
            None => self.neurons_per_rank(),
        }
    }

    /// Reconstruct the originating config from the embedded INI and
    /// cross-check it against the stored fingerprint (catches neuron
    /// parameters that have no INI key and therefore cannot round-trip).
    pub fn config(&self) -> Result<SimConfig, String> {
        let cfg = SimConfig::from_ini(&self.header.config_ini)
            .map_err(|e| format!("snapshot's embedded config does not parse: {e}"))?;
        if config_fingerprint_for_version(&cfg, self.header.version) != self.header.fingerprint {
            return Err(
                "snapshot's embedded config does not reproduce its fingerprint — the \
                 original run used parameters that are not INI-expressible; resume with \
                 an explicit --config/--set matching the original run"
                    .to_string(),
            );
        }
        Ok(cfg)
    }

    /// Structural compatibility: the state arrays must fit `cfg`.
    fn validate_structure(&self, cfg: &SimConfig) -> Result<(), String> {
        if self.ranks() != cfg.ranks {
            return Err(format!(
                "snapshot was taken with {} ranks but the config asks for {}",
                self.ranks(),
                cfg.ranks
            ));
        }
        if self.neurons_per_rank() != cfg.neurons_per_rank {
            return Err(format!(
                "snapshot was taken with {} neurons per rank but the config asks for {}",
                self.neurons_per_rank(),
                cfg.neurons_per_rank
            ));
        }
        if cfg.steps <= self.next_step() {
            return Err(format!(
                "nothing to resume: snapshot already has {} steps completed but \
                 schedule.steps is {}; raise --steps above {}",
                self.next_step(),
                cfg.steps,
                self.next_step()
            ));
        }
        Ok(())
    }

    /// Full validation for bit-exact resume: structure plus an exact
    /// config-fingerprint match. The fingerprint is recomputed the way
    /// the writing build computed it (pre-v4 files hashed no balance
    /// bytes), so older snapshots keep resuming under the same config.
    pub fn validate_for(&self, cfg: &SimConfig) -> Result<(), String> {
        self.validate_structure(cfg)?;
        let have = config_fingerprint_for_version(cfg, self.header.version);
        if have != self.header.fingerprint {
            return Err(format!(
                "config fingerprint mismatch: snapshot {:016x} vs current config {:016x} — \
                 a dynamics-relevant setting (seed, algorithms, model parameters, topology \
                 or intervals) differs from the run that wrote this snapshot. Resume with \
                 the original config, or pass --branch to deliberately fork a new scenario \
                 from this state",
                self.header.fingerprint, have
            ));
        }
        Ok(())
    }

    /// Relaxed validation for scenario *branching*: the state must fit
    /// structurally, but dynamics parameters may differ (that is the
    /// point of a branch — same brain, different protocol).
    pub fn validate_for_branch(&self, cfg: &SimConfig) -> Result<(), String> {
        self.validate_structure(cfg)
    }

    /// Decode rank `rank`'s section.
    pub fn section(&self, rank: usize) -> Result<RankSection, String> {
        let raw = self.sections.get(rank).ok_or_else(|| {
            format!("snapshot has no section for rank {rank} (ranks: {})", self.ranks())
        })?;
        let total = self.ranks() * self.neurons_per_rank();
        RankSection::decode(raw, self.expected_n(rank), total, self.header.version)
            .map_err(|e| format!("rank {rank}: {e}"))
    }
}

/// The newest snapshot file (`step_*.ilmisnap`, highest step) in `dir`.
pub fn latest_snapshot_in(dir: impl AsRef<Path>) -> Result<PathBuf, String> {
    let dir = dir.as_ref();
    let entries = std::fs::read_dir(dir)
        .map_err(|e| format!("reading checkpoint dir {}: {e}", dir.display()))?;
    let mut best: Option<PathBuf> = None;
    for entry in entries {
        let path = entry.map_err(|e| format!("listing {}: {e}", dir.display()))?.path();
        let is_snap = path
            .extension()
            .and_then(|e| e.to_str())
            .map(|e| e == SNAPSHOT_EXT)
            .unwrap_or(false);
        if !is_snap {
            continue;
        }
        // `step_{:010}` zero-padding makes lexicographic == numeric order.
        if best.as_ref().map(|b| path.file_name() > b.file_name()).unwrap_or(true) {
            best = Some(path);
        }
    }
    best.ok_or_else(|| format!("no *.{SNAPSHOT_EXT} files in {}", dir.display()))
}

/// What [`scan_for_recovery`] found: the newest *fully valid* snapshot
/// plus the evidence needed for honest recovery accounting.
pub struct RecoveryScan {
    /// The snapshot recovery will resume from.
    pub snapshot: Snapshot,
    /// Its file path.
    pub path: PathBuf,
    /// The highest step number named by ANY `step_*.ilmisnap` file in
    /// the directory, valid or not. The gap between this and the chosen
    /// snapshot's step is a lower bound on the work a recovery replays
    /// (the fleet provably reached at least this step).
    pub newest_step_seen: u64,
    /// Newer snapshot files that were skipped, with why (corrupt,
    /// truncated, fingerprint mismatch, undecodable section...).
    pub skipped: Vec<(PathBuf, String)>,
}

/// Find the newest snapshot in `dir` that a recovery can actually trust:
/// reads each `step_*.ilmisnap` newest-first and requires a full parse
/// (v5+: whole-file checksum), a fingerprint match against `cfg`, and a
/// successful decode of EVERY rank section before accepting it — a
/// checkpoint that was being written when the fleet died, or one an
/// injected fault corrupted, is skipped and an older ring entry wins.
pub fn scan_for_recovery(dir: impl AsRef<Path>, cfg: &SimConfig) -> Result<RecoveryScan, String> {
    let dir = dir.as_ref();
    let entries = std::fs::read_dir(dir)
        .map_err(|e| format!("reading checkpoint dir {}: {e}", dir.display()))?;
    let mut candidates: Vec<PathBuf> = entries
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| {
            p.extension().and_then(|e| e.to_str()) == Some(SNAPSHOT_EXT)
                && super::writer::step_of_file_name(p).is_some()
        })
        .collect();
    // Zero-padded names: lexicographic descending == newest first.
    candidates.sort();
    candidates.reverse();
    let newest_step_seen = candidates
        .first()
        .and_then(|p| super::writer::step_of_file_name(p))
        .unwrap_or(0);
    let mut skipped = Vec::new();
    for path in candidates {
        let verdict = Snapshot::read_file(&path).and_then(|snap| {
            snap.validate_for(cfg)?;
            for rank in 0..snap.ranks() {
                snap.section(rank)?;
            }
            Ok(snap)
        });
        match verdict {
            Ok(snapshot) => {
                return Ok(RecoveryScan { snapshot, path, newest_step_seen, skipped });
            }
            Err(reason) => skipped.push((path, reason)),
        }
    }
    if skipped.is_empty() {
        return Err(format!("no *.{SNAPSHOT_EXT} files in {}", dir.display()));
    }
    let mut msg = format!(
        "no usable checkpoint in {}: all {} snapshot file(s) failed validation",
        dir.display(),
        skipped.len()
    );
    for (path, reason) in &skipped {
        msg.push_str(&format!("\n  {}: {reason}", path.display()));
    }
    Err(msg)
}

#[cfg(test)]
mod tests {
    use super::super::writer::write_snapshot_sections;
    use super::*;
    use crate::snapshot::format::FORMAT_VERSION;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "ilmi_snap_test_{tag}_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn tiny_cfg() -> SimConfig {
        SimConfig { ranks: 2, neurons_per_rank: 4, steps: 100, ..SimConfig::default() }
    }

    fn tiny_sections(cfg: &SimConfig) -> Vec<RankSection> {
        use crate::util::{Rng, Vec3};
        (0..cfg.ranks)
            .map(|rank| {
                let n = cfg.neurons_per_rank;
                RankSection {
                    first_id: (rank * n) as u64,
                    positions: vec![Vec3::new(1.0, 2.0, 3.0); n],
                    is_excitatory: vec![true; n],
                    v: vec![-65.0; n],
                    u: vec![-13.0; n],
                    ca: vec![0.1; n],
                    z_ax: vec![1.2; n],
                    z_den_exc: vec![1.3; n],
                    z_den_inh: vec![1.4; n],
                    i_syn: vec![0.0; n],
                    noise: vec![0.0; n],
                    fired: vec![false; n],
                    epoch_spikes: vec![0; n],
                    out_edges: vec![Vec::new(); n],
                    in_edges: vec![Vec::new(); n],
                    connected_ax: vec![0; n],
                    connected_den_exc: vec![0; n],
                    connected_den_inh: vec![0; n],
                    rng_model: Rng::new(1).state(),
                    rng_conn: Rng::new(2).state(),
                    rng_spikes: Rng::new(3).state(),
                    freq_entries: Vec::new(),
                    baseline_comm: Default::default(),
                    spike_lookups: 0,
                    deletion: Default::default(),
                    formation: Default::default(),
                    calcium_trace: Vec::new(),
                }
            })
            .collect()
    }

    #[test]
    fn file_roundtrip_and_latest_selection() {
        let dir = tmp_dir("roundtrip");
        let cfg = tiny_cfg();
        let sections = tiny_sections(&cfg);
        for step in [10u64, 50, 30] {
            let path = dir.join(super::super::writer::snapshot_file_name(step));
            write_snapshot_sections(&path, &cfg, step, &sections).unwrap();
        }
        let latest = latest_snapshot_in(&dir).unwrap();
        let snap = Snapshot::read_file(&latest).unwrap();
        assert_eq!(snap.next_step(), 50);
        assert_eq!(snap.ranks(), 2);
        assert_eq!(snap.neurons_per_rank(), 4);
        let sec = snap.section(1).unwrap();
        assert_eq!(sec.first_id, 4);
        assert_eq!(sec.positions.len(), 4);
        let cfg_back = snap.config().unwrap();
        assert_eq!(cfg_back.ranks, cfg.ranks);
        snap.validate_for(&cfg_back).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn v1_files_load_through_dense_conversion() {
        // Manufacture a complete v1 file: version-1 header + sections in
        // the dense-frequency-table layout. It must parse, report its
        // version, and convert the dense table to sparse entries.
        use crate::snapshot::format::SnapshotHeader;
        use crate::util::wire::{put_u32, put_u64};
        let cfg = tiny_cfg();
        let mut sections = tiny_sections(&cfg);
        sections[1].freq_entries = vec![(0, 0.5), (3, 0.25)];
        let mut hdr = SnapshotHeader::for_config(&cfg, 20);
        hdr.version = 1;
        // What a v1-era build would have stamped: no balance bytes.
        hdr.fingerprint = config_fingerprint_for_version(&cfg, 1);
        let mut buf = Vec::new();
        hdr.encode(&mut buf);
        for (rank, sec) in sections.iter().enumerate() {
            let enc = sec.encode_v1(cfg.total_neurons());
            put_u32(&mut buf, rank as u32);
            put_u64(&mut buf, enc.len() as u64);
            buf.extend_from_slice(&enc);
        }
        let snap = Snapshot::from_bytes(&buf).unwrap();
        assert_eq!(snap.version(), 1);
        assert_eq!(snap.next_step(), 20);
        snap.validate_for(&cfg).unwrap();
        assert!(snap.section(0).unwrap().freq_entries.is_empty());
        assert_eq!(snap.section(1).unwrap().freq_entries, vec![(0, 0.5), (3, 0.25)]);
    }

    #[test]
    fn mismatched_config_is_rejected_with_details() {
        let dir = tmp_dir("mismatch");
        let cfg = tiny_cfg();
        let path = dir.join("one.ilmisnap");
        write_snapshot_sections(&path, &cfg, 10, &tiny_sections(&cfg)).unwrap();
        let snap = Snapshot::read_file(&path).unwrap();

        let mut other_seed = cfg.clone();
        other_seed.seed += 1;
        let err = snap.validate_for(&other_seed).unwrap_err();
        assert!(err.contains("fingerprint mismatch"), "{err}");
        // ...but branching from the same structure is allowed.
        snap.validate_for_branch(&other_seed).unwrap();

        let mut other_ranks = cfg.clone();
        other_ranks.ranks = 4;
        let err = snap.validate_for_branch(&other_ranks).unwrap_err();
        assert!(err.contains("2 ranks"), "{err}");

        let mut done = cfg.clone();
        done.steps = 10;
        let err = snap.validate_for(&done).unwrap_err();
        assert!(err.contains("nothing to resume"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_files_error_instead_of_garbage() {
        let dir = tmp_dir("corrupt");
        let cfg = tiny_cfg();
        let path = dir.join("snap.ilmisnap");
        write_snapshot_sections(&path, &cfg, 10, &tiny_sections(&cfg)).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();

        // Bad magic.
        let mut bad = bytes.clone();
        bad[3] ^= 0xFF;
        assert!(Snapshot::from_bytes(&bad).unwrap_err().contains("bad magic"));

        // Unsupported version.
        let mut bad = bytes.clone();
        bad[8] = FORMAT_VERSION as u8 + 9;
        assert!(Snapshot::from_bytes(&bad).unwrap_err().contains("unsupported"));

        // Truncation.
        bytes.truncate(bytes.len() - 7);
        assert!(Snapshot::from_bytes(&bytes).unwrap_err().contains("truncated"));
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Satellite hardening: a v5 file truncated at EVERY possible offset
    /// must fail with an error — never a panic, never a partial parse.
    #[test]
    fn truncation_at_every_offset_is_a_checked_error() {
        let dir = tmp_dir("trunc_sweep");
        let cfg = tiny_cfg();
        let path = dir.join("snap.ilmisnap");
        write_snapshot_sections(&path, &cfg, 10, &tiny_sections(&cfg)).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert!(Snapshot::from_bytes(&bytes).is_ok());
        for len in 0..bytes.len() {
            assert!(
                Snapshot::from_bytes(&bytes[..len]).is_err(),
                "truncation to {len}/{} bytes parsed successfully",
                bytes.len()
            );
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Satellite hardening: flipping ANY single byte of a v5 file must
    /// fail — the whole-file checksum leaves no unprotected region.
    #[test]
    fn every_single_byte_flip_is_a_checked_error() {
        let dir = tmp_dir("flip_sweep");
        let cfg = tiny_cfg();
        let path = dir.join("snap.ilmisnap");
        write_snapshot_sections(&path, &cfg, 10, &tiny_sections(&cfg)).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        for i in 0..bytes.len() {
            bytes[i] ^= 0xFF;
            assert!(
                Snapshot::from_bytes(&bytes).is_err(),
                "byte flip at offset {i}/{} parsed successfully",
                bytes.len()
            );
            bytes[i] ^= 0xFF;
        }
        assert!(Snapshot::from_bytes(&bytes).is_ok(), "restored file must parse");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Satellite hardening: absurd length fields must error without a
    /// matching up-front allocation, even when the file carries a valid
    /// checksum over its crafted contents.
    #[test]
    fn huge_length_fields_error_without_allocating() {
        use crate::snapshot::format::{content_checksum, SnapshotHeader};
        use crate::util::wire::{put_u32, put_u64};
        let cfg = tiny_cfg();

        // Section claiming u64::MAX bytes.
        let hdr = SnapshotHeader::for_config(&cfg, 20);
        let mut buf = Vec::new();
        hdr.encode(&mut buf);
        put_u32(&mut buf, 0);
        put_u64(&mut buf, u64::MAX);
        let sum = content_checksum(&buf);
        put_u64(&mut buf, sum);
        let err = Snapshot::from_bytes(&buf).unwrap_err();
        assert!(err.contains("truncated"), "{err}");

        // Header claiming u32::MAX ranks (capacity clamp, then a framing
        // error on the first missing section).
        let mut hdr = SnapshotHeader::for_config(&cfg, 20);
        hdr.ranks = u32::MAX;
        let mut buf = Vec::new();
        hdr.encode(&mut buf);
        let sum = content_checksum(&buf);
        put_u64(&mut buf, sum);
        assert!(Snapshot::from_bytes(&buf).is_err());
    }

    #[test]
    fn recovery_scan_falls_back_past_corrupt_newest() {
        let dir = tmp_dir("recovery_scan");
        let cfg = tiny_cfg();
        let sections = tiny_sections(&cfg);
        for step in [10u64, 30, 50] {
            let path = dir.join(super::super::writer::snapshot_file_name(step));
            write_snapshot_sections(&path, &cfg, step, &sections).unwrap();
        }
        // Corrupt the newest (as an interrupted write would), leave the
        // middle intact.
        let newest = dir.join(super::super::writer::snapshot_file_name(50));
        let mut bytes = std::fs::read(&newest).unwrap();
        bytes.truncate(bytes.len() * 2 / 3);
        std::fs::write(&newest, &bytes).unwrap();

        let scan = scan_for_recovery(&dir, &cfg).unwrap();
        assert_eq!(scan.snapshot.next_step(), 30);
        assert_eq!(scan.path, dir.join(super::super::writer::snapshot_file_name(30)));
        assert_eq!(scan.newest_step_seen, 50);
        assert_eq!(scan.skipped.len(), 1);
        assert!(scan.skipped[0].1.contains("checksum"), "{}", scan.skipped[0].1);

        // A fingerprint-incompatible config finds nothing usable.
        let mut other = cfg.clone();
        other.seed += 1;
        let err = scan_for_recovery(&dir, &other).unwrap_err();
        assert!(err.contains("no usable checkpoint"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
