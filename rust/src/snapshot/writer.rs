//! Snapshot writing: single-file assembly plus the in-run
//! `CheckpointSink` that collects per-rank sections and writes one
//! complete snapshot file per checkpoint step.
//!
//! Checkpoint I/O is deliberately invisible to the simulation: capture
//! only *reads* rank state, sections travel through shared process
//! memory (not the simulated-MPI communicator, whose byte counters
//! reproduce the paper's tables and must not see checkpoint traffic),
//! and files are written atomically (temp file + rename) so a crash
//! mid-write never leaves a half-snapshot behind.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use super::format::{RankSection, SnapshotHeader, SNAPSHOT_EXT};
use crate::balance::Partition;
use crate::config::SimConfig;
use crate::util::wire::{put_u32, put_u64};

/// Canonical file name of the checkpoint taken with `next_step` steps
/// completed: `step_0000001000.ilmisnap`.
///
/// # Examples
///
/// The usual write path is config-driven — the driver deposits into a
/// [`CheckpointSink`] every `checkpoint_every` steps — and this
/// function names the file a given checkpoint landed in:
///
/// ```no_run
/// use ilmi::config::SimConfig;
/// use ilmi::coordinator::{resume_simulation, run_simulation};
/// use ilmi::snapshot::{snapshot_file_name, Snapshot};
///
/// let mut cfg = SimConfig::default();
/// cfg.steps = 1000;
/// cfg.checkpoint_every = 500;
/// cfg.checkpoint_dir = "ckpts".to_string();
/// run_simulation(&cfg).unwrap();
///
/// // Reopen the mid-run snapshot and resume to a longer schedule.
/// let snap = Snapshot::read_file(format!("ckpts/{}", snapshot_file_name(500))).unwrap();
/// let mut longer = cfg.clone();
/// longer.steps = 2000;
/// longer.checkpoint_every = 0;
/// longer.checkpoint_dir = String::new();
/// let report = resume_simulation(&longer, &snap).unwrap();
/// assert_eq!(report.ranks.len(), cfg.ranks);
/// ```
pub fn snapshot_file_name(next_step: u64) -> String {
    format!("step_{next_step:010}.{SNAPSHOT_EXT}")
}

/// Assemble and atomically write one snapshot file from already-encoded
/// per-rank sections (`sections[r]` = rank r, see `RankSection::encode`)
/// under the uniform stride layout. Always writes the current format
/// version (v4); the reader additionally accepts v1–v3 files. Runs with
/// an active (or skewed) load-balancing partition go through
/// [`write_snapshot_with_partition`] instead, so the ownership section
/// records which rank owned which id range at capture time.
pub fn write_snapshot(
    path: &Path,
    cfg: &SimConfig,
    next_step: u64,
    sections: &[Vec<u8>],
) -> Result<(), String> {
    write_with_header(path, SnapshotHeader::for_config(cfg, next_step), cfg, sections)
}

/// `write_snapshot` recording the run's current ownership partition in
/// the header (collapses to the uniform tag when it IS the default).
pub fn write_snapshot_with_partition(
    path: &Path,
    cfg: &SimConfig,
    next_step: u64,
    partition: &Partition,
    sections: &[Vec<u8>],
) -> Result<(), String> {
    write_with_header(path, SnapshotHeader::for_run(cfg, next_step, partition), cfg, sections)
}

fn write_with_header(
    path: &Path,
    header: SnapshotHeader,
    cfg: &SimConfig,
    sections: &[Vec<u8>],
) -> Result<(), String> {
    if sections.len() != cfg.ranks {
        return Err(format!(
            "snapshot needs one section per rank: got {} for {} ranks",
            sections.len(),
            cfg.ranks
        ));
    }
    let mut buf = Vec::with_capacity(
        64 + sections.iter().map(|s| s.len() + 12).sum::<usize>(),
    );
    header.encode(&mut buf);
    for (rank, section) in sections.iter().enumerate() {
        put_u32(&mut buf, rank as u32);
        put_u64(&mut buf, section.len() as u64);
        buf.extend_from_slice(section);
    }
    let tmp = path.with_extension("ilmisnap.tmp");
    std::fs::write(&tmp, &buf)
        .map_err(|e| format!("writing snapshot {}: {e}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .map_err(|e| format!("renaming snapshot into place at {}: {e}", path.display()))?;
    Ok(())
}

/// Convenience for callers holding decoded sections (examples, tests).
pub fn write_snapshot_sections(
    path: &Path,
    cfg: &SimConfig,
    next_step: u64,
    sections: &[RankSection],
) -> Result<(), String> {
    let encoded: Vec<Vec<u8>> = sections.iter().map(|s| s.encode()).collect();
    write_snapshot(path, cfg, next_step, &encoded)
}

/// Collects per-rank sections during a run and writes one snapshot file
/// per checkpoint step once every rank has deposited. Rank threads call
/// `deposit` concurrently; the last depositor of a step performs the
/// file write, so no barrier beyond the one the simulation step already
/// implies is added.
pub struct CheckpointSink {
    dir: PathBuf,
    cfg: SimConfig,
    /// next_step -> (per-rank section slots, the partition at that
    /// step — identical on every rank, installed by the first
    /// depositor).
    #[allow(clippy::type_complexity)]
    pending: Mutex<HashMap<u64, (Vec<Option<Vec<u8>>>, Partition)>>,
    /// First failure, kept for end-of-run reporting. Checkpoint I/O
    /// errors must NOT abort one rank's step loop mid-run: the other
    /// ranks would block forever at their next collective barrier. The
    /// driver records failures here, keeps simulating, and surfaces
    /// the error after all ranks have joined.
    first_error: Mutex<Option<String>>,
}

impl CheckpointSink {
    /// Create the sink (and the checkpoint directory).
    pub fn create(cfg: &SimConfig) -> Result<CheckpointSink, String> {
        if cfg.checkpoint_dir.is_empty() {
            return Err("checkpoint sink needs a non-empty checkpoint_dir".to_string());
        }
        let dir = PathBuf::from(&cfg.checkpoint_dir);
        std::fs::create_dir_all(&dir)
            .map_err(|e| format!("creating checkpoint dir {}: {e}", dir.display()))?;
        Ok(CheckpointSink {
            dir,
            cfg: cfg.clone(),
            pending: Mutex::new(HashMap::new()),
            first_error: Mutex::new(None),
        })
    }

    /// `deposit`, but failures are recorded (and printed once) instead
    /// of returned, so a rank's step loop never aborts over checkpoint
    /// I/O — see `first_error`.
    pub fn deposit_nonfatal(
        &self,
        next_step: u64,
        rank: usize,
        section: Vec<u8>,
        partition: &Partition,
    ) {
        if let Err(e) = self.deposit(next_step, rank, section, partition) {
            let mut first = self.first_error.lock().unwrap();
            if first.is_none() {
                eprintln!("warning: checkpoint at step {next_step} failed: {e}");
                *first = Some(e);
            }
        }
    }

    /// The first recorded checkpoint failure, if any (checked by the
    /// driver after all ranks have joined).
    pub fn first_error(&self) -> Option<String> {
        self.first_error.lock().unwrap().clone()
    }

    /// Deposit rank `rank`'s encoded section for the checkpoint taken
    /// with `next_step` steps completed. `partition` is the run's
    /// ownership partition at that step (replicated, so every rank
    /// passes an identical value; the first depositor's copy lands in
    /// the header). Returns the written file path if this call
    /// completed the snapshot, `None` while sections from other ranks
    /// are still outstanding.
    pub fn deposit(
        &self,
        next_step: u64,
        rank: usize,
        section: Vec<u8>,
        partition: &Partition,
    ) -> Result<Option<PathBuf>, String> {
        let complete = {
            let mut pending = self.pending.lock().unwrap();
            let (slots, part) = pending
                .entry(next_step)
                .or_insert_with(|| (vec![None; self.cfg.ranks], partition.clone()));
            debug_assert_eq!(&*part, partition, "ranks disagree on the partition");
            if slots[rank].is_some() {
                return Err(format!(
                    "rank {rank} deposited twice for checkpoint step {next_step}"
                ));
            }
            slots[rank] = Some(section);
            if slots.iter().all(|s| s.is_some()) {
                let (slots, part) = pending.remove(&next_step).unwrap();
                Some((slots.into_iter().map(|s| s.unwrap()).collect::<Vec<_>>(), part))
            } else {
                None
            }
        };
        match complete {
            None => Ok(None),
            Some((sections, part)) => {
                let path = self.dir.join(snapshot_file_name(next_step));
                write_snapshot_with_partition(&path, &self.cfg, next_step, &part, &sections)?;
                Ok(Some(path))
            }
        }
    }
}
