//! Snapshot writing: single-file assembly plus the in-run checkpoint
//! sinks that collect per-rank sections and write one complete snapshot
//! file per checkpoint step — [`CheckpointSink`] when every rank is a
//! thread of this process, [`PartSink`] when each rank is its own
//! process and sections must meet on the filesystem instead.
//!
//! Checkpoint I/O is deliberately invisible to the simulation: capture
//! only *reads* rank state, sections travel through shared process
//! memory or part files (not the simulated-MPI communicator, whose byte
//! counters reproduce the paper's tables and must not see checkpoint
//! traffic), and files are written atomically (temp file + rename) so a
//! crash mid-write never leaves a half-snapshot behind.
//!
//! Both sinks apply the `checkpoint_keep` retention ring (prune the
//! oldest snapshots after each successful write) and both route the
//! final file through the `fault::on_checkpoint_write` hook, so
//! checkpoint failures are injectable deterministically (DESIGN.md
//! §13).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use super::format::{content_checksum, RankSection, SnapshotHeader, SNAPSHOT_EXT};
use crate::balance::Partition;
use crate::config::SimConfig;
use crate::fault::CkptAction;
use crate::util::wire::{put_u32, put_u64};

/// Canonical file name of the checkpoint taken with `next_step` steps
/// completed: `step_0000001000.ilmisnap`.
///
/// # Examples
///
/// The usual write path is config-driven — the driver deposits into a
/// [`CheckpointSink`] every `checkpoint_every` steps — and this
/// function names the file a given checkpoint landed in:
///
/// ```no_run
/// use ilmi::config::SimConfig;
/// use ilmi::coordinator::{resume_simulation, run_simulation};
/// use ilmi::snapshot::{snapshot_file_name, Snapshot};
///
/// let mut cfg = SimConfig::default();
/// cfg.steps = 1000;
/// cfg.checkpoint_every = 500;
/// cfg.checkpoint_dir = "ckpts".to_string();
/// run_simulation(&cfg).unwrap();
///
/// // Reopen the mid-run snapshot and resume to a longer schedule.
/// let snap = Snapshot::read_file(format!("ckpts/{}", snapshot_file_name(500))).unwrap();
/// let mut longer = cfg.clone();
/// longer.steps = 2000;
/// longer.checkpoint_every = 0;
/// longer.checkpoint_dir = String::new();
/// let report = resume_simulation(&longer, &snap).unwrap();
/// assert_eq!(report.ranks.len(), cfg.ranks);
/// ```
pub fn snapshot_file_name(next_step: u64) -> String {
    format!("step_{next_step:010}.{SNAPSHOT_EXT}")
}

/// Assemble and atomically write one snapshot file from already-encoded
/// per-rank sections (`sections[r]` = rank r, see `RankSection::encode`)
/// under the uniform stride layout. Always writes the current format
/// version (v5); the reader additionally accepts v1–v4 files. Runs with
/// an active (or skewed) load-balancing partition go through
/// [`write_snapshot_with_partition`] instead, so the ownership section
/// records which rank owned which id range at capture time.
pub fn write_snapshot(
    path: &Path,
    cfg: &SimConfig,
    next_step: u64,
    sections: &[Vec<u8>],
) -> Result<(), String> {
    write_with_header(path, SnapshotHeader::for_config(cfg, next_step), cfg, sections)
}

/// `write_snapshot` recording the run's current ownership partition in
/// the header (collapses to the uniform tag when it IS the default).
pub fn write_snapshot_with_partition(
    path: &Path,
    cfg: &SimConfig,
    next_step: u64,
    partition: &Partition,
    sections: &[Vec<u8>],
) -> Result<(), String> {
    write_with_header(path, SnapshotHeader::for_run(cfg, next_step, partition), cfg, sections)
}

fn write_with_header(
    path: &Path,
    header: SnapshotHeader,
    cfg: &SimConfig,
    sections: &[Vec<u8>],
) -> Result<(), String> {
    if sections.len() != cfg.ranks {
        return Err(format!(
            "snapshot needs one section per rank: got {} for {} ranks",
            sections.len(),
            cfg.ranks
        ));
    }
    let next_step = header.next_step;
    let mut buf = Vec::with_capacity(
        64 + sections.iter().map(|s| s.len() + 12).sum::<usize>(),
    );
    header.encode(&mut buf);
    for (rank, section) in sections.iter().enumerate() {
        put_u32(&mut buf, rank as u32);
        put_u64(&mut buf, section.len() as u64);
        buf.extend_from_slice(section);
    }
    // v5 trailer: whole-file content checksum, so the recovery scan can
    // reject any corrupt or truncated checkpoint with a checked read.
    let checksum = content_checksum(&buf);
    put_u64(&mut buf, checksum);
    // Deterministic fault injection (no-ops unless a plan is armed in
    // this process): fail the write outright, or leave a truncated —
    // hence checksum-invalid — file in place that the recovery scan
    // must fall back past.
    match crate::fault::on_checkpoint_write(next_step) {
        CkptAction::Pass => {}
        CkptAction::Fail => {
            return Err(format!(
                "injected fault: checkpoint write for step {next_step} failed"
            ));
        }
        CkptAction::Corrupt => {
            eprintln!("[fault] corrupting checkpoint for step {next_step}");
            buf.truncate(buf.len() * 2 / 3);
        }
    }
    let tmp = path.with_extension("ilmisnap.tmp");
    std::fs::write(&tmp, &buf)
        .map_err(|e| format!("writing snapshot {}: {e}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .map_err(|e| format!("renaming snapshot into place at {}: {e}", path.display()))?;
    Ok(())
}

/// Apply the `checkpoint_keep` retention ring: keep only the newest
/// `keep` complete snapshots in `dir`, deleting older `.ilmisnap` files
/// plus any stale part/claim files from checkpoints that can no longer
/// matter (their step precedes the newest complete snapshot). `keep ==
/// 0` means keep everything. Prune errors are non-fatal by design —
/// the snapshot that was just written is already safe on disk — so the
/// function reports, at most, a best effort.
pub fn prune_checkpoint_ring(dir: &Path, keep: usize) {
    let Ok(entries) = std::fs::read_dir(dir) else { return };
    let mut snaps: Vec<PathBuf> = Vec::new();
    let mut scraps: Vec<PathBuf> = Vec::new();
    for entry in entries.flatten() {
        let path = entry.path();
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if name.ends_with(&format!(".{SNAPSHOT_EXT}")) && name.starts_with("step_") {
            snaps.push(path);
        } else if name.starts_with("step_")
            && (name.ends_with(".sect") || name.ends_with(".claim"))
        {
            scraps.push(path);
        }
    }
    // Zero-padded step numbers sort lexicographically = numerically.
    snaps.sort();
    if keep > 0 && snaps.len() > keep {
        for old in &snaps[..snaps.len() - keep] {
            let _ = std::fs::remove_file(old);
        }
    }
    // Part/claim files for steps at or before the newest complete
    // snapshot are leftovers of failed or finished assemblies: an
    // assembly that has not completed by the time a NEWER snapshot
    // exists never will (deposits arrive in step order).
    let newest = snaps.last().and_then(|p| step_of_file_name(p));
    if let Some(newest) = newest {
        for scrap in &scraps {
            if step_of_file_name(scrap).is_some_and(|s| s <= newest) {
                let _ = std::fs::remove_file(scrap);
            }
        }
    }
}

/// Parse the step number out of a `step_{N:010}.*` checkpoint-related
/// file name; `None` for anything else.
pub fn step_of_file_name(path: &Path) -> Option<u64> {
    let name = path.file_name()?.to_str()?;
    name.strip_prefix("step_")?.get(..10)?.parse().ok()
}

/// Convenience for callers holding decoded sections (examples, tests).
pub fn write_snapshot_sections(
    path: &Path,
    cfg: &SimConfig,
    next_step: u64,
    sections: &[RankSection],
) -> Result<(), String> {
    let encoded: Vec<Vec<u8>> = sections.iter().map(|s| s.encode()).collect();
    write_snapshot(path, cfg, next_step, &encoded)
}

/// What the driver's step loop needs from a checkpoint sink, abstracted
/// over WHERE the other ranks' sections live: shared process memory
/// ([`CheckpointSink`], thread backend) or part files in the checkpoint
/// directory ([`PartSink`], process-per-rank socket backend). Failures
/// must be recorded, not returned — a rank aborting its step loop over
/// checkpoint I/O would deadlock the others at the next collective.
pub trait SectionSink: Sync {
    /// Deposit rank `rank`'s encoded section for the checkpoint taken
    /// with `next_step` steps completed, recording (never propagating)
    /// failures.
    fn deposit_nonfatal(&self, next_step: u64, rank: usize, section: Vec<u8>, partition: &Partition);

    /// The first recorded failure, surfaced by the driver after all
    /// ranks have joined.
    fn first_error(&self) -> Option<String>;
}

/// Collects per-rank sections during a run and writes one snapshot file
/// per checkpoint step once every rank has deposited. Rank threads call
/// `deposit` concurrently; the last depositor of a step performs the
/// file write, so no barrier beyond the one the simulation step already
/// implies is added.
pub struct CheckpointSink {
    dir: PathBuf,
    cfg: SimConfig,
    /// next_step -> (per-rank section slots, the partition at that
    /// step — identical on every rank, installed by the first
    /// depositor).
    #[allow(clippy::type_complexity)]
    pending: Mutex<HashMap<u64, (Vec<Option<Vec<u8>>>, Partition)>>,
    /// First failure, kept for end-of-run reporting. Checkpoint I/O
    /// errors must NOT abort one rank's step loop mid-run: the other
    /// ranks would block forever at their next collective barrier. The
    /// driver records failures here, keeps simulating, and surfaces
    /// the error after all ranks have joined.
    first_error: Mutex<Option<String>>,
}

impl CheckpointSink {
    /// Create the sink (and the checkpoint directory).
    pub fn create(cfg: &SimConfig) -> Result<CheckpointSink, String> {
        if cfg.checkpoint_dir.is_empty() {
            return Err("checkpoint sink needs a non-empty checkpoint_dir".to_string());
        }
        let dir = PathBuf::from(&cfg.checkpoint_dir);
        std::fs::create_dir_all(&dir)
            .map_err(|e| format!("creating checkpoint dir {}: {e}", dir.display()))?;
        Ok(CheckpointSink {
            dir,
            cfg: cfg.clone(),
            pending: Mutex::new(HashMap::new()),
            first_error: Mutex::new(None),
        })
    }

    /// `deposit`, but failures are recorded (and printed once) instead
    /// of returned, so a rank's step loop never aborts over checkpoint
    /// I/O — see `first_error`.
    pub fn deposit_nonfatal(
        &self,
        next_step: u64,
        rank: usize,
        section: Vec<u8>,
        partition: &Partition,
    ) {
        if let Err(e) = self.deposit(next_step, rank, section, partition) {
            let mut first = self.first_error.lock().unwrap();
            if first.is_none() {
                eprintln!("warning: checkpoint at step {next_step} failed: {e}");
                *first = Some(e);
            }
        }
    }

    /// The first recorded checkpoint failure, if any (checked by the
    /// driver after all ranks have joined).
    pub fn first_error(&self) -> Option<String> {
        self.first_error.lock().unwrap().clone()
    }

    /// Deposit rank `rank`'s encoded section for the checkpoint taken
    /// with `next_step` steps completed. `partition` is the run's
    /// ownership partition at that step (replicated, so every rank
    /// passes an identical value; the first depositor's copy lands in
    /// the header). Returns the written file path if this call
    /// completed the snapshot, `None` while sections from other ranks
    /// are still outstanding.
    pub fn deposit(
        &self,
        next_step: u64,
        rank: usize,
        section: Vec<u8>,
        partition: &Partition,
    ) -> Result<Option<PathBuf>, String> {
        let complete = {
            let mut pending = self.pending.lock().unwrap();
            let (slots, part) = pending
                .entry(next_step)
                .or_insert_with(|| (vec![None; self.cfg.ranks], partition.clone()));
            debug_assert_eq!(&*part, partition, "ranks disagree on the partition");
            if slots[rank].is_some() {
                return Err(format!(
                    "rank {rank} deposited twice for checkpoint step {next_step}"
                ));
            }
            slots[rank] = Some(section);
            if slots.iter().all(|s| s.is_some()) {
                let (slots, part) = pending.remove(&next_step).unwrap();
                Some((slots.into_iter().map(|s| s.unwrap()).collect::<Vec<_>>(), part))
            } else {
                None
            }
        };
        match complete {
            None => Ok(None),
            Some((sections, part)) => {
                let path = self.dir.join(snapshot_file_name(next_step));
                write_snapshot_with_partition(&path, &self.cfg, next_step, &part, &sections)?;
                prune_checkpoint_ring(&self.dir, self.cfg.checkpoint_keep);
                Ok(Some(path))
            }
        }
    }
}

impl SectionSink for CheckpointSink {
    fn deposit_nonfatal(
        &self,
        next_step: u64,
        rank: usize,
        section: Vec<u8>,
        partition: &Partition,
    ) {
        CheckpointSink::deposit_nonfatal(self, next_step, rank, section, partition)
    }

    fn first_error(&self) -> Option<String> {
        CheckpointSink::first_error(self)
    }
}

/// Name of rank `rank`'s part file for the checkpoint at `next_step`.
fn part_file_name(next_step: u64, rank: usize) -> String {
    format!("step_{next_step:010}.r{rank}.sect")
}

/// Name of the assembly claim file for the checkpoint at `next_step`.
fn claim_file_name(next_step: u64) -> String {
    format!("step_{next_step:010}.claim")
}

/// The process-per-rank checkpoint sink: rank processes cannot share a
/// `CheckpointSink`, so sections meet on the filesystem instead. Each
/// rank atomically writes its encoded section to a part file
/// (`step_N.rK.sect`); whichever rank then observes all parts present
/// claims assembly (an exclusive `step_N.claim` create), reads them
/// back, and writes the ordinary snapshot file — byte-identical to what
/// the thread backend's sink writes, which the cross-backend
/// differential suite pins.
///
/// Liveness: part renames are totally ordered per step, so the rank
/// that performs the LAST rename observes a complete set and triggers
/// assembly; the claim file makes racing observers idempotent. No
/// communicator traffic is involved, so the paper's byte counters never
/// see checkpoint I/O here either.
pub struct PartSink {
    dir: PathBuf,
    cfg: SimConfig,
    first_error: Mutex<Option<String>>,
}

impl PartSink {
    /// Create the sink (and the checkpoint directory) for one rank
    /// process.
    pub fn create(cfg: &SimConfig) -> Result<PartSink, String> {
        if cfg.checkpoint_dir.is_empty() {
            return Err("checkpoint part sink needs a non-empty checkpoint_dir".to_string());
        }
        let dir = PathBuf::from(&cfg.checkpoint_dir);
        std::fs::create_dir_all(&dir)
            .map_err(|e| format!("creating checkpoint dir {}: {e}", dir.display()))?;
        Ok(PartSink { dir, cfg: cfg.clone(), first_error: Mutex::new(None) })
    }

    /// Deposit this rank's section; assembles and writes the snapshot
    /// if this deposit completed the set. Returns the snapshot path if
    /// this call performed the assembly.
    pub fn deposit(
        &self,
        next_step: u64,
        rank: usize,
        section: Vec<u8>,
        partition: &Partition,
    ) -> Result<Option<PathBuf>, String> {
        // Atomic part write: tmp + rename, like the snapshot itself.
        let part = self.dir.join(part_file_name(next_step, rank));
        let tmp = part.with_extension("sect.tmp");
        std::fs::write(&tmp, &section)
            .map_err(|e| format!("writing checkpoint part {}: {e}", tmp.display()))?;
        std::fs::rename(&tmp, &part)
            .map_err(|e| format!("renaming checkpoint part {}: {e}", part.display()))?;
        // Completeness check. At least one rank — the one whose rename
        // lands last — sees every part present.
        for r in 0..self.cfg.ranks {
            if !self.dir.join(part_file_name(next_step, r)).exists() {
                return Ok(None);
            }
        }
        // Claim assembly exclusively; losing the race is success.
        let claim = self.dir.join(claim_file_name(next_step));
        match std::fs::OpenOptions::new().write(true).create_new(true).open(&claim) {
            Ok(_) => {}
            Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => return Ok(None),
            Err(e) => return Err(format!("claiming assembly {}: {e}", claim.display())),
        }
        let mut sections = Vec::with_capacity(self.cfg.ranks);
        for r in 0..self.cfg.ranks {
            let path = self.dir.join(part_file_name(next_step, r));
            sections.push(
                std::fs::read(&path)
                    .map_err(|e| format!("reading checkpoint part {}: {e}", path.display()))?,
            );
        }
        let path = self.dir.join(snapshot_file_name(next_step));
        write_snapshot_with_partition(&path, &self.cfg, next_step, partition, &sections)?;
        for r in 0..self.cfg.ranks {
            let _ = std::fs::remove_file(self.dir.join(part_file_name(next_step, r)));
        }
        let _ = std::fs::remove_file(&claim);
        prune_checkpoint_ring(&self.dir, self.cfg.checkpoint_keep);
        Ok(Some(path))
    }
}

impl SectionSink for PartSink {
    fn deposit_nonfatal(
        &self,
        next_step: u64,
        rank: usize,
        section: Vec<u8>,
        partition: &Partition,
    ) {
        if let Err(e) = self.deposit(next_step, rank, section, partition) {
            let mut first = self.first_error.lock().unwrap();
            if first.is_none() {
                eprintln!("warning: checkpoint at step {next_step} failed: {e}");
                *first = Some(e);
            }
        }
    }

    fn first_error(&self) -> Option<String> {
        self.first_error.lock().unwrap().clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fresh_dir(label: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("ilmi_writer_{label}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn tiny_cfg(dir: &Path) -> SimConfig {
        let mut cfg = SimConfig::default();
        cfg.ranks = 2;
        cfg.neurons_per_rank = 8;
        cfg.checkpoint_every = 10;
        cfg.checkpoint_dir = dir.to_str().unwrap().to_string();
        cfg
    }

    fn names_in(dir: &Path) -> Vec<String> {
        let mut names: Vec<String> = std::fs::read_dir(dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        names.sort();
        names
    }

    #[test]
    fn ring_prune_keeps_newest_and_clears_stale_scraps() {
        let dir = fresh_dir("ring");
        for name in [
            "step_0000000050.ilmisnap",
            "step_0000000100.ilmisnap",
            "step_0000000150.ilmisnap",
            "step_0000000100.r0.sect", // stale: ≤ newest snapshot
            "step_0000000100.claim",   // stale: ≤ newest snapshot
            "step_0000000200.r1.sect", // in-flight: newer than any snapshot
            "unrelated.txt",
        ] {
            std::fs::write(dir.join(name), b"x").unwrap();
        }

        // keep == 0 keeps every snapshot but still clears stale scraps.
        prune_checkpoint_ring(&dir, 0);
        assert_eq!(
            names_in(&dir),
            vec![
                "step_0000000050.ilmisnap",
                "step_0000000100.ilmisnap",
                "step_0000000150.ilmisnap",
                "step_0000000200.r1.sect",
                "unrelated.txt",
            ]
        );

        prune_checkpoint_ring(&dir, 2);
        assert_eq!(
            names_in(&dir),
            vec![
                "step_0000000100.ilmisnap",
                "step_0000000150.ilmisnap",
                "step_0000000200.r1.sect",
                "unrelated.txt",
            ]
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn step_parsing_handles_all_checkpoint_file_kinds() {
        for (name, want) in [
            ("step_0000000100.ilmisnap", Some(100)),
            ("step_0000000100.r3.sect", Some(100)),
            ("step_0000000100.claim", Some(100)),
            ("step_123.ilmisnap", None), // not zero-padded to width 10
            ("other.ilmisnap", None),
        ] {
            assert_eq!(step_of_file_name(Path::new(name)), want, "{name}");
        }
    }

    #[test]
    fn part_sink_assembles_exactly_once_and_cleans_up() {
        let dir = fresh_dir("parts");
        let cfg = tiny_cfg(&dir);
        let part = Partition::uniform(cfg.ranks, cfg.neurons_per_rank as u64);
        let sink = PartSink::create(&cfg).unwrap();

        assert_eq!(sink.deposit(10, 0, vec![1, 2, 3], &part).unwrap(), None);
        assert_eq!(names_in(&dir), vec!["step_0000000010.r0.sect"]);

        let written = sink.deposit(10, 1, vec![4, 5], &part).unwrap();
        assert_eq!(written, Some(dir.join("step_0000000010.ilmisnap")));
        // Parts and claim are gone; only the assembled snapshot remains.
        assert_eq!(names_in(&dir), vec!["step_0000000010.ilmisnap"]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn part_sink_output_is_byte_identical_to_checkpoint_sink() {
        // The cross-backend differential suite relies on socket-run
        // checkpoints matching thread-run checkpoints bit for bit; pin
        // that at the sink level (same cfg, hence same embedded INI).
        let dir = fresh_dir("equiv");
        let cfg = tiny_cfg(&dir);
        let part = Partition::uniform(cfg.ranks, cfg.neurons_per_rank as u64);
        let sections = [vec![9u8; 40], vec![7u8; 40]];

        let shared = CheckpointSink::create(&cfg).unwrap();
        shared.deposit(20, 0, sections[0].clone(), &part).unwrap();
        let path = shared.deposit(20, 1, sections[1].clone(), &part).unwrap().unwrap();
        let via_threads = std::fs::read(&path).unwrap();
        std::fs::remove_file(&path).unwrap();

        let parts = PartSink::create(&cfg).unwrap();
        parts.deposit(20, 0, sections[0].clone(), &part).unwrap();
        parts.deposit(20, 1, sections[1].clone(), &part).unwrap();
        let via_parts = std::fs::read(&path).unwrap();

        assert_eq!(via_threads, via_parts);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn checkpoint_sink_applies_the_retention_ring() {
        let dir = fresh_dir("sink_ring");
        let mut cfg = tiny_cfg(&dir);
        cfg.checkpoint_keep = 2;
        let part = Partition::uniform(cfg.ranks, cfg.neurons_per_rank as u64);
        let sink = CheckpointSink::create(&cfg).unwrap();
        for step in [10u64, 20, 30] {
            sink.deposit(step, 0, vec![1], &part).unwrap();
            sink.deposit(step, 1, vec![2], &part).unwrap();
        }
        assert_eq!(
            names_in(&dir),
            vec!["step_0000000020.ilmisnap", "step_0000000030.ilmisnap"]
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}
