//! Live telemetry plane: rank heartbeats, fleet status, `ilmi status`.
//!
//! PR 6's trace subsystem explains a run *after* it ends; this module
//! makes a socket fleet observable *while it runs* (DESIGN.md §14).
//! Three pieces:
//!
//! * **Heartbeats** — every `telemetry.every` steps, each rank process
//!   encodes a fixed-layout [`HealthFrame`] (step, phase-seconds deltas,
//!   comm-counter deltas, rss estimate, epoch-boundary bits) and writes
//!   it to the supervisor over the launcher's existing control socket
//!   (`ctl.sock`, tag `HEARTBEAT`). One fresh connection per beat, the
//!   same pattern as result reporting — no long-lived channel to leak.
//! * **Watchdog** — the launcher tracks per-rank inter-beat gaps; a rank
//!   that stays silent for `watchdog_misses` times the largest gap seen
//!   so far is declared hung and the launch fails, which routes into the
//!   supervisor's existing kill-reap-scan-respawn recovery loop
//!   (`comm::proc`, DESIGN.md §13). Hangs become recoverable, not just
//!   deaths.
//! * **Status** — the supervisor folds heartbeats into an atomically
//!   rewritten `status.json` ([`StatusWriter`]); `ilmi status <dir>`
//!   renders it as a table ([`render_status`]).
//!
//! Telemetry is pure observation: heartbeat bytes travel only on the
//! control socket (never a peer data channel), are excluded from
//! `CommCounters`, and the `[telemetry]` config keys are
//! instrumentation knobs outside the dynamics fingerprint — a run with
//! telemetry on ends bit-identical to the same run with it off (pinned
//! by the fault-tolerance suite). Everything here is zero-cost when
//! off: the per-step hook is one `OnceLock::get()` returning `None`.

use std::path::{Path, PathBuf};
use std::sync::{Mutex, OnceLock};

use crate::bench::json::{obj, parse, Json};
use crate::comm::CounterSnapshot;
use crate::trace::boundary_names;
use crate::util::wire::{put_f64, put_u32, put_u64, put_u8, Cursor};

/// Environment variable carrying the heartbeat cadence (steps per beat)
/// to rank processes; consumed and removed by `proc::maybe_run_child`.
/// Absent or `0` means telemetry is off.
pub const ENV_TELEMETRY_EVERY: &str = "ILMI_TELEMETRY_EVERY";

/// Status JSON schema version (bumped on layout changes).
pub const STATUS_SCHEMA_VERSION: u64 = 1;

/// Encoded size of a [`HealthFrame`]: rank + step + boundaries +
/// 7 phase deltas + 6 counter deltas + rss.
pub const HEALTH_FRAME_LEN: usize = 4 + 8 + 1 + 7 * 8 + 6 * 8 + 8;

/// One rank heartbeat: everything the supervisor needs to render a
/// top-like view, as *deltas since the previous beat* so the stream is
/// meaningful mid-run without history. Fixed layout, no heap fields.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct HealthFrame {
    pub rank: u32,
    /// Global steps completed when the beat was taken.
    pub step: u64,
    /// Epoch-boundary bits of the beat step (`trace::SPIKE_EPOCH` etc.).
    pub boundaries: u8,
    /// Per-phase busy seconds since the previous beat, `ALL_PHASES`
    /// order.
    pub phase_delta: [f64; 7],
    /// Comm-counter deltas since the previous beat.
    pub comm_delta: CounterSnapshot,
    /// Resident-set estimate in bytes (0 where unavailable).
    pub rss_bytes: u64,
}

impl HealthFrame {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(HEALTH_FRAME_LEN);
        put_u32(&mut out, self.rank);
        put_u64(&mut out, self.step);
        put_u8(&mut out, self.boundaries);
        for v in self.phase_delta {
            put_f64(&mut out, v);
        }
        let c = self.comm_delta;
        for v in [c.bytes_sent, c.bytes_recv, c.bytes_rma, c.msgs_sent, c.collectives, c.rma_gets]
        {
            put_u64(&mut out, v);
        }
        put_u64(&mut out, self.rss_bytes);
        debug_assert_eq!(out.len(), HEALTH_FRAME_LEN);
        out
    }

    pub fn decode(buf: &[u8]) -> Result<HealthFrame, String> {
        let mut c = Cursor::new(buf, "health frame");
        let rank = c.u32("rank")?;
        let step = c.u64("step")?;
        let boundaries = c.u8("boundaries")?;
        let mut phase_delta = [0.0; 7];
        for p in phase_delta.iter_mut() {
            *p = c.f64("phase delta")?;
        }
        let comm_delta = CounterSnapshot {
            bytes_sent: c.u64("bytes_sent")?,
            bytes_recv: c.u64("bytes_recv")?,
            bytes_rma: c.u64("bytes_rma")?,
            msgs_sent: c.u64("msgs_sent")?,
            collectives: c.u64("collectives")?,
            rma_gets: c.u64("rma_gets")?,
        };
        let rss_bytes = c.u64("rss_bytes")?;
        c.finish("health frame")?;
        Ok(HealthFrame { rank, step, boundaries, phase_delta, comm_delta, rss_bytes })
    }
}

// -- child side (rank process) -------------------------------------------

struct ChildTelemetry {
    every: u64,
    rank: u32,
    ctl: PathBuf,
    state: Mutex<BeatState>,
}

#[derive(Default)]
struct BeatState {
    prev_phase: [f64; 7],
    prev_comm: CounterSnapshot,
}

static CHILD: OnceLock<ChildTelemetry> = OnceLock::new();

/// Arm heartbeat emission for this rank process (idempotent; only the
/// first call wins, mirroring `fault::arm`). `every == 0` is a no-op so
/// the beat hook stays on its `None` fast path.
pub fn arm_child(every: u64, rank: usize, ctl: PathBuf) {
    if every == 0 {
        return;
    }
    let _ = CHILD.set(ChildTelemetry {
        every,
        rank: rank as u32,
        ctl,
        state: Mutex::new(BeatState::default()),
    });
}

/// Arm from [`ENV_TELEMETRY_EVERY`] if present, removing the variable so
/// nested launches don't inherit it. The control socket lives in the
/// launcher's rendezvous `dir`.
pub fn arm_child_from_env(rank: usize, dir: &Path) {
    if let Ok(v) = std::env::var(ENV_TELEMETRY_EVERY) {
        std::env::remove_var(ENV_TELEMETRY_EVERY);
        let every: u64 = v
            .parse()
            .unwrap_or_else(|_| panic!("invalid {ENV_TELEMETRY_EVERY} value `{v}`"));
        arm_child(every, rank, dir.join("ctl.sock"));
    }
}

/// Emit a heartbeat if telemetry is armed and `steps_done` lands on the
/// cadence (or `force` is set — segment start emits one unconditionally
/// so the watchdog arms for this rank before step 0 can hang). `read`
/// is only called when a beat is actually due: cumulative phase seconds
/// and comm counters, from which this rank's deltas are computed.
///
/// Best-effort by design: a beat that cannot be sent (supervisor gone,
/// socket pressure) is dropped silently — telemetry must never be able
/// to fail a healthy run.
pub fn maybe_beat(
    steps_done: u64,
    boundaries: u8,
    force: bool,
    read: impl FnOnce() -> ([f64; 7], CounterSnapshot),
) {
    let Some(child) = CHILD.get() else { return };
    if !force && steps_done % child.every != 0 {
        return;
    }
    let (phase, comm) = read();
    let frame = {
        let mut st = child.state.lock().unwrap();
        let mut phase_delta = [0.0; 7];
        for (d, (now, prev)) in phase_delta.iter_mut().zip(phase.iter().zip(&st.prev_phase)) {
            *d = (now - prev).max(0.0);
        }
        let comm_delta = comm.since(&st.prev_comm);
        st.prev_phase = phase;
        st.prev_comm = comm;
        HealthFrame {
            rank: child.rank,
            step: steps_done,
            boundaries,
            phase_delta,
            comm_delta,
            rss_bytes: rss_estimate(),
        }
    };
    #[cfg(unix)]
    send_beat(child, &frame);
    #[cfg(not(unix))]
    let _ = frame;
}

#[cfg(unix)]
fn send_beat(child: &ChildTelemetry, frame: &HealthFrame) {
    use crate::comm::beat_wire;
    if let Ok(stream) = std::os::unix::net::UnixStream::connect(&child.ctl) {
        let mut framed = Vec::with_capacity(4 + HEALTH_FRAME_LEN);
        put_u32(&mut framed, frame.rank);
        framed.extend_from_slice(&frame.encode());
        let _ = beat_wire(&stream, &framed);
    }
}

/// Resident-set estimate from `/proc/self/statm` (pages → bytes).
/// Returns 0 on platforms without it — the field is best-effort.
pub fn rss_estimate() -> u64 {
    let Ok(text) = std::fs::read_to_string("/proc/self/statm") else { return 0 };
    let rss_pages: u64 = text
        .split_whitespace()
        .nth(1)
        .and_then(|f| f.parse().ok())
        .unwrap_or(0);
    rss_pages * 4096
}

// -- supervisor side (status aggregation) --------------------------------

/// Per-rank accumulation of the heartbeat stream.
#[derive(Clone, Debug, Default)]
struct RankStat {
    seen: bool,
    step: u64,
    beats: u64,
    boundaries: u8,
    /// Cumulative busy seconds (sum of all phase deltas received).
    busy_seconds: f64,
    /// Busy seconds of the most recent beat window (imbalance input).
    window_seconds: f64,
    /// Per-phase cumulative seconds, `ALL_PHASES` order.
    phase_seconds: [f64; 7],
    /// Accumulated comm deltas.
    comm: CounterSnapshot,
    rss_bytes: u64,
}

/// Folds [`HealthFrame`]s into an atomically rewritten `status.json`
/// under the status directory. The supervisor drives it: one `on_beat`
/// per heartbeat, one `set_state` per lifecycle transition.
pub struct StatusWriter {
    path: PathBuf,
    every: u64,
    watchdog_misses: u32,
    state: String,
    attempt: u32,
    recoveries: u32,
    ranks: Vec<RankStat>,
}

impl StatusWriter {
    /// `dir` must exist; the status file is `dir/status.json`.
    pub fn new(dir: &Path, ranks: usize, every: u64, watchdog_misses: u32) -> StatusWriter {
        StatusWriter {
            path: dir.join("status.json"),
            every,
            watchdog_misses,
            state: "starting".to_string(),
            attempt: 0,
            recoveries: 0,
            ranks: vec![RankStat::default(); ranks],
        }
    }

    /// Record a lifecycle transition and rewrite the file.
    pub fn set_state(&mut self, state: &str, attempt: u32, recoveries: u32) {
        self.state = state.to_string();
        self.attempt = attempt;
        self.recoveries = recoveries;
        self.write();
    }

    /// Fold one heartbeat in and rewrite the file.
    pub fn on_beat(&mut self, frame: &HealthFrame) {
        let Some(r) = self.ranks.get_mut(frame.rank as usize) else { return };
        r.seen = true;
        r.step = frame.step;
        r.beats += 1;
        r.boundaries = frame.boundaries;
        let window: f64 = frame.phase_delta.iter().sum();
        r.busy_seconds += window;
        r.window_seconds = window;
        for (acc, d) in r.phase_seconds.iter_mut().zip(&frame.phase_delta) {
            *acc += d;
        }
        r.comm = r.comm.merge(&frame.comm_delta);
        r.rss_bytes = frame.rss_bytes;
        self.write();
    }

    /// Max-over-mean of the latest beat window's busy seconds across
    /// ranks — 1.0 is a perfectly balanced fleet (paper §V-B's imbalance
    /// notion, live). 0.0 until every rank has beaten at least once.
    pub fn imbalance(&self) -> f64 {
        let windows: Vec<f64> =
            self.ranks.iter().filter(|r| r.seen).map(|r| r.window_seconds).collect();
        if windows.len() < self.ranks.len() || windows.is_empty() {
            return 0.0;
        }
        let mean = windows.iter().sum::<f64>() / windows.len() as f64;
        if mean <= 0.0 {
            return 0.0;
        }
        windows.iter().cloned().fold(0.0, f64::max) / mean
    }

    fn to_json(&self) -> Json {
        let seen: Vec<&RankStat> = self.ranks.iter().filter(|r| r.seen).collect();
        let min_step = seen.iter().map(|r| r.step).min().unwrap_or(0);
        let max_step = seen.iter().map(|r| r.step).max().unwrap_or(0);
        let watchdog = if self.watchdog_misses > 0 { "armed" } else { "off" };
        let ranks: Vec<Json> = self
            .ranks
            .iter()
            .enumerate()
            .map(|(rank, r)| {
                let c = r.comm;
                obj(vec![
                    ("rank", Json::Num(rank as f64)),
                    ("seen", Json::Bool(r.seen)),
                    ("step", Json::Num(r.step as f64)),
                    ("beats", Json::Num(r.beats as f64)),
                    ("busy_seconds", Json::Num(r.busy_seconds)),
                    ("window_seconds", Json::Num(r.window_seconds)),
                    (
                        "phase_seconds",
                        Json::Arr(r.phase_seconds.iter().map(|&s| Json::Num(s)).collect()),
                    ),
                    (
                        "boundaries",
                        Json::Arr(
                            boundary_names(r.boundaries)
                                .into_iter()
                                .map(|n| Json::Str(n.to_string()))
                                .collect(),
                        ),
                    ),
                    ("bytes_sent", Json::Num(c.bytes_sent as f64)),
                    ("bytes_rma", Json::Num(c.bytes_rma as f64)),
                    ("collectives", Json::Num(c.collectives as f64)),
                    ("rma_gets", Json::Num(c.rma_gets as f64)),
                    ("rss_bytes", Json::Num(r.rss_bytes as f64)),
                ])
            })
            .collect();
        obj(vec![
            ("schema_version", Json::Num(STATUS_SCHEMA_VERSION as f64)),
            ("state", Json::Str(self.state.clone())),
            ("attempt", Json::Num(f64::from(self.attempt))),
            ("recoveries", Json::Num(f64::from(self.recoveries))),
            ("watchdog", Json::Str(watchdog.to_string())),
            ("watchdog_misses", Json::Num(f64::from(self.watchdog_misses))),
            ("telemetry_every", Json::Num(self.every as f64)),
            (
                "fleet",
                obj(vec![
                    ("min_step", Json::Num(min_step as f64)),
                    ("max_step", Json::Num(max_step as f64)),
                    ("imbalance", Json::Num(self.imbalance())),
                ]),
            ),
            ("ranks", Json::Arr(ranks)),
        ])
    }

    /// Atomic rewrite: write a temp file in the same directory, then
    /// rename over `status.json` — a concurrent `ilmi status` never
    /// sees a torn file. Failures are swallowed (observability must not
    /// fail the run).
    pub fn write(&self) {
        let tmp = self.path.with_extension("json.tmp");
        if std::fs::write(&tmp, self.to_json().pretty()).is_ok() {
            let _ = std::fs::rename(&tmp, &self.path);
        }
    }
}

// -- `ilmi status` rendering ---------------------------------------------

/// Read `<dir>/status.json` and render the table `ilmi status` prints.
pub fn render_status(dir: &Path) -> Result<String, String> {
    let path = dir.join("status.json");
    let text = std::fs::read_to_string(&path)
        .map_err(|e| format!("reading {}: {e} (is --status-dir pointed here?)", path.display()))?;
    let v = parse(&text).map_err(|e| format!("parsing {}: {e}", path.display()))?;
    let schema = v.req("schema_version")?.as_u64()?;
    if schema != STATUS_SCHEMA_VERSION {
        return Err(format!(
            "status schema v{schema} unsupported (this build reads v{STATUS_SCHEMA_VERSION})"
        ));
    }
    let fleet = v.req("fleet")?;
    let mut out = String::new();
    out.push_str(&format!(
        "state {}  attempt {}  recoveries {}  watchdog {} (misses={})  every {} steps\n",
        v.req("state")?.as_str()?,
        v.req("attempt")?.as_u64()?,
        v.req("recoveries")?.as_u64()?,
        v.req("watchdog")?.as_str()?,
        v.req("watchdog_misses")?.as_u64()?,
        v.req("telemetry_every")?.as_u64()?,
    ));
    out.push_str(&format!(
        "fleet step {}..{}  imbalance {:.2}\n",
        fleet.req("min_step")?.as_u64()?,
        fleet.req("max_step")?.as_u64()?,
        fleet.req("imbalance")?.as_f64()?,
    ));
    out.push_str(&format!(
        "{:>4} {:>8} {:>6} {:>10} {:>10} {:>12} {:>9} {:>8}  {}\n",
        "rank", "step", "beats", "busy(s)", "window(s)", "bytes_sent", "rma_gets", "rss(MB)", "boundary"
    ));
    for r in v.req("ranks")?.as_arr()? {
        let names: Vec<String> = r
            .req("boundaries")?
            .as_arr()?
            .iter()
            .map(|n| n.as_str().map(str::to_string))
            .collect::<Result<_, _>>()?;
        let seen = r.req("seen")?.as_bool()?;
        out.push_str(&format!(
            "{:>4} {:>8} {:>6} {:>10.3} {:>10.3} {:>12} {:>9} {:>8.1}  {}\n",
            r.req("rank")?.as_u64()?,
            if seen { r.req("step")?.as_u64()?.to_string() } else { "-".to_string() },
            r.req("beats")?.as_u64()?,
            r.req("busy_seconds")?.as_f64()?,
            r.req("window_seconds")?.as_f64()?,
            r.req("bytes_sent")?.as_u64()?,
            r.req("rma_gets")?.as_u64()?,
            r.req("rss_bytes")?.as_f64()? / (1024.0 * 1024.0),
            if names.is_empty() { "-".to_string() } else { names.join("+") },
        ));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame(rank: u32, step: u64) -> HealthFrame {
        HealthFrame {
            rank,
            step,
            boundaries: crate::trace::SPIKE_EPOCH | crate::trace::PLASTICITY_EPOCH,
            phase_delta: [0.5, 0.0, 1.0, 0.0, 0.25, 0.125, 0.0],
            comm_delta: CounterSnapshot {
                bytes_sent: 1000,
                bytes_recv: 900,
                bytes_rma: 64,
                msgs_sent: 10,
                collectives: 5,
                rma_gets: 2,
            },
            rss_bytes: 8 << 20,
        }
    }

    #[test]
    fn health_frame_roundtrips_at_fixed_length() {
        let f = frame(3, 120);
        let bytes = f.encode();
        assert_eq!(bytes.len(), HEALTH_FRAME_LEN);
        assert_eq!(HealthFrame::decode(&bytes).unwrap(), f);
        // Truncation and trailing bytes both fail loudly.
        assert!(HealthFrame::decode(&bytes[..bytes.len() - 1]).is_err());
        let mut long = bytes.clone();
        long.push(0);
        assert!(HealthFrame::decode(&long).is_err());
    }

    #[test]
    fn status_writer_aggregates_and_rewrites_atomically() {
        let dir = std::env::temp_dir().join(format!("ilmi_status_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let mut w = StatusWriter::new(&dir, 2, 10, 3);
        w.set_state("running", 0, 0);
        w.on_beat(&frame(0, 10));
        assert_eq!(w.imbalance(), 0.0, "rank 1 has not beaten yet");
        w.on_beat(&frame(1, 10));
        w.on_beat(&frame(0, 20));
        assert!(w.imbalance() >= 1.0);
        let rendered = render_status(&dir).unwrap();
        assert!(rendered.contains("state running"), "{rendered}");
        assert!(rendered.contains("watchdog armed"), "{rendered}");
        assert!(rendered.contains("spike+plasticity"), "{rendered}");
        // Fleet min/max straddle the two ranks' steps.
        assert!(rendered.contains("fleet step 10..20"), "{rendered}");
        // No torn temp file left behind.
        assert!(!dir.join("status.json.tmp").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn render_rejects_missing_and_foreign_schemas() {
        let dir = std::env::temp_dir().join(format!("ilmi_status_bad_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        assert!(render_status(&dir).unwrap_err().contains("status-dir"));
        std::fs::write(dir.join("status.json"), "{\"schema_version\": 99}").unwrap();
        assert!(render_status(&dir).unwrap_err().contains("v99"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn unarmed_beat_hook_is_pass_through() {
        // The suite shares one process; nothing arms telemetry in unit
        // tests, so the reader closure must never run.
        maybe_beat(10, 0, true, || panic!("read closure ran while unarmed"));
    }

    #[test]
    fn rss_estimate_is_nonzero_on_linux() {
        if std::path::Path::new("/proc/self/statm").exists() {
            assert!(rss_estimate() > 0);
        }
    }
}
