//! Local Barnes–Hut target selection (no communication).
//!
//! The probabilistic descent of the MSP-adapted Barnes–Hut algorithm
//! (paper §III-B0c): starting from a node, rejected nodes are replaced by
//! their children, accepted nodes (and leaves) become candidates, one
//! candidate is sampled with probability ∝ vacancy · exp(−d²/σ²); if it
//! is an inner node the process restarts from it, until an actual neuron
//! is found.
//!
//! Used directly by the owner-side search of the location-aware
//! algorithm (everything below a branch node is local to its owner) and
//! by any search whose path stays on one rank.

use crate::neuron::GlobalNeuronId;
use crate::octree::{ElementKind, Octree, NO_CHILD, NO_NEURON};
use crate::util::{Rng, Vec3};

use super::{accepts_d2, kernel_weight};

/// Search parameters threaded through every selection.
#[derive(Clone, Copy, Debug)]
pub struct SelectParams {
    pub theta: f64,
    pub sigma: f64,
    /// Searching neuron (excluded as its own target).
    pub exclude: GlobalNeuronId,
    pub kind: ElementKind,
}

/// Reusable scratch buffers — the descent runs once per vacant axonal
/// element, so allocation here is hot (see EXPERIMENTS.md §Perf).
#[derive(Default)]
pub struct SelectScratch {
    stack: Vec<usize>,
    cand_nodes: Vec<usize>,
    cand_weights: Vec<f64>,
}

/// Select a target neuron by descending *locally* from `start`
/// (inclusive of its subtree only). Returns `None` when no admissible
/// candidate exists (e.g. all vacancy is the excluded neuron's).
pub fn select_local(
    tree: &Octree,
    start: usize,
    src_pos: &Vec3,
    params: &SelectParams,
    scratch: &mut SelectScratch,
    rng: &mut Rng,
) -> Option<GlobalNeuronId> {
    let mut at = start;
    loop {
        scratch.cand_nodes.clear();
        scratch.cand_weights.clear();
        scratch.stack.clear();

        // The start node itself is always "rejected": expand children.
        // A start that is already a leaf is the candidate itself.
        if tree.nodes[at].is_leaf() {
            scratch.stack.push(at);
        } else {
            for &c in &tree.nodes[at].children {
                if c != NO_CHILD {
                    scratch.stack.push(c as usize);
                }
            }
        }

        while let Some(i) = scratch.stack.pop() {
            let n = &tree.nodes[i];
            let vac = n.vac(params.kind);
            if vac <= 0.0 {
                continue;
            }
            let d2 = src_pos.dist2(&n.pos(params.kind));
            if n.is_leaf() {
                if n.neuron != params.exclude as i64 && n.neuron != NO_NEURON {
                    scratch.cand_nodes.push(i);
                    scratch.cand_weights.push(kernel_weight(vac, d2, params.sigma));
                }
            } else if accepts_d2(n.side, d2, params.theta) {
                scratch.cand_nodes.push(i);
                scratch.cand_weights.push(kernel_weight(vac, d2, params.sigma));
            } else {
                for &c in &n.children {
                    if c != NO_CHILD {
                        scratch.stack.push(c as usize);
                    }
                }
            }
        }

        let pick = rng.weighted_choice(&scratch.cand_weights)?;
        let node = scratch.cand_nodes[pick];
        if tree.nodes[node].is_leaf() {
            return Some(tree.nodes[node].neuron as GlobalNeuronId);
        }
        // Inner node selected: restart the whole process from it
        // (paper: "the entire process restarts with the target node").
        at = node;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::octree::DomainDecomposition;

    fn build(positions: &[Vec3], vac: &[f32]) -> Octree {
        let decomp = DomainDecomposition::new(1, 100.0);
        let mut tree = Octree::build(&decomp, 0, 0, positions);
        tree.reset_and_set_leaves(0, vac, vac);
        tree.aggregate_local();
        tree.aggregate_upper();
        tree.normalize();
        tree
    }

    fn params(exclude: u64) -> SelectParams {
        SelectParams {
            theta: 0.3,
            sigma: 750.0,
            exclude,
            kind: ElementKind::Excitatory,
        }
    }

    #[test]
    fn finds_the_only_candidate() {
        let positions =
            vec![Vec3::new(10.0, 10.0, 10.0), Vec3::new(90.0, 90.0, 90.0)];
        let tree = build(&positions, &[1.0, 1.0]);
        let mut rng = Rng::new(1);
        let mut scratch = SelectScratch::default();
        // Searching from neuron 0 must find neuron 1.
        let got = select_local(
            &tree,
            tree.root(),
            &positions[0],
            &params(0),
            &mut scratch,
            &mut rng,
        );
        assert_eq!(got, Some(1));
    }

    #[test]
    fn excludes_self_even_when_alone() {
        let positions = vec![Vec3::new(10.0, 10.0, 10.0)];
        let tree = build(&positions, &[1.0]);
        let mut rng = Rng::new(2);
        let mut scratch = SelectScratch::default();
        let got = select_local(
            &tree,
            tree.root(),
            &positions[0],
            &params(0),
            &mut scratch,
            &mut rng,
        );
        assert_eq!(got, None);
    }

    #[test]
    fn zero_vacancy_is_never_selected() {
        let positions =
            vec![Vec3::new(10.0, 10.0, 10.0), Vec3::new(50.0, 50.0, 50.0), Vec3::new(90.0, 90.0, 90.0)];
        let tree = build(&positions, &[1.0, 0.0, 1.0]);
        let mut rng = Rng::new(3);
        let mut scratch = SelectScratch::default();
        for _ in 0..50 {
            let got = select_local(
                &tree,
                tree.root(),
                &positions[0],
                &params(0),
                &mut scratch,
                &mut rng,
            );
            assert_eq!(got, Some(2), "vacancy-0 neuron 1 must never be chosen");
        }
    }

    #[test]
    fn returns_none_when_no_vacancy_at_all() {
        let positions = vec![Vec3::new(10.0, 10.0, 10.0), Vec3::new(90.0, 90.0, 90.0)];
        let tree = build(&positions, &[0.0, 0.0]);
        let mut rng = Rng::new(4);
        let mut scratch = SelectScratch::default();
        assert_eq!(
            select_local(&tree, tree.root(), &positions[0], &params(0), &mut scratch, &mut rng),
            None
        );
    }

    #[test]
    fn closer_targets_preferred_with_small_sigma() {
        // Neuron 0 searches; neuron 1 is near, neuron 2 far. With a
        // small sigma the near one should dominate.
        let positions = vec![
            Vec3::new(10.0, 10.0, 10.0),
            Vec3::new(15.0, 10.0, 10.0),
            Vec3::new(95.0, 95.0, 95.0),
        ];
        let tree = build(&positions, &[1.0, 1.0, 1.0]);
        let mut rng = Rng::new(5);
        let mut scratch = SelectScratch::default();
        let mut p = params(0);
        p.sigma = 20.0;
        let mut near = 0;
        for _ in 0..200 {
            match select_local(&tree, tree.root(), &positions[0], &p, &mut scratch, &mut rng) {
                Some(1) => near += 1,
                Some(2) => {}
                other => panic!("unexpected {other:?}"),
            }
        }
        assert!(near > 190, "near target chosen {near}/200");
    }

    #[test]
    fn theta_zero_is_exact_and_still_terminates() {
        let mut rng = Rng::new(6);
        let positions: Vec<Vec3> = (0..50)
            .map(|_| {
                Vec3::new(
                    rng.uniform(0.0, 100.0),
                    rng.uniform(0.0, 100.0),
                    rng.uniform(0.0, 100.0),
                )
            })
            .collect();
        let vac = vec![1.0f32; 50];
        let tree = build(&positions, &vac);
        let mut scratch = SelectScratch::default();
        let mut p = params(0);
        p.theta = 0.0; // never approximate: all candidates are leaves
        let got =
            select_local(&tree, tree.root(), &positions[0], &p, &mut scratch, &mut rng);
        assert!(matches!(got, Some(id) if id != 0 && id < 50));
    }
}
