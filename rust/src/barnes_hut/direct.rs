//! Direct O(n²) synapse formation — the NEST-style baseline (paper §II:
//! NEST "incorporates MSP with a time complexity of O(n²)").
//!
//! Every rank gathers (id, position, vacancies) of all neurons, then
//! evaluates the full Gaussian probability row for each searching axon —
//! exactly the computation the L1 `gauss_probs` Pallas kernel performs,
//! and the oracle the Barnes–Hut variants approximate. Used as a
//! baseline in benches and as the reference distribution in tests.

use crate::comm::{gather_all, Comm};
use crate::config::SimConfig;
use crate::neuron::{GlobalNeuronId, Population};
use crate::plasticity::{vacant, SynapseStore};
use crate::util::wire::{get_f32, get_u64, put_f32, put_u64, Wire};
use crate::util::{Rng, Vec3};

use super::{axon_kind, kernel_weight, old_request_roundtrip, FormationStats, OldRequest};
use crate::octree::ElementKind;

/// Per-neuron record gathered by every rank (28 B).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DirectRecord {
    pub id: GlobalNeuronId,
    pub pos: [f32; 3],
    pub vac_exc: f32,
    pub vac_inh: f32,
}

impl Wire for DirectRecord {
    const SIZE: usize = 8 + 12 + 4 + 4;
    fn write(&self, out: &mut Vec<u8>) {
        put_u64(out, self.id);
        for v in self.pos {
            put_f32(out, v);
        }
        put_f32(out, self.vac_exc);
        put_f32(out, self.vac_inh);
    }
    fn read(buf: &[u8]) -> Self {
        DirectRecord {
            id: get_u64(buf, 0),
            pos: [get_f32(buf, 8), get_f32(buf, 12), get_f32(buf, 16)],
            vac_exc: get_f32(buf, 20),
            vac_inh: get_f32(buf, 24),
        }
    }
}

/// Gather the global candidate table (only neurons with any vacant
/// dendritic element; others can never be chosen).
pub fn gather_candidates(
    comm: &impl Comm,
    pop: &Population,
    store: &SynapseStore,
) -> Vec<DirectRecord> {
    let mine: Vec<DirectRecord> = (0..pop.len())
        .filter_map(|i| {
            let ve = vacant(pop.z_den_exc[i], store.connected_den_exc[i]) as f32;
            let vi = vacant(pop.z_den_inh[i], store.connected_den_inh[i]) as f32;
            if ve == 0.0 && vi == 0.0 {
                return None;
            }
            let p = pop.positions[i];
            Some(DirectRecord {
                id: pop.global_id(i),
                pos: [p.x as f32, p.y as f32, p.z as f32],
                vac_exc: ve,
                vac_inh: vi,
            })
        })
        .collect();
    gather_all(comm, &mine).into_iter().flatten().collect()
}

/// Sample one target for a source at `src_pos` from the full candidate
/// table — the exact distribution Barnes–Hut approximates.
pub fn sample_direct(
    records: &[DirectRecord],
    src_id: GlobalNeuronId,
    src_pos: &Vec3,
    kind: ElementKind,
    sigma: f64,
    weights_scratch: &mut Vec<f64>,
    rng: &mut Rng,
) -> Option<GlobalNeuronId> {
    weights_scratch.clear();
    weights_scratch.reserve(records.len());
    for r in records {
        let vac = match kind {
            ElementKind::Excitatory => r.vac_exc,
            ElementKind::Inhibitory => r.vac_inh,
        };
        let w = if r.id == src_id {
            0.0
        } else {
            let p = Vec3::new(r.pos[0] as f64, r.pos[1] as f64, r.pos[2] as f64);
            kernel_weight(vac, src_pos.dist2(&p), sigma)
        };
        weights_scratch.push(w);
    }
    rng.weighted_choice(weights_scratch).map(|k| records[k].id)
}

/// Full formation phase, direct algorithm. `owners` routes each chosen
/// target id to its owning rank.
pub fn run_formation(
    comm: &impl Comm,
    pop: &Population,
    store: &mut SynapseStore,
    cfg: &SimConfig,
    owners: &crate::balance::OwnershipMap,
    rng: &mut Rng,
) -> FormationStats {
    let mut stats = FormationStats::default();
    let t_gather = std::time::Instant::now();
    let records = gather_candidates(comm, pop, store);
    stats.exchange_nanos += t_gather.elapsed().as_nanos() as u64;
    let mut requests: Vec<Vec<OldRequest>> = vec![Vec::new(); comm.size()];
    let mut weights = Vec::new();

    let t_sample = std::time::Instant::now();
    for local in 0..pop.len() {
        let kind = axon_kind(pop.is_excitatory[local]);
        let n_vacant = vacant(pop.z_ax[local], store.connected_ax[local]);
        let src_id = pop.global_id(local);
        let src_pos = pop.positions[local];
        for _ in 0..n_vacant {
            stats.searches += 1;
            match sample_direct(&records, src_id, &src_pos, kind, cfg.sigma, &mut weights, rng) {
                Some(target) => requests[owners.rank_of(target) as usize].push(OldRequest {
                    source: src_id,
                    target,
                    source_exc: pop.is_excitatory[local],
                }),
                None => stats.failed_searches += 1,
            }
        }
    }

    stats.compute_nanos += t_sample.elapsed().as_nanos() as u64;
    let rt = old_request_roundtrip(comm, requests, pop, store, rng);
    stats.merge(&rt)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(id: u64, x: f32, ve: f32) -> DirectRecord {
        DirectRecord { id, pos: [x, 0.0, 0.0], vac_exc: ve, vac_inh: 0.0 }
    }

    #[test]
    fn record_roundtrip_is_28_bytes() {
        assert_eq!(DirectRecord::SIZE, 28);
        let r = rec(7, 1.5, 2.0);
        let mut buf = Vec::new();
        r.write(&mut buf);
        assert_eq!(buf.len(), 28);
        assert_eq!(DirectRecord::read(&buf), r);
    }

    #[test]
    fn sampling_excludes_self_and_zero_vacancy() {
        let records = vec![rec(0, 0.0, 1.0), rec(1, 1.0, 0.0), rec(2, 2.0, 1.0)];
        let mut rng = Rng::new(1);
        let mut w = Vec::new();
        for _ in 0..100 {
            let got = sample_direct(
                &records,
                0,
                &Vec3::ZERO,
                ElementKind::Excitatory,
                100.0,
                &mut w,
                &mut rng,
            );
            assert_eq!(got, Some(2)); // not self (0), not vacancy-0 (1)
        }
    }

    #[test]
    fn sampling_matches_kernel_ratio() {
        // Two candidates at distances 10 and 20 with sigma 20:
        // ratio = exp(-100/400) / exp(-400/400) ≈ e^{0.75}.
        let records = vec![rec(1, 10.0, 1.0), rec(2, 20.0, 1.0)];
        let mut rng = Rng::new(2);
        let mut w = Vec::new();
        let mut near = 0usize;
        let n = 200_000;
        for _ in 0..n {
            if sample_direct(
                &records,
                0,
                &Vec3::ZERO,
                ElementKind::Excitatory,
                20.0,
                &mut w,
                &mut rng,
            ) == Some(1)
            {
                near += 1;
            }
        }
        let p_near = near as f64 / n as f64;
        let w1 = (-100.0f64 / 400.0).exp();
        let w2 = (-400.0f64 / 400.0).exp();
        let expect = w1 / (w1 + w2);
        assert!((p_near - expect).abs() < 0.01, "{p_near} vs {expect}");
    }

    #[test]
    fn none_when_no_candidates() {
        let records = vec![rec(0, 0.0, 1.0)];
        let mut rng = Rng::new(3);
        let mut w = Vec::new();
        assert_eq!(
            sample_direct(&records, 0, &Vec3::ZERO, ElementKind::Excitatory, 10.0, &mut w, &mut rng),
            None
        );
    }
}
