//! The *old* distributed Barnes–Hut algorithm (Rinke et al. 2018,
//! paper §III-B0c): the searching rank performs the entire descent
//! itself; whenever the path drops below the branch level into another
//! rank's subtree, the needed octree nodes are downloaded via RMA and
//! cached for the remainder of the formation phase.
//!
//! Per-neuron communication is O(log n) node downloads — the baseline
//! the location-aware algorithm (`new.rs`) eliminates.

use crate::comm::Comm;
use crate::config::SimConfig;
use crate::neuron::{GlobalNeuronId, Population};
use crate::octree::{
    ElementKind, NodeKind, Octree, RemoteNodeCache, WireNode, NO_CHILD, NO_NEURON,
};
use crate::plasticity::{vacant, SynapseStore};
use crate::util::{Rng, Vec3};

use super::{accepts_d2, axon_kind, kernel_weight, old_request_roundtrip, FormationStats, OldRequest};

/// Handle onto a node that may live in the local arena or in another
/// rank's published window.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum H {
    Local(usize),
    Remote { rank: u32, idx: i32 },
}

/// Node attributes the descent needs, resolved from either side.
struct Info {
    vac: f32,
    pos: Vec3,
    side: f64,
    is_leaf: bool,
    neuron: i64,
}

/// The old algorithm's tree view: local arena + RMA downloads.
pub struct OldView<'a, C: Comm> {
    pub tree: &'a Octree,
    pub cache: &'a mut RemoteNodeCache,
    pub comm: &'a C,
}

impl<'a, C: Comm> OldView<'a, C> {
    fn info(&mut self, h: H, kind: ElementKind) -> Info {
        match h {
            H::Local(i) => {
                let n = &self.tree.nodes[i];
                Info {
                    vac: n.vac(kind),
                    pos: n.pos(kind),
                    side: n.side,
                    is_leaf: n.is_leaf() && !self.is_expandable_remote_branch(i),
                    neuron: n.neuron,
                }
            }
            H::Remote { rank, idx } => {
                let w: WireNode = self.cache.get(self.comm, rank, idx);
                Info {
                    vac: w.vac(kind),
                    pos: w.pos(kind),
                    side: w.side as f64,
                    is_leaf: w.is_leaf,
                    neuron: w.neuron,
                }
            }
        }
    }

    /// A branch node of a remote cell with a non-empty subtree: locally
    /// childless, but expandable through the owner's window.
    fn is_expandable_remote_branch(&self, i: usize) -> bool {
        let n = &self.tree.nodes[i];
        n.kind == NodeKind::Branch
            && n.owner != self.tree.rank
            && n.window_root != NO_CHILD
            && n.neuron == NO_NEURON
    }

    fn push_children(&mut self, h: H, out: &mut Vec<H>) {
        match h {
            H::Local(i) => {
                if self.is_expandable_remote_branch(i) {
                    // Cross into the owner's subtree: download the
                    // window root to learn its children ("download the
                    // red nodes", paper Fig. 2).
                    let n = &self.tree.nodes[i];
                    let rank = n.owner;
                    let root: WireNode = self.cache.get(self.comm, rank, n.window_root);
                    for &c in &root.children {
                        if c != NO_CHILD {
                            out.push(H::Remote { rank, idx: c });
                        }
                    }
                } else {
                    for &c in &self.tree.nodes[i].children {
                        if c != NO_CHILD {
                            out.push(H::Local(c as usize));
                        }
                    }
                }
            }
            H::Remote { rank, idx } => {
                let w: WireNode = self.cache.get(self.comm, rank, idx);
                for &c in &w.children {
                    if c != NO_CHILD {
                        out.push(H::Remote { rank, idx: c });
                    }
                }
            }
        }
    }
}

/// One full old-style target search from the root. Downloads remote
/// nodes as needed; returns the found neuron or None.
pub fn search_old<C: Comm>(
    view: &mut OldView<'_, C>,
    src_id: GlobalNeuronId,
    src_pos: &Vec3,
    kind: ElementKind,
    theta: f64,
    sigma: f64,
    rng: &mut Rng,
) -> Option<GlobalNeuronId> {
    let mut start = H::Local(view.tree.root());
    let mut stack: Vec<H> = Vec::new();
    // Candidate handle + (is_leaf, neuron) so the chosen one needs no
    // second resolution (EXPERIMENTS.md §Perf, opt 3).
    let mut cand: Vec<(H, bool, i64)> = Vec::new();
    let mut weights: Vec<f64> = Vec::new();
    loop {
        stack.clear();
        cand.clear();
        weights.clear();

        let start_info = view.info(start, kind);
        if start_info.is_leaf {
            stack.push(start);
        } else {
            view.push_children(start, &mut stack);
        }

        while let Some(h) = stack.pop() {
            let info = view.info(h, kind);
            if info.vac <= 0.0 {
                continue;
            }
            let d2 = src_pos.dist2(&info.pos);
            if info.is_leaf {
                if info.neuron != NO_NEURON && info.neuron != src_id as i64 {
                    cand.push((h, true, info.neuron));
                    weights.push(kernel_weight(info.vac, d2, sigma));
                }
            } else if accepts_d2(info.side, d2, theta) {
                cand.push((h, false, NO_NEURON));
                weights.push(kernel_weight(info.vac, d2, sigma));
            } else {
                view.push_children(h, &mut stack);
            }
        }

        let pick = rng.weighted_choice(&weights)?;
        let (chosen, is_leaf, neuron) = cand[pick];
        if is_leaf {
            return Some(neuron as GlobalNeuronId);
        }
        start = chosen;
    }
}

/// Full formation phase, old algorithm: every vacant axonal element
/// searches (with RMA downloads), then one request/response round-trip.
/// `owners` routes each found target id to its owning rank.
#[allow(clippy::too_many_arguments)]
pub fn run_formation(
    comm: &impl Comm,
    tree: &Octree,
    pop: &Population,
    store: &mut SynapseStore,
    cache: &mut RemoteNodeCache,
    cfg: &SimConfig,
    owners: &crate::balance::OwnershipMap,
    rng: &mut Rng,
) -> FormationStats {
    let mut stats = FormationStats::default();
    let mut requests: Vec<Vec<OldRequest>> = vec![Vec::new(); comm.size()];

    let t_search = std::time::Instant::now();
    for local in 0..pop.len() {
        let kind = axon_kind(pop.is_excitatory[local]);
        let n_vacant = vacant(pop.z_ax[local], store.connected_ax[local]);
        let src_id = pop.global_id(local);
        let src_pos = pop.positions[local];
        for _ in 0..n_vacant {
            stats.searches += 1;
            let mut view = OldView { tree, cache, comm };
            match search_old(&mut view, src_id, &src_pos, kind, cfg.theta, cfg.sigma, rng) {
                Some(target) => {
                    let owner = owners.rank_of(target) as usize;
                    requests[owner].push(OldRequest {
                        source: src_id,
                        target,
                        source_exc: pop.is_excitatory[local],
                    });
                }
                None => stats.failed_searches += 1,
            }
        }
    }

    stats.compute_nanos += t_search.elapsed().as_nanos() as u64;

    let rt = old_request_roundtrip(comm, requests, pop, store, rng);
    // Downloaded nodes are only valid for this formation phase.
    cache.clear();
    stats.merge(&rt)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::run_ranks;
    use crate::octree::{serialize_local_subtrees, DomainDecomposition, OCTREE_WINDOW};

    /// Two ranks, two neurons each (so remote branch cells are NOT
    /// leaves): the old search from rank 0 must cross into rank 1's
    /// subtree via RMA to resolve an actual neuron.
    #[test]
    fn cross_rank_search_downloads_and_finds() {
        let results = run_ranks(2, |comm| {
            let decomp = DomainDecomposition::new(2, 100.0);
            let rank = comm.rank();
            // Two neurons inside the rank's first cell.
            let (lo, hi) = decomp.cell_bounds(decomp.cells_of_rank(rank).start);
            let mid = (lo + hi) / 2.0;
            let positions =
                vec![(lo * 3.0 + hi) / 4.0, (lo + hi * 3.0) / 4.0];
            let pos = mid;
            let first_id = 2 * rank as u64;
            let mut tree = Octree::build(&decomp, rank, first_id, &positions);
            tree.reset_and_set_leaves(first_id, &[1.0, 1.0], &[1.0, 1.0]);
            tree.aggregate_local();
            let win = serialize_local_subtrees(&tree, decomp.cells_of_rank(rank));
            comm.publish_window(OCTREE_WINDOW, win.bytes);
            comm.barrier();
            let payloads = tree.own_branch_payloads(decomp.cells_of_rank(rank), |c| {
                win.root_of_cell[&c]
            });
            let all = crate::comm::gather_all(&comm, &payloads);
            for (src, batch) in all.iter().enumerate() {
                if src != rank {
                    tree.apply_branch_payloads(batch);
                }
            }
            tree.aggregate_upper();
            tree.normalize();

            let mut cache = RemoteNodeCache::default();
            let mut rng = Rng::new(rank as u64 + 10);
            let mut found = Vec::new();
            for _ in 0..20 {
                let mut view =
                    OldView { tree: &tree, cache: &mut cache, comm: &comm };
                let got = search_old(
                    &mut view,
                    first_id,
                    &pos,
                    ElementKind::Excitatory,
                    0.3,
                    750.0,
                    &mut rng,
                );
                found.push(got.expect("candidates exist"));
            }
            let rma = comm.counters().snapshot().bytes_rma;
            comm.barrier();
            (found, rma, first_id)
        });
        for (rank, (found, rma, first_id)) in results.iter().enumerate() {
            // Never the searching neuron itself; all ids valid.
            assert!(found.iter().all(|&id| id != *first_id && id < 4));
            // Some searches must land on the remote rank (2 of 3
            // admissible candidates are remote) and resolving them
            // requires RMA downloads.
            let remote_lo = 2 * (1 - rank as u64);
            assert!(
                found.iter().any(|&id| id == remote_lo || id == remote_lo + 1),
                "rank {rank}: no remote target in {found:?}"
            );
            assert!(*rma > 0, "old search must use RMA");
        }
    }

}
