//! The *new* location-aware Barnes–Hut algorithm (paper §IV-A,
//! Algorithm 1): "move the computation, not the data".
//!
//! The source rank descends only through what it already holds — the
//! shared upper tree and its own subtrees. A remote branch node is a
//! terminal: instead of downloading the subtree below it, the rank sends
//! a 42 B *synapse formation and calculation* request (source id +
//! position + target node + flags) to the owner, which finishes the
//! search locally and answers with a 9 B response (found neuron id +
//! accept/decline). Per-neuron communication drops from O(log n) RMA
//! fetches to O(1) messages.

use crate::comm::{exchange_ref, Comm};
use crate::config::SimConfig;
use crate::neuron::{GlobalNeuronId, Population};
use crate::octree::{ElementKind, NodeKind, Octree, NO_CHILD, NO_NEURON};
use crate::plasticity::{vacant, SynapseStore};
use crate::util::{Rng, Vec3};

use super::select::{select_local, SelectParams, SelectScratch};
use super::{
    accept_proposals, accepts_d2, axon_kind, kernel_weight, FormationStats, NewRequest,
    NewResponse, Proposal, NO_TARGET,
};

/// Result of the source-side descent.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Outcome {
    /// Search bottomed out at an actual neuron (local leaf, or a remote
    /// subdomain known to hold exactly one neuron).
    Leaf { neuron: GlobalNeuronId, owner: u32 },
    /// Search selected a remote branch node: the owner must continue.
    RemoteInner { cell: u32, owner: u32 },
    /// No admissible candidate.
    None,
}

/// Source-side search: descend from the root using only locally-held
/// information. Remote branch nodes are candidates but never expanded.
pub fn search_new(
    tree: &Octree,
    src_id: GlobalNeuronId,
    src_pos: &Vec3,
    kind: ElementKind,
    theta: f64,
    sigma: f64,
    scratch: &mut SelectScratch2,
    rng: &mut Rng,
) -> Outcome {
    let me = tree.rank;
    let mut start = tree.root();
    loop {
        scratch.stack.clear();
        scratch.cand.clear();
        scratch.weights.clear();

        if tree.nodes[start].is_leaf() && !is_remote_branch(tree, start, me) {
            scratch.stack.push(start);
        } else if is_remote_branch(tree, start, me) {
            unreachable!("remote branch nodes are terminals, never restart points");
        } else {
            for &c in &tree.nodes[start].children {
                if c != NO_CHILD {
                    scratch.stack.push(c as usize);
                }
            }
        }

        while let Some(i) = scratch.stack.pop() {
            let n = &tree.nodes[i];
            let vac = n.vac(kind);
            if vac <= 0.0 {
                continue;
            }
            let d2 = src_pos.dist2(&n.pos(kind));
            if is_remote_branch(tree, i, me) {
                // Terminal candidate regardless of the acceptance
                // criterion: if selected, the owner restarts from it.
                scratch.cand.push(i);
                scratch.weights.push(kernel_weight(vac, d2, sigma));
            } else if n.is_leaf() {
                if n.neuron != NO_NEURON && n.neuron != src_id as i64 {
                    scratch.cand.push(i);
                    scratch.weights.push(kernel_weight(vac, d2, sigma));
                }
            } else if accepts_d2(n.side, d2, theta) {
                scratch.cand.push(i);
                scratch.weights.push(kernel_weight(vac, d2, sigma));
            } else {
                for &c in &n.children {
                    if c != NO_CHILD {
                        scratch.stack.push(c as usize);
                    }
                }
            }
        }

        let Some(pick) = rng.weighted_choice(&scratch.weights) else {
            return Outcome::None;
        };
        let i = scratch.cand[pick];
        let n = &tree.nodes[i];
        if is_remote_branch(tree, i, me) {
            if n.neuron != NO_NEURON {
                // The whole remote subdomain is one known neuron: the
                // request can be marked "target is already a leaf".
                return Outcome::Leaf { neuron: n.neuron as GlobalNeuronId, owner: n.owner };
            }
            return Outcome::RemoteInner { cell: n.cell, owner: n.owner };
        }
        if n.is_leaf() {
            return Outcome::Leaf { neuron: n.neuron as GlobalNeuronId, owner: me };
        }
        start = i;
    }
}

fn is_remote_branch(tree: &Octree, i: usize, me: u32) -> bool {
    let n = &tree.nodes[i];
    n.kind == NodeKind::Branch && n.owner != me
}

/// Scratch buffers for `search_new` (hot path: one search per vacant
/// axonal element).
#[derive(Default)]
pub struct SelectScratch2 {
    stack: Vec<usize>,
    cand: Vec<usize>,
    weights: Vec<f64>,
}

/// Reusable per-destination send buffers for the formation phase's two
/// all-to-alls, held by the driver across connectivity updates —
/// EXPERIMENTS.md §Perf, opt 6 applied to the formation path: the
/// request/response `Vec<Vec<_>>` pairs are cleared and refilled, never
/// reallocated, and travel through the borrowing `comm::exchange_ref`
/// exactly like both spike-exchange paths.
#[derive(Default)]
pub struct FormationScratch {
    requests: Vec<Vec<NewRequest>>,
    responses: Vec<Vec<NewResponse>>,
    /// Descent scratch for the source-side searches, hoisted here so a
    /// formation phase no longer allocates a fresh `SelectScratch2` per
    /// call (one search runs per vacant axonal element, every
    /// connectivity update — EXPERIMENTS.md §Perf, opt 8 satellite).
    select: SelectScratch2,
}

/// Full formation phase, location-aware algorithm (Algorithm 1):
/// source-side searches, one 42 B-request all-to-all, owner-side
/// searches, acceptance, one 9 B-response all-to-all.
pub fn run_formation(
    comm: &impl Comm,
    tree: &Octree,
    pop: &Population,
    store: &mut SynapseStore,
    cfg: &SimConfig,
    rng: &mut Rng,
    send_scratch: &mut FormationScratch,
) -> FormationStats {
    let mut stats = FormationStats::default();
    let FormationScratch { requests, responses, select: scratch } = send_scratch;
    requests.resize_with(comm.size(), Vec::new);
    requests.iter_mut().for_each(|v| v.clear());

    // Phase 1: local descents (lines 6-12 of Algorithm 1).
    let t_search = std::time::Instant::now();
    for local in 0..pop.len() {
        let kind = axon_kind(pop.is_excitatory[local]);
        let n_vacant = vacant(pop.z_ax[local], store.connected_ax[local]);
        let src_id = pop.global_id(local);
        let src_pos = pop.positions[local];
        for _ in 0..n_vacant {
            stats.searches += 1;
            match search_new(tree, src_id, &src_pos, kind, cfg.theta, cfg.sigma, scratch, rng)
            {
                Outcome::Leaf { neuron, owner } => {
                    requests[owner as usize].push(NewRequest {
                        source: src_id,
                        pos: src_pos,
                        target_node: neuron,
                        is_leaf: true,
                        source_exc: pop.is_excitatory[local],
                    });
                }
                Outcome::RemoteInner { cell, owner } => {
                    requests[owner as usize].push(NewRequest {
                        source: src_id,
                        pos: src_pos,
                        target_node: cell as u64,
                        is_leaf: false,
                        source_exc: pop.is_excitatory[local],
                    });
                }
                Outcome::None => stats.failed_searches += 1,
            }
        }
    }
    stats.compute_nanos += t_search.elapsed().as_nanos() as u64;
    stats.proposals = requests.iter().map(|v| v.len() as u64).sum();
    let sent: Vec<usize> = requests.iter().map(|v| v.len()).collect();
    let sent_sources: Vec<Vec<GlobalNeuronId>> =
        requests.iter().map(|v| v.iter().map(|r| r.source).collect()).collect();

    // Phase 2: all-to-all the requests (line 15) — borrowing the
    // reusable scratch, identical wire accounting to the consuming
    // `exchange` (pinned by `scratch_reuse_keeps_accounting_identical`).
    let t_x1 = std::time::Instant::now();
    let incoming = exchange_ref(comm, requests);
    stats.exchange_nanos += t_x1.elapsed().as_nanos() as u64;

    // Phase 3: owner-side continuation (lines 17-20). Leaf-typed
    // requests convert straight to proposals; inner-typed ones restart
    // the Barnes-Hut search at the named branch node — entirely local,
    // no further RMA (the whole point of the algorithm).
    let mut proposals = Vec::new();
    let mut origin = Vec::new(); // (src_rank, seq) per proposal
    let mut found: Vec<Vec<GlobalNeuronId>> =
        incoming.iter().map(|b| vec![NO_TARGET; b.len()]).collect();
    let mut local_scratch = SelectScratch::default();
    let t_owner = std::time::Instant::now();
    for (src_rank, batch) in incoming.iter().enumerate() {
        for (seq, req) in batch.iter().enumerate() {
            let kind = if req.source_exc {
                ElementKind::Excitatory
            } else {
                ElementKind::Inhibitory
            };
            let target = if req.is_leaf {
                Some(req.target_node)
            } else {
                let start = tree.branch_of_cell[req.target_node as usize];
                debug_assert_eq!(tree.nodes[start].owner, tree.rank);
                select_local(
                    tree,
                    start,
                    &req.pos,
                    &SelectParams {
                        theta: cfg.theta,
                        sigma: cfg.sigma,
                        exclude: req.source,
                        kind,
                    },
                    &mut local_scratch,
                    rng,
                )
            };
            if let Some(t) = target {
                found[src_rank][seq] = t;
                proposals.push(Proposal {
                    source: req.source,
                    source_exc: req.source_exc,
                    target_local: pop.local_index(t),
                });
                origin.push((src_rank, seq));
            }
        }
    }

    stats.compute_nanos += t_owner.elapsed().as_nanos() as u64;

    // Phase 4: acceptance on the target rank.
    let success = accept_proposals(pop, store, &proposals, rng);

    // Phase 5: 9 B responses, order-preserving per source rank
    // (lines 23-26), through the same reusable scratch.
    responses.resize_with(comm.size(), Vec::new);
    for (resp, f) in responses.iter_mut().zip(&found) {
        resp.clear();
        resp.extend(f.iter().map(|&t| NewResponse { target: t, success: false }));
    }
    for (k, &(r, seq)) in origin.iter().enumerate() {
        responses[r][seq].success = success[k];
    }
    let t_x2 = std::time::Instant::now();
    let replies = exchange_ref(comm, responses);
    stats.exchange_nanos += t_x2.elapsed().as_nanos() as u64;

    // Phase 6: apply on the source side.
    for (rank, batch) in replies.iter().enumerate() {
        debug_assert_eq!(batch.len(), sent[rank]);
        for (seq, resp) in batch.iter().enumerate() {
            if resp.success {
                debug_assert_ne!(resp.target, NO_TARGET);
                let src_local = pop.local_index(sent_sources[rank][seq]);
                store.add_out(src_local, resp.target);
                stats.formed += 1;
            } else {
                stats.declined += 1;
            }
        }
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{run_ranks, ThreadComm};
    use crate::octree::DomainDecomposition;

    fn build_two_rank_tree(
        comm: &ThreadComm,
        rank: usize,
        vac: f32,
    ) -> (DomainDecomposition, Octree, Vec3) {
        let decomp = DomainDecomposition::new(2, 100.0);
        let (lo, hi) = decomp.cell_bounds(decomp.cells_of_rank(rank).start);
        let pos = (lo + hi) / 2.0;
        let mut tree = Octree::build(&decomp, rank, rank as u64, &[pos]);
        tree.reset_and_set_leaves(rank as u64, &[vac], &[vac]);
        tree.aggregate_local();
        let payloads =
            tree.own_branch_payloads(decomp.cells_of_rank(rank), |_| NO_CHILD);
        let all = crate::comm::gather_all(comm, &payloads);
        for (src, batch) in all.iter().enumerate() {
            if src != rank {
                tree.apply_branch_payloads(batch);
            }
        }
        tree.aggregate_upper();
        tree.normalize();
        (decomp, tree, pos)
    }

    #[test]
    fn source_search_terminates_at_remote_leaf_branch() {
        // Each rank holds one neuron; the remote subdomain is a single
        // known neuron, so the outcome is Leaf with the remote owner.
        let results = run_ranks(2, |comm| {
            let rank = comm.rank();
            let (_, tree, pos) = build_two_rank_tree(&comm, rank, 1.0);
            let mut scratch = SelectScratch2::default();
            let mut rng = Rng::new(rank as u64);
            let out = search_new(
                &tree,
                rank as u64,
                &pos,
                ElementKind::Excitatory,
                0.3,
                750.0,
                &mut scratch,
                &mut rng,
            );
            let rma = comm.counters().snapshot().bytes_rma;
            (out, rma)
        });
        assert_eq!(results[0].0, Outcome::Leaf { neuron: 1, owner: 1 });
        assert_eq!(results[1].0, Outcome::Leaf { neuron: 0, owner: 0 });
        // The defining property: zero RMA.
        assert_eq!(results[0].1, 0);
        assert_eq!(results[1].1, 0);
    }

    /// Build the frozen one-neuron-per-rank scenario and run one
    /// formation phase through `scratch`; returns the stats, the store,
    /// and the counters the formation itself produced (tree-setup
    /// collectives excluded).
    fn one_formation_round(
        comm: &ThreadComm,
        seed: u64,
        scratch: &mut FormationScratch,
    ) -> (FormationStats, SynapseStore, crate::comm::CounterSnapshot) {
        let rank = comm.rank();
        let cfg = SimConfig {
            ranks: 2,
            neurons_per_rank: 1,
            theta: 0.3,
            ..SimConfig::default()
        };
        let mut rng = Rng::new(seed + rank as u64);
        let decomp = DomainDecomposition::new(2, cfg.domain_size);
        let (lo, hi) = decomp.cell_bounds(decomp.cells_of_rank(rank).start);
        let pos = (lo + hi) / 2.0;
        let mut pop = Population::init(&cfg, rank, lo, hi, &mut rng);
        pop.positions[0] = pos;
        pop.is_excitatory[0] = true;
        pop.z_ax[0] = 1.0;
        pop.z_den_exc[0] = 1.0;
        pop.z_den_inh[0] = 0.0;

        let mut tree = Octree::build(&decomp, rank, pop.first_id, &pop.positions);
        tree.reset_and_set_leaves(pop.first_id, &pop.z_den_exc, &pop.z_den_inh);
        tree.aggregate_local();
        let payloads = tree.own_branch_payloads(decomp.cells_of_rank(rank), |_| NO_CHILD);
        let all = crate::comm::gather_all(comm, &payloads);
        for (src, batch) in all.iter().enumerate() {
            if src != rank {
                tree.apply_branch_payloads(batch);
            }
        }
        tree.aggregate_upper();
        tree.normalize();

        let mut store = SynapseStore::new(1, 1);
        let before = comm.counters().snapshot();
        let stats = run_formation(&comm, &tree, &pop, &mut store, &cfg, &mut rng, scratch);
        let during = comm.counters().snapshot().since(&before);
        (stats, store, during)
    }

    #[test]
    fn formation_forms_cross_rank_synapses_without_rma() {
        let results = run_ranks(2, |comm| {
            let mut scratch = FormationScratch::default();
            one_formation_round(&comm, 100, &mut scratch)
        });
        for (rank, (stats, store, snap)) in results.iter().enumerate() {
            assert_eq!(stats.searches, 1, "rank {rank}");
            assert_eq!(stats.formed, 1, "rank {rank}: one synapse formed");
            assert_eq!(store.total_out(), 1);
            assert_eq!(store.total_in(), 1);
            assert_eq!(snap.bytes_rma, 0, "new algorithm must not RMA");
            // Wire pins at the paper's exact message sizes: each rank
            // ships one 42 B request and one 9 B response in two
            // collectives — the values the `exchange_ref` migration
            // must not move (pre-refactor accounting).
            assert_eq!(snap.bytes_sent, 42 + 9, "rank {rank}: bytes");
            assert_eq!(snap.bytes_recv, 42 + 9, "rank {rank}: bytes");
            assert_eq!(snap.msgs_sent, 2, "rank {rank}: messages");
            assert_eq!(snap.collectives, 2, "rank {rank}: collectives");
            store.check_invariants().unwrap();
        }
    }

    #[test]
    fn scratch_reuse_keeps_accounting_identical() {
        // Two formation rounds over identical (freshly rebuilt) state
        // through ONE FormationScratch: the reused request/response
        // buffers must reproduce exactly the counters of the first
        // round — the scratch changes allocation, not accounting
        // (EXPERIMENTS.md §Perf, opt 6 on the formation path).
        let results = run_ranks(2, |comm| {
            let mut scratch = FormationScratch::default();
            let (s1, _, c1) = one_formation_round(&comm, 100, &mut scratch);
            let (s2, _, c2) = one_formation_round(&comm, 100, &mut scratch);
            (s1, c1, s2, c2)
        });
        for (s1, c1, s2, c2) in &results {
            assert_eq!(s1.proposals, s2.proposals);
            assert_eq!(s1.formed, s2.formed);
            assert_eq!(c1, c2);
        }
    }
}
