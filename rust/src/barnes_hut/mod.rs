//! Synapse formation: the Barnes–Hut target search in both variants, and
//! the shared request/accept/response protocol.
//!
//! * `old` — the prior distributed Barnes–Hut (Rinke et al. 2018):
//!   descents that need remote octree nodes download them via RMA.
//! * `new` — the paper's location-aware Barnes–Hut: descents stop at
//!   remote branch nodes and ship the *searching neuron* to the owner
//!   ("move computation, not data").
//! * `direct` — O(n²) probability evaluation (NEST-style baseline).
//!
//! Wire sizes follow the paper exactly: old request 17 B, old response
//! 1 B, new request 42 B, new response 9 B (§IV-A).

pub mod direct;
pub mod new;
pub mod old;
pub mod select;

use crate::comm::{exchange, Comm};
use crate::neuron::{GlobalNeuronId, Population};
use crate::octree::ElementKind;
use crate::plasticity::SynapseStore;
use crate::util::wire::{get_f64, get_u64, get_u8, put_f64, put_u64, put_u8, Wire};
use crate::util::{Rng, Vec3};

/// Gaussian connection-probability kernel: `vac * exp(-d² / σ²)`
/// (the quantity the L1 `gauss_probs` Pallas kernel computes rows of).
#[inline]
pub fn kernel_weight(vac: f32, dist2: f64, sigma: f64) -> f64 {
    vac as f64 * (-dist2 / (sigma * sigma)).exp()
}

/// Barnes–Hut acceptance criterion (paper §II): a cell of edge length
/// `side` at distance `dist` may be approximated iff `side/dist < θ`.
/// Always fails for `dist == 0` (e.g. the root containing the source).
#[inline]
pub fn accepts(side: f64, dist: f64, theta: f64) -> bool {
    dist > 0.0 && side / dist < theta
}

/// `accepts` on the SQUARED distance (hot path: saves the sqrt —
/// side/√d² < θ ⟺ side² < θ²·d²; EXPERIMENTS.md §Perf, opt 3).
#[inline]
pub fn accepts_d2(side: f64, dist2: f64, theta: f64) -> bool {
    dist2 > 0.0 && side * side < theta * theta * dist2
}

// -- wire formats --------------------------------------------------------

/// Old-format synapse request (17 B): source id, target id, type.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct OldRequest {
    pub source: GlobalNeuronId,
    pub target: GlobalNeuronId,
    /// Source neuron type == dendritic element kind requested.
    pub source_exc: bool,
}

impl Wire for OldRequest {
    const SIZE: usize = 17;
    fn write(&self, out: &mut Vec<u8>) {
        put_u64(out, self.source);
        put_u64(out, self.target);
        put_u8(out, u8::from(self.source_exc));
    }
    fn read(buf: &[u8]) -> Self {
        OldRequest {
            source: get_u64(buf, 0),
            target: get_u64(buf, 8),
            source_exc: get_u8(buf, 16) != 0,
        }
    }
}

/// Old-format response (1 B): yes/no — "the requesting neuron knows
/// which partner it has chosen" (paper §III-B0c).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct OldResponse {
    pub success: bool,
}

impl Wire for OldResponse {
    const SIZE: usize = 1;
    fn write(&self, out: &mut Vec<u8>) {
        put_u8(out, u8::from(self.success));
    }
    fn read(buf: &[u8]) -> Self {
        OldResponse { success: get_u8(buf, 0) != 0 }
    }
}

/// New-format *synapse formation and calculation* request (42 B =
/// 8 + 24 + 8 + 1 + 1, paper §IV-A): the searching neuron travels to the
/// rank owning the target subtree.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NewRequest {
    pub source: GlobalNeuronId,
    /// Source neuron position (the owner continues the search with it).
    pub pos: Vec3,
    /// Target node id: the target *neuron* id when `is_leaf`, else the
    /// Morton cell index of the branch node to search below.
    pub target_node: u64,
    /// Whether the target node is already a leaf.
    pub is_leaf: bool,
    /// Source neuron type == dendritic element kind requested.
    pub source_exc: bool,
}

impl Wire for NewRequest {
    const SIZE: usize = 42;
    fn write(&self, out: &mut Vec<u8>) {
        put_u64(out, self.source);
        put_f64(out, self.pos.x);
        put_f64(out, self.pos.y);
        put_f64(out, self.pos.z);
        put_u64(out, self.target_node);
        put_u8(out, u8::from(self.is_leaf));
        put_u8(out, u8::from(self.source_exc));
    }
    fn read(buf: &[u8]) -> Self {
        NewRequest {
            source: get_u64(buf, 0),
            pos: Vec3::new(get_f64(buf, 8), get_f64(buf, 16), get_f64(buf, 24)),
            target_node: get_u64(buf, 32),
            is_leaf: get_u8(buf, 40) != 0,
            source_exc: get_u8(buf, 41) != 0,
        }
    }
}

/// New-format response (9 B = 8 + 1): the id of the neuron the owner's
/// search found (u64::MAX if none) and the acceptance outcome.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct NewResponse {
    pub target: GlobalNeuronId,
    pub success: bool,
}

pub const NO_TARGET: GlobalNeuronId = u64::MAX;

impl Wire for NewResponse {
    const SIZE: usize = 9;
    fn write(&self, out: &mut Vec<u8>) {
        put_u64(out, self.target);
        put_u8(out, u8::from(self.success));
    }
    fn read(buf: &[u8]) -> Self {
        NewResponse { target: get_u64(buf, 0), success: get_u8(buf, 8) != 0 }
    }
}

// -- acceptance phase ----------------------------------------------------

/// A resolved synapse proposal awaiting target-side acceptance.
#[derive(Clone, Copy, Debug)]
pub struct Proposal {
    pub source: GlobalNeuronId,
    pub source_exc: bool,
    pub target_local: usize,
}

/// Target-side acceptance (paper §III-A0c): each neuron accepts randomly
/// chosen requests up to its vacant dendritic elements of the requested
/// kind; the rest are declined. Accepted proposals are recorded as
/// in-edges. Returns per-proposal success, aligned with the input order.
pub fn accept_proposals(
    pop: &Population,
    store: &mut SynapseStore,
    proposals: &[Proposal],
    rng: &mut Rng,
) -> Vec<bool> {
    // Remaining capacity per (local neuron, kind), computed against the
    // current element/synapse state.
    let n = pop.len();
    let mut cap_exc: Vec<i64> = (0..n)
        .map(|i| pop.z_den_exc[i].floor() as i64 - store.connected_den_exc[i] as i64)
        .collect();
    let mut cap_inh: Vec<i64> = (0..n)
        .map(|i| pop.z_den_inh[i].floor() as i64 - store.connected_den_inh[i] as i64)
        .collect();

    let mut order: Vec<usize> = (0..proposals.len()).collect();
    rng.shuffle(&mut order);
    let mut success = vec![false; proposals.len()];
    for idx in order {
        let p = &proposals[idx];
        let cap = if p.source_exc {
            &mut cap_exc[p.target_local]
        } else {
            &mut cap_inh[p.target_local]
        };
        if *cap > 0 {
            *cap -= 1;
            success[idx] = true;
            store.add_in(p.target_local, p.source, p.source_exc);
        }
    }
    success
}

/// Shared plumbing for algorithms whose proposals already name a target
/// neuron (old + direct): all-to-all the requests, accept on the target
/// rank, all-to-all the 1 B responses back (order-preserving), and apply
/// successful formations on the source side.
pub fn old_request_roundtrip(
    comm: &impl Comm,
    requests: Vec<Vec<OldRequest>>,
    pop: &Population,
    store: &mut SynapseStore,
    rng: &mut Rng,
) -> FormationStats {
    let mut stats = FormationStats::default();
    stats.proposals = requests.iter().map(|v| v.len() as u64).sum();
    // Remember what we asked each rank, in order.
    let sent: Vec<Vec<OldRequest>> = requests.clone();
    let t0 = std::time::Instant::now();
    let incoming = exchange(comm, requests);
    stats.exchange_nanos += t0.elapsed().as_nanos() as u64;

    // Flatten to proposals, tracking (rank, seq) for the replies.
    let mut proposals = Vec::new();
    let mut origin = Vec::new();
    for (src_rank, batch) in incoming.iter().enumerate() {
        for (seq, req) in batch.iter().enumerate() {
            proposals.push(Proposal {
                source: req.source,
                source_exc: req.source_exc,
                target_local: pop.local_index(req.target),
            });
            origin.push((src_rank, seq));
        }
    }
    let success = accept_proposals(pop, store, &proposals, rng);

    let mut responses: Vec<Vec<OldResponse>> =
        incoming.iter().map(|b| vec![OldResponse { success: false }; b.len()]).collect();
    for (i, &(r, seq)) in origin.iter().enumerate() {
        responses[r][seq] = OldResponse { success: success[i] };
    }
    let t1 = std::time::Instant::now();
    let replies = exchange(comm, responses);
    stats.exchange_nanos += t1.elapsed().as_nanos() as u64;

    for (rank, batch) in replies.iter().enumerate() {
        debug_assert_eq!(batch.len(), sent[rank].len());
        for (req, resp) in sent[rank].iter().zip(batch) {
            if resp.success {
                store.add_out(pop.local_index(req.source), req.target);
                stats.formed += 1;
            } else {
                stats.declined += 1;
            }
        }
    }
    stats
}

/// Outcome of one formation phase on one rank.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FormationStats {
    /// Vacant axonal elements that searched.
    pub searches: u64,
    /// Searches that found no admissible target.
    pub failed_searches: u64,
    /// Requests/proposals sent (by this rank's sources).
    pub proposals: u64,
    /// Synapses formed (source side).
    pub formed: u64,
    /// Proposals declined by the target.
    pub declined: u64,
    /// Nanoseconds spent in Barnes–Hut compute (incl. RMA waits for the
    /// old algorithm, owner-side continuation for the new one).
    pub compute_nanos: u64,
    /// Nanoseconds spent in the request/response all-to-alls.
    pub exchange_nanos: u64,
}

impl FormationStats {
    pub fn merge(&self, o: &FormationStats) -> FormationStats {
        FormationStats {
            searches: self.searches + o.searches,
            failed_searches: self.failed_searches + o.failed_searches,
            proposals: self.proposals + o.proposals,
            formed: self.formed + o.formed,
            declined: self.declined + o.declined,
            compute_nanos: self.compute_nanos + o.compute_nanos,
            exchange_nanos: self.exchange_nanos + o.exchange_nanos,
        }
    }
}

/// The element kind a neuron's axon searches for.
#[inline]
pub fn axon_kind(is_excitatory: bool) -> ElementKind {
    if is_excitatory {
        ElementKind::Excitatory
    } else {
        ElementKind::Inhibitory
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;

    #[test]
    fn wire_sizes_match_paper() {
        assert_eq!(OldRequest::SIZE, 17);
        assert_eq!(OldResponse::SIZE, 1);
        assert_eq!(NewRequest::SIZE, 42);
        assert_eq!(NewResponse::SIZE, 9);
    }

    #[test]
    fn request_roundtrips() {
        let old = OldRequest { source: 3, target: 9, source_exc: true };
        let mut buf = Vec::new();
        old.write(&mut buf);
        assert_eq!(OldRequest::read(&buf), old);

        let new = NewRequest {
            source: 3,
            pos: Vec3::new(1.5, 2.5, 3.5),
            target_node: 42,
            is_leaf: false,
            source_exc: false,
        };
        buf.clear();
        new.write(&mut buf);
        assert_eq!(buf.len(), 42);
        assert_eq!(NewRequest::read(&buf), new);

        let resp = NewResponse { target: NO_TARGET, success: false };
        buf.clear();
        resp.write(&mut buf);
        assert_eq!(NewResponse::read(&buf), resp);
    }

    #[test]
    fn acceptance_criterion() {
        assert!(accepts(1.0, 10.0, 0.2)); // 0.1 < 0.2
        assert!(!accepts(1.0, 4.0, 0.2)); // 0.25 >= 0.2
        assert!(!accepts(1.0, 0.0, 0.2)); // containing cell never accepted
        // theta = 0 -> direct solution (nothing accepted)
        assert!(!accepts(0.001, 1e9, 0.0));
    }

    #[test]
    fn kernel_weight_decays() {
        assert!(kernel_weight(1.0, 0.0, 10.0) == 1.0);
        assert!(kernel_weight(1.0, 100.0, 10.0) < kernel_weight(1.0, 1.0, 10.0));
        assert_eq!(kernel_weight(0.0, 1.0, 10.0), 0.0);
        assert!(kernel_weight(3.0, 1.0, 10.0) == 3.0 * kernel_weight(1.0, 1.0, 10.0));
    }

    fn tiny_pop(n: usize) -> Population {
        let cfg = SimConfig { neurons_per_rank: n, ..SimConfig::default() };
        let mut rng = Rng::new(1);
        Population::init(&cfg, 0, crate::util::Vec3::ZERO, crate::util::Vec3::splat(10.0), &mut rng)
    }

    #[test]
    fn acceptance_respects_capacity() {
        let mut pop = tiny_pop(2);
        pop.z_den_exc[0] = 1.0; // capacity 1
        let mut store = SynapseStore::new(2, 2);
        let mut rng = Rng::new(2);
        let proposals = vec![
            Proposal { source: 100, source_exc: true, target_local: 0 },
            Proposal { source: 101, source_exc: true, target_local: 0 },
            Proposal { source: 102, source_exc: true, target_local: 0 },
        ];
        let ok = accept_proposals(&pop, &mut store, &proposals, &mut rng);
        assert_eq!(ok.iter().filter(|&&s| s).count(), 1);
        assert_eq!(store.connected_den_exc[0], 1);
        store.check_invariants().unwrap();
    }

    #[test]
    fn acceptance_separates_kinds() {
        let mut pop = tiny_pop(1);
        pop.z_den_exc[0] = 1.0;
        pop.z_den_inh[0] = 1.0;
        let mut store = SynapseStore::new(1, 1);
        let mut rng = Rng::new(3);
        let proposals = vec![
            Proposal { source: 100, source_exc: true, target_local: 0 },
            Proposal { source: 101, source_exc: false, target_local: 0 },
        ];
        let ok = accept_proposals(&pop, &mut store, &proposals, &mut rng);
        assert_eq!(ok, vec![true, true]);
    }

    #[test]
    fn acceptance_counts_existing_synapses() {
        let mut pop = tiny_pop(1);
        pop.z_den_exc[0] = 2.0;
        let mut store = SynapseStore::new(1, 1);
        store.add_in(0, 55, true); // one element already bound
        let mut rng = Rng::new(4);
        let proposals = vec![
            Proposal { source: 100, source_exc: true, target_local: 0 },
            Proposal { source: 101, source_exc: true, target_local: 0 },
        ];
        let ok = accept_proposals(&pop, &mut store, &proposals, &mut rng);
        assert_eq!(ok.iter().filter(|&&s| s).count(), 1);
    }

    #[test]
    fn old_roundtrip_forms_synapses_across_ranks() {
        let results = crate::comm::run_ranks(2, |comm| {
            let cfg = SimConfig { neurons_per_rank: 1, ..SimConfig::default() };
            let mut rng = Rng::new(10 + comm.rank() as u64);
            let mut pop = Population::init(
                &cfg,
                comm.rank(),
                crate::util::Vec3::ZERO,
                crate::util::Vec3::splat(10.0),
                &mut rng,
            );
            pop.z_den_exc[0] = 3.0;
            let mut store = SynapseStore::new(1, 1);
            // Each rank proposes to the other rank's neuron.
            let other = 1 - comm.rank();
            let mut reqs = vec![Vec::new(), Vec::new()];
            reqs[other].push(OldRequest {
                source: comm.rank() as u64,
                target: other as u64,
                source_exc: true,
            });
            let stats = old_request_roundtrip(&comm, reqs, &pop, &mut store, &mut rng);
            (stats, store)
        });
        for (rank, (stats, store)) in results.iter().enumerate() {
            assert_eq!(stats.formed, 1, "rank {rank}");
            assert_eq!(store.total_out(), 1);
            assert_eq!(store.total_in(), 1);
            store.check_invariants().unwrap();
        }
    }
}
