//! Minimal CLI argument handling (the offline crate set has no `clap`).
//!
//! Grammar: `ilmi <subcommand> [--flag value]... [--bool-flag]...`

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: String,
    flags: BTreeMap<String, Vec<String>>,
    bools: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`. Flags may repeat (`--set a=1 --set b=2`).
    pub fn parse(argv: &[String]) -> Result<Args, String> {
        let mut args = Args::default();
        let mut it = argv.iter().peekable();
        args.subcommand = it.next().cloned().unwrap_or_default();
        while let Some(tok) = it.next() {
            let Some(name) = tok.strip_prefix("--") else {
                return Err(format!("unexpected positional argument {tok:?}"));
            };
            match it.peek() {
                Some(v) if !v.starts_with("--") => {
                    let v = it.next().unwrap().clone();
                    args.flags.entry(name.to_string()).or_default().push(v);
                }
                _ => args.bools.push(name.to_string()),
            }
        }
        Ok(args)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).and_then(|v| v.last()).map(|s| s.as_str())
    }

    pub fn get_all(&self, name: &str) -> &[String] {
        self.flags.get(name).map(|v| v.as_slice()).unwrap_or(&[])
    }

    pub fn get_bool(&self, name: &str) -> bool {
        self.bools.iter().any(|b| b == name)
    }

    pub fn get_parse<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>, String> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => {
                v.parse().map(Some).map_err(|_| format!("invalid value {v:?} for --{name}"))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_flags_and_bools() {
        let a = Args::parse(&sv(&[
            "simulate", "--config", "x.ini", "--set", "a=1", "--set", "b=2", "--verbose",
        ]))
        .unwrap();
        assert_eq!(a.subcommand, "simulate");
        assert_eq!(a.get("config"), Some("x.ini"));
        assert_eq!(a.get_all("set"), &["a=1".to_string(), "b=2".to_string()]);
        assert!(a.get_bool("verbose"));
        assert!(!a.get_bool("quiet"));
    }

    #[test]
    fn rejects_positionals() {
        assert!(Args::parse(&sv(&["run", "oops"])).is_err());
    }

    #[test]
    fn typed_parse() {
        let a = Args::parse(&sv(&["x", "--steps", "100"])).unwrap();
        assert_eq!(a.get_parse::<usize>("steps").unwrap(), Some(100));
        assert_eq!(a.get_parse::<usize>("missing").unwrap(), None);
        let bad = Args::parse(&sv(&["x", "--steps", "abc"])).unwrap();
        assert!(bad.get_parse::<usize>("steps").is_err());
    }
}
