//! Spike transmission between ranks — both algorithms (paper §IV-B).
//!
//! * `old` — every simulation step, ranks all-to-all the ids of neurons
//!   that fired; receivers binary-search the sorted lists for each
//!   remote in-partner.
//! * `new` — every Δ steps, ranks exchange per-neuron firing
//!   *frequencies*; receivers reconstruct spikes with a PRNG draw per
//!   remote in-edge per step. Synchronization points drop by a factor
//!   of Δ.
//!
//! Local pairs (sender and receiver on the same rank) always read the
//! fired flag directly — "checking whether one spiked is virtually free
//! for connected neuron pairs on the same MPI rank".
//!
//! Receiver-side reconstruction state is **epoch-scoped and sparse**
//! ([`PartnerFreqs`]): one (id, frequency) entry per remote in-partner
//! that reported at the last epoch boundary, O(local partners) per rank
//! instead of the former O(total neurons) dense table — and entries die
//! with the epoch or the edge, which is what fixes the stale-frequency
//! reconstruction bug (EXPERIMENTS.md §Perf, opt 7).
//!
//! Per-step delivery itself runs through the epoch-compiled
//! [`DeliveryPlan`] (`plan` module): a CSR flattening of the in-edge
//! lists with slot-interned remote sources, so the hot loop does no
//! division and no per-edge search (EXPERIMENTS.md §Perf, opt 8).

pub mod new;
pub mod old;
pub mod plan;

pub use new::FrequencyExchange;
pub use old::IdExchange;
pub use plan::{DeliveryPlan, PlannedEdge};

#[cfg(test)]
use crate::neuron::Population;
#[cfg(test)]
use crate::plasticity::SynapseStore;

/// Sparse frequency table keyed by remote sender id, sorted for
/// binary-search lookup. This is the receiver half of the new spike
/// algorithm's exchange state:
///
/// * **installed** wholesale at each epoch boundary from the records
///   that actually arrived — a sender that stopped reporting (its last
///   out-edge to this rank was deleted) simply has no entry afterwards;
/// * **pruned** between boundaries when the last in-edge from a source
///   is deleted ([`FrequencyExchange::prune_stale`]), so an edge that
///   re-forms mid-epoch reconstructs against 0.0 instead of a frequency
///   from an arbitrarily old epoch;
/// * **missing entries read as 0.0**, which never draws the PRNG — a
///   missing and a zero-frequency entry are behaviorally identical.
#[derive(Clone, Debug, Default)]
pub struct PartnerFreqs {
    /// Strictly ascending sender ids.
    ids: Vec<u64>,
    /// `freqs[i]` is the epoch frequency of `ids[i]`.
    freqs: Vec<f32>,
    /// `thrs[i]` is `freqs[i] as f64` — the Bernoulli threshold the
    /// reconstruction draw compares `next_f64()` against. Precomputed
    /// once per install/prune instead of converting on every draw
    /// (EXPERIMENTS.md §Perf, opt 8); the widening is exact, so draws
    /// are bit-identical to converting inline.
    thrs: Vec<f64>,
}

impl PartnerFreqs {
    pub fn new() -> PartnerFreqs {
        PartnerFreqs::default()
    }

    /// Entries currently installed (== remote partners that reported at
    /// the last boundary and still have a surviving in-edge).
    pub fn len(&self) -> usize {
        self.ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }

    /// Last installed frequency of sender `id`; 0.0 when absent.
    #[inline]
    pub fn get(&self, id: u64) -> f32 {
        match self.ids.binary_search(&id) {
            Ok(i) => self.freqs[i],
            Err(_) => 0.0,
        }
    }

    /// The installed entry of sender `id`, distinguishing an explicit
    /// zero from a missing entry (behaviorally identical for draws, but
    /// the migration packer must preserve the table bit-faithfully).
    #[inline]
    pub fn lookup(&self, id: u64) -> Option<f32> {
        self.ids.binary_search(&id).ok().map(|i| self.freqs[i])
    }

    /// Last installed Bernoulli threshold (`frequency as f64`) of
    /// sender `id`; 0.0 when absent. The draw-site lookup: precomputed
    /// at install time, never converted per draw.
    #[inline]
    pub fn get_thr(&self, id: u64) -> f64 {
        match self.ids.binary_search(&id) {
            Ok(i) => self.thrs[i],
            Err(_) => 0.0,
        }
    }

    /// Replace the whole table with this epoch's reports. The records
    /// must arrive in strictly ascending id order — which concatenating
    /// the all-to-all batches in source-rank order guarantees: per-rank
    /// id ranges are disjoint and ascending with rank, and each sender
    /// emits at most one record per neuron, in local (= id) order.
    pub fn install_epoch(&mut self, records: impl Iterator<Item = (u64, f32)>) {
        self.ids.clear();
        self.freqs.clear();
        self.thrs.clear();
        for (id, f) in records {
            debug_assert!(
                !self.ids.last().is_some_and(|&last| last >= id),
                "epoch records not strictly ascending by id"
            );
            self.ids.push(id);
            self.freqs.push(f);
            self.thrs.push(f as f64);
        }
    }

    /// Drop every entry whose id fails `keep` (edge-deletion pruning).
    pub fn retain(&mut self, mut keep: impl FnMut(u64) -> bool) {
        let mut w = 0;
        for r in 0..self.ids.len() {
            if keep(self.ids[r]) {
                self.ids[w] = self.ids[r];
                self.freqs[w] = self.freqs[r];
                self.thrs[w] = self.thrs[r];
                w += 1;
            }
        }
        self.ids.truncate(w);
        self.freqs.truncate(w);
        self.thrs.truncate(w);
    }

    /// Borrowing iterator over the installed (id, frequency) pairs in
    /// ascending id order — the snapshot writer path encodes straight
    /// from this instead of allocating a fresh `Vec` on every capture
    /// inside the step loop.
    pub fn entries_iter(&self) -> impl ExactSizeIterator<Item = (u64, f32)> + '_ {
        self.ids.iter().copied().zip(self.freqs.iter().copied())
    }

    /// The installed (id, frequency) pairs in ascending id order, as an
    /// owned `Vec` (tests / restore round-trips; the snapshot writer
    /// uses the borrowing [`PartnerFreqs::entries_iter`] instead).
    pub fn entries(&self) -> Vec<(u64, f32)> {
        self.entries_iter().collect()
    }

    /// Scatter this table's Bernoulli thresholds into a slot-aligned
    /// array: `out[slot]` becomes the threshold of `slot_ids[slot]`, or
    /// 0.0 when that sender has no installed entry. `slot_ids` must be
    /// ascending (the `DeliveryPlan` slot-table invariant), so one
    /// merge walk fills every slot — O(slots + entries).
    pub fn fill_slot_thrs(&self, slot_ids: &[u64], out: &mut Vec<f64>) {
        out.clear();
        out.resize(slot_ids.len(), 0.0);
        let mut e = 0;
        for (slot, &id) in slot_ids.iter().enumerate() {
            while e < self.ids.len() && self.ids[e] < id {
                e += 1;
            }
            if e < self.ids.len() && self.ids[e] == id {
                out[slot] = self.thrs[e];
            }
        }
    }

    /// Validate the strictly-ascending-id invariant every producer of
    /// sparse entries must uphold (binary-search lookups silently
    /// misbehave otherwise). The single authority: the snapshot
    /// decoder and the driver's section validation call this too.
    pub fn check_ascending(entries: &[(u64, f32)]) -> Result<(), String> {
        for w in entries.windows(2) {
            if w[0].0 >= w[1].0 {
                return Err(format!(
                    "frequency entries not strictly ascending: id {} then {}",
                    w[0].0, w[1].0
                ));
            }
        }
        Ok(())
    }

    /// Rebuild from captured entries; rejects unordered or duplicate
    /// ids via [`PartnerFreqs::check_ascending`].
    pub fn from_entries(entries: Vec<(u64, f32)>) -> Result<PartnerFreqs, String> {
        Self::check_ascending(&entries)?;
        let thrs = entries.iter().map(|&(_, f)| f as f64).collect();
        let (ids, freqs) = entries.into_iter().unzip();
        Ok(PartnerFreqs { ids, freqs, thrs })
    }

    /// Logical size of the exchange state: one 12 B (u64 id, f32
    /// frequency) record per installed partner — the quantity the bench
    /// harness reports as `spike_state_bytes` to demonstrate the
    /// O(local partners) vs O(total neurons) win.
    pub fn state_bytes(&self) -> u64 {
        (self.ids.len() * 12) as u64
    }
}

/// Synaptic weight per spike: +1 for excitatory sources, −1 for
/// inhibitory (scaled by `NeuronParams::i_scale` inside the neuron
/// update).
#[inline]
pub fn spike_weight(source_exc: bool) -> f32 {
    if source_exc {
        1.0
    } else {
        -1.0
    }
}

/// Accumulate synaptic input for every local neuron: local in-partners
/// read the fired flag; remote ones are resolved by `remote_spiked`
/// (binary search for `old`, PRNG draw for `new`). Returns the number of
/// remote look-ups performed (paper Fig. 5 measures exactly these).
///
/// This is the **naive oracle**: the driver delivers through the
/// epoch-compiled [`DeliveryPlan`] instead (EXPERIMENTS.md §Perf,
/// opt 8), and this loop survives only as the reference the plan's
/// differential tests compare against — per edge per step it pays the
/// u64 division, the `Vec<Vec<InEdge>>` pointer chase, and the
/// per-edge search the plan compiles away.
#[cfg(test)]
pub fn deliver_input(
    pop: &mut Population,
    store: &SynapseStore,
    owners: &crate::balance::OwnershipMap,
    my_rank: usize,
    mut remote_spiked: impl FnMut(usize, u64) -> bool,
) -> u64 {
    let mut lookups = 0;
    let first = pop.first_id;
    for local in 0..pop.len() {
        let mut acc = 0.0f32;
        for e in &store.in_edges[local] {
            let src_rank = owners.rank_of(e.source) as usize;
            let spiked = if src_rank == my_rank {
                pop.fired[(e.source - first) as usize]
            } else {
                lookups += 1;
                remote_spiked(src_rank, e.source)
            };
            if spiked {
                acc += spike_weight(e.source_exc);
            }
        }
        pop.i_syn[local] = acc;
    }
    lookups
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::util::{Rng, Vec3};

    #[test]
    fn local_delivery_reads_fired_flags() {
        let cfg = SimConfig { neurons_per_rank: 3, ..SimConfig::default() };
        let mut rng = Rng::new(1);
        let mut pop =
            Population::init(&cfg, 0, Vec3::ZERO, Vec3::splat(10.0), &mut rng);
        let mut store = SynapseStore::new(3, 3);
        // 0 -> 2 (exc), 1 -> 2 (inh); 0 fired, 1 did not.
        store.add_in(2, 0, true);
        store.add_in(2, 1, false);
        pop.fired[0] = true;
        pop.fired[1] = false;
        let owners = crate::balance::OwnershipMap::stride(3);
        let lookups = deliver_input(&mut pop, &store, &owners, 0, |_, _| {
            panic!("no remote edges here")
        });
        assert_eq!(lookups, 0);
        assert_eq!(pop.i_syn[2], 1.0);
        assert_eq!(pop.i_syn[0], 0.0);
    }

    #[test]
    fn remote_delivery_consults_callback_and_counts_lookups() {
        let cfg = SimConfig { neurons_per_rank: 2, ..SimConfig::default() };
        let mut rng = Rng::new(2);
        let mut pop =
            Population::init(&cfg, 0, Vec3::ZERO, Vec3::splat(10.0), &mut rng);
        let mut store = SynapseStore::new(2, 2);
        // Remote sources 2 (rank 1, exc) and 4 (rank 2, inh) -> local 0.
        store.add_in(0, 2, true);
        store.add_in(0, 4, false);
        let owners = crate::balance::OwnershipMap::stride(2);
        let lookups = deliver_input(&mut pop, &store, &owners, 0, |rank, id| {
            assert_eq!(rank as u64, id / 2);
            true // everyone spiked
        });
        assert_eq!(lookups, 2);
        assert_eq!(pop.i_syn[0], 0.0); // +1 - 1
    }

    #[test]
    fn inhibitory_weight_is_negative() {
        assert_eq!(spike_weight(true), 1.0);
        assert_eq!(spike_weight(false), -1.0);
    }

    #[test]
    fn partner_freqs_lookup_and_epoch_scoping() {
        let mut pf = PartnerFreqs::new();
        assert_eq!(pf.get(5), 0.0);
        assert_eq!(pf.state_bytes(), 0);
        pf.install_epoch([(2u64, 0.25f32), (5, 0.5), (9, 0.0)].into_iter());
        assert_eq!(pf.len(), 3);
        assert_eq!(pf.state_bytes(), 36);
        assert_eq!(pf.get(2), 0.25);
        assert_eq!(pf.get(5), 0.5);
        assert_eq!(pf.get(9), 0.0, "explicit zero reads like a missing entry");
        assert_eq!(pf.get(4), 0.0);
        // `lookup` (the migration packer's view) DOES distinguish an
        // explicit zero from a missing entry.
        assert_eq!(pf.lookup(9), Some(0.0));
        assert_eq!(pf.lookup(4), None);
        // A new epoch REPLACES the table: a sender that stopped
        // reporting loses its entry, it is not carried over.
        pf.install_epoch([(5u64, 0.125f32)].into_iter());
        assert_eq!(pf.len(), 1);
        assert_eq!(pf.get(2), 0.0);
        assert_eq!(pf.get(5), 0.125);
    }

    #[test]
    fn partner_freqs_retain_drops_selected_ids() {
        let mut pf = PartnerFreqs::new();
        pf.install_epoch([(1u64, 0.1f32), (4, 0.4), (7, 0.7)].into_iter());
        pf.retain(|id| id != 4);
        assert_eq!(pf.entries(), vec![(1, 0.1), (7, 0.7)]);
        assert_eq!(pf.get(4), 0.0);
        assert_eq!(pf.get(7), 0.7);
    }

    #[test]
    fn thresholds_are_precomputed_and_track_installs_and_prunes() {
        let mut pf = PartnerFreqs::new();
        assert_eq!(pf.get_thr(3), 0.0);
        pf.install_epoch([(3u64, 0.25f32), (6, 0.0), (9, 0.75)].into_iter());
        // The threshold is exactly the widened frequency — same bits
        // the draw site used to compute inline.
        assert_eq!(pf.get_thr(3).to_bits(), (0.25f32 as f64).to_bits());
        assert_eq!(pf.get_thr(6), 0.0);
        assert_eq!(pf.get_thr(9).to_bits(), (0.75f32 as f64).to_bits());
        assert_eq!(pf.get_thr(4), 0.0, "missing entries read 0.0");
        pf.retain(|id| id != 3);
        assert_eq!(pf.get_thr(3), 0.0);
        assert_eq!(pf.get_thr(9).to_bits(), (0.75f32 as f64).to_bits());
        let back = PartnerFreqs::from_entries(pf.entries()).unwrap();
        assert_eq!(back.get_thr(9).to_bits(), pf.get_thr(9).to_bits());
    }

    #[test]
    fn borrowing_iter_matches_entries() {
        let mut pf = PartnerFreqs::new();
        pf.install_epoch([(2u64, 0.5f32), (7, 0.125)].into_iter());
        let it: Vec<(u64, f32)> = pf.entries_iter().collect();
        assert_eq!(it, pf.entries());
        assert_eq!(
            pf.entries_iter().len(),
            2,
            "ExactSizeIterator for the writer's count prefix"
        );
    }

    #[test]
    fn fill_slot_thrs_is_slot_aligned_with_zero_for_missing() {
        let mut pf = PartnerFreqs::new();
        pf.install_epoch([(2u64, 0.5f32), (9, 0.25)].into_iter());
        let mut out = vec![9.9; 1]; // stale scratch must be overwritten
        pf.fill_slot_thrs(&[1, 2, 5, 9, 12], &mut out);
        assert_eq!(out.len(), 5);
        assert_eq!(out[0], 0.0);
        assert_eq!(out[1].to_bits(), (0.5f32 as f64).to_bits());
        assert_eq!(out[2], 0.0);
        assert_eq!(out[3].to_bits(), (0.25f32 as f64).to_bits());
        assert_eq!(out[4], 0.0);
        // An empty slot table clears the scratch.
        pf.fill_slot_thrs(&[], &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn partner_freqs_entries_roundtrip_and_reject_disorder() {
        let mut pf = PartnerFreqs::new();
        pf.install_epoch([(3u64, 0.3f32), (8, 0.8)].into_iter());
        let back = PartnerFreqs::from_entries(pf.entries()).unwrap();
        assert_eq!(back.entries(), pf.entries());
        let err = PartnerFreqs::from_entries(vec![(8, 0.8), (3, 0.3)]).unwrap_err();
        assert!(err.contains("ascending"), "{err}");
        let err = PartnerFreqs::from_entries(vec![(3, 0.8), (3, 0.3)]).unwrap_err();
        assert!(err.contains("ascending"), "{err}");
    }
}
