//! Spike transmission between ranks — both algorithms (paper §IV-B).
//!
//! * `old` — every simulation step, ranks all-to-all the ids of neurons
//!   that fired; receivers binary-search the sorted lists for each
//!   remote in-partner.
//! * `new` — every Δ steps, ranks exchange per-neuron firing
//!   *frequencies*; receivers reconstruct spikes with a PRNG draw per
//!   remote in-edge per step. Synchronization points drop by a factor
//!   of Δ.
//!
//! Local pairs (sender and receiver on the same rank) always read the
//! fired flag directly — "checking whether one spiked is virtually free
//! for connected neuron pairs on the same MPI rank".

pub mod new;
pub mod old;

pub use new::FrequencyExchange;
pub use old::IdExchange;

use crate::neuron::Population;
use crate::plasticity::SynapseStore;

/// Synaptic weight per spike: +1 for excitatory sources, −1 for
/// inhibitory (scaled by `NeuronParams::i_scale` inside the neuron
/// update).
#[inline]
pub fn spike_weight(source_exc: bool) -> f32 {
    if source_exc {
        1.0
    } else {
        -1.0
    }
}

/// Accumulate synaptic input for every local neuron: local in-partners
/// read the fired flag; remote ones are resolved by `remote_spiked`
/// (binary search for `old`, PRNG draw for `new`). Returns the number of
/// remote look-ups performed (paper Fig. 5 measures exactly these).
pub fn deliver_input(
    pop: &mut Population,
    store: &SynapseStore,
    neurons_per_rank: u64,
    my_rank: usize,
    mut remote_spiked: impl FnMut(usize, u64) -> bool,
) -> u64 {
    let mut lookups = 0;
    let first = pop.first_id;
    for local in 0..pop.len() {
        let mut acc = 0.0f32;
        for e in &store.in_edges[local] {
            let src_rank = (e.source / neurons_per_rank) as usize;
            let spiked = if src_rank == my_rank {
                pop.fired[(e.source - first) as usize]
            } else {
                lookups += 1;
                remote_spiked(src_rank, e.source)
            };
            if spiked {
                acc += spike_weight(e.source_exc);
            }
        }
        pop.i_syn[local] = acc;
    }
    lookups
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::util::{Rng, Vec3};

    #[test]
    fn local_delivery_reads_fired_flags() {
        let cfg = SimConfig { neurons_per_rank: 3, ..SimConfig::default() };
        let mut rng = Rng::new(1);
        let mut pop =
            Population::init(&cfg, 0, Vec3::ZERO, Vec3::splat(10.0), &mut rng);
        let mut store = SynapseStore::new(3);
        // 0 -> 2 (exc), 1 -> 2 (inh); 0 fired, 1 did not.
        store.add_in(2, 0, true);
        store.add_in(2, 1, false);
        pop.fired[0] = true;
        pop.fired[1] = false;
        let lookups = deliver_input(&mut pop, &store, 3, 0, |_, _| {
            panic!("no remote edges here")
        });
        assert_eq!(lookups, 0);
        assert_eq!(pop.i_syn[2], 1.0);
        assert_eq!(pop.i_syn[0], 0.0);
    }

    #[test]
    fn remote_delivery_consults_callback_and_counts_lookups() {
        let cfg = SimConfig { neurons_per_rank: 2, ..SimConfig::default() };
        let mut rng = Rng::new(2);
        let mut pop =
            Population::init(&cfg, 0, Vec3::ZERO, Vec3::splat(10.0), &mut rng);
        let mut store = SynapseStore::new(2);
        // Remote sources 2 (rank 1, exc) and 4 (rank 2, inh) -> local 0.
        store.add_in(0, 2, true);
        store.add_in(0, 4, false);
        let lookups = deliver_input(&mut pop, &store, 2, 0, |rank, id| {
            assert_eq!(rank as u64, id / 2);
            true // everyone spiked
        });
        assert_eq!(lookups, 2);
        assert_eq!(pop.i_syn[0], 0.0); // +1 - 1
    }

    #[test]
    fn inhibitory_weight_is_negative() {
        assert_eq!(spike_weight(true), 1.0);
        assert_eq!(spike_weight(false), -1.0);
    }
}
