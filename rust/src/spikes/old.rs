//! Old spike transmission: per-step all-to-all of fired neuron ids,
//! sorted on receipt, binary-searched per remote in-partner
//! (paper §III-A0a / §V-B0b).

use crate::comm::{exchange_ref, Comm};
use crate::neuron::Population;
use crate::plasticity::SynapseStore;

use super::DeliveryPlan;

/// State of the old algorithm on one rank: the sorted id lists received
/// this step, indexed by source rank.
pub struct IdExchange {
    sorted: Vec<Vec<u64>>,
    /// Scratch: per-destination send lists, reused across steps — this
    /// runs every step, so rebuilding the `Vec<Vec<_>>` here was
    /// measurable allocation churn (EXPERIMENTS.md §Perf, opt 6).
    sends: Vec<Vec<u64>>,
    /// Per-step slot bitmap: `slot_bits[slot]` is true iff the sender
    /// the `DeliveryPlan` interned at `slot` fired this step. Scattered
    /// once per step from the received id lists — O(|fired| · log P) —
    /// so per-edge delivery is one indexed load instead of a binary
    /// search over the received lists, O(edges · log |fired|)
    /// (EXPERIMENTS.md §Perf, opt 8). Reused scratch, never snapshotted.
    slot_bits: Vec<bool>,
}

impl IdExchange {
    pub fn new(size: usize) -> Self {
        IdExchange {
            sorted: vec![Vec::new(); size],
            sends: vec![Vec::new(); size],
            slot_bits: Vec::new(),
        }
    }

    /// One step: send the ids of local neurons that fired to every rank
    /// hosting at least one of their out-partners; sort what arrives.
    /// This happens EVERY simulation step — the synchronization the new
    /// algorithm amortizes away. Destination ranks come straight from
    /// the `SynapseStore`'s incrementally-maintained out-rank table
    /// (EXPERIMENTS.md §Perf, opt 7) instead of rescanning `out_edges`
    /// into a per-destination flag array per firing neuron.
    pub fn exchange(&mut self, comm: &impl Comm, pop: &Population, store: &SynapseStore) {
        let sends = &mut self.sends;
        sends.iter_mut().for_each(|s| s.clear());
        let me = comm.rank() as u32;
        for local in 0..pop.len() {
            if !pop.fired[local] {
                continue;
            }
            let id = pop.global_id(local);
            for &(rank, _) in store.out_ranks(local) {
                if rank != me {
                    sends[rank as usize].push(id);
                }
            }
        }
        self.sorted = exchange_ref(comm, sends);
        for list in self.sorted.iter_mut() {
            list.sort_unstable();
        }
    }

    /// Did remote neuron `id` (on `src_rank`) fire this step?
    /// Binary search over the received list (paper Fig. 5, "search").
    /// Oracle path — the driver reads [`Self::slot_fired`] instead.
    #[inline]
    pub fn spiked(&self, src_rank: usize, id: u64) -> bool {
        self.sorted[src_rank].binary_search(&id).is_ok()
    }

    /// Scatter this step's received fired ids into the plan's slot
    /// bitmap: each id is located once (binary search over the interned
    /// slot table), instead of every in-edge searching the received
    /// lists. Ids without a slot are senders this rank holds no in-edge
    /// from — the oracle's per-edge search could never match them
    /// either, so they are skipped.
    pub fn scatter_slots(&mut self, plan: &DeliveryPlan) {
        self.slot_bits.clear();
        self.slot_bits.resize(plan.slot_count(), false);
        for list in &self.sorted {
            for id in list {
                if let Ok(slot) = plan.remote_ids().binary_search(id) {
                    self.slot_bits[slot] = true;
                }
            }
        }
    }

    /// Did the sender interned at `slot` fire this step? One indexed
    /// load — the O(1) lookup behind `DeliveryPlan::deliver`.
    #[inline]
    pub fn slot_fired(&self, slot: usize) -> bool {
        self.slot_bits[slot]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::run_ranks;
    use crate::config::SimConfig;
    use crate::util::{Rng, Vec3};

    fn make_pop(rank: usize, n: usize) -> Population {
        let cfg = SimConfig { neurons_per_rank: n, ..SimConfig::default() };
        let mut rng = Rng::new(9);
        Population::init(&cfg, rank, Vec3::ZERO, Vec3::splat(10.0), &mut rng)
    }

    #[test]
    fn fired_ids_reach_partner_ranks_only() {
        let results = run_ranks(3, |comm| {
            let rank = comm.rank();
            let mut pop = make_pop(rank, 2);
            let mut store = SynapseStore::new(2, 2);
            // Rank 0's neuron 0 projects to rank 1 (id 2) only.
            if rank == 0 {
                store.add_out(0, 2);
                pop.fired[0] = true;
                pop.fired[1] = true; // fired but no out-partners: not sent
            }
            let mut ex = IdExchange::new(3);
            ex.exchange(&comm, &pop, &store);
            let sent = comm.counters().snapshot().bytes_sent;
            (ex, sent)
        });
        // Rank 1 sees rank 0's neuron 0.
        assert!(results[1].0.spiked(0, 0));
        assert!(!results[1].0.spiked(0, 1));
        // Rank 2 got nothing.
        assert!(!results[2].0.spiked(0, 0));
        // Rank 0 sent exactly one 8-byte id.
        assert_eq!(results[0].1, 8);
    }

    #[test]
    fn lists_are_sorted_for_binary_search() {
        let results = run_ranks(2, |comm| {
            let rank = comm.rank();
            let mut pop = make_pop(rank, 8);
            let mut store = SynapseStore::new(8, 8);
            if rank == 1 {
                // Fire several, all projecting to rank 0's neuron 0.
                for i in [5usize, 1, 7, 3] {
                    store.add_out(i, 0);
                    pop.fired[i] = true;
                }
            }
            let mut ex = IdExchange::new(2);
            ex.exchange(&comm, &pop, &store);
            ex
        });
        let ex = &results[0];
        for id in [9u64, 11, 13, 15] {
            assert!(ex.spiked(1, id), "id {id}");
        }
        for id in [8u64, 10, 12, 14] {
            assert!(!ex.spiked(1, id));
        }
    }

    #[test]
    fn scatter_sets_exactly_the_fired_slots_and_resets_per_step() {
        let results = run_ranks(2, |comm| {
            let rank = comm.rank();
            let mut pop = make_pop(rank, 4);
            let mut store = SynapseStore::new(4, 4);
            if rank == 1 {
                // Rank 1 fires neurons 4 and 6 toward rank 0.
                for i in [0usize, 2] {
                    store.add_out(i, 0);
                    pop.fired[i] = true;
                }
            } else {
                // Rank 0 holds in-edges from 4, 5, 6 (slots 0, 1, 2).
                store.add_in(0, 4, true);
                store.add_in(1, 5, true);
                store.add_in(2, 6, false);
            }
            let plan = DeliveryPlan::compile(&store, (rank * 4) as u64);
            let mut ex = IdExchange::new(2);
            ex.exchange(&comm, &pop, &store);
            ex.scatter_slots(&plan);
            let first: Vec<bool> =
                (0..plan.slot_count()).map(|s| ex.slot_fired(s)).collect();
            // Next step nobody fires: the bitmap must fully reset.
            pop.fired.iter_mut().for_each(|f| *f = false);
            ex.exchange(&comm, &pop, &store);
            ex.scatter_slots(&plan);
            let second: Vec<bool> =
                (0..plan.slot_count()).map(|s| ex.slot_fired(s)).collect();
            (first, second)
        });
        assert_eq!(results[0].0, vec![true, false, true]);
        assert_eq!(results[0].1, vec![false, false, false]);
        assert!(results[1].0.is_empty(), "rank 1 has no remote in-edges");
    }

    #[test]
    fn scratch_reuse_keeps_accounting_identical() {
        // Two consecutive steps through ONE IdExchange (reused hoisted
        // send buffers) must produce exactly the per-step counters a
        // fresh step produces: the scratch changes allocation, not
        // accounting (EXPERIMENTS.md §Perf, opt 6).
        let results = run_ranks(2, |comm| {
            let rank = comm.rank();
            let mut pop = make_pop(rank, 4);
            let mut store = SynapseStore::new(4, 4);
            if rank == 0 {
                store.add_out(0, 4); // both to rank 1
                store.add_out(1, 5);
                pop.fired[0] = true;
                pop.fired[1] = true;
            }
            let mut ex = IdExchange::new(2);
            ex.exchange(&comm, &pop, &store);
            let first = comm.counters().snapshot();
            ex.exchange(&comm, &pop, &store);
            let second = comm.counters().snapshot().since(&first);
            (first, second)
        });
        for (first, second) in &results {
            assert_eq!(first, second);
        }
        // Absolute values match the wire format: two 8-byte ids in one
        // message from rank 0, one collective on every rank.
        assert_eq!(results[0].0.bytes_sent, 16);
        assert_eq!(results[0].0.msgs_sent, 1);
        assert_eq!(results[0].0.collectives, 1);
        assert_eq!(results[1].0.bytes_sent, 0);
        assert_eq!(results[1].0.bytes_recv, 16);
        assert_eq!(results[1].0.collectives, 1);
    }

    #[test]
    fn empty_step_exchanges_nothing_but_still_synchronizes() {
        let results = run_ranks(2, |comm| {
            let pop = make_pop(comm.rank(), 2);
            let store = SynapseStore::new(2, 2);
            let mut ex = IdExchange::new(2);
            ex.exchange(&comm, &pop, &store);
            comm.counters().snapshot()
        });
        for snap in results {
            assert_eq!(snap.bytes_sent, 0);
            // The collective still happened (the old algorithm's cost:
            // every rank synchronizes even with zero spikes).
            assert_eq!(snap.collectives, 1);
        }
    }
}
