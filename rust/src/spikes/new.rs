//! New spike transmission: firing-*frequency* exchange every Δ steps +
//! PRNG reconstruction (paper §IV-B).
//!
//! At every epoch boundary each rank computes, for each local neuron
//! with remote out-partners, the firing frequency over the elapsed epoch
//! (spikes / Δ) and sends (id, frequency) records to the partner ranks.
//! In between, a receiving rank decides per remote in-edge per step with
//! probability = frequency whether the sender spiked. Spikes lose exact
//! timing across ranks — the approximation §V-D quantifies — but the
//! number of synchronization points drops by Δ and transfer volume
//! becomes independent of the firing rate.

use crate::comm::{exchange_ref, ThreadComm};
use crate::neuron::Population;
use crate::plasticity::SynapseStore;
use crate::util::wire::{get_f32, get_u64, put_f32, put_u64, Wire};
use crate::util::Rng;

/// (neuron id, firing frequency) record — 12 B.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FreqRecord {
    pub id: u64,
    pub freq: f32,
}

impl Wire for FreqRecord {
    const SIZE: usize = 12;
    fn write(&self, out: &mut Vec<u8>) {
        put_u64(out, self.id);
        put_f32(out, self.freq);
    }
    fn read(buf: &[u8]) -> Self {
        FreqRecord { id: get_u64(buf, 0), freq: get_f32(buf, 4 + 4) }
    }
}

/// State of the new algorithm on one rank.
pub struct FrequencyExchange {
    /// Epoch length Δ (paper: 100 — every connectivity update).
    pub delta: usize,
    /// Dense frequency table indexed by global neuron id (only entries
    /// for remote in-partners are ever read; dense indexing keeps the
    /// per-lookup cost at one load — see EXPERIMENTS.md §Perf).
    freqs: Vec<f32>,
    /// PRNG for spike reconstruction.
    rng: Rng,
    dest_flags: Vec<bool>,
    /// Scratch: per-destination send lists, reused across epochs like
    /// `dest_flags` instead of rebuilding a `Vec<Vec<_>>` per exchange
    /// (EXPERIMENTS.md §Perf, opt 6).
    sends: Vec<Vec<FreqRecord>>,
}

impl FrequencyExchange {
    pub fn new(delta: usize, total_neurons: usize, rng: Rng) -> Self {
        FrequencyExchange {
            delta,
            freqs: vec![0.0; total_neurons],
            rng,
            dest_flags: Vec::new(),
            sends: Vec::new(),
        }
    }

    /// Run at epoch boundaries (`step % delta == 0`): exchange the
    /// frequencies accumulated over the previous epoch and reset the
    /// per-neuron spike counters. No-op on other steps — and crucially,
    /// no synchronization on other steps either.
    pub fn maybe_exchange(
        &mut self,
        comm: &ThreadComm,
        pop: &mut Population,
        store: &SynapseStore,
        neurons_per_rank: u64,
        step: usize,
    ) -> bool {
        if step % self.delta != 0 {
            return false;
        }
        let size = comm.size();
        self.dest_flags.resize(size, false);
        self.sends.resize_with(size, Vec::new);
        let sends = &mut self.sends;
        sends.iter_mut().for_each(|s| s.clear());
        for local in 0..pop.len() {
            let spikes = pop.epoch_spikes[local];
            pop.epoch_spikes[local] = 0;
            if store.out_edges[local].is_empty() {
                continue;
            }
            self.dest_flags.iter_mut().for_each(|f| *f = false);
            for &tgt in &store.out_edges[local] {
                self.dest_flags[(tgt / neurons_per_rank) as usize] = true;
            }
            let rec = FreqRecord {
                id: pop.global_id(local),
                freq: spikes as f32 / self.delta as f32,
            };
            for (rank, &flagged) in self.dest_flags.iter().enumerate() {
                if flagged && rank != comm.rank() {
                    sends[rank].push(rec);
                }
            }
        }
        let incoming = exchange_ref(comm, sends);
        for batch in incoming {
            for rec in batch {
                self.freqs[rec.id as usize] = rec.freq;
            }
        }
        true
    }

    /// Reconstruct: did remote neuron `id` spike this step? One PRNG
    /// draw against its last known frequency (paper Fig. 5, "PRNG").
    #[inline]
    pub fn spiked(&mut self, id: u64) -> bool {
        let f = self.freqs[id as usize];
        f > 0.0 && self.rng.bernoulli(f as f64)
    }

    /// Last received frequency of a neuron (tests/inspection).
    pub fn freq_of(&self, id: u64) -> f32 {
        self.freqs[id as usize]
    }

    // -- checkpoint/restore accessors (see `snapshot`) -------------------

    /// The dense frequency table, for snapshotting. Mid-epoch this holds
    /// the frequencies received at the last epoch boundary, which the
    /// receiver keeps consulting until the next exchange — so a restored
    /// rank must get these back bit-exactly.
    pub fn freq_table(&self) -> &[f32] {
        &self.freqs
    }

    /// Reconstruction-PRNG state, for snapshotting.
    pub fn rng_state(&self) -> crate::util::RngState {
        self.rng.state()
    }

    /// Rebuild an exchange from snapshotted parts. `total_neurons` is
    /// the size the simulation expects the dense table to have.
    pub fn from_parts(
        delta: usize,
        total_neurons: usize,
        freqs: Vec<f32>,
        rng: crate::util::RngState,
    ) -> Result<FrequencyExchange, String> {
        if freqs.len() != total_neurons {
            return Err(format!(
                "frequency table size mismatch: snapshot has {}, simulation expects \
                 {total_neurons}",
                freqs.len(),
            ));
        }
        Ok(FrequencyExchange {
            delta,
            freqs,
            rng: Rng::from_state(rng),
            dest_flags: Vec::new(),
            sends: Vec::new(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::run_ranks;
    use crate::config::SimConfig;
    use crate::util::Vec3;

    fn make_pop(rank: usize, n: usize) -> Population {
        let cfg = SimConfig { neurons_per_rank: n, ..SimConfig::default() };
        let mut rng = Rng::new(4);
        Population::init(&cfg, rank, Vec3::ZERO, Vec3::splat(10.0), &mut rng)
    }

    #[test]
    fn record_is_12_bytes() {
        assert_eq!(FreqRecord::SIZE, 12);
        let r = FreqRecord { id: 77, freq: 0.25 };
        let mut buf = Vec::new();
        r.write(&mut buf);
        assert_eq!(buf.len(), 12);
        assert_eq!(FreqRecord::read(&buf), r);
    }

    #[test]
    fn frequencies_cross_ranks_at_epoch_boundaries_only() {
        let results = run_ranks(2, |comm| {
            let rank = comm.rank();
            let mut pop = make_pop(rank, 2);
            let mut store = SynapseStore::new(2);
            if rank == 0 {
                store.add_out(0, 2); // to rank 1
                pop.epoch_spikes[0] = 10; // fired 10 times this epoch
            }
            let mut ex = FrequencyExchange::new(100, 4, Rng::new(1));
            // Mid-epoch: nothing happens, no synchronization.
            assert!(!ex.maybe_exchange(&comm, &mut pop, &store, 2, 50));
            assert_eq!(comm.counters().snapshot().collectives, 0);
            // Epoch boundary: records move.
            assert!(ex.maybe_exchange(&comm, &mut pop, &store, 2, 100));
            (ex, pop, comm.counters().snapshot())
        });
        let (ex1, _, _) = &results[1];
        assert!((ex1.freq_of(0) - 0.1).abs() < 1e-6);
        // Sender reset its epoch counter.
        assert_eq!(results[0].1.epoch_spikes[0], 0);
        // 12 bytes went rank0 -> rank1.
        assert_eq!(results[0].2.bytes_sent, 12);
        assert_eq!(results[1].2.bytes_sent, 0);
    }

    #[test]
    fn reconstruction_matches_frequency_statistically() {
        let mut ex = FrequencyExchange::new(100, 4, Rng::new(7));
        ex.freqs[2] = 0.3;
        let n = 100_000;
        let hits = (0..n).filter(|_| ex.spiked(2)).count();
        let rate = hits as f64 / n as f64;
        assert!((rate - 0.3).abs() < 0.01, "rate {rate}");
    }

    #[test]
    fn zero_frequency_never_spikes() {
        let mut ex = FrequencyExchange::new(100, 4, Rng::new(8));
        assert!((0..1000).all(|_| !ex.spiked(1)));
    }

    #[test]
    fn scratch_reuse_keeps_accounting_identical() {
        // Two consecutive epoch boundaries through ONE FrequencyExchange
        // (reused hoisted send buffers) must produce exactly the
        // per-epoch counters of the first exchange: the scratch changes
        // allocation, not accounting (EXPERIMENTS.md §Perf, opt 6).
        let results = run_ranks(2, |comm| {
            let rank = comm.rank();
            let mut pop = make_pop(rank, 2);
            let mut store = SynapseStore::new(2);
            if rank == 0 {
                store.add_out(0, 2); // to rank 1
            }
            let mut ex = FrequencyExchange::new(10, 4, Rng::new(3));
            pop.epoch_spikes[0] = 5;
            assert!(ex.maybe_exchange(&comm, &mut pop, &store, 2, 0));
            let first = comm.counters().snapshot();
            pop.epoch_spikes[0] = 7;
            assert!(ex.maybe_exchange(&comm, &mut pop, &store, 2, 10));
            let second = comm.counters().snapshot().since(&first);
            (first, second)
        });
        for (first, second) in &results {
            assert_eq!(first, second);
        }
        // One 12-byte record rank0 -> rank1 per epoch, one collective each.
        assert_eq!(results[0].0.bytes_sent, 12);
        assert_eq!(results[0].0.msgs_sent, 1);
        assert_eq!(results[0].0.collectives, 1);
        assert_eq!(results[1].0.bytes_sent, 0);
        assert_eq!(results[1].0.bytes_recv, 12);
    }

    #[test]
    fn neurons_without_partners_send_nothing() {
        let results = run_ranks(2, |comm| {
            let mut pop = make_pop(comm.rank(), 4);
            pop.epoch_spikes.iter_mut().for_each(|s| *s = 50);
            let store = SynapseStore::new(4); // no synapses at all
            let mut ex = FrequencyExchange::new(10, 8, Rng::new(2));
            ex.maybe_exchange(&comm, &mut pop, &store, 4, 0);
            comm.counters().snapshot().bytes_sent
        });
        assert_eq!(results[0], 0);
        assert_eq!(results[1], 0);
    }
}
