//! New spike transmission: firing-*frequency* exchange every Δ steps +
//! PRNG reconstruction (paper §IV-B).
//!
//! At every epoch boundary each rank computes, for each local neuron
//! with remote out-partners, the firing frequency over the elapsed epoch
//! (spikes / Δ) and sends (id, frequency) records to the partner ranks.
//! In between, a receiving rank decides per remote in-edge per step with
//! probability = frequency whether the sender spiked. Spikes lose exact
//! timing across ranks — the approximation §V-D quantifies — but the
//! number of synchronization points drops by Δ and transfer volume
//! becomes independent of the firing rate.
//!
//! Receiver state is the **epoch-scoped sparse** [`PartnerFreqs`] table
//! (EXPERIMENTS.md §Perf, opt 7): O(local remote partners) per rank, not
//! O(total neurons), rebuilt from scratch at each boundary and pruned by
//! the connectivity update when an in-edge dies. Sender routing comes
//! from the `SynapseStore`'s incrementally-maintained out-rank table
//! instead of rescanning `out_edges` per firing neuron per exchange.

use crate::comm::{exchange_ref, Comm};
use crate::neuron::Population;
use crate::plasticity::SynapseStore;
use crate::util::wire::{get_f32, get_u64, put_f32, put_u64, Wire};
use crate::util::Rng;

use super::PartnerFreqs;

/// (neuron id, firing frequency) record — 12 B.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FreqRecord {
    pub id: u64,
    pub freq: f32,
}

impl Wire for FreqRecord {
    const SIZE: usize = 12;
    fn write(&self, out: &mut Vec<u8>) {
        put_u64(out, self.id);
        put_f32(out, self.freq);
    }
    fn read(buf: &[u8]) -> Self {
        FreqRecord { id: get_u64(buf, 0), freq: get_f32(buf, 4 + 4) }
    }
}

/// State of the new algorithm on one rank.
pub struct FrequencyExchange {
    /// Epoch length Δ (paper: 100 — every connectivity update).
    pub delta: usize,
    /// Sparse per-partner frequency table, epoch-scoped: rebuilt from
    /// the records received at each boundary, pruned on edge deletion.
    freqs: PartnerFreqs,
    /// PRNG for spike reconstruction.
    rng: Rng,
    /// Scratch: per-destination send lists, reused across epochs
    /// instead of rebuilding a `Vec<Vec<_>>` per exchange
    /// (EXPERIMENTS.md §Perf, opt 6).
    sends: Vec<Vec<FreqRecord>>,
    /// Slot-aligned Bernoulli thresholds: `slot_thrs[slot]` is the
    /// threshold of the sender the `DeliveryPlan` interned at `slot`
    /// (0.0 when that sender has no installed entry). Refilled by
    /// [`FrequencyExchange::install_slots`] whenever the table or the
    /// plan changes, so the per-edge draw site is one indexed load —
    /// no binary search, no per-draw `f as f64` (EXPERIMENTS.md §Perf,
    /// opt 8). Derived cache: never snapshotted.
    slot_thrs: Vec<f64>,
}

impl FrequencyExchange {
    pub fn new(delta: usize, rng: Rng) -> Self {
        FrequencyExchange {
            delta,
            freqs: PartnerFreqs::new(),
            rng,
            sends: Vec::new(),
            slot_thrs: Vec::new(),
        }
    }

    /// Run at epoch boundaries (`step % delta == 0`, excluding the
    /// degenerate step 0, which has no elapsed epoch to report and
    /// would cost one all-zero collective): exchange the frequencies
    /// accumulated over the previous epoch and reset the per-neuron
    /// spike counters. No-op on other steps — and crucially, no
    /// synchronization on other steps either.
    ///
    /// The received records **replace** the table: a sender with no
    /// surviving out-edge to this rank stops reporting, so its entry
    /// dies with the epoch instead of lingering indefinitely.
    pub fn maybe_exchange(
        &mut self,
        comm: &impl Comm,
        pop: &mut Population,
        store: &SynapseStore,
        step: usize,
    ) -> bool {
        if step == 0 || step % self.delta != 0 {
            return false;
        }
        let size = comm.size();
        self.sends.resize_with(size, Vec::new);
        let sends = &mut self.sends;
        sends.iter_mut().for_each(|s| s.clear());
        let me = comm.rank() as u32;
        for local in 0..pop.len() {
            let spikes = pop.epoch_spikes[local];
            pop.epoch_spikes[local] = 0;
            let routes = store.out_ranks(local);
            if routes.is_empty() {
                continue;
            }
            let rec = FreqRecord {
                id: pop.global_id(local),
                freq: spikes as f32 / self.delta as f32,
            };
            for &(rank, _) in routes {
                if rank != me {
                    sends[rank as usize].push(rec);
                }
            }
        }
        let incoming = exchange_ref(comm, sends);
        // Batches arrive in source-rank order; per-rank id ranges are
        // disjoint and each batch is in ascending id order, so the
        // concatenation is globally sorted — install is O(records).
        self.freqs.install_epoch(incoming.iter().flatten().map(|r| (r.id, r.freq)));
        true
    }

    /// Drop frequency entries whose last in-edge from that source was
    /// deleted (the `SynapseStore` refcounts are maintained at the
    /// deletion site). The driver calls this right after the deletion
    /// sub-phase of every connectivity update — before formation, so
    /// even an edge deleted and re-formed **within one plasticity
    /// phase** (let alone one epoch) reconstructs against 0.0 instead
    /// of the dead edge's last reported frequency — the other half of
    /// the staleness fix, for the window the boundary rebuild cannot
    /// cover.
    pub fn prune_stale(&mut self, store: &SynapseStore) {
        self.freqs.retain(|id| store.in_partner_count(id) > 0);
    }

    /// Reconstruct: did remote neuron `id` spike this step? One PRNG
    /// draw against its last known frequency (paper Fig. 5, "PRNG");
    /// an absent entry is frequency 0.0 and never draws. The threshold
    /// is precomputed at install time (`f as f64` is exact, so draws
    /// are bit-identical to the former inline conversion). Id-keyed
    /// oracle path — the driver draws through [`Self::spiked_slot`].
    #[inline]
    pub fn spiked(&mut self, id: u64) -> bool {
        let t = self.freqs.get_thr(id);
        t > 0.0 && self.rng.bernoulli(t)
    }

    /// Reconstruct by plan slot: the O(1) draw site behind
    /// `DeliveryPlan::deliver` — one indexed load instead of the
    /// oracle's binary search, same PRNG stream (a zero threshold
    /// never draws, exactly like a zero or missing frequency).
    #[inline]
    pub fn spiked_slot(&mut self, slot: usize) -> bool {
        let t = self.slot_thrs[slot];
        t > 0.0 && self.rng.bernoulli(t)
    }

    /// Refill the slot-aligned threshold array from the installed
    /// frequency table for `plan`'s slot interning. The driver calls
    /// this after every epoch install, plan recompile, and snapshot
    /// restore — the three points where table and slots can diverge.
    pub fn install_slots(&mut self, plan: &super::DeliveryPlan) {
        let slot_thrs = &mut self.slot_thrs;
        self.freqs.fill_slot_thrs(plan.remote_ids(), slot_thrs);
    }

    /// Last received frequency of a neuron (tests/inspection); 0.0 when
    /// no entry is installed.
    pub fn freq_of(&self, id: u64) -> f32 {
        self.freqs.get(id)
    }

    /// The installed entry of sender `id`, distinguishing an explicit
    /// zero from absence — the migration packer ships entries
    /// bit-faithfully so a migrated-and-returned neuron restores the
    /// exact table.
    pub fn entry_of(&self, id: u64) -> Option<f32> {
        self.freqs.lookup(id)
    }

    /// Number of partners with an installed entry (tests/inspection).
    pub fn partner_count(&self) -> usize {
        self.freqs.len()
    }

    /// *Logical* size of the reconstruction state: 12 B per installed
    /// (u64 id, f32 frequency) record — the per-rank quantity the bench
    /// harness reports as `spike_state_bytes` (O(local partners), not
    /// O(total neurons)). Derived caches (the precomputed f64
    /// thresholds and the slot-aligned array) are deliberately
    /// excluded: they are rebuildable acceleration state, and the
    /// counter's meaning is pinned by baseline drift checks.
    pub fn state_bytes(&self) -> u64 {
        self.freqs.state_bytes()
    }

    // -- checkpoint/restore accessors (see `snapshot`) -------------------

    /// The sparse (id, frequency) entries, for snapshotting. Mid-epoch
    /// these hold the frequencies received at the last epoch boundary,
    /// which the receiver keeps consulting until the next exchange — so
    /// a restored rank must get these back bit-exactly.
    pub fn entries(&self) -> Vec<(u64, f32)> {
        self.freqs.entries()
    }

    /// Borrowing variant of [`Self::entries`] for the snapshot writer
    /// path: the capture that runs inside the step loop encodes the
    /// entries straight from this iterator instead of allocating a
    /// fresh `Vec` per checkpoint.
    pub fn entries_iter(&self) -> impl ExactSizeIterator<Item = (u64, f32)> + '_ {
        self.freqs.entries_iter()
    }

    /// Reconstruction-PRNG state, for snapshotting.
    pub fn rng_state(&self) -> crate::util::RngState {
        self.rng.state()
    }

    /// Rebuild an exchange from snapshotted parts. The entries must be
    /// strictly ascending by id (the sparse table's lookup invariant).
    pub fn from_parts(
        delta: usize,
        entries: Vec<(u64, f32)>,
        rng: crate::util::RngState,
    ) -> Result<FrequencyExchange, String> {
        Ok(FrequencyExchange {
            delta,
            freqs: PartnerFreqs::from_entries(entries)?,
            rng: Rng::from_state(rng),
            sends: Vec::new(),
            slot_thrs: Vec::new(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::run_ranks;
    use crate::config::SimConfig;
    use crate::util::Vec3;

    fn make_pop(rank: usize, n: usize) -> Population {
        let cfg = SimConfig { neurons_per_rank: n, ..SimConfig::default() };
        let mut rng = Rng::new(4);
        Population::init(&cfg, rank, Vec3::ZERO, Vec3::splat(10.0), &mut rng)
    }

    #[test]
    fn record_is_12_bytes() {
        assert_eq!(FreqRecord::SIZE, 12);
        let r = FreqRecord { id: 77, freq: 0.25 };
        let mut buf = Vec::new();
        r.write(&mut buf);
        assert_eq!(buf.len(), 12);
        assert_eq!(FreqRecord::read(&buf), r);
    }

    #[test]
    fn frequencies_cross_ranks_at_epoch_boundaries_only() {
        let results = run_ranks(2, |comm| {
            let rank = comm.rank();
            let mut pop = make_pop(rank, 2);
            let mut store = SynapseStore::new(2, 2);
            if rank == 0 {
                store.add_out(0, 2); // to rank 1
                pop.epoch_spikes[0] = 10; // fired 10 times this epoch
            }
            let mut ex = FrequencyExchange::new(100, Rng::new(1));
            // Mid-epoch: nothing happens, no synchronization.
            assert!(!ex.maybe_exchange(&comm, &mut pop, &store, 50));
            assert_eq!(comm.counters().snapshot().collectives, 0);
            // Epoch boundary: records move.
            assert!(ex.maybe_exchange(&comm, &mut pop, &store, 100));
            (ex, pop, comm.counters().snapshot())
        });
        let (ex1, _, _) = &results[1];
        assert!((ex1.freq_of(0) - 0.1).abs() < 1e-6);
        assert_eq!(ex1.partner_count(), 1);
        assert_eq!(ex1.state_bytes(), 12);
        // Sender reset its epoch counter and holds no receiver state.
        assert_eq!(results[0].1.epoch_spikes[0], 0);
        assert_eq!(results[0].0.partner_count(), 0);
        // 12 bytes went rank0 -> rank1.
        assert_eq!(results[0].2.bytes_sent, 12);
        assert_eq!(results[1].2.bytes_sent, 0);
    }

    #[test]
    fn step_zero_is_not_an_epoch_boundary() {
        // The old behavior exchanged a zero-length epoch of all-zero
        // frequencies at step 0 — one wasted collective per run that
        // polluted bench counters. The degenerate boundary is skipped.
        let results = run_ranks(2, |comm| {
            let rank = comm.rank();
            let mut pop = make_pop(rank, 2);
            let mut store = SynapseStore::new(2, 2);
            if rank == 0 {
                store.add_out(0, 2);
            }
            let mut ex = FrequencyExchange::new(10, Rng::new(5));
            assert!(!ex.maybe_exchange(&comm, &mut pop, &store, 0));
            comm.counters().snapshot()
        });
        for snap in results {
            assert_eq!(snap.collectives, 0);
            assert_eq!(snap.bytes_sent, 0);
        }
    }

    #[test]
    fn reconstruction_matches_frequency_statistically() {
        let mut ex =
            FrequencyExchange::from_parts(100, vec![(2, 0.3)], Rng::new(7).state()).unwrap();
        let n = 100_000;
        let hits = (0..n).filter(|_| ex.spiked(2)).count();
        let rate = hits as f64 / n as f64;
        assert!((rate - 0.3).abs() < 0.01, "rate {rate}");
    }

    #[test]
    fn slot_draws_match_id_draws_bit_exactly() {
        // Same entries, same PRNG state: drawing through the plan slots
        // must produce the identical spike sequence AND leave the PRNG
        // at the identical stream position as the id-keyed oracle —
        // including never drawing for zero-frequency/missing slots.
        let mut store = SynapseStore::new(2, 2);
        store.add_in(0, 3, true); // rank 1
        store.add_in(1, 4, true); // rank 2
        store.add_in(0, 6, false); // rank 3, no installed entry
        let plan = crate::spikes::DeliveryPlan::compile(&store, 0);
        let entries = vec![(3u64, 0.6f32), (4, 0.0)];
        let st = Rng::new(13).state();
        let mut by_id = FrequencyExchange::from_parts(10, entries.clone(), st).unwrap();
        let mut by_slot = FrequencyExchange::from_parts(10, entries, st).unwrap();
        by_slot.install_slots(&plan);
        assert_eq!(plan.remote_ids(), &[3, 4, 6]);
        for _ in 0..500 {
            for (slot, &id) in plan.remote_ids().iter().enumerate() {
                assert_eq!(by_id.spiked(id), by_slot.spiked_slot(slot));
            }
        }
        assert_eq!(by_id.rng_state(), by_slot.rng_state(), "stream positions");
    }

    #[test]
    fn entries_iter_borrows_what_entries_allocates() {
        let ex =
            FrequencyExchange::from_parts(10, vec![(2, 0.5), (8, 0.25)], Rng::new(1).state())
                .unwrap();
        let borrowed: Vec<(u64, f32)> = ex.entries_iter().collect();
        assert_eq!(borrowed, ex.entries());
        assert_eq!(ex.entries_iter().len(), 2);
    }

    #[test]
    fn zero_frequency_never_spikes() {
        let mut ex = FrequencyExchange::new(100, Rng::new(8));
        assert!((0..1000).all(|_| !ex.spiked(1)));
    }

    #[test]
    fn stale_frequency_is_not_reused_after_edge_reform() {
        // The headline regression (ISSUE 3): a remote in-edge is
        // deleted, at least one epoch boundary passes (the sender stops
        // reporting, so under the old dense table its last frequency
        // would sit there stale forever), then the edge re-forms
        // mid-epoch. Reconstruction must draw against 0.0.
        let results = run_ranks(2, |comm| {
            let rank = comm.rank();
            let mut pop = make_pop(rank, 2);
            let mut store = SynapseStore::new(2, 2);
            if rank == 0 {
                store.add_out(0, 2); // to rank 1's neuron 2
            } else {
                store.add_in(0, 0, true); // from rank 0's neuron 0
            }
            let mut ex = FrequencyExchange::new(10, Rng::new(11));
            // Boundary 1: sender reports a saturated frequency.
            if rank == 0 {
                pop.epoch_spikes[0] = 10; // freq 1.0
            }
            assert!(ex.maybe_exchange(&comm, &mut pop, &store, 10));
            if rank == 1 {
                assert!((ex.freq_of(0) - 1.0).abs() < 1e-6);
            }
            // Mid-epoch: the edge is deleted on both sides; the
            // connectivity update prunes receiver state.
            if rank == 0 {
                assert!(store.remove_specific_out(0, 2));
            } else {
                assert!(store.remove_specific_in(0, 0));
            }
            ex.prune_stale(&store);
            // Boundary 2: the sender no longer reports this rank.
            assert!(ex.maybe_exchange(&comm, &mut pop, &store, 20));
            // Mid-epoch: the edge re-forms.
            if rank == 0 {
                store.add_out(0, 2);
            } else {
                store.add_in(0, 0, true);
            }
            // Reconstruction draws against 0.0, not the stale 1.0 (which
            // would make EVERY draw a spike).
            if rank == 1 {
                assert_eq!(ex.freq_of(0), 0.0);
                assert!((0..1000).all(|_| !ex.spiked(0)));
            }
            ex.partner_count()
        });
        assert_eq!(results[1], 0);
    }

    #[test]
    fn prune_drops_entry_when_last_in_edge_dies_within_an_epoch() {
        // Deletion + re-formation inside ONE epoch: the boundary
        // rebuild cannot help here, only the deletion-site prune can.
        let mut store = SynapseStore::new(1, 1);
        store.add_in(0, 5, true); // remote source 5
        let mut ex =
            FrequencyExchange::from_parts(10, vec![(5, 0.8)], Rng::new(2).state()).unwrap();
        assert_eq!(ex.freq_of(5), 0.8);
        assert!(store.remove_specific_in(0, 5));
        ex.prune_stale(&store);
        store.add_in(0, 5, true); // re-formed in the same epoch
        assert_eq!(ex.freq_of(5), 0.0, "re-formed edge must start from zero");
        assert!((0..1000).all(|_| !ex.spiked(5)));
    }

    #[test]
    fn prune_keeps_partners_with_surviving_in_edges() {
        // Source 4 feeds two local targets; deleting one edge must NOT
        // drop the entry — its frequency is still current for the other.
        let mut store = SynapseStore::new(2, 2);
        store.add_in(0, 4, true);
        store.add_in(1, 4, true);
        let mut ex =
            FrequencyExchange::from_parts(10, vec![(4, 0.5)], Rng::new(3).state()).unwrap();
        assert!(store.remove_specific_in(0, 4));
        ex.prune_stale(&store);
        assert_eq!(ex.freq_of(4), 0.5);
        assert!(store.remove_specific_in(1, 4));
        ex.prune_stale(&store);
        assert_eq!(ex.freq_of(4), 0.0);
        assert_eq!(ex.partner_count(), 0);
    }

    #[test]
    fn scratch_reuse_keeps_accounting_identical() {
        // Two consecutive epoch boundaries through ONE FrequencyExchange
        // (reused hoisted send buffers) must produce exactly the
        // per-epoch counters of the first exchange: the scratch changes
        // allocation, not accounting (EXPERIMENTS.md §Perf, opt 6).
        let results = run_ranks(2, |comm| {
            let rank = comm.rank();
            let mut pop = make_pop(rank, 2);
            let mut store = SynapseStore::new(2, 2);
            if rank == 0 {
                store.add_out(0, 2); // to rank 1
            }
            let mut ex = FrequencyExchange::new(10, Rng::new(3));
            pop.epoch_spikes[0] = 5;
            assert!(ex.maybe_exchange(&comm, &mut pop, &store, 10));
            let first = comm.counters().snapshot();
            pop.epoch_spikes[0] = 7;
            assert!(ex.maybe_exchange(&comm, &mut pop, &store, 20));
            let second = comm.counters().snapshot().since(&first);
            (first, second)
        });
        for (first, second) in &results {
            assert_eq!(first, second);
        }
        // One 12-byte record rank0 -> rank1 per epoch, one collective each.
        assert_eq!(results[0].0.bytes_sent, 12);
        assert_eq!(results[0].0.msgs_sent, 1);
        assert_eq!(results[0].0.collectives, 1);
        assert_eq!(results[1].0.bytes_sent, 0);
        assert_eq!(results[1].0.bytes_recv, 12);
    }

    #[test]
    fn neurons_without_partners_send_nothing() {
        let results = run_ranks(2, |comm| {
            let mut pop = make_pop(comm.rank(), 4);
            pop.epoch_spikes.iter_mut().for_each(|s| *s = 50);
            let store = SynapseStore::new(4, 4); // no synapses at all
            let mut ex = FrequencyExchange::new(10, Rng::new(2));
            ex.maybe_exchange(&comm, &mut pop, &store, 10);
            comm.counters().snapshot().bytes_sent
        });
        assert_eq!(results[0], 0);
        assert_eq!(results[1], 0);
    }

    #[test]
    fn routing_sends_one_record_per_partner_rank() {
        // A neuron with out-edges on two remote ranks (and one local)
        // must send exactly one record to each remote partner rank —
        // driven by the incrementally-maintained out-rank table, with
        // wire order identical to the old dest_flags rescan.
        let results = run_ranks(3, |comm| {
            let rank = comm.rank();
            let mut pop = make_pop(rank, 2);
            let mut store = SynapseStore::new(2, 2);
            if rank == 0 {
                store.add_out(0, 1); // local: never sent
                store.add_out(0, 2); // rank 1
                store.add_out(0, 3); // rank 1 again: still one record
                store.add_out(0, 4); // rank 2
                pop.epoch_spikes[0] = 5;
            }
            let mut ex = FrequencyExchange::new(10, Rng::new(6));
            assert!(ex.maybe_exchange(&comm, &mut pop, &store, 10));
            (ex, comm.counters().snapshot())
        });
        // 12 B to rank 1 + 12 B to rank 2, one message each.
        assert_eq!(results[0].1.bytes_sent, 24);
        assert_eq!(results[0].1.msgs_sent, 2);
        assert!((results[1].0.freq_of(0) - 0.5).abs() < 1e-6);
        assert!((results[2].0.freq_of(0) - 0.5).abs() < 1e-6);
    }
}
