//! Epoch-compiled spike delivery: a CSR in-edge plan with O(1)
//! slot-interned remote lookups (EXPERIMENTS.md §Perf, opt 8).
//!
//! The per-step delivery loop is the only O(edges)-per-step work in the
//! simulator, and the naive loop pays, per edge per step, a u64 division
//! (owner-rank derivation), a `Vec<Vec<InEdge>>` pointer chase, and a
//! binary search (`PartnerFreqs::get` for the new spike algorithm,
//! `sorted[src_rank].binary_search` for the old). [`DeliveryPlan`]
//! compiles all of that out once per connectivity update:
//!
//! * the in-edge lists flatten into **one contiguous edge array** with
//!   per-neuron offsets (CSR), each neuron's edges partitioned
//!   **local-first** so the inner loop splits into two branch-light
//!   sequential scans;
//! * every **local** edge carries the pre-resolved local source index
//!   plus its signed weight — delivery is one `fired[idx]` load;
//! * every **remote** edge carries a *slot*: an index into the plan's
//!   table of unique remote sources (interned in ascending id order)
//!   plus its signed weight — delivery is one `O(1)` indexed load into
//!   whatever per-slot state the spike algorithm maintains
//!   (`FrequencyExchange::spiked_slot`, `IdExchange::slot_fired`).
//!
//! The plan is **derived state**: `SynapseStore` edit sites bump an
//! in-edge generation counter ([`SynapseStore::in_edits`]), the driver
//! recompiles after any plasticity phase that edited in-edges and on
//! snapshot restore (the plan is never stored in the ILMISNAP format),
//! and [`DeliveryPlan::check_against`] cross-validates a plan against
//! the store it claims to compile.
//!
//! Bit-exactness contract: within one neuron the local/remote partition
//! keeps each class in its original edge order, so the sequence of
//! remote edges — and with it the reconstruction-PRNG draw order of the
//! new algorithm, including its draw-iff-frequency>0 rule — is exactly
//! the naive loop's. The synaptic sum reorders ±1.0 terms only;
//! f32 addition of small integers is exact, so `i_syn` is bit-identical
//! (the differential oracle tests below pin all of this).

use crate::balance::OwnershipMap;
use crate::neuron::{GlobalNeuronId, Population};
use crate::plasticity::SynapseStore;

use super::spike_weight;

/// Edges per delivery chunk: 1024 × 8 B = 8 KiB of planned edges per
/// chunk — a quarter of a typical 32 KiB L1d, leaving room for the
/// `fired`/slot-state stripes the edges index into. Chunking changes
/// neither the edge order nor the single-accumulator sum, so delivery
/// stays bit-identical to the unchunked loop (see `deliver`).
pub const EDGE_BLOCK: usize = 1024;

/// One compiled in-edge: a pre-resolved index (local source index for
/// local edges, remote-source *slot* for remote ones) and the signed
/// synaptic weight (+1.0 excitatory, −1.0 inhibitory). 8 B, so a
/// cache line holds eight edges.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PlannedEdge {
    pub idx: u32,
    pub weight: f32,
}

/// The epoch-compiled delivery plan of one rank (see module docs).
#[derive(Clone, Debug, PartialEq)]
pub struct DeliveryPlan {
    /// First global id of the local population (locality resolution).
    first_id: GlobalNeuronId,
    /// Ownership map the plan was compiled with (locality and slot
    /// decisions are relative to it; a migration that changes the map
    /// rebuilds the store, which forces a recompile).
    owners: OwnershipMap,
    /// CSR offsets into `edges`, length n+1.
    offsets: Vec<u32>,
    /// Per neuron: index into `edges` where its remote edges begin
    /// (`edges[offsets[i]..remote_starts[i]]` are its local edges).
    remote_starts: Vec<u32>,
    /// All in-edges, flattened; per neuron local-first, each class in
    /// its original `SynapseStore::in_edges` order.
    edges: Vec<PlannedEdge>,
    /// Slot table: the unique remote source ids, strictly ascending
    /// (`remote_ids[slot]` is the sender the slot stands for).
    remote_ids: Vec<GlobalNeuronId>,
    /// Total remote edges (== lookups per delivery step).
    remote_edges: u64,
    /// `SynapseStore::in_edits` value the plan was compiled at.
    generation: u64,
}

impl Default for DeliveryPlan {
    /// A valid plan for zero neurons (the placeholder `RankState`
    /// construction holds before its first `rebuild_plan`). `offsets`
    /// must be `[0]`, never empty: the CSR invariant is length n+1, and
    /// a derived empty `Vec` would make `deliver` underflow.
    fn default() -> DeliveryPlan {
        DeliveryPlan {
            first_id: 0,
            owners: OwnershipMap::stride(1),
            offsets: vec![0],
            remote_starts: Vec::new(),
            edges: Vec::new(),
            remote_ids: Vec::new(),
            remote_edges: 0,
            generation: 0,
        }
    }
}

impl DeliveryPlan {
    /// Compile the store's in-edge lists into the CSR plan. Run once
    /// per connectivity update that edited in-edges — all divisions and
    /// id searches the per-step loop used to pay happen here instead.
    pub fn compile(store: &SynapseStore, first_id: GlobalNeuronId) -> DeliveryPlan {
        let owners = store.owners();
        let my_rank = owners.rank_of(first_id);
        let n = store.in_edges.len();

        // Slot table: unique remote sources in ascending id order. The
        // store's in-partner refcount map is already sorted and unique.
        let remote_ids: Vec<GlobalNeuronId> = store
            .in_partners()
            .map(|(id, _)| id)
            .filter(|&id| owners.rank_of(id) != my_rank)
            .collect();

        let total = store.total_in();
        assert!(total <= u32::MAX as usize, "edge count overflows the u32 CSR");
        let mut edges = Vec::with_capacity(total);
        let mut offsets = Vec::with_capacity(n + 1);
        let mut remote_starts = Vec::with_capacity(n);
        let mut remote_edges = 0u64;
        offsets.push(0);
        for in_edges in &store.in_edges {
            for e in in_edges {
                if owners.rank_of(e.source) == my_rank {
                    edges.push(PlannedEdge {
                        idx: (e.source - first_id) as u32,
                        weight: spike_weight(e.source_exc),
                    });
                }
            }
            remote_starts.push(edges.len() as u32);
            for e in in_edges {
                if owners.rank_of(e.source) != my_rank {
                    let slot = remote_ids
                        .binary_search(&e.source)
                        .expect("remote in-edge source missing from slot table");
                    edges.push(PlannedEdge {
                        idx: slot as u32,
                        weight: spike_weight(e.source_exc),
                    });
                    remote_edges += 1;
                }
            }
            offsets.push(edges.len() as u32);
        }
        DeliveryPlan {
            first_id,
            owners: owners.clone(),
            offsets,
            remote_starts,
            edges,
            remote_ids,
            remote_edges,
            generation: store.in_edits(),
        }
    }

    /// Accumulate synaptic input for every local neuron through the
    /// compiled plan: branch-light sequential reads, zero division,
    /// zero per-edge search. `remote_spiked(slot)` answers "did the
    /// sender interned at `slot` spike this step" — it is called once
    /// per remote edge, in exactly the naive loop's remote-edge order.
    /// Returns the number of remote look-ups performed (the paper's
    /// Fig. 5 quantity, identical to the naive loop's count).
    ///
    /// Both edge walks run in [`EDGE_BLOCK`]-sized chunks (ROADMAP
    /// item 2: cache-block the delivery hot loop). A neuron's input is
    /// still one left-to-right accumulation into a single `acc`, so the
    /// f32 addition sequence — and therefore every result bit — is
    /// identical to the unchunked loop; the chunking only bounds the
    /// working set the prefetcher has to track per iteration.
    pub fn deliver(
        &self,
        pop: &mut Population,
        mut remote_spiked: impl FnMut(usize) -> bool,
    ) -> u64 {
        let n = self.offsets.len() - 1;
        debug_assert_eq!(n, pop.len(), "plan compiled for a different population");
        debug_assert_eq!(self.first_id, pop.first_id);
        for local in 0..n {
            let lo = self.offsets[local] as usize;
            let mid = self.remote_starts[local] as usize;
            let hi = self.offsets[local + 1] as usize;
            let mut acc = 0.0f32;
            for chunk in self.edges[lo..mid].chunks(EDGE_BLOCK) {
                for e in chunk {
                    if pop.fired[e.idx as usize] {
                        acc += e.weight;
                    }
                }
            }
            for chunk in self.edges[mid..hi].chunks(EDGE_BLOCK) {
                for e in chunk {
                    if remote_spiked(e.idx as usize) {
                        acc += e.weight;
                    }
                }
            }
            pop.i_syn[local] = acc;
        }
        self.remote_edges
    }

    /// Is this plan compiled against the store's current in-edge set?
    /// (The edit sites bump the generation; equal generations mean no
    /// in-edge was added or deleted since `compile`.)
    pub fn is_current(&self, store: &SynapseStore) -> bool {
        self.generation == store.in_edits()
    }

    /// Number of interned remote sources (slots).
    pub fn slot_count(&self) -> usize {
        self.remote_ids.len()
    }

    /// The interned remote source ids, ascending (`[slot] -> id`). The
    /// owning rank of a slot, when needed, comes from the ownership
    /// map's `rank_of` — not cached: no per-step consumer exists.
    pub fn remote_ids(&self) -> &[GlobalNeuronId] {
        &self.remote_ids
    }

    /// Remote in-edges in the plan (== remote lookups per step).
    pub fn remote_edge_count(&self) -> u64 {
        self.remote_edges
    }

    /// Total planned edges (local + remote).
    pub fn edge_count(&self) -> usize {
        self.edges.len()
    }

    /// Cross-validate this plan against `store`: it must be current
    /// (generation) and structurally identical to a fresh compile of
    /// the store's edge lists. Used by the invariant checks and the
    /// driver's debug assertions — a plan that drifts from its store
    /// silently mis-delivers spikes, which is exactly the failure mode
    /// this catches.
    pub fn check_against(&self, store: &SynapseStore) -> Result<(), String> {
        if !self.is_current(store) {
            return Err(format!(
                "delivery plan is stale: compiled at in-edit generation {}, store is at {}",
                self.generation,
                store.in_edits()
            ));
        }
        let fresh = DeliveryPlan::compile(store, self.first_id);
        if self.remote_ids != fresh.remote_ids {
            return Err("delivery plan slot table disagrees with store in-partners".to_string());
        }
        if self.offsets != fresh.offsets
            || self.remote_starts != fresh.remote_starts
            || self.edges != fresh.edges
        {
            return Err("delivery plan CSR disagrees with store in-edges".to_string());
        }
        if self.remote_edges != fresh.remote_edges || self.owners != fresh.owners {
            return Err("delivery plan summary counters disagree with store".to_string());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::{deliver_input, FrequencyExchange, IdExchange};
    use super::*;
    use crate::comm::run_ranks;
    use crate::config::SimConfig;
    use crate::testing::forall;
    use crate::util::{Rng, Vec3};

    fn make_pop(rank: usize, n: usize, seed: u64) -> Population {
        let cfg = SimConfig { neurons_per_rank: n, ..SimConfig::default() };
        let mut rng = Rng::new(seed);
        Population::init(&cfg, rank, Vec3::ZERO, Vec3::splat(10.0), &mut rng)
    }

    #[test]
    fn csr_partitions_local_first_and_interns_slots_ascending() {
        // Rank 0 of stride 4: ids 0..4 local, the rest remote.
        let mut store = SynapseStore::new(2, 4);
        store.add_in(0, 9, true); // remote (rank 2)
        store.add_in(0, 1, false); // local
        store.add_in(0, 5, false); // remote (rank 1)
        store.add_in(1, 9, true); // remote, same source as neuron 0's
        store.add_in(1, 2, true); // local
        let plan = DeliveryPlan::compile(&store, 0);
        // Slots: unique remote sources, ascending.
        assert_eq!(plan.remote_ids(), &[5, 9]);
        assert_eq!(plan.slot_count(), 2);
        assert_eq!(plan.remote_edge_count(), 3);
        assert_eq!(plan.edge_count(), 5);
        // Neuron 0: local (1, inh) first, then remotes 9, 5 in original
        // edge order (NOT id order — draw order must match the naive
        // loop, which walks edges as stored).
        assert_eq!(plan.offsets, vec![0, 3, 5]);
        assert_eq!(plan.remote_starts, vec![1, 4]);
        assert_eq!(plan.edges[0], PlannedEdge { idx: 1, weight: -1.0 });
        assert_eq!(plan.edges[1], PlannedEdge { idx: 1, weight: 1.0 }); // slot of id 9
        assert_eq!(plan.edges[2], PlannedEdge { idx: 0, weight: -1.0 }); // slot of id 5
        // Neuron 1: local 2 then remote 9.
        assert_eq!(plan.edges[3], PlannedEdge { idx: 2, weight: 1.0 });
        assert_eq!(plan.edges[4], PlannedEdge { idx: 1, weight: 1.0 });
        plan.check_against(&store).unwrap();
    }

    #[test]
    fn uniform_ranges_plan_is_structurally_identical_to_stride() {
        // Identical in-edge edits against a Stride store and a uniform
        // Ranges store must intern the identical slot table and compile
        // the identical CSR (only the ownership representation differs;
        // everything derived from it must not).
        let mut rng = Rng::new(99);
        let starts: Vec<u64> = (0..=3u64).map(|r| r * 4).collect();
        let mut sa = SynapseStore::new(4, 4);
        let mut sb = SynapseStore::with_owners(
            4,
            crate::balance::OwnershipMap::ranges(starts).unwrap(),
        );
        for _ in 0..40 {
            let tgt = rng.next_below(4);
            let src = rng.next_below(12) as u64;
            let exc = rng.bernoulli(0.5);
            sa.add_in(tgt, src, exc);
            sb.add_in(tgt, src, exc);
        }
        let pa = DeliveryPlan::compile(&sa, 4);
        let pb = DeliveryPlan::compile(&sb, 4);
        assert_eq!(pa.remote_ids, pb.remote_ids, "slot interning");
        assert_eq!(pa.offsets, pb.offsets);
        assert_eq!(pa.remote_starts, pb.remote_starts);
        assert_eq!(pa.edges, pb.edges);
        assert_eq!(pa.remote_edges, pb.remote_edges);
        pa.check_against(&sa).unwrap();
        pb.check_against(&sb).unwrap();
    }

    #[test]
    fn check_against_catches_stale_and_corrupt_plans() {
        let mut store = SynapseStore::new(2, 2);
        store.add_in(0, 2, true);
        let plan = DeliveryPlan::compile(&store, 0);
        plan.check_against(&store).unwrap();
        // An in-edge edit makes the plan stale.
        store.add_in(1, 3, false);
        assert!(plan.check_against(&store).unwrap_err().contains("stale"));
        assert!(!plan.is_current(&store));
        // A recompiled plan is current again.
        let plan = DeliveryPlan::compile(&store, 0);
        plan.check_against(&store).unwrap();
        // Structural corruption at equal generation is caught too.
        let mut bad = plan.clone();
        bad.remote_ids[0] = 999;
        assert!(bad.check_against(&store).unwrap_err().contains("slot table"));
        let mut bad = plan;
        bad.edges[0].weight = -bad.edges[0].weight;
        assert!(bad.check_against(&store).unwrap_err().contains("CSR"));
    }

    #[test]
    fn out_edge_edits_do_not_dirty_the_plan() {
        let mut store = SynapseStore::new(2, 2);
        store.add_in(0, 2, true);
        let plan = DeliveryPlan::compile(&store, 0);
        store.add_out(0, 3);
        assert!(store.remove_specific_out(0, 3));
        assert!(plan.is_current(&store), "axonal edits cannot change the in-edge plan");
        plan.check_against(&store).unwrap();
    }

    #[test]
    fn planned_delivery_matches_naive_on_crafted_store() {
        // Rank 1 of a 3-rank stride-2 layout: locals are ids 2, 3.
        let mut pop = make_pop(1, 2, 7);
        let mut store = SynapseStore::new(2, 2);
        store.add_in(0, 4, true); // remote rank 2
        store.add_in(0, 3, true); // local
        store.add_in(0, 1, false); // remote rank 0
        store.add_in(1, 2, false); // local
        pop.fired[0] = false;
        pop.fired[1] = true;
        let remote_fired = |id: u64| id == 4; // only id 4 spiked
        let owners = OwnershipMap::stride(2);
        let naive = deliver_input(&mut pop, &store, &owners, 1, |_, id| remote_fired(id));
        let naive_isyn: Vec<u32> = pop.i_syn.iter().map(|x| x.to_bits()).collect();

        let plan = DeliveryPlan::compile(&store, 2);
        let planned =
            plan.deliver(&mut pop, |slot| remote_fired(plan.remote_ids()[slot]));
        let plan_isyn: Vec<u32> = pop.i_syn.iter().map(|x| x.to_bits()).collect();
        assert_eq!(naive, planned, "lookup counts");
        assert_eq!(naive_isyn, plan_isyn, "i_syn bit patterns");
        assert_eq!(pop.i_syn[0], 2.0); // +1 (remote 4 fired) +1 (local 3 fired) +0 (remote 1 silent)
        assert_eq!(pop.i_syn[1], 0.0); // local inhibitory source 2 did not fire
    }

    /// Build a random dendritic topology on rank `my_rank` of a 3-rank,
    /// stride-8 layout and return (pop, store).
    fn random_topology(rng: &mut Rng, seed: u64) -> (Population, SynapseStore) {
        let pop = make_pop(1, 8, seed);
        let mut store = SynapseStore::new(8, 8);
        let n_edges = rng.next_below(40);
        for _ in 0..n_edges {
            let tgt = rng.next_below(8);
            let src = rng.next_below(24) as u64;
            store.add_in(tgt, src, rng.bernoulli(0.6));
        }
        (pop, store)
    }

    /// Sparse frequency entries for every remote in-partner of `store`
    /// (ascending by construction), with some zero frequencies mixed in
    /// to exercise the draw-iff-frequency>0 rule.
    fn random_freq_entries(rng: &mut Rng, store: &SynapseStore) -> Vec<(u64, f32)> {
        store
            .in_partners()
            .filter(|&(id, _)| id / 8 != 1)
            .map(|(id, _)| {
                let f = if rng.bernoulli(0.3) { 0.0 } else { rng.next_f32() };
                (id, f)
            })
            .collect()
    }

    fn randomize_fired(rng: &mut Rng, pop: &mut Population) {
        for f in pop.fired.iter_mut() {
            *f = rng.bernoulli(0.4);
        }
    }

    #[test]
    fn prop_plan_matches_oracle_new_algorithm_across_plasticity() {
        // The differential contract for the frequency algorithm:
        // identical i_syn bit patterns, identical lookup counts, and an
        // identical PRNG stream position after every step — including
        // across a delete/re-form plasticity phase mid-epoch.
        forall(
            "plan delivery ≡ naive oracle (new algorithm)",
            25,
            |rng| rng.next_u64(),
            |&seed| {
                let mut rng = Rng::new(seed);
                let (mut pop, mut store) = random_topology(&mut rng, seed ^ 1);
                let entries = random_freq_entries(&mut rng, &store);
                let rng_state = Rng::new(seed ^ 2).state();
                let mut naive_ex =
                    FrequencyExchange::from_parts(100, entries.clone(), rng_state)?;
                let mut plan_ex = FrequencyExchange::from_parts(100, entries, rng_state)?;
                let mut plan = DeliveryPlan::compile(&store, 8);
                plan.check_against(&store)?;
                plan_ex.install_slots(&plan);

                let owners = OwnershipMap::stride(8);
                for round in 0..4 {
                    randomize_fired(&mut rng, &mut pop);
                    let naive = deliver_input(&mut pop, &store, &owners, 1, |_, id| {
                        naive_ex.spiked(id)
                    });
                    let want: Vec<u32> = pop.i_syn.iter().map(|x| x.to_bits()).collect();
                    let planned =
                        plan.deliver(&mut pop, |slot| plan_ex.spiked_slot(slot));
                    let got: Vec<u32> = pop.i_syn.iter().map(|x| x.to_bits()).collect();
                    if naive != planned {
                        return Err(format!("round {round}: lookups {naive} vs {planned}"));
                    }
                    if want != got {
                        return Err(format!("round {round}: i_syn diverged"));
                    }
                    if naive_ex.rng_state() != plan_ex.rng_state() {
                        return Err(format!("round {round}: PRNG stream position diverged"));
                    }

                    // A mini plasticity phase: delete a few random
                    // in-edges, prune, re-form a few (possibly the same
                    // sources), then recompile — mid-epoch, so the
                    // surviving entries keep their frequencies.
                    for _ in 0..rng.next_below(4) {
                        let tgt = rng.next_below(8);
                        if let Some(&e) = store.in_edges[tgt].first() {
                            assert!(store.remove_specific_in(tgt, e.source));
                        }
                    }
                    naive_ex.prune_stale(&store);
                    plan_ex.prune_stale(&store);
                    for _ in 0..rng.next_below(4) {
                        store.add_in(rng.next_below(8), rng.next_below(24) as u64, true);
                    }
                    if !plan.is_current(&store) {
                        plan = DeliveryPlan::compile(&store, 8);
                    }
                    plan.check_against(&store)?;
                    plan_ex.install_slots(&plan);
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_plan_matches_oracle_old_algorithm() {
        // The id-exchange differential: the per-step slot bitmap
        // (scattered once per received fired id) must reproduce the
        // per-edge binary search bit-exactly, across ranks.
        forall(
            "plan delivery ≡ naive oracle (old algorithm)",
            8,
            |rng| rng.next_u64(),
            |&seed| {
                let results = run_ranks(2, move |comm| {
                    let rank = comm.rank();
                    let mut rng = Rng::new(seed ^ (rank as u64) << 3);
                    let mut pop = make_pop(rank, 8, seed ^ 9);
                    let mut store = SynapseStore::new(8, 8);
                    let other = 1 - rank;
                    for _ in 0..rng.next_below(24) {
                        // In-edge from a random neuron on the other rank
                        // (mirrored by an out-edge there, below).
                        store.add_in(
                            rng.next_below(8),
                            (other * 8 + rng.next_below(8)) as u64,
                            rng.bernoulli(0.5),
                        );
                    }
                    // Everyone broadcasts to the other rank so every
                    // fired id arrives (a superset of what edges need —
                    // receivers must ignore ids they hold no edge from).
                    for i in 0..8 {
                        store.add_out(i, (other * 8) as u64);
                        pop.fired[i] = rng.bernoulli(0.5);
                    }
                    let mut ex = IdExchange::new(2);
                    ex.exchange(&comm, &pop, &store);
                    let owners = OwnershipMap::stride(8);
                    let naive = deliver_input(&mut pop, &store, &owners, rank, |r, id| {
                        ex.spiked(r, id)
                    });
                    let want: Vec<u32> = pop.i_syn.iter().map(|x| x.to_bits()).collect();
                    let plan = DeliveryPlan::compile(&store, (rank * 8) as u64);
                    plan.check_against(&store).unwrap();
                    ex.scatter_slots(&plan);
                    let planned = plan.deliver(&mut pop, |slot| ex.slot_fired(slot));
                    let got: Vec<u32> = pop.i_syn.iter().map(|x| x.to_bits()).collect();
                    (naive, planned, want, got)
                });
                for (rank, (naive, planned, want, got)) in results.iter().enumerate() {
                    if naive != planned {
                        return Err(format!("rank {rank}: lookups {naive} vs {planned}"));
                    }
                    if want != got {
                        return Err(format!("rank {rank}: i_syn diverged"));
                    }
                }
                Ok(())
            },
        );
    }
}
