//! Backend-generic `Comm` semantics checks.
//!
//! Each check runs *inside* a rank closure — hand it the communicator
//! from `run_ranks` (threads) or `socket_ranks` (socket transport) and
//! it asserts the same contract on either backend. This is how the
//! property suite proves the two backends are interchangeable: the
//! identical check body, parameterized only by the transport
//! (DESIGN.md §11).

use std::panic::{catch_unwind, AssertUnwindSafe};

use crate::comm::{Comm, WindowKey};
use crate::util::Rng;

/// Deterministic payload for the (round, src → dst) message: both ends
/// can derive it independently, so routing errors show up as content
/// mismatches, not just length mismatches.
fn pattern_bytes(round: usize, src: usize, dst: usize, len: usize) -> Vec<u8> {
    (0..len).map(|i| ((src * 7 + dst * 13 + round * 31 + i) % 251) as u8).collect()
}

/// Run the panicking closure and return its panic message.
fn panic_message(f: impl FnOnce()) -> String {
    let err = catch_unwind(AssertUnwindSafe(f)).expect_err("expected a panic");
    err.downcast_ref::<String>()
        .cloned()
        .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
        .unwrap_or_else(|| "non-string panic payload".to_string())
}

/// `all_to_all` routes ragged (including empty and zero-length) buffer
/// patterns permutation-correctly, and the counter deltas follow the
/// accounting contract exactly: self-delivery free, `bytes_sent`/
/// `bytes_recv` summed over distinct-rank pairs, `msgs_sent` only for
/// non-empty sends, one collective per call — on *any* backend.
///
/// All ranks must call this with the same `seed` (the pattern table is
/// derived from it identically everywhere).
pub fn check_all_to_all_routes(comm: &impl Comm, seed: u64) {
    let me = comm.rank();
    let size = comm.size();
    let rounds = 8usize;
    // Shared-seed pattern table: lens[round][src][dst]. Round 0 is
    // forced all-empty — a zero-byte collective still synchronizes and
    // still counts as one collective, with zero messages.
    let mut rng = Rng::new(seed);
    let lens: Vec<Vec<Vec<usize>>> = (0..rounds)
        .map(|round| {
            (0..size)
                .map(|_| {
                    (0..size)
                        .map(|_| {
                            let len = if rng.bernoulli(0.3) {
                                0
                            } else {
                                (rng.next_u64() % 300) as usize
                            };
                            if round == 0 {
                                0
                            } else {
                                len
                            }
                        })
                        .collect()
                })
                .collect()
        })
        .collect();

    let base = comm.counters().snapshot();
    let (mut want_sent, mut want_recv, mut want_msgs) = (0u64, 0u64, 0u64);
    for (round, table) in lens.iter().enumerate() {
        let sends: Vec<Vec<u8>> =
            (0..size).map(|dst| pattern_bytes(round, me, dst, table[me][dst])).collect();
        let recvs = comm.all_to_all(sends);
        assert_eq!(recvs.len(), size, "round {round}: one buffer per source rank");
        for (src, buf) in recvs.iter().enumerate() {
            let want = pattern_bytes(round, src, me, table[src][me]);
            assert_eq!(buf, &want, "round {round}: wrong bytes from rank {src}");
        }
        for dst in (0..size).filter(|&d| d != me) {
            want_sent += table[me][dst] as u64;
            want_msgs += (table[me][dst] > 0) as u64;
        }
        for src in (0..size).filter(|&s| s != me) {
            want_recv += table[src][me] as u64;
        }
    }
    let now = comm.counters().snapshot();
    assert_eq!(now.bytes_sent - base.bytes_sent, want_sent, "bytes_sent accounting");
    assert_eq!(now.bytes_recv - base.bytes_recv, want_recv, "bytes_recv accounting");
    assert_eq!(now.msgs_sent - base.msgs_sent, want_msgs, "msgs_sent accounting");
    assert_eq!(now.collectives - base.collectives, rounds as u64, "collective accounting");
}

/// A failing `rma_get` — range past the window end, `offset + len`
/// overflowing `usize`, or a missing window — panics with the same
/// message shape on every backend, never poisons the communicator, and
/// leaves it usable. All ranks call this together (it synchronizes
/// internally).
pub fn check_rma_oob_fails_cleanly(comm: &impl Comm) {
    const KEY: WindowKey = 7001;
    const ABSENT: WindowKey = 7999;
    comm.publish_window(KEY, vec![0xAB; 16]);
    comm.barrier(); // fence: windows visible everywhere
    let target = (comm.rank() + 1) % comm.size();

    assert_eq!(comm.rma_get(target, KEY, 8, 8), vec![0xAB; 8], "in-range get");
    let rma_before = comm.counters().snapshot().bytes_rma;

    let msg = panic_message(|| {
        comm.rma_get(target, KEY, 10, 10);
    });
    assert!(msg.contains("rma_get out of bounds: 10+10 > 16"), "oob message: {msg}");

    let msg = panic_message(|| {
        comm.rma_get(target, KEY, usize::MAX, 2);
    });
    assert!(msg.contains("overflows usize"), "overflow message: {msg}");

    let msg = panic_message(|| {
        comm.rma_get(target, ABSENT, 0, 1);
    });
    assert!(msg.contains(&format!("no window {ABSENT}")), "missing-window message: {msg}");

    // Failed gets move no bytes and do not poison: the communicator
    // stays fully usable.
    assert_eq!(comm.counters().snapshot().bytes_rma, rma_before, "failed gets are free");
    assert!(!comm.is_poisoned(), "a failed get must not poison the communicator");
    assert_eq!(comm.rma_get(target, KEY, 0, 16), vec![0xAB; 16], "get after failures");
    comm.barrier(); // fence before retraction
    comm.retract_window(KEY);
}

/// The paper's exact message sizes (42 B new request, 9 B new response,
/// 17 B old request, 1 B old response) hold on the wire: the encoders
/// pin them, and on the socket transport each all_to_all buffer adds
/// exactly `FRAME_HEADER` bytes of framing on top — framing is
/// transport overhead, never counted traffic.
pub fn check_wire_pins() {
    use crate::barnes_hut::{NewRequest, NewResponse, OldRequest, OldResponse};
    use crate::util::wire::Wire;
    assert_eq!(NewRequest::SIZE, 42);
    assert_eq!(NewResponse::SIZE, 9);
    assert_eq!(OldRequest::SIZE, 17);
    assert_eq!(OldResponse::SIZE, 1);
    #[cfg(unix)]
    {
        use crate::comm::{decode_frame, encode_frame, FRAME_HEADER};
        for payload_len in [NewRequest::SIZE, NewResponse::SIZE, OldRequest::SIZE, 0] {
            let payload = vec![0x5A; payload_len];
            let frame = encode_frame(2, &payload);
            assert_eq!(frame.len(), FRAME_HEADER + payload_len);
            let (tag, back) = decode_frame(&frame).expect("frame round-trip");
            assert_eq!((tag, back), (2, payload));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::run_ranks;

    #[test]
    fn thread_backend_satisfies_all_to_all_property() {
        run_ranks(3, |comm| check_all_to_all_routes(&comm, 0xA11));
    }

    #[test]
    fn thread_backend_fails_rma_cleanly() {
        run_ranks(2, |comm| check_rma_oob_fails_cleanly(&comm));
    }

    #[test]
    fn wire_pins_hold() {
        check_wire_pins();
    }
}
