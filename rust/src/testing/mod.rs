//! Mini property-testing harness.
//!
//! The offline crate set has no `proptest`, so this provides the piece we
//! actually need: run a property over many PRNG-generated cases, and on
//! failure report the case index and seed so the exact case can be
//! replayed (`forall_seeded` with the printed seed).

pub mod comm_props;

use crate::util::Rng;

/// Run `prop` over `cases` random inputs drawn by `gen`. Panics with the
/// failing seed + case description on the first violation.
pub fn forall<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    forall_seeded(name, 0xDEFA17, cases, &mut gen, &mut prop);
}

/// Like `forall` with an explicit base seed (to replay a failure).
pub fn forall_seeded<T: std::fmt::Debug>(
    name: &str,
    base_seed: u64,
    cases: usize,
    gen: &mut impl FnMut(&mut Rng) -> T,
    prop: &mut impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64);
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property {name:?} failed at case {case} (seed {seed}):\n  {msg}\n  input: {input:?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_when_property_holds() {
        forall("u64 is even after doubling", 100, |rng| rng.next_u64() / 2 * 2, |&x| {
            if x % 2 == 0 {
                Ok(())
            } else {
                Err("odd".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property \"always fails\"")]
    fn reports_failure_with_seed() {
        forall("always fails", 10, |rng| rng.next_u64(), |_| Err("nope".into()));
    }
}
