//! `ilmi` — *I Like To Move It: Computation Instead of Data in the Brain*.
//!
//! A full reimplementation of the paper's structural-plasticity
//! simulation stack (MSP + distributed Barnes–Hut) with both
//! communication algorithms — the original RMA-download variant and the
//! proposed location-aware / frequency-approximation variants — on a
//! simulated-MPI substrate, with the per-neuron numeric hot path compiled
//! from JAX/Pallas to HLO and executed through PJRT.
//!
//! # Paper-section → module map
//!
//! | Paper section | What it describes | Module |
//! |---|---|---|
//! | §III-A | MSP step loop (spikes → activity → plasticity) | [`coordinator`] |
//! | §III-A0a | Electrical activity / Izhikevich model | [`neuron`] |
//! | §III-B | Distributed octree over Morton-order domains | [`octree`] |
//! | §III-B0c | Barnes–Hut target search (old, RMA download) | [`barnes_hut`] |
//! | §IV-A | Location-aware Barnes–Hut ("move computation") | [`barnes_hut`] |
//! | §IV-B | Frequency approximation of spike exchange | [`spikes`] |
//! | §V-B | Timing experiments, phase breakdown (Fig. 11) | [`metrics`], [`bench`] |
//! | §V-C | Transferred-bytes accounting (Tables I/II) | [`comm`] |
//! | §V-D | Calcium-quality experiment (Figs. 8/9) | [`neuron`], `quality` CLI |
//! | — | Synapse bookkeeping + deletion protocol | [`plasticity`] |
//! | — | AOT artifact execution through PJRT | [`runtime`] |
//! | — | Checkpoint/restore + scenario branching | [`snapshot`] |
//! | — | Benchmark matrix + `BENCH_*.json` trajectories | [`bench`] |
//! | — | Dynamic load balancing (neuron migration) | [`balance`] |
//! | — | Epoch-granular telemetry (Perfetto/JSONL export) | [`trace`] |
//! | — | Fault injection + checkpoint-restart recovery | [`fault`] |
//! | — | Live telemetry: heartbeats, watchdog, `ilmi status` | [`telemetry`] |
//!
//! Entry points: [`config::SimConfig`] describes a run,
//! [`coordinator::run_simulation`] executes it,
//! [`snapshot::Snapshot`] reopens a checkpointed one, and
//! [`bench::run_matrix`] measures a scenario matrix.
//!
//! See `DESIGN.md` for the architecture, `EXPERIMENTS.md` for the
//! recorded measurements (§Perf, §Bench), and `README.md` for the CLI
//! quickstart.

pub mod balance;
pub mod barnes_hut;
pub mod bench;
pub mod cli;
pub mod comm;
pub mod coordinator;
pub mod config;
pub mod fault;
pub mod metrics;
pub mod neuron;
pub mod octree;
pub mod plasticity;
pub mod runtime;
pub mod snapshot;
pub mod spikes;
pub mod telemetry;
pub mod testing;
pub mod trace;
pub mod util;
