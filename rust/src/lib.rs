//! `ilmi` — *I Like To Move It: Computation Instead of Data in the Brain*.
//!
//! A full reimplementation of the paper's structural-plasticity
//! simulation stack (MSP + distributed Barnes–Hut) with both
//! communication algorithms — the original RMA-download variant and the
//! proposed location-aware / frequency-approximation variants — on a
//! simulated-MPI substrate, with the per-neuron numeric hot path compiled
//! from JAX/Pallas to HLO and executed through PJRT.
//!
//! See DESIGN.md for the architecture and the experiment index.

pub mod barnes_hut;
pub mod cli;
pub mod comm;
pub mod coordinator;
pub mod config;
pub mod metrics;
pub mod neuron;
pub mod octree;
pub mod plasticity;
pub mod runtime;
pub mod snapshot;
pub mod spikes;
pub mod testing;
pub mod util;
