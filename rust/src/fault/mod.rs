//! Deterministic fault injection (DESIGN.md §13).
//!
//! A [`FaultPlan`] is a small, seeded-run-friendly description of
//! *exactly* which failures to inject and when: kill a named rank at a
//! named step, truncate or delay the nth outbound socket frame, stall
//! an RMA reply, or fail/corrupt a checkpoint write. Because every
//! trigger is keyed on deterministic counters (step index, per-process
//! frame ordinal) and never on wall clock, an injected run is exactly
//! reproducible — the property the recovery differential tests lean on.
//!
//! The plan travels two ways:
//!
//! * configuration: the `[faults] plan = ...` INI key or repeated
//!   `--fault` CLI flags populate `SimConfig::fault_plan`. The key is
//!   deliberately **never re-emitted** by `SimConfig::to_ini`, so the
//!   config INI embedded in snapshots (and therefore the snapshot
//!   bytes) of a faulted run is identical to a clean run's — which is
//!   what makes "recovered run ends bit-identical to the uninterrupted
//!   run" a meaningful invariant.
//! * process environment: the supervisor filters the plan down to the
//!   current launch attempt ([`FaultPlan::for_attempt`]) and ships it
//!   to rank processes via [`ENV_FAULT_PLAN`]; `proc::maybe_run_child`
//!   arms it process-globally before the communicator connects.
//!
//! Hooks are zero-cost when nothing is armed: each one is a single
//! `OnceLock::get()` returning `None` on the hot path.
//!
//! Spec grammar (`;`-separated faults, `,`-separated fields):
//!
//! ```text
//! kill:rank=1,step=120            # exit(KILL_EXIT_CODE) before step 120
//! frame_truncate:rank=1,nth=3,keep=2   # cut rank 1's 3rd data frame to 2 bytes
//! frame_delay:rank=0,nth=5,ms=40  # sleep 40ms before rank 0's 5th data frame
//! rma_stall:rank=0,nth=2,ms=40    # sleep 40ms before rank 0's 2nd RMA reply
//! ckpt_fail:step=100              # error the checkpoint write for next_step 100
//! ckpt_corrupt:step=100           # write that checkpoint truncated (invalid)
//! ```
//!
//! Every fault takes an optional `attempt=K` field (default 0): it only
//! fires on supervision attempt K, so an injected kill does not re-fire
//! after the supervisor respawns the fleet.
//!
//! `frame_delay` and `rma_stall` additionally take an optional `step=S`
//! gate (default 0): `nth` then counts only events occurring at or
//! after simulation step S. Frame ordinals from process start are hard
//! to predict across algorithm generations (rendezvous, initial
//! exchanges); the step gate lets a test say "hang the first frame
//! after step 30" — e.g. deterministically *after* the first checkpoint
//! exists, which is what the watchdog recovery tests need.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;

/// Environment variable carrying an attempt-filtered plan spec to rank
/// processes (consumed and removed by `proc::maybe_run_child`).
pub const ENV_FAULT_PLAN: &str = "ILMI_FAULT_PLAN";

/// Exit code used by an injected kill; distinctive so launcher
/// diagnostics ("exited with code 86 before reporting") read as an
/// injected fault, not an organic crash.
pub const KILL_EXIT_CODE: i32 = 86;

/// One injectable failure. `rank`-keyed faults act inside that rank's
/// process; checkpoint faults are keyed by the checkpoint's `next_step`
/// alone and fire in whichever process performs the write (under the
/// socket backend the assembling rank is a benign race — the *effect*,
/// a missing or invalid `step_N` snapshot, is deterministic).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fault {
    /// Terminate rank `rank`'s process immediately before executing
    /// (0-based) step `step`.
    Kill { rank: u32, step: u64 },
    /// Truncate rank `rank`'s `nth` (1-based) outbound data frame to
    /// `keep` bytes and shut the stream down: the peer sees a short
    /// read, the sender poisons itself — a deterministic transport
    /// failure.
    FrameTruncate { rank: u32, nth: u64, keep: u32 },
    /// Sleep `millis` before rank `rank`'s `nth` outbound data frame at
    /// or after step `step` (non-fatal by itself: exercises timeout
    /// headroom and, with a long sleep, the heartbeat watchdog).
    FrameDelay { rank: u32, nth: u64, millis: u64, step: u64 },
    /// Sleep `millis` before rank `rank` serves its `nth` RMA reply at
    /// or after step `step`.
    RmaStall { rank: u32, nth: u64, millis: u64, step: u64 },
    /// Error the checkpoint write whose file would be `step_{step}`.
    CheckpointFail { step: u64 },
    /// Write that checkpoint truncated so it exists but fails
    /// validation — the recovery scan must skip it.
    CheckpointCorrupt { step: u64 },
}

/// A fault plus the supervision attempt it is scoped to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultSpec {
    pub attempt: u32,
    pub fault: Fault,
}

/// An ordered set of [`FaultSpec`]s; parse/print round-trips exactly.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    pub faults: Vec<FaultSpec>,
}

/// Parse `key=value` fields, rejecting unknown or duplicate keys so a
/// typo'd spec fails loudly instead of silently not firing.
fn parse_fields<'a>(
    kind: &str,
    body: &'a str,
    allowed: &[&str],
    optional: &[&str],
) -> Result<Vec<(&'a str, u64)>, String> {
    let mut out: Vec<(&str, u64)> = Vec::new();
    for field in body.split(',').filter(|f| !f.is_empty()) {
        let (key, value) = field
            .split_once('=')
            .ok_or_else(|| format!("fault `{kind}`: field `{field}` is not key=value"))?;
        if !allowed.contains(&key) && !optional.contains(&key) && key != "attempt" {
            return Err(format!(
                "fault `{kind}`: unknown field `{key}` (expected {})",
                allowed.join("/")
            ));
        }
        if out.iter().any(|(k, _)| *k == key) {
            return Err(format!("fault `{kind}`: duplicate field `{key}`"));
        }
        let parsed: u64 = value
            .parse()
            .map_err(|_| format!("fault `{kind}`: field `{key}`: `{value}` is not a number"))?;
        out.push((key, parsed));
    }
    for required in allowed {
        if !out.iter().any(|(k, _)| k == required) {
            return Err(format!("fault `{kind}`: missing required field `{required}`"));
        }
    }
    Ok(out)
}

fn field(fields: &[(&str, u64)], key: &str) -> u64 {
    fields.iter().find(|(k, _)| *k == key).map(|(_, v)| *v).unwrap_or(0)
}

impl FaultPlan {
    /// Parse a spec string; empty (or all-whitespace) means no faults.
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut faults = Vec::new();
        for item in spec.split(';').map(str::trim).filter(|s| !s.is_empty()) {
            let (kind, body) = item.split_once(':').unwrap_or((item, ""));
            let (allowed, optional): (&[&str], &[&str]) = match kind {
                "kill" => (&["rank", "step"], &[]),
                "frame_truncate" => (&["rank", "nth", "keep"], &[]),
                "frame_delay" | "rma_stall" => (&["rank", "nth", "ms"], &["step"]),
                "ckpt_fail" | "ckpt_corrupt" => (&["step"], &[]),
                other => {
                    return Err(format!(
                        "unknown fault kind `{other}` (expected kill/frame_truncate/\
                         frame_delay/rma_stall/ckpt_fail/ckpt_corrupt)"
                    ))
                }
            };
            let f = parse_fields(kind, body, allowed, optional)?;
            let attempt = field(&f, "attempt") as u32;
            let rank = field(&f, "rank") as u32;
            let nth = field(&f, "nth");
            let fault = match kind {
                "kill" => Fault::Kill { rank, step: field(&f, "step") },
                "frame_truncate" => {
                    Fault::FrameTruncate { rank, nth, keep: field(&f, "keep") as u32 }
                }
                "frame_delay" => Fault::FrameDelay {
                    rank,
                    nth,
                    millis: field(&f, "ms"),
                    step: field(&f, "step"),
                },
                "rma_stall" => Fault::RmaStall {
                    rank,
                    nth,
                    millis: field(&f, "ms"),
                    step: field(&f, "step"),
                },
                "ckpt_fail" => Fault::CheckpointFail { step: field(&f, "step") },
                _ => Fault::CheckpointCorrupt { step: field(&f, "step") },
            };
            faults.push(FaultSpec { attempt, fault });
        }
        Ok(FaultPlan { faults })
    }

    /// Canonical spec string; `parse(to_spec())` round-trips exactly.
    pub fn to_spec(&self) -> String {
        let items: Vec<String> = self
            .faults
            .iter()
            .map(|s| {
                let body = match s.fault {
                    Fault::Kill { rank, step } => format!("kill:rank={rank},step={step}"),
                    Fault::FrameTruncate { rank, nth, keep } => {
                        format!("frame_truncate:rank={rank},nth={nth},keep={keep}")
                    }
                    Fault::FrameDelay { rank, nth, millis, step } => {
                        let gate = if step > 0 { format!(",step={step}") } else { String::new() };
                        format!("frame_delay:rank={rank},nth={nth},ms={millis}{gate}")
                    }
                    Fault::RmaStall { rank, nth, millis, step } => {
                        let gate = if step > 0 { format!(",step={step}") } else { String::new() };
                        format!("rma_stall:rank={rank},nth={nth},ms={millis}{gate}")
                    }
                    Fault::CheckpointFail { step } => format!("ckpt_fail:step={step}"),
                    Fault::CheckpointCorrupt { step } => format!("ckpt_corrupt:step={step}"),
                };
                if s.attempt == 0 {
                    body
                } else {
                    format!("{body},attempt={}", s.attempt)
                }
            })
            .collect();
        items.join(";")
    }

    /// The sub-plan scoped to one supervision attempt (attempt fields
    /// are dropped: the receiving process applies everything it gets).
    pub fn for_attempt(&self, attempt: u32) -> FaultPlan {
        FaultPlan {
            faults: self
                .faults
                .iter()
                .filter(|s| s.attempt == attempt)
                .map(|s| FaultSpec { attempt: 0, fault: s.fault })
                .collect(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    /// Whether any fault needs rank *processes* to act on (kill,
    /// transport faults) — these are socket-backend-only; checkpoint
    /// faults work under either backend.
    pub fn requires_processes(&self) -> bool {
        self.faults.iter().any(|s| {
            !matches!(s.fault, Fault::CheckpointFail { .. } | Fault::CheckpointCorrupt { .. })
        })
    }
}

// -- process-global armed state ------------------------------------------

struct Armed {
    plan: FaultPlan,
    rank: u32,
    /// Outbound data frames sent by this process (1-based ordinals).
    data_frames: AtomicU64,
    /// Per-fault event counters for step-gated faults (`frame_delay`,
    /// `rma_stall`): each counts only events at/after its own gate, so
    /// `nth` is relative to the gate. Indexed parallel to `plan.faults`.
    gated_hits: Vec<AtomicU64>,
    /// Most recent step index seen by [`on_step`] — the clock the step
    /// gates compare against (0 until the first step begins, so a gate
    /// of 0 preserves the count-from-process-start semantics).
    current_step: AtomicU64,
}

static ARMED: OnceLock<Armed> = OnceLock::new();

/// Arm a plan for this process (idempotent per process; only the first
/// call wins — rank processes arm exactly once, before connecting).
/// Empty plans are ignored so the hooks stay on their `None` fast path.
pub fn arm(plan: FaultPlan, rank: usize) {
    if plan.is_empty() {
        return;
    }
    let gated_hits = plan.faults.iter().map(|_| AtomicU64::new(0)).collect();
    let _ = ARMED.set(Armed {
        plan,
        rank: rank as u32,
        data_frames: AtomicU64::new(0),
        gated_hits,
        current_step: AtomicU64::new(0),
    });
}

/// Arm from [`ENV_FAULT_PLAN`] if present, removing the variable so
/// nested launches don't inherit it. Parse errors are fatal here: a
/// fault plan that silently fails to arm would "pass" every test.
pub fn arm_from_env(rank: usize) {
    if let Ok(spec) = std::env::var(ENV_FAULT_PLAN) {
        std::env::remove_var(ENV_FAULT_PLAN);
        let plan = FaultPlan::parse(&spec)
            .unwrap_or_else(|e| panic!("invalid {ENV_FAULT_PLAN} spec `{spec}`: {e}"));
        arm(plan, rank);
    }
}

/// What a transport hook should do with the frame it is about to send.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrameAction {
    Pass,
    Truncate { keep: u32 },
    Delay { millis: u64 },
}

/// What a checkpoint writer should do with the write it is about to
/// perform.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CkptAction {
    Pass,
    Fail,
    Corrupt,
}

/// Kill hook, called at the top of every simulation step. Exits the
/// process (code [`KILL_EXIT_CODE`]) if an armed kill matches this
/// process's rank and this step.
#[inline]
pub fn on_step(step: u64) {
    let Some(armed) = ARMED.get() else { return };
    // Advance the gate clock first: faults gated on `step=S` must see
    // the new step for frames sent during it (RMA server threads read
    // this cross-thread).
    armed.current_step.store(step, Ordering::SeqCst);
    for s in &armed.plan.faults {
        if let Fault::Kill { rank, step: at } = s.fault {
            if rank == armed.rank && at == step {
                eprintln!(
                    "[fault] rank {rank}: injected kill before step {step} \
                     (exit code {KILL_EXIT_CODE})"
                );
                std::process::exit(KILL_EXIT_CODE);
            }
        }
    }
}

/// Transport hook: called once per outbound data frame, in send order.
#[inline]
pub fn on_data_frame() -> FrameAction {
    let Some(armed) = ARMED.get() else { return FrameAction::Pass };
    let ordinal = armed.data_frames.fetch_add(1, Ordering::Relaxed) + 1;
    let step_now = armed.current_step.load(Ordering::SeqCst);
    for (i, s) in armed.plan.faults.iter().enumerate() {
        match s.fault {
            Fault::FrameTruncate { rank, nth, keep } if rank == armed.rank && nth == ordinal => {
                return FrameAction::Truncate { keep };
            }
            Fault::FrameDelay { rank, nth, millis, step } if rank == armed.rank => {
                if step_now >= step && armed.gated_hits[i].fetch_add(1, Ordering::SeqCst) + 1 == nth
                {
                    return FrameAction::Delay { millis };
                }
            }
            _ => {}
        }
    }
    FrameAction::Pass
}

/// RMA server hook: called once per served reply, in service order.
/// Returns a stall duration in milliseconds when armed and matching.
#[inline]
pub fn on_rma_reply() -> Option<u64> {
    let armed = ARMED.get()?;
    let step_now = armed.current_step.load(Ordering::SeqCst);
    for (i, s) in armed.plan.faults.iter().enumerate() {
        if let Fault::RmaStall { rank, nth, millis, step } = s.fault {
            if rank == armed.rank
                && step_now >= step
                && armed.gated_hits[i].fetch_add(1, Ordering::SeqCst) + 1 == nth
            {
                return Some(millis);
            }
        }
    }
    None
}

/// Checkpoint hook: consulted before writing the snapshot (or part
/// file) for `next_step`.
#[inline]
pub fn on_checkpoint_write(next_step: u64) -> CkptAction {
    let Some(armed) = ARMED.get() else { return CkptAction::Pass };
    for s in &armed.plan.faults {
        match s.fault {
            Fault::CheckpointFail { step } if step == next_step => return CkptAction::Fail,
            Fault::CheckpointCorrupt { step } if step == next_step => return CkptAction::Corrupt,
            _ => {}
        }
    }
    CkptAction::Pass
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_to_spec_round_trips() {
        let spec = "kill:rank=1,step=120;frame_truncate:rank=1,nth=3,keep=2;\
                    frame_delay:rank=0,nth=5,ms=40;rma_stall:rank=0,nth=2,ms=40;\
                    ckpt_fail:step=100;ckpt_corrupt:step=160,attempt=1";
        let plan = FaultPlan::parse(spec).unwrap();
        assert_eq!(plan.faults.len(), 6);
        assert_eq!(plan.to_spec(), spec);
        assert_eq!(FaultPlan::parse(&plan.to_spec()).unwrap(), plan);
    }

    #[test]
    fn step_gate_parses_defaults_and_round_trips() {
        // Ungated specs keep the count-from-process-start default.
        let plan = FaultPlan::parse("frame_delay:rank=0,nth=5,ms=40").unwrap();
        assert_eq!(plan.faults[0].fault, Fault::FrameDelay {
            rank: 0,
            nth: 5,
            millis: 40,
            step: 0
        });
        assert_eq!(plan.to_spec(), "frame_delay:rank=0,nth=5,ms=40");
        // Gated specs carry the gate and round-trip (with attempt too).
        let spec = "frame_delay:rank=1,nth=1,ms=9,step=30;\
                    rma_stall:rank=0,nth=1,ms=9,step=30,attempt=1";
        let plan = FaultPlan::parse(spec).unwrap();
        assert_eq!(plan.to_spec(), spec);
        assert_eq!(FaultPlan::parse(&plan.to_spec()).unwrap(), plan);
        // The gate is not legal where it means nothing.
        assert!(FaultPlan::parse("frame_truncate:rank=0,nth=1,keep=0,step=3")
            .unwrap_err()
            .contains("unknown field"));
    }

    #[test]
    fn empty_and_whitespace_specs_are_empty_plans() {
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse("  ;  ; ").unwrap().is_empty());
    }

    #[test]
    fn unknown_kinds_and_fields_are_rejected() {
        assert!(FaultPlan::parse("explode:rank=0").unwrap_err().contains("unknown fault kind"));
        assert!(FaultPlan::parse("kill:rank=0,step=1,when=now")
            .unwrap_err()
            .contains("unknown field"));
        assert!(FaultPlan::parse("kill:rank=0").unwrap_err().contains("missing required"));
        assert!(FaultPlan::parse("kill:rank=0,rank=1,step=2")
            .unwrap_err()
            .contains("duplicate"));
        assert!(FaultPlan::parse("kill:rank=zero,step=1")
            .unwrap_err()
            .contains("not a number"));
    }

    #[test]
    fn for_attempt_filters_and_strips_attempt_tags() {
        let plan =
            FaultPlan::parse("kill:rank=1,step=10;kill:rank=0,step=20,attempt=1").unwrap();
        let a0 = plan.for_attempt(0);
        assert_eq!(a0.faults, vec![FaultSpec {
            attempt: 0,
            fault: Fault::Kill { rank: 1, step: 10 }
        }]);
        let a1 = plan.for_attempt(1);
        assert_eq!(a1.faults, vec![FaultSpec {
            attempt: 0,
            fault: Fault::Kill { rank: 0, step: 20 }
        }]);
        assert!(plan.for_attempt(2).is_empty());
    }

    #[test]
    fn process_requirements_distinguish_checkpoint_faults() {
        assert!(FaultPlan::parse("kill:rank=0,step=1").unwrap().requires_processes());
        assert!(FaultPlan::parse("frame_delay:rank=0,nth=1,ms=1")
            .unwrap()
            .requires_processes());
        assert!(!FaultPlan::parse("ckpt_fail:step=1;ckpt_corrupt:step=2")
            .unwrap()
            .requires_processes());
    }

    #[test]
    fn unarmed_hooks_are_pass_through() {
        // The suite shares one process; nothing arms in unit tests, so
        // every hook must take its fast path.
        on_step(0);
        assert_eq!(on_data_frame(), FrameAction::Pass);
        assert_eq!(on_rma_reply(), None);
        assert_eq!(on_checkpoint_write(0), CkptAction::Pass);
    }
}
