//! Domain decomposition (paper §III-B0a).
//!
//! The cubic simulation domain is split into `8^b` subdomains indexed by
//! the Morton curve; each rank owns a consecutive run of subdomains. For
//! power-of-two rank counts every rank gets 1, 2, or 4 cells (8 when the
//! rank count itself is a lower power of 8); other counts get near-even
//! consecutive runs.

use crate::util::{morton, Vec3};

#[derive(Clone, Debug)]
pub struct DomainDecomposition {
    /// Branch level `b`: subdomains are the cells at tree depth `b`.
    pub branch_level: u32,
    /// Number of subdomains = 8^b.
    pub num_cells: usize,
    /// Edge length of the whole domain.
    pub domain_size: f64,
    /// `cell_start[r]..cell_start[r+1]` = Morton cell range of rank r.
    cell_start: Vec<usize>,
}

impl DomainDecomposition {
    /// Decompose for `ranks` ranks: smallest `b` with `8^b >= ranks`.
    pub fn new(ranks: usize, domain_size: f64) -> Self {
        assert!(ranks > 0);
        let mut b = 0u32;
        while 8usize.pow(b) < ranks {
            b += 1;
        }
        let num_cells = 8usize.pow(b);
        // Near-even consecutive distribution: first `extra` ranks get one
        // more cell. (Power-of-two ranks -> exact 8^b/ranks each.)
        let base = num_cells / ranks;
        let extra = num_cells % ranks;
        let mut cell_start = Vec::with_capacity(ranks + 1);
        let mut at = 0;
        for r in 0..ranks {
            cell_start.push(at);
            at += base + usize::from(r < extra);
        }
        cell_start.push(at);
        debug_assert_eq!(at, num_cells);
        Self { branch_level: b, num_cells, domain_size, cell_start }
    }

    /// Decompose with an EXPLICIT rank → cell assignment
    /// (`cell_start[r]..cell_start[r+1]` = Morton cells of rank r), the
    /// constructor the load-balancing subsystem rebuilds with after a
    /// migration shifts boundary cells between adjacent ranks. The cell
    /// count must be a Morton-complete 8^b and every rank must keep at
    /// least one cell.
    pub fn with_cells(domain_size: f64, cell_start: Vec<usize>) -> Self {
        assert!(cell_start.len() >= 2, "need at least one rank");
        assert_eq!(cell_start[0], 0, "cell runs must start at cell 0");
        for w in cell_start.windows(2) {
            assert!(w[0] < w[1], "every rank needs at least one Morton cell");
        }
        let num_cells = *cell_start.last().unwrap();
        let mut b = 0u32;
        while 8usize.pow(b) < num_cells {
            b += 1;
        }
        assert_eq!(8usize.pow(b), num_cells, "cell count must be 8^b, got {num_cells}");
        Self { branch_level: b, num_cells, domain_size, cell_start }
    }

    /// The rank → cell assignment (`cell_start[r]..cell_start[r+1]` =
    /// cells of rank r; length ranks+1). What `with_cells` consumes.
    pub fn cell_partition(&self) -> Vec<usize> {
        self.cell_start.clone()
    }

    pub fn ranks(&self) -> usize {
        self.cell_start.len() - 1
    }

    /// Cells per axis at the branch level (2^b).
    pub fn cells_per_axis(&self) -> u64 {
        1u64 << self.branch_level
    }

    /// Edge length of one subdomain.
    pub fn cell_size(&self) -> f64 {
        self.domain_size / self.cells_per_axis() as f64
    }

    /// Morton cell range owned by `rank`.
    pub fn cells_of_rank(&self, rank: usize) -> std::ops::Range<usize> {
        self.cell_start[rank]..self.cell_start[rank + 1]
    }

    /// Which rank owns Morton cell `cell`.
    pub fn owner_of_cell(&self, cell: usize) -> usize {
        debug_assert!(cell < self.num_cells);
        // cell_start is sorted; find the last start <= cell.
        match self.cell_start.binary_search(&cell) {
            Ok(r) => r.min(self.ranks() - 1),
            Err(ins) => ins - 1,
        }
    }

    /// Spatial bounds `[lo, hi)` of Morton cell `cell`.
    pub fn cell_bounds(&self, cell: usize) -> (Vec3, Vec3) {
        let (x, y, z) = morton::decode(cell as u64);
        let s = self.cell_size();
        let lo = Vec3::new(x as f64 * s, y as f64 * s, z as f64 * s);
        let hi = lo + Vec3::splat(s);
        (lo, hi)
    }

    /// Morton cell containing `pos`.
    pub fn cell_of_position(&self, pos: &Vec3) -> usize {
        let s = self.cell_size();
        let clamp = |v: f64| {
            (v / s).floor().max(0.0).min((self.cells_per_axis() - 1) as f64) as u64
        };
        morton::encode(clamp(pos.x), clamp(pos.y), clamp(pos.z)) as usize
    }

    /// Which rank owns `pos`.
    pub fn owner_of_position(&self, pos: &Vec3) -> usize {
        self.owner_of_cell(self.cell_of_position(pos))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn branch_level_matches_paper_examples() {
        // k ranks -> 8^b cells, 1/2/4 consecutive each (power-of-two k).
        assert_eq!(DomainDecomposition::new(1, 1.0).branch_level, 0);
        assert_eq!(DomainDecomposition::new(2, 1.0).branch_level, 1); // 4 each
        assert_eq!(DomainDecomposition::new(8, 1.0).branch_level, 1); // 1 each
        assert_eq!(DomainDecomposition::new(16, 1.0).branch_level, 2); // 4 each
        assert_eq!(DomainDecomposition::new(32, 1.0).branch_level, 2); // 2 each
        assert_eq!(DomainDecomposition::new(64, 1.0).branch_level, 2); // 1 each
        assert_eq!(DomainDecomposition::new(1024, 1.0).branch_level, 4);
    }

    #[test]
    fn power_of_two_ranks_get_1_2_or_4_cells() {
        for ranks in [2usize, 4, 8, 16, 32, 64, 128, 256, 512, 1024] {
            let d = DomainDecomposition::new(ranks, 1.0);
            for r in 0..ranks {
                let c = d.cells_of_rank(r).len();
                assert!(
                    c == 1 || c == 2 || c == 4,
                    "ranks={ranks} rank={r} cells={c}"
                );
            }
        }
    }

    #[test]
    fn cells_partition_exactly() {
        for ranks in [1usize, 3, 5, 8, 13, 32] {
            let d = DomainDecomposition::new(ranks, 1.0);
            let mut covered = 0;
            for r in 0..ranks {
                let range = d.cells_of_rank(r);
                assert_eq!(range.start, covered);
                covered = range.end;
                for c in range {
                    assert_eq!(d.owner_of_cell(c), r);
                }
            }
            assert_eq!(covered, d.num_cells);
        }
    }

    #[test]
    fn cell_bounds_tile_domain() {
        let d = DomainDecomposition::new(16, 100.0);
        let mut volume = 0.0;
        for c in 0..d.num_cells {
            let (lo, hi) = d.cell_bounds(c);
            assert!(lo.x >= 0.0 && hi.x <= 100.0 + 1e-9);
            volume += (hi.x - lo.x) * (hi.y - lo.y) * (hi.z - lo.z);
        }
        assert!((volume - 100.0f64.powi(3)).abs() < 1e-6);
    }

    #[test]
    fn position_cell_roundtrip() {
        let d = DomainDecomposition::new(16, 100.0);
        let mut rng = crate::util::Rng::new(3);
        for _ in 0..500 {
            let p = Vec3::new(
                rng.uniform(0.0, 100.0),
                rng.uniform(0.0, 100.0),
                rng.uniform(0.0, 100.0),
            );
            let cell = d.cell_of_position(&p);
            let (lo, hi) = d.cell_bounds(cell);
            assert!(p.in_box(&lo, &hi), "{p:?} not in cell {cell}");
        }
    }

    #[test]
    fn with_cells_reproduces_and_shifts_the_default_assignment() {
        let d = DomainDecomposition::new(2, 100.0);
        let same = DomainDecomposition::with_cells(100.0, d.cell_partition());
        assert_eq!(same.branch_level, d.branch_level);
        assert_eq!(same.num_cells, d.num_cells);
        assert_eq!(same.cells_of_rank(0), d.cells_of_rank(0));
        // A shifted boundary moves cell ownership (the migration move).
        let skew = DomainDecomposition::with_cells(100.0, vec![0, 6, 8]);
        assert_eq!(skew.cells_of_rank(0), 0..6);
        assert_eq!(skew.cells_of_rank(1), 6..8);
        assert_eq!(skew.owner_of_cell(5), 0);
        assert_eq!(skew.owner_of_cell(6), 1);
        assert_eq!(skew.branch_level, 1);
    }

    #[test]
    #[should_panic(expected = "8^b")]
    fn with_cells_rejects_non_morton_counts() {
        DomainDecomposition::with_cells(100.0, vec![0, 3, 7]);
    }

    #[test]
    fn boundary_positions_clamp() {
        let d = DomainDecomposition::new(8, 100.0);
        let p = Vec3::new(100.0, 100.0, 100.0); // exactly on the far corner
        let cell = d.cell_of_position(&p);
        assert!(cell < d.num_cells);
    }
}
