//! Distributed spatial octree: domain decomposition, the per-rank tree
//! with shared upper portion, and the RMA-window serialization used by
//! the old (download-based) Barnes–Hut algorithm.

pub mod domain;
pub mod tree;
pub mod window;

pub use domain::DomainDecomposition;
pub use tree::{BranchPayload, ElementKind, Node, NodeKind, Octree, NO_CHILD, NO_NEURON};
pub use window::{serialize_local_subtrees, RemoteNodeCache, SerializedWindow, WireNode, OCTREE_WINDOW};

use crate::util::wire::{get_f32, get_u32, put_f32, put_u32, Wire};
use crate::util::Vec3;

/// Wire format for the branch-node all-to-all exchange: cell index,
/// both vacancy aggregates, both weighted position sums, the owner's
/// window root index, and the leaf neuron id (if the whole subdomain is
/// a single leaf). 48 B per subdomain — part of the "small amount of
/// bookkeeping" in Tables I/II, identical for old and new algorithms.
impl Wire for BranchPayload {
    const SIZE: usize = 4 + 4 + 4 + 12 + 12 + 4 + 8;

    fn write(&self, out: &mut Vec<u8>) {
        put_u32(out, self.cell);
        put_f32(out, self.vac_exc);
        put_f32(out, self.vac_inh);
        put_f32(out, self.pos_exc.x as f32);
        put_f32(out, self.pos_exc.y as f32);
        put_f32(out, self.pos_exc.z as f32);
        put_f32(out, self.pos_inh.x as f32);
        put_f32(out, self.pos_inh.y as f32);
        put_f32(out, self.pos_inh.z as f32);
        put_u32(out, self.window_root as u32);
        out.extend_from_slice(&self.neuron.to_le_bytes());
    }

    fn read(buf: &[u8]) -> Self {
        BranchPayload {
            cell: get_u32(buf, 0),
            vac_exc: get_f32(buf, 4),
            vac_inh: get_f32(buf, 8),
            pos_exc: Vec3::new(
                get_f32(buf, 12) as f64,
                get_f32(buf, 16) as f64,
                get_f32(buf, 20) as f64,
            ),
            pos_inh: Vec3::new(
                get_f32(buf, 24) as f64,
                get_f32(buf, 28) as f64,
                get_f32(buf, 32) as f64,
            ),
            window_root: get_u32(buf, 36) as i32,
            neuron: crate::util::wire::get_i64_at(buf, 40),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn branch_payload_roundtrip() {
        let p = BranchPayload {
            cell: 17,
            vac_exc: 3.5,
            vac_inh: 1.25,
            pos_exc: Vec3::new(1.0, 2.0, 3.0),
            pos_inh: Vec3::new(4.0, 5.0, 6.0),
            window_root: -1,
            neuron: 99,
        };
        let mut buf = Vec::new();
        p.write(&mut buf);
        assert_eq!(buf.len(), BranchPayload::SIZE);
        let q = BranchPayload::read(&buf);
        assert_eq!(q.cell, 17);
        assert_eq!(q.window_root, -1);
        assert_eq!(q.neuron, 99);
        assert!((q.vac_exc - 3.5).abs() < 1e-6);
        assert!(q.pos_inh.dist(&p.pos_inh) < 1e-6);
    }
}
