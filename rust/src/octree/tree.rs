//! The distributed spatial octree (paper §III-B0b, Fig. 1).
//!
//! Every rank holds: the *shared upper portion* (root down to the branch
//! level, identical structure on all ranks), and *local subtrees* below
//! the branch nodes of the cells it owns. Leaves hold exactly one neuron.
//! Inner nodes aggregate vacant dendritic elements (excitatory and
//! inhibitory separately) and their weighted mean positions — the
//! quantities the Barnes–Hut probability kernel consumes.
//!
//! Arena storage: children are always created after their parent, so a
//! single reverse index pass implements bottom-up aggregation.

use super::domain::DomainDecomposition;
use crate::neuron::GlobalNeuronId;
use crate::util::{morton, Vec3};

pub const NO_CHILD: i32 = -1;
pub const NO_NEURON: i64 = -1;

/// Which dendrite kind a search targets (= the searching axon's type).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementKind {
    /// Excitatory axon -> vacant excitatory-dendritic elements.
    Excitatory,
    /// Inhibitory axon -> vacant inhibitory-dendritic elements.
    Inhibitory,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NodeKind {
    /// Shared upper node (level < b), replicated on all ranks.
    Upper,
    /// Branch node (level == b): one per Morton subdomain, replicated;
    /// only the owner has its subtree.
    Branch,
    /// Local node below a branch node of an owned cell.
    Local,
}

#[derive(Clone, Debug)]
pub struct Node {
    /// Lower corner of this cubic cell.
    pub lo: Vec3,
    /// Edge length of this cell.
    pub side: f64,
    /// Depth in the tree (root = 0, branch = b).
    pub level: u32,
    pub kind: NodeKind,
    pub parent: i32,
    pub children: [i32; 8],
    /// Leaf payload: global neuron id, or NO_NEURON.
    pub neuron: i64,
    /// Position of the leaf neuron (valid when `neuron >= 0`).
    pub leaf_pos: Vec3,
    /// Vacant dendritic elements aggregated below (incl.) this node.
    pub vac_exc: f32,
    pub vac_inh: f32,
    /// During aggregation: vacancy-weighted position sums; after
    /// `normalize()`: weighted mean positions.
    pub pos_exc: Vec3,
    pub pos_inh: Vec3,
    /// Owning rank (meaningful for Branch/Local nodes).
    pub owner: u32,
    /// Branch only: Morton cell index.
    pub cell: u32,
    /// Branch only: index of the subtree root inside the owner's RMA
    /// window (set by the branch exchange; NO_CHILD if none/empty).
    pub window_root: i32,
}

impl Node {
    fn new(lo: Vec3, side: f64, level: u32, kind: NodeKind, parent: i32) -> Self {
        Node {
            lo,
            side,
            level,
            kind,
            parent,
            children: [NO_CHILD; 8],
            neuron: NO_NEURON,
            leaf_pos: Vec3::ZERO,
            vac_exc: 0.0,
            vac_inh: 0.0,
            pos_exc: Vec3::ZERO,
            pos_inh: Vec3::ZERO,
            owner: u32::MAX,
            cell: u32::MAX,
            window_root: NO_CHILD,
        }
    }

    pub fn center(&self) -> Vec3 {
        self.lo + Vec3::splat(self.side / 2.0)
    }

    /// Has no children (may or may not hold a neuron).
    pub fn is_leaf(&self) -> bool {
        self.children.iter().all(|&c| c == NO_CHILD)
    }

    /// Vacant elements of `kind` at/below this node.
    pub fn vac(&self, kind: ElementKind) -> f32 {
        match kind {
            ElementKind::Excitatory => self.vac_exc,
            ElementKind::Inhibitory => self.vac_inh,
        }
    }

    /// Weighted mean position for `kind` (valid after `normalize()`).
    pub fn pos(&self, kind: ElementKind) -> Vec3 {
        match kind {
            ElementKind::Excitatory => self.pos_exc,
            ElementKind::Inhibitory => self.pos_inh,
        }
    }

    /// Octant of `pos` within this cell (bit0=x, bit1=y, bit2=z —
    /// matches Morton child order).
    fn octant_of(&self, pos: &Vec3) -> usize {
        let c = self.center();
        (usize::from(pos.x >= c.x))
            | (usize::from(pos.y >= c.y) << 1)
            | (usize::from(pos.z >= c.z) << 2)
    }

    fn child_bounds(&self, octant: usize) -> (Vec3, f64) {
        let half = self.side / 2.0;
        let lo = Vec3::new(
            self.lo.x + if octant & 1 != 0 { half } else { 0.0 },
            self.lo.y + if octant & 2 != 0 { half } else { 0.0 },
            self.lo.z + if octant & 4 != 0 { half } else { 0.0 },
        );
        (lo, half)
    }
}

/// One rank's view of the distributed octree.
#[derive(Clone, Debug)]
pub struct Octree {
    pub nodes: Vec<Node>,
    /// Arena index of the branch node of each Morton cell.
    pub branch_of_cell: Vec<usize>,
    /// Nodes `[0, upper_count)` are the shared upper portion (incl.
    /// branch nodes); `[upper_count, ..)` are local subtree nodes.
    pub upper_count: usize,
    pub rank: u32,
    pub branch_level: u32,
}

/// Branch-node aggregate exchanged all-to-all each connectivity update
/// (paper §III-B0c: "all-to-all exchanges of branch nodes").
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BranchPayload {
    pub cell: u32,
    pub vac_exc: f32,
    pub vac_inh: f32,
    pub pos_exc: Vec3,
    pub pos_inh: Vec3,
    /// Subtree root index in the owner's RMA window (NO_CHILD if the
    /// cell is empty).
    pub window_root: i32,
    /// If the branch node is itself a leaf (<= 1 neuron in the cell):
    /// that neuron's id, else NO_NEURON. Lets the location-aware
    /// algorithm mark requests whose target "is already a leaf"
    /// (paper §IV-A).
    pub neuron: i64,
}

impl Octree {
    /// Build the structural tree for `rank`: shared upper portion plus
    /// local subtrees containing `positions` (all owned by this rank;
    /// ids are `first_id + i`).
    pub fn build(
        decomp: &DomainDecomposition,
        rank: usize,
        first_id: GlobalNeuronId,
        positions: &[Vec3],
    ) -> Octree {
        let b = decomp.branch_level;
        let mut nodes = Vec::new();
        nodes.push(Node::new(Vec3::ZERO, decomp.domain_size, 0, if b == 0 {
            NodeKind::Branch
        } else {
            NodeKind::Upper
        }, NO_CHILD));

        // Breadth-first creation of the shared upper portion down to the
        // branch level; children are in octant (= Morton) order, so the
        // branch nodes of one parent are Morton-consecutive.
        let mut frontier = vec![0usize];
        for level in 0..b {
            let mut next = Vec::with_capacity(frontier.len() * 8);
            for &ni in &frontier {
                for oct in 0..8 {
                    let (lo, side) = nodes[ni].child_bounds(oct);
                    let kind =
                        if level + 1 == b { NodeKind::Branch } else { NodeKind::Upper };
                    let idx = nodes.len();
                    nodes.push(Node::new(lo, side, level + 1, kind, ni as i32));
                    nodes[ni].children[oct] = idx as i32;
                    next.push(idx);
                }
            }
            frontier = next;
        }

        // Identify branch node of each Morton cell and set owners.
        let mut branch_of_cell = vec![usize::MAX; decomp.num_cells];
        for &ni in &frontier {
            let n = &nodes[ni];
            let s = decomp.cell_size();
            let cx = (n.lo.x / s).round() as u64;
            let cy = (n.lo.y / s).round() as u64;
            let cz = (n.lo.z / s).round() as u64;
            let cell = morton::encode(cx, cy, cz) as usize;
            branch_of_cell[cell] = ni;
        }
        for (cell, &ni) in branch_of_cell.iter().enumerate() {
            nodes[ni].cell = cell as u32;
            nodes[ni].owner = decomp.owner_of_cell(cell) as u32;
        }
        let upper_count = nodes.len();

        let mut tree = Octree {
            nodes,
            branch_of_cell,
            upper_count,
            rank: rank as u32,
            branch_level: b,
        };
        for (i, pos) in positions.iter().enumerate() {
            tree.insert(decomp, first_id + i as u64, pos);
        }
        tree
    }

    /// Insert one owned neuron below its cell's branch node.
    fn insert(&mut self, decomp: &DomainDecomposition, id: GlobalNeuronId, pos: &Vec3) {
        let cell = decomp.cell_of_position(pos);
        debug_assert_eq!(
            decomp.owner_of_cell(cell),
            self.rank as usize,
            "neuron {id} at {pos:?} not owned by rank {}",
            self.rank
        );
        let mut at = self.branch_of_cell[cell];
        loop {
            debug_assert!(
                self.nodes[at].level < 64,
                "octree too deep: coincident neuron positions?"
            );
            if !self.nodes[at].is_leaf() {
                // Internal: descend (creating the child if needed).
                at = self.child_for(at, pos);
            } else if self.nodes[at].neuron == NO_NEURON {
                // Empty leaf: claim it.
                self.nodes[at].neuron = id as i64;
                self.nodes[at].leaf_pos = *pos;
                return;
            } else {
                // Occupied leaf: push the resident neuron one level down,
                // then retry (the loop re-descends for `pos`).
                let old_id = self.nodes[at].neuron;
                let old_pos = self.nodes[at].leaf_pos;
                self.nodes[at].neuron = NO_NEURON;
                let child = self.child_for(at, &old_pos);
                self.nodes[child].neuron = old_id;
                self.nodes[child].leaf_pos = old_pos;
            }
        }
    }

    /// Child of `at` containing `pos`, created on demand.
    fn child_for(&mut self, at: usize, pos: &Vec3) -> usize {
        let oct = self.nodes[at].octant_of(pos);
        if self.nodes[at].children[oct] != NO_CHILD {
            return self.nodes[at].children[oct] as usize;
        }
        let (lo, side) = self.nodes[at].child_bounds(oct);
        let level = self.nodes[at].level + 1;
        let idx = self.nodes.len();
        self.nodes.push(Node::new(lo, side, level, NodeKind::Local, at as i32));
        self.nodes[idx].owner = self.rank;
        self.nodes[at].children[oct] = idx as i32;
        idx
    }

    // -- per-connectivity-update aggregation ----------------------------

    /// Step 1: zero aggregates everywhere, then set leaf vacancies from
    /// the population (`vac_*[local]` = vacant dendritic elements of the
    /// neuron with global id `first_id + local`).
    pub fn reset_and_set_leaves(
        &mut self,
        first_id: GlobalNeuronId,
        vac_exc: &[f32],
        vac_inh: &[f32],
    ) {
        let rank = self.rank;
        for n in self.nodes.iter_mut() {
            n.vac_exc = 0.0;
            n.vac_inh = 0.0;
            n.pos_exc = Vec3::ZERO;
            n.pos_inh = Vec3::ZERO;
            if n.neuron != NO_NEURON && n.owner == rank {
                // A locally-owned leaf: seed with the neuron's vacancy.
                let local = (n.neuron as u64 - first_id) as usize;
                n.vac_exc = vac_exc[local];
                n.vac_inh = vac_inh[local];
                n.pos_exc = n.leaf_pos * vac_exc[local] as f64;
                n.pos_inh = n.leaf_pos * vac_inh[local] as f64;
            } else if n.neuron != NO_NEURON {
                // Stale remote leaf-branch info from the previous
                // connectivity update; the fresh branch payload will
                // re-install it.
                n.neuron = NO_NEURON;
            }
        }
    }

    /// Step 2: aggregate local subtrees bottom-up into their branch
    /// nodes (children always have higher arena indices than parents).
    pub fn aggregate_local(&mut self) {
        for i in (self.upper_count..self.nodes.len()).rev() {
            let parent = self.nodes[i].parent;
            debug_assert!(parent != NO_CHILD);
            let (vac_e, vac_i, pe, pi) = {
                let n = &self.nodes[i];
                (n.vac_exc, n.vac_inh, n.pos_exc, n.pos_inh)
            };
            let p = &mut self.nodes[parent as usize];
            p.vac_exc += vac_e;
            p.vac_inh += vac_i;
            p.pos_exc += pe;
            p.pos_inh += pi;
        }
    }

    /// Step 3: read this rank's branch aggregates for the all-to-all
    /// exchange. `window_root_of` maps a cell to the subtree-root index
    /// in this rank's freshly published RMA window.
    pub fn own_branch_payloads(
        &self,
        cells: std::ops::Range<usize>,
        window_root_of: impl Fn(usize) -> i32,
    ) -> Vec<BranchPayload> {
        cells
            .map(|cell| {
                let n = &self.nodes[self.branch_of_cell[cell]];
                BranchPayload {
                    cell: cell as u32,
                    vac_exc: n.vac_exc,
                    vac_inh: n.vac_inh,
                    pos_exc: n.pos_exc,
                    pos_inh: n.pos_inh,
                    window_root: window_root_of(cell),
                    neuron: n.neuron,
                }
            })
            .collect()
    }

    /// Step 4: install branch aggregates received from other ranks
    /// (position sums, not yet normalized — symmetric with local ones).
    pub fn apply_branch_payloads(&mut self, payloads: &[BranchPayload]) {
        for p in payloads {
            let ni = self.branch_of_cell[p.cell as usize];
            let n = &mut self.nodes[ni];
            n.vac_exc = p.vac_exc;
            n.vac_inh = p.vac_inh;
            n.pos_exc = p.pos_exc;
            n.pos_inh = p.pos_inh;
            n.window_root = p.window_root;
            if n.owner != self.rank {
                // Remote cell that is a single leaf: remember its neuron
                // so a search terminating here knows the final target.
                // (Position comes out of the normal sum/vac division in
                // `normalize`; `leaf_pos` stays unset for remote leaves.)
                n.neuron = p.neuron;
            }
        }
    }

    /// Step 5: aggregate the shared upper portion from the branch nodes
    /// up to the root.
    pub fn aggregate_upper(&mut self) {
        for i in (1..self.upper_count).rev() {
            let parent = self.nodes[i].parent;
            let (vac_e, vac_i, pe, pi) = {
                let n = &self.nodes[i];
                (n.vac_exc, n.vac_inh, n.pos_exc, n.pos_inh)
            };
            let p = &mut self.nodes[parent as usize];
            p.vac_exc += vac_e;
            p.vac_inh += vac_i;
            p.pos_exc += pe;
            p.pos_inh += pi;
        }
    }

    /// Step 6: convert position sums to weighted means. Locally-owned
    /// leaves keep the exact neuron position regardless of vacancy, so a
    /// leaf with zero vacancy still reports where its neuron sits.
    /// (Remote leaf-branch nodes only carry sums; their position is the
    /// division result and is only consumed when vacancy > 0.)
    pub fn normalize(&mut self) {
        let rank = self.rank;
        for n in self.nodes.iter_mut() {
            if n.neuron != NO_NEURON && n.owner == rank {
                n.pos_exc = n.leaf_pos;
                n.pos_inh = n.leaf_pos;
            } else {
                if n.vac_exc > 0.0 {
                    n.pos_exc = n.pos_exc / n.vac_exc as f64;
                }
                if n.vac_inh > 0.0 {
                    n.pos_inh = n.pos_inh / n.vac_inh as f64;
                }
            }
        }
    }

    /// Arena index of the root.
    pub fn root(&self) -> usize {
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn build_one_rank(n: usize, seed: u64) -> (DomainDecomposition, Octree, Vec<Vec3>) {
        let decomp = DomainDecomposition::new(1, 100.0);
        let mut rng = Rng::new(seed);
        let positions: Vec<Vec3> = (0..n)
            .map(|_| {
                Vec3::new(
                    rng.uniform(0.0, 100.0),
                    rng.uniform(0.0, 100.0),
                    rng.uniform(0.0, 100.0),
                )
            })
            .collect();
        let tree = Octree::build(&decomp, 0, 0, &positions);
        (decomp, tree, positions)
    }

    #[test]
    fn build_stores_every_neuron_in_exactly_one_leaf() {
        let (_, tree, positions) = build_one_rank(200, 1);
        let mut found = vec![false; positions.len()];
        for n in &tree.nodes {
            if n.neuron != NO_NEURON {
                let id = n.neuron as usize;
                assert!(!found[id], "neuron {id} in two leaves");
                found[id] = true;
                assert_eq!(n.leaf_pos, positions[id]);
                // The neuron lies inside its leaf cell.
                let hi = n.lo + Vec3::splat(n.side);
                assert!(positions[id].in_box(&n.lo, &hi));
            }
        }
        assert!(found.iter().all(|&f| f));
    }

    #[test]
    fn leaves_hold_at_most_one_neuron() {
        let (_, tree, _) = build_one_rank(300, 2);
        for n in &tree.nodes {
            if n.neuron != NO_NEURON {
                assert!(n.is_leaf(), "neuron stored in internal node");
            }
        }
    }

    #[test]
    fn children_have_higher_indices_than_parents() {
        let (_, tree, _) = build_one_rank(150, 3);
        for (i, n) in tree.nodes.iter().enumerate() {
            for &c in &n.children {
                if c != NO_CHILD {
                    assert!(c as usize > i);
                    assert_eq!(tree.nodes[c as usize].parent, i as i32);
                }
            }
        }
    }

    #[test]
    fn aggregation_conserves_vacancies() {
        let (_, mut tree, positions) = build_one_rank(120, 4);
        let n = positions.len();
        let vac_exc: Vec<f32> = (0..n).map(|i| (i % 3) as f32).collect();
        let vac_inh: Vec<f32> = (0..n).map(|i| (i % 2) as f32).collect();
        tree.reset_and_set_leaves(0, &vac_exc, &vac_inh);
        tree.aggregate_local();
        // One-rank decomposition: branch level 0, root == branch node.
        tree.aggregate_upper();
        tree.normalize();
        let root = &tree.nodes[0];
        assert!((root.vac_exc - vac_exc.iter().sum::<f32>()).abs() < 1e-3);
        assert!((root.vac_inh - vac_inh.iter().sum::<f32>()).abs() < 1e-3);
    }

    #[test]
    fn weighted_positions_are_inside_bounds() {
        let (_, mut tree, positions) = build_one_rank(80, 5);
        let vac = vec![1.0f32; positions.len()];
        tree.reset_and_set_leaves(0, &vac, &vac);
        tree.aggregate_local();
        tree.aggregate_upper();
        tree.normalize();
        for n in &tree.nodes {
            if n.vac_exc > 0.0 {
                let hi = n.lo + Vec3::splat(n.side + 1e-9);
                let lo = n.lo - Vec3::splat(1e-9);
                assert!(n.pos_exc.in_box(&lo, &hi), "mean position outside cell");
            }
        }
    }

    #[test]
    fn root_mean_is_centroid_for_uniform_vacancy() {
        let (_, mut tree, positions) = build_one_rank(64, 6);
        let vac = vec![1.0f32; positions.len()];
        tree.reset_and_set_leaves(0, &vac, &vac);
        tree.aggregate_local();
        tree.aggregate_upper();
        tree.normalize();
        let mut centroid = Vec3::ZERO;
        for p in &positions {
            centroid += *p;
        }
        centroid = centroid / positions.len() as f64;
        let root = &tree.nodes[0];
        assert!(root.pos_exc.dist(&centroid) < 1e-6);
    }

    #[test]
    fn multi_rank_upper_structure_is_shared() {
        let decomp = DomainDecomposition::new(4, 100.0);
        // Two ranks build with no neurons: upper structure must agree.
        let t0 = Octree::build(&decomp, 0, 0, &[]);
        let t1 = Octree::build(&decomp, 1, 100, &[]);
        assert_eq!(t0.upper_count, t1.upper_count);
        assert_eq!(t0.branch_of_cell, t1.branch_of_cell);
        for (a, b) in t0.nodes.iter().zip(&t1.nodes) {
            assert_eq!(a.lo, b.lo);
            assert_eq!(a.level, b.level);
            assert_eq!(a.cell, b.cell);
            assert_eq!(a.owner, b.owner);
        }
    }

    #[test]
    fn branch_payload_roundtrip_across_ranks() {
        let decomp = DomainDecomposition::new(2, 100.0);
        // Rank 0 owns cells 0..4 (x<50 half via Morton? — use decomp),
        // place one neuron in rank 0's first cell.
        let (lo, hi) = decomp.cell_bounds(decomp.cells_of_rank(0).start);
        let pos = (lo + hi) / 2.0;
        let mut t0 = Octree::build(&decomp, 0, 0, &[pos]);
        let mut t1 = Octree::build(&decomp, 1, 1, &[]);
        t0.reset_and_set_leaves(0, &[2.0], &[1.0]);
        t0.aggregate_local();
        let payloads = t0.own_branch_payloads(decomp.cells_of_rank(0), |_| NO_CHILD);
        t1.apply_branch_payloads(&payloads);
        t1.aggregate_upper();
        t1.normalize();
        let root1 = &t1.nodes[0];
        assert!((root1.vac_exc - 2.0).abs() < 1e-6);
        assert!((root1.vac_inh - 1.0).abs() < 1e-6);
        assert!(root1.pos_exc.dist(&pos) < 1e-6);
    }
}
