//! RMA window serialization of a rank's local subtrees, plus the
//! remote-node cache the *old* Barnes–Hut algorithm uses.
//!
//! Each connectivity update, every rank publishes its local subtree
//! nodes (everything at or below the branch nodes of its cells) as a
//! flat, index-addressable array of fixed-size `WireNode`s. The old
//! algorithm downloads nodes from these windows one at a time during its
//! descent ("download all red nodes", paper Fig. 2) and caches them for
//! the rest of the synapse-formation phase (paper §III-B0c). The new
//! algorithm never touches these windows below the branch level — that is
//! the entire point.

use std::collections::HashMap;

use super::tree::{ElementKind, NodeKind, Octree, NO_CHILD};
use crate::comm::{Comm, WindowKey};
use crate::util::wire::{get_f32, get_i64_at, get_i32_at, put_f32, put_u32, Wire};
use crate::util::Vec3;

/// Window key under which octree nodes are published.
pub const OCTREE_WINDOW: WindowKey = 1;

/// A serialized octree node as it travels over (emulated) RMA.
///
/// 89 B on the wire: bounds (16) + vacancies (8) + weighted positions
/// (24) + child window indices (32) + neuron id (8) + flags (1).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WireNode {
    pub lo: [f32; 3],
    pub side: f32,
    pub vac_exc: f32,
    pub vac_inh: f32,
    pub pos_exc: [f32; 3],
    pub pos_inh: [f32; 3],
    /// Children as indices into the owner's window (NO_CHILD = none).
    pub children: [i32; 8],
    pub neuron: i64,
    pub is_leaf: bool,
}

impl WireNode {
    pub fn vac(&self, kind: ElementKind) -> f32 {
        match kind {
            ElementKind::Excitatory => self.vac_exc,
            ElementKind::Inhibitory => self.vac_inh,
        }
    }

    pub fn pos(&self, kind: ElementKind) -> Vec3 {
        let p = match kind {
            ElementKind::Excitatory => self.pos_exc,
            ElementKind::Inhibitory => self.pos_inh,
        };
        Vec3::new(p[0] as f64, p[1] as f64, p[2] as f64)
    }
}

impl Wire for WireNode {
    const SIZE: usize = 16 + 8 + 24 + 32 + 8 + 1;

    fn write(&self, out: &mut Vec<u8>) {
        for v in self.lo {
            put_f32(out, v);
        }
        put_f32(out, self.side);
        put_f32(out, self.vac_exc);
        put_f32(out, self.vac_inh);
        for v in self.pos_exc {
            put_f32(out, v);
        }
        for v in self.pos_inh {
            put_f32(out, v);
        }
        for c in self.children {
            put_u32(out, c as u32);
        }
        out.extend_from_slice(&self.neuron.to_le_bytes());
        out.push(u8::from(self.is_leaf));
    }

    fn read(buf: &[u8]) -> Self {
        let mut lo = [0f32; 3];
        for (i, v) in lo.iter_mut().enumerate() {
            *v = get_f32(buf, i * 4);
        }
        let side = get_f32(buf, 12);
        let vac_exc = get_f32(buf, 16);
        let vac_inh = get_f32(buf, 20);
        let mut pos_exc = [0f32; 3];
        let mut pos_inh = [0f32; 3];
        for i in 0..3 {
            pos_exc[i] = get_f32(buf, 24 + i * 4);
            pos_inh[i] = get_f32(buf, 36 + i * 4);
        }
        let mut children = [NO_CHILD; 8];
        for (i, c) in children.iter_mut().enumerate() {
            *c = get_i32_at(buf, 48 + i * 4);
        }
        let neuron = get_i64_at(buf, 80);
        let is_leaf = buf[88] != 0;
        WireNode { lo, side, vac_exc, vac_inh, pos_exc, pos_inh, children, neuron, is_leaf }
    }
}

/// Serialized local subtrees: the window bytes plus the window index of
/// each owned branch cell's subtree root.
pub struct SerializedWindow {
    pub bytes: Vec<u8>,
    /// cell -> window index of the branch node (only owned cells).
    pub root_of_cell: HashMap<usize, i32>,
}

/// Serialize this rank's branch nodes + local subtrees in DFS order.
/// Children pointers become window indices. Called after
/// `aggregate_local` but BEFORE `normalize` (the publish happens inside
/// the octree-update phase, ahead of the branch exchange), so position
/// sums are converted to weighted means here.
pub fn serialize_local_subtrees(
    tree: &Octree,
    own_cells: std::ops::Range<usize>,
) -> SerializedWindow {
    // First pass: assign window indices in DFS order.
    let mut order: Vec<usize> = Vec::new();
    let mut window_idx: HashMap<usize, i32> = HashMap::new();
    let mut root_of_cell = HashMap::new();
    for cell in own_cells {
        let root = tree.branch_of_cell[cell];
        root_of_cell.insert(cell, order.len() as i32);
        let mut stack = vec![root];
        while let Some(at) = stack.pop() {
            window_idx.insert(at, order.len() as i32);
            order.push(at);
            for &c in tree.nodes[at].children.iter().rev() {
                if c != NO_CHILD {
                    stack.push(c as usize);
                }
            }
        }
    }
    // Second pass: encode with remapped children.
    let mut bytes = Vec::with_capacity(order.len() * WireNode::SIZE);
    for &at in &order {
        let n = &tree.nodes[at];
        debug_assert!(matches!(n.kind, NodeKind::Branch | NodeKind::Local));
        let mut children = [NO_CHILD; 8];
        for (i, &c) in n.children.iter().enumerate() {
            if c != NO_CHILD {
                children[i] = window_idx[&(c as usize)];
            }
        }
        // Convert vacancy-weighted position sums to means; leaves carry
        // the exact neuron position.
        let mean = |sum: Vec3, vac: f32| -> [f32; 3] {
            let p = if n.neuron != super::tree::NO_NEURON {
                n.leaf_pos
            } else if vac > 0.0 {
                sum / vac as f64
            } else {
                Vec3::ZERO
            };
            [p.x as f32, p.y as f32, p.z as f32]
        };
        let w = WireNode {
            lo: [n.lo.x as f32, n.lo.y as f32, n.lo.z as f32],
            side: n.side as f32,
            vac_exc: n.vac_exc,
            vac_inh: n.vac_inh,
            pos_exc: mean(n.pos_exc, n.vac_exc),
            pos_inh: mean(n.pos_inh, n.vac_inh),
            children,
            neuron: n.neuron,
            is_leaf: n.is_leaf(),
        };
        w.write(&mut bytes);
    }
    SerializedWindow { bytes, root_of_cell }
}

/// Cache of octree nodes downloaded from other ranks' windows.
///
/// Paper §III-B0c: downloaded nodes "remain valid until the end of the
/// synapse-formation phase and thus do not need re-downloading for
/// subsequent neurons" — so the cache lives for one formation phase and
/// is cleared afterwards.
/// Dense per-rank node cache: window indices are contiguous, so a
/// `Vec<Option<WireNode>>` per rank turns each lookup into one indexed
/// load (a `HashMap<(rank, idx), _>` here cost ~35% of the old
/// algorithm's runtime in SipHash — EXPERIMENTS.md §Perf, opt 2).
#[derive(Default)]
pub struct RemoteNodeCache {
    per_rank: Vec<Vec<Option<WireNode>>>,
    /// Cache hits/misses for perf reporting.
    pub hits: u64,
    pub misses: u64,
}

impl RemoteNodeCache {
    pub fn clear(&mut self) {
        for v in self.per_rank.iter_mut() {
            v.clear();
        }
    }

    /// Fetch node `idx` of `rank`'s window, via RMA on a miss.
    pub fn get(&mut self, comm: &impl Comm, rank: u32, idx: i32) -> WireNode {
        let r = rank as usize;
        if self.per_rank.len() <= r {
            self.per_rank.resize_with(r + 1, Vec::new);
        }
        let slots = &mut self.per_rank[r];
        let i = idx as usize;
        if slots.len() <= i {
            // First touch of this rank this phase: size the cache to the
            // window once (free metadata peek).
            let window_nodes = comm
                .window_len(r, OCTREE_WINDOW)
                .map(|len| len / WireNode::SIZE)
                .unwrap_or(i + 1)
                .max(i + 1);
            slots.resize(window_nodes, None);
        }
        if let Some(n) = slots[i] {
            self.hits += 1;
            return n;
        }
        self.misses += 1;
        let bytes = comm.rma_get(r, OCTREE_WINDOW, i * WireNode::SIZE, WireNode::SIZE);
        let node = WireNode::read(&bytes);
        slots[i] = Some(node);
        node
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::ThreadComm;
    use crate::octree::domain::DomainDecomposition;
    use crate::octree::NO_NEURON;
    use crate::util::Rng;

    fn build_tree(n: usize) -> (DomainDecomposition, Octree) {
        let decomp = DomainDecomposition::new(1, 100.0);
        let mut rng = Rng::new(1);
        let positions: Vec<Vec3> = (0..n)
            .map(|_| {
                Vec3::new(
                    rng.uniform(0.0, 100.0),
                    rng.uniform(0.0, 100.0),
                    rng.uniform(0.0, 100.0),
                )
            })
            .collect();
        let mut tree = Octree::build(&decomp, 0, 0, &positions);
        let vac = vec![1.0f32; n];
        tree.reset_and_set_leaves(0, &vac, &vac);
        tree.aggregate_local();
        // NOTE: serialization happens pre-normalize (sums), mirroring
        // the octree-update phase ordering.
        (decomp, tree)
    }

    #[test]
    fn wire_node_size_is_89_bytes() {
        assert_eq!(WireNode::SIZE, 89);
    }

    #[test]
    fn wire_node_roundtrip() {
        let w = WireNode {
            lo: [1.0, 2.0, 3.0],
            side: 4.5,
            vac_exc: 2.0,
            vac_inh: 0.5,
            pos_exc: [1.5, 2.5, 3.5],
            pos_inh: [0.5, 0.5, 0.5],
            children: [0, NO_CHILD, 2, NO_CHILD, NO_CHILD, 5, NO_CHILD, 7],
            neuron: 1234567,
            is_leaf: false,
        };
        let mut buf = Vec::new();
        w.write(&mut buf);
        assert_eq!(buf.len(), WireNode::SIZE);
        assert_eq!(WireNode::read(&buf), w);
    }

    #[test]
    fn serialization_preserves_structure_and_values() {
        let (decomp, tree) = build_tree(100);
        let win = serialize_local_subtrees(&tree, decomp.cells_of_rank(0));
        let nodes: Vec<WireNode> =
            crate::util::wire::decode_all(&win.bytes);
        // Walk the window tree from the root; count leaves with neurons.
        let root = win.root_of_cell[&0] as usize;
        let mut stack = vec![root];
        let mut neurons = 0;
        let mut vac_sum = 0.0f32;
        while let Some(at) = stack.pop() {
            let n = &nodes[at];
            if n.neuron != NO_NEURON {
                neurons += 1;
                vac_sum += n.vac_exc;
            }
            for &c in &n.children {
                if c != NO_CHILD {
                    stack.push(c as usize);
                }
            }
        }
        assert_eq!(neurons, 100);
        assert!((vac_sum - 100.0).abs() < 1e-4);
        // Root aggregate survives the f32 narrowing.
        assert!((nodes[root].vac_exc - 100.0).abs() < 1e-3);
        // Positions on the wire are MEANS (a downloaded node is consumed
        // directly by the acceptance criterion), not weighted sums.
        let wp = nodes[root].pos(ElementKind::Excitatory);
        assert!(
            wp.x < 100.0 && wp.y < 100.0 && wp.z < 100.0 && wp.x > 0.0,
            "window root position {wp:?} looks like an unnormalized sum"
        );
    }

    #[test]
    fn remote_cache_fetches_once() {
        let (decomp, tree) = build_tree(10);
        let comm = ThreadComm::solo();
        let win = serialize_local_subtrees(&tree, decomp.cells_of_rank(0));
        comm.publish_window(OCTREE_WINDOW, win.bytes);
        let mut cache = RemoteNodeCache::default();
        let a = cache.get(&comm, 0, 0);
        let b = cache.get(&comm, 0, 0);
        assert_eq!(a, b);
        assert_eq!(cache.misses, 1);
        assert_eq!(cache.hits, 1);
    }
}
