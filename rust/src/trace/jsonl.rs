//! JSONL time-series export: one compact line per (rank, sample),
//! ordered by rank then step — ready for `jq`/pandas without a
//! Perfetto UI in the loop — plus one `"kind": "rank_summary"` line per
//! rank carrying the run-level observability that has no per-sample
//! shape: tracer ring evictions (`trace_dropped`) and the comm-latency
//! histograms (full bucket arrays; totals are deterministic call
//! counts, the spread is wall-clock — DESIGN.md §14).

use crate::bench::json::{obj, Json};
use crate::metrics::{HistSnapshot, RankReport, SimReport, ALL_PHASES};

use super::{boundary_names, EpochSample};

fn sample_json(rank: usize, s: &EpochSample) -> Json {
    let phases = ALL_PHASES
        .iter()
        .map(|p| (p.name().to_string(), Json::Num(s.phase_seconds[p.index()])))
        .collect();
    let boundaries =
        boundary_names(s.boundaries).into_iter().map(|n| Json::Str(n.to_string())).collect();
    obj(vec![
        ("rank", Json::Num(rank as f64)),
        ("step", Json::Num(s.step as f64)),
        ("boundaries", Json::Arr(boundaries)),
        ("ts_us", Json::Num(s.ts_micros)),
        ("phases", Json::Obj(phases)),
        (
            "comm",
            obj(vec![
                ("bytes_sent", Json::Num(s.comm.bytes_sent as f64)),
                ("bytes_recv", Json::Num(s.comm.bytes_recv as f64)),
                ("bytes_rma", Json::Num(s.comm.bytes_rma as f64)),
                ("msgs_sent", Json::Num(s.comm.msgs_sent as f64)),
                ("collectives", Json::Num(s.comm.collectives as f64)),
                ("rma_gets", Json::Num(s.comm.rma_gets as f64)),
            ]),
        ),
        ("spikes", Json::Num(s.spikes as f64)),
        ("formed", Json::Num(s.formed as f64)),
        ("retractions", Json::Num(s.retractions as f64)),
        ("plan_rebuilds", Json::Num(s.plan_rebuilds as f64)),
        ("migrations", Json::Num(s.migrations as f64)),
        (
            "cost",
            obj(vec![
                ("neurons", Json::Num(s.cost.neurons as f64)),
                ("local_edges", Json::Num(s.cost.local_edges as f64)),
                ("remote_partners", Json::Num(s.cost.remote_partners as f64)),
                ("nanos", Json::Num(s.cost.nanos as f64)),
                ("step_cost", Json::Num(s.cost.cost())),
            ]),
        ),
    ])
}

fn hist_json(h: &HistSnapshot) -> Json {
    obj(vec![
        ("total", Json::Num(h.total() as f64)),
        ("buckets", Json::Arr(h.counts.iter().map(|&c| Json::Num(c as f64)).collect())),
    ])
}

fn rank_summary_json(r: &RankReport) -> Json {
    obj(vec![
        ("rank", Json::Num(r.rank as f64)),
        ("kind", Json::Str("rank_summary".to_string())),
        ("trace_dropped", Json::Num(r.trace_dropped as f64)),
        (
            "comm_hist",
            obj(vec![
                ("a2a", hist_json(&r.comm_hists.a2a)),
                ("rma", hist_json(&r.comm_hists.rma)),
                ("barrier", hist_json(&r.comm_hists.barrier)),
            ]),
        ),
    ])
}

/// Render the report's traces as JSONL: one object per (rank, sample),
/// then one `rank_summary` object per rank.
pub fn trace_jsonl(report: &SimReport) -> String {
    let mut out = String::new();
    for r in &report.ranks {
        for s in &r.trace {
            out.push_str(&sample_json(r.rank, s).compact());
            out.push('\n');
        }
    }
    for r in &report.ranks {
        out.push_str(&rank_summary_json(r).compact());
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::json::parse;
    use crate::comm::CounterSnapshot;
    use crate::metrics::RankReport;
    use crate::trace::{BALANCE_EPOCH, PLASTICITY_EPOCH};

    #[test]
    fn one_parseable_line_per_rank_sample() {
        let s = EpochSample {
            step: 50,
            boundaries: PLASTICITY_EPOCH | BALANCE_EPOCH,
            comm: CounterSnapshot { bytes_sent: 1024, ..CounterSnapshot::default() },
            spikes: 12,
            ..EpochSample::default()
        };
        let r0 = RankReport { rank: 0, trace: vec![s.clone(), s.clone()], ..Default::default() };
        let mut r1 = RankReport { rank: 1, trace: vec![s], ..Default::default() };
        r1.trace_dropped = 3;
        r1.comm_hists.a2a.counts[2] = 8;
        r1.comm_hists.barrier.counts[0] = 1;
        let sim = SimReport { ranks: vec![r0, r1], ..Default::default() };
        let text = trace_jsonl(&sim);
        let lines: Vec<&str> = text.lines().collect();
        // 3 sample lines + one rank_summary per rank.
        assert_eq!(lines.len(), 5);
        let v = parse(lines[2]).unwrap();
        assert_eq!(v.get("rank").unwrap().as_u64().unwrap(), 1);
        assert_eq!(v.get("step").unwrap().as_u64().unwrap(), 50);
        assert_eq!(v.get("comm").unwrap().get("bytes_sent").unwrap().as_u64().unwrap(), 1024);
        assert_eq!(v.get("spikes").unwrap().as_u64().unwrap(), 12);
        let names: Vec<&str> = v
            .get("boundaries")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|b| b.as_str().unwrap())
            .collect();
        assert_eq!(names, vec!["plasticity", "balance"]);
        for p in ALL_PHASES {
            assert!(v.get("phases").unwrap().get(p.name()).is_some());
        }
        // The trailing summary lines surface ring evictions and the
        // latency histograms, one per rank in rank order.
        let s0 = parse(lines[3]).unwrap();
        assert_eq!(s0.get("kind").unwrap().as_str().unwrap(), "rank_summary");
        assert_eq!(s0.get("rank").unwrap().as_u64().unwrap(), 0);
        assert_eq!(s0.get("trace_dropped").unwrap().as_u64().unwrap(), 0);
        let s1 = parse(lines[4]).unwrap();
        assert_eq!(s1.get("trace_dropped").unwrap().as_u64().unwrap(), 3);
        let a2a = s1.get("comm_hist").unwrap().get("a2a").unwrap();
        assert_eq!(a2a.get("total").unwrap().as_u64().unwrap(), 8);
        assert_eq!(a2a.get("buckets").unwrap().as_arr().unwrap().len(), 32);
        assert_eq!(
            s1.get("comm_hist").unwrap().get("barrier").unwrap().get("total").unwrap()
                .as_u64().unwrap(),
            1
        );
        assert_eq!(trace_jsonl(&SimReport::default()), "");
    }
}
