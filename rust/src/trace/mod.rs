//! Epoch-granular telemetry: per-rank time series over the run.
//!
//! Every end-of-run aggregate this repro reports — spike-exchange
//! bytes, plan recompiles, the imbalance factor — hides *when* the
//! interesting dynamics happened. This module records them over time:
//! each rank keeps a bounded ring buffer of [`EpochSample`]s, one per
//! trace boundary (`instrumentation.trace_every` steps; the CLI
//! defaults it to the plasticity interval, one sample per connectivity
//! epoch). A sample holds the *deltas* since the previous sample —
//! per-phase seconds from `PhaseTimers`, comm counters via
//! [`CounterSnapshot::since`], spikes fired, synapse formations and
//! retractions, plan rebuilds, migrations — plus the rank's
//! [`RankCost`] at the boundary, finally surfacing the
//! gathered-but-unused `RankCost.nanos` (DESIGN.md §10).
//!
//! At run end the samples ride into `SimReport` and export two ways:
//! a Chrome `trace_event` JSON for Perfetto ([`chrome_trace`]) and a
//! JSONL time series ([`trace_jsonl`]).
//!
//! Determinism contract: sample *counts* and the counter-valued fields
//! of every sample are pure functions of seed + config, so the bench
//! harness drift-checks [`event_count`] (`trace_events`, BENCH schema
//! v5) exactly like `spike_lookups`. Only `ts_micros`,
//! `phase_seconds`, and `cost.nanos` are wall-clock observations.
//!
//! Segment scoping: like `phase_seconds`, traces belong to a process
//! segment and are **never stored in ILMISNAP** snapshots. The tracer
//! is primed (baselines captured) right after the rank's initial plan
//! compile — on restore too, so the recompile a resume performs is
//! excluded — which makes a resumed run's samples concatenate exactly
//! onto the pre-checkpoint run's (pinned by a differential test in
//! `coordinator::driver`).

mod jsonl;
mod perfetto;

pub use jsonl::trace_jsonl;
pub use perfetto::chrome_trace;

use std::collections::VecDeque;
use std::time::Instant;

use crate::balance::RankCost;
use crate::comm::CounterSnapshot;
use crate::config::SimConfig;
use crate::metrics::{SimReport, ALL_PHASES};

/// Sample boundary coincided with a spike-exchange epoch (`delta`).
pub const SPIKE_EPOCH: u8 = 1 << 0;
/// Sample boundary coincided with a plasticity epoch.
pub const PLASTICITY_EPOCH: u8 = 1 << 1;
/// Sample boundary coincided with a balance epoch.
pub const BALANCE_EPOCH: u8 = 1 << 2;
/// First sample of a segment that resumed from a checkpoint after a
/// supervised recovery (DESIGN.md §13). Unlike the other bits this is
/// NOT a pure function of step and config — it marks where a fault
/// actually struck, so recovery points stay visible in exported traces.
pub const RECOVERY_EPOCH: u8 = 1 << 3;

/// Human-readable names for a [`EpochSample::boundaries`] bit set.
pub fn boundary_names(bits: u8) -> Vec<&'static str> {
    let mut out = Vec::new();
    if bits & SPIKE_EPOCH != 0 {
        out.push("spike");
    }
    if bits & PLASTICITY_EPOCH != 0 {
        out.push("plasticity");
    }
    if bits & BALANCE_EPOCH != 0 {
        out.push("balance");
    }
    if bits & RECOVERY_EPOCH != 0 {
        out.push("recovery");
    }
    out
}

/// One rank's telemetry at one trace boundary. All counter-valued
/// fields are deltas since the previous sample (or since the tracer
/// was primed, for the first one); `ts_micros`, `phase_seconds`, and
/// `cost.nanos` are the only wall-clock observations.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct EpochSample {
    /// 1-based step count at the boundary (the sample covers steps
    /// `step - trace_every + 1 ..= step`).
    pub step: u64,
    /// Which epoch kinds this boundary coincided with
    /// ([`SPIKE_EPOCH`] | [`PLASTICITY_EPOCH`] | [`BALANCE_EPOCH`] |
    /// [`RECOVERY_EPOCH`]). A pure function of step and config, except
    /// `RECOVERY_EPOCH`, which marks the first sample after a
    /// supervised restart.
    pub boundaries: u8,
    /// Microseconds since the tracer was primed. Observational only.
    pub ts_micros: f64,
    /// Per-phase seconds spent in this window, `ALL_PHASES` order.
    /// Observational only.
    pub phase_seconds: [f64; ALL_PHASES.len()],
    /// Comm-counter deltas for this window (`CounterSnapshot::since`).
    pub comm: CounterSnapshot,
    /// Local neurons that fired in this window.
    pub spikes: u64,
    /// Synapses formed (formation phase) in this window.
    pub formed: u64,
    /// Synaptic-element retractions (axonal + dendritic) in this window.
    pub retractions: u64,
    /// Delivery-plan recompiles in this window.
    pub plan_rebuilds: u64,
    /// Neuron migrations applied in this window.
    pub migrations: u64,
    /// The rank's measured load at the boundary. The structural terms
    /// are deterministic; `cost.nanos` is the phase-timer reading.
    pub cost: RankCost,
}

/// Absolute (cumulative) readings taken off a rank at one boundary;
/// [`Tracer::record`] turns consecutive readings into deltas.
#[derive(Clone, Debug, Default)]
pub struct Cumulative {
    pub phase_seconds: [f64; ALL_PHASES.len()],
    pub comm: CounterSnapshot,
    pub spikes: u64,
    pub formed: u64,
    pub retractions: u64,
    pub plan_rebuilds: u64,
    pub migrations: u64,
}

/// Per-rank ring-buffered sampler. Pure scratch from the snapshot
/// format's point of view: never serialized, rebuilt (and re-primed)
/// at segment start, exactly like `PhaseTimers`.
#[derive(Clone, Debug)]
pub struct Tracer {
    every: usize,
    capacity: usize,
    ring: VecDeque<EpochSample>,
    recorded: u64,
    start: Instant,
    prev: Cumulative,
}

impl Tracer {
    pub fn new(every: usize, capacity: usize) -> Tracer {
        Tracer {
            every,
            capacity,
            ring: VecDeque::new(),
            recorded: 0,
            start: Instant::now(),
            prev: Cumulative::default(),
        }
    }

    pub fn from_config(cfg: &SimConfig) -> Tracer {
        Tracer::new(cfg.trace_every, cfg.trace_capacity)
    }

    /// Tracing is on at all (`trace_every > 0`).
    pub fn enabled(&self) -> bool {
        self.every > 0
    }

    /// Is the 0-based `step` a trace boundary?
    pub fn due(&self, step: usize) -> bool {
        self.every > 0 && (step + 1) % self.every == 0
    }

    /// Capture the baseline the first sample's deltas are taken
    /// against, and start the wall clock. Called once per segment,
    /// after the initial plan compile — so on a resumed segment the
    /// restore-time recompile is *not* attributed to the first window.
    pub fn prime(&mut self, now: &Cumulative) {
        self.prev = now.clone();
        self.start = Instant::now();
    }

    /// Record one sample: deltas of `now` against the previous
    /// reading. Oldest samples are evicted once the ring is full.
    pub fn record(&mut self, step: u64, boundaries: u8, now: &Cumulative, cost: RankCost) {
        if !self.enabled() {
            return;
        }
        let mut phase_seconds = [0.0; ALL_PHASES.len()];
        for (i, d) in phase_seconds.iter_mut().enumerate() {
            *d = (now.phase_seconds[i] - self.prev.phase_seconds[i]).max(0.0);
        }
        let sample = EpochSample {
            step,
            boundaries,
            ts_micros: self.start.elapsed().as_secs_f64() * 1e6,
            phase_seconds,
            comm: now.comm.since(&self.prev.comm),
            spikes: now.spikes - self.prev.spikes,
            formed: now.formed - self.prev.formed,
            retractions: now.retractions - self.prev.retractions,
            plan_rebuilds: now.plan_rebuilds - self.prev.plan_rebuilds,
            migrations: now.migrations - self.prev.migrations,
            cost,
        };
        self.prev = now.clone();
        while self.ring.len() >= self.capacity.max(1) {
            self.ring.pop_front();
        }
        self.ring.push_back(sample);
        self.recorded += 1;
    }

    /// Samples currently held (≤ capacity).
    pub fn len(&self) -> usize {
        self.ring.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ring.is_empty()
    }

    /// Samples recorded over the segment, including any evicted ones.
    pub fn recorded(&self) -> u64 {
        self.recorded
    }

    /// Drain the ring into the report's per-rank sample vector.
    pub fn into_samples(self) -> Vec<EpochSample> {
        self.ring.into_iter().collect()
    }
}

/// Chrome trace events per rank sample that [`chrome_trace`] emits:
/// one complete slice per phase (always all seven, even at zero
/// duration — event counts must not depend on timing) plus one point
/// per counter track (`bytes_sent`, `step_cost`, `spikes`).
pub const EVENTS_PER_SAMPLE: u64 = ALL_PHASES.len() as u64 + 3;

/// Samples every rank has (min across ranks): the length of the
/// cluster-wide `imbalance` counter track, which needs one cost per
/// rank per point.
pub fn aligned_samples(report: &SimReport) -> u64 {
    report.ranks.iter().map(|r| r.trace.len() as u64).min().unwrap_or(0)
}

/// Deterministic count of non-metadata Chrome trace events the report
/// exports: per-rank slices + counter points, plus the cluster
/// imbalance track. The quantity BENCH schema v5 drift-checks as
/// `trace_events`; a unit test pins it against the actual export.
pub fn event_count(report: &SimReport) -> u64 {
    let per_rank: u64 =
        report.ranks.iter().map(|r| r.trace.len() as u64 * EVENTS_PER_SAMPLE).sum();
    per_rank + aligned_samples(report)
}

/// Where `--trace-out PATH` writes: the Chrome trace at `PATH` itself
/// and the JSONL series next to it (`.json` swapped for `.jsonl`, or
/// appended when the extension differs).
pub fn export_paths(out: &str) -> (String, String) {
    let jsonl = match out.strip_suffix(".json") {
        Some(stem) => format!("{stem}.jsonl"),
        None => format!("{out}.jsonl"),
    };
    (out.to_string(), jsonl)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reading(scale: u64) -> Cumulative {
        Cumulative {
            phase_seconds: [scale as f64 * 0.5; ALL_PHASES.len()],
            comm: CounterSnapshot {
                bytes_sent: 100 * scale,
                bytes_recv: 100 * scale,
                bytes_rma: 8 * scale,
                msgs_sent: 4 * scale,
                collectives: 2 * scale,
                rma_gets: scale,
            },
            spikes: 10 * scale,
            formed: 3 * scale,
            retractions: 2 * scale,
            plan_rebuilds: scale,
            migrations: 0,
        }
    }

    #[test]
    fn record_takes_deltas_against_previous_reading() {
        let mut t = Tracer::new(50, 16);
        t.prime(&reading(1));
        t.record(50, PLASTICITY_EPOCH, &reading(3), RankCost::default());
        t.record(100, PLASTICITY_EPOCH | BALANCE_EPOCH, &reading(4), RankCost::default());
        let s = t.into_samples();
        assert_eq!(s.len(), 2);
        // First window: reading(3) - reading(1).
        assert_eq!(s[0].comm.bytes_sent, 200);
        assert_eq!(s[0].spikes, 20);
        assert_eq!(s[0].formed, 6);
        assert_eq!(s[0].plan_rebuilds, 2);
        assert!((s[0].phase_seconds[0] - 1.0).abs() < 1e-12);
        // Second window: reading(4) - reading(3).
        assert_eq!(s[1].comm.bytes_sent, 100);
        assert_eq!(s[1].spikes, 10);
        assert_eq!(s[1].retractions, 2);
        assert_eq!(s[1].boundaries, PLASTICITY_EPOCH | BALANCE_EPOCH);
        assert_eq!(boundary_names(s[1].boundaries), vec!["plasticity", "balance"]);
        assert_eq!(
            boundary_names(SPIKE_EPOCH | RECOVERY_EPOCH),
            vec!["spike", "recovery"]
        );
    }

    #[test]
    fn ring_keeps_the_last_capacity_samples() {
        let mut t = Tracer::new(10, 3);
        t.prime(&reading(0));
        for i in 1..=5u64 {
            t.record(10 * i, 0, &reading(i), RankCost::default());
        }
        assert_eq!(t.len(), 3);
        assert_eq!(t.recorded(), 5);
        let steps: Vec<u64> = t.into_samples().iter().map(|s| s.step).collect();
        assert_eq!(steps, vec![30, 40, 50]);
    }

    #[test]
    fn due_follows_the_cadence_and_disabled_never_fires() {
        let t = Tracer::new(25, 8);
        assert!(!t.due(0));
        assert!(t.due(24));
        assert!(!t.due(25));
        assert!(t.due(49));
        let off = Tracer::new(0, 8);
        assert!(!off.enabled());
        assert!(!off.due(24));
    }

    #[test]
    fn export_paths_swap_or_append_the_extension() {
        assert_eq!(
            export_paths("trace.json"),
            ("trace.json".to_string(), "trace.jsonl".to_string())
        );
        assert_eq!(export_paths("trace"), ("trace".to_string(), "trace.jsonl".to_string()));
    }
}
