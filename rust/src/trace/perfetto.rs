//! Chrome `trace_event` export: open the file in Perfetto
//! (ui.perfetto.dev) or `chrome://tracing`.
//!
//! Layout: one process (`pid`) per rank named `rank N`, with one named
//! thread track per phase carrying complete ("X") slices — all seven
//! phases get a slice per sample, even at zero duration, so the event
//! count is a pure function of the sample count — plus per-rank
//! counter ("C") tracks for `bytes_sent`, `step_cost`, and `spikes`.
//! A final `cluster` process carries the `imbalance` counter track
//! (max/mean `step_cost` across ranks per aligned sample), the same
//! quantity the load balancer drives down: on a skewed run the
//! migration epoch is readable straight off its drop (EXPERIMENTS.md
//! §Tracing).
//!
//! Timestamps (`ts`, `dur`, microseconds) are observational; slices
//! for a window are laid out end-to-start against the sample's
//! boundary timestamp, which places them correctly relative to each
//! other without requiring per-phase wall-clock bookkeeping.

use crate::bench::json::{obj, Json};
use crate::metrics::{SimReport, ALL_PHASES};

use super::aligned_samples;

fn metadata(name: &str, pid: usize, tid: usize, value: &str) -> Json {
    obj(vec![
        ("name", Json::Str(name.to_string())),
        ("ph", Json::Str("M".to_string())),
        ("pid", Json::Num(pid as f64)),
        ("tid", Json::Num(tid as f64)),
        ("args", obj(vec![("name", Json::Str(value.to_string()))])),
    ])
}

fn counter(name: &str, pid: usize, ts: f64, key: &str, value: f64) -> Json {
    obj(vec![
        ("name", Json::Str(name.to_string())),
        ("ph", Json::Str("C".to_string())),
        ("pid", Json::Num(pid as f64)),
        ("tid", Json::Num(0.0)),
        ("ts", Json::Num(ts)),
        ("args", obj(vec![(key, Json::Num(value))])),
    ])
}

/// Render the whole report as a Chrome trace-event JSON string.
/// Emits exactly [`super::event_count`] non-metadata events, plus (per
/// traced rank) three `comm_hist_*` counter points that sit outside the
/// count — they are run totals, not per-sample events.
pub fn chrome_trace(report: &SimReport) -> String {
    let mut events = Vec::new();
    for r in &report.ranks {
        let pid = r.rank;
        events.push(metadata("process_name", pid, 0, &format!("rank {pid}")));
        for p in ALL_PHASES {
            events.push(metadata("thread_name", pid, p.index() + 1, p.name()));
        }
        for s in &r.trace {
            // Phase slices, laid out back-to-back ending at the
            // boundary timestamp (most recent phase last).
            let mut end = s.ts_micros;
            for p in ALL_PHASES.iter().rev() {
                let dur = s.phase_seconds[p.index()] * 1e6;
                let ts = (end - dur).max(0.0);
                events.push(obj(vec![
                    ("name", Json::Str(p.name().to_string())),
                    ("ph", Json::Str("X".to_string())),
                    ("pid", Json::Num(pid as f64)),
                    ("tid", Json::Num(p.index() as f64 + 1.0)),
                    ("ts", Json::Num(ts)),
                    ("dur", Json::Num(dur)),
                    ("args", obj(vec![("step", Json::Num(s.step as f64))])),
                ]));
                end = ts;
            }
            events.push(counter(
                "bytes_sent",
                pid,
                s.ts_micros,
                "bytes_sent",
                s.comm.bytes_sent as f64,
            ));
            events.push(counter("step_cost", pid, s.ts_micros, "step_cost", s.cost.cost()));
            events.push(counter("spikes", pid, s.ts_micros, "spikes", s.spikes as f64));
        }
        // Comm-latency histogram totals: one counter point per primitive
        // at the rank's last boundary. Run-level observability riding on
        // the trace, NOT per-sample telemetry — excluded from
        // `super::event_count`'s closed form, which stays a pure
        // function of the sample count (DESIGN.md §14).
        if let Some(last) = r.trace.last() {
            let h = &r.comm_hists;
            for (name, total) in [
                ("comm_hist_a2a", h.a2a.total()),
                ("comm_hist_rma", h.rma.total()),
                ("comm_hist_barrier", h.barrier.total()),
            ] {
                events.push(counter(name, pid, last.ts_micros, "calls", total as f64));
            }
        }
    }
    // Cluster-wide imbalance track: one point per sample every rank
    // has. Rings evict oldest-first and all ranks share the cadence,
    // so aligning from the tail pairs up identical boundary steps.
    let cluster_pid = report.ranks.len();
    if !report.ranks.is_empty() {
        events.push(metadata("process_name", cluster_pid, 0, "cluster"));
    }
    let aligned = aligned_samples(report) as usize;
    for i in 0..aligned {
        let mut costs = Vec::with_capacity(report.ranks.len());
        let mut ts = 0.0f64;
        for r in &report.ranks {
            let s = &r.trace[r.trace.len() - aligned + i];
            costs.push(s.cost.cost());
            ts = ts.max(s.ts_micros);
        }
        events.push(counter(
            "imbalance",
            cluster_pid,
            ts,
            "imbalance",
            crate::balance::imbalance(&costs),
        ));
    }
    obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::Str("ms".to_string())),
    ])
    .pretty()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::balance::RankCost;
    use crate::bench::json::parse;
    use crate::metrics::RankReport;
    use crate::trace::{event_count, EpochSample, PLASTICITY_EPOCH};

    fn sample(step: u64, neurons: u64) -> EpochSample {
        EpochSample {
            step,
            boundaries: PLASTICITY_EPOCH,
            ts_micros: step as f64 * 1000.0,
            phase_seconds: [0.0001; ALL_PHASES.len()],
            spikes: 5,
            cost: RankCost { neurons, local_edges: 10, remote_partners: 2, nanos: 7 },
            ..EpochSample::default()
        }
    }

    fn two_rank_report() -> SimReport {
        let mk = |rank: usize, n: u64, samples: usize| RankReport {
            rank,
            trace: (1..=samples).map(|i| sample(50 * i as u64, n)).collect(),
            ..RankReport::default()
        };
        // Unequal sample counts: rank 1's ring evicted one sample.
        SimReport { ranks: vec![mk(0, 48, 3), mk(1, 16, 2)], wall_seconds: 1.0, ..Default::default() }
    }

    #[test]
    fn export_matches_the_deterministic_event_count() {
        let report = two_rank_report();
        let root = parse(&chrome_trace(&report)).unwrap();
        let events = root.get("traceEvents").unwrap().as_arr().unwrap();
        let non_meta = events
            .iter()
            .filter(|e| {
                e.get("ph").unwrap().as_str().unwrap() != "M"
                    && !e.get("name").unwrap().as_str().unwrap().starts_with("comm_hist_")
            })
            .count() as u64;
        // 3 + 2 samples at 10 events each, plus 2 aligned imbalance points.
        assert_eq!(non_meta, 52);
        assert_eq!(non_meta, event_count(&report));
        // The histogram tracks ride along outside the closed form: three
        // per traced rank.
        let hist_points = events
            .iter()
            .filter(|e| e.get("name").unwrap().as_str().unwrap().starts_with("comm_hist_"))
            .count();
        assert_eq!(hist_points, 6);
    }

    #[test]
    fn every_rank_gets_a_process_all_phases_and_counter_tracks() {
        let text = chrome_trace(&two_rank_report());
        let root = parse(&text).unwrap();
        let events = root.get("traceEvents").unwrap().as_arr().unwrap();
        for pid in [0.0, 1.0] {
            for p in ALL_PHASES {
                assert!(
                    events.iter().any(|e| {
                        e.get("ph").map(|v| v.as_str() == Ok("X")).unwrap_or(false)
                            && e.get("pid").unwrap().as_f64().unwrap() == pid
                            && e.get("name").unwrap().as_str().unwrap() == p.name()
                    }),
                    "rank {pid} missing a {} slice",
                    p.name()
                );
            }
            for track in
                ["bytes_sent", "step_cost", "spikes", "comm_hist_a2a", "comm_hist_barrier"]
            {
                assert!(events.iter().any(|e| {
                    e.get("ph").map(|v| v.as_str() == Ok("C")).unwrap_or(false)
                        && e.get("pid").unwrap().as_f64().unwrap() == pid
                        && e.get("name").unwrap().as_str().unwrap() == track
                }));
            }
        }
        // The cluster process carries the imbalance counter: 48 + 12 vs
        // 16 + 12 cost with two ranks -> max/mean = 60/44.
        let imb: Vec<f64> = events
            .iter()
            .filter(|e| {
                e.get("name").unwrap().as_str().unwrap() == "imbalance"
                    && e.get("ph").unwrap().as_str().unwrap() == "C"
            })
            .map(|e| e.get("args").unwrap().get("imbalance").unwrap().as_f64().unwrap())
            .collect();
        assert_eq!(imb.len(), 2);
        assert!((imb[0] - 60.0 / 44.0).abs() < 1e-12);
        assert!(text.contains("\"traceEvents\""));
    }

    #[test]
    fn empty_report_exports_no_events() {
        let report = SimReport::default();
        let root = parse(&chrome_trace(&report)).unwrap();
        assert!(root.get("traceEvents").unwrap().as_arr().unwrap().is_empty());
        assert_eq!(event_count(&report), 0);
    }
}
