//! Simulation configuration: typed config struct, INI-style parser,
//! presets matching the paper's experimental setups.
//!
//! The offline crate set has no `serde`/`toml`, so the parser is a small
//! hand-rolled INI subset: `[section]` headers, `key = value` lines, `#`
//! comments. Every key can also be overridden from the CLI as
//! `--set section.key=value`.

mod parser;

pub use parser::{parse_ini, ParseError};

use crate::neuron::params::NeuronParams;

/// Which connectivity-update algorithm to run (paper §IV-A).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConnectivityAlg {
    /// Original distributed Barnes–Hut: remote octree nodes are
    /// downloaded via (emulated) RMA during the descent.
    OldRma,
    /// Proposed location-aware Barnes–Hut: the searching neuron is sent
    /// to the rank owning the target subtree ("move computation").
    NewLocationAware,
    /// Direct O(n^2) evaluation (NEST-style baseline; testing/validation).
    Direct,
}

/// Which spike-exchange algorithm to run (paper §IV-B).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpikeAlg {
    /// Original: all-to-all exchange of fired neuron ids every step;
    /// receivers binary-search the sorted id lists.
    OldIds,
    /// Proposed: exchange firing frequencies every `delta` steps;
    /// receivers reconstruct spikes with a PRNG.
    NewFrequency,
}

/// Which backend executes the per-step neuron update.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Native Rust implementation (bit-compatible with the L1 kernel).
    Native,
    /// AOT-lowered JAX/Pallas artifact executed through PJRT.
    Xla,
}

/// Which communicator transports the simulated MPI traffic. Transport
/// only: the backend never enters the dynamics, so it is excluded from
/// the snapshot config fingerprint and both values produce bit-identical
/// trajectories (pinned by the cross-backend differential suite).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommBackend {
    /// Each rank is an OS thread in this process, exchanging through
    /// shared-memory slots (`comm::ThreadComm`) — the default.
    Thread,
    /// Each rank is a separate OS process, exchanging over Unix domain
    /// sockets (`comm::SocketComm`; Unix only).
    Socket,
}

/// Which `NeuronKernel` implementation executes the fused per-step
/// activity update (see `neuron::kernel`). Kernels are *execution
/// strategy*, not dynamics: all three produce bit-identical
/// trajectories (pinned by the cross-kernel differential suite), so
/// the choice is excluded from the snapshot config fingerprint and a
/// run may resume under a different kernel than it checkpointed with.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelKind {
    /// The straight-line scalar loop — the reference oracle.
    Scalar,
    /// Cache-blocked SoA walk in fixed-width chunks with branchless
    /// spike/reset selects (autovectorizes; elementwise, so lane order
    /// — and with it every bit — matches the scalar loop).
    Blocked,
    /// The XLA/PJRT staged path with persistent staging buffers
    /// (Izhikevich only; requires a live executor service).
    Xla,
}

impl KernelKind {
    /// Stable lower-case name (INI value, CLI value, bench JSON).
    pub fn name(&self) -> &'static str {
        match self {
            KernelKind::Scalar => "scalar",
            KernelKind::Blocked => "blocked",
            KernelKind::Xla => "xla",
        }
    }

    /// Inverse of [`name`](Self::name).
    pub fn from_name(name: &str) -> Option<KernelKind> {
        match name {
            "scalar" => Some(KernelKind::Scalar),
            "blocked" => Some(KernelKind::Blocked),
            "xla" => Some(KernelKind::Xla),
            _ => None,
        }
    }
}

/// Which neuron model drives the electrical activity (the plasticity
/// machinery is model-agnostic — paper §III-A0a "computed using models
/// like Izhikevich").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NeuronModel {
    /// Izhikevich (2003) spiking model — the default; this is what the
    /// L1 Pallas kernel implements, so it works on both backends.
    Izhikevich,
    /// Rate-based Poisson model (native backend only).
    Poisson,
}

/// Full simulation configuration.
///
/// # Examples
///
/// Start from defaults, override fields the INI way (exactly what the
/// CLI's `--set section.key=value` does), and validate:
///
/// ```
/// use ilmi::config::{ConnectivityAlg, SimConfig};
///
/// let mut cfg = SimConfig::default();
/// cfg.apply_kv("topology.ranks", "4").unwrap();
/// cfg.apply_kv("algorithms.connectivity", "old").unwrap();
/// assert_eq!(cfg.connectivity_alg, ConnectivityAlg::OldRma);
/// assert_eq!(cfg.total_neurons(), 4 * cfg.neurons_per_rank);
/// cfg.validate().unwrap();
///
/// // Unknown keys error instead of silently doing nothing.
/// assert!(cfg.apply_kv("topology.bogus", "1").is_err());
///
/// // Configs round-trip through the INI dialect snapshots embed.
/// let back = SimConfig::from_ini(&cfg.to_ini()).unwrap();
/// assert_eq!(back, cfg);
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct SimConfig {
    // -- topology ------------------------------------------------------
    /// Number of simulated MPI ranks (threads).
    pub ranks: usize,
    /// Neurons owned by each rank.
    pub neurons_per_rank: usize,
    /// Edge length of the cubic simulation domain (µm).
    pub domain_size: f64,
    /// Global PRNG seed.
    pub seed: u64,
    /// Communicator transport (thread or process-per-rank socket).
    pub comm_backend: CommBackend,

    // -- schedule ------------------------------------------------------
    /// Total simulation steps (1 step = 1 ms biological time).
    pub steps: usize,
    /// Connectivity update every this many steps (paper: 100).
    pub plasticity_interval: usize,
    /// Frequency-exchange epoch Δ for `SpikeAlg::NewFrequency`
    /// (paper: 100 — every connectivity update).
    pub delta: usize,

    // -- algorithms ----------------------------------------------------
    pub connectivity_alg: ConnectivityAlg,
    pub spike_alg: SpikeAlg,
    pub backend: Backend,
    pub neuron_model: NeuronModel,
    /// Which `NeuronKernel` executes the activity update (execution
    /// strategy only — all kernels are bit-identical; see `[compute]`).
    pub kernel: KernelKind,
    /// Barnes–Hut acceptance criterion θ (paper: {0.2, 0.3, 0.4}).
    pub theta: f64,

    // -- model ---------------------------------------------------------
    /// Gaussian connection-kernel width σ (µm).
    pub sigma: f64,
    /// Fraction of excitatory neurons (rest inhibitory).
    pub frac_excitatory: f64,
    /// Initial vacant synaptic elements per neuron drawn uniformly from
    /// [lo, hi] (paper: [1.1, 1.5]).
    pub init_elements_lo: f64,
    pub init_elements_hi: f64,
    /// Background input ~ N(mean, std) (paper §V-D: N(5, 1)).
    pub bg_mean: f64,
    pub bg_std: f64,
    /// Neuron/plasticity model parameters (shared with L1/L2 as a
    /// (16,)-f32 vector — see `neuron::params`).
    pub neuron: NeuronParams,

    // -- instrumentation -----------------------------------------------
    /// Record per-neuron calcium every this many steps (0 = off).
    pub record_calcium_every: usize,
    /// Directory with AOT artifacts (for `Backend::Xla`).
    pub artifacts_dir: String,
    /// Write a resumable snapshot every this many steps (0 = off).
    /// Requires `checkpoint_dir`. See the `snapshot` module.
    pub checkpoint_every: usize,
    /// Directory snapshots are written to (one file per checkpoint).
    pub checkpoint_dir: String,
    /// Retention ring: keep only the newest K checkpoints in
    /// `checkpoint_dir`, pruning older ones after each successful write
    /// (0 = keep all). Gives the recovery scan a bounded set of
    /// fallback candidates without unbounded disk growth.
    pub checkpoint_keep: usize,
    /// Record an epoch-telemetry sample every this many steps (0 =
    /// off). Sample counts are seed-deterministic; see the `trace`
    /// module. The CLI defaults this to the plasticity interval when
    /// `--trace-out` is given alone.
    pub trace_every: usize,
    /// Ring-buffer bound on retained samples per rank; the oldest are
    /// evicted once full.
    pub trace_capacity: usize,
    /// Write the Chrome trace-event JSON here at run end (the JSONL
    /// series lands next to it); empty = no export.
    pub trace_out: String,

    // -- load balancing (see the `balance` module) -----------------------
    /// Check rank-load imbalance (and migrate neurons if it exceeds the
    /// threshold) every this many steps; 0 disables balancing entirely
    /// (the default — the historical fixed-stride behavior). Must be a
    /// multiple of `plasticity_interval`: migrations piggyback on
    /// connectivity-update epochs.
    pub balance_every: usize,
    /// Migrate only while max/mean step cost exceeds this factor
    /// (1.0 = perfectly balanced).
    pub balance_threshold: f64,
    /// Boundary cells migrated per balance epoch (at most).
    pub balance_max_moves: usize,
    /// Explicit initial rank → cell split, as comma-separated cell
    /// counts summing to the domain's 8^b Morton cells (e.g. "6,2").
    /// Empty = the uniform default. A skewed split seeds a skewed
    /// neuron distribution — the scenario the balancer demonstrably
    /// irons out (EXPERIMENTS.md §Load balancing).
    pub balance_init_cells: String,

    // -- fault tolerance (see the `fault` module, DESIGN.md §13) ---------
    /// Deterministic fault-injection plan (`fault::FaultPlan` spec
    /// grammar; `[faults] plan = ...` or repeated `--fault`). Empty =
    /// no injection. Deliberately **never emitted** by [`to_ini`]: the
    /// config embedded in snapshots describes the simulation, not the
    /// failures injected around it, so a recovered faulted run ends
    /// bit-identical to a clean one.
    pub fault_plan: String,
    /// Supervised socket runs: when a rank process dies, respawn the
    /// fleet from the newest valid checkpoint up to this many times
    /// (0 = fail fast, the historical behavior). Requires the socket
    /// backend and `checkpoint_every > 0`.
    pub max_recoveries: usize,

    // -- live telemetry (see the `telemetry` module, DESIGN.md §14) ------
    /// Heartbeat cadence in steps (`[telemetry] every`,
    /// `--telemetry-every`; 0 = off). Socket backend only: each rank
    /// process streams a `HealthFrame` to the supervisor every this
    /// many completed steps. Pure observation — like `faults.plan`,
    /// the `[telemetry]` keys are never serialized by [`to_ini`], so
    /// snapshot bytes and config fingerprints are unchanged by them.
    pub telemetry_every: u64,
    /// Hang watchdog: treat a rank silent for this many times the
    /// largest observed inter-beat gap as hung and fail the fleet into
    /// the recovery loop (0 = watchdog off). Requires
    /// `telemetry_every > 0`.
    pub telemetry_watchdog_misses: u32,
    /// Directory the supervisor atomically rewrites `status.json` in
    /// for `ilmi status` (`[telemetry] status_dir`, `--status-dir`;
    /// empty = off). Requires `telemetry_every > 0`.
    pub status_dir: String,
}

impl Default for SimConfig {
    fn default() -> Self {
        Self {
            ranks: 2,
            neurons_per_rank: 256,
            domain_size: 1000.0,
            seed: 42,
            comm_backend: CommBackend::Thread,
            steps: 1000,
            plasticity_interval: 100,
            delta: 100,
            connectivity_alg: ConnectivityAlg::NewLocationAware,
            spike_alg: SpikeAlg::NewFrequency,
            backend: Backend::Native,
            neuron_model: NeuronModel::Izhikevich,
            kernel: KernelKind::Scalar,
            theta: 0.3,
            sigma: 750.0,
            frac_excitatory: 0.8,
            init_elements_lo: 1.1,
            init_elements_hi: 1.5,
            bg_mean: 5.0,
            bg_std: 1.0,
            neuron: NeuronParams::default(),
            record_calcium_every: 0,
            artifacts_dir: "artifacts".to_string(),
            checkpoint_every: 0,
            checkpoint_dir: String::new(),
            checkpoint_keep: 0,
            trace_every: 0,
            trace_capacity: 4096,
            trace_out: String::new(),
            balance_every: 0,
            balance_threshold: 1.2,
            balance_max_moves: 1,
            balance_init_cells: String::new(),
            fault_plan: String::new(),
            max_recoveries: 0,
            telemetry_every: 0,
            telemetry_watchdog_misses: 0,
            status_dir: String::new(),
        }
    }
}

impl SimConfig {
    /// Total neuron count across all ranks.
    pub fn total_neurons(&self) -> usize {
        self.ranks * self.neurons_per_rank
    }

    /// Paper §V-B experimental setup: 1000 steps, 10 plasticity updates,
    /// no initial connectivity, 1.1–1.5 vacant elements per neuron.
    pub fn paper_timing(ranks: usize, neurons_per_rank: usize, theta: f64) -> Self {
        Self {
            ranks,
            neurons_per_rank,
            theta,
            steps: 1000,
            plasticity_interval: 100,
            delta: 100,
            ..Self::default()
        }
    }

    /// Paper §V-D quality setup: 32 neurons on 32 ranks (one each),
    /// target calcium 0.7, growth rate 0.001, background N(5,1).
    pub fn paper_quality(steps: usize) -> Self {
        let mut neuron = NeuronParams::default();
        neuron.eps_target_ca = 0.7;
        neuron.nu_growth = 0.001;
        Self {
            ranks: 32,
            neurons_per_rank: 1,
            steps,
            plasticity_interval: 100,
            delta: 100,
            bg_mean: 5.0,
            bg_std: 1.0,
            neuron,
            record_calcium_every: 100,
            ..Self::default()
        }
    }

    /// Apply a `section.key=value` override. Unknown keys are an error so
    /// typos surface instead of silently doing nothing.
    pub fn apply_kv(&mut self, key: &str, value: &str) -> Result<(), String> {
        let bad = |what: &str| format!("invalid value {value:?} for {what}");
        match key {
            "topology.ranks" => self.ranks = value.parse().map_err(|_| bad(key))?,
            "topology.neurons_per_rank" => {
                self.neurons_per_rank = value.parse().map_err(|_| bad(key))?
            }
            "topology.domain_size" => self.domain_size = value.parse().map_err(|_| bad(key))?,
            "topology.seed" => self.seed = value.parse().map_err(|_| bad(key))?,
            "topology.comm" => {
                self.comm_backend = match value {
                    "thread" => CommBackend::Thread,
                    "socket" => CommBackend::Socket,
                    _ => return Err(bad(key)),
                }
            }
            "schedule.steps" => self.steps = value.parse().map_err(|_| bad(key))?,
            "schedule.plasticity_interval" => {
                self.plasticity_interval = value.parse().map_err(|_| bad(key))?
            }
            "schedule.delta" => self.delta = value.parse().map_err(|_| bad(key))?,
            "algorithms.connectivity" => {
                self.connectivity_alg = match value {
                    "old" | "old_rma" => ConnectivityAlg::OldRma,
                    "new" | "location_aware" => ConnectivityAlg::NewLocationAware,
                    "direct" => ConnectivityAlg::Direct,
                    _ => return Err(bad(key)),
                }
            }
            "algorithms.spikes" => {
                self.spike_alg = match value {
                    "old" | "ids" => SpikeAlg::OldIds,
                    "new" | "frequency" => SpikeAlg::NewFrequency,
                    _ => return Err(bad(key)),
                }
            }
            "algorithms.backend" => {
                self.backend = match value {
                    "native" => Backend::Native,
                    "xla" => Backend::Xla,
                    _ => return Err(bad(key)),
                }
            }
            "compute.kernel" => {
                self.kernel = KernelKind::from_name(value).ok_or_else(|| bad(key))?
            }
            "model.neuron_model" => {
                self.neuron_model = match value {
                    "izhikevich" => NeuronModel::Izhikevich,
                    "poisson" | "rate" => NeuronModel::Poisson,
                    _ => return Err(bad(key)),
                }
            }
            "algorithms.theta" => self.theta = value.parse().map_err(|_| bad(key))?,
            "model.sigma" => self.sigma = value.parse().map_err(|_| bad(key))?,
            "model.frac_excitatory" => {
                self.frac_excitatory = value.parse().map_err(|_| bad(key))?
            }
            "model.init_elements_lo" => {
                self.init_elements_lo = value.parse().map_err(|_| bad(key))?
            }
            "model.init_elements_hi" => {
                self.init_elements_hi = value.parse().map_err(|_| bad(key))?
            }
            "model.bg_mean" => self.bg_mean = value.parse().map_err(|_| bad(key))?,
            "model.bg_std" => self.bg_std = value.parse().map_err(|_| bad(key))?,
            "model.target_calcium" => {
                self.neuron.eps_target_ca = value.parse().map_err(|_| bad(key))?
            }
            "model.growth_rate" => {
                self.neuron.nu_growth = value.parse().map_err(|_| bad(key))?
            }
            "model.tau_calcium" => self.neuron.tau_ca = value.parse().map_err(|_| bad(key))?,
            "model.beta_calcium" => self.neuron.beta_ca = value.parse().map_err(|_| bad(key))?,
            "instrumentation.record_calcium_every" => {
                self.record_calcium_every = value.parse().map_err(|_| bad(key))?
            }
            "instrumentation.artifacts_dir" => self.artifacts_dir = value.to_string(),
            "instrumentation.checkpoint_every" => {
                self.checkpoint_every = value.parse().map_err(|_| bad(key))?
            }
            "instrumentation.checkpoint_dir" => self.checkpoint_dir = value.to_string(),
            "instrumentation.checkpoint_keep" => {
                self.checkpoint_keep = value.parse().map_err(|_| bad(key))?
            }
            "instrumentation.trace_every" => {
                self.trace_every = value.parse().map_err(|_| bad(key))?
            }
            "instrumentation.trace_capacity" => {
                self.trace_capacity = value.parse().map_err(|_| bad(key))?
            }
            "instrumentation.trace_out" => self.trace_out = value.to_string(),
            "balance.every" => self.balance_every = value.parse().map_err(|_| bad(key))?,
            "balance.threshold" => {
                self.balance_threshold = value.parse().map_err(|_| bad(key))?
            }
            "balance.max_moves" => {
                self.balance_max_moves = value.parse().map_err(|_| bad(key))?
            }
            "balance.init_cells" => self.balance_init_cells = value.to_string(),
            "faults.plan" => self.fault_plan = value.to_string(),
            "recovery.max_recoveries" => {
                self.max_recoveries = value.parse().map_err(|_| bad(key))?
            }
            "telemetry.every" => {
                self.telemetry_every = value.parse().map_err(|_| bad(key))?
            }
            "telemetry.watchdog_misses" => {
                self.telemetry_watchdog_misses = value.parse().map_err(|_| bad(key))?
            }
            "telemetry.status_dir" => self.status_dir = value.to_string(),
            _ => return Err(format!("unknown config key: {key}")),
        }
        Ok(())
    }

    /// Serialize to the INI dialect `from_ini` parses, so a config can
    /// travel inside a snapshot and `ilmi resume` needs no separate
    /// config file. Float formatting uses Rust's shortest-round-trip
    /// `Display`, so `from_ini(to_ini(cfg))` reproduces every
    /// INI-expressible field exactly. Neuron parameters without an INI
    /// key (e.g. Izhikevich a/b/c/d) are not serialized — the snapshot
    /// config *fingerprint* covers them, so a programmatically changed
    /// parameter is still caught at resume time.
    pub fn to_ini(&self) -> String {
        let conn = match self.connectivity_alg {
            ConnectivityAlg::OldRma => "old",
            ConnectivityAlg::NewLocationAware => "new",
            ConnectivityAlg::Direct => "direct",
        };
        let spikes = match self.spike_alg {
            SpikeAlg::OldIds => "old",
            SpikeAlg::NewFrequency => "new",
        };
        let backend = match self.backend {
            Backend::Native => "native",
            Backend::Xla => "xla",
        };
        let model = match self.neuron_model {
            NeuronModel::Izhikevich => "izhikevich",
            NeuronModel::Poisson => "poisson",
        };
        let mut out = format!(
            "[topology]\n\
             ranks = {}\n\
             neurons_per_rank = {}\n\
             domain_size = {}\n\
             seed = {}\n",
            self.ranks, self.neurons_per_rank, self.domain_size, self.seed,
        );
        // Emitted only when non-default so a thread-backend config's INI
        // bytes — and with them every snapshot fingerprint and pinned
        // golden file — are unchanged by the key's existence.
        if self.comm_backend == CommBackend::Socket {
            out.push_str("comm = socket\n");
        }
        out.push_str(&format!(
            "[schedule]\n\
             steps = {}\n\
             plasticity_interval = {}\n\
             delta = {}\n\
             [algorithms]\n\
             connectivity = {conn}\n\
             spikes = {spikes}\n\
             backend = {backend}\n\
             theta = {}\n\
             [model]\n\
             neuron_model = {model}\n\
             sigma = {}\n\
             frac_excitatory = {}\n\
             init_elements_lo = {}\n\
             init_elements_hi = {}\n\
             bg_mean = {}\n\
             bg_std = {}\n\
             target_calcium = {}\n\
             growth_rate = {}\n\
             tau_calcium = {}\n\
             beta_calcium = {}\n\
             [instrumentation]\n\
             record_calcium_every = {}\n\
             artifacts_dir = {}\n",
            self.steps,
            self.plasticity_interval,
            self.delta,
            self.theta,
            self.sigma,
            self.frac_excitatory,
            self.init_elements_lo,
            self.init_elements_hi,
            self.bg_mean,
            self.bg_std,
            self.neuron.eps_target_ca,
            self.neuron.nu_growth,
            self.neuron.tau_ca,
            self.neuron.beta_ca,
            self.record_calcium_every,
            self.artifacts_dir,
        ));
        if self.checkpoint_every > 0 {
            out.push_str(&format!("checkpoint_every = {}\n", self.checkpoint_every));
        }
        if !self.checkpoint_dir.is_empty() {
            out.push_str(&format!("checkpoint_dir = {}\n", self.checkpoint_dir));
        }
        if self.checkpoint_keep > 0 {
            out.push_str(&format!("checkpoint_keep = {}\n", self.checkpoint_keep));
        }
        out.push_str(&format!(
            "trace_every = {}\ntrace_capacity = {}\n",
            self.trace_every, self.trace_capacity
        ));
        if !self.trace_out.is_empty() {
            out.push_str(&format!("trace_out = {}\n", self.trace_out));
        }
        out.push_str(&format!(
            "[balance]\n\
             every = {}\n\
             threshold = {}\n\
             max_moves = {}\n",
            self.balance_every, self.balance_threshold, self.balance_max_moves,
        ));
        if !self.balance_init_cells.is_empty() {
            out.push_str(&format!("init_cells = {}\n", self.balance_init_cells));
        }
        // Emitted only when non-default, like `topology.comm`: a
        // scalar-kernel config's INI bytes — and with them every
        // pre-existing snapshot's embedded config — are unchanged by
        // the key's existence.
        if self.kernel != KernelKind::Scalar {
            out.push_str(&format!("[compute]\nkernel = {}\n", self.kernel.name()));
        }
        // Emitted only when non-default, like the keys above. The fault
        // plan (`faults.plan`) is deliberately NOT serialized at all:
        // snapshots describe the simulation, not the failures injected
        // around it, so a faulted run's snapshots stay byte-identical
        // to a clean run's.
        if self.max_recoveries > 0 {
            out.push_str(&format!("[recovery]\nmax_recoveries = {}\n", self.max_recoveries));
        }
        // The `[telemetry]` keys are deliberately NOT serialized, like
        // `faults.plan`: they are live-observation knobs around the run,
        // not part of the simulated dynamics, so snapshot bytes and the
        // config fingerprint are identical with telemetry on or off.
        out
    }

    /// Parse an INI-style config file content into a config, starting
    /// from defaults.
    pub fn from_ini(text: &str) -> Result<Self, String> {
        let mut cfg = Self::default();
        let entries = parse_ini(text).map_err(|e| e.to_string())?;
        for (key, value) in entries {
            cfg.apply_kv(&key, &value)?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Load from a file path.
    pub fn from_file(path: &str) -> Result<Self, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("cannot read config {path}: {e}"))?;
        Self::from_ini(&text)
    }

    /// Sanity-check invariants the rest of the system assumes.
    pub fn validate(&self) -> Result<(), String> {
        if self.ranks == 0 {
            return Err("topology.ranks must be > 0".into());
        }
        if self.neurons_per_rank == 0 {
            return Err("topology.neurons_per_rank must be > 0".into());
        }
        if !(self.theta >= 0.0 && self.theta < 1.0) {
            return Err("algorithms.theta must be in [0, 1)".into());
        }
        if self.plasticity_interval == 0 || self.delta == 0 {
            return Err("schedule intervals must be > 0".into());
        }
        if !(self.frac_excitatory >= 0.0 && self.frac_excitatory <= 1.0) {
            return Err("model.frac_excitatory must be in [0, 1]".into());
        }
        if self.init_elements_lo > self.init_elements_hi {
            return Err("model.init_elements_lo must be <= hi".into());
        }
        if self.sigma <= 0.0 || self.domain_size <= 0.0 {
            return Err("model.sigma and topology.domain_size must be > 0".into());
        }
        // Directory values travel through the INI round-trip inside
        // snapshots; the parser treats '#'/';' as comment starts and
        // has no escaping, so such paths would be silently truncated
        // at resume. Reject them up front instead.
        for (key, value) in [
            ("instrumentation.artifacts_dir", &self.artifacts_dir),
            ("instrumentation.checkpoint_dir", &self.checkpoint_dir),
            ("instrumentation.trace_out", &self.trace_out),
        ] {
            if value.contains(&['#', ';', '\n'][..]) {
                return Err(format!(
                    "{key} must not contain '#', ';' or newlines (the INI config \
                     format embedded in snapshots cannot represent them): {value:?}"
                ));
            }
        }
        if self.checkpoint_every > 0 && self.checkpoint_dir.is_empty() {
            return Err(
                "instrumentation.checkpoint_every (--checkpoint-every) requires \
                 instrumentation.checkpoint_dir (--checkpoint-dir): snapshots need \
                 a directory to be written to"
                    .into(),
            );
        }
        if self.checkpoint_keep > 0 && self.checkpoint_every == 0 {
            return Err(
                "instrumentation.checkpoint_keep (--checkpoint-keep) requires \
                 instrumentation.checkpoint_every > 0: there is no checkpoint \
                 ring to prune without checkpointing"
                    .into(),
            );
        }
        if !self.trace_out.is_empty() && self.trace_every == 0 {
            return Err(
                "instrumentation.trace_out (--trace-out) requires \
                 instrumentation.trace_every > 0 (--trace-every; the CLI defaults \
                 it to the plasticity interval when only --trace-out is given)"
                    .into(),
            );
        }
        if self.trace_every > 0 && self.trace_capacity == 0 {
            return Err(
                "instrumentation.trace_capacity must be >= 1 when tracing is on \
                 (it bounds the per-rank sample ring)"
                    .into(),
            );
        }
        if self.neuron_model == NeuronModel::Poisson && self.backend == Backend::Xla {
            return Err(
                "model.neuron_model=poisson runs on the native backend only \
                 (the AOT artifact implements the Izhikevich kernel)"
                    .into(),
            );
        }
        if self.neuron_model == NeuronModel::Poisson && self.kernel == KernelKind::Xla {
            return Err(
                "model.neuron_model=poisson cannot run compute.kernel=xla \
                 (the AOT artifact implements the Izhikevich kernel; use \
                 scalar or blocked)"
                    .into(),
            );
        }
        if self.comm_backend == CommBackend::Socket {
            // Socket ranks are separate processes; the shared XLA
            // executor handle assumes one address space. (Checkpointing
            // works: rank processes assemble snapshots through part
            // files in `checkpoint_dir` — see `snapshot::PartSink`.)
            if self.backend == Backend::Xla {
                return Err(
                    "topology.comm=socket runs the native backend only \
                     (algorithms.backend=xla needs the shared in-process executor)"
                        .into(),
                );
            }
            if self.kernel == KernelKind::Xla {
                return Err(
                    "topology.comm=socket cannot run compute.kernel=xla: rank \
                     processes cannot share the in-process XLA executor handle \
                     (use scalar or blocked)"
                        .into(),
                );
            }
        }
        // The initial partition must be constructible (init_cells format,
        // per-rank cell minimums, Morton cell totals)...
        crate::balance::Partition::from_config(self)?;
        // ...and active balancing needs sane knobs: migrations piggyback
        // on connectivity-update epochs, and a threshold at or below 1.0
        // would migrate forever (1.0 is unreachable in general).
        if self.balance_every > 0 {
            if self.balance_every % self.plasticity_interval != 0 {
                return Err(format!(
                    "balance.every ({}) must be a multiple of schedule.plasticity_interval \
                     ({}): migrations run at connectivity-update epochs",
                    self.balance_every, self.plasticity_interval
                ));
            }
            // Under the frequency algorithm a migration must land on a
            // spike-epoch boundary too: the very next step then runs a
            // fresh frequency exchange routed by the new ownership, so
            // a formerly-local pair (for which no entry exists anywhere
            // to migrate) never silently reconstructs against 0.0 for
            // the rest of a straddled epoch.
            if self.spike_alg == SpikeAlg::NewFrequency && self.balance_every % self.delta != 0
            {
                return Err(format!(
                    "balance.every ({}) must be a multiple of schedule.delta ({}) under \
                     the frequency spike algorithm: migrations must land on spike-epoch \
                     boundaries so reconstruction state is rebuilt immediately",
                    self.balance_every, self.delta
                ));
            }
            if !(self.balance_threshold > 1.0 && self.balance_threshold.is_finite()) {
                return Err("balance.threshold must be > 1.0 (max/mean cost factor)".into());
            }
            if self.balance_max_moves == 0 {
                return Err("balance.max_moves must be >= 1 when balancing is on".into());
            }
        }
        // Fault-injection and supervision knobs: a malformed plan (or
        // one whose faults can never fire) must fail at validation, not
        // silently "pass" a chaos test by injecting nothing.
        let plan = crate::fault::FaultPlan::parse(&self.fault_plan)
            .map_err(|e| format!("faults.plan (--fault): {e}"))?;
        if !plan.is_empty() && self.comm_backend != CommBackend::Socket {
            return Err(
                "faults.plan (--fault) requires topology.comm=socket: faults are \
                 armed inside rank processes (arming the shared thread-backend \
                 process would leak injected state across runs)"
                    .into(),
            );
        }
        if self.max_recoveries > 0 {
            if self.comm_backend != CommBackend::Socket {
                return Err(
                    "recovery.max_recoveries (--max-recoveries) requires \
                     topology.comm=socket: only rank processes can be respawned \
                     (thread-backend failures abort the whole process)"
                        .into(),
                );
            }
            if self.checkpoint_every == 0 {
                return Err(
                    "recovery.max_recoveries (--max-recoveries) requires \
                     instrumentation.checkpoint_every > 0: recovery restarts \
                     from the newest valid checkpoint"
                        .into(),
                );
            }
        }
        // Live-telemetry knobs: heartbeats only exist between rank
        // processes and a supervisor, and the watchdog/status plane
        // consumes heartbeats — each gate names the missing half.
        if self.telemetry_every > 0 && self.comm_backend != CommBackend::Socket {
            return Err(
                "telemetry.every (--telemetry-every) requires topology.comm=socket: \
                 heartbeats stream from rank processes to the supervisor over the \
                 launcher's control socket (the thread backend has neither)"
                    .into(),
            );
        }
        if self.telemetry_watchdog_misses > 0 && self.telemetry_every == 0 {
            return Err(
                "telemetry.watchdog_misses (--watchdog-misses) requires \
                 telemetry.every > 0: the hang watchdog counts missed heartbeats"
                    .into(),
            );
        }
        if !self.status_dir.is_empty() && self.telemetry_every == 0 {
            return Err(
                "telemetry.status_dir (--status-dir) requires telemetry.every > 0: \
                 status.json aggregates heartbeats"
                    .into(),
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        SimConfig::default().validate().unwrap();
    }

    #[test]
    fn paper_presets_validate() {
        SimConfig::paper_timing(8, 1024, 0.3).validate().unwrap();
        SimConfig::paper_quality(1000).validate().unwrap();
    }

    #[test]
    fn quality_preset_matches_paper() {
        let cfg = SimConfig::paper_quality(200_000);
        assert_eq!(cfg.ranks, 32);
        assert_eq!(cfg.neurons_per_rank, 1);
        assert_eq!(cfg.neuron.eps_target_ca, 0.7);
        assert_eq!(cfg.neuron.nu_growth, 0.001);
        assert_eq!(cfg.bg_mean, 5.0);
        assert_eq!(cfg.bg_std, 1.0);
    }

    #[test]
    fn ini_roundtrip() {
        let text = "
[topology]
ranks = 4
neurons_per_rank = 128
# a comment
[algorithms]
connectivity = old
spikes = frequency
theta = 0.2
[model]
target_calcium = 0.6
";
        let cfg = SimConfig::from_ini(text).unwrap();
        assert_eq!(cfg.ranks, 4);
        assert_eq!(cfg.neurons_per_rank, 128);
        assert_eq!(cfg.connectivity_alg, ConnectivityAlg::OldRma);
        assert_eq!(cfg.spike_alg, SpikeAlg::NewFrequency);
        assert_eq!(cfg.theta, 0.2);
        assert_eq!(cfg.neuron.eps_target_ca, 0.6);
    }

    #[test]
    fn to_ini_roundtrips_exactly() {
        let mut cfg = SimConfig {
            ranks: 8,
            neurons_per_rank: 96,
            domain_size: 1234.5,
            seed: 991,
            steps: 777,
            plasticity_interval: 50,
            delta: 25,
            connectivity_alg: ConnectivityAlg::OldRma,
            spike_alg: SpikeAlg::OldIds,
            theta: 0.2,
            sigma: 333.25,
            frac_excitatory: 0.75,
            bg_mean: 4.5,
            bg_std: 1.25,
            record_calcium_every: 10,
            checkpoint_every: 100,
            checkpoint_dir: "ckpts".to_string(),
            trace_every: 50,
            trace_capacity: 128,
            trace_out: "trace.json".to_string(),
            balance_every: 50,
            balance_threshold: 1.375,
            balance_max_moves: 2,
            ..SimConfig::default()
        };
        cfg.neuron.eps_target_ca = 0.65;
        cfg.neuron.nu_growth = 0.002;
        let back = SimConfig::from_ini(&cfg.to_ini()).unwrap();
        assert_eq!(back, cfg, "every INI-expressible field must survive the round-trip");
    }

    #[test]
    fn comm_backend_roundtrips_and_default_ini_is_unchanged() {
        // The default (thread) emits NO comm key: a pre-existing
        // snapshot's embedded INI and fingerprint are untouched by the
        // key's existence.
        let thread = SimConfig::default();
        assert!(!thread.to_ini().contains("comm"), "thread configs must not emit the key");
        assert_eq!(SimConfig::from_ini(&thread.to_ini()).unwrap().comm_backend, CommBackend::Thread);

        let socket = SimConfig { comm_backend: CommBackend::Socket, ..SimConfig::default() };
        let ini = socket.to_ini();
        assert!(ini.contains("comm = socket"), "{ini}");
        let back = SimConfig::from_ini(&ini).unwrap();
        assert_eq!(back, socket);

        let mut cfg = SimConfig::default();
        cfg.apply_kv("topology.comm", "socket").unwrap();
        assert_eq!(cfg.comm_backend, CommBackend::Socket);
        assert!(cfg.apply_kv("topology.comm", "carrier-pigeon").is_err());
    }

    #[test]
    fn kernel_kind_roundtrips_and_default_ini_is_unchanged() {
        // Scalar (the default) emits NO [compute] section: pre-existing
        // snapshots' embedded INIs are byte-stable under the new key.
        let scalar = SimConfig::default();
        assert!(!scalar.to_ini().contains("kernel"), "scalar configs must not emit the key");
        assert_eq!(SimConfig::from_ini(&scalar.to_ini()).unwrap().kernel, KernelKind::Scalar);

        for kind in [KernelKind::Blocked, KernelKind::Xla] {
            let cfg = SimConfig { kernel: kind, ..SimConfig::default() };
            let ini = cfg.to_ini();
            assert!(ini.contains(&format!("kernel = {}", kind.name())), "{ini}");
            let back = SimConfig::from_ini(&ini).unwrap();
            assert_eq!(back, cfg);
            assert_eq!(KernelKind::from_name(kind.name()), Some(kind));
        }

        let mut cfg = SimConfig::default();
        cfg.apply_kv("compute.kernel", "blocked").unwrap();
        assert_eq!(cfg.kernel, KernelKind::Blocked);
        assert!(cfg.apply_kv("compute.kernel", "abacus").is_err());
    }

    #[test]
    fn xla_kernel_rejects_poisson_and_socket() {
        // The AOT artifact implements the Izhikevich kernel only.
        let mut cfg = SimConfig {
            kernel: KernelKind::Xla,
            neuron_model: NeuronModel::Poisson,
            ..SimConfig::default()
        };
        let err = cfg.validate().unwrap_err();
        assert!(err.contains("poisson"), "{err}");
        cfg.neuron_model = NeuronModel::Izhikevich;
        cfg.validate().unwrap();
        // Socket rank processes cannot share the in-process executor.
        cfg.comm_backend = CommBackend::Socket;
        let err = cfg.validate().unwrap_err();
        assert!(err.contains("socket") && err.contains("kernel"), "{err}");
        cfg.kernel = KernelKind::Blocked;
        cfg.validate().unwrap();
    }

    #[test]
    fn socket_backend_allows_checkpointing_but_rejects_xla() {
        // PR 9 lifted the socket+checkpoint restriction (rank processes
        // assemble snapshots through part files); xla stays rejected.
        let mut cfg = SimConfig {
            comm_backend: CommBackend::Socket,
            checkpoint_every: 50,
            checkpoint_dir: "ckpts".to_string(),
            ..SimConfig::default()
        };
        cfg.validate().unwrap();
        cfg.backend = Backend::Xla;
        let err = cfg.validate().unwrap_err();
        assert!(err.contains("socket"), "{err}");
    }

    #[test]
    fn fault_and_recovery_knobs_validate() {
        // A malformed plan fails loudly at validation.
        let mut cfg = SimConfig { fault_plan: "explode:rank=0".to_string(), ..SimConfig::default() };
        let err = cfg.validate().unwrap_err();
        assert!(err.contains("faults.plan"), "{err}");
        // Faults arm inside rank processes, so a plan needs the socket
        // backend — checkpoint faults included.
        cfg.fault_plan = "kill:rank=1,step=10".to_string();
        let err = cfg.validate().unwrap_err();
        assert!(err.contains("socket"), "{err}");
        cfg.comm_backend = CommBackend::Socket;
        cfg.validate().unwrap();
        let thread = SimConfig { fault_plan: "ckpt_fail:step=10".to_string(), ..SimConfig::default() };
        assert!(thread.validate().unwrap_err().contains("socket"));
        // The plan is intentionally NOT serialized: faulted and clean
        // runs embed byte-identical configs in their snapshots.
        let ini = cfg.to_ini();
        assert!(!ini.contains("[faults]") && !ini.contains("plan ="), "{ini}");
        let mut clean = cfg.clone();
        clean.fault_plan.clear();
        assert_eq!(ini, clean.to_ini(), "fault plans must not change INI bytes");
        // Supervision needs the socket backend and a checkpoint cadence.
        let mut sup = SimConfig { max_recoveries: 2, ..SimConfig::default() };
        assert!(sup.validate().unwrap_err().contains("socket"));
        sup.comm_backend = CommBackend::Socket;
        assert!(sup.validate().unwrap_err().contains("checkpoint_every"));
        sup.checkpoint_every = 50;
        sup.checkpoint_dir = "ckpts".to_string();
        sup.validate().unwrap();
        // And the supervision/retention knobs round-trip through INI.
        sup.checkpoint_keep = 3;
        let back = SimConfig::from_ini(&sup.to_ini()).unwrap();
        assert_eq!(back, sup);
        // checkpoint_keep without checkpointing is meaningless.
        let keep = SimConfig { checkpoint_keep: 2, ..SimConfig::default() };
        assert!(keep.validate().unwrap_err().contains("checkpoint_keep"));
    }

    #[test]
    fn prop_parse_to_ini_is_identity() {
        // The snapshot self-description contract for every key PRs 1-5
        // added (checkpointing, balance) and everything before them:
        // parse(to_ini(cfg)) == cfg over randomized INI-expressible
        // configs. A key serialized but not parsed (or vice versa)
        // would silently desynchronize resumed runs from their
        // snapshots — exactly what this property pins down.
        use crate::testing::forall;
        forall(
            "parse(to_ini(cfg)) == cfg",
            60,
            |rng| {
                let mut cfg = SimConfig {
                    ranks: 1 + rng.next_below(8),
                    neurons_per_rank: 1 + rng.next_below(512),
                    domain_size: 100.0 + rng.next_f64() * 900.0,
                    seed: rng.next_u64(),
                    steps: 1 + rng.next_below(5000),
                    plasticity_interval: 1 + rng.next_below(200),
                    delta: 1 + rng.next_below(200),
                    connectivity_alg: match rng.next_below(3) {
                        0 => ConnectivityAlg::OldRma,
                        1 => ConnectivityAlg::NewLocationAware,
                        _ => ConnectivityAlg::Direct,
                    },
                    spike_alg: if rng.bernoulli(0.5) {
                        SpikeAlg::OldIds
                    } else {
                        SpikeAlg::NewFrequency
                    },
                    neuron_model: if rng.bernoulli(0.5) {
                        NeuronModel::Izhikevich
                    } else {
                        NeuronModel::Poisson
                    },
                    theta: rng.next_f64() * 0.999,
                    sigma: 1.0 + rng.next_f64() * 1000.0,
                    frac_excitatory: rng.next_f64(),
                    init_elements_lo: 1.0 + rng.next_f64(),
                    bg_mean: rng.next_f64() * 10.0,
                    bg_std: 0.5 + rng.next_f64(),
                    record_calcium_every: rng.next_below(100),
                    ..SimConfig::default()
                };
                cfg.init_elements_hi = cfg.init_elements_lo + rng.next_f64();
                if rng.bernoulli(0.5) {
                    cfg.checkpoint_every = 1 + rng.next_below(1000);
                    cfg.checkpoint_dir = format!("ckpt_{}", rng.next_below(100));
                    if rng.bernoulli(0.5) {
                        cfg.checkpoint_keep = 1 + rng.next_below(8);
                    }
                }
                if rng.bernoulli(0.5) {
                    cfg.comm_backend = CommBackend::Socket;
                    // Supervision requires socket + checkpointing.
                    if cfg.checkpoint_every > 0 && rng.bernoulli(0.5) {
                        cfg.max_recoveries = 1 + rng.next_below(4);
                    }
                }
                // The xla kernel excludes Poisson and socket (validate
                // rejects both pairs); blocked is unconstrained.
                cfg.kernel = match rng.next_below(3) {
                    0 => KernelKind::Scalar,
                    1 => KernelKind::Blocked,
                    _ if cfg.neuron_model == NeuronModel::Izhikevich
                        && cfg.comm_backend == CommBackend::Thread =>
                    {
                        KernelKind::Xla
                    }
                    _ => KernelKind::Blocked,
                };
                if rng.bernoulli(0.5) {
                    cfg.trace_every = 1 + rng.next_below(500);
                    cfg.trace_capacity = 1 + rng.next_below(10_000);
                    if rng.bernoulli(0.5) {
                        cfg.trace_out = format!("trace_{}.json", rng.next_below(100));
                    }
                }
                if rng.bernoulli(0.5) {
                    // Valid balancing knobs: every = multiple of both
                    // the plasticity interval and (for the frequency
                    // algorithm) the spike epoch, threshold > 1.
                    cfg.delta = cfg.plasticity_interval;
                    cfg.balance_every =
                        cfg.plasticity_interval * (1 + rng.next_below(4));
                    cfg.balance_threshold = 1.0 + 0.001 + rng.next_f64();
                    cfg.balance_max_moves = 1 + rng.next_below(4);
                }
                // Neuron parameters with INI keys are f32: Display
                // round-trips them exactly too.
                cfg.neuron.eps_target_ca = rng.next_f32();
                cfg.neuron.nu_growth = rng.next_f32() * 0.01;
                cfg.neuron.tau_ca = 1.0 + rng.next_f32() * 100.0;
                cfg.neuron.beta_ca = rng.next_f32();
                cfg
            },
            |cfg| {
                cfg.validate().map_err(|e| format!("generated config invalid: {e}"))?;
                let back = SimConfig::from_ini(&cfg.to_ini())
                    .map_err(|e| format!("re-parse failed: {e}"))?;
                if &back != cfg {
                    return Err(format!("round-trip changed the config:\n{back:#?}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn skewed_init_cells_roundtrip_and_validate() {
        let mut cfg = SimConfig {
            ranks: 2,
            neurons_per_rank: 32,
            plasticity_interval: 50,
            delta: 50,
            balance_every: 50,
            balance_threshold: 1.1,
            balance_init_cells: "6,2".to_string(),
            ..SimConfig::default()
        };
        cfg.validate().unwrap();
        let back = SimConfig::from_ini(&cfg.to_ini()).unwrap();
        assert_eq!(back, cfg);
        // Malformed splits are rejected by validate.
        cfg.balance_init_cells = "5,2".to_string();
        assert!(cfg.validate().unwrap_err().contains("Morton"), "cell sum must match");
        cfg.balance_init_cells = "6,2".to_string();
        cfg.balance_every = 30; // not a multiple of 50
        assert!(cfg.validate().unwrap_err().contains("multiple"));
        cfg.balance_every = 50;
        cfg.balance_threshold = 1.0;
        assert!(cfg.validate().unwrap_err().contains("threshold"));
        // Under the frequency algorithm, balance epochs must land on
        // spike-epoch boundaries too — a migration straddling an epoch
        // would leave formerly-local pairs reconstructing against 0.0.
        cfg.balance_threshold = 1.1;
        cfg.delta = 30;
        let err = cfg.validate().unwrap_err();
        assert!(err.contains("schedule.delta"), "{err}");
        // The old (per-step id) algorithm has no spike epochs: allowed.
        cfg.spike_alg = SpikeAlg::OldIds;
        cfg.validate().unwrap();
    }

    #[test]
    fn telemetry_knobs_parse_gate_and_stay_out_of_ini() {
        // [telemetry] keys parse from INI text onto the config...
        let base = SimConfig { comm_backend: CommBackend::Socket, ..SimConfig::default() };
        let text = format!(
            "{}[telemetry]\nevery = 5\nwatchdog_misses = 3\nstatus_dir = status\n",
            base.to_ini()
        );
        let cfg = SimConfig::from_ini(&text).unwrap();
        assert_eq!(cfg.telemetry_every, 5);
        assert_eq!(cfg.telemetry_watchdog_misses, 3);
        assert_eq!(cfg.status_dir, "status");
        cfg.validate().unwrap();
        // ...but heartbeats ride the control socket, so the thread
        // backend rejects them.
        let mut thread = cfg.clone();
        thread.comm_backend = CommBackend::Thread;
        let err = thread.validate().unwrap_err();
        assert!(err.contains("socket"), "{err}");
        // Watchdog and status aggregation are meaningless without beats.
        let wd = SimConfig {
            comm_backend: CommBackend::Socket,
            telemetry_watchdog_misses: 2,
            ..SimConfig::default()
        };
        assert!(wd.validate().unwrap_err().contains("watchdog_misses"));
        let st = SimConfig {
            comm_backend: CommBackend::Socket,
            status_dir: "st".to_string(),
            ..SimConfig::default()
        };
        assert!(st.validate().unwrap_err().contains("status_dir"));
        // Like faults.plan, the telemetry keys are deliberately NOT
        // serialized: telemetry on and off must embed byte-identical
        // configs in their snapshots.
        let ini = cfg.to_ini();
        assert!(!ini.contains("[telemetry]") && !ini.contains("status_dir"), "{ini}");
        assert_eq!(ini, base.to_ini(), "telemetry knobs must not change INI bytes");
    }

    #[test]
    fn checkpoint_every_without_dir_rejected() {
        let mut cfg = SimConfig { checkpoint_every: 100, ..SimConfig::default() };
        let err = cfg.validate().unwrap_err();
        assert!(err.contains("checkpoint_dir"), "{err}");
        cfg.checkpoint_dir = "somewhere".to_string();
        cfg.validate().unwrap();
    }

    #[test]
    fn trace_knob_invariants() {
        // trace_out without a cadence is rejected (config-file path;
        // the CLI fills the default in before validating).
        let mut cfg = SimConfig { trace_out: "trace.json".to_string(), ..SimConfig::default() };
        let err = cfg.validate().unwrap_err();
        assert!(err.contains("trace_every"), "{err}");
        cfg.trace_every = 100;
        cfg.validate().unwrap();
        // A zero-sample ring makes no sense while tracing.
        cfg.trace_capacity = 0;
        assert!(cfg.validate().unwrap_err().contains("trace_capacity"));
        cfg.trace_capacity = 16;
        // INI-unrepresentable paths are rejected like the other dirs.
        cfg.trace_out = "trace#1.json".to_string();
        assert!(cfg.validate().unwrap_err().contains("trace_out"));
        cfg.trace_out = "trace.json".to_string();
        // And the knobs survive the snapshot round-trip.
        let back = SimConfig::from_ini(&cfg.to_ini()).unwrap();
        assert_eq!(back, cfg);
    }

    #[test]
    fn unknown_key_rejected() {
        assert!(SimConfig::from_ini("[topology]\nbogus = 1").is_err());
        let mut cfg = SimConfig::default();
        assert!(cfg.apply_kv("no.such.key", "1").is_err());
    }

    #[test]
    fn invalid_values_rejected() {
        let mut cfg = SimConfig::default();
        assert!(cfg.apply_kv("topology.ranks", "not-a-number").is_err());
        cfg.theta = 1.5;
        assert!(cfg.validate().is_err());
        cfg.theta = 0.3;
        cfg.ranks = 0;
        assert!(cfg.validate().is_err());
    }
}
