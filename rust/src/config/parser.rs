//! Tiny INI-subset parser: `[section]` headers, `key = value` pairs,
//! `#`/`;` comments, blank lines. Returns flattened `section.key` pairs
//! in file order.

use std::fmt;

#[derive(Debug, PartialEq, Eq)]
pub struct ParseError {
    pub line: usize,
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config parse error at line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for ParseError {}

/// Parse INI text into ordered `(section.key, value)` pairs.
pub fn parse_ini(text: &str) -> Result<Vec<(String, String)>, ParseError> {
    let mut section = String::new();
    let mut out = Vec::new();
    for (idx, raw) in text.lines().enumerate() {
        let line_no = idx + 1;
        // Strip comments (not inside values — values with '#' need quoting
        // we don't support; fine for this config surface).
        let line = match raw.find(['#', ';']) {
            Some(pos) => &raw[..pos],
            None => raw,
        };
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest.strip_suffix(']').ok_or_else(|| ParseError {
                line: line_no,
                message: "unterminated section header".into(),
            })?;
            let name = name.trim();
            if name.is_empty() {
                return Err(ParseError { line: line_no, message: "empty section name".into() });
            }
            section = name.to_string();
            continue;
        }
        let eq = line.find('=').ok_or_else(|| ParseError {
            line: line_no,
            message: format!("expected `key = value`, got {line:?}"),
        })?;
        let key = line[..eq].trim();
        let value = line[eq + 1..].trim();
        if key.is_empty() {
            return Err(ParseError { line: line_no, message: "empty key".into() });
        }
        let full_key =
            if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
        out.push((full_key, value.to_string()));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_pairs() {
        let got = parse_ini("[a]\nx = 1\ny=2\n[b]\nz = hello world\n").unwrap();
        assert_eq!(
            got,
            vec![
                ("a.x".to_string(), "1".to_string()),
                ("a.y".to_string(), "2".to_string()),
                ("b.z".to_string(), "hello world".to_string()),
            ]
        );
    }

    #[test]
    fn comments_and_blank_lines() {
        let got = parse_ini("# header\n\n[s]\nk = v  # trailing\n; full line\n").unwrap();
        assert_eq!(got, vec![("s.k".to_string(), "v".to_string())]);
    }

    #[test]
    fn sectionless_keys() {
        let got = parse_ini("k = v\n").unwrap();
        assert_eq!(got, vec![("k".to_string(), "v".to_string())]);
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = parse_ini("[ok]\nbroken line\n").unwrap_err();
        assert_eq!(err.line, 2);
        let err = parse_ini("[unterminated\n").unwrap_err();
        assert_eq!(err.line, 1);
        let err = parse_ini("= nokey\n").unwrap_err();
        assert_eq!(err.line, 1);
    }
}
