//! Neuron model: parameters, per-rank population (SoA), and the native
//! state-update implementation mirroring the L1 Pallas kernel.

pub mod izhikevich;
pub mod kernel;
pub mod params;
pub mod poisson;
pub mod population;

pub use kernel::{blocks_per_step, make_kernel, NeuronKernel, BLOCK_WIDTH};
pub use params::NeuronParams;
pub use population::{GlobalNeuronId, Population};
