//! Neuron model: parameters, per-rank population (SoA), and the native
//! state-update implementation mirroring the L1 Pallas kernel.

pub mod izhikevich;
pub mod params;
pub mod poisson;
pub mod population;

pub use params::NeuronParams;
pub use population::{GlobalNeuronId, Population};
