//! Neuron/plasticity model parameters.
//!
//! The same parameter vector crosses all three layers: Rust packs it as a
//! `(16,)` f32 array that the AOT-lowered L2/L1 artifact consumes; the
//! index constants MUST stay in sync with `python/compile/kernels/ref.py`
//! (`P_*` there). `integration_runtime.rs` cross-checks the two layers.

/// Index constants into the packed parameter vector (= ref.py `P_*`).
pub const PARAM_A: usize = 0;
pub const PARAM_B: usize = 1;
pub const PARAM_C: usize = 2;
pub const PARAM_D: usize = 3;
pub const PARAM_DT: usize = 4;
pub const PARAM_TAU_CA: usize = 5;
pub const PARAM_BETA_CA: usize = 6;
pub const PARAM_NU: usize = 7;
pub const PARAM_EPS: usize = 8;
pub const PARAM_ETA_AX: usize = 9;
pub const PARAM_ETA_DEN: usize = 10;
pub const PARAM_VSPIKE: usize = 11;
pub const PARAM_ISCALE: usize = 12;
pub const NUM_PARAMS: usize = 16;

/// sqrt(ln 2) — growth-curve shape constant (see `growth_curve`).
pub const SQRT_LN2: f32 = 0.832_554_6;

/// All per-neuron model constants (Izhikevich + calcium + MSP growth).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NeuronParams {
    /// Izhikevich recovery time scale.
    pub a: f32,
    /// Izhikevich recovery sensitivity.
    pub b: f32,
    /// Izhikevich reset potential (mV).
    pub c: f32,
    /// Izhikevich reset recovery increment.
    pub d: f32,
    /// Integration step (ms); 1 step = 1 ms biological time (paper §V-A).
    pub dt: f32,
    /// Calcium decay constant (steps).
    pub tau_ca: f32,
    /// Calcium increment per spike.
    pub beta_ca: f32,
    /// Synaptic-element growth rate ν (paper §V-D: 0.001).
    pub nu_growth: f32,
    /// Target calcium ε (paper §V-D: 0.7).
    pub eps_target_ca: f32,
    /// Minimal calcium for axonal element growth η_ax.
    pub eta_ax: f32,
    /// Minimal calcium for dendritic element growth η_den.
    pub eta_den: f32,
    /// Spike threshold (mV).
    pub v_spike: f32,
    /// Scaling of summed synaptic input into Izhikevich current.
    pub i_scale: f32,
}

impl Default for NeuronParams {
    fn default() -> Self {
        Self {
            a: 0.02,
            b: 0.2,
            c: -65.0,
            d: 8.0,
            dt: 1.0,
            // Calcium scale: fixed point is beta*tau*rate (rate in
            // spikes/step). The paper gives the target (0.7) but not
            // beta/tau; we pick beta*tau = 40 so the ~10 Hz response to
            // the paper's N(5,1) background alone settles near 0.4 —
            // reproducing the Fig. 8 bootstrap ("background activity
            // raises neurons to approximately 0.4 calcium") — and the
            // 0.7 target corresponds to ~17.5 Hz.
            tau_ca: 1000.0,
            beta_ca: 0.04,
            nu_growth: 0.001,
            eps_target_ca: 0.7,
            eta_ax: 0.1,
            eta_den: 0.0,
            v_spike: 30.0,
            i_scale: 5.0,
        }
    }
}

impl NeuronParams {
    /// Pack into the (16,) f32 vector the AOT artifact expects.
    pub fn to_vec(&self) -> [f32; NUM_PARAMS] {
        let mut p = [0.0f32; NUM_PARAMS];
        p[PARAM_A] = self.a;
        p[PARAM_B] = self.b;
        p[PARAM_C] = self.c;
        p[PARAM_D] = self.d;
        p[PARAM_DT] = self.dt;
        p[PARAM_TAU_CA] = self.tau_ca;
        p[PARAM_BETA_CA] = self.beta_ca;
        p[PARAM_NU] = self.nu_growth;
        p[PARAM_EPS] = self.eps_target_ca;
        p[PARAM_ETA_AX] = self.eta_ax;
        p[PARAM_ETA_DEN] = self.eta_den;
        p[PARAM_VSPIKE] = self.v_spike;
        p[PARAM_ISCALE] = self.i_scale;
        p
    }

    /// Inverse of [`to_vec`](Self::to_vec): unpack the (16,) wire/AOT
    /// vector back into the struct. `from_vec(p.to_vec()) == p` for all
    /// parameters (spare slots carry no information).
    pub fn from_vec(p: &[f32; NUM_PARAMS]) -> NeuronParams {
        NeuronParams {
            a: p[PARAM_A],
            b: p[PARAM_B],
            c: p[PARAM_C],
            d: p[PARAM_D],
            dt: p[PARAM_DT],
            tau_ca: p[PARAM_TAU_CA],
            beta_ca: p[PARAM_BETA_CA],
            nu_growth: p[PARAM_NU],
            eps_target_ca: p[PARAM_EPS],
            eta_ax: p[PARAM_ETA_AX],
            eta_den: p[PARAM_ETA_DEN],
            v_spike: p[PARAM_VSPIKE],
            i_scale: p[PARAM_ISCALE],
        }
    }
}

/// Butz & van Ooyen (2013) Gaussian growth curve, mirroring
/// `ref.growth_curve` op-for-op in f32: zero at `eta` and `eps`, positive
/// between (growth), negative outside (retraction — homeostasis).
#[inline]
pub fn growth_curve(ca: f32, nu: f32, eta: f32, eps: f32) -> f32 {
    let xi = (eta + eps) / 2.0;
    let zeta = (eps - eta) / (2.0 * SQRT_LN2);
    let g = (ca - xi) / zeta;
    nu * (2.0 * (-(g * g)).exp() - 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_layout_matches_ref_py() {
        let p = NeuronParams::default().to_vec();
        assert_eq!(p[PARAM_A], 0.02);
        assert_eq!(p[PARAM_C], -65.0);
        assert_eq!(p[PARAM_EPS], 0.7);
        assert_eq!(p[PARAM_VSPIKE], 30.0);
        assert_eq!(p[13], 0.0); // spare slots stay zero
        assert_eq!(p.len(), NUM_PARAMS);
    }

    #[test]
    fn pack_unpack_is_identity() {
        let p = NeuronParams { a: 0.03, tau_ca: 512.0, ..NeuronParams::default() };
        assert_eq!(NeuronParams::from_vec(&p.to_vec()), p);
    }

    #[test]
    fn growth_curve_zeros() {
        assert!(growth_curve(0.1, 0.001, 0.1, 0.7).abs() < 1e-8);
        assert!(growth_curve(0.7, 0.001, 0.1, 0.7).abs() < 1e-8);
    }

    #[test]
    fn growth_curve_signs() {
        assert!(growth_curve(0.4, 0.001, 0.1, 0.7) > 0.0);
        assert!(growth_curve(0.0, 0.001, 0.1, 0.7) < 0.0);
        assert!(growth_curve(1.0, 0.001, 0.1, 0.7) < 0.0);
    }

    #[test]
    fn growth_curve_peak_at_midpoint() {
        let mid = growth_curve(0.4, 0.001, 0.1, 0.7);
        assert!(mid > growth_curve(0.39, 0.001, 0.1, 0.7));
        assert!(mid > growth_curve(0.41, 0.001, 0.1, 0.7));
        assert!((mid - 0.001).abs() < 1e-9); // peak value = nu
    }
}
