//! Kernel execution layer: pluggable backends for the fused activity
//! update (Izhikevich/Poisson + calcium + element growth).
//!
//! The kernel boundary is noise-in / `{v,u,ca,z_*,fired,epoch_spikes}`-
//! out on the population's SoA arrays. Three backends implement it:
//!
//! * [`ScalarKernel`] — the straight-line loops in `izhikevich.rs` /
//!   `poisson.rs`, retained untouched as the reference oracle.
//! * [`BlockedKernel`] — walks the population in [`BLOCK_WIDTH`]-wide
//!   chunks with branchless spike/reset selects. The update is
//!   elementwise, so lane order (and with it every result bit) matches
//!   the scalar loop; the blocked form exists so the compiler can keep
//!   a block's eight SoA stripes resident in L1 and autovectorize.
//! * [`XlaKernel`] — the AOT/PJRT path moved behind the trait. It owns
//!   persistent staging buffers (`NeuronInputs` + `NeuronOutputs`) and
//!   a reply channel, ping-ponging the boxed buffers through the
//!   service thread — no per-step heap allocation: the buffers are
//!   created once and refilled in place every step.
//!
//! Backend choice is pure execution strategy — every kernel produces
//! bit-identical trajectories (pinned by `tests/integration_kernels.rs`
//! and the unit tests below), so `compute.kernel` never enters the
//! snapshot config fingerprint.

use std::sync::mpsc;

use anyhow::{anyhow, bail, Result};

use super::izhikevich;
use super::params::NeuronParams;
use super::poisson::{self, PoissonParams};
use super::population::Population;
use crate::config::{Backend, KernelKind, NeuronModel, SimConfig};
use crate::runtime::{NeuronInputs, NeuronOutputs, StagedReply, XlaHandle};
use crate::util::Rng;

/// Neurons per cache block. Eight f32 SoA stripes × 64 lanes = 2 KiB of
/// hot state per block — comfortably inside L1 alongside the parameter
/// constants, and a multiple of every SIMD width the compiler targets.
pub const BLOCK_WIDTH: usize = 64;

/// Deterministic work metric: blocks one activity step covers for a
/// population of `n`. Counted by the driver (not the kernels), so it is
/// kernel-independent by construction — the bench harness drift-checks
/// it across reps and backends.
pub fn blocks_per_step(n: usize) -> u64 {
    n.div_ceil(BLOCK_WIDTH) as u64
}

/// One fused activity update over the whole population. Reads
/// `i_syn`/`noise`, writes `v`, `u`, `ca`, `z_*`, `fired`,
/// `epoch_spikes`. `rng` is the model RNG (consumed only by the
/// Poisson model, one draw per neuron in index order).
pub trait NeuronKernel: Send {
    /// Stable backend name (reporting/debug).
    fn name(&self) -> &'static str;
    /// Execute one step.
    fn step(&mut self, pop: &mut Population, cfg: &SimConfig, rng: &mut Rng) -> Result<()>;
}

/// Build the kernel for a config. The effective kind is `cfg.kernel`,
/// except that the pre-kernel-layer combination `backend = xla` with the
/// default `kernel = scalar` still selects the XLA path (back-compat:
/// that pair meant "run the artifact" before `compute.kernel` existed).
///
/// Two silent-downgrade hazards are resolved here rather than at call
/// sites: the Poisson model never routes to the XLA kernel (the artifact
/// implements Izhikevich only — running it would silently execute the
/// wrong dynamics), and an XLA request without a live handle falls back
/// to the scalar oracle (the historical `(Backend::Xla, None)`
/// behavior). `SimConfig::validate` rejects the Poisson and socket
/// combinations up front; this is the defense in depth behind it.
pub fn make_kernel(cfg: &SimConfig, xla: Option<&XlaHandle>) -> Box<dyn NeuronKernel> {
    let kind = match cfg.kernel {
        KernelKind::Scalar if cfg.backend == Backend::Xla => KernelKind::Xla,
        k => k,
    };
    match kind {
        KernelKind::Scalar => Box::new(ScalarKernel),
        KernelKind::Blocked => Box::new(BlockedKernel),
        KernelKind::Xla => match xla {
            Some(h) if cfg.neuron_model == NeuronModel::Izhikevich => {
                Box::new(XlaKernel::new(h.clone()))
            }
            _ => Box::new(ScalarKernel),
        },
    }
}

/// Reference backend: the scalar loops, verbatim.
pub struct ScalarKernel;

impl NeuronKernel for ScalarKernel {
    fn name(&self) -> &'static str {
        "scalar"
    }

    fn step(&mut self, pop: &mut Population, cfg: &SimConfig, rng: &mut Rng) -> Result<()> {
        match cfg.neuron_model {
            NeuronModel::Izhikevich => izhikevich::step(pop, &cfg.neuron),
            NeuronModel::Poisson => {
                poisson::step(pop, &cfg.neuron, &PoissonParams::default(), rng)
            }
        }
        Ok(())
    }
}

/// Cache-blocked backend: fixed-width chunks, branchless selects.
pub struct BlockedKernel;

/// One Izhikevich block `[lo, hi)`, mirroring `izhikevich::step`
/// op-for-op in f32 (same expressions, same order — no algebraic
/// rewrites), with the spike/reset branches written as selects and the
/// epoch counter as a branchless add. Both forms compute identical
/// values; the blocked shape is what lets the compiler vectorize.
fn izhikevich_block(pop: &mut Population, p: &NeuronParams, lo: usize, hi: usize) {
    use super::params::growth_curve;
    for i in lo..hi {
        let i_total = pop.i_syn[i] * p.i_scale + pop.noise[i];

        let v = pop.v[i];
        let u = pop.u[i];
        let v_new = v + p.dt * (0.04 * v * v + 5.0 * v + 140.0 - u + i_total);
        let u_new = u + p.dt * p.a * (p.b * v - u);

        let fired = v_new >= p.v_spike;
        pop.v[i] = if fired { p.c } else { v_new };
        pop.u[i] = if fired { u_new + p.d } else { u_new };
        pop.fired[i] = fired;
        pop.epoch_spikes[i] += fired as u32;

        let spike = if fired { 1.0f32 } else { 0.0 };
        let ca = pop.ca[i] - p.dt * pop.ca[i] / p.tau_ca + p.beta_ca * spike;
        pop.ca[i] = ca;

        let g_ax = growth_curve(ca, p.nu_growth, p.eta_ax, p.eps_target_ca);
        let g_den = growth_curve(ca, p.nu_growth, p.eta_den, p.eps_target_ca);
        pop.z_ax[i] = (pop.z_ax[i] + g_ax).max(0.0);
        pop.z_den_exc[i] = (pop.z_den_exc[i] + g_den).max(0.0);
        pop.z_den_inh[i] = (pop.z_den_inh[i] + g_den).max(0.0);
    }
}

/// One Poisson block `[lo, hi)`, mirroring `poisson::step` op-for-op —
/// including exactly one `rng.next_f32()` per neuron in index order, so
/// the model RNG stream stays aligned with the scalar loop.
fn poisson_block(
    pop: &mut Population,
    p: &NeuronParams,
    pp: &PoissonParams,
    rng: &mut Rng,
    lo: usize,
    hi: usize,
) {
    use super::params::growth_curve;
    for i in lo..hi {
        let i_total = pop.i_syn[i] * p.i_scale + pop.noise[i];
        let v = pop.v[i] + (i_total - pop.v[i]) / pp.tau_v;
        pop.v[i] = v;

        let rate = pp.rate_max / (1.0 + (-(pp.beta * (v - pp.v_half))).exp());
        let fired = rng.next_f32() < rate;
        pop.fired[i] = fired;
        pop.epoch_spikes[i] += fired as u32;

        let spike = if fired { 1.0f32 } else { 0.0 };
        let ca = pop.ca[i] - p.dt * pop.ca[i] / p.tau_ca + p.beta_ca * spike;
        pop.ca[i] = ca;

        let g_ax = growth_curve(ca, p.nu_growth, p.eta_ax, p.eps_target_ca);
        let g_den = growth_curve(ca, p.nu_growth, p.eta_den, p.eps_target_ca);
        pop.z_ax[i] = (pop.z_ax[i] + g_ax).max(0.0);
        pop.z_den_exc[i] = (pop.z_den_exc[i] + g_den).max(0.0);
        pop.z_den_inh[i] = (pop.z_den_inh[i] + g_den).max(0.0);
    }
}

impl NeuronKernel for BlockedKernel {
    fn name(&self) -> &'static str {
        "blocked"
    }

    fn step(&mut self, pop: &mut Population, cfg: &SimConfig, rng: &mut Rng) -> Result<()> {
        let n = pop.len();
        let mut lo = 0;
        while lo < n {
            let hi = (lo + BLOCK_WIDTH).min(n);
            match cfg.neuron_model {
                NeuronModel::Izhikevich => izhikevich_block(pop, &cfg.neuron, lo, hi),
                NeuronModel::Poisson => {
                    poisson_block(pop, &cfg.neuron, &PoissonParams::default(), rng, lo, hi)
                }
            }
            lo = hi;
        }
        Ok(())
    }
}

/// XLA/PJRT backend with persistent staging. The two boxed buffers are
/// allocated once at construction and ping-pong through the service
/// thread every step: stage (clear + extend in place), send both boxes,
/// receive them back with the outputs refilled, unstage
/// (`copy_from_slice` into the SoA arrays). The reply channel is also
/// created once; cloning its `Sender` per send is a refcount bump, not
/// an allocation.
pub struct XlaKernel {
    handle: XlaHandle,
    /// `Some` between steps; taken while a request is in flight.
    bufs: Option<(Box<NeuronInputs>, Box<NeuronOutputs>)>,
    reply_tx: mpsc::Sender<StagedReply>,
    reply_rx: mpsc::Receiver<StagedReply>,
}

impl XlaKernel {
    pub fn new(handle: XlaHandle) -> Self {
        let (reply_tx, reply_rx) = mpsc::channel();
        let inputs = Box::new(NeuronInputs {
            v: Vec::new(),
            u: Vec::new(),
            ca: Vec::new(),
            z_ax: Vec::new(),
            z_de: Vec::new(),
            z_di: Vec::new(),
            i_syn: Vec::new(),
            noise: Vec::new(),
            params: [0.0; crate::neuron::params::NUM_PARAMS],
        });
        let outputs = Box::new(NeuronOutputs {
            v: Vec::new(),
            u: Vec::new(),
            ca: Vec::new(),
            z_ax: Vec::new(),
            z_de: Vec::new(),
            z_di: Vec::new(),
            fired: Vec::new(),
        });
        XlaKernel { handle, bufs: Some((inputs, outputs)), reply_tx, reply_rx }
    }
}

/// Refill `dst` from `src` without releasing its capacity.
fn restage(dst: &mut Vec<f32>, src: &[f32]) {
    dst.clear();
    dst.extend_from_slice(src);
}

impl NeuronKernel for XlaKernel {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn step(&mut self, pop: &mut Population, cfg: &SimConfig, _rng: &mut Rng) -> Result<()> {
        if cfg.neuron_model != NeuronModel::Izhikevich {
            bail!("the XLA kernel implements the Izhikevich model only");
        }
        let (mut inputs, outputs) =
            self.bufs.take().ok_or_else(|| anyhow!("XLA staging buffers lost to a prior error"))?;
        restage(&mut inputs.v, &pop.v);
        restage(&mut inputs.u, &pop.u);
        restage(&mut inputs.ca, &pop.ca);
        restage(&mut inputs.z_ax, &pop.z_ax);
        restage(&mut inputs.z_de, &pop.z_den_exc);
        restage(&mut inputs.z_di, &pop.z_den_inh);
        restage(&mut inputs.i_syn, &pop.i_syn);
        restage(&mut inputs.noise, &pop.noise);
        inputs.params = cfg.neuron.to_vec();

        self.handle.neuron_update_staged(inputs, outputs, self.reply_tx.clone())?;
        let (inputs, outputs) = self
            .reply_rx
            .recv()
            .map_err(|_| anyhow!("XLA service dropped the staged reply"))??;

        pop.v.copy_from_slice(&outputs.v);
        pop.u.copy_from_slice(&outputs.u);
        pop.ca.copy_from_slice(&outputs.ca);
        pop.z_ax.copy_from_slice(&outputs.z_ax);
        pop.z_den_exc.copy_from_slice(&outputs.z_de);
        pop.z_den_inh.copy_from_slice(&outputs.z_di);
        for (i, &f) in outputs.fired.iter().enumerate() {
            let fired = f > 0.5;
            pop.fired[i] = fired;
            if fired {
                pop.epoch_spikes[i] += 1;
            }
        }
        self.bufs = Some((inputs, outputs));
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::spawn_mock_service;
    use crate::util::Vec3;

    fn make_pop(n: usize, model: NeuronModel) -> (Population, SimConfig) {
        let cfg =
            SimConfig { neurons_per_rank: n, neuron_model: model, ..SimConfig::default() };
        let mut rng = Rng::new(11);
        let pop = Population::init(&cfg, 0, Vec3::ZERO, Vec3::splat(100.0), &mut rng);
        (pop, cfg)
    }

    fn bits(xs: &[f32]) -> Vec<u32> {
        xs.iter().map(|x| x.to_bits()).collect()
    }

    fn assert_pops_bit_identical(a: &Population, b: &Population, tag: &str) {
        assert_eq!(bits(&a.v), bits(&b.v), "{tag}: v");
        assert_eq!(bits(&a.u), bits(&b.u), "{tag}: u");
        assert_eq!(bits(&a.ca), bits(&b.ca), "{tag}: ca");
        assert_eq!(bits(&a.z_ax), bits(&b.z_ax), "{tag}: z_ax");
        assert_eq!(bits(&a.z_den_exc), bits(&b.z_den_exc), "{tag}: z_den_exc");
        assert_eq!(bits(&a.z_den_inh), bits(&b.z_den_inh), "{tag}: z_den_inh");
        assert_eq!(a.fired, b.fired, "{tag}: fired");
        assert_eq!(a.epoch_spikes, b.epoch_spikes, "{tag}: epoch_spikes");
    }

    /// Drive two kernels over the same noise/input schedule and demand
    /// bit-identical state. 100 neurons exercises a partial tail block.
    fn assert_kernels_match(
        model: NeuronModel,
        mut a: Box<dyn NeuronKernel>,
        mut b: Box<dyn NeuronKernel>,
        tag: &str,
    ) {
        let (mut pa, cfg) = make_pop(100, model);
        let mut pb = pa.clone();
        let mut rng_a = Rng::new(42);
        let mut rng_b = Rng::new(42);
        for step in 0..200 {
            pa.draw_noise(&cfg, &mut rng_a);
            pb.draw_noise(&cfg, &mut rng_b);
            // A crude synaptic drive so the spike/reset selects and the
            // growth clamp all see both sides of their branch.
            for i in 0..pa.len() {
                let s = ((i + step) % 7) as f32;
                pa.i_syn[i] = s;
                pb.i_syn[i] = s;
            }
            a.step(&mut pa, &cfg, &mut rng_a).unwrap();
            b.step(&mut pb, &cfg, &mut rng_b).unwrap();
        }
        assert!(pa.epoch_spikes.iter().any(|&s| s > 0), "{tag}: nothing fired");
        assert_pops_bit_identical(&pa, &pb, tag);
        assert_eq!(rng_a.state(), rng_b.state(), "{tag}: rng streams diverged");
    }

    #[test]
    fn blocked_matches_scalar_izhikevich() {
        assert_kernels_match(
            NeuronModel::Izhikevich,
            Box::new(ScalarKernel),
            Box::new(BlockedKernel),
            "izhikevich",
        );
    }

    #[test]
    fn blocked_matches_scalar_poisson() {
        assert_kernels_match(
            NeuronModel::Poisson,
            Box::new(ScalarKernel),
            Box::new(BlockedKernel),
            "poisson",
        );
    }

    #[test]
    fn xla_staged_matches_scalar_via_mock_service() {
        let handle = spawn_mock_service();
        assert_kernels_match(
            NeuronModel::Izhikevich,
            Box::new(ScalarKernel),
            Box::new(XlaKernel::new(handle.clone())),
            "xla-mock",
        );
        handle.shutdown();
    }

    #[test]
    fn block_math() {
        assert_eq!(blocks_per_step(0), 0);
        assert_eq!(blocks_per_step(1), 1);
        assert_eq!(blocks_per_step(64), 1);
        assert_eq!(blocks_per_step(65), 2);
        assert_eq!(blocks_per_step(16), 1);
    }

    #[test]
    fn dispatch_honors_config_and_never_routes_poisson_to_xla() {
        let cfg = SimConfig::default();
        assert_eq!(make_kernel(&cfg, None).name(), "scalar");

        let blocked = SimConfig { kernel: KernelKind::Blocked, ..SimConfig::default() };
        assert_eq!(make_kernel(&blocked, None).name(), "blocked");

        let handle = spawn_mock_service();
        // Explicit kernel=xla and the pre-kernel-layer backend=xla
        // spelling both select the staged path...
        let explicit = SimConfig { kernel: KernelKind::Xla, ..SimConfig::default() };
        assert_eq!(make_kernel(&explicit, Some(&handle)).name(), "xla");
        let legacy = SimConfig { backend: Backend::Xla, ..SimConfig::default() };
        assert_eq!(make_kernel(&legacy, Some(&handle)).name(), "xla");
        // ...but never for the Poisson model (the artifact computes
        // Izhikevich dynamics — the satellite-a regression).
        let poisson = SimConfig {
            backend: Backend::Xla,
            neuron_model: NeuronModel::Poisson,
            ..SimConfig::default()
        };
        assert_eq!(make_kernel(&poisson, Some(&handle)).name(), "scalar");
        // And without a live handle the request degrades to the oracle.
        assert_eq!(make_kernel(&explicit, None).name(), "scalar");
        handle.shutdown();
    }

    #[test]
    fn xla_kernel_rejects_poisson_defensively() {
        let handle = spawn_mock_service();
        let mut k = XlaKernel::new(handle.clone());
        let (mut pop, cfg) = make_pop(8, NeuronModel::Poisson);
        let mut rng = Rng::new(1);
        let err = k.step(&mut pop, &cfg, &mut rng).unwrap_err();
        assert!(format!("{err:#}").contains("Izhikevich"), "{err:#}");
        handle.shutdown();
    }
}
