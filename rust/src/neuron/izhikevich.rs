//! Native-Rust neuron state update, op-for-op identical (in f32) to the
//! L1 Pallas kernel / `ref.py` oracle.
//!
//! Exists for three reasons: (1) a backend when artifacts are absent,
//! (2) the cross-layer correctness check (`integration_runtime.rs`
//! asserts the XLA-executed artifact matches this to f32 tolerance), and
//! (3) a fair baseline for the perf comparison in EXPERIMENTS.md §Perf.

use super::params::{growth_curve, NeuronParams};
use super::population::Population;

/// One fused step over the whole population (Izhikevich + calcium +
/// growth of the three element kinds). Reads `i_syn`/`noise`, writes
/// `v`, `u`, `ca`, `z_*`, `fired`.
pub fn step(pop: &mut Population, p: &NeuronParams) {
    let n = pop.len();
    for i in 0..n {
        let i_total = pop.i_syn[i] * p.i_scale + pop.noise[i];

        // Izhikevich (2003): v' = 0.04 v^2 + 5v + 140 - u + I.
        let v = pop.v[i];
        let u = pop.u[i];
        let v_new = v + p.dt * (0.04 * v * v + 5.0 * v + 140.0 - u + i_total);
        let u_new = u + p.dt * p.a * (p.b * v - u);

        let fired = v_new >= p.v_spike;
        pop.v[i] = if fired { p.c } else { v_new };
        pop.u[i] = if fired { u_new + p.d } else { u_new };
        pop.fired[i] = fired;
        if fired {
            pop.epoch_spikes[i] += 1;
        }

        // Calcium trace (decaying spike average).
        let spike = if fired { 1.0f32 } else { 0.0 };
        let ca = pop.ca[i] - p.dt * pop.ca[i] / p.tau_ca + p.beta_ca * spike;
        pop.ca[i] = ca;

        // Synaptic-element growth; counts never go negative. Both
        // dendrite kinds share (nu, eta_den, eps) -> one curve
        // evaluation serves both (saves an exp per neuron per step;
        // EXPERIMENTS.md §Perf, opt 5 — values identical to the L1
        // kernel, which XLA fuses the same way).
        let g_ax = growth_curve(ca, p.nu_growth, p.eta_ax, p.eps_target_ca);
        let g_den = growth_curve(ca, p.nu_growth, p.eta_den, p.eps_target_ca);
        pop.z_ax[i] = (pop.z_ax[i] + g_ax).max(0.0);
        pop.z_den_exc[i] = (pop.z_den_exc[i] + g_den).max(0.0);
        pop.z_den_inh[i] = (pop.z_den_inh[i] + g_den).max(0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::util::{Rng, Vec3};

    fn make_pop(n: usize) -> (Population, NeuronParams) {
        let cfg = SimConfig { neurons_per_rank: n, ..SimConfig::default() };
        let mut rng = Rng::new(7);
        let pop = Population::init(&cfg, 0, Vec3::ZERO, Vec3::splat(100.0), &mut rng);
        (pop, cfg.neuron)
    }

    #[test]
    fn strong_input_fires_and_resets() {
        let (mut pop, p) = make_pop(8);
        pop.noise.iter_mut().for_each(|x| *x = 1000.0);
        step(&mut pop, &p);
        assert!(pop.fired.iter().all(|&f| f));
        assert!(pop.v.iter().all(|&v| v == p.c));
        assert!(pop.epoch_spikes.iter().all(|&s| s == 1));
    }

    #[test]
    fn resting_state_is_quiet() {
        let (mut pop, p) = make_pop(8);
        // No input at all: the resting fixed point should not fire.
        step(&mut pop, &p);
        assert!(pop.fired.iter().all(|&f| !f));
    }

    #[test]
    fn calcium_tracks_firing_rate() {
        // Drive neurons hard for many steps; calcium should approach
        // beta * tau (the fixed point for firing every step). Use a
        // short tau so 2000 steps converge.
        let (mut pop, mut p) = make_pop(4);
        p.tau_ca = 100.0;
        p.beta_ca = 0.01;
        for _ in 0..2000 {
            pop.noise.iter_mut().for_each(|x| *x = 1000.0);
            step(&mut pop, &p);
        }
        let expect = p.beta_ca * p.tau_ca; // = 1.0
        for &ca in &pop.ca {
            assert!((ca - expect).abs() < 0.05, "ca {ca} vs {expect}");
        }
    }

    #[test]
    fn elements_grow_when_calcium_in_band() {
        let (mut pop, p) = make_pop(4);
        pop.ca.iter_mut().for_each(|c| *c = 0.4); // inside (eta, eps)
        let before = pop.z_den_exc.clone();
        step(&mut pop, &p);
        for i in 0..pop.len() {
            assert!(pop.z_den_exc[i] > before[i]);
        }
    }

    #[test]
    fn elements_retract_above_target() {
        let (mut pop, p) = make_pop(4);
        // Hold calcium above target: no firing input, but set ca high.
        pop.ca.iter_mut().for_each(|c| *c = 2.0);
        let before = pop.z_ax.clone();
        step(&mut pop, &p);
        for i in 0..pop.len() {
            assert!(pop.z_ax[i] < before[i]);
        }
    }

    #[test]
    fn deterministic() {
        let (mut a, p) = make_pop(32);
        let mut b = a.clone();
        for _ in 0..50 {
            step(&mut a, &p);
            step(&mut b, &p);
        }
        assert_eq!(a.v, b.v);
        assert_eq!(a.ca, b.ca);
        assert_eq!(a.z_ax, b.z_ax);
    }
}
