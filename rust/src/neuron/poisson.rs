//! Rate-based (Poisson) neuron model — the simpler alternative the MSP
//! literature also uses (Butz & van Ooyen 2013 drive their neurons with
//! rate dynamics; the paper's framework is model-agnostic: "computed
//! using models like Izhikevich").
//!
//! The membrane variable follows a leaky integrator of the total input;
//! the neuron fires with probability sigmoid(v), giving a smooth
//! rate-current curve. Calcium and synaptic-element updates are shared
//! with the Izhikevich path (the homeostatic loop does not care where
//! spikes come from — which this model demonstrates).

use super::params::{growth_curve, NeuronParams};
use super::population::Population;
use crate::util::Rng;

/// Extra constants of the rate model.
#[derive(Clone, Copy, Debug)]
pub struct PoissonParams {
    /// Membrane leak time constant (steps).
    pub tau_v: f32,
    /// Sigmoid midpoint: input level at which the rate is half-maximal.
    pub v_half: f32,
    /// Sigmoid steepness.
    pub beta: f32,
    /// Maximal firing probability per step.
    pub rate_max: f32,
}

impl Default for PoissonParams {
    fn default() -> Self {
        // Tuned so the paper's N(5,1) background alone yields ~10 Hz
        // (the same operating point as the Izhikevich defaults).
        PoissonParams { tau_v: 10.0, v_half: 7.0, beta: 1.0, rate_max: 0.1 }
    }
}

/// One fused step of the rate model (reuses `v` as the membrane trace).
pub fn step(pop: &mut Population, p: &NeuronParams, pp: &PoissonParams, rng: &mut Rng) {
    let n = pop.len();
    for i in 0..n {
        let i_total = pop.i_syn[i] * p.i_scale + pop.noise[i];
        let v = pop.v[i] + (i_total - pop.v[i]) / pp.tau_v;
        pop.v[i] = v;

        let rate = pp.rate_max / (1.0 + (-(pp.beta * (v - pp.v_half))).exp());
        let fired = rng.next_f32() < rate;
        pop.fired[i] = fired;
        if fired {
            pop.epoch_spikes[i] += 1;
        }

        let spike = if fired { 1.0f32 } else { 0.0 };
        let ca = pop.ca[i] - p.dt * pop.ca[i] / p.tau_ca + p.beta_ca * spike;
        pop.ca[i] = ca;

        let g_ax = growth_curve(ca, p.nu_growth, p.eta_ax, p.eps_target_ca);
        let g_den = growth_curve(ca, p.nu_growth, p.eta_den, p.eps_target_ca);
        pop.z_ax[i] = (pop.z_ax[i] + g_ax).max(0.0);
        pop.z_den_exc[i] = (pop.z_den_exc[i] + g_den).max(0.0);
        pop.z_den_inh[i] = (pop.z_den_inh[i] + g_den).max(0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::util::Vec3;

    fn make_pop(n: usize) -> (Population, NeuronParams) {
        let cfg = SimConfig { neurons_per_rank: n, ..SimConfig::default() };
        let mut rng = Rng::new(3);
        let mut pop = Population::init(&cfg, 0, Vec3::ZERO, Vec3::splat(10.0), &mut rng);
        pop.v.iter_mut().for_each(|v| *v = 0.0);
        (pop, cfg.neuron)
    }

    #[test]
    fn rate_increases_with_input() {
        let pp = PoissonParams::default();
        let (mut pop, p) = make_pop(500);
        let mut rng = Rng::new(1);
        let count_spikes = |pop: &mut Population, rng: &mut Rng, drive: f32| {
            let mut spikes = 0usize;
            for _ in 0..400 {
                pop.noise.iter_mut().for_each(|x| *x = drive);
                step(pop, &p, &pp, rng);
                spikes += pop.fired.iter().filter(|&&f| f).count();
            }
            spikes
        };
        let low = count_spikes(&mut pop, &mut rng, 2.0);
        let high = count_spikes(&mut pop, &mut rng, 12.0);
        assert!(high > 2 * low, "rate must grow with drive: {low} vs {high}");
    }

    #[test]
    fn rate_bounded_by_rate_max() {
        let pp = PoissonParams::default();
        let (mut pop, p) = make_pop(2000);
        let mut rng = Rng::new(2);
        pop.noise.iter_mut().for_each(|x| *x = 1000.0);
        // Warm the membrane up, then measure.
        for _ in 0..50 {
            step(&mut pop, &p, &pp, &mut rng);
        }
        let mut spikes = 0usize;
        for _ in 0..100 {
            pop.noise.iter_mut().for_each(|x| *x = 1000.0);
            step(&mut pop, &p, &pp, &mut rng);
            spikes += pop.fired.iter().filter(|&&f| f).count();
        }
        let rate = spikes as f64 / (2000.0 * 100.0);
        assert!(rate <= pp.rate_max as f64 * 1.05, "rate {rate}");
        assert!(rate >= pp.rate_max as f64 * 0.9, "saturated drive should be near max");
    }

    #[test]
    fn homeostatic_machinery_shared_with_izhikevich() {
        // Calcium and element updates behave identically to the
        // Izhikevich path given the same spike train.
        let pp = PoissonParams::default();
        let (mut pop, p) = make_pop(64);
        let mut rng = Rng::new(4);
        pop.ca.iter_mut().for_each(|c| *c = 0.4); // in the growth band
        let before = pop.z_den_exc.clone();
        step(&mut pop, &p, &pp, &mut rng);
        for i in 0..pop.len() {
            assert!(pop.z_den_exc[i] > before[i], "elements must grow at ca=0.4");
            assert_eq!(pop.z_den_exc[i], pop.z_den_inh[i] - (pop.z_den_inh[i] - pop.z_den_exc[i]));
        }
    }

    #[test]
    fn background_operating_point_matches_izhikevich_regime() {
        // N(5,1) background -> ~10 Hz (0.01 spikes/step), the same
        // operating point the calcium constants are tuned for.
        let pp = PoissonParams::default();
        let (mut pop, p) = make_pop(2000);
        let mut rng = Rng::new(5);
        let cfg = SimConfig { neurons_per_rank: 2000, ..SimConfig::default() };
        for _ in 0..100 {
            pop.draw_noise(&cfg, &mut rng);
            step(&mut pop, &p, &pp, &mut rng);
        }
        let mut spikes = 0usize;
        for _ in 0..500 {
            pop.draw_noise(&cfg, &mut rng);
            step(&mut pop, &p, &pp, &mut rng);
            spikes += pop.fired.iter().filter(|&&f| f).count();
        }
        let rate = spikes as f64 / (2000.0 * 500.0);
        assert!((0.002..0.05).contains(&rate), "background rate {rate}");
    }
}
