//! Per-rank neuron population, stored structure-of-arrays.
//!
//! SoA mirrors the L1 kernel's layout, so handing the state to the XLA
//! runtime is a set of slice views, no transposition.

use crate::config::SimConfig;
use crate::util::{Rng, Vec3};

/// Globally unique neuron id. With the fixed block distribution the
/// owning rank is `id / neurons_per_rank` and the local index is
/// `id % neurons_per_rank`.
pub type GlobalNeuronId = u64;

/// A rank's neurons (structure of arrays).
#[derive(Clone, Debug)]
pub struct Population {
    /// Global id of local neuron 0 (ids are contiguous per rank).
    pub first_id: GlobalNeuronId,
    pub positions: Vec<Vec3>,
    pub is_excitatory: Vec<bool>,
    // Electrical state.
    pub v: Vec<f32>,
    pub u: Vec<f32>,
    pub ca: Vec<f32>,
    // Synaptic-element counts (continuous).
    pub z_ax: Vec<f32>,
    pub z_den_exc: Vec<f32>,
    pub z_den_inh: Vec<f32>,
    // Per-step scratch.
    pub i_syn: Vec<f32>,
    pub noise: Vec<f32>,
    pub fired: Vec<bool>,
    /// Spikes fired during the current frequency epoch (for the new
    /// spike-exchange algorithm).
    pub epoch_spikes: Vec<u32>,
}

impl Population {
    /// Number of local neurons.
    pub fn len(&self) -> usize {
        self.positions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.positions.is_empty()
    }

    pub fn global_id(&self, local: usize) -> GlobalNeuronId {
        self.first_id + local as GlobalNeuronId
    }

    pub fn local_index(&self, id: GlobalNeuronId) -> usize {
        debug_assert!(id >= self.first_id && id < self.first_id + self.len() as u64);
        (id - self.first_id) as usize
    }

    /// Initialize `n` neurons for `rank`, placed uniformly inside the
    /// rank's spatial region `[lo, hi)`, with the paper's initial
    /// conditions: resting Izhikevich state, zero calcium, and every
    /// element count drawn from [init_lo, init_hi] (paper §V-B: each
    /// neuron starts with 1.1–1.5 vacant elements of each kind and no
    /// synapses).
    pub fn init(cfg: &SimConfig, rank: usize, lo: Vec3, hi: Vec3, rng: &mut Rng) -> Population {
        let n = cfg.neurons_per_rank;
        Self::init_n(cfg, n, (rank * n) as GlobalNeuronId, lo, hi, rng)
    }

    /// `init` with an explicit population size and first global id —
    /// the building block the load-balancing subsystem uses when a
    /// rank's share is NOT the uniform `neurons_per_rank` block (e.g. a
    /// deliberately skewed initial partition).
    pub fn init_n(
        cfg: &SimConfig,
        n: usize,
        first_id: GlobalNeuronId,
        lo: Vec3,
        hi: Vec3,
        rng: &mut Rng,
    ) -> Population {
        let mut positions = Vec::with_capacity(n);
        let mut is_excitatory = Vec::with_capacity(n);
        let mut z_ax = Vec::with_capacity(n);
        let mut z_den_exc = Vec::with_capacity(n);
        let mut z_den_inh = Vec::with_capacity(n);
        for _ in 0..n {
            positions.push(Vec3::new(
                rng.uniform(lo.x, hi.x),
                rng.uniform(lo.y, hi.y),
                rng.uniform(lo.z, hi.z),
            ));
            is_excitatory.push(rng.bernoulli(cfg.frac_excitatory));
            z_ax.push(rng.uniform(cfg.init_elements_lo, cfg.init_elements_hi) as f32);
            z_den_exc.push(rng.uniform(cfg.init_elements_lo, cfg.init_elements_hi) as f32);
            z_den_inh.push(rng.uniform(cfg.init_elements_lo, cfg.init_elements_hi) as f32);
        }
        let v0 = cfg.neuron.c;
        let u0 = cfg.neuron.b * v0;
        Population {
            first_id,
            positions,
            is_excitatory,
            v: vec![v0; n],
            u: vec![u0; n],
            ca: vec![0.0; n],
            z_ax,
            z_den_exc,
            z_den_inh,
            i_syn: vec![0.0; n],
            noise: vec![0.0; n],
            fired: vec![false; n],
            epoch_spikes: vec![0; n],
        }
    }

    /// Initialize neurons spread over the rank's Morton cells in
    /// contiguous id blocks: `cells[k]` is a (`[lo, hi)` box, neuron
    /// count) pair, and the k-th block of ids lands uniformly inside
    /// the k-th box. Blocked (not round-robin) placement is what the
    /// load balancer relies on: each Morton cell owns one contiguous
    /// global-id block, so migrating a boundary cell migrates a
    /// contiguous id range — and the distributed octree's assumption
    /// that every local neuron falls inside an owned subdomain keeps
    /// holding after the move.
    pub fn init_in_cells(
        cfg: &SimConfig,
        first_id: GlobalNeuronId,
        cells: &[((Vec3, Vec3), u64)],
        rng: &mut Rng,
    ) -> Population {
        assert!(!cells.is_empty());
        let n: u64 = cells.iter().map(|&(_, count)| count).sum();
        let ((lo0, hi0), _) = cells[0];
        let mut pop = Population::init_n(cfg, n as usize, first_id, lo0, hi0, rng);
        let mut i = 0usize;
        for &((lo, hi), count) in cells {
            for _ in 0..count {
                pop.positions[i] = Vec3::new(
                    rng.uniform(lo.x, hi.x),
                    rng.uniform(lo.y, hi.y),
                    rng.uniform(lo.z, hi.z),
                );
                i += 1;
            }
        }
        debug_assert_eq!(i, pop.len());
        pop
    }

    /// Draw fresh background noise ~ N(bg_mean, bg_std) for every neuron.
    pub fn draw_noise(&mut self, cfg: &SimConfig, rng: &mut Rng) {
        for x in self.noise.iter_mut() {
            *x = rng.normal_ms(cfg.bg_mean, cfg.bg_std) as f32;
        }
    }

    /// Zero the synaptic-input accumulator (start of a step).
    pub fn clear_inputs(&mut self) {
        self.i_syn.iter_mut().for_each(|x| *x = 0.0);
    }

    /// Mean calcium across local neurons (reporting).
    pub fn mean_calcium(&self) -> f64 {
        if self.is_empty() {
            return 0.0;
        }
        self.ca.iter().map(|&c| c as f64).sum::<f64>() / self.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;

    fn cfg() -> SimConfig {
        SimConfig { neurons_per_rank: 100, ..SimConfig::default() }
    }

    #[test]
    fn init_places_in_box_with_initial_elements() {
        let cfg = cfg();
        let mut rng = Rng::new(1);
        let lo = Vec3::new(10.0, 0.0, 0.0);
        let hi = Vec3::new(20.0, 5.0, 5.0);
        let pop = Population::init(&cfg, 3, lo, hi, &mut rng);
        assert_eq!(pop.len(), 100);
        assert_eq!(pop.first_id, 300);
        for p in &pop.positions {
            assert!(p.in_box(&lo, &hi));
        }
        for i in 0..pop.len() {
            assert!((1.1..=1.5).contains(&(pop.z_ax[i] as f64)));
            assert!((1.1..=1.5).contains(&(pop.z_den_exc[i] as f64)));
            assert!((1.1..=1.5).contains(&(pop.z_den_inh[i] as f64)));
        }
        assert!(pop.ca.iter().all(|&c| c == 0.0));
        assert!(pop.v.iter().all(|&v| v == cfg.neuron.c));
    }

    #[test]
    fn init_in_cells_places_contiguous_id_blocks() {
        let mut cfg = cfg();
        cfg.neurons_per_rank = 7; // irrelevant: counts come from cells
        let mut rng = Rng::new(9);
        let box_a = (Vec3::ZERO, Vec3::splat(5.0));
        let box_b = (Vec3::new(5.0, 0.0, 0.0), Vec3::new(10.0, 5.0, 5.0));
        let pop =
            Population::init_in_cells(&cfg, 40, &[(box_a, 3), (box_b, 2)], &mut rng);
        assert_eq!(pop.len(), 5);
        assert_eq!(pop.first_id, 40);
        // First block of ids in the first box, second block in the
        // second — the cell ↔ id-block invariant migration relies on.
        for i in 0..3 {
            assert!(pop.positions[i].in_box(&box_a.0, &box_a.1), "id {}", 40 + i);
        }
        for i in 3..5 {
            assert!(pop.positions[i].in_box(&box_b.0, &box_b.1), "id {}", 40 + i);
        }
    }

    #[test]
    fn id_mapping_roundtrips() {
        let cfg = cfg();
        let mut rng = Rng::new(2);
        let pop =
            Population::init(&cfg, 2, Vec3::ZERO, Vec3::splat(1.0), &mut rng);
        for local in [0usize, 5, 99] {
            assert_eq!(pop.local_index(pop.global_id(local)), local);
        }
    }

    #[test]
    fn excitatory_fraction_roughly_respected() {
        let mut cfg = cfg();
        cfg.neurons_per_rank = 10_000;
        cfg.frac_excitatory = 0.8;
        let mut rng = Rng::new(3);
        let pop =
            Population::init(&cfg, 0, Vec3::ZERO, Vec3::splat(1.0), &mut rng);
        let frac =
            pop.is_excitatory.iter().filter(|&&e| e).count() as f64 / pop.len() as f64;
        assert!((frac - 0.8).abs() < 0.02, "frac {frac}");
    }

    #[test]
    fn noise_has_requested_moments() {
        let mut cfg = cfg();
        cfg.neurons_per_rank = 50_000;
        cfg.bg_mean = 5.0;
        cfg.bg_std = 1.0;
        let mut rng = Rng::new(4);
        let mut pop =
            Population::init(&cfg, 0, Vec3::ZERO, Vec3::splat(1.0), &mut rng);
        pop.draw_noise(&cfg, &mut rng);
        let n = pop.len() as f64;
        let mean = pop.noise.iter().map(|&x| x as f64).sum::<f64>() / n;
        let var =
            pop.noise.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n;
        assert!((mean - 5.0).abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }
}
