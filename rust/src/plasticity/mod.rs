//! Structural plasticity: synapse bookkeeping and the deletion phase.
//! (Synapse *formation* lives in `barnes_hut`, which implements the
//! paper's old and new target-search algorithms.)

pub mod deletion;
pub mod synapses;

pub use deletion::{run_deletion_phase, DeleteNotify, DeletionStats};
pub use synapses::{vacant, InEdge, SynapseStore};
