//! Per-rank synapse bookkeeping.
//!
//! A synapse is an (axon of source neuron) -> (dendrite of target neuron)
//! pair. Each rank stores the axonal side of its local sources
//! (`out_edges`) and the dendritic side of its local targets
//! (`in_edges`); a synapse crossing ranks appears once on each rank.
//! Dendrites are typed by the *source* neuron (an excitatory axon binds
//! an excitatory-dendritic element), matching MSP.

use crate::neuron::GlobalNeuronId;
use crate::octree::ElementKind;
use crate::util::Rng;

/// One incoming synapse as stored on the dendritic side.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct InEdge {
    pub source: GlobalNeuronId,
    /// Source neuron's type == which dendritic element kind is bound.
    pub source_exc: bool,
}

/// Synapse store for one rank (`n` local neurons).
#[derive(Clone, Debug, Default)]
pub struct SynapseStore {
    /// Axonal side: targets of each local neuron's outgoing synapses.
    pub out_edges: Vec<Vec<GlobalNeuronId>>,
    /// Dendritic side: sources of each local neuron's incoming synapses.
    pub in_edges: Vec<Vec<InEdge>>,
    /// Bound (connected) element counts per local neuron.
    pub connected_ax: Vec<u32>,
    pub connected_den_exc: Vec<u32>,
    pub connected_den_inh: Vec<u32>,
}

impl SynapseStore {
    pub fn new(n: usize) -> Self {
        SynapseStore {
            out_edges: vec![Vec::new(); n],
            in_edges: vec![Vec::new(); n],
            connected_ax: vec![0; n],
            connected_den_exc: vec![0; n],
            connected_den_inh: vec![0; n],
        }
    }

    /// Record the axonal side of a new synapse on local `src`.
    pub fn add_out(&mut self, src_local: usize, target: GlobalNeuronId) {
        self.out_edges[src_local].push(target);
        self.connected_ax[src_local] += 1;
    }

    /// Record the dendritic side of a new synapse on local `tgt`.
    pub fn add_in(&mut self, tgt_local: usize, source: GlobalNeuronId, source_exc: bool) {
        self.in_edges[tgt_local].push(InEdge { source, source_exc });
        if source_exc {
            self.connected_den_exc[tgt_local] += 1;
        } else {
            self.connected_den_inh[tgt_local] += 1;
        }
    }

    /// Remove a uniformly-random outgoing synapse of local `src`
    /// (axonal retraction). Returns the disconnected target.
    pub fn remove_random_out(&mut self, src_local: usize, rng: &mut Rng) -> Option<GlobalNeuronId> {
        let edges = &mut self.out_edges[src_local];
        if edges.is_empty() {
            return None;
        }
        let k = rng.next_below(edges.len());
        let target = edges.swap_remove(k);
        self.connected_ax[src_local] -= 1;
        Some(target)
    }

    /// Remove a uniformly-random incoming synapse of kind `kind` on
    /// local `tgt` (dendritic retraction). Returns the source.
    pub fn remove_random_in(
        &mut self,
        tgt_local: usize,
        kind: ElementKind,
        rng: &mut Rng,
    ) -> Option<GlobalNeuronId> {
        let want_exc = kind == ElementKind::Excitatory;
        let edges = &self.in_edges[tgt_local];
        let matching: Vec<usize> = edges
            .iter()
            .enumerate()
            .filter(|(_, e)| e.source_exc == want_exc)
            .map(|(i, _)| i)
            .collect();
        if matching.is_empty() {
            return None;
        }
        let k = matching[rng.next_below(matching.len())];
        let e = self.in_edges[tgt_local].swap_remove(k);
        if want_exc {
            self.connected_den_exc[tgt_local] -= 1;
        } else {
            self.connected_den_inh[tgt_local] -= 1;
        }
        Some(e.source)
    }

    /// Remove one specific outgoing synapse (partner-initiated deletion).
    /// Returns false if it was already gone (both ends deleted in the
    /// same update — benign race the protocol tolerates).
    pub fn remove_specific_out(&mut self, src_local: usize, target: GlobalNeuronId) -> bool {
        let edges = &mut self.out_edges[src_local];
        if let Some(k) = edges.iter().position(|&t| t == target) {
            edges.swap_remove(k);
            self.connected_ax[src_local] -= 1;
            true
        } else {
            false
        }
    }

    /// Remove one specific incoming synapse (partner-initiated deletion).
    pub fn remove_specific_in(&mut self, tgt_local: usize, source: GlobalNeuronId) -> bool {
        let edges = &mut self.in_edges[tgt_local];
        if let Some(k) = edges.iter().position(|e| e.source == source) {
            let e = edges.swap_remove(k);
            if e.source_exc {
                self.connected_den_exc[tgt_local] -= 1;
            } else {
                self.connected_den_inh[tgt_local] -= 1;
            }
            true
        } else {
            false
        }
    }

    /// Bound dendritic elements of `kind` on local `tgt`.
    pub fn connected_den(&self, tgt_local: usize, kind: ElementKind) -> u32 {
        match kind {
            ElementKind::Excitatory => self.connected_den_exc[tgt_local],
            ElementKind::Inhibitory => self.connected_den_inh[tgt_local],
        }
    }

    /// Total synapses stored on the axonal side of this rank.
    pub fn total_out(&self) -> usize {
        self.out_edges.iter().map(|e| e.len()).sum()
    }

    /// Total synapses stored on the dendritic side of this rank.
    pub fn total_in(&self) -> usize {
        self.in_edges.iter().map(|e| e.len()).sum()
    }

    /// Internal-consistency check (used by property tests): counters
    /// match edge-list lengths.
    pub fn check_invariants(&self) -> Result<(), String> {
        for i in 0..self.out_edges.len() {
            if self.out_edges[i].len() != self.connected_ax[i] as usize {
                return Err(format!("neuron {i}: out edges vs connected_ax mismatch"));
            }
            let exc = self.in_edges[i].iter().filter(|e| e.source_exc).count();
            let inh = self.in_edges[i].len() - exc;
            if exc != self.connected_den_exc[i] as usize {
                return Err(format!("neuron {i}: exc in-edges mismatch"));
            }
            if inh != self.connected_den_inh[i] as usize {
                return Err(format!("neuron {i}: inh in-edges mismatch"));
            }
        }
        Ok(())
    }
}

/// Number of vacant elements given a continuous count `z` and `bound`
/// elements already in synapses: floor(z) - bound, clamped at 0.
#[inline]
pub fn vacant(z: f32, bound: u32) -> u32 {
    (z.floor() as i64 - bound as i64).max(0) as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_and_counts() {
        let mut s = SynapseStore::new(3);
        s.add_out(0, 100);
        s.add_out(0, 101);
        s.add_in(1, 50, true);
        s.add_in(1, 51, false);
        s.add_in(1, 52, true);
        assert_eq!(s.connected_ax[0], 2);
        assert_eq!(s.connected_den_exc[1], 2);
        assert_eq!(s.connected_den_inh[1], 1);
        assert_eq!(s.total_out(), 2);
        assert_eq!(s.total_in(), 3);
        s.check_invariants().unwrap();
    }

    #[test]
    fn remove_random_out_updates_counts() {
        let mut s = SynapseStore::new(1);
        let mut rng = Rng::new(1);
        s.add_out(0, 7);
        s.add_out(0, 8);
        let t = s.remove_random_out(0, &mut rng).unwrap();
        assert!(t == 7 || t == 8);
        assert_eq!(s.connected_ax[0], 1);
        assert!(s.remove_random_out(0, &mut rng).is_some());
        assert!(s.remove_random_out(0, &mut rng).is_none());
        s.check_invariants().unwrap();
    }

    #[test]
    fn remove_random_in_respects_kind() {
        let mut s = SynapseStore::new(1);
        let mut rng = Rng::new(2);
        s.add_in(0, 10, true);
        s.add_in(0, 11, false);
        let src = s.remove_random_in(0, ElementKind::Inhibitory, &mut rng).unwrap();
        assert_eq!(src, 11);
        assert_eq!(s.connected_den_inh[0], 0);
        assert_eq!(s.connected_den_exc[0], 1);
        assert!(s.remove_random_in(0, ElementKind::Inhibitory, &mut rng).is_none());
        s.check_invariants().unwrap();
    }

    #[test]
    fn remove_specific_tolerates_missing() {
        let mut s = SynapseStore::new(1);
        s.add_out(0, 5);
        assert!(s.remove_specific_out(0, 5));
        assert!(!s.remove_specific_out(0, 5));
        s.add_in(0, 6, true);
        assert!(s.remove_specific_in(0, 6));
        assert!(!s.remove_specific_in(0, 6));
        s.check_invariants().unwrap();
    }

    #[test]
    fn vacant_clamps() {
        assert_eq!(vacant(2.7, 1), 1);
        assert_eq!(vacant(2.7, 2), 0);
        assert_eq!(vacant(2.7, 5), 0);
        assert_eq!(vacant(0.9, 0), 0);
        assert_eq!(vacant(1.0, 0), 1);
    }
}
