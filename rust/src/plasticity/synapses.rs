//! Per-rank synapse bookkeeping.
//!
//! A synapse is an (axon of source neuron) -> (dendrite of target neuron)
//! pair. Each rank stores the axonal side of its local sources
//! (`out_edges`) and the dendritic side of its local targets
//! (`in_edges`); a synapse crossing ranks appears once on each rank.
//! Dendrites are typed by the *source* neuron (an excitatory axon binds
//! an excitatory-dendritic element), matching MSP.
//!
//! Beyond the raw edge lists, the store maintains two derived structures
//! *incrementally at the add/delete site* — "move the computation to
//! where the edit happens" (EXPERIMENTS.md §Perf, opt 7) — instead of
//! letting the hot paths rescan the edge lists:
//!
//! * the per-neuron **out-rank routing table** (`out_ranks`): sorted
//!   `(destination rank, out-edge count)` pairs, consulted by both spike
//!   exchange paths to route a firing neuron's record to exactly the
//!   ranks hosting an out-partner — replacing the old per-firing-neuron
//!   `dest_flags` rescan of `out_edges`;
//! * the **in-partner reference count** (`in_partner_refs`): an ordered
//!   map `source id -> in-edge count` over every in-edge of this rank,
//!   consulted by `spikes::FrequencyExchange::prune_stale` to drop an
//!   epoch-scoped frequency entry the moment the last in-edge from that
//!   source is deleted.

use std::collections::BTreeMap;

use crate::balance::OwnershipMap;
use crate::neuron::GlobalNeuronId;
use crate::octree::ElementKind;
use crate::util::Rng;

/// One incoming synapse as stored on the dendritic side.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct InEdge {
    pub source: GlobalNeuronId,
    /// Source neuron's type == which dendritic element kind is bound.
    pub source_exc: bool,
}

/// Synapse store for one rank (`n` local neurons).
#[derive(Clone, Debug)]
pub struct SynapseStore {
    /// Axonal side: targets of each local neuron's outgoing synapses.
    pub out_edges: Vec<Vec<GlobalNeuronId>>,
    /// Dendritic side: sources of each local neuron's incoming synapses.
    pub in_edges: Vec<Vec<InEdge>>,
    /// Bound (connected) element counts per local neuron.
    pub connected_ax: Vec<u32>,
    pub connected_den_exc: Vec<u32>,
    pub connected_den_inh: Vec<u32>,
    /// Who owns which global id — the routing authority every derived
    /// table is built against. The historical `id / neurons_per_rank`
    /// stride is the `OwnershipMap::Stride` fast path; after a
    /// load-balancing migration this becomes a `Ranges` table and the
    /// store is rebuilt via `from_parts` with the new map.
    owners: OwnershipMap,
    /// Per local neuron: sorted (destination rank, out-edge count).
    /// A flat sorted Vec is right here — entry count is bounded by the
    /// rank count, so insert/remove memmoves are tiny.
    out_ranks: Vec<Vec<(u32, u32)>>,
    /// Source id -> in-edge count over all in-edges of this rank. An
    /// ordered map (not a sorted Vec): a rank can hold in-edges from a
    /// partner set that scales with the network, and first/last-edge
    /// edits must stay O(log sources), not O(sources) memmoves.
    in_partner_refs: BTreeMap<GlobalNeuronId, u32>,
    /// Generation counter bumped at every in-edge edit site (add or
    /// delete). The `spikes::DeliveryPlan` records the value it was
    /// compiled at; a mismatch marks the plan dirty, which is how the
    /// driver knows a plasticity phase requires a recompile without
    /// rescanning the edge lists (EXPERIMENTS.md §Perf, opt 8).
    /// Out-edge edits do not bump it — the plan is dendritic-side only.
    in_edits: u64,
}

/// Increment `key`'s count in a sorted `(key, count)` list, inserting at
/// the sort position on first reference.
fn bump<K: Ord + Copy>(list: &mut Vec<(K, u32)>, key: K) {
    match list.binary_search_by_key(&key, |&(k, _)| k) {
        Ok(i) => list[i].1 += 1,
        Err(i) => list.insert(i, (key, 1)),
    }
}

/// Decrement `key`'s count in a sorted `(key, count)` list, removing the
/// entry when it reaches zero.
fn unbump<K: Ord + Copy + std::fmt::Debug>(list: &mut Vec<(K, u32)>, key: K) {
    let i = list
        .binary_search_by_key(&key, |&(k, _)| k)
        .unwrap_or_else(|_| panic!("derived count missing for {key:?}"));
    list[i].1 -= 1;
    if list[i].1 == 0 {
        list.remove(i);
    }
}

/// Decrement `key`'s count in an ordered map, removing the entry when
/// it reaches zero.
fn unbump_map(map: &mut BTreeMap<GlobalNeuronId, u32>, key: GlobalNeuronId) {
    let count = map
        .get_mut(&key)
        .unwrap_or_else(|| panic!("in-partner refcount missing for {key}"));
    *count -= 1;
    if *count == 0 {
        map.remove(&key);
    }
}

impl SynapseStore {
    /// An empty store for `n` local neurons on a simulation partitioned
    /// `neurons_per_rank` neurons per rank (the historical stride; the
    /// routing table derives destination ranks from it).
    pub fn new(n: usize, neurons_per_rank: u64) -> Self {
        Self::with_owners(n, OwnershipMap::stride(neurons_per_rank))
    }

    /// An empty store routing through an explicit [`OwnershipMap`].
    pub fn with_owners(n: usize, owners: OwnershipMap) -> Self {
        SynapseStore {
            out_edges: vec![Vec::new(); n],
            in_edges: vec![Vec::new(); n],
            connected_ax: vec![0; n],
            connected_den_exc: vec![0; n],
            connected_den_inh: vec![0; n],
            owners,
            out_ranks: vec![Vec::new(); n],
            in_partner_refs: BTreeMap::new(),
            in_edits: 0,
        }
    }

    /// Recompute the derived routing table and partner refcounts from
    /// scratch (shared by `from_parts` and `check_invariants`).
    fn derive_routing(
        out_edges: &[Vec<GlobalNeuronId>],
        in_edges: &[Vec<InEdge>],
        owners: &OwnershipMap,
    ) -> (Vec<Vec<(u32, u32)>>, BTreeMap<GlobalNeuronId, u32>) {
        let mut out_ranks: Vec<Vec<(u32, u32)>> = vec![Vec::new(); out_edges.len()];
        for (local, edges) in out_edges.iter().enumerate() {
            for &tgt in edges {
                bump(&mut out_ranks[local], owners.rank_of(tgt));
            }
        }
        let mut in_partner_refs = BTreeMap::new();
        for edges in in_edges {
            for e in edges {
                *in_partner_refs.entry(e.source).or_insert(0) += 1;
            }
        }
        (out_ranks, in_partner_refs)
    }

    /// Rebuild a store from captured edge lists and counters (snapshot
    /// restore): the derived routing table and partner refcounts are
    /// recomputed deterministically from the edge lists.
    pub fn from_parts(
        out_edges: Vec<Vec<GlobalNeuronId>>,
        in_edges: Vec<Vec<InEdge>>,
        connected_ax: Vec<u32>,
        connected_den_exc: Vec<u32>,
        connected_den_inh: Vec<u32>,
        owners: OwnershipMap,
    ) -> Self {
        let (out_ranks, in_partner_refs) =
            Self::derive_routing(&out_edges, &in_edges, &owners);
        SynapseStore {
            out_edges,
            in_edges,
            connected_ax,
            connected_den_exc,
            connected_den_inh,
            owners,
            out_ranks,
            in_partner_refs,
            in_edits: 0,
        }
    }

    /// The ownership map this store routes with.
    pub fn owners(&self) -> &OwnershipMap {
        &self.owners
    }

    /// Destination ranks of local `src`'s out-edges, as sorted
    /// (rank, count) pairs — the spike-exchange routing table.
    pub fn out_ranks(&self, src_local: usize) -> &[(u32, u32)] {
        &self.out_ranks[src_local]
    }

    /// Number of in-edges this rank holds from global `source` (any
    /// local target). Zero means no synapse from that source survives,
    /// so no spike-reconstruction state for it may survive either.
    pub fn in_partner_count(&self, source: GlobalNeuronId) -> u32 {
        self.in_partner_refs.get(&source).copied().unwrap_or(0)
    }

    /// Number of distinct sources with at least one in-edge here.
    pub fn in_partner_sources(&self) -> usize {
        self.in_partner_refs.len()
    }

    /// Every (source id, in-edge count) pair with at least one in-edge
    /// here, in ascending id order — the `DeliveryPlan` compiler interns
    /// its remote-source slots from this.
    pub fn in_partners(&self) -> impl Iterator<Item = (GlobalNeuronId, u32)> + '_ {
        self.in_partner_refs.iter().map(|(&id, &count)| (id, count))
    }

    /// In-edge edit generation: bumped by every in-edge add or delete.
    /// Derived consumers (the `spikes::DeliveryPlan`) compare against
    /// the value they were built at to detect staleness in O(1).
    pub fn in_edits(&self) -> u64 {
        self.in_edits
    }

    /// Record the axonal side of a new synapse on local `src`.
    pub fn add_out(&mut self, src_local: usize, target: GlobalNeuronId) {
        self.out_edges[src_local].push(target);
        self.connected_ax[src_local] += 1;
        bump(&mut self.out_ranks[src_local], self.owners.rank_of(target));
    }

    /// Record the dendritic side of a new synapse on local `tgt`.
    pub fn add_in(&mut self, tgt_local: usize, source: GlobalNeuronId, source_exc: bool) {
        self.in_edges[tgt_local].push(InEdge { source, source_exc });
        if source_exc {
            self.connected_den_exc[tgt_local] += 1;
        } else {
            self.connected_den_inh[tgt_local] += 1;
        }
        *self.in_partner_refs.entry(source).or_insert(0) += 1;
        self.in_edits += 1;
    }

    /// Remove a uniformly-random outgoing synapse of local `src`
    /// (axonal retraction). Returns the disconnected target.
    pub fn remove_random_out(&mut self, src_local: usize, rng: &mut Rng) -> Option<GlobalNeuronId> {
        let edges = &mut self.out_edges[src_local];
        if edges.is_empty() {
            return None;
        }
        let k = rng.next_below(edges.len());
        let target = edges.swap_remove(k);
        self.connected_ax[src_local] -= 1;
        unbump(&mut self.out_ranks[src_local], self.owners.rank_of(target));
        Some(target)
    }

    /// Remove a uniformly-random incoming synapse of kind `kind` on
    /// local `tgt` (dendritic retraction). Returns the source.
    pub fn remove_random_in(
        &mut self,
        tgt_local: usize,
        kind: ElementKind,
        rng: &mut Rng,
    ) -> Option<GlobalNeuronId> {
        let want_exc = kind == ElementKind::Excitatory;
        let edges = &self.in_edges[tgt_local];
        let matching: Vec<usize> = edges
            .iter()
            .enumerate()
            .filter(|(_, e)| e.source_exc == want_exc)
            .map(|(i, _)| i)
            .collect();
        if matching.is_empty() {
            return None;
        }
        let k = matching[rng.next_below(matching.len())];
        let e = self.in_edges[tgt_local].swap_remove(k);
        if want_exc {
            self.connected_den_exc[tgt_local] -= 1;
        } else {
            self.connected_den_inh[tgt_local] -= 1;
        }
        unbump_map(&mut self.in_partner_refs, e.source);
        self.in_edits += 1;
        Some(e.source)
    }

    /// Remove one specific outgoing synapse (partner-initiated deletion).
    /// Returns false if it was already gone (both ends deleted in the
    /// same update — benign race the protocol tolerates).
    pub fn remove_specific_out(&mut self, src_local: usize, target: GlobalNeuronId) -> bool {
        let edges = &mut self.out_edges[src_local];
        if let Some(k) = edges.iter().position(|&t| t == target) {
            edges.swap_remove(k);
            self.connected_ax[src_local] -= 1;
            unbump(&mut self.out_ranks[src_local], self.owners.rank_of(target));
            true
        } else {
            false
        }
    }

    /// Remove one specific incoming synapse (partner-initiated deletion).
    pub fn remove_specific_in(&mut self, tgt_local: usize, source: GlobalNeuronId) -> bool {
        let edges = &mut self.in_edges[tgt_local];
        if let Some(k) = edges.iter().position(|e| e.source == source) {
            let e = edges.swap_remove(k);
            if e.source_exc {
                self.connected_den_exc[tgt_local] -= 1;
            } else {
                self.connected_den_inh[tgt_local] -= 1;
            }
            unbump_map(&mut self.in_partner_refs, source);
            self.in_edits += 1;
            true
        } else {
            false
        }
    }

    /// Bound dendritic elements of `kind` on local `tgt`.
    pub fn connected_den(&self, tgt_local: usize, kind: ElementKind) -> u32 {
        match kind {
            ElementKind::Excitatory => self.connected_den_exc[tgt_local],
            ElementKind::Inhibitory => self.connected_den_inh[tgt_local],
        }
    }

    /// Total synapses stored on the axonal side of this rank.
    pub fn total_out(&self) -> usize {
        self.out_edges.iter().map(|e| e.len()).sum()
    }

    /// Total synapses stored on the dendritic side of this rank.
    pub fn total_in(&self) -> usize {
        self.in_edges.iter().map(|e| e.len()).sum()
    }

    /// Internal-consistency check (used by property tests): counters
    /// match edge-list lengths, and the incrementally-maintained routing
    /// table / partner refcounts equal what a from-scratch rebuild of
    /// the edge lists produces.
    pub fn check_invariants(&self) -> Result<(), String> {
        for i in 0..self.out_edges.len() {
            if self.out_edges[i].len() != self.connected_ax[i] as usize {
                return Err(format!("neuron {i}: out edges vs connected_ax mismatch"));
            }
            let exc = self.in_edges[i].iter().filter(|e| e.source_exc).count();
            let inh = self.in_edges[i].len() - exc;
            if exc != self.connected_den_exc[i] as usize {
                return Err(format!("neuron {i}: exc in-edges mismatch"));
            }
            if inh != self.connected_den_inh[i] as usize {
                return Err(format!("neuron {i}: inh in-edges mismatch"));
            }
        }
        let (out_ranks, in_partner_refs) =
            Self::derive_routing(&self.out_edges, &self.in_edges, &self.owners);
        if out_ranks != self.out_ranks {
            return Err("out-rank routing table disagrees with out_edges".to_string());
        }
        if in_partner_refs != self.in_partner_refs {
            return Err("in-partner refcounts disagree with in_edges".to_string());
        }
        Ok(())
    }
}

/// Number of vacant elements given a continuous count `z` and `bound`
/// elements already in synapses: floor(z) - bound, clamped at 0.
#[inline]
pub fn vacant(z: f32, bound: u32) -> u32 {
    (z.floor() as i64 - bound as i64).max(0) as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_and_counts() {
        let mut s = SynapseStore::new(3, 3);
        s.add_out(0, 100);
        s.add_out(0, 101);
        s.add_in(1, 50, true);
        s.add_in(1, 51, false);
        s.add_in(1, 52, true);
        assert_eq!(s.connected_ax[0], 2);
        assert_eq!(s.connected_den_exc[1], 2);
        assert_eq!(s.connected_den_inh[1], 1);
        assert_eq!(s.total_out(), 2);
        assert_eq!(s.total_in(), 3);
        s.check_invariants().unwrap();
    }

    #[test]
    fn remove_random_out_updates_counts() {
        let mut s = SynapseStore::new(1, 4);
        let mut rng = Rng::new(1);
        s.add_out(0, 7);
        s.add_out(0, 8);
        let t = s.remove_random_out(0, &mut rng).unwrap();
        assert!(t == 7 || t == 8);
        assert_eq!(s.connected_ax[0], 1);
        assert!(s.remove_random_out(0, &mut rng).is_some());
        assert!(s.remove_random_out(0, &mut rng).is_none());
        s.check_invariants().unwrap();
    }

    #[test]
    fn remove_random_in_respects_kind() {
        let mut s = SynapseStore::new(1, 12);
        let mut rng = Rng::new(2);
        s.add_in(0, 10, true);
        s.add_in(0, 11, false);
        let src = s.remove_random_in(0, ElementKind::Inhibitory, &mut rng).unwrap();
        assert_eq!(src, 11);
        assert_eq!(s.connected_den_inh[0], 0);
        assert_eq!(s.connected_den_exc[0], 1);
        assert!(s.remove_random_in(0, ElementKind::Inhibitory, &mut rng).is_none());
        s.check_invariants().unwrap();
    }

    #[test]
    fn remove_specific_tolerates_missing() {
        let mut s = SynapseStore::new(1, 8);
        s.add_out(0, 5);
        assert!(s.remove_specific_out(0, 5));
        assert!(!s.remove_specific_out(0, 5));
        s.add_in(0, 6, true);
        assert!(s.remove_specific_in(0, 6));
        assert!(!s.remove_specific_in(0, 6));
        s.check_invariants().unwrap();
    }

    #[test]
    fn out_rank_routing_follows_adds_and_removes() {
        // Stride 4: targets 1 -> rank 0, 5 and 6 -> rank 1, 9 -> rank 2.
        let mut s = SynapseStore::new(2, 4);
        s.add_out(0, 5);
        s.add_out(0, 1);
        s.add_out(0, 6);
        s.add_out(0, 9);
        assert_eq!(s.out_ranks(0), &[(0, 1), (1, 2), (2, 1)]);
        assert!(s.out_ranks(1).is_empty());
        // Dropping one of the two rank-1 edges keeps the route alive...
        assert!(s.remove_specific_out(0, 5));
        assert_eq!(s.out_ranks(0), &[(0, 1), (1, 1), (2, 1)]);
        // ...dropping the last removes the route entirely.
        assert!(s.remove_specific_out(0, 6));
        assert_eq!(s.out_ranks(0), &[(0, 1), (2, 1)]);
        s.check_invariants().unwrap();
    }

    #[test]
    fn in_partner_refcounts_track_last_edge() {
        let mut s = SynapseStore::new(2, 2);
        // Source 7 feeds both local targets; source 4 only one.
        s.add_in(0, 7, true);
        s.add_in(1, 7, false);
        s.add_in(0, 4, true);
        assert_eq!(s.in_partner_count(7), 2);
        assert_eq!(s.in_partner_count(4), 1);
        assert_eq!(s.in_partner_count(9), 0);
        assert_eq!(s.in_partner_sources(), 2);
        assert!(s.remove_specific_in(0, 7));
        assert_eq!(s.in_partner_count(7), 1, "second in-edge keeps the partner");
        assert!(s.remove_specific_in(1, 7));
        assert_eq!(s.in_partner_count(7), 0, "last deletion drops the partner");
        assert_eq!(s.in_partner_sources(), 1);
        s.check_invariants().unwrap();
    }

    #[test]
    fn in_edit_generation_tracks_dendritic_edits_only() {
        let mut s = SynapseStore::new(2, 2);
        let mut rng = Rng::new(4);
        assert_eq!(s.in_edits(), 0);
        // Out-edge edits never bump: the delivery plan is in-side only.
        s.add_out(0, 3);
        assert!(s.remove_specific_out(0, 3));
        s.remove_random_out(0, &mut rng);
        assert_eq!(s.in_edits(), 0);
        // Every in-edge edit bumps exactly once.
        s.add_in(0, 3, true);
        assert_eq!(s.in_edits(), 1);
        s.add_in(1, 3, false);
        assert_eq!(s.in_edits(), 2);
        assert!(s.remove_specific_in(0, 3));
        assert_eq!(s.in_edits(), 3);
        // A no-op removal is not an edit.
        assert!(!s.remove_specific_in(0, 3));
        assert_eq!(s.in_edits(), 3);
        assert!(s.remove_random_in(1, ElementKind::Inhibitory, &mut rng).is_some());
        assert_eq!(s.in_edits(), 4);
        assert!(s.remove_random_in(1, ElementKind::Inhibitory, &mut rng).is_none());
        assert_eq!(s.in_edits(), 4);
    }

    #[test]
    fn in_partners_iterates_ascending_with_counts() {
        let mut s = SynapseStore::new(2, 2);
        s.add_in(0, 7, true);
        s.add_in(1, 7, false);
        s.add_in(0, 4, true);
        let got: Vec<(u64, u32)> = s.in_partners().collect();
        assert_eq!(got, vec![(4, 1), (7, 2)]);
    }

    #[test]
    fn from_parts_rebuilds_derived_structures() {
        let mut incremental = SynapseStore::new(2, 4);
        incremental.add_out(0, 6);
        incremental.add_out(0, 9);
        incremental.add_out(1, 2);
        incremental.add_in(0, 13, true);
        incremental.add_in(1, 13, false);
        incremental.add_in(1, 2, true);
        let rebuilt = SynapseStore::from_parts(
            incremental.out_edges.clone(),
            incremental.in_edges.clone(),
            incremental.connected_ax.clone(),
            incremental.connected_den_exc.clone(),
            incremental.connected_den_inh.clone(),
            OwnershipMap::stride(4),
        );
        assert_eq!(rebuilt.out_ranks, incremental.out_ranks);
        assert_eq!(rebuilt.in_partner_refs, incremental.in_partner_refs);
        rebuilt.check_invariants().unwrap();
    }

    #[test]
    fn uniform_ranges_store_routes_identically_to_stride() {
        // The ownership-map equivalence at the store layer: the same
        // random edit sequence against a Stride store and a uniform
        // Ranges store must produce identical derived routing tables,
        // partner refcounts, and edit generations.
        use crate::testing::forall;
        forall(
            "uniform Ranges store ≡ Stride store",
            20,
            |rng| rng.next_u64(),
            |&seed| {
                let mut rng_a = Rng::new(seed);
                let mut rng_b = Rng::new(seed);
                let n = 6usize;
                let npr = 6u64;
                let total = 4 * npr; // 4 ranks
                let starts: Vec<u64> = (0..=4u64).map(|r| r * npr).collect();
                let mut a = SynapseStore::new(n, npr);
                let mut b =
                    SynapseStore::with_owners(n, OwnershipMap::ranges(starts).unwrap());
                for step in 0..120 {
                    let op = step % 4;
                    let local = (seed as usize + step) % n;
                    let partner = ((seed >> 8) as u64 + step as u64 * 7) % total;
                    match op {
                        0 => {
                            a.add_out(local, partner);
                            b.add_out(local, partner);
                        }
                        1 => {
                            a.add_in(local, partner, step % 2 == 0);
                            b.add_in(local, partner, step % 2 == 0);
                        }
                        2 => {
                            let ra = a.remove_random_out(local, &mut rng_a);
                            let rb = b.remove_random_out(local, &mut rng_b);
                            if ra != rb {
                                return Err(format!("random out removal diverged at {step}"));
                            }
                        }
                        _ => {
                            let ra = a.remove_random_in(
                                local,
                                ElementKind::Excitatory,
                                &mut rng_a,
                            );
                            let rb = b.remove_random_in(
                                local,
                                ElementKind::Excitatory,
                                &mut rng_b,
                            );
                            if ra != rb {
                                return Err(format!("random in removal diverged at {step}"));
                            }
                        }
                    }
                }
                if a.out_ranks != b.out_ranks {
                    return Err("routing tables diverged".to_string());
                }
                if a.in_partner_refs != b.in_partner_refs {
                    return Err("partner refcounts diverged".to_string());
                }
                if a.in_edits() != b.in_edits() {
                    return Err("edit generations diverged".to_string());
                }
                a.check_invariants()?;
                b.check_invariants()?;
                Ok(())
            },
        );
    }

    #[test]
    fn invariants_catch_corrupt_derived_state() {
        let mut s = SynapseStore::new(1, 2);
        s.add_out(0, 3);
        s.out_ranks[0].clear();
        assert!(s.check_invariants().unwrap_err().contains("routing"));

        let mut s = SynapseStore::new(1, 2);
        s.add_in(0, 3, true);
        s.in_partner_refs.clear();
        assert!(s.check_invariants().unwrap_err().contains("refcounts"));
    }

    #[test]
    fn vacant_clamps() {
        assert_eq!(vacant(2.7, 1), 1);
        assert_eq!(vacant(2.7, 2), 0);
        assert_eq!(vacant(2.7, 5), 0);
        assert_eq!(vacant(0.9, 0), 0);
        assert_eq!(vacant(1.0, 0), 1);
    }
}
