//! Synapse-deletion phase (paper §III-A0c, first sub-phase).
//!
//! When a neuron's element count falls below its bound-synapse count
//! (floor(z) < connected), bound elements have retracted: synapses are
//! chosen uniformly at random and broken. The affected partner on the
//! other side must be notified — it keeps its element (now vacant) but
//! loses the synapse. Notifications cross ranks in one all-to-all.

use crate::comm::{exchange, Comm};
use crate::neuron::{GlobalNeuronId, Population};
use crate::octree::ElementKind;
use crate::util::wire::{get_u64, get_u8, put_u64, put_u8, Wire};
use crate::util::Rng;

use super::synapses::SynapseStore;

/// "Your synapse partner dropped the synapse" notification.
/// 17 B: partner id (8) + notifying id (8) + which side retracted (1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DeleteNotify {
    /// Neuron that must drop its edge (lives on the receiving rank).
    pub partner: GlobalNeuronId,
    /// Neuron whose element retracted (lives on the sending rank).
    pub initiator: GlobalNeuronId,
    /// True if the *axonal* side retracted (so the partner drops an
    /// in-edge); false if the dendritic side retracted (partner drops an
    /// out-edge).
    pub axon_side: bool,
}

impl Wire for DeleteNotify {
    const SIZE: usize = 17;

    fn write(&self, out: &mut Vec<u8>) {
        put_u64(out, self.partner);
        put_u64(out, self.initiator);
        put_u8(out, u8::from(self.axon_side));
    }

    fn read(buf: &[u8]) -> Self {
        DeleteNotify {
            partner: get_u64(buf, 0),
            initiator: get_u64(buf, 8),
            axon_side: get_u8(buf, 16) != 0,
        }
    }
}

/// Outcome counters of one deletion phase (for reporting/tests).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DeletionStats {
    pub axonal_retractions: u64,
    pub dendritic_retractions: u64,
    pub notifications_sent: u64,
}

/// Run the deletion phase for this rank. `owner_of` maps a global neuron
/// id to its rank.
pub fn run_deletion_phase(
    comm: &impl Comm,
    pop: &Population,
    store: &mut SynapseStore,
    rng: &mut Rng,
    owner_of: impl Fn(GlobalNeuronId) -> usize,
) -> DeletionStats {
    let mut stats = DeletionStats::default();
    let mut notifies: Vec<Vec<DeleteNotify>> = vec![Vec::new(); comm.size()];

    for local in 0..pop.len() {
        let my_id = pop.global_id(local);

        // Axonal retraction: bound axonal elements exceed floor(z_ax).
        let want_ax = pop.z_ax[local].floor().max(0.0) as i64;
        while (store.connected_ax[local] as i64) > want_ax {
            let target = store
                .remove_random_out(local, rng)
                .expect("connected_ax > 0 implies an out-edge");
            stats.axonal_retractions += 1;
            notifies[owner_of(target)].push(DeleteNotify {
                partner: target,
                initiator: my_id,
                axon_side: true,
            });
        }

        // Dendritic retraction, per element kind.
        for kind in [ElementKind::Excitatory, ElementKind::Inhibitory] {
            let z = match kind {
                ElementKind::Excitatory => pop.z_den_exc[local],
                ElementKind::Inhibitory => pop.z_den_inh[local],
            };
            let want = z.floor().max(0.0) as i64;
            while (store.connected_den(local, kind) as i64) > want {
                let source = store
                    .remove_random_in(local, kind, rng)
                    .expect("connected_den > 0 implies an in-edge");
                stats.dendritic_retractions += 1;
                notifies[owner_of(source)].push(DeleteNotify {
                    partner: source,
                    initiator: my_id,
                    axon_side: false,
                });
            }
        }
    }

    stats.notifications_sent =
        notifies.iter().enumerate().filter(|(r, _)| *r != comm.rank()).map(|(_, v)| v.len() as u64).sum();

    // One all-to-all; apply what lands here. A notification can miss if
    // both ends retracted the same synapse this round — that's fine.
    let incoming = exchange(comm, notifies);
    for batch in incoming {
        for n in batch {
            let local = pop.local_index(n.partner);
            if n.axon_side {
                // Partner's axon retracted: we lose an in-edge.
                store.remove_specific_in(local, n.initiator);
            } else {
                // Partner's dendrite retracted: we lose an out-edge.
                store.remove_specific_out(local, n.initiator);
            }
        }
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::run_ranks;
    use crate::config::SimConfig;
    use crate::util::Vec3;

    #[test]
    fn notify_wire_is_17_bytes() {
        assert_eq!(DeleteNotify::SIZE, 17);
        let n = DeleteNotify { partner: 5, initiator: 9, axon_side: true };
        let mut buf = Vec::new();
        n.write(&mut buf);
        assert_eq!(buf.len(), 17);
        assert_eq!(DeleteNotify::read(&buf), n);
    }

    fn make_pop(rank: usize, n: usize) -> Population {
        let cfg = SimConfig { neurons_per_rank: n, ..SimConfig::default() };
        let mut rng = Rng::new(rank as u64);
        Population::init(&cfg, rank, Vec3::ZERO, Vec3::splat(100.0), &mut rng)
    }

    #[test]
    fn local_retraction_breaks_both_sides() {
        // Single rank, two neurons, one synapse 0 -> 1; force z_ax to 0.
        let results = run_ranks(1, |comm| {
            let mut pop = make_pop(0, 2);
            let mut store = SynapseStore::new(2, 2);
            store.add_out(0, 1);
            store.add_in(1, 0, pop.is_excitatory[0]);
            pop.z_ax[0] = 0.0;
            // Keep dendrites generous so only the axon retracts.
            pop.z_den_exc[1] = 5.0;
            pop.z_den_inh[1] = 5.0;
            let mut rng = Rng::new(1);
            let stats = run_deletion_phase(&comm, &pop, &mut store, &mut rng, |_| 0);
            (stats, store)
        });
        let (stats, store) = &results[0];
        assert_eq!(stats.axonal_retractions, 1);
        assert_eq!(store.total_out(), 0);
        assert_eq!(store.total_in(), 0);
        store.check_invariants().unwrap();
    }

    #[test]
    fn cross_rank_retraction_notifies_partner() {
        // Rank 0: neuron 0 with axon to neuron 1 (rank 1). Rank 0's
        // z_ax drops to 0 -> rank 1 must lose the in-edge.
        let results = run_ranks(2, |comm| {
            let mut pop = make_pop(comm.rank(), 1);
            let mut store = SynapseStore::new(1, 1);
            if comm.rank() == 0 {
                store.add_out(0, 1);
                pop.z_ax[0] = 0.0;
            } else {
                store.add_in(0, 0, true);
                pop.z_den_exc[0] = 5.0;
                pop.z_den_inh[0] = 5.0;
                pop.z_ax[0] = 5.0;
            }
            if comm.rank() == 0 {
                pop.z_den_exc[0] = 5.0;
                pop.z_den_inh[0] = 5.0;
            }
            let mut rng = Rng::new(comm.rank() as u64);
            let stats =
                run_deletion_phase(&comm, &pop, &mut store, &mut rng, |id| id as usize);
            (stats, store)
        });
        assert_eq!(results[0].0.axonal_retractions, 1);
        assert_eq!(results[0].0.notifications_sent, 1);
        assert_eq!(results[0].1.total_out(), 0);
        assert_eq!(results[1].1.total_in(), 0);
        results[1].1.check_invariants().unwrap();
    }

    #[test]
    fn dendritic_retraction_notifies_source() {
        let results = run_ranks(2, |comm| {
            let mut pop = make_pop(comm.rank(), 1);
            let mut store = SynapseStore::new(1, 1);
            pop.z_ax[0] = 5.0;
            pop.z_den_exc[0] = 5.0;
            pop.z_den_inh[0] = 5.0;
            if comm.rank() == 0 {
                store.add_out(0, 1);
            } else {
                store.add_in(0, 0, true);
                pop.z_den_exc[0] = 0.0; // force dendritic retraction
            }
            let mut rng = Rng::new(comm.rank() as u64);
            let stats =
                run_deletion_phase(&comm, &pop, &mut store, &mut rng, |id| id as usize);
            (stats, store)
        });
        assert_eq!(results[1].0.dendritic_retractions, 1);
        assert_eq!(results[0].1.total_out(), 0, "source must drop its out-edge");
    }

    #[test]
    fn deletion_phase_dirties_the_delivery_plan() {
        // The deletion protocol edits in-edges only through the store's
        // edit sites (remove_random_in locally, remove_specific_in via
        // notification), so a phase that breaks a synapse must bump the
        // in-edge generation and mark any compiled DeliveryPlan stale —
        // the signal the driver's C4 recompile keys off.
        use crate::spikes::DeliveryPlan;
        let results = run_ranks(2, |comm| {
            let mut pop = make_pop(comm.rank(), 1);
            let mut store = SynapseStore::new(1, 1);
            if comm.rank() == 0 {
                store.add_out(0, 1);
                pop.z_ax[0] = 0.0; // force axonal retraction
                pop.z_den_exc[0] = 5.0;
                pop.z_den_inh[0] = 5.0;
            } else {
                store.add_in(0, 0, true);
                pop.z_ax[0] = 5.0;
                pop.z_den_exc[0] = 5.0;
                pop.z_den_inh[0] = 5.0;
            }
            let plan = DeliveryPlan::compile(&store, comm.rank() as u64);
            assert!(plan.is_current(&store));
            let mut rng = Rng::new(comm.rank() as u64);
            run_deletion_phase(&comm, &pop, &mut store, &mut rng, |id| id as usize);
            (plan.is_current(&store), store)
        });
        // Rank 1 lost its in-edge via the cross-rank notification: its
        // plan must be stale. Rank 0 only lost an out-edge: its
        // (dendritic-side) plan stays current.
        assert!(results[0].0, "axonal-only edit must not dirty the plan");
        assert!(!results[1].0, "in-edge deletion must dirty the plan");
        let fresh = DeliveryPlan::compile(&results[1].1, 1);
        assert_eq!(fresh.slot_count(), 0, "no remote partners survive");
        fresh.check_against(&results[1].1).unwrap();
    }

    #[test]
    fn no_retraction_when_elements_sufficient() {
        let results = run_ranks(1, |comm| {
            let mut pop = make_pop(0, 2);
            let mut store = SynapseStore::new(2, 2);
            store.add_out(0, 1);
            store.add_in(1, 0, true);
            pop.z_ax[0] = 2.0;
            pop.z_den_exc[1] = 2.0;
            pop.z_den_inh[1] = 2.0;
            pop.z_den_exc[0] = 2.0;
            pop.z_den_inh[0] = 2.0;
            pop.z_ax[1] = 2.0;
            let mut rng = Rng::new(3);
            run_deletion_phase(&comm, &pop, &mut store, &mut rng, |_| 0);
            store
        });
        assert_eq!(results[0].total_out(), 1);
        assert_eq!(results[0].total_in(), 1);
    }
}
