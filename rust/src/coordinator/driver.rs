//! The MSP simulation loop (paper §III-A): per step — spike transmission,
//! electrical update, element update; every `plasticity_interval` steps —
//! synapse deletion, octree update, Barnes–Hut formation. Each phase is
//! timed under the paper's Fig. 11 categories and every byte crossing
//! ranks is counted by the communicator.

use std::time::{Duration, Instant};

use anyhow::Result;

use crate::barnes_hut::{self, FormationStats};
use crate::comm::{gather_all, run_ranks, ThreadComm};
use crate::config::{Backend, ConnectivityAlg, SimConfig, SpikeAlg};
use crate::metrics::{Phase, PhaseTimers, RankReport, SimReport};
use crate::neuron::{izhikevich, Population};
use crate::octree::{
    serialize_local_subtrees, DomainDecomposition, Octree, RemoteNodeCache, NO_CHILD,
    OCTREE_WINDOW,
};
use crate::plasticity::{run_deletion_phase, vacant, DeletionStats, SynapseStore};
use crate::runtime::{NeuronInputs, XlaHandle};
use crate::spikes::{deliver_input, FrequencyExchange, IdExchange};
use crate::util::Rng;

/// All mutable state of one rank during a simulation.
pub struct RankState {
    pub pop: Population,
    pub store: SynapseStore,
    pub tree: Octree,
    pub id_exchange: IdExchange,
    pub freq_exchange: FrequencyExchange,
    pub cache: RemoteNodeCache,
    pub rng_model: Rng,
    pub rng_conn: Rng,
    pub timers: PhaseTimers,
    pub formation: FormationStats,
    pub deletion: DeletionStats,
    pub spike_lookups: u64,
    pub calcium_trace: Vec<(usize, Vec<f32>)>,
}

impl RankState {
    /// Build the initial state of `rank` (placement, octree, RNG streams).
    pub fn init(cfg: &SimConfig, decomp: &DomainDecomposition, comm: &ThreadComm) -> RankState {
        let rank = comm.rank();
        let root = Rng::new(cfg.seed);
        let mut rng_model = root.fork(1_000 + rank as u64);
        let rng_conn = root.fork(2_000 + rank as u64);
        let rng_spikes = root.fork(3_000 + rank as u64);

        let cells: Vec<_> =
            decomp.cells_of_rank(rank).map(|c| decomp.cell_bounds(c)).collect();
        let pop = Population::init_in_cells(cfg, rank, &cells, &mut rng_model);
        let tree = Octree::build(decomp, rank, pop.first_id, &pop.positions);
        let n = pop.len();
        RankState {
            pop,
            store: SynapseStore::new(n),
            tree,
            id_exchange: IdExchange::new(comm.size()),
            freq_exchange: FrequencyExchange::new(cfg.delta, cfg.total_neurons(), rng_spikes),
            cache: RemoteNodeCache::default(),
            rng_model,
            rng_conn,
            timers: PhaseTimers::new(),
            formation: FormationStats::default(),
            deletion: DeletionStats::default(),
            spike_lookups: 0,
            calcium_trace: Vec::new(),
        }
    }

    /// Phase A: spike transmission (previous step's spikes / last epoch's
    /// frequencies) + input assembly.
    pub fn spike_phase(&mut self, cfg: &SimConfig, comm: &ThreadComm, step: usize) {
        let npr = cfg.neurons_per_rank as u64;
        match cfg.spike_alg {
            SpikeAlg::OldIds => {
                let (pop, store, ex) = (&mut self.pop, &self.store, &mut self.id_exchange);
                self.timers.time(Phase::SpikeExchange, || ex.exchange(comm, pop, store, npr));
                let ex = &self.id_exchange;
                self.spike_lookups += self.timers.time(Phase::SpikeLookup, || {
                    deliver_input(&mut self.pop, &self.store, npr, comm.rank(), |r, id| {
                        ex.spiked(r, id)
                    })
                });
            }
            SpikeAlg::NewFrequency => {
                let (pop, store, ex) = (&mut self.pop, &self.store, &mut self.freq_exchange);
                self.timers
                    .time(Phase::SpikeExchange, || ex.maybe_exchange(comm, pop, store, npr, step));
                let ex = &mut self.freq_exchange;
                self.spike_lookups += self.timers.time(Phase::SpikeLookup, || {
                    deliver_input(&mut self.pop, &self.store, npr, comm.rank(), |_, id| {
                        ex.spiked(id)
                    })
                });
            }
        }
    }

    /// Phase B: background noise + the fused neuron/element update
    /// (native mirror or the AOT XLA artifact).
    pub fn activity_phase(&mut self, cfg: &SimConfig, xla: Option<&XlaHandle>) -> Result<()> {
        let t0 = Instant::now();
        self.pop.draw_noise(cfg, &mut self.rng_model);
        match (cfg.backend, xla) {
            (Backend::Native, _) | (Backend::Xla, None) => match cfg.neuron_model {
                crate::config::NeuronModel::Izhikevich => {
                    izhikevich::step(&mut self.pop, &cfg.neuron);
                }
                crate::config::NeuronModel::Poisson => {
                    crate::neuron::poisson::step(
                        &mut self.pop,
                        &cfg.neuron,
                        &crate::neuron::poisson::PoissonParams::default(),
                        &mut self.rng_model,
                    );
                }
            },
            (Backend::Xla, Some(handle)) => {
                let pop = &mut self.pop;
                let out = handle.neuron_update(NeuronInputs {
                    v: pop.v.clone(),
                    u: pop.u.clone(),
                    ca: pop.ca.clone(),
                    z_ax: pop.z_ax.clone(),
                    z_de: pop.z_den_exc.clone(),
                    z_di: pop.z_den_inh.clone(),
                    i_syn: pop.i_syn.clone(),
                    noise: pop.noise.clone(),
                    params: cfg.neuron.to_vec(),
                })?;
                pop.v = out.v;
                pop.u = out.u;
                pop.ca = out.ca;
                pop.z_ax = out.z_ax;
                pop.z_den_exc = out.z_de;
                pop.z_den_inh = out.z_di;
                for (i, &f) in out.fired.iter().enumerate() {
                    let fired = f > 0.5;
                    pop.fired[i] = fired;
                    if fired {
                        pop.epoch_spikes[i] += 1;
                    }
                }
            }
        }
        self.timers.add(Phase::ActivityUpdate, t0.elapsed());
        Ok(())
    }

    /// Phase C: the connectivity update — deletion, octree refresh (incl.
    /// branch all-to-all and, for the old algorithm, RMA-window publish),
    /// then formation with the configured algorithm.
    pub fn plasticity_phase(
        &mut self,
        cfg: &SimConfig,
        decomp: &DomainDecomposition,
        comm: &ThreadComm,
    ) {
        let npr = cfg.neurons_per_rank as u64;
        // C1: deletion.
        let (pop, store, rng) = (&self.pop, &mut self.store, &mut self.rng_conn);
        let dstats = self.timers.time(Phase::DeleteSynapses, || {
            run_deletion_phase(comm, pop, store, rng, |id| (id / npr) as usize)
        });
        self.deletion.axonal_retractions += dstats.axonal_retractions;
        self.deletion.dendritic_retractions += dstats.dendritic_retractions;
        self.deletion.notifications_sent += dstats.notifications_sent;

        // C2: octree vacancy update + branch exchange (+ window publish
        // for the old algorithm's RMA path).
        let t0 = Instant::now();
        let n = self.pop.len();
        let vac_exc: Vec<f32> = (0..n)
            .map(|i| vacant(self.pop.z_den_exc[i], self.store.connected_den_exc[i]) as f32)
            .collect();
        let vac_inh: Vec<f32> = (0..n)
            .map(|i| vacant(self.pop.z_den_inh[i], self.store.connected_den_inh[i]) as f32)
            .collect();
        self.tree.reset_and_set_leaves(self.pop.first_id, &vac_exc, &vac_inh);
        self.tree.aggregate_local();

        let own_cells = decomp.cells_of_rank(comm.rank());
        let payloads = if cfg.connectivity_alg == ConnectivityAlg::OldRma {
            let win = serialize_local_subtrees(&self.tree, own_cells.clone());
            comm.publish_window(OCTREE_WINDOW, win.bytes);
            self.tree.own_branch_payloads(own_cells, |c| win.root_of_cell[&c])
        } else {
            self.tree.own_branch_payloads(own_cells, |_| NO_CHILD)
        };
        let all = gather_all(comm, &payloads);
        for (src, batch) in all.iter().enumerate() {
            if src != comm.rank() {
                self.tree.apply_branch_payloads(batch);
            }
        }
        self.tree.aggregate_upper();
        self.tree.normalize();
        self.timers.add(Phase::OctreeUpdate, t0.elapsed());

        // C3: formation.
        let fstats = match cfg.connectivity_alg {
            ConnectivityAlg::OldRma => barnes_hut::old::run_formation(
                comm,
                &self.tree,
                &self.pop,
                &mut self.store,
                &mut self.cache,
                cfg,
                &mut self.rng_conn,
            ),
            ConnectivityAlg::NewLocationAware => barnes_hut::new::run_formation(
                comm,
                &self.tree,
                &self.pop,
                &mut self.store,
                cfg,
                &mut self.rng_conn,
            ),
            ConnectivityAlg::Direct => barnes_hut::direct::run_formation(
                comm,
                &self.pop,
                &mut self.store,
                cfg,
                &mut self.rng_conn,
            ),
        };
        self.timers.add(Phase::BarnesHut, Duration::from_nanos(fstats.compute_nanos));
        self.timers.add(Phase::SynapseExchange, Duration::from_nanos(fstats.exchange_nanos));
        self.formation = self.formation.merge(&fstats);
    }

    /// One full simulation step.
    pub fn step(
        &mut self,
        cfg: &SimConfig,
        decomp: &DomainDecomposition,
        comm: &ThreadComm,
        step: usize,
        xla: Option<&XlaHandle>,
    ) -> Result<()> {
        self.spike_phase(cfg, comm, step);
        self.activity_phase(cfg, xla)?;
        if (step + 1) % cfg.plasticity_interval == 0 {
            self.plasticity_phase(cfg, decomp, comm);
        }
        if cfg.record_calcium_every > 0 && step % cfg.record_calcium_every == 0 {
            self.calcium_trace.push((step, self.pop.ca.clone()));
        }
        Ok(())
    }

    /// Assemble this rank's final report.
    pub fn into_report(self, comm: &ThreadComm) -> RankReport {
        RankReport {
            rank: comm.rank(),
            phase_seconds: self.timers.seconds(),
            comm: comm.counters().snapshot(),
            formation: self.formation,
            deletion: self.deletion,
            spike_lookups: self.spike_lookups,
            synapses_out: self.store.total_out(),
            synapses_in: self.store.total_in(),
            mean_calcium: self.pop.mean_calcium(),
            calcium_trace: self.calcium_trace,
        }
    }
}

/// Run a full simulation with the native backend (or whatever the config
/// says, if an XLA handle is supplied via `run_simulation_with_xla`).
pub fn run_simulation(cfg: &SimConfig) -> Result<SimReport> {
    run_simulation_with_xla(cfg, None)
}

/// Run a full simulation; `xla` supplies the shared artifact executor
/// when `cfg.backend == Backend::Xla`.
pub fn run_simulation_with_xla(cfg: &SimConfig, xla: Option<XlaHandle>) -> Result<SimReport> {
    cfg.validate().map_err(anyhow::Error::msg)?;
    let decomp = DomainDecomposition::new(cfg.ranks, cfg.domain_size);
    let wall = Instant::now();
    let results: Vec<Result<RankReport>> = run_ranks(cfg.ranks, |comm| {
        let mut state = RankState::init(cfg, &decomp, &comm);
        for step in 0..cfg.steps {
            state.step(cfg, &decomp, &comm, step, xla.as_ref())?;
        }
        Ok(state.into_report(&comm))
    });
    let mut ranks = Vec::with_capacity(results.len());
    for r in results {
        ranks.push(r?);
    }
    Ok(SimReport { ranks, wall_seconds: wall.elapsed().as_secs_f64() })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn smoke_cfg() -> SimConfig {
        SimConfig {
            ranks: 2,
            neurons_per_rank: 32,
            steps: 200,
            plasticity_interval: 50,
            delta: 50,
            ..SimConfig::default()
        }
    }

    #[test]
    fn smoke_new_algorithms() {
        let report = run_simulation(&smoke_cfg()).unwrap();
        assert_eq!(report.ranks.len(), 2);
        // Synapse bookkeeping is globally consistent.
        let out: usize = report.ranks.iter().map(|r| r.synapses_out).sum();
        let inn: usize = report.ranks.iter().map(|r| r.synapses_in).sum();
        assert_eq!(out, inn);
        // With background N(5,1) the network is active and forms synapses.
        assert!(out > 0, "no synapses formed");
        assert!(report.mean_calcium() > 0.0);
        // New algorithm: no RMA at all.
        assert_eq!(report.total_bytes_rma(), 0);
    }

    #[test]
    fn smoke_old_algorithms() {
        let mut cfg = smoke_cfg();
        cfg.connectivity_alg = ConnectivityAlg::OldRma;
        cfg.spike_alg = SpikeAlg::OldIds;
        let report = run_simulation(&cfg).unwrap();
        let out: usize = report.ranks.iter().map(|r| r.synapses_out).sum();
        assert!(out > 0);
        // The old path downloads octree nodes at some point once
        // cross-rank proposals happen.
        assert!(
            report.total_bytes_rma() > 0,
            "old algorithm should use RMA (bytes_rma = 0)"
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = smoke_cfg();
        let a = run_simulation(&cfg).unwrap();
        let b = run_simulation(&cfg).unwrap();
        for (ra, rb) in a.ranks.iter().zip(&b.ranks) {
            assert_eq!(ra.synapses_out, rb.synapses_out);
            assert_eq!(ra.mean_calcium, rb.mean_calcium);
            assert_eq!(ra.comm.bytes_sent, rb.comm.bytes_sent);
        }
    }

    #[test]
    fn direct_baseline_runs() {
        let mut cfg = smoke_cfg();
        cfg.connectivity_alg = ConnectivityAlg::Direct;
        cfg.steps = 100;
        let report = run_simulation(&cfg).unwrap();
        assert!(report.total_synapses() > 0);
    }

    #[test]
    fn single_rank_runs() {
        let mut cfg = smoke_cfg();
        cfg.ranks = 1;
        cfg.neurons_per_rank = 64;
        let report = run_simulation(&cfg).unwrap();
        assert_eq!(report.ranks.len(), 1);
        // One rank: everything is local — nothing on the wire.
        assert_eq!(report.total_bytes_sent(), 0);
        assert_eq!(report.total_bytes_rma(), 0);
        assert!(report.total_synapses() > 0);
    }
}
