//! The MSP simulation loop (paper §III-A): per step — spike transmission,
//! electrical update, element update; every `plasticity_interval` steps —
//! synapse deletion, octree update, Barnes–Hut formation. Each phase is
//! timed under the paper's Fig. 11 categories and every byte crossing
//! ranks is counted by the communicator; the `bench` subsystem sweeps
//! exactly these timings and counters across its scenario matrix
//! (EXPERIMENTS.md §Bench), so the driver carries no bench-only code.

use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::balance::{
    plan_rebalance, MigrationBatch, NeuronRecord, OwnershipMap, Partition, RankCost,
};
use crate::barnes_hut::{self, new::FormationScratch, FormationStats};
use crate::comm::{gather_all, run_ranks, Comm, CounterSnapshot};
use crate::config::{Backend, ConnectivityAlg, SimConfig, SpikeAlg};
use crate::metrics::{Phase, PhaseTimers, RankReport, SimReport};
use crate::neuron::{blocks_per_step, make_kernel, NeuronKernel, Population};
use crate::octree::{
    serialize_local_subtrees, DomainDecomposition, Octree, RemoteNodeCache, NO_CHILD,
    OCTREE_WINDOW,
};
use crate::plasticity::{run_deletion_phase, vacant, DeletionStats, InEdge, SynapseStore};
use crate::runtime::XlaHandle;
use crate::snapshot::{CheckpointSink, RankSection, SectionSink, Snapshot};
use crate::spikes::{DeliveryPlan, FrequencyExchange, IdExchange};
use crate::trace::{Cumulative, Tracer};
use crate::util::Rng;

/// Reusable per-plasticity-phase vacancy buffers for the octree update
/// (EXPERIMENTS.md §Perf, opt 8 satellite): the C2 sub-phase used to
/// allocate two fresh `Vec<f32>` of n elements every connectivity
/// update. Pure scratch — fully rewritten each phase, never
/// snapshotted, rebuilt empty on restore.
#[derive(Default)]
pub struct VacancyScratch {
    pub exc: Vec<f32>,
    pub inh: Vec<f32>,
}

/// All mutable state of one rank during a simulation.
pub struct RankState {
    pub pop: Population,
    pub store: SynapseStore,
    pub tree: Octree,
    /// The replicated cell-level partition (identical on every rank).
    /// Migration replaces it — together with `owners`, `decomp`, and
    /// every structure derived from ownership — wholesale.
    pub partition: Partition,
    /// The id → rank routing view of `partition` (`Stride` until a
    /// migration or skewed init makes it a `Ranges` table).
    pub owners: OwnershipMap,
    /// The spatial decomposition of `partition`'s cell assignment.
    pub decomp: DomainDecomposition,
    /// Migrations applied in this process segment.
    pub migrations: u64,
    pub id_exchange: IdExchange,
    pub freq_exchange: FrequencyExchange,
    /// Epoch-compiled CSR delivery plan (EXPERIMENTS.md §Perf, opt 8).
    /// Derived state: recompiled whenever the store's in-edge
    /// generation moves (after plasticity phases) and on restore —
    /// never stored in the ILMISNAP format.
    pub plan: DeliveryPlan,
    /// Plan recompiles performed in this process segment (initial
    /// compile included). Like the phase timers, this is per-segment
    /// bookkeeping: it is not snapshotted, so a resumed run reports its
    /// own segment's count rather than the straight run's total.
    pub plan_rebuilds: u64,
    pub cache: RemoteNodeCache,
    pub rng_model: Rng,
    pub rng_conn: Rng,
    pub timers: PhaseTimers,
    pub formation: FormationStats,
    pub deletion: DeletionStats,
    pub spike_lookups: u64,
    pub calcium_trace: Vec<(usize, Vec<f32>)>,
    /// Reusable send buffers for the location-aware formation phase's
    /// two all-to-alls (EXPERIMENTS.md §Perf, opt 6). Pure scratch:
    /// never snapshotted, rebuilt empty on restore.
    pub bh_scratch: FormationScratch,
    /// Reusable vacancy buffers for the octree update (pure scratch).
    pub vac_scratch: VacancyScratch,
    /// Communication counters accumulated before this process segment
    /// (non-zero only for states restored from a snapshot): the run's
    /// communicator starts at zero, so the final report adds this
    /// baseline to make a resumed run's accounting equal a straight
    /// run's.
    pub baseline_comm: CounterSnapshot,
    /// Local spikes accumulated for the epoch trace. Only maintained
    /// while tracing is enabled; derived from `pop.fired`, so it is
    /// per-segment bookkeeping and never snapshotted.
    pub spikes_fired: u64,
    /// Epoch-telemetry sampler (see the `trace` module). Pure scratch:
    /// segment-scoped like the phase timers, never stored in ILMISNAP,
    /// primed right after each segment's initial plan compile so that
    /// a resumed segment's first window excludes the restore recompile.
    pub tracer: Tracer,
    /// The activity-update backend (see `neuron::kernel`). Pure
    /// execution strategy — every backend is bit-identical — so it is
    /// derived from config (constructors build the handle-less dispatch;
    /// `simulate_rank` re-installs with the XLA handle when one exists)
    /// and never snapshotted.
    pub kernel: Box<dyn NeuronKernel>,
    /// Deterministic work metric: cache blocks covered by this segment's
    /// activity updates (`blocks_per_step` per step — counted here, not
    /// by the kernels, so it is kernel-independent by construction).
    /// Per-segment bookkeeping like `plan_rebuilds`: never snapshotted,
    /// drift-checked by the bench harness.
    pub kernel_blocks: u64,
    /// Set when this segment was (re)started by the recovery supervisor:
    /// the first trace sample taken afterwards carries the
    /// `RECOVERY_EPOCH` boundary bit, marking the restart in Perfetto /
    /// JSONL exports. Consumed by the first due sample; never
    /// snapshotted (recovery is a property of the segment, not the
    /// trajectory).
    pub recovery_pending: bool,
}

impl RankState {
    /// Build the initial state of `rank` (placement, octree, RNG
    /// streams) under the partition `cfg` describes (uniform by
    /// default, skewed when `balance.init_cells` says so).
    pub fn init(cfg: &SimConfig, comm: &impl Comm) -> RankState {
        let partition = Partition::from_config(cfg).expect("config was validated");
        Self::init_with_partition(cfg, partition, comm)
    }

    /// `init` under an explicit (pre-validated) partition.
    pub fn init_with_partition(
        cfg: &SimConfig,
        partition: Partition,
        comm: &impl Comm,
    ) -> RankState {
        let rank = comm.rank();
        let owners = partition.ownership();
        let decomp = partition.decomposition(cfg.domain_size);
        let root = Rng::new(cfg.seed);
        let mut rng_model = root.fork(1_000 + rank as u64);
        let rng_conn = root.fork(2_000 + rank as u64);
        let rng_spikes = root.fork(3_000 + rank as u64);

        // One contiguous id block per owned Morton cell — the
        // cell ↔ id-block alignment the migration protocol relies on.
        let cells: Vec<((crate::util::Vec3, crate::util::Vec3), u64)> = partition
            .cells_of_rank(rank)
            .map(|c| (decomp.cell_bounds(c), partition.cell_counts[c]))
            .collect();
        let pop =
            Population::init_in_cells(cfg, owners.first_id(rank), &cells, &mut rng_model);
        let tree = Octree::build(&decomp, rank, pop.first_id, &pop.positions);
        let n = pop.len();
        let mut state = RankState {
            pop,
            store: SynapseStore::with_owners(n, owners.clone()),
            tree,
            partition,
            owners,
            decomp,
            migrations: 0,
            id_exchange: IdExchange::new(comm.size()),
            freq_exchange: FrequencyExchange::new(cfg.delta, rng_spikes),
            plan: DeliveryPlan::default(),
            plan_rebuilds: 0,
            cache: RemoteNodeCache::default(),
            rng_model,
            rng_conn,
            timers: PhaseTimers::new(),
            formation: FormationStats::default(),
            deletion: DeletionStats::default(),
            spike_lookups: 0,
            calcium_trace: Vec::new(),
            bh_scratch: FormationScratch::default(),
            vac_scratch: VacancyScratch::default(),
            baseline_comm: CounterSnapshot::default(),
            spikes_fired: 0,
            tracer: Tracer::from_config(cfg),
            kernel: make_kernel(cfg, None),
            kernel_blocks: 0,
            recovery_pending: false,
        };
        state.rebuild_plan();
        let baseline = state.trace_cumulative(comm);
        state.tracer.prime(&baseline);
        state
    }

    /// Recompile the delivery plan from the current store and re-align
    /// the frequency exchange's slot thresholds with the new slot
    /// table. Runs at init, on restore, and after any plasticity phase
    /// whose deletions/formations touched the in-edge set.
    fn rebuild_plan(&mut self) {
        self.plan = DeliveryPlan::compile(&self.store, self.pop.first_id);
        self.plan_rebuilds += 1;
        self.freq_exchange.install_slots(&self.plan);
        debug_assert_eq!(self.plan.check_against(&self.store), Ok(()));
    }

    /// Capture this rank's complete state as an encoded snapshot
    /// section (see `snapshot::format`). Read-only: capturing must not
    /// perturb the simulation, so a checkpointed run stays bit-identical
    /// to an unchekpointed one. The octree is not captured — `restore`
    /// rebuilds it from the (immutable) positions — and neither is the
    /// delivery plan (recompiled from the stored edge lists). The
    /// frequency entries are encoded straight from the exchange's
    /// borrowing iterator: this runs inside the step loop, so the
    /// writer path allocates no per-capture entry `Vec`.
    pub fn capture(&self, comm: &impl Comm) -> Vec<u8> {
        RankSection {
            first_id: self.pop.first_id,
            positions: self.pop.positions.clone(),
            is_excitatory: self.pop.is_excitatory.clone(),
            v: self.pop.v.clone(),
            u: self.pop.u.clone(),
            ca: self.pop.ca.clone(),
            z_ax: self.pop.z_ax.clone(),
            z_den_exc: self.pop.z_den_exc.clone(),
            z_den_inh: self.pop.z_den_inh.clone(),
            i_syn: self.pop.i_syn.clone(),
            noise: self.pop.noise.clone(),
            fired: self.pop.fired.clone(),
            epoch_spikes: self.pop.epoch_spikes.clone(),
            out_edges: self.store.out_edges.clone(),
            in_edges: self
                .store
                .in_edges
                .iter()
                .map(|edges| edges.iter().map(|e| (e.source, e.source_exc)).collect())
                .collect(),
            connected_ax: self.store.connected_ax.clone(),
            connected_den_exc: self.store.connected_den_exc.clone(),
            connected_den_inh: self.store.connected_den_inh.clone(),
            rng_model: self.rng_model.state(),
            rng_conn: self.rng_conn.state(),
            rng_spikes: self.freq_exchange.rng_state(),
            freq_entries: Vec::new(), // encoded from the iterator below
            baseline_comm: self.baseline_comm.merge(&comm.counters().snapshot()),
            spike_lookups: self.spike_lookups,
            deletion: self.deletion,
            formation: self.formation,
            calcium_trace: self
                .calcium_trace
                .iter()
                .map(|(step, cas)| (*step as u64, cas.clone()))
                .collect(),
        }
        .encode_with_freqs(self.freq_exchange.entries_iter())
    }

    /// Rebuild a rank's state from a validated snapshot, bit-exactly:
    /// stepping the restored state continues the exact trajectory of
    /// the run that wrote the snapshot. The caller validates the
    /// snapshot against `cfg` first (`Snapshot::validate_for`, or
    /// `validate_for_branch` when deliberately forking a scenario).
    pub fn restore(
        cfg: &SimConfig,
        comm: &impl Comm,
        snap: &Snapshot,
    ) -> Result<RankState, String> {
        let partition = snap.partition_for_resume();
        partition
            .validate(cfg.ranks, cfg.total_neurons() as u64)
            .map_err(|e| format!("snapshot partition does not fit the config: {e}"))?;
        let owners = partition.ownership();
        let sec = load_validated_section(cfg, &owners, snap, comm.rank())?;
        RankState::restore_section(cfg, partition, comm, sec)
    }

    /// `restore` from an already decoded and validated section (see
    /// `load_validated_section`), under the snapshot's partition.
    fn restore_section(
        cfg: &SimConfig,
        partition: Partition,
        comm: &impl Comm,
        sec: RankSection,
    ) -> Result<RankState, String> {
        let rank = comm.rank();
        let owners = partition.ownership();
        let decomp = partition.decomposition(cfg.domain_size);
        let pop = Population {
            first_id: sec.first_id,
            positions: sec.positions,
            is_excitatory: sec.is_excitatory,
            v: sec.v,
            u: sec.u,
            ca: sec.ca,
            z_ax: sec.z_ax,
            z_den_exc: sec.z_den_exc,
            z_den_inh: sec.z_den_inh,
            i_syn: sec.i_syn,
            noise: sec.noise,
            fired: sec.fired,
            epoch_spikes: sec.epoch_spikes,
        };
        // Edge-list/counter consistency and id bounds were verified by
        // `load_validated_section` before any state is built here;
        // `from_parts` rebuilds the derived routing table and partner
        // refcounts from the edge lists deterministically.
        let store = SynapseStore::from_parts(
            sec.out_edges,
            sec.in_edges
                .into_iter()
                .map(|edges| {
                    edges
                        .into_iter()
                        .map(|(source, source_exc)| InEdge { source, source_exc })
                        .collect()
                })
                .collect(),
            sec.connected_ax,
            sec.connected_den_exc,
            sec.connected_den_inh,
            owners.clone(),
        );
        // The octree is structural over the (immutable) positions;
        // rebuilding it reproduces the exact arena the original run had,
        // and its aggregates are recomputed from scratch at every
        // plasticity phase anyway.
        let tree = Octree::build(&decomp, rank, pop.first_id, &pop.positions);
        let freq_exchange =
            FrequencyExchange::from_parts(cfg.delta, sec.freq_entries, sec.rng_spikes)
                .map_err(|e| format!("rank {rank}: {e}"))?;
        let mut state = RankState {
            pop,
            store,
            tree,
            partition,
            owners,
            decomp,
            migrations: 0,
            id_exchange: IdExchange::new(comm.size()),
            freq_exchange,
            plan: DeliveryPlan::default(),
            plan_rebuilds: 0,
            cache: RemoteNodeCache::default(),
            rng_model: Rng::from_state(sec.rng_model),
            rng_conn: Rng::from_state(sec.rng_conn),
            timers: PhaseTimers::new(),
            formation: sec.formation,
            deletion: sec.deletion,
            spike_lookups: sec.spike_lookups,
            calcium_trace: sec
                .calcium_trace
                .into_iter()
                .map(|(step, cas)| (step as usize, cas))
                .collect(),
            bh_scratch: FormationScratch::default(),
            vac_scratch: VacancyScratch::default(),
            baseline_comm: sec.baseline_comm,
            spikes_fired: 0,
            tracer: Tracer::from_config(cfg),
            kernel: make_kernel(cfg, None),
            kernel_blocks: 0,
            recovery_pending: false,
        };
        // The plan is derived state: never read from the snapshot,
        // always recompiled from the restored store (and the slot
        // thresholds re-derived from the restored frequency entries).
        state.rebuild_plan();
        // Priming after the recompile keeps the restore-time rebuild
        // (and the restored cumulative stats) out of the first trace
        // window: a resumed segment's samples line up delta-for-delta
        // with the straight run's.
        let baseline = state.trace_cumulative(comm);
        state.tracer.prime(&baseline);
        Ok(state)
    }

    /// Phase A: spike transmission (previous step's spikes / last epoch's
    /// frequencies) + input assembly. Delivery runs through the
    /// epoch-compiled [`DeliveryPlan`] — branch-light sequential reads
    /// with O(1) slot lookups instead of per-edge division + search
    /// (EXPERIMENTS.md §Perf, opt 8; the naive loop survives as the
    /// differential-test oracle in `spikes`).
    pub fn spike_phase(&mut self, cfg: &SimConfig, comm: &impl Comm, step: usize) {
        debug_assert!(
            self.plan.is_current(&self.store),
            "delivery plan not rebuilt after an in-edge edit"
        );
        match cfg.spike_alg {
            SpikeAlg::OldIds => {
                let (pop, store, ex) = (&mut self.pop, &self.store, &mut self.id_exchange);
                self.timers.time(Phase::SpikeExchange, || ex.exchange(comm, pop, store));
                let (pop, plan, ex) = (&mut self.pop, &self.plan, &mut self.id_exchange);
                self.spike_lookups += self.timers.time(Phase::SpikeLookup, || {
                    ex.scatter_slots(plan);
                    plan.deliver(pop, |slot| ex.slot_fired(slot))
                });
            }
            SpikeAlg::NewFrequency => {
                let (pop, store, ex) = (&mut self.pop, &self.store, &mut self.freq_exchange);
                let plan = &self.plan;
                self.timers.time(Phase::SpikeExchange, || {
                    if ex.maybe_exchange(comm, pop, store, step) {
                        // Fresh epoch table: re-align the slot-indexed
                        // Bernoulli thresholds with the (unchanged)
                        // slot interning.
                        ex.install_slots(plan);
                    }
                });
                let (pop, ex) = (&mut self.pop, &mut self.freq_exchange);
                self.spike_lookups += self.timers.time(Phase::SpikeLookup, || {
                    plan.deliver(pop, |slot| ex.spiked_slot(slot))
                });
            }
        }
    }

    /// Phase B: background noise + the fused neuron/element update,
    /// dispatched through the rank's [`NeuronKernel`] backend (scalar
    /// oracle, cache-blocked, or the XLA staged path — bit-identical by
    /// the kernel contract, so backend choice never moves the
    /// trajectory).
    pub fn activity_phase(&mut self, cfg: &SimConfig) -> Result<()> {
        let t0 = Instant::now();
        self.pop.draw_noise(cfg, &mut self.rng_model);
        self.kernel.step(&mut self.pop, cfg, &mut self.rng_model)?;
        self.kernel_blocks += blocks_per_step(self.pop.len());
        self.timers.add(Phase::ActivityUpdate, t0.elapsed());
        Ok(())
    }

    /// Phase C: the connectivity update — deletion, octree refresh (incl.
    /// branch all-to-all and, for the old algorithm, RMA-window publish),
    /// then formation with the configured algorithm.
    pub fn plasticity_phase(&mut self, cfg: &SimConfig, comm: &impl Comm) {
        // C1: deletion, routed through the ownership map (the stride
        // fast path when no migration ever happened).
        let owners = self.owners.clone();
        let (pop, store, rng) = (&self.pop, &mut self.store, &mut self.rng_conn);
        let dstats = self.timers.time(Phase::DeleteSynapses, || {
            run_deletion_phase(comm, pop, store, rng, |id| owners.rank_of(id) as usize)
        });
        self.deletion.axonal_retractions += dstats.axonal_retractions;
        self.deletion.dendritic_retractions += dstats.dendritic_retractions;
        self.deletion.notifications_sent += dstats.notifications_sent;

        // C1.5: spike-state maintenance, BEFORE formation. Deletion may
        // have removed a source's last in-edge on this rank; its
        // epoch-scoped frequency entry must die here so that an edge
        // re-formed from the same source — whether by this phase's C3
        // below or any later one — reconstructs against 0.0, never the
        // dead edge's last reported frequency. (Pruning after C3 would
        // silently keep the entry alive through a same-phase
        // delete-and-reform.) No-op under `SpikeAlg::OldIds`.
        self.freq_exchange.prune_stale(&self.store);

        // C2: octree vacancy update + branch exchange (+ window publish
        // for the old algorithm's RMA path). The vacancy buffers are
        // driver-held scratch, fully rewritten here each phase instead
        // of two fresh n-element allocations per connectivity update
        // (EXPERIMENTS.md §Perf, opt 8 satellite).
        let t0 = Instant::now();
        let n = self.pop.len();
        let vac = &mut self.vac_scratch;
        vac.exc.clear();
        vac.exc.extend(
            (0..n).map(|i| vacant(self.pop.z_den_exc[i], self.store.connected_den_exc[i]) as f32),
        );
        vac.inh.clear();
        vac.inh.extend(
            (0..n).map(|i| vacant(self.pop.z_den_inh[i], self.store.connected_den_inh[i]) as f32),
        );
        self.tree.reset_and_set_leaves(self.pop.first_id, &vac.exc, &vac.inh);
        self.tree.aggregate_local();

        let own_cells = self.decomp.cells_of_rank(comm.rank());
        let payloads = if cfg.connectivity_alg == ConnectivityAlg::OldRma {
            let win = serialize_local_subtrees(&self.tree, own_cells.clone());
            comm.publish_window(OCTREE_WINDOW, win.bytes);
            self.tree.own_branch_payloads(own_cells, |c| win.root_of_cell[&c])
        } else {
            self.tree.own_branch_payloads(own_cells, |_| NO_CHILD)
        };
        let all = gather_all(comm, &payloads);
        for (src, batch) in all.iter().enumerate() {
            if src != comm.rank() {
                self.tree.apply_branch_payloads(batch);
            }
        }
        self.tree.aggregate_upper();
        self.tree.normalize();
        self.timers.add(Phase::OctreeUpdate, t0.elapsed());

        // C3: formation.
        let fstats = match cfg.connectivity_alg {
            ConnectivityAlg::OldRma => barnes_hut::old::run_formation(
                comm,
                &self.tree,
                &self.pop,
                &mut self.store,
                &mut self.cache,
                cfg,
                &self.owners,
                &mut self.rng_conn,
            ),
            ConnectivityAlg::NewLocationAware => barnes_hut::new::run_formation(
                comm,
                &self.tree,
                &self.pop,
                &mut self.store,
                cfg,
                &mut self.rng_conn,
                &mut self.bh_scratch,
            ),
            ConnectivityAlg::Direct => barnes_hut::direct::run_formation(
                comm,
                &self.pop,
                &mut self.store,
                cfg,
                &self.owners,
                &mut self.rng_conn,
            ),
        };
        self.timers.add(Phase::BarnesHut, Duration::from_nanos(fstats.compute_nanos));
        self.timers.add(Phase::SynapseExchange, Duration::from_nanos(fstats.exchange_nanos));
        self.formation = self.formation.merge(&fstats);

        // C4: recompile the delivery plan iff this phase's deletions or
        // formations edited the in-edge set (the store's edit sites
        // marked it dirty via the in-edge generation). The recompile
        // also re-aligns the frequency exchange's slot thresholds with
        // the new slot table, covering any entries C1.5 pruned.
        if !self.plan.is_current(&self.store) {
            self.rebuild_plan();
        }
    }

    /// One full simulation step.
    pub fn step(&mut self, cfg: &SimConfig, comm: &impl Comm, step: usize) -> Result<()> {
        self.spike_phase(cfg, comm, step);
        self.activity_phase(cfg)?;
        if self.tracer.enabled() {
            self.spikes_fired += self.pop.fired.iter().filter(|&&f| f).count() as u64;
        }
        if (step + 1) % cfg.plasticity_interval == 0 {
            self.plasticity_phase(cfg, comm);
            // Balance epochs piggyback on connectivity updates (the
            // config validates the divisibility), so migration always
            // sees a freshly recompiled, cross-validated world.
            if cfg.balance_every > 0 && (step + 1) % cfg.balance_every == 0 {
                self.rebalance_phase(cfg, comm);
            }
        }
        if cfg.record_calcium_every > 0 && step % cfg.record_calcium_every == 0 {
            self.calcium_trace.push((step, self.pop.ca.clone()));
        }
        if self.tracer.due(step) {
            let mut boundaries = Self::epoch_boundaries(cfg, step);
            if self.recovery_pending {
                boundaries |= crate::trace::RECOVERY_EPOCH;
                self.recovery_pending = false;
            }
            let now = self.trace_cumulative(comm);
            let cost = self.measure_cost();
            self.tracer.record(step as u64 + 1, boundaries, &now, cost);
        }
        Ok(())
    }

    /// Which epoch kinds the boundary after `step` coincides with — a
    /// pure function of step and config, so it is deterministic. The
    /// tracer ORs in `RECOVERY_EPOCH` separately (that bit is segment
    /// state, not schedule); heartbeats reuse the schedule bits as-is.
    fn epoch_boundaries(cfg: &SimConfig, step: usize) -> u8 {
        let mut boundaries = 0u8;
        if (step + 1) % cfg.delta == 0 {
            boundaries |= crate::trace::SPIKE_EPOCH;
        }
        if (step + 1) % cfg.plasticity_interval == 0 {
            boundaries |= crate::trace::PLASTICITY_EPOCH;
        }
        if cfg.balance_every > 0 && (step + 1) % cfg.balance_every == 0 {
            boundaries |= crate::trace::BALANCE_EPOCH;
        }
        boundaries
    }

    /// The cumulative readings the tracer deltas consecutive samples
    /// against. Uses the segment-local communicator snapshot (NOT the
    /// pre-resume baseline): trace windows are segment-scoped, which is
    /// what makes a resumed run's samples concatenate exactly onto the
    /// pre-checkpoint run's.
    fn trace_cumulative(&self, comm: &impl Comm) -> Cumulative {
        Cumulative {
            phase_seconds: self.timers.seconds(),
            comm: comm.counters().snapshot(),
            spikes: self.spikes_fired,
            formed: self.formation.formed,
            retractions: self.deletion.axonal_retractions + self.deletion.dendritic_retractions,
            plan_rebuilds: self.plan_rebuilds,
            migrations: self.migrations,
        }
    }

    /// The per-rank load measurement the balance decision gathers.
    pub fn measure_cost(&self) -> RankCost {
        RankCost {
            neurons: self.pop.len() as u64,
            local_edges: (self.store.total_in() + self.store.total_out()) as u64,
            remote_partners: self.plan.slot_count() as u64,
            nanos: self.timers.total().as_nanos() as u64,
        }
    }

    /// One balance epoch: gather every rank's cost, run the (identical,
    /// deterministic) decision, and migrate if it says so. Collective —
    /// every rank must call this at the same step.
    fn rebalance_phase(&mut self, cfg: &SimConfig, comm: &impl Comm) {
        let all = gather_all(comm, &[self.measure_cost()]);
        let costs: Vec<RankCost> = all.iter().map(|batch| batch[0]).collect();
        if let Some(new_part) = plan_rebalance(
            &self.partition,
            &costs,
            cfg.balance_threshold,
            cfg.balance_max_moves,
        ) {
            self.apply_partition(cfg, comm, new_part);
        }
    }

    /// Execute a migration: pack every locally-owned neuron whose new
    /// owner differs, all-to-all the batches (counted traffic — moving
    /// computation is communication), and rebuild population, store,
    /// octree, exchange state, and delivery plan under the new
    /// ownership. `SynapseStore::check_invariants` and
    /// `DeliveryPlan::check_against` are hard-checked after every
    /// migration (not just in debug builds).
    fn apply_partition(&mut self, cfg: &SimConfig, comm: &impl Comm, new_part: Partition) {
        let me = comm.rank();
        let size = comm.size();
        let new_owners = new_part.ownership();

        // Pack departures (and the frequency entries their in-edge
        // sources have installed, so mid-epoch reconstruction continues
        // seamlessly on the new owner). Deliberately O(local neurons):
        // every record is built and the whole SoA world rebuilt below,
        // even though only boundary-cell movers cross the wire — same
        // ground-truth-rebuild philosophy as snapshot restore. At one
        // migration per balance epoch (hundreds of steps) the O(n)
        // repack is noise next to a single plasticity phase; splicing
        // contiguous keeper ranges in place would save copies at a real
        // complexity/bug cost and is left until a profile demands it.
        let mut batches: Vec<MigrationBatch> =
            (0..size).map(|_| MigrationBatch::default()).collect();
        let mut freq_sets: Vec<std::collections::BTreeMap<u64, f32>> =
            (0..size).map(|_| Default::default()).collect();
        let mut records: Vec<NeuronRecord> = Vec::new();
        for local in 0..self.pop.len() {
            let id = self.pop.first_id + local as u64;
            let rec = NeuronRecord {
                id,
                pos: self.pop.positions[local],
                is_excitatory: self.pop.is_excitatory[local],
                v: self.pop.v[local],
                u: self.pop.u[local],
                ca: self.pop.ca[local],
                z_ax: self.pop.z_ax[local],
                z_den_exc: self.pop.z_den_exc[local],
                z_den_inh: self.pop.z_den_inh[local],
                i_syn: self.pop.i_syn[local],
                noise: self.pop.noise[local],
                fired: self.pop.fired[local],
                epoch_spikes: self.pop.epoch_spikes[local],
                out_edges: self.store.out_edges[local].clone(),
                in_edges: self.store.in_edges[local]
                    .iter()
                    .map(|e| (e.source, e.source_exc))
                    .collect(),
            };
            let dest = new_owners.rank_of(id) as usize;
            if dest == me {
                records.push(rec);
            } else {
                for e in &self.store.in_edges[local] {
                    if let Some(f) = self.freq_exchange.entry_of(e.source) {
                        freq_sets[dest].insert(e.source, f);
                    }
                }
                batches[dest].records.push(rec);
            }
        }
        for (dest, set) in freq_sets.into_iter().enumerate() {
            batches[dest].freq_entries = set.into_iter().collect();
        }

        // Ship through the counted all-to-all.
        let sends: Vec<Vec<u8>> = batches
            .iter()
            .enumerate()
            .map(|(d, b)| if d == me || b.is_empty() { Vec::new() } else { b.encode() })
            .collect();
        let recvs = comm.all_to_all(sends);
        let mut incoming_freqs: Vec<(u64, f32)> = Vec::new();
        for (src, buf) in recvs.iter().enumerate() {
            if src == me || buf.is_empty() {
                continue;
            }
            let batch = MigrationBatch::decode(buf)
                .unwrap_or_else(|e| panic!("rank {me}: malformed migration batch: {e}"));
            records.extend(batch.records);
            incoming_freqs.extend(batch.freq_entries);
        }

        // The kept + received records must tile the new contiguous id
        // range exactly.
        records.sort_unstable_by_key(|r| r.id);
        let first = new_owners.first_id(me);
        let count = new_owners.count(me) as usize;
        assert_eq!(records.len(), count, "rank {me}: migration lost or duplicated neurons");
        for (i, r) in records.iter().enumerate() {
            assert_eq!(r.id, first + i as u64, "rank {me}: migrated range not contiguous");
        }

        // Rebuild the population (SoA) and store from ground truth.
        let n = records.len();
        let mut positions = Vec::with_capacity(n);
        let mut is_excitatory = Vec::with_capacity(n);
        let mut v = Vec::with_capacity(n);
        let mut u = Vec::with_capacity(n);
        let mut ca = Vec::with_capacity(n);
        let mut z_ax = Vec::with_capacity(n);
        let mut z_den_exc = Vec::with_capacity(n);
        let mut z_den_inh = Vec::with_capacity(n);
        let mut i_syn = Vec::with_capacity(n);
        let mut noise = Vec::with_capacity(n);
        let mut fired = Vec::with_capacity(n);
        let mut epoch_spikes = Vec::with_capacity(n);
        let mut out_edges = Vec::with_capacity(n);
        let mut in_edges: Vec<Vec<InEdge>> = Vec::with_capacity(n);
        let mut connected_ax = Vec::with_capacity(n);
        let mut connected_den_exc = Vec::with_capacity(n);
        let mut connected_den_inh = Vec::with_capacity(n);
        for r in records {
            positions.push(r.pos);
            is_excitatory.push(r.is_excitatory);
            v.push(r.v);
            u.push(r.u);
            ca.push(r.ca);
            z_ax.push(r.z_ax);
            z_den_exc.push(r.z_den_exc);
            z_den_inh.push(r.z_den_inh);
            i_syn.push(r.i_syn);
            noise.push(r.noise);
            fired.push(r.fired);
            epoch_spikes.push(r.epoch_spikes);
            connected_ax.push(r.out_edges.len() as u32);
            let exc = r.in_edges.iter().filter(|&&(_, e)| e).count() as u32;
            connected_den_exc.push(exc);
            connected_den_inh.push(r.in_edges.len() as u32 - exc);
            out_edges.push(r.out_edges);
            in_edges.push(
                r.in_edges
                    .into_iter()
                    .map(|(source, source_exc)| InEdge { source, source_exc })
                    .collect(),
            );
        }
        let pop = Population {
            first_id: first,
            positions,
            is_excitatory,
            v,
            u,
            ca,
            z_ax,
            z_den_exc,
            z_den_inh,
            i_syn,
            noise,
            fired,
            epoch_spikes,
        };
        let store = SynapseStore::from_parts(
            out_edges,
            in_edges,
            connected_ax,
            connected_den_exc,
            connected_den_inh,
            new_owners.clone(),
        );
        store
            .check_invariants()
            .unwrap_or_else(|e| panic!("rank {me}: store invariants after migration: {e}"));

        // Merge the frequency table: surviving own entries + the
        // entries that traveled with arriving neurons. Entries whose
        // source became local are kept — never read (the plan treats
        // local edges through fired flags) and replaced wholesale at
        // the next epoch boundary — so a migrate-back restores the
        // exact table. Conflicting ids must agree: both copies came
        // from the same sender's same epoch report.
        if !incoming_freqs.is_empty() || self.freq_exchange.partner_count() > 0 {
            let mut merged: std::collections::BTreeMap<u64, f32> =
                self.freq_exchange.entries_iter().collect();
            for (id, f) in incoming_freqs {
                if let Some(prev) = merged.insert(id, f) {
                    debug_assert_eq!(
                        prev.to_bits(),
                        f.to_bits(),
                        "ranks disagree on source {id}'s epoch frequency"
                    );
                }
            }
            self.freq_exchange = FrequencyExchange::from_parts(
                cfg.delta,
                merged.into_iter().collect(),
                self.freq_exchange.rng_state(),
            )
            .expect("BTreeMap iteration is ascending");
        }

        // Install the new ownership world; rebuild all derived state.
        self.pop = pop;
        self.store = store;
        self.owners = new_owners;
        self.partition = new_part;
        self.decomp = self.partition.decomposition(cfg.domain_size);
        self.tree = Octree::build(&self.decomp, me, first, &self.pop.positions);
        self.id_exchange = IdExchange::new(size);
        self.freq_exchange.prune_stale(&self.store);
        self.rebuild_plan();
        self.plan
            .check_against(&self.store)
            .unwrap_or_else(|e| panic!("rank {me}: plan cross-validation after migration: {e}"));
        self.migrations += 1;
    }

    /// Assemble this rank's final report. Restored states add their
    /// pre-resume communication baseline so the totals equal a straight
    /// run's.
    pub fn into_report(self, comm: &impl Comm) -> RankReport {
        // `into_samples` drains the ring, so count evictions first:
        // everything recorded that is no longer in the ring was dropped.
        let recorded = self.tracer.recorded();
        let trace = self.tracer.into_samples();
        let trace_dropped = recorded - trace.len() as u64;
        RankReport {
            rank: comm.rank(),
            phase_seconds: self.timers.seconds(),
            comm: self.baseline_comm.merge(&comm.counters().snapshot()),
            formation: self.formation,
            deletion: self.deletion,
            spike_lookups: self.spike_lookups,
            spike_state_bytes: self.freq_exchange.state_bytes(),
            plan_rebuilds: self.plan_rebuilds,
            synapses_out: self.store.total_out(),
            synapses_in: self.store.total_in(),
            neurons: self.pop.len(),
            local_edges: (self.store.total_in() + self.store.total_out()) as u64,
            remote_partners: self.plan.slot_count() as u64,
            migrations: self.migrations,
            kernel_blocks: self.kernel_blocks,
            recoveries: 0,
            mean_calcium: self.pop.mean_calcium(),
            calcium_trace: self.calcium_trace,
            trace,
            trace_dropped,
            comm_hists: comm.comm_hists(),
        }
    }
}

/// Run a full simulation with the native backend (or whatever the config
/// says, if an XLA handle is supplied via `run_simulation_with_xla`).
pub fn run_simulation(cfg: &SimConfig) -> Result<SimReport> {
    run_simulation_with_xla(cfg, None)
}

/// Run a full simulation; `xla` supplies the shared artifact executor
/// when `cfg.backend == Backend::Xla`. With `cfg.checkpoint_every > 0`
/// a resumable snapshot is written to `cfg.checkpoint_dir` every that
/// many steps (see the `snapshot` module).
pub fn run_simulation_with_xla(cfg: &SimConfig, xla: Option<XlaHandle>) -> Result<SimReport> {
    run_simulation_inner(cfg, xla, None, false)
}

/// Resume a simulation from a snapshot, bit-exactly: steps
/// `snap.next_step()..cfg.steps` continue the exact trajectory of the
/// run that wrote the snapshot (`cfg.steps` is always the TOTAL
/// schedule length, not an increment). The config must match the
/// snapshot's fingerprint.
pub fn resume_simulation(cfg: &SimConfig, snap: &Snapshot) -> Result<SimReport> {
    run_simulation_inner(cfg, None, Some(snap), false)
}

/// `resume_simulation` with an XLA executor handle.
pub fn resume_simulation_with_xla(
    cfg: &SimConfig,
    snap: &Snapshot,
    xla: Option<XlaHandle>,
) -> Result<SimReport> {
    run_simulation_inner(cfg, xla, Some(snap), false)
}

/// Fork a new *scenario* from a snapshot: like `resume_simulation`, but
/// only the structural compatibility of the state is enforced — the
/// dynamics config (background input, model parameters, algorithms,
/// seed) may deliberately differ from the run that wrote the snapshot.
/// Same brain, different protocol.
pub fn branch_simulation(cfg: &SimConfig, snap: &Snapshot) -> Result<SimReport> {
    branch_simulation_with_xla(cfg, snap, None)
}

/// `branch_simulation` with an XLA executor handle.
pub fn branch_simulation_with_xla(
    cfg: &SimConfig,
    snap: &Snapshot,
    xla: Option<XlaHandle>,
) -> Result<SimReport> {
    run_simulation_inner(cfg, xla, Some(snap), true)
}

/// Decode and fully validate one rank's snapshot section: framing
/// (via `RankSection::decode`), the expected id range, edge-list
/// consistency and id bounds, and the sparse frequency entries
/// (strictly ascending, in-range ids). After this passes,
/// `RankState::restore_section` cannot fail on the same data.
fn load_validated_section(
    cfg: &SimConfig,
    owners: &OwnershipMap,
    snap: &Snapshot,
    rank: usize,
) -> Result<RankSection, String> {
    let sec = snap.section(rank)?;
    let expect_first = owners.first_id(rank);
    if sec.first_id != expect_first {
        return Err(format!(
            "rank {rank}: snapshot section starts at neuron {} (expected {expect_first})",
            sec.first_id
        ));
    }
    sec.check_synapse_consistency(cfg.total_neurons() as u64)
        .map_err(|e| format!("rank {rank}: {e}"))?;
    sec.check_freq_entries(cfg.total_neurons() as u64)
        .map_err(|e| format!("rank {rank}: {e}"))?;
    Ok(sec)
}

/// One rank's full simulation, generic over the comm backend: restore or
/// init, the step loop (with optional checkpoint capture), final report.
/// This is the exact body every rank runs — as a thread over a
/// [`ThreadComm`](crate::comm::ThreadComm) or as a process over a
/// [`SocketComm`](crate::comm::SocketComm) — so the two backends cannot
/// drift apart in what they simulate.
fn simulate_rank<C: Comm>(
    cfg: &SimConfig,
    partition: Partition,
    comm: &C,
    preloaded: Option<RankSection>,
    sink: Option<&dyn SectionSink>,
    start_step: usize,
    recovered: bool,
    xla: Option<&XlaHandle>,
) -> Result<RankReport> {
    let mut state = match preloaded {
        Some(sec) => RankState::restore_section(cfg, partition, comm, sec)
            .map_err(anyhow::Error::msg)?,
        None => RankState::init_with_partition(cfg, partition, comm),
    };
    // The constructors build the handle-less kernel; re-dispatch with
    // the run's XLA handle (if any) so `backend/kernel = xla` selects
    // the staged path. Trajectories are kernel-independent, so this is
    // safe after restore too.
    state.kernel = make_kernel(cfg, xla);
    state.recovery_pending = recovered;
    // Telemetry (no-op unless armed in this process): one forced beat
    // before the loop so the supervisor's watchdog covers this rank
    // even if the very first step hangs, then one candidate beat per
    // completed step (the cadence filter lives in `maybe_beat`).
    crate::telemetry::maybe_beat(start_step as u64, 0, true, || {
        (state.timers.seconds(), comm.counters().snapshot())
    });
    for step in start_step..cfg.steps {
        // Injected-kill hook (no-op unless a fault plan is armed in
        // this process): "kill rank R at step S" means R's process
        // exits immediately before executing 0-based step S.
        crate::fault::on_step(step as u64);
        state.step(cfg, comm, step)?;
        crate::telemetry::maybe_beat(
            step as u64 + 1,
            RankState::epoch_boundaries(cfg, step),
            false,
            || (state.timers.seconds(), comm.counters().snapshot()),
        );
        if let Some(sink) = sink {
            if (step + 1) % cfg.checkpoint_every == 0 {
                // Checkpoint I/O failures are recorded, not returned:
                // erroring out of one rank's loop would deadlock the
                // others at the next barrier. The first failure is
                // surfaced after the join in `run_simulation_inner`.
                sink.deposit_nonfatal(
                    step as u64 + 1,
                    comm.rank(),
                    state.capture(comm),
                    &state.partition,
                );
            }
        }
    }
    Ok(state.into_report(comm))
}

/// The registry name of the per-rank simulation entry a socket child runs.
#[cfg(unix)]
pub const SIMULATE_ENTRY: &str = "simulate";

/// The socket-child entry registry the `ilmi` binary (and any test
/// harness that launches socket simulations) hands to
/// [`crate::comm::proc::maybe_run_child`].
#[cfg(unix)]
pub const SOCKET_ENTRIES: &[(&str, crate::comm::proc::Entry)] =
    &[(SIMULATE_ENTRY, simulate_entry as crate::comm::proc::Entry)];

/// Encode the `simulate` entry's argument bytes: the child config INI,
/// the supervision attempt number, and (for restarts) the checkpoint
/// file every rank resumes from.
#[cfg(unix)]
fn encode_simulate_args(ini: &str, attempt: u32, resume: Option<&std::path::Path>) -> Vec<u8> {
    use crate::util::wire::{put_u8, put_u32};
    let mut out = Vec::with_capacity(16 + ini.len());
    put_u32(&mut out, ini.len() as u32);
    out.extend_from_slice(ini.as_bytes());
    put_u32(&mut out, attempt);
    match resume {
        None => put_u8(&mut out, 0),
        Some(path) => {
            let s = path.to_str().expect("checkpoint paths are UTF-8");
            put_u8(&mut out, 1);
            put_u32(&mut out, s.len() as u32);
            out.extend_from_slice(s.as_bytes());
        }
    }
    out
}

#[cfg(unix)]
fn decode_simulate_args(
    args: &[u8],
) -> Result<(SimConfig, u32, Option<std::path::PathBuf>), String> {
    use crate::util::wire::Cursor;
    let mut c = Cursor::new(args, "simulate entry args");
    let ini_len = c.u32("ini length")? as usize;
    let ini = std::str::from_utf8(c.bytes(ini_len, "config ini")?)
        .map_err(|e| format!("entry config not UTF-8: {e}"))?
        .to_string();
    let cfg = SimConfig::from_ini(&ini)?;
    let attempt = c.u32("attempt")?;
    let resume = if c.u8("has resume path")? != 0 {
        let n = c.u32("resume path length")? as usize;
        let s = std::str::from_utf8(c.bytes(n, "resume path")?)
            .map_err(|e| format!("resume path not UTF-8: {e}"))?;
        Some(std::path::PathBuf::from(s))
    } else {
        None
    };
    c.finish("simulate entry args")?;
    Ok((cfg, attempt, resume))
}

/// Child-side body of one socket rank: parse the config + attempt +
/// optional resume checkpoint the launcher shipped, build (or restore)
/// this rank's state, run `simulate_rank` on the process's `SocketComm`
/// — with a [`PartSink`](crate::snapshot::PartSink) when checkpointing,
/// so the fleet's sections assemble into ordinary snapshot files through
/// the shared checkpoint dir — and return the encoded `RankReport`.
#[cfg(unix)]
fn simulate_entry(comm: &crate::comm::SocketComm, args: &[u8]) -> Result<Vec<u8>, String> {
    let (cfg, attempt, resume) = decode_simulate_args(args)?;
    // Child-side guard (the launcher rewrites `comm` to thread before
    // shipping the INI, so `validate`'s socket+xla rejection no longer
    // fires here): a socket child has no XLA executor handle, and
    // silently degrading to the native kernel would misreport what ran.
    if cfg.backend == Backend::Xla || cfg.kernel == crate::config::KernelKind::Xla {
        return Err(
            "socket rank has no XLA executor handle: backend/kernel = xla cannot run \
             over --comm socket (use scalar or blocked)"
                .to_string(),
        );
    }
    let (partition, preloaded, start_step) = match &resume {
        None => (Partition::from_config(&cfg)?, None, 0),
        Some(path) => {
            // Every rank validates the full snapshot independently —
            // cheap at these sizes, and it means a rank never starts
            // from a checkpoint its peers would reject.
            let snap = Snapshot::read_file(path)?;
            snap.validate_for(&cfg)?;
            let partition = snap.partition_for_resume();
            partition
                .validate(cfg.ranks, cfg.total_neurons() as u64)
                .map_err(|e| format!("snapshot partition does not fit the config: {e}"))?;
            let owners = partition.ownership();
            let sec = load_validated_section(&cfg, &owners, &snap, comm.rank())?;
            let start = snap.next_step();
            (partition, Some(sec), start)
        }
    };
    let sink = if cfg.checkpoint_every > 0 {
        Some(crate::snapshot::PartSink::create(&cfg)?)
    } else {
        None
    };
    let report = simulate_rank(
        &cfg,
        partition,
        comm,
        preloaded,
        sink.as_ref().map(|s| s as &dyn SectionSink),
        start_step,
        attempt > 0,
        None,
    )
    .map_err(|e| format!("{e:#}"))?;
    if let Some(sink) = &sink {
        if let Some(e) = sink.first_error() {
            return Err(format!("simulation finished but checkpointing failed: {e}"));
        }
    }
    Ok(report.encode())
}

/// Resume a socket-backend run from an on-disk checkpoint file: the
/// supervisor ships the path to every rank process, which restores and
/// continues bit-exactly (the socket twin of [`resume_simulation`],
/// which takes an in-memory [`Snapshot`] — rank processes can't share
/// one, so the file itself is the interchange).
#[cfg(unix)]
pub fn resume_simulation_socket(
    cfg: &SimConfig,
    snapshot_path: &std::path::Path,
) -> Result<SimReport> {
    cfg.validate().map_err(anyhow::Error::msg)?;
    if cfg.comm_backend != crate::config::CommBackend::Socket {
        bail!("resume_simulation_socket needs topology.comm = socket");
    }
    // Parent-side validation up front, for a good error message before
    // any fleet is spawned (children re-validate independently).
    let snap = Snapshot::read_file(snapshot_path).map_err(anyhow::Error::msg)?;
    let mut child_cfg = cfg.clone();
    child_cfg.comm_backend = crate::config::CommBackend::Thread;
    snap.validate_for(&child_cfg).map_err(anyhow::Error::msg)?;
    run_simulation_socket_from(cfg, Some(snapshot_path.to_path_buf()))
}

/// Orchestrate a socket-backend run: re-exec this binary once per rank
/// (see `comm::proc`), ship the config as INI bytes, and decode the
/// per-rank reports the children send back. The shipped config is
/// rewritten to the thread backend so the child-side parse describes the
/// per-rank body, not this orchestrator — the `comm` key is transport
/// for THIS invocation, never part of the simulated dynamics.
#[cfg(unix)]
fn run_simulation_socket(cfg: &SimConfig) -> Result<SimReport> {
    run_simulation_socket_from(cfg, None)
}

/// The supervised launch loop (DESIGN.md §13). Each iteration launches
/// the full fleet; when the launch fails and `recovery.max_recoveries`
/// allows another attempt, the supervisor backs off, scans
/// `checkpoint_dir` for the newest *fully valid* snapshot (falling back
/// past whatever a dying fleet left truncated), and relaunches every
/// rank from it. `proc::run_entry` already guarantees no partial fleet
/// survives a failed launch (kill + reap + rendezvous-dir removal), so
/// iterations never overlap.
#[cfg(unix)]
fn run_simulation_socket_from(
    cfg: &SimConfig,
    mut resume_path: Option<std::path::PathBuf>,
) -> Result<SimReport> {
    // Children get the thread-backend per-rank body config. The fault
    // plan is stripped (it travels per-attempt via ILMI_FAULT_PLAN, so
    // the INI embedded in snapshots matches a clean run's bytes) and
    // supervision is parent-only; checkpoint knobs stay — children
    // write the part files that assemble into snapshots.
    let mut child_cfg = cfg.clone();
    child_cfg.comm_backend = crate::config::CommBackend::Thread;
    child_cfg.fault_plan = String::new();
    child_cfg.max_recoveries = 0;
    let ini = child_cfg.to_ini();
    let plan = crate::fault::FaultPlan::parse(&cfg.fault_plan).map_err(anyhow::Error::msg)?;
    // Live status aggregation (tentpole d): heartbeats fold into an
    // atomically rewritten status.json for `ilmi status` to render.
    // Parent-only, like supervision — children never see the dir.
    let status: Option<std::cell::RefCell<crate::telemetry::StatusWriter>> =
        if cfg.status_dir.is_empty() {
            None
        } else {
            let dir = std::path::Path::new(&cfg.status_dir);
            std::fs::create_dir_all(dir)
                .map_err(|e| anyhow::Error::msg(format!("creating status dir: {e}")))?;
            Some(std::cell::RefCell::new(crate::telemetry::StatusWriter::new(
                dir,
                cfg.ranks,
                cfg.telemetry_every,
                cfg.telemetry_watchdog_misses,
            )))
        };
    let set_state = |state: &str, attempt: u32, recoveries: u64| {
        if let Some(s) = &status {
            s.borrow_mut().set_state(state, attempt, recoveries as u32);
        }
    };
    let on_beat = |frame: &crate::telemetry::HealthFrame| {
        if let Some(s) = &status {
            s.borrow_mut().on_beat(frame);
        }
    };
    let wall = Instant::now();
    let mut recoveries: u64 = 0;
    let mut lost_steps: u64 = 0;
    let mut recovery_seconds: f64 = 0.0;
    loop {
        let attempt = recoveries as u32;
        let args = encode_simulate_args(&ini, attempt, resume_path.as_deref());
        let attempt_plan = plan.for_attempt(attempt);
        let mut env: Vec<(String, String)> = Vec::new();
        if !attempt_plan.is_empty() {
            env.push((crate::fault::ENV_FAULT_PLAN.to_string(), attempt_plan.to_spec()));
        }
        if cfg.telemetry_every > 0 {
            env.push((
                crate::telemetry::ENV_TELEMETRY_EVERY.to_string(),
                cfg.telemetry_every.to_string(),
            ));
        }
        set_state("running", attempt, recoveries);
        let spec = crate::comm::proc::LaunchSpec {
            entry: SIMULATE_ENTRY,
            ranks: cfg.ranks,
            args: &args,
            timeout: socket_launch_timeout(cfg),
            env: &env,
            watchdog_misses: cfg.telemetry_watchdog_misses,
            on_beat: if cfg.telemetry_every > 0 { Some(&on_beat) } else { None },
        };
        let failure = match crate::comm::proc::run_entry(&spec) {
            Ok(encoded) => {
                let mut ranks = Vec::with_capacity(encoded.len());
                for (rank, bytes) in encoded.iter().enumerate() {
                    let mut report = RankReport::decode(bytes).map_err(|e| {
                        anyhow::Error::msg(format!(
                            "socket rank {rank} returned a malformed report: {e}"
                        ))
                    })?;
                    report.recoveries = recoveries;
                    ranks.push(report);
                }
                set_state("done", attempt, recoveries);
                return Ok(SimReport {
                    ranks,
                    wall_seconds: wall.elapsed().as_secs_f64(),
                    recoveries,
                    lost_steps,
                    recovery_seconds,
                });
            }
            Err(e) => e,
        };
        if cfg.max_recoveries == 0 {
            set_state("failed", attempt, recoveries);
            bail!("socket fleet failed (recovery disabled; set recovery.max_recoveries \
                   and checkpointing to supervise): {failure}");
        }
        if recoveries >= cfg.max_recoveries as u64 {
            set_state("failed", attempt, recoveries);
            bail!(
                "socket fleet failed after {recoveries} recover{}: giving up \
                 (recovery.max_recoveries = {}): {failure}",
                if recoveries == 1 { "y" } else { "ies" },
                cfg.max_recoveries
            );
        }
        let t0 = Instant::now();
        set_state("recovering", attempt, recoveries);
        // Bounded exponential backoff: transient causes (fd pressure,
        // load spikes) get breathing room; the cap keeps a doomed
        // config from stalling for minutes before giving up.
        let backoff = Duration::from_millis((100u64 << recoveries.min(5)).min(5_000));
        std::thread::sleep(backoff);
        let scan = match crate::snapshot::scan_for_recovery(&cfg.checkpoint_dir, &child_cfg) {
            Ok(scan) => scan,
            Err(scan_err) => {
                set_state("failed", attempt, recoveries);
                bail!(
                    "socket fleet failed ({failure}) and no usable checkpoint to recover \
                     from: {scan_err}"
                )
            }
        };
        let resume_step = scan.snapshot.next_step() as u64;
        // Evidence-based lower bound on replayed work: the fleet
        // provably wrote (or started writing) a checkpoint at
        // `newest_step_seen`, and this attempt restarts from
        // `resume_step`. Steps executed after the newest checkpoint
        // left no trace, so the true loss can only be larger.
        lost_steps += scan.newest_step_seen.saturating_sub(resume_step);
        recoveries += 1;
        eprintln!(
            "[recover] socket fleet failed ({failure}); attempt {recoveries}: resuming \
             from {} (step {resume_step})",
            scan.path.display()
        );
        for (path, reason) in &scan.skipped {
            eprintln!("[recover]   skipped {}: {reason}", path.display());
        }
        resume_path = Some(scan.path);
        recovery_seconds += t0.elapsed().as_secs_f64();
    }
}

/// Bound on the whole socket launch (rendezvous + every peer read). The
/// floor covers smoke configs; large schedules scale it so a legitimate
/// long run is not mistaken for a hung fleet.
#[cfg(unix)]
fn socket_launch_timeout(cfg: &SimConfig) -> Duration {
    let budget = 60 + (cfg.steps as u64 * cfg.total_neurons() as u64) / 100_000;
    Duration::from_secs(budget.min(3600))
}

fn run_simulation_inner(
    cfg: &SimConfig,
    xla: Option<XlaHandle>,
    resume: Option<&Snapshot>,
    branch: bool,
) -> Result<SimReport> {
    cfg.validate().map_err(anyhow::Error::msg)?;
    if cfg.comm_backend == crate::config::CommBackend::Socket {
        if resume.is_some() || branch {
            bail!(
                "the socket backend cannot resume from an in-memory snapshot (rank \
                 processes cannot share it); use resume_simulation_socket with the \
                 on-disk checkpoint file, or the thread backend"
            );
        }
        if xla.is_some() {
            bail!("the socket backend does not support an XLA executor handle");
        }
        // validate() already rejects socket + backend/kernel = xla;
        // this is the defense in depth for callers that bypass it.
        if cfg.backend == Backend::Xla || cfg.kernel == crate::config::KernelKind::Xla {
            bail!(
                "the socket backend cannot run backend/kernel = xla: rank processes \
                 cannot share the in-process XLA executor handle (use scalar or blocked)"
            );
        }
        #[cfg(unix)]
        return run_simulation_socket(cfg);
        #[cfg(not(unix))]
        bail!("the socket backend requires Unix domain sockets; use the thread backend");
    }
    // The initial partition: a resumed run inherits the snapshot's
    // (possibly migrated) one; a fresh run builds the config's.
    let partition = match resume {
        Some(snap) => {
            let p = snap.partition_for_resume();
            p.validate(cfg.ranks, cfg.total_neurons() as u64).map_err(anyhow::Error::msg)?;
            p
        }
        None => Partition::from_config(cfg).map_err(anyhow::Error::msg)?,
    };
    let owners = partition.ownership();
    // Decode and validate every rank's section BEFORE spawning rank
    // threads: an error inside one rank's closure would strand the
    // other ranks at their next collective barrier (deadlock) instead
    // of surfacing the decoder's message. Each slot is consumed by its
    // rank inside `run_ranks`.
    let preloaded: Option<Vec<std::sync::Mutex<Option<RankSection>>>> = match resume {
        Some(snap) => {
            let check =
                if branch { snap.validate_for_branch(cfg) } else { snap.validate_for(cfg) };
            check.map_err(anyhow::Error::msg)?;
            let mut slots = Vec::with_capacity(cfg.ranks);
            for rank in 0..cfg.ranks {
                let sec = load_validated_section(cfg, &owners, snap, rank)
                    .map_err(anyhow::Error::msg)?;
                slots.push(std::sync::Mutex::new(Some(sec)));
            }
            Some(slots)
        }
        None => None,
    };
    let sink = if cfg.checkpoint_every > 0 {
        Some(CheckpointSink::create(cfg).map_err(anyhow::Error::msg)?)
    } else {
        None
    };
    let start_step = resume.map_or(0, |s| s.next_step());
    let wall = Instant::now();
    let results: Vec<Result<RankReport>> = run_ranks(cfg.ranks, |comm| {
        let sec = preloaded.as_ref().map(|slots| {
            slots[comm.rank()]
                .lock()
                .unwrap()
                .take()
                .expect("preloaded section consumed exactly once per rank")
        });
        simulate_rank(
            cfg,
            partition.clone(),
            &comm,
            sec,
            sink.as_ref().map(|s| s as &dyn SectionSink),
            start_step,
            false,
            xla.as_ref(),
        )
    });
    let mut ranks = Vec::with_capacity(results.len());
    for r in results {
        ranks.push(r?);
    }
    if let Some(sink) = &sink {
        if let Some(e) = sink.first_error() {
            bail!("simulation finished but checkpointing failed: {e}");
        }
    }
    Ok(SimReport {
        ranks,
        wall_seconds: wall.elapsed().as_secs_f64(),
        recoveries: 0,
        lost_steps: 0,
        recovery_seconds: 0.0,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn smoke_cfg() -> SimConfig {
        SimConfig {
            ranks: 2,
            neurons_per_rank: 32,
            steps: 200,
            plasticity_interval: 50,
            delta: 50,
            ..SimConfig::default()
        }
    }

    #[test]
    fn smoke_new_algorithms() {
        let report = run_simulation(&smoke_cfg()).unwrap();
        assert_eq!(report.ranks.len(), 2);
        // Synapse bookkeeping is globally consistent.
        let out: usize = report.ranks.iter().map(|r| r.synapses_out).sum();
        let inn: usize = report.ranks.iter().map(|r| r.synapses_in).sum();
        assert_eq!(out, inn);
        // With background N(5,1) the network is active and forms synapses.
        assert!(out > 0, "no synapses formed");
        assert!(report.mean_calcium() > 0.0);
        // New algorithm: no RMA at all.
        assert_eq!(report.total_bytes_rma(), 0);
    }

    #[test]
    fn smoke_old_algorithms() {
        let mut cfg = smoke_cfg();
        cfg.connectivity_alg = ConnectivityAlg::OldRma;
        cfg.spike_alg = SpikeAlg::OldIds;
        let report = run_simulation(&cfg).unwrap();
        let out: usize = report.ranks.iter().map(|r| r.synapses_out).sum();
        assert!(out > 0);
        // The old path downloads octree nodes at some point once
        // cross-rank proposals happen.
        assert!(
            report.total_bytes_rma() > 0,
            "old algorithm should use RMA (bytes_rma = 0)"
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = smoke_cfg();
        let a = run_simulation(&cfg).unwrap();
        let b = run_simulation(&cfg).unwrap();
        for (ra, rb) in a.ranks.iter().zip(&b.ranks) {
            assert_eq!(ra.synapses_out, rb.synapses_out);
            assert_eq!(ra.mean_calcium, rb.mean_calcium);
            assert_eq!(ra.comm.bytes_sent, rb.comm.bytes_sent);
        }
    }

    #[test]
    fn plan_rebuilds_are_counted_and_deterministic() {
        let cfg = smoke_cfg();
        let a = run_simulation(&cfg).unwrap();
        let b = run_simulation(&cfg).unwrap();
        let phases = (cfg.steps / cfg.plasticity_interval) as u64;
        for (ra, rb) in a.ranks.iter().zip(&b.ranks) {
            assert!(ra.plan_rebuilds >= 1, "the initial compile is counted");
            assert_eq!(ra.plan_rebuilds, rb.plan_rebuilds, "rebuild count is deterministic");
            assert!(
                ra.plan_rebuilds <= 1 + phases,
                "at most one recompile per plasticity phase (got {})",
                ra.plan_rebuilds
            );
        }
        // An active smoke network forms synapses, so some phase must
        // have dirtied and recompiled the plan somewhere.
        assert!(a.total_plan_rebuilds() > a.ranks.len() as u64);
    }

    #[test]
    fn plan_stays_cross_validated_with_store_through_a_run() {
        // Drive RankStates manually through the full smoke schedule and
        // cross-validate plan against store at the end — for both spike
        // algorithms (the invariant the driver's debug assertions check
        // at every rebuild, verified here in release builds too).
        for (conn, spikes) in [
            (ConnectivityAlg::NewLocationAware, SpikeAlg::NewFrequency),
            (ConnectivityAlg::OldRma, SpikeAlg::OldIds),
        ] {
            let mut cfg = smoke_cfg();
            cfg.connectivity_alg = conn;
            cfg.spike_alg = spikes;
            let results = run_ranks(cfg.ranks, |comm| {
                let mut state = RankState::init(&cfg, &comm);
                for step in 0..cfg.steps {
                    state.step(&cfg, &comm, step).unwrap();
                }
                state.plan.check_against(&state.store).map_err(|e| format!("{spikes:?}: {e}"))
            });
            for r in results {
                r.unwrap();
            }
        }
    }

    #[test]
    fn poisoned_scratch_cannot_leak_into_results() {
        // The opt-8 scratch-reuse accounting contract: the vacancy
        // buffers are fully rewritten every plasticity phase, so
        // pre-poisoning them with garbage of the wrong length must
        // change nothing — synapses, calcium bits, wire accounting and
        // lookup counts all match a clean run.
        let cfg = smoke_cfg();
        let clean = run_simulation(&cfg).unwrap();
        let poisoned = run_ranks(cfg.ranks, |comm| {
            let mut state = RankState::init(&cfg, &comm);
            state.vac_scratch.exc = vec![1e30; 1000];
            state.vac_scratch.inh = vec![-7.5; 3];
            for step in 0..cfg.steps {
                state.step(&cfg, &comm, step).unwrap();
            }
            state.into_report(&comm)
        });
        for (c, p) in clean.ranks.iter().zip(&poisoned) {
            assert_eq!(c.synapses_out, p.synapses_out);
            assert_eq!(c.synapses_in, p.synapses_in);
            assert_eq!(c.mean_calcium.to_bits(), p.mean_calcium.to_bits());
            assert_eq!(c.comm.bytes_sent, p.comm.bytes_sent);
            assert_eq!(c.comm.collectives, p.comm.collectives);
            assert_eq!(c.spike_lookups, p.spike_lookups);
            assert_eq!(c.plan_rebuilds, p.plan_rebuilds);
        }
    }

    #[test]
    fn direct_baseline_runs() {
        let mut cfg = smoke_cfg();
        cfg.connectivity_alg = ConnectivityAlg::Direct;
        cfg.steps = 100;
        let report = run_simulation(&cfg).unwrap();
        assert!(report.total_synapses() > 0);
    }

    #[test]
    fn single_rank_runs() {
        let mut cfg = smoke_cfg();
        cfg.ranks = 1;
        cfg.neurons_per_rank = 64;
        let report = run_simulation(&cfg).unwrap();
        assert_eq!(report.ranks.len(), 1);
        // One rank: everything is local — nothing on the wire.
        assert_eq!(report.total_bytes_sent(), 0);
        assert_eq!(report.total_bytes_rma(), 0);
        assert!(report.total_synapses() > 0);
    }

    /// Temp checkpoint directory unique to one test.
    fn ckpt_dir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("ilmi_driver_ckpt_{tag}_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        dir
    }

    /// The checkpoint/resume determinism contract: N steps + resume for
    /// the rest == the straight run, bit-exactly, for the given
    /// algorithm pair. The checkpoint lands mid-frequency-epoch and
    /// between plasticity updates (step 75 with delta = interval = 50),
    /// so partial epoch counters and the received frequency table must
    /// all survive the round-trip.
    fn assert_resume_matches_straight(conn: ConnectivityAlg, spikes: SpikeAlg, tag: &str) {
        let dir = ckpt_dir(tag);
        let base = SimConfig {
            ranks: 2,
            neurons_per_rank: 32,
            steps: 150,
            plasticity_interval: 50,
            delta: 50,
            connectivity_alg: conn,
            spike_alg: spikes,
            record_calcium_every: 30,
            ..SimConfig::default()
        };
        let straight = run_simulation(&base).unwrap();

        // Leg 1: run the first half with checkpointing on.
        let mut first = base.clone();
        first.steps = 75;
        first.checkpoint_every = 75;
        first.checkpoint_dir = dir.to_str().unwrap().to_string();
        run_simulation(&first).unwrap();
        let snap_path = dir.join(crate::snapshot::snapshot_file_name(75));
        let snap = Snapshot::read_file(&snap_path).unwrap();
        assert_eq!(snap.next_step(), 75);

        // Leg 2: resume to the full schedule, no checkpointing.
        let resumed = resume_simulation(&base, &snap).unwrap();

        assert_eq!(straight.ranks.len(), resumed.ranks.len());
        for (s, r) in straight.ranks.iter().zip(&resumed.ranks) {
            assert_eq!(s.synapses_out, r.synapses_out, "{tag}: synapses_out");
            assert_eq!(s.synapses_in, r.synapses_in, "{tag}: synapses_in");
            assert_eq!(
                s.mean_calcium.to_bits(),
                r.mean_calcium.to_bits(),
                "{tag}: mean_calcium {} vs {}",
                s.mean_calcium,
                r.mean_calcium
            );
            assert_eq!(s.comm.bytes_sent, r.comm.bytes_sent, "{tag}: bytes_sent");
            assert_eq!(s.comm.bytes_recv, r.comm.bytes_recv, "{tag}: bytes_recv");
            assert_eq!(s.comm.bytes_rma, r.comm.bytes_rma, "{tag}: bytes_rma");
            assert_eq!(s.comm.msgs_sent, r.comm.msgs_sent, "{tag}: msgs_sent");
            assert_eq!(s.spike_lookups, r.spike_lookups, "{tag}: spike_lookups");
            assert_eq!(s.deletion, r.deletion, "{tag}: deletion stats");
            assert_eq!(s.formation.formed, r.formation.formed, "{tag}: formed");
            assert_eq!(s.formation.searches, r.formation.searches, "{tag}: searches");
            // The calcium trace spans both legs seamlessly.
            assert_eq!(s.calcium_trace.len(), r.calcium_trace.len(), "{tag}: trace len");
            for ((ss, sv), (rs, rv)) in s.calcium_trace.iter().zip(&r.calcium_trace) {
                assert_eq!(ss, rs, "{tag}: trace step");
                let sb: Vec<u32> = sv.iter().map(|x| x.to_bits()).collect();
                let rb: Vec<u32> = rv.iter().map(|x| x.to_bits()).collect();
                assert_eq!(sb, rb, "{tag}: trace values at step {ss}");
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn resume_is_bit_exact_new_algorithms() {
        assert_resume_matches_straight(
            ConnectivityAlg::NewLocationAware,
            SpikeAlg::NewFrequency,
            "new",
        );
    }

    #[test]
    fn resume_is_bit_exact_old_algorithms() {
        assert_resume_matches_straight(ConnectivityAlg::OldRma, SpikeAlg::OldIds, "old");
    }

    /// The deterministic fields of a trace sample — everything except
    /// the wall-clock observations (`ts_micros`, `phase_seconds`,
    /// `cost.nanos`).
    #[allow(clippy::type_complexity)]
    fn det_fields(
        s: &crate::trace::EpochSample,
    ) -> (u64, u8, CounterSnapshot, u64, u64, u64, u64, u64, u64, u64, u64) {
        (
            s.step,
            s.boundaries,
            s.comm,
            s.spikes,
            s.formed,
            s.retractions,
            s.plan_rebuilds,
            s.migrations,
            s.cost.neurons,
            s.cost.local_edges,
            s.cost.remote_partners,
        )
    }

    #[test]
    fn trace_counts_and_deltas_are_deterministic() {
        for (conn, spikes) in [
            (ConnectivityAlg::NewLocationAware, SpikeAlg::NewFrequency),
            (ConnectivityAlg::OldRma, SpikeAlg::OldIds),
        ] {
            let mut cfg = smoke_cfg();
            cfg.connectivity_alg = conn;
            cfg.spike_alg = spikes;
            cfg.trace_every = 25;
            // 200 steps record 8 samples; a capacity of 4 forces the
            // ring to evict the first half.
            cfg.trace_capacity = 4;
            let a = run_simulation(&cfg).unwrap();
            let b = run_simulation(&cfg).unwrap();
            assert_eq!(a.trace_events(), b.trace_events(), "{spikes:?}: event count");
            assert!(a.trace_events() > 0, "{spikes:?}: tracing was on");
            for (ra, rb) in a.ranks.iter().zip(&b.ranks) {
                assert_eq!(ra.trace.len(), 4, "{spikes:?}: ring bound");
                let steps: Vec<u64> = ra.trace.iter().map(|s| s.step).collect();
                assert_eq!(steps, vec![125, 150, 175, 200], "{spikes:?}: last windows kept");
                // Boundary flags are a pure function of step + config:
                // with delta = interval = 50, steps 150/200 are
                // spike+plasticity epochs, 125/175 are plain samples.
                assert_eq!(ra.trace[0].boundaries, 0);
                assert_eq!(
                    ra.trace[1].boundaries,
                    crate::trace::SPIKE_EPOCH | crate::trace::PLASTICITY_EPOCH
                );
                for (sa, sb) in ra.trace.iter().zip(&rb.trace) {
                    assert_eq!(det_fields(sa), det_fields(sb), "{spikes:?}: sample drift");
                }
            }
        }
    }

    #[test]
    fn tracing_is_pure_observation() {
        // Turning the tracer on must not move the trajectory or any
        // deterministic counter, and with the ring unbounded the
        // per-window deltas must sum back to the run totals.
        let cfg = smoke_cfg();
        let off = run_simulation(&cfg).unwrap();
        assert_eq!(off.trace_events(), 0);
        assert!(off.ranks.iter().all(|r| r.trace.is_empty()));
        let mut traced = cfg.clone();
        traced.trace_every = 50;
        let on = run_simulation(&traced).unwrap();
        for (a, b) in off.ranks.iter().zip(&on.ranks) {
            assert_eq!(a.comm, b.comm);
            assert_eq!(a.synapses_out, b.synapses_out);
            assert_eq!(a.mean_calcium.to_bits(), b.mean_calcium.to_bits());
            assert_eq!(a.spike_lookups, b.spike_lookups);
            assert_eq!(a.plan_rebuilds, b.plan_rebuilds);
            assert_eq!(b.trace.len(), 4);
            let sum_formed: u64 = b.trace.iter().map(|s| s.formed).sum();
            assert_eq!(sum_formed, b.formation.formed, "formation deltas tile the run");
            let sum_sent: u64 = b.trace.iter().map(|s| s.comm.bytes_sent).sum();
            assert_eq!(sum_sent, b.comm.bytes_sent, "comm deltas tile the run");
        }
    }

    /// The trace sibling of `assert_resume_matches_straight`: traces
    /// are segment-scoped (never snapshotted), so the pre-checkpoint
    /// leg's samples followed by the resumed leg's must reproduce the
    /// straight run's samples field-for-field (timestamps excluded).
    fn assert_trace_segments_concatenate(conn: ConnectivityAlg, spikes: SpikeAlg, tag: &str) {
        let dir = ckpt_dir(tag);
        let base = SimConfig {
            ranks: 2,
            neurons_per_rank: 32,
            steps: 150,
            plasticity_interval: 50,
            delta: 50,
            trace_every: 25,
            connectivity_alg: conn,
            spike_alg: spikes,
            ..SimConfig::default()
        };
        let straight = run_simulation(&base).unwrap();

        let mut first = base.clone();
        first.steps = 75;
        first.checkpoint_every = 75;
        first.checkpoint_dir = dir.to_str().unwrap().to_string();
        let leg1 = run_simulation(&first).unwrap();
        let snap =
            Snapshot::read_file(dir.join(crate::snapshot::snapshot_file_name(75))).unwrap();
        let resumed = resume_simulation(&base, &snap).unwrap();

        for ((s, l), r) in straight.ranks.iter().zip(&leg1.ranks).zip(&resumed.ranks) {
            assert_eq!(s.trace.len(), 6, "{tag}: straight samples");
            assert_eq!(l.trace.len(), 3, "{tag}: leg-1 trace is segment-scoped");
            assert_eq!(r.trace.len(), 3, "{tag}: resumed trace is segment-scoped");
            let concat: Vec<_> = l.trace.iter().chain(&r.trace).map(det_fields).collect();
            let whole: Vec<_> = s.trace.iter().map(det_fields).collect();
            assert_eq!(concat, whole, "{tag}: segment traces must concatenate");
        }
        // The drift-checked event counts concatenate too.
        assert_eq!(
            leg1.trace_events() + resumed.trace_events(),
            straight.trace_events(),
            "{tag}: event counts"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn trace_segments_concatenate_across_resume_new_algorithms() {
        assert_trace_segments_concatenate(
            ConnectivityAlg::NewLocationAware,
            SpikeAlg::NewFrequency,
            "trace_new",
        );
    }

    #[test]
    fn trace_segments_concatenate_across_resume_old_algorithms() {
        assert_trace_segments_concatenate(
            ConnectivityAlg::OldRma,
            SpikeAlg::OldIds,
            "trace_old",
        );
    }

    #[test]
    fn v1_snapshot_resumes_bit_exactly() {
        // Format-compatibility contract: a version-1 snapshot (dense
        // per-rank frequency table) of the same state must load and
        // resume to exactly the straight run's report. The v1 file is
        // manufactured by re-encoding a fresh checkpoint's sections in
        // the old dense layout (nonzero entries scattered over
        // total_neurons f32s) under a version-1 header.
        use crate::snapshot::{config_fingerprint_for_version, SnapshotHeader, MIN_FORMAT_VERSION};
        use crate::util::wire::{put_u32, put_u64};
        let dir = ckpt_dir("v1compat");
        let base = SimConfig {
            ranks: 2,
            neurons_per_rank: 32,
            steps: 150,
            plasticity_interval: 50,
            delta: 50,
            ..SimConfig::default()
        };
        let straight = run_simulation(&base).unwrap();

        let mut first = base.clone();
        first.steps = 75;
        first.checkpoint_every = 75;
        first.checkpoint_dir = dir.to_str().unwrap().to_string();
        run_simulation(&first).unwrap();
        let snap =
            Snapshot::read_file(dir.join(crate::snapshot::snapshot_file_name(75))).unwrap();

        // Rewrite as a v1 file, stamped with the fingerprint a v1-era
        // build would have computed (no balance bytes) — resuming it
        // exercises the version-matched fingerprint comparison.
        let mut hdr = SnapshotHeader::for_config(&base, 75);
        hdr.version = MIN_FORMAT_VERSION;
        hdr.fingerprint = config_fingerprint_for_version(&base, MIN_FORMAT_VERSION);
        let mut buf = Vec::new();
        hdr.encode(&mut buf);
        for rank in 0..base.ranks {
            let enc = snap.section(rank).unwrap().encode_v1(base.total_neurons());
            put_u32(&mut buf, rank as u32);
            put_u64(&mut buf, enc.len() as u64);
            buf.extend_from_slice(&enc);
        }
        let v1 = Snapshot::from_bytes(&buf).unwrap();
        assert_eq!(v1.version(), MIN_FORMAT_VERSION);

        let resumed = resume_simulation(&base, &v1).unwrap();
        for (s, r) in straight.ranks.iter().zip(&resumed.ranks) {
            assert_eq!(s.synapses_out, r.synapses_out);
            assert_eq!(s.mean_calcium.to_bits(), r.mean_calcium.to_bits());
            assert_eq!(s.comm.bytes_sent, r.comm.bytes_sent);
            assert_eq!(s.comm.collectives, r.comm.collectives);
            assert_eq!(s.spike_lookups, r.spike_lookups);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn spike_state_is_sparse_not_dense() {
        // The memory claim behind EXPERIMENTS.md §Perf, opt 7: per-rank
        // reconstruction state is 12 B per remote in-partner, bounded
        // by the remote-neuron count and entirely absent under the old
        // algorithm — never the 4·total_neurons dense table.
        let report = run_simulation(&smoke_cfg()).unwrap();
        let total = smoke_cfg().total_neurons() as u64;
        for r in &report.ranks {
            assert_eq!(r.spike_state_bytes % 12, 0, "whole 12 B records");
            let remote = total - smoke_cfg().neurons_per_rank as u64;
            assert!(
                r.spike_state_bytes <= remote * 12,
                "state {} exceeds 12 B per possible remote partner ({remote})",
                r.spike_state_bytes
            );
        }
        // An active 2-rank network forms cross-rank edges, so some
        // partner state must exist somewhere.
        assert!(report.ranks.iter().any(|r| r.spike_state_bytes > 0));

        let mut old = smoke_cfg();
        old.spike_alg = SpikeAlg::OldIds;
        let report = run_simulation(&old).unwrap();
        for r in &report.ranks {
            assert_eq!(r.spike_state_bytes, 0, "old algorithm holds no frequency state");
        }
    }

    #[test]
    fn chained_resume_accumulates_baselines() {
        // checkpoint -> resume (checkpointing again) -> resume: counters
        // and stats must keep matching the straight run across TWO
        // restore round-trips.
        let dir = ckpt_dir("chained");
        let base = SimConfig {
            ranks: 2,
            neurons_per_rank: 32,
            steps: 150,
            plasticity_interval: 50,
            delta: 50,
            ..SimConfig::default()
        };
        let straight = run_simulation(&base).unwrap();

        let mut ck = base.clone();
        ck.steps = 150;
        ck.checkpoint_every = 50;
        ck.checkpoint_dir = dir.to_str().unwrap().to_string();
        // Leg 1: 0..50.
        let mut leg1 = ck.clone();
        leg1.steps = 50;
        run_simulation(&leg1).unwrap();
        // Leg 2: 50..100, still checkpointing (tests capture-on-resumed-state).
        let snap50 = Snapshot::read_file(dir.join(crate::snapshot::snapshot_file_name(50))).unwrap();
        let mut leg2 = ck.clone();
        leg2.steps = 100;
        resume_simulation(&leg2, &snap50).unwrap();
        // Leg 3: 100..150, from the checkpoint leg 2 wrote.
        let snap100 =
            Snapshot::read_file(dir.join(crate::snapshot::snapshot_file_name(100))).unwrap();
        let final_cfg = base.clone();
        let resumed = resume_simulation(&final_cfg, &snap100).unwrap();

        for (s, r) in straight.ranks.iter().zip(&resumed.ranks) {
            assert_eq!(s.synapses_out, r.synapses_out);
            assert_eq!(s.mean_calcium.to_bits(), r.mean_calcium.to_bits());
            assert_eq!(s.comm.bytes_sent, r.comm.bytes_sent);
            assert_eq!(s.comm.collectives, r.comm.collectives);
            assert_eq!(s.spike_lookups, r.spike_lookups);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Skewed start (48/16 neurons over a 6/2 cell split) with
    /// balancing on: one boundary-cell migration per epoch.
    fn skew_cfg() -> SimConfig {
        SimConfig {
            ranks: 2,
            neurons_per_rank: 32,
            steps: 150,
            plasticity_interval: 50,
            delta: 50,
            balance_every: 50,
            balance_threshold: 1.1,
            balance_max_moves: 1,
            balance_init_cells: "6,2".to_string(),
            ..SimConfig::default()
        }
    }

    #[test]
    fn skewed_run_rebalances_and_imbalance_strictly_decreases() {
        let cfg = skew_cfg();
        cfg.validate().unwrap();
        let results = run_ranks(cfg.ranks, |comm| {
            let mut state = RankState::init(&cfg, &comm);
            let mut trace = Vec::new();
            for step in 0..cfg.steps {
                state.step(&cfg, &comm, step).unwrap();
                if (step + 1) % cfg.balance_every == 0 {
                    // Collective probe of the post-epoch global
                    // imbalance (every rank probes at the same steps).
                    let all = gather_all(&comm, &[state.measure_cost()]);
                    let costs: Vec<f64> = all.iter().map(|b| b[0].cost()).collect();
                    trace.push(crate::balance::imbalance(&costs));
                }
            }
            // The acceptance invariants, hard-checked at the end too
            // (apply_partition already asserts them per migration).
            state.store.check_invariants().unwrap();
            state.plan.check_against(&state.store).unwrap();
            (trace, state.migrations, state.pop.len())
        });
        let (trace, migrations, _) = &results[0];
        assert!(*migrations >= 1, "the skewed start must trigger migrations");
        // Strictly decreasing across balance epochs until the factor is
        // at the threshold.
        for w in trace.windows(2) {
            assert!(
                w[1] < w[0] || w[0] <= cfg.balance_threshold,
                "imbalance failed to decrease: {trace:?}"
            );
        }
        assert!(
            trace.last().unwrap() < &trace[0],
            "imbalance must end below its first probe: {trace:?}"
        );
        // Neurons actually moved toward even (48/16 is the skewed start).
        let (n0, n1) = (results[0].2, results[1].2);
        assert_eq!(n0 + n1, 64);
        assert!(n0 < 48 && n1 > 16, "neurons did not move: {n0}/{n1}");
    }

    #[test]
    fn migration_roundtrip_restores_bit_identical_state() {
        // Grow a real network (old algorithm pair: no frequency state,
        // so the whole digest must round-trip), force a migration of
        // rank 0's last two cells to rank 1, then migrate them back:
        // every array must be bit-identical to before.
        let mut cfg = smoke_cfg();
        cfg.connectivity_alg = ConnectivityAlg::OldRma;
        cfg.spike_alg = SpikeAlg::OldIds;
        type Digest = (
            Vec<crate::util::Vec3>,
            Vec<u32>,
            Vec<u32>,
            Vec<u32>,
            Vec<u32>,
            Vec<bool>,
            Vec<Vec<u64>>,
            Vec<Vec<InEdge>>,
            Vec<(u64, f32)>,
            (crate::util::RngState, crate::util::RngState, crate::util::RngState),
        );
        let digest = |s: &RankState| -> Digest {
            (
                s.pop.positions.clone(),
                s.pop.v.iter().map(|x| x.to_bits()).collect(),
                s.pop.u.iter().map(|x| x.to_bits()).collect(),
                s.pop.ca.iter().map(|x| x.to_bits()).collect(),
                s.pop.epoch_spikes.clone(),
                s.pop.fired.clone(),
                s.store.out_edges.clone(),
                s.store.in_edges.clone(),
                s.freq_exchange.entries(),
                (s.rng_model.state(), s.rng_conn.state(), s.freq_exchange.rng_state()),
            )
        };
        let results = run_ranks(cfg.ranks, |comm| {
            let mut state = RankState::init(&cfg, &comm);
            for step in 0..60 {
                state.step(&cfg, &comm, step).unwrap();
            }
            let before = digest(&state);
            let uniform = state.partition.clone();
            let shifted = Partition {
                cell_counts: uniform.cell_counts.clone(),
                cell_start: vec![0, 2, 8],
            };
            state.apply_partition(&cfg, &comm, shifted);
            assert_eq!(state.migrations, 1);
            assert_eq!(
                state.pop.len() as u64,
                state.owners.count(comm.rank()),
                "population must match the new ownership share"
            );
            state.apply_partition(&cfg, &comm, uniform);
            let after = digest(&state);
            (before, after)
        });
        for (before, after) in results {
            assert_eq!(before, after, "migrate + migrate back must be the identity");
        }
    }

    #[test]
    fn migration_carries_frequency_entries_mid_epoch() {
        // A mid-epoch migration must ship the receiver-side frequency
        // entries of the moving neurons' sources along, and a
        // migrate-back must restore both ranks' tables exactly.
        let cfg = SimConfig {
            ranks: 2,
            neurons_per_rank: 32,
            plasticity_interval: 50,
            delta: 50,
            ..SimConfig::default()
        };
        run_ranks(2, |comm| {
            let rank = comm.rank();
            let mut state = RankState::init(&cfg, &comm);
            // Rank 1's neuron 40 feeds rank 0's neurons 17 (stays) and
            // 25 (will migrate); rank 0 holds its epoch frequency.
            if rank == 0 {
                state.store.add_in(17, 40, true);
                state.store.add_in(25, 40, true);
                state.freq_exchange = FrequencyExchange::from_parts(
                    cfg.delta,
                    vec![(40, 1.0)],
                    state.freq_exchange.rng_state(),
                )
                .unwrap();
            } else {
                state.store.add_out(8, 17); // local index of id 40
                state.store.add_out(8, 25);
            }
            state.rebuild_plan();
            let before = state.freq_exchange.entries();
            // Ship rank 0's last cell (ids 24..32) to rank 1.
            let uniform = state.partition.clone();
            let shifted = Partition {
                cell_counts: uniform.cell_counts.clone(),
                cell_start: vec![0, 3, 8],
            };
            state.apply_partition(&cfg, &comm, shifted);
            // Both ranks now hold the entry: rank 0 because neuron 17
            // still reads it, rank 1 because it traveled with 25.
            assert_eq!(state.freq_exchange.entries(), vec![(40, 1.0)]);
            // And back: the tables restore exactly on both ranks.
            state.apply_partition(&cfg, &comm, uniform);
            assert_eq!(state.freq_exchange.entries(), before);
            state.store.check_invariants().unwrap();
            state.plan.check_against(&state.store).unwrap();
        });
    }

    #[test]
    fn explicit_uniform_init_cells_matches_default_run() {
        // "4,4" names EXACTLY the default partition, so the whole
        // trajectory — placement, routing, wire accounting — must be
        // identical to the empty-string default (the Stride ≡ uniform
        // Ranges equivalence at system level).
        let base = smoke_cfg();
        let a = run_simulation(&base).unwrap();
        let mut explicit = base.clone();
        explicit.balance_init_cells = "4,4".to_string();
        let b = run_simulation(&explicit).unwrap();
        for (ra, rb) in a.ranks.iter().zip(&b.ranks) {
            assert_eq!(ra.synapses_out, rb.synapses_out);
            assert_eq!(ra.mean_calcium.to_bits(), rb.mean_calcium.to_bits());
            assert_eq!(ra.comm.bytes_sent, rb.comm.bytes_sent);
            assert_eq!(ra.comm.collectives, rb.comm.collectives);
            assert_eq!(ra.spike_lookups, rb.spike_lookups);
        }
    }

    #[test]
    fn balanced_run_resumes_bit_exactly_across_migrations() {
        // Checkpoint AFTER the first migration (step 50): the v4 header
        // carries the migrated (non-uniform) partition, and resuming
        // from it reproduces the straight skewed run — including the
        // SECOND migration at step 100 — bit-exactly.
        let dir = ckpt_dir("balance");
        let base = skew_cfg();
        let straight = run_simulation(&base).unwrap();

        let mut first = base.clone();
        first.steps = 50;
        first.checkpoint_every = 50;
        first.checkpoint_dir = dir.to_str().unwrap().to_string();
        run_simulation(&first).unwrap();
        let snap =
            Snapshot::read_file(dir.join(crate::snapshot::snapshot_file_name(50))).unwrap();
        assert!(
            snap.partition().is_some(),
            "one migration in: the header must store an explicit partition"
        );

        let resumed = resume_simulation(&base, &snap).unwrap();
        for (s, r) in straight.ranks.iter().zip(&resumed.ranks) {
            assert_eq!(s.neurons, r.neurons, "per-rank populations after rebalancing");
            assert_eq!(s.synapses_out, r.synapses_out);
            assert_eq!(s.synapses_in, r.synapses_in);
            assert_eq!(s.mean_calcium.to_bits(), r.mean_calcium.to_bits());
            assert_eq!(s.comm.bytes_sent, r.comm.bytes_sent);
            assert_eq!(s.comm.collectives, r.comm.collectives);
            assert_eq!(s.spike_lookups, r.spike_lookups);
        }
        // The straight skewed run ends balanced: 32/32.
        assert_eq!(straight.ranks[0].neurons, 32);
        assert_eq!(straight.ranks[1].neurons, 32);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn reports_surface_load_observability() {
        // Default (balancing off): migrations are zero, populations are
        // uniform, and the load fields feed a finite imbalance factor.
        let report = run_simulation(&smoke_cfg()).unwrap();
        for r in &report.ranks {
            assert_eq!(r.migrations, 0);
            assert_eq!(r.neurons, 32);
            assert_eq!(r.local_edges, (r.synapses_in + r.synapses_out) as u64);
        }
        assert_eq!(report.total_migrations(), 0);
        let imb = report.imbalance();
        assert!(imb >= 1.0 && imb.is_finite(), "imbalance {imb}");
    }

    #[test]
    fn resume_rejects_mismatched_config() {
        let dir = ckpt_dir("reject");
        let mut cfg = smoke_cfg();
        cfg.steps = 50;
        cfg.checkpoint_every = 50;
        cfg.checkpoint_dir = dir.to_str().unwrap().to_string();
        run_simulation(&cfg).unwrap();
        let snap = Snapshot::read_file(dir.join(crate::snapshot::snapshot_file_name(50))).unwrap();

        let mut other = cfg.clone();
        other.steps = 100;
        other.checkpoint_every = 0;
        other.checkpoint_dir = String::new();
        other.seed += 1; // dynamics-relevant change
        let err = resume_simulation(&other, &snap).unwrap_err();
        assert!(format!("{err:#}").contains("fingerprint"), "{err:#}");
        // ...but branch_simulation deliberately allows it.
        let report = branch_simulation(&other, &snap).unwrap();
        assert_eq!(report.ranks.len(), cfg.ranks);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn kernel_blocks_counter_is_deterministic_and_kernel_independent() {
        // 16 neurons per rank = one 64-wide block per step; 60 steps =
        // 60 blocks per rank, regardless of which backend executed them
        // (the driver counts blocks, not the kernels).
        let mut cfg = smoke_cfg();
        cfg.neurons_per_rank = 16;
        cfg.steps = 60;
        for kind in [crate::config::KernelKind::Scalar, crate::config::KernelKind::Blocked] {
            let mut c = cfg.clone();
            c.kernel = kind;
            let report = run_simulation(&c).unwrap();
            for r in &report.ranks {
                assert_eq!(r.kernel_blocks, 60, "{kind:?}");
            }
            assert_eq!(report.total_kernel_blocks(), 120, "{kind:?}");
        }
    }

    #[test]
    fn blocked_kernel_reproduces_scalar_run_bit_exactly() {
        let scalar = run_simulation(&smoke_cfg()).unwrap();
        let mut cfg = smoke_cfg();
        cfg.kernel = crate::config::KernelKind::Blocked;
        let blocked = run_simulation(&cfg).unwrap();
        for (a, b) in scalar.ranks.iter().zip(&blocked.ranks) {
            assert_eq!(a.mean_calcium.to_bits(), b.mean_calcium.to_bits());
            assert_eq!(a.synapses_out, b.synapses_out);
            assert_eq!(a.synapses_in, b.synapses_in);
            assert_eq!(a.comm.bytes_sent, b.comm.bytes_sent);
            assert_eq!(a.spike_lookups, b.spike_lookups);
            assert_eq!(a.kernel_blocks, b.kernel_blocks);
        }
    }

    #[test]
    fn mock_xla_backend_matches_native_run_bit_exactly() {
        // End-to-end over the staged path: backend = xla with a mock
        // service (the scalar oracle behind the service protocol) must
        // reproduce the native run bit-for-bit.
        let native = run_simulation(&smoke_cfg()).unwrap();
        let mut cfg = smoke_cfg();
        cfg.backend = Backend::Xla;
        let handle = crate::runtime::spawn_mock_service();
        let xla = run_simulation_with_xla(&cfg, Some(handle.clone())).unwrap();
        handle.shutdown();
        for (a, b) in native.ranks.iter().zip(&xla.ranks) {
            assert_eq!(a.mean_calcium.to_bits(), b.mean_calcium.to_bits());
            assert_eq!(a.synapses_out, b.synapses_out);
            assert_eq!(a.comm.bytes_sent, b.comm.bytes_sent);
            assert_eq!(a.spike_lookups, b.spike_lookups);
        }
    }

    #[test]
    fn poisson_with_xla_handle_keeps_native_dynamics() {
        // The satellite-a regression. The explicit combination is
        // rejected up front...
        let mut bad = smoke_cfg();
        bad.backend = Backend::Xla;
        bad.neuron_model = crate::config::NeuronModel::Poisson;
        let handle = crate::runtime::spawn_mock_service();
        let err = run_simulation_with_xla(&bad, Some(handle.clone())).unwrap_err();
        assert!(format!("{err:#}").contains("poisson"), "{err:#}");
        // ...and even a state handed an XLA handle directly never
        // routes Poisson dynamics to the Izhikevich artifact: the
        // dispatch falls back to the scalar kernel, matching a plain
        // native run bit-for-bit.
        let mut cfg = smoke_cfg();
        cfg.neuron_model = crate::config::NeuronModel::Poisson;
        cfg.steps = 60;
        let plain = run_simulation(&cfg).unwrap();
        let results = run_ranks(cfg.ranks, |comm| {
            let mut state = RankState::init(&cfg, &comm);
            state.kernel = make_kernel(&cfg, Some(&handle));
            assert_eq!(state.kernel.name(), "scalar");
            for step in 0..cfg.steps {
                state.step(&cfg, &comm, step).unwrap();
            }
            state.into_report(&comm)
        });
        handle.shutdown();
        for (a, b) in plain.ranks.iter().zip(&results) {
            assert_eq!(a.mean_calcium.to_bits(), b.mean_calcium.to_bits());
            assert_eq!(a.synapses_out, b.synapses_out);
        }
    }

    #[test]
    fn socket_with_xla_fails_fast_at_launch() {
        // The satellite-b guard: a socket launch with the XLA backend
        // or kernel must error before any child is spawned instead of
        // silently degrading to the native path.
        for set in [
            |c: &mut SimConfig| c.backend = Backend::Xla,
            |c: &mut SimConfig| c.kernel = crate::config::KernelKind::Xla,
        ] {
            let mut cfg = smoke_cfg();
            cfg.comm_backend = crate::config::CommBackend::Socket;
            set(&mut cfg);
            let err = run_simulation(&cfg).unwrap_err();
            let msg = format!("{err:#}").to_lowercase();
            assert!(msg.contains("socket") && msg.contains("xla"), "{msg}");
        }
    }
}
