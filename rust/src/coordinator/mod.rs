//! The simulation coordinator: per-rank phase loop, algorithm selection,
//! backend dispatch, and report assembly.

mod driver;

pub use driver::{
    branch_simulation, branch_simulation_with_xla, resume_simulation, resume_simulation_with_xla,
    run_simulation, run_simulation_with_xla, RankState,
};
#[cfg(unix)]
pub use driver::{resume_simulation_socket, SIMULATE_ENTRY, SOCKET_ENTRIES};
