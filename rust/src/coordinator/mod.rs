//! The simulation coordinator: per-rank phase loop, algorithm selection,
//! backend dispatch, and report assembly.

mod driver;

pub use driver::{run_simulation, run_simulation_with_xla, RankState};
