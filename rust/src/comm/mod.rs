//! Simulated MPI communicator.
//!
//! The paper's algorithms are defined over MPI collectives (all-to-all,
//! all-gather, barrier) plus one-sided remote memory access (RMA).
//! This module reproduces that interface inside one process: each rank is
//! an OS thread, collectives move real buffers through per-pair slots,
//! and RMA reads copy from a window another rank has published. Per-rank
//! counters track bytes sent/received/remotely-accessed and message
//! counts using the paper's own accounting ("we only count bytes we
//! directly handle"), which is what regenerates Tables I and II.
//!
//! Why this is a faithful substitute for MPI (DESIGN.md §1): the old and
//! new algorithms differ in *communication structure and volume*, not in
//! which transport carries the bytes. Who-talks-to-whom, message counts,
//! synchronization points, and byte volumes are preserved exactly.
//!
//! Two backends implement the [`Comm`] trait (DESIGN.md §11):
//! - [`ThreadComm`]: each rank is an OS thread in this process;
//!   collectives move buffers through shared-memory slots.
//! - [`SocketComm`]: each rank is its own OS process; collectives and
//!   RMA move length-prefixed frames over Unix domain sockets (launched
//!   by [`proc::run_entry`], selected with `--comm socket`).
//!
//! Accounting is byte-for-byte identical across backends — the
//! cross-backend differential suite pins it.

mod api;
mod counters;
#[cfg(unix)]
pub mod proc;
#[cfg(unix)]
mod socket_comm;
mod thread_comm;

pub use api::Comm;
pub use counters::{CommCounters, CounterSnapshot};
#[cfg(unix)]
pub(crate) use socket_comm::beat_wire;
#[cfg(unix)]
pub use socket_comm::{decode_frame, encode_frame, socket_ranks, SocketComm, FRAME_HEADER};
pub use thread_comm::{run_ranks, ThreadComm, WindowKey};

use crate::util::wire::{decode_all, encode_all, Wire};

/// Typed all-to-all: `sends[d]` goes to rank `d`; returns `recvs[s]`
/// received from rank `s`. Counts wire bytes on the communicator.
pub fn exchange<T: Wire>(comm: &impl Comm, sends: Vec<Vec<T>>) -> Vec<Vec<T>> {
    exchange_ref(comm, &sends)
}

/// `exchange` borrowing the send lists, so per-step callers can keep
/// them as reusable scratch instead of reallocating one `Vec<Vec<_>>`
/// per call (EXPERIMENTS.md §Perf, opt 6). The wire bytes on the
/// communicator are identical to `exchange`'s: encoding copies out of
/// the borrowed lists either way.
pub fn exchange_ref<T: Wire>(comm: &impl Comm, sends: &[Vec<T>]) -> Vec<Vec<T>> {
    let bufs = sends.iter().map(|msgs| encode_all(msgs)).collect();
    comm.all_to_all(bufs).iter().map(|buf| decode_all(buf)).collect()
}

/// Typed all-gather: every rank contributes `items`; returns per-source
/// vectors on every rank.
pub fn gather_all<T: Wire + Clone>(comm: &impl Comm, items: &[T]) -> Vec<Vec<T>> {
    let sends = vec![items.to_vec(); comm.size()];
    exchange(comm, sends)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exchange_routes_typed_messages() {
        let results = run_ranks(4, |comm| {
            // rank r sends the value 100*r + d to destination d
            let sends: Vec<Vec<u64>> = (0..4)
                .map(|d| vec![(100 * comm.rank() + d) as u64])
                .collect();
            exchange(&comm, sends)
        });
        for (rank, recvs) in results.iter().enumerate() {
            for (src, msgs) in recvs.iter().enumerate() {
                assert_eq!(msgs, &vec![(100 * src + rank) as u64]);
            }
        }
    }

    #[test]
    fn gather_all_broadcasts() {
        let results = run_ranks(3, |comm| {
            let mine = vec![comm.rank() as u64; comm.rank() + 1];
            gather_all(&comm, &mine)
        });
        for recvs in &results {
            for (src, msgs) in recvs.iter().enumerate() {
                assert_eq!(msgs, &vec![src as u64; src + 1]);
            }
        }
    }
}
