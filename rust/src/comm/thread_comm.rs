//! Thread-backed communicator: each simulated MPI rank is an OS thread.
//!
//! Collectives move real heap buffers through per-(dst, src) slots with a
//! barrier on each side — the synchronization structure of a synchronous
//! MPI all-to-all. RMA windows are published `Arc<Vec<u8>>` buffers other
//! ranks copy from (one-sided: the owner does not participate in a get,
//! exactly like `MPI_Get` on a passive target).

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Barrier, Mutex, RwLock};

use super::counters::{CommCounters, CounterSnapshot};
use crate::metrics::histogram::{CommHistSnapshot, CommHists};

/// Key identifying a published RMA window (e.g. "octree nodes of this
/// connectivity update").
pub type WindowKey = u32;

struct Shared {
    size: usize,
    barrier: Barrier,
    /// `slots[parity][dst][src]`: in-flight buffer from `src` to `dst`.
    /// Two parity-alternating slot sets let `all_to_all` get away with a
    /// SINGLE barrier per collective: writes of collective k+1 go to the
    /// other set, so they can never clobber a k-buffer a slower rank has
    /// not consumed yet, and by the time collective k+2 (same set as k)
    /// writes, every rank has passed the k+1 barrier — which it can only
    /// do after consuming k. (EXPERIMENTS.md §Perf, optimization 1.)
    slots: [Vec<Vec<Mutex<Option<Vec<u8>>>>>; 2],
    /// Per-rank published RMA windows.
    windows: Vec<RwLock<HashMap<WindowKey, Arc<Vec<u8>>>>>,
    counters: Vec<CommCounters>,
    poisoned: AtomicBool,
}

/// One rank's handle onto the shared communicator.
pub struct ThreadComm {
    rank: usize,
    /// Parity of the next collective on this rank (ranks stay in
    /// lockstep: a collective is collective for everyone).
    parity: std::cell::Cell<u8>,
    /// Comm latency histograms for calls made through the `Comm` trait.
    /// Per-handle (each rank's handle lives on one thread), never part
    /// of `CommCounters` accounting.
    hists: CommHists,
    shared: Arc<Shared>,
}

impl ThreadComm {
    /// Create handles for all `size` ranks of a new communicator.
    pub fn create(size: usize) -> Vec<ThreadComm> {
        assert!(size > 0);
        let make_slots = || {
            (0..size)
                .map(|_| (0..size).map(|_| Mutex::new(None)).collect())
                .collect()
        };
        let shared = Arc::new(Shared {
            size,
            barrier: Barrier::new(size),
            slots: [make_slots(), make_slots()],
            windows: (0..size).map(|_| RwLock::new(HashMap::new())).collect(),
            counters: (0..size).map(|_| CommCounters::default()).collect(),
            poisoned: AtomicBool::new(false),
        });
        (0..size)
            .map(|rank| ThreadComm {
                rank,
                parity: std::cell::Cell::new(0),
                hists: CommHists::default(),
                shared: Arc::clone(&shared),
            })
            .collect()
    }

    /// A single-rank communicator (serial execution, e.g. unit tests).
    pub fn solo() -> ThreadComm {
        ThreadComm::create(1).pop().unwrap()
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn size(&self) -> usize {
        self.shared.size
    }

    /// Synchronize all ranks.
    pub fn barrier(&self) {
        self.shared.barrier.wait();
    }

    /// Synchronous all-to-all: `sends[d]` is delivered to rank `d`;
    /// returns `recvs[s]` = buffer sent by rank `s`. Bytes moving between
    /// distinct ranks are counted; self-delivery is free (no network).
    pub fn all_to_all(&self, mut sends: Vec<Vec<u8>>) -> Vec<Vec<u8>> {
        let size = self.shared.size;
        assert_eq!(sends.len(), size, "all_to_all needs one buffer per rank");
        let me = self.rank;
        let counters = &self.shared.counters[me];
        counters.add_collective();
        let parity = self.parity.get() as usize;
        self.parity.set(1 - parity as u8);
        let slots = &self.shared.slots[parity];

        // Keep our own buffer aside; post the rest.
        let mut own = Some(std::mem::take(&mut sends[me]));
        for (dst, buf) in sends.into_iter().enumerate() {
            if dst == me {
                continue;
            }
            counters.add_sent(buf.len() as u64);
            *slots[dst][me].lock().unwrap() = Some(buf);
        }
        // One barrier: all posts are visible; parity double-buffering
        // makes a drain barrier unnecessary (see `Shared::slots`).
        self.barrier();

        let mut recvs = Vec::with_capacity(size);
        for src in 0..size {
            if src == me {
                recvs.push(own.take().expect("self buffer consumed twice"));
                continue;
            }
            let buf = slots[me][src]
                .lock()
                .unwrap()
                .take()
                .expect("all_to_all slot empty: collective mismatch across ranks");
            counters.add_recv(buf.len() as u64);
            recvs.push(buf);
        }
        recvs
    }

    /// Publish (replace) an RMA window under `key`. Visible to other
    /// ranks after the next barrier (caller synchronizes, like
    /// `MPI_Win_fence`).
    pub fn publish_window(&self, key: WindowKey, data: Vec<u8>) {
        self.shared.windows[self.rank].write().unwrap().insert(key, Arc::new(data));
    }

    /// Remove a published window.
    pub fn retract_window(&self, key: WindowKey) {
        self.shared.windows[self.rank].write().unwrap().remove(&key);
    }

    /// One-sided get: copy `len` bytes at `offset` from `target`'s window.
    /// Counted as remotely-accessed bytes on the *calling* rank (the paper
    /// attributes RMA traffic to the requester). Self-gets are free.
    pub fn rma_get(&self, target: usize, key: WindowKey, offset: usize, len: usize) -> Vec<u8> {
        // Bind the lookup result before panicking on a missing window:
        // panicking inside the statement would unwind while the read
        // guard temporary is still alive and poison the lock, taking
        // every later window operation down with it. A failed get must
        // leave the communicator usable (DESIGN.md §11).
        let win = self.shared.windows[target].read().unwrap().get(&key).cloned();
        let win = win.unwrap_or_else(|| panic!("rank {} has no window {key}", target));
        // checked_add: with plain `+`, an offset near usize::MAX wraps
        // in release builds and the bounds assert silently passes.
        let end = offset.checked_add(len).unwrap_or_else(|| {
            panic!("rma_get out of bounds: {offset}+{len} overflows usize")
        });
        assert!(
            end <= win.len(),
            "rma_get out of bounds: {}+{} > {}",
            offset,
            len,
            win.len()
        );
        if target != self.rank {
            self.shared.counters[self.rank].add_rma(len as u64);
        }
        win[offset..offset + len].to_vec()
    }

    /// Size in bytes of `target`'s window (free metadata peek used to
    /// bound fetches; not counted).
    pub fn window_len(&self, target: usize, key: WindowKey) -> Option<usize> {
        self.shared.windows[target].read().unwrap().get(&key).map(|w| w.len())
    }

    /// This rank's counter handle.
    pub fn counters(&self) -> &CommCounters {
        &self.shared.counters[self.rank]
    }

    /// Snapshot of every rank's counters (any rank may read).
    pub fn all_counters(&self) -> Vec<CounterSnapshot> {
        self.shared.counters.iter().map(|c| c.snapshot()).collect()
    }

    /// Mark the communicator as failed (a panicking rank sets this so
    /// sibling ranks blocked in a barrier can be diagnosed).
    pub fn poison(&self) {
        self.shared.poisoned.store(true, Ordering::SeqCst);
    }

    pub fn is_poisoned(&self) -> bool {
        self.shared.poisoned.load(Ordering::SeqCst)
    }
}

/// `ThreadComm` is the reference implementation of the backend-neutral
/// communicator surface; `SocketComm` must match it byte-for-byte in
/// accounting and routing (pinned by the cross-backend differential
/// suite). The inherent methods above stay callable without the trait
/// in scope; this impl forwards to them, adding only latency-histogram
/// sampling around the three instrumented primitives — which is why
/// histogram totals are exact counts of *trait-level* comm calls (the
/// barrier inside the inherent `all_to_all` is not a trait call and is
/// not double-counted).
impl super::Comm for ThreadComm {
    fn rank(&self) -> usize {
        ThreadComm::rank(self)
    }

    fn size(&self) -> usize {
        ThreadComm::size(self)
    }

    fn barrier(&self) {
        self.hists.barrier.time(|| ThreadComm::barrier(self))
    }

    fn all_to_all(&self, sends: Vec<Vec<u8>>) -> Vec<Vec<u8>> {
        self.hists.a2a.time(|| ThreadComm::all_to_all(self, sends))
    }

    fn publish_window(&self, key: WindowKey, data: Vec<u8>) {
        ThreadComm::publish_window(self, key, data)
    }

    fn retract_window(&self, key: WindowKey) {
        ThreadComm::retract_window(self, key)
    }

    fn rma_get(&self, target: usize, key: WindowKey, offset: usize, len: usize) -> Vec<u8> {
        // Self-gets are free in `CommCounters` but still sampled here:
        // histogram totals must count every call identically on both
        // backends to stay deterministic.
        self.hists.rma.time(|| ThreadComm::rma_get(self, target, key, offset, len))
    }

    fn window_len(&self, target: usize, key: WindowKey) -> Option<usize> {
        ThreadComm::window_len(self, target, key)
    }

    fn counters(&self) -> &CommCounters {
        ThreadComm::counters(self)
    }

    fn all_counters(&self) -> Vec<CounterSnapshot> {
        ThreadComm::all_counters(self)
    }

    fn comm_hists(&self) -> CommHistSnapshot {
        self.hists.snapshot()
    }

    fn poison(&self) {
        ThreadComm::poison(self)
    }

    fn is_poisoned(&self) -> bool {
        ThreadComm::is_poisoned(self)
    }
}

/// Run `f` on `size` ranks (threads); returns per-rank results in rank
/// order. Panics propagate after all threads finish or abort.
pub fn run_ranks<R, F>(size: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(ThreadComm) -> R + Send + Sync,
{
    let comms = ThreadComm::create(size);
    let mut results: Vec<Option<R>> = (0..size).map(|_| None).collect();
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(size);
        for (slot, comm) in results.iter_mut().zip(comms) {
            let f = &f;
            handles.push(scope.spawn(move || {
                *slot = Some(f(comm));
            }));
        }
        let mut panic: Option<Box<dyn std::any::Any + Send>> = None;
        for h in handles {
            if let Err(e) = h.join() {
                panic = Some(e);
            }
        }
        if let Some(e) = panic {
            std::panic::resume_unwind(e);
        }
    });
    results.into_iter().map(|r| r.expect("rank produced no result")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_to_all_routes_buffers() {
        let results = run_ranks(3, |comm| {
            let sends: Vec<Vec<u8>> =
                (0..3).map(|d| vec![comm.rank() as u8, d as u8]).collect();
            comm.all_to_all(sends)
        });
        for (rank, recvs) in results.iter().enumerate() {
            for (src, buf) in recvs.iter().enumerate() {
                assert_eq!(buf, &vec![src as u8, rank as u8]);
            }
        }
    }

    #[test]
    fn repeated_collectives_do_not_cross() {
        let results = run_ranks(4, |comm| {
            let mut sums = Vec::new();
            for round in 0..10u8 {
                let sends: Vec<Vec<u8>> = (0..4).map(|_| vec![round]).collect();
                let recvs = comm.all_to_all(sends);
                sums.push(recvs.iter().map(|b| b[0] as u32).sum::<u32>());
            }
            sums
        });
        for sums in results {
            assert_eq!(sums, (0..10).map(|r| 4 * r).collect::<Vec<u32>>());
        }
    }

    #[test]
    fn self_delivery_is_free() {
        let results = run_ranks(2, |comm| {
            let sends: Vec<Vec<u8>> = vec![vec![0; 100], vec![0; 100]];
            comm.all_to_all(sends);
            comm.counters().snapshot()
        });
        for snap in results {
            assert_eq!(snap.bytes_sent, 100); // only the off-rank buffer
            assert_eq!(snap.bytes_recv, 100);
            assert_eq!(snap.msgs_sent, 1);
        }
    }

    #[test]
    fn solo_all_to_all() {
        let comm = ThreadComm::solo();
        let recvs = comm.all_to_all(vec![vec![1, 2, 3]]);
        assert_eq!(recvs, vec![vec![1, 2, 3]]);
        assert_eq!(comm.counters().snapshot().bytes_sent, 0);
    }

    #[test]
    fn rma_window_get() {
        let results = run_ranks(2, |comm| {
            comm.publish_window(7, vec![comm.rank() as u8; 16]);
            comm.barrier();
            let other = 1 - comm.rank();
            let got = comm.rma_get(other, 7, 4, 8);
            comm.barrier();
            (got, comm.counters().snapshot())
        });
        for (rank, (got, snap)) in results.iter().enumerate() {
            assert_eq!(got, &vec![(1 - rank) as u8; 8]);
            assert_eq!(snap.bytes_rma, 8);
            assert_eq!(snap.rma_gets, 1);
        }
    }

    #[test]
    fn self_rma_is_free() {
        let comm = ThreadComm::solo();
        comm.publish_window(1, vec![9; 4]);
        let got = comm.rma_get(0, 1, 0, 4);
        assert_eq!(got, vec![9; 4]);
        assert_eq!(comm.counters().snapshot().bytes_rma, 0);
    }

    #[test]
    fn window_len_and_retract() {
        let comm = ThreadComm::solo();
        comm.publish_window(3, vec![0; 10]);
        assert_eq!(comm.window_len(0, 3), Some(10));
        comm.retract_window(3);
        assert_eq!(comm.window_len(0, 3), None);
    }

    #[test]
    #[should_panic]
    fn rma_out_of_bounds_panics() {
        let comm = ThreadComm::solo();
        comm.publish_window(1, vec![0; 4]);
        comm.rma_get(0, 1, 2, 8);
    }

    #[test]
    #[should_panic(expected = "rma_get out of bounds")]
    fn rma_overflowing_range_panics_instead_of_wrapping() {
        // offset + len wraps to 1 under unchecked usize addition, which
        // would satisfy `1 <= win.len()` and read out of bounds in a
        // release build. checked_add must turn it into the same panic
        // an ordinary out-of-range get produces.
        let comm = ThreadComm::solo();
        comm.publish_window(1, vec![0; 4]);
        comm.rma_get(0, 1, usize::MAX, 2);
    }
}
