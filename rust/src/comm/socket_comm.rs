//! Process-per-rank communicator over Unix domain sockets.
//!
//! Each rank is its own OS process (launched by [`super::proc`]). Ranks
//! rendezvous in a shared directory: rank `r` binds a listener at
//! `r{r}.sock`, then opens two stream channels to every peer:
//!
//! - a **data** channel (one direction per ordered pair): collective and
//!   barrier frames from `r` to the peer. `all_to_all` writes one frame
//!   to every other rank, then reads one frame from every other rank;
//!   because every rank issues the same collective sequence (the same
//!   contract `ThreadComm` relies on), frames per pair arrive in order.
//! - an **RMA** channel (request/reply, client side at `r`): `rma_get`,
//!   `window_len`, and `all_counters` become request frames answered by
//!   a server thread on the owning rank, which reads the owner's
//!   published window map. This turns one-sided RMA into request/reply
//!   while keeping the *accounting* identical: fetched bytes are counted
//!   on the requester only (`add_rma`), request/metadata frames are
//!   free, exactly like `ThreadComm`.
//!
//! Every frame is length-prefixed: `[tag: u8][len: u32 LE][payload]`.
//! Request payloads are decoded with `wire::Cursor`, so a truncated or
//! corrupt frame is rejected with a descriptive error reply instead of a
//! panic in the server thread. Reads on data and client channels carry a
//! bounded timeout: a peer process that dies (EOF) or stalls (timeout)
//! mid-collective poisons this rank's communicator and panics with a
//! diagnostic instead of deadlocking. See DESIGN.md §11.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, RwLock};
use std::time::{Duration, Instant};

use super::counters::{CommCounters, CounterSnapshot};
use super::thread_comm::WindowKey;
use crate::fault::FrameAction;
use crate::util::wire::{put_u32, put_u64, put_u8, Cursor};

/// Frame tags. One byte on the wire; grouped by channel.
pub(crate) mod tags {
    /// First frame on any inbound channel: `[rank u32][kind u8]`.
    pub const HELLO: u8 = 1;
    /// One `all_to_all` buffer (data channel).
    pub const COLLECTIVE: u8 = 2;
    /// Barrier token, empty payload (data channel).
    pub const BARRIER: u8 = 3;
    /// `rma_get` request: `[key u32][offset u64][len u64]` (RMA channel).
    pub const RMA_REQ: u8 = 4;
    /// `rma_get` reply: the fetched bytes.
    pub const RMA_OK: u8 = 5;
    /// `window_len` request: `[key u32]`.
    pub const WINLEN_REQ: u8 = 6;
    /// `window_len` reply: `[present u8][len u64]`.
    pub const WINLEN_RESP: u8 = 7;
    /// Counter snapshot request, empty payload.
    pub const CNT_REQ: u8 = 8;
    /// Counter snapshot reply: six `u64`s.
    pub const CNT_RESP: u8 = 9;
    /// Error reply: UTF-8 message. The requester re-panics with it.
    pub const ERR: u8 = 10;
    /// Child → launcher result frame: `[rank u32][bytes]` (control socket).
    pub const RESULT: u8 = 11;
    /// Child → launcher failure frame: `[rank u32][UTF-8 message]`.
    pub const CHILD_ERR: u8 = 12;
    /// Child → launcher heartbeat: `[rank u32][HealthFrame]` (control
    /// socket, `telemetry` module). Never on a peer data channel, never
    /// counted.
    pub const HEARTBEAT: u8 = 13;
}

/// Channel kinds carried in the HELLO frame.
const KIND_DATA: u8 = 0;
const KIND_RMA: u8 = 1;

/// Upper bound on a single frame payload; a corrupt length prefix must
/// not turn into a multi-gigabyte allocation.
pub(crate) const MAX_FRAME: usize = 1 << 30;

/// Bytes of framing added to every payload: `[tag u8][len u32]`.
pub const FRAME_HEADER: usize = 5;

// -- frame codec --------------------------------------------------------

/// Encode one frame: `[tag][len u32 LE][payload]`.
pub fn encode_frame(tag: u8, payload: &[u8]) -> Vec<u8> {
    assert!(payload.len() <= MAX_FRAME, "frame payload exceeds MAX_FRAME");
    let mut out = Vec::with_capacity(FRAME_HEADER + payload.len());
    put_u8(&mut out, tag);
    put_u32(&mut out, payload.len() as u32);
    out.extend_from_slice(payload);
    out
}

/// Decode one complete frame from a byte buffer via checked `Cursor`
/// reads: truncation (in the header or the payload), trailing garbage,
/// and an oversized length prefix are all `Err`, never a panic.
pub fn decode_frame(buf: &[u8]) -> Result<(u8, Vec<u8>), String> {
    let mut c = Cursor::new(buf, "socket frame");
    let tag = c.u8("frame tag")?;
    let len = c.u32("frame length")? as usize;
    if len > MAX_FRAME {
        return Err(format!("socket frame: length {len} exceeds MAX_FRAME"));
    }
    let payload = c.bytes(len, "frame payload")?.to_vec();
    c.finish("frame")?;
    Ok((tag, payload))
}

/// Write one frame to a stream.
pub(crate) fn write_frame(mut stream: &UnixStream, tag: u8, payload: &[u8]) -> std::io::Result<()> {
    stream.write_all(&encode_frame(tag, payload))?;
    stream.flush()
}

/// Write one `HEARTBEAT` control frame — the telemetry module's only
/// touchpoint with the frame codec (keeps `write_frame` and the tag
/// table crate-private to `comm`).
pub(crate) fn beat_wire(stream: &UnixStream, framed: &[u8]) -> std::io::Result<()> {
    write_frame(stream, tags::HEARTBEAT, framed)
}

/// Read one frame from a stream (blocking, honoring any read timeout set
/// on the socket). EOF, timeout, and a corrupt length prefix are errors.
pub(crate) fn read_frame(mut stream: &UnixStream) -> std::io::Result<(u8, Vec<u8>)> {
    let mut header = [0u8; FRAME_HEADER];
    stream.read_exact(&mut header)?;
    let tag = header[0];
    let len = u32::from_le_bytes(header[1..5].try_into().unwrap()) as usize;
    if len > MAX_FRAME {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("socket frame: length {len} exceeds MAX_FRAME"),
        ));
    }
    let mut payload = vec![0u8; len];
    stream.read_exact(&mut payload)?;
    Ok((tag, payload))
}

pub(crate) fn encode_snapshot(s: &CounterSnapshot) -> Vec<u8> {
    let mut out = Vec::with_capacity(48);
    put_u64(&mut out, s.bytes_sent);
    put_u64(&mut out, s.bytes_recv);
    put_u64(&mut out, s.bytes_rma);
    put_u64(&mut out, s.msgs_sent);
    put_u64(&mut out, s.collectives);
    put_u64(&mut out, s.rma_gets);
    out
}

pub(crate) fn decode_snapshot(buf: &[u8]) -> Result<CounterSnapshot, String> {
    let mut c = Cursor::new(buf, "counter snapshot");
    let s = CounterSnapshot {
        bytes_sent: c.u64("bytes_sent")?,
        bytes_recv: c.u64("bytes_recv")?,
        bytes_rma: c.u64("bytes_rma")?,
        msgs_sent: c.u64("msgs_sent")?,
        collectives: c.u64("collectives")?,
        rma_gets: c.u64("rma_gets")?,
    };
    c.finish("counter snapshot")?;
    Ok(s)
}

// -- the communicator ---------------------------------------------------

type Windows = Arc<RwLock<HashMap<WindowKey, Arc<Vec<u8>>>>>;

/// One rank's endpoint of a process-per-rank socket communicator.
pub struct SocketComm {
    rank: usize,
    size: usize,
    counters: Arc<CommCounters>,
    windows: Windows,
    poisoned: Arc<AtomicBool>,
    /// Outbound data channel to each peer (`None` at `self.rank`).
    data_out: Vec<Option<UnixStream>>,
    /// Inbound data channel from each peer.
    data_in: Vec<Option<UnixStream>>,
    /// Request/reply client channel to each peer's RMA server thread.
    rma_out: Vec<Option<UnixStream>>,
    /// Comm latency histograms for calls made through the `Comm` trait.
    /// Observability-only; never part of `CommCounters` accounting.
    hists: crate::metrics::histogram::CommHists,
}

fn connect_retry(path: &Path, deadline: Instant, rank: usize) -> std::io::Result<UnixStream> {
    // Capped exponential backoff with a deterministic, rank-derived
    // jitter. After a supervised recovery the whole fleet re-executes
    // and re-dials in near-lockstep; the jitter de-synchronizes the
    // retry storm without introducing nondeterminism (same rank, same
    // offset, every run).
    let jitter = Duration::from_micros(((rank as u64).wrapping_mul(2_654_435_761) >> 16) % 8_000);
    let mut backoff = Duration::from_millis(1);
    loop {
        match UnixStream::connect(path) {
            Ok(s) => return Ok(s),
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::NotFound | std::io::ErrorKind::ConnectionRefused
                ) && Instant::now() < deadline =>
            {
                let remaining = deadline.saturating_duration_since(Instant::now());
                std::thread::sleep((backoff + jitter).min(remaining));
                backoff = (backoff * 2).min(Duration::from_millis(32));
            }
            Err(e) => {
                return Err(std::io::Error::new(
                    e.kind(),
                    format!("connecting {}: {e}", path.display()),
                ))
            }
        }
    }
}

fn io_invalid(msg: String) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, msg)
}

/// Serve one peer's RMA request/reply channel. Runs on a detached thread
/// owned by the window-owning rank; exits when the peer hangs up.
/// Malformed request frames get an `ERR` reply (checked `Cursor`
/// decoding), never a panic: a corrupt peer must not take the owner
/// down with it.
fn serve_rma(stream: UnixStream, windows: Windows, counters: Arc<CommCounters>, my_rank: usize) {
    loop {
        let (tag, payload) = match read_frame(&stream) {
            Ok(f) => f,
            Err(_) => return, // peer closed (or died): server retires
        };
        let (rtag, reply) = match tag {
            tags::RMA_REQ => match serve_rma_get(&payload, &windows, my_rank) {
                Ok(bytes) => (tags::RMA_OK, bytes),
                Err(msg) => (tags::ERR, msg.into_bytes()),
            },
            tags::WINLEN_REQ => match serve_window_len(&payload, &windows) {
                Ok(bytes) => (tags::WINLEN_RESP, bytes),
                Err(msg) => (tags::ERR, msg.into_bytes()),
            },
            tags::CNT_REQ => (tags::CNT_RESP, encode_snapshot(&counters.snapshot())),
            other => (
                tags::ERR,
                format!("rank {my_rank}: unexpected frame tag {other} on RMA channel").into_bytes(),
            ),
        };
        // Injected RMA stall: hold the reply back so the requester's
        // read-timeout path (bounded waits, DESIGN.md §11) is exercised
        // deterministically.
        if rtag == tags::RMA_OK {
            if let Some(millis) = crate::fault::on_rma_reply() {
                std::thread::sleep(Duration::from_millis(millis));
            }
        }
        if write_frame(&stream, rtag, &reply).is_err() {
            return;
        }
    }
}

fn serve_rma_get(payload: &[u8], windows: &Windows, my_rank: usize) -> Result<Vec<u8>, String> {
    let mut c = Cursor::new(payload, "rma_get request");
    let key = c.u32("window key")?;
    let offset = c.u64("offset")? as usize;
    let len = c.u64("length")? as usize;
    c.finish("rma_get request")?;
    let win = windows
        .read()
        .unwrap()
        .get(&key)
        .cloned()
        .ok_or_else(|| format!("rank {my_rank} has no window {key}"))?;
    let end = offset
        .checked_add(len)
        .ok_or_else(|| format!("rma_get out of bounds: {offset}+{len} overflows usize"))?;
    if end > win.len() {
        return Err(format!("rma_get out of bounds: {}+{} > {}", offset, len, win.len()));
    }
    Ok(win[offset..end].to_vec())
}

fn serve_window_len(payload: &[u8], windows: &Windows) -> Result<Vec<u8>, String> {
    let mut c = Cursor::new(payload, "window_len request");
    let key = c.u32("window key")?;
    c.finish("window_len request")?;
    let len = windows.read().unwrap().get(&key).map(|w| w.len());
    let mut out = Vec::with_capacity(9);
    put_u8(&mut out, len.is_some() as u8);
    put_u64(&mut out, len.unwrap_or(0) as u64);
    Ok(out)
}

impl SocketComm {
    /// Join the communicator rendezvousing in `dir`: bind this rank's
    /// listener, open data + RMA channels to every peer, and start the
    /// RMA server threads. `timeout` bounds both the rendezvous and
    /// every subsequent peer read (the anti-deadlock budget).
    pub fn connect(
        rank: usize,
        size: usize,
        dir: &Path,
        timeout: Duration,
    ) -> std::io::Result<SocketComm> {
        assert!(size > 0, "communicator needs at least one rank");
        assert!(rank < size, "rank {rank} out of range for size {size}");
        let mut comm = SocketComm {
            rank,
            size,
            counters: Arc::new(CommCounters::default()),
            windows: Arc::new(RwLock::new(HashMap::new())),
            poisoned: Arc::new(AtomicBool::new(false)),
            data_out: (0..size).map(|_| None).collect(),
            data_in: (0..size).map(|_| None).collect(),
            rma_out: (0..size).map(|_| None).collect(),
            hists: crate::metrics::histogram::CommHists::default(),
        };
        if size == 1 {
            return Ok(comm); // solo: every operation is local
        }
        let deadline = Instant::now() + timeout;
        let listener = UnixListener::bind(dir.join(format!("r{rank}.sock")))?;
        listener.set_nonblocking(true)?;

        // Outbound: a data channel and an RMA client channel per peer.
        // Peers that have not bound yet are retried until the deadline.
        for peer in 0..size {
            if peer == rank {
                continue;
            }
            let path = dir.join(format!("r{peer}.sock"));
            for kind in [KIND_DATA, KIND_RMA] {
                let stream = connect_retry(&path, deadline, rank)?;
                let mut hello = Vec::with_capacity(5);
                put_u32(&mut hello, rank as u32);
                put_u8(&mut hello, kind);
                write_frame(&stream, tags::HELLO, &hello)?;
                if kind == KIND_DATA {
                    comm.data_out[peer] = Some(stream);
                } else {
                    stream.set_read_timeout(Some(timeout))?;
                    comm.rma_out[peer] = Some(stream);
                }
            }
        }

        // Inbound: accept the mirror-image channels and classify them by
        // their HELLO frame. The listener is non-blocking so a peer that
        // never arrives turns into a rendezvous timeout, not a hang.
        let mut pending = 2 * (size - 1);
        while pending > 0 {
            match listener.accept() {
                Ok((stream, _)) => {
                    stream.set_nonblocking(false)?;
                    let grace = deadline
                        .saturating_duration_since(Instant::now())
                        .max(Duration::from_millis(10));
                    stream.set_read_timeout(Some(grace))?;
                    let (tag, payload) = read_frame(&stream)?;
                    if tag != tags::HELLO {
                        return Err(io_invalid(format!("expected HELLO frame, got tag {tag}")));
                    }
                    let mut c = Cursor::new(&payload, "hello frame");
                    let peer = c.u32("peer rank").map_err(io_invalid)? as usize;
                    let kind = c.u8("channel kind").map_err(io_invalid)?;
                    c.finish("hello frame").map_err(io_invalid)?;
                    if peer >= size || peer == rank {
                        return Err(io_invalid(format!("bad HELLO peer rank {peer}")));
                    }
                    match kind {
                        KIND_DATA => {
                            if comm.data_in[peer].is_some() {
                                return Err(io_invalid(format!(
                                    "duplicate data channel from rank {peer}"
                                )));
                            }
                            stream.set_read_timeout(Some(timeout))?;
                            comm.data_in[peer] = Some(stream);
                        }
                        KIND_RMA => {
                            // The server blocks indefinitely between
                            // requests; it retires on peer hang-up.
                            stream.set_read_timeout(None)?;
                            let windows = Arc::clone(&comm.windows);
                            let counters = Arc::clone(&comm.counters);
                            std::thread::spawn(move || {
                                serve_rma(stream, windows, counters, rank)
                            });
                        }
                        other => {
                            return Err(io_invalid(format!("bad HELLO channel kind {other}")))
                        }
                    }
                    pending -= 1;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        return Err(std::io::Error::new(
                            std::io::ErrorKind::TimedOut,
                            format!(
                                "rank {rank}: rendezvous timed out with {pending} channels missing"
                            ),
                        ));
                    }
                    std::thread::sleep(Duration::from_millis(1));
                }
                Err(e) => return Err(e),
            }
        }
        Ok(comm)
    }

    fn send_data(&self, dst: usize, tag: u8, payload: &[u8], ctx: &str) {
        let stream = self.data_out[dst].as_ref().expect("no data channel to peer");
        // Deterministic fault injection (a no-op unless a plan is armed
        // in this process): the hook counts outbound data frames and
        // can delay one or cut it off mid-frame.
        match crate::fault::on_data_frame() {
            FrameAction::Pass => {}
            FrameAction::Delay { millis } => std::thread::sleep(Duration::from_millis(millis)),
            FrameAction::Truncate { keep } => {
                let frame = encode_frame(tag, payload);
                let keep = (keep as usize).min(frame.len());
                let mut partial: &UnixStream = stream;
                let _ = partial.write_all(&frame[..keep]);
                let _ = partial.flush();
                let _ = stream.shutdown(std::net::Shutdown::Write);
                self.poison_now();
                panic!(
                    "rank {}: fault injection truncated a frame to {keep} bytes during {ctx}; \
                     communicator poisoned",
                    self.rank
                );
            }
        }
        if let Err(e) = write_frame(stream, tag, payload) {
            self.poison_now();
            panic!(
                "rank {}: peer rank {dst} unreachable during {ctx} ({e}); communicator poisoned",
                self.rank
            );
        }
    }

    fn recv_data(&self, src: usize, expect: u8, ctx: &str) -> Vec<u8> {
        let stream = self.data_in[src].as_ref().expect("no data channel from peer");
        match read_frame(stream) {
            Ok((tag, payload)) if tag == expect => payload,
            Ok((tag, _)) => {
                self.poison_now();
                panic!(
                    "rank {}: collective sequence diverged in {ctx}: got frame tag {tag} \
                     from rank {src}; communicator poisoned",
                    self.rank
                );
            }
            Err(e) => {
                self.poison_now();
                panic!(
                    "rank {}: peer rank {src} unreachable during {ctx} ({e}); \
                     communicator poisoned",
                    self.rank
                );
            }
        }
    }

    /// One request/reply round on the RMA channel to `target`. An `ERR`
    /// reply re-panics with the owner's message verbatim so failure
    /// modes (missing window, out-of-bounds get) read identically to
    /// `ThreadComm`'s; transport failures poison first.
    fn rma_request(&self, target: usize, tag: u8, payload: &[u8], expect: u8, ctx: &str) -> Vec<u8> {
        let stream = self.rma_out[target].as_ref().expect("no RMA channel to peer");
        if let Err(e) = write_frame(stream, tag, payload) {
            self.poison_now();
            panic!(
                "rank {}: peer rank {target} unreachable during {ctx} ({e}); \
                 communicator poisoned",
                self.rank
            );
        }
        match read_frame(stream) {
            Ok((t, p)) if t == expect => p,
            Ok((t, p)) if t == tags::ERR => panic!("{}", String::from_utf8_lossy(&p)),
            Ok((t, _)) => {
                self.poison_now();
                panic!(
                    "rank {}: protocol mismatch in {ctx}: got frame tag {t} from rank {target}; \
                     communicator poisoned",
                    self.rank
                );
            }
            Err(e) => {
                self.poison_now();
                panic!(
                    "rank {}: peer rank {target} unreachable during {ctx} ({e}); \
                     communicator poisoned",
                    self.rank
                );
            }
        }
    }

    fn poison_now(&self) {
        self.poisoned.store(true, Ordering::SeqCst);
    }
}

impl super::Comm for SocketComm {
    fn rank(&self) -> usize {
        self.rank
    }

    fn size(&self) -> usize {
        self.size
    }

    /// Full-mesh barrier: send a token to every peer, collect one from
    /// every peer. A rank can only pass once all peers have entered —
    /// the same post/consume discipline as `ThreadComm`'s `Barrier`.
    /// Uncounted, like every synchronization-only operation.
    fn barrier(&self) {
        self.hists.barrier.time(|| {
            for dst in 0..self.size {
                if dst != self.rank {
                    self.send_data(dst, tags::BARRIER, &[], "barrier");
                }
            }
            for src in 0..self.size {
                if src != self.rank {
                    self.recv_data(src, tags::BARRIER, "barrier");
                }
            }
        })
    }

    fn all_to_all(&self, mut sends: Vec<Vec<u8>>) -> Vec<Vec<u8>> {
        self.hists.a2a.time(|| {
            let size = self.size;
            assert_eq!(sends.len(), size, "all_to_all needs one buffer per rank");
            let me = self.rank;
            self.counters.add_collective();
            let mut own = Some(std::mem::take(&mut sends[me]));
            for (dst, buf) in sends.iter().enumerate() {
                if dst == me {
                    continue;
                }
                self.counters.add_sent(buf.len() as u64);
                self.send_data(dst, tags::COLLECTIVE, buf, "all_to_all");
            }
            let mut recvs = Vec::with_capacity(size);
            for src in 0..size {
                if src == me {
                    recvs.push(own.take().expect("self buffer consumed twice"));
                    continue;
                }
                let buf = self.recv_data(src, tags::COLLECTIVE, "all_to_all");
                self.counters.add_recv(buf.len() as u64);
                recvs.push(buf);
            }
            recvs
        })
    }

    fn publish_window(&self, key: WindowKey, data: Vec<u8>) {
        self.windows.write().unwrap().insert(key, Arc::new(data));
    }

    fn retract_window(&self, key: WindowKey) {
        self.windows.write().unwrap().remove(&key);
    }

    fn rma_get(&self, target: usize, key: WindowKey, offset: usize, len: usize) -> Vec<u8> {
        // Every call is sampled — self-gets too, so histogram totals
        // stay deterministic call counts matching ThreadComm's.
        self.hists.rma.time(|| self.rma_get_inner(target, key, offset, len))
    }

    fn window_len(&self, target: usize, key: WindowKey) -> Option<usize> {
        SocketComm::window_len_inner(self, target, key)
    }

    fn counters(&self) -> &CommCounters {
        &self.counters
    }

    fn all_counters(&self) -> Vec<CounterSnapshot> {
        SocketComm::all_counters_inner(self)
    }

    fn comm_hists(&self) -> crate::metrics::histogram::CommHistSnapshot {
        self.hists.snapshot()
    }

    fn poison(&self) {
        self.poison_now();
    }

    fn is_poisoned(&self) -> bool {
        self.poisoned.load(Ordering::SeqCst)
    }
}

impl SocketComm {
    fn rma_get_inner(&self, target: usize, key: WindowKey, offset: usize, len: usize) -> Vec<u8> {
        // checked_add on the requester, before any wire traffic: the
        // same guard (and message) as ThreadComm's.
        let end = offset.checked_add(len).unwrap_or_else(|| {
            panic!("rma_get out of bounds: {offset}+{len} overflows usize")
        });
        if target == self.rank {
            // Bind before panicking: unwinding with the read-guard
            // temporary alive would poison the windows lock the RMA
            // server threads share (see ThreadComm::rma_get).
            let win = self.windows.read().unwrap().get(&key).cloned();
            let win =
                win.unwrap_or_else(|| panic!("rank {} has no window {key}", target));
            assert!(
                end <= win.len(),
                "rma_get out of bounds: {}+{} > {}",
                offset,
                len,
                win.len()
            );
            return win[offset..end].to_vec(); // self-gets are free
        }
        let mut req = Vec::with_capacity(20);
        put_u32(&mut req, key);
        put_u64(&mut req, offset as u64);
        put_u64(&mut req, len as u64);
        let bytes = self.rma_request(target, tags::RMA_REQ, &req, tags::RMA_OK, "rma_get");
        debug_assert_eq!(bytes.len(), len, "rma_get reply length mismatch");
        self.counters.add_rma(len as u64);
        bytes
    }

    fn window_len_inner(&self, target: usize, key: WindowKey) -> Option<usize> {
        if target == self.rank {
            return self.windows.read().unwrap().get(&key).map(|w| w.len());
        }
        let mut req = Vec::with_capacity(4);
        put_u32(&mut req, key);
        let resp = self.rma_request(target, tags::WINLEN_REQ, &req, tags::WINLEN_RESP, "window_len");
        let parsed = (|| -> Result<Option<u64>, String> {
            let mut c = Cursor::new(&resp, "window_len reply");
            let present = c.u8("present")?;
            let len = c.u64("length")?;
            c.finish("window_len reply")?;
            Ok((present != 0).then_some(len))
        })();
        match parsed {
            Ok(len) => len.map(|l| l as usize),
            Err(e) => {
                self.poison_now();
                panic!(
                    "rank {}: malformed window_len reply from rank {target}: {e}; \
                     communicator poisoned",
                    self.rank
                );
            }
        }
    }

    fn all_counters_inner(&self) -> Vec<CounterSnapshot> {
        let mut out = Vec::with_capacity(self.size);
        for r in 0..self.size {
            if r == self.rank {
                out.push(self.counters.snapshot());
                continue;
            }
            let resp = self.rma_request(r, tags::CNT_REQ, &[], tags::CNT_RESP, "all_counters");
            match decode_snapshot(&resp) {
                Ok(s) => out.push(s),
                Err(e) => {
                    self.poison_now();
                    panic!(
                        "rank {}: malformed counter snapshot from rank {r}: {e}; \
                         communicator poisoned",
                        self.rank
                    );
                }
            }
        }
        out
    }
}

// -- in-process harness -------------------------------------------------

static DIR_SEQ: AtomicU64 = AtomicU64::new(0);

/// A fresh, short, unique rendezvous directory (UDS paths are limited to
/// ~108 bytes, so this stays under the system temp dir).
pub(crate) fn fresh_rendezvous_dir(label: &str) -> std::io::Result<PathBuf> {
    let n = DIR_SEQ.fetch_add(1, Ordering::Relaxed);
    let dir = std::env::temp_dir().join(format!("ilmi-{label}{}-{n}", std::process::id()));
    std::fs::create_dir_all(&dir)?;
    Ok(dir)
}

/// Removes the rendezvous directory when dropped, so every exit path —
/// normal return, `?` error propagation, and panics unwinding through
/// the owning frame (including `resume_unwind` re-raises) — cleans up.
/// Leaked rendezvous dirs were exactly how repeated failure-path runs
/// used to litter the temp dir.
pub(crate) struct RendezvousDirGuard(pub PathBuf);

impl Drop for RendezvousDirGuard {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

/// Run `f` on `size` ranks, each with a `SocketComm`, hosted on threads
/// of this process: the full socket transport (frames, UDS, RMA server
/// threads) without the process launcher. The drop-in socket twin of
/// [`super::run_ranks`], used by the differential and property suites;
/// end-to-end process isolation is exercised via [`super::proc`].
pub fn socket_ranks<R, F>(size: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(SocketComm) -> R + Send + Sync,
{
    // Drop guard, not a trailing remove: a rank panic re-raised by
    // `resume_unwind` below used to skip cleanup and leak the dir.
    let guard = RendezvousDirGuard(fresh_rendezvous_dir("sr").expect("creating rendezvous dir"));
    let dir = &guard.0;
    let timeout = Duration::from_secs(30);
    let mut results: Vec<Option<R>> = (0..size).map(|_| None).collect();
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(size);
        for (rank, slot) in results.iter_mut().enumerate() {
            let f = &f;
            handles.push(scope.spawn(move || {
                let comm = SocketComm::connect(rank, size, dir, timeout)
                    .unwrap_or_else(|e| panic!("rank {rank}: socket rendezvous failed: {e}"));
                *slot = Some(f(comm));
            }));
        }
        let mut panic: Option<Box<dyn std::any::Any + Send>> = None;
        for h in handles {
            if let Err(e) = h.join() {
                panic = Some(e);
            }
        }
        if let Some(e) = panic {
            std::panic::resume_unwind(e);
        }
    });
    results.into_iter().map(|r| r.expect("rank produced no result")).collect()
}

#[cfg(test)]
mod tests {
    use super::super::Comm;
    use super::*;

    #[test]
    fn frame_roundtrip() {
        let buf = encode_frame(tags::COLLECTIVE, b"hello");
        assert_eq!(buf.len(), FRAME_HEADER + 5);
        let (tag, payload) = decode_frame(&buf).unwrap();
        assert_eq!(tag, tags::COLLECTIVE);
        assert_eq!(payload, b"hello");
    }

    #[test]
    fn truncated_frames_are_errors_not_panics() {
        let buf = encode_frame(tags::RMA_REQ, &[1, 2, 3, 4, 5, 6, 7, 8]);
        // Every proper prefix must fail with a descriptive error.
        for cut in 0..buf.len() {
            let err = decode_frame(&buf[..cut]).unwrap_err();
            assert!(err.contains("truncated"), "cut {cut}: {err}");
        }
        decode_frame(&buf).unwrap();
    }

    #[test]
    fn oversized_length_prefix_is_rejected() {
        let mut buf = vec![tags::COLLECTIVE];
        buf.extend_from_slice(&u32::MAX.to_le_bytes());
        let err = decode_frame(&buf).unwrap_err();
        assert!(err.contains("MAX_FRAME"), "{err}");
    }

    #[test]
    fn trailing_garbage_is_rejected() {
        let mut buf = encode_frame(tags::BARRIER, &[]);
        buf.push(0xFF);
        assert!(decode_frame(&buf).unwrap_err().contains("trailing"));
    }

    #[test]
    fn malformed_rma_request_gets_error_reply_shape() {
        // The server-side decoder itself: a truncated request payload is
        // a clean Err (which serve_rma turns into an ERR reply frame).
        let windows: Windows = Arc::new(RwLock::new(HashMap::new()));
        let err = serve_rma_get(&[1, 2, 3], &windows, 0).unwrap_err();
        assert!(err.contains("truncated"), "{err}");
    }

    #[test]
    fn socket_all_to_all_routes_buffers() {
        let results = socket_ranks(3, |comm| {
            let sends: Vec<Vec<u8>> =
                (0..3).map(|d| vec![comm.rank() as u8, d as u8]).collect();
            comm.all_to_all(sends)
        });
        for (rank, recvs) in results.iter().enumerate() {
            for (src, buf) in recvs.iter().enumerate() {
                assert_eq!(buf, &vec![src as u8, rank as u8]);
            }
        }
    }

    #[test]
    fn socket_counters_match_thread_accounting() {
        let results = socket_ranks(2, |comm| {
            comm.all_to_all(vec![vec![0; 100], vec![0; 100]]);
            comm.counters().snapshot()
        });
        for snap in results {
            assert_eq!(snap.bytes_sent, 100); // only the off-rank buffer
            assert_eq!(snap.bytes_recv, 100);
            assert_eq!(snap.msgs_sent, 1);
            assert_eq!(snap.collectives, 1);
        }
    }

    #[test]
    fn socket_rma_window_get() {
        let results = socket_ranks(2, |comm| {
            comm.publish_window(7, vec![comm.rank() as u8; 16]);
            comm.barrier();
            let other = 1 - comm.rank();
            assert_eq!(comm.window_len(other, 7), Some(16));
            assert_eq!(comm.window_len(other, 99), None);
            let got = comm.rma_get(other, 7, 4, 8);
            comm.barrier();
            (got, comm.counters().snapshot())
        });
        for (rank, (got, snap)) in results.iter().enumerate() {
            assert_eq!(got, &vec![(1 - rank) as u8; 8]);
            assert_eq!(snap.bytes_rma, 8);
            assert_eq!(snap.rma_gets, 1);
        }
    }

    #[test]
    fn socket_all_counters_gathers_every_rank() {
        let results = socket_ranks(3, |comm| {
            let mut sends = vec![Vec::new(); 3];
            sends[(comm.rank() + 1) % 3] = vec![0; 10 * (comm.rank() + 1)];
            comm.all_to_all(sends);
            comm.barrier(); // quiesce so the snapshot cut is deterministic
            comm.all_counters()
        });
        for all in &results {
            assert_eq!(all.len(), 3);
            for (r, snap) in all.iter().enumerate() {
                assert_eq!(snap.bytes_sent, 10 * (r as u64 + 1));
                assert_eq!(snap.collectives, 1);
            }
        }
    }

    #[test]
    fn socket_solo_is_fully_local() {
        let dir = fresh_rendezvous_dir("solo").unwrap();
        let comm = SocketComm::connect(0, 1, &dir, Duration::from_secs(5)).unwrap();
        let recvs = comm.all_to_all(vec![vec![1, 2, 3]]);
        assert_eq!(recvs, vec![vec![1, 2, 3]]);
        comm.publish_window(1, vec![9; 4]);
        assert_eq!(comm.rma_get(0, 1, 0, 4), vec![9; 4]);
        let snap = comm.counters().snapshot();
        assert_eq!(snap.bytes_sent, 0);
        assert_eq!(snap.bytes_rma, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn socket_repeated_collectives_do_not_cross() {
        let results = socket_ranks(4, |comm| {
            let mut sums = Vec::new();
            for round in 0..10u8 {
                let sends: Vec<Vec<u8>> = (0..4).map(|_| vec![round]).collect();
                let recvs = comm.all_to_all(sends);
                sums.push(recvs.iter().map(|b| b[0] as u32).sum::<u32>());
            }
            sums
        });
        for sums in results {
            assert_eq!(sums, (0..10).map(|r| 4 * r).collect::<Vec<u32>>());
        }
    }
}
