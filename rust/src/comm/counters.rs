//! Per-rank communication accounting.
//!
//! Matches the paper's Tables I/II accounting: bytes sent/received over
//! collectives (self-delivery is free, exactly as a rank's copy to itself
//! costs no network traffic) and bytes fetched through RMA. Message and
//! collective counts feed the latency analysis in the bench harness.

use std::sync::atomic::{AtomicU64, Ordering};

#[derive(Default, Debug)]
pub struct CommCounters {
    bytes_sent: AtomicU64,
    bytes_recv: AtomicU64,
    bytes_rma: AtomicU64,
    msgs_sent: AtomicU64,
    collectives: AtomicU64,
    rma_gets: AtomicU64,
}

/// A plain-data copy of the counters at one point in time.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CounterSnapshot {
    pub bytes_sent: u64,
    pub bytes_recv: u64,
    pub bytes_rma: u64,
    pub msgs_sent: u64,
    pub collectives: u64,
    pub rma_gets: u64,
}

impl CommCounters {
    pub fn add_sent(&self, bytes: u64) {
        self.bytes_sent.fetch_add(bytes, Ordering::Relaxed);
        if bytes > 0 {
            self.msgs_sent.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn add_recv(&self, bytes: u64) {
        self.bytes_recv.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn add_rma(&self, bytes: u64) {
        self.bytes_rma.fetch_add(bytes, Ordering::Relaxed);
        self.rma_gets.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add_collective(&self) {
        self.collectives.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> CounterSnapshot {
        CounterSnapshot {
            bytes_sent: self.bytes_sent.load(Ordering::Relaxed),
            bytes_recv: self.bytes_recv.load(Ordering::Relaxed),
            bytes_rma: self.bytes_rma.load(Ordering::Relaxed),
            msgs_sent: self.msgs_sent.load(Ordering::Relaxed),
            collectives: self.collectives.load(Ordering::Relaxed),
            rma_gets: self.rma_gets.load(Ordering::Relaxed),
        }
    }

    pub fn reset(&self) {
        self.bytes_sent.store(0, Ordering::Relaxed);
        self.bytes_recv.store(0, Ordering::Relaxed);
        self.bytes_rma.store(0, Ordering::Relaxed);
        self.msgs_sent.store(0, Ordering::Relaxed);
        self.collectives.store(0, Ordering::Relaxed);
        self.rma_gets.store(0, Ordering::Relaxed);
    }
}

impl CounterSnapshot {
    /// Difference since an earlier snapshot. Counters are monotone
    /// within a communicator's lifetime, so a baseline exceeding the
    /// current snapshot means the caller mixed up snapshot order (or
    /// mixed communicators, e.g. across a restore) — debug builds
    /// assert, release builds saturate to zero instead of wrapping to
    /// a ~2^64 "delta".
    pub fn since(&self, earlier: &CounterSnapshot) -> CounterSnapshot {
        debug_assert!(
            self.bytes_sent >= earlier.bytes_sent
                && self.bytes_recv >= earlier.bytes_recv
                && self.bytes_rma >= earlier.bytes_rma
                && self.msgs_sent >= earlier.msgs_sent
                && self.collectives >= earlier.collectives
                && self.rma_gets >= earlier.rma_gets,
            "since(): baseline exceeds current snapshot ({earlier:?} > {self:?})"
        );
        CounterSnapshot {
            bytes_sent: self.bytes_sent.saturating_sub(earlier.bytes_sent),
            bytes_recv: self.bytes_recv.saturating_sub(earlier.bytes_recv),
            bytes_rma: self.bytes_rma.saturating_sub(earlier.bytes_rma),
            msgs_sent: self.msgs_sent.saturating_sub(earlier.msgs_sent),
            collectives: self.collectives.saturating_sub(earlier.collectives),
            rma_gets: self.rma_gets.saturating_sub(earlier.rma_gets),
        }
    }

    /// Elementwise sum (aggregating over ranks).
    pub fn merge(&self, other: &CounterSnapshot) -> CounterSnapshot {
        CounterSnapshot {
            bytes_sent: self.bytes_sent + other.bytes_sent,
            bytes_recv: self.bytes_recv + other.bytes_recv,
            bytes_rma: self.bytes_rma + other.bytes_rma,
            msgs_sent: self.msgs_sent + other.msgs_sent,
            collectives: self.collectives + other.collectives,
            rma_gets: self.rma_gets + other.rma_gets,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accounting_and_snapshot() {
        let c = CommCounters::default();
        c.add_sent(100);
        c.add_sent(0); // zero-byte sends are not messages
        c.add_recv(50);
        c.add_rma(17);
        c.add_collective();
        let s = c.snapshot();
        assert_eq!(s.bytes_sent, 100);
        assert_eq!(s.msgs_sent, 1);
        assert_eq!(s.bytes_recv, 50);
        assert_eq!(s.bytes_rma, 17);
        assert_eq!(s.rma_gets, 1);
        assert_eq!(s.collectives, 1);
    }

    #[test]
    fn since_and_merge() {
        let c = CommCounters::default();
        c.add_sent(10);
        let before = c.snapshot();
        c.add_sent(30);
        let diff = c.snapshot().since(&before);
        assert_eq!(diff.bytes_sent, 30);
        assert_eq!(diff.msgs_sent, 1);
        let merged = before.merge(&diff);
        assert_eq!(merged.bytes_sent, 40);
    }

    #[test]
    fn since_with_misordered_snapshots_saturates_instead_of_wrapping() {
        let newer = CounterSnapshot { bytes_sent: 10, msgs_sent: 1, ..Default::default() };
        let older = CounterSnapshot { bytes_sent: 50, msgs_sent: 5, ..Default::default() };
        if cfg!(debug_assertions) {
            // Debug builds flag the programming error loudly.
            let r = std::panic::catch_unwind(|| newer.since(&older));
            assert!(r.is_err(), "debug since() must assert on a misordered baseline");
        } else {
            // Release builds degrade to an empty delta, never a ~2^64 one.
            let d = newer.since(&older);
            assert_eq!(d.bytes_sent, 0);
            assert_eq!(d.msgs_sent, 0);
        }
        // Well-ordered snapshots are unaffected.
        assert_eq!(older.since(&newer.since(&newer)).bytes_sent, 50);
    }

    #[test]
    fn reset_clears() {
        let c = CommCounters::default();
        c.add_sent(10);
        c.reset();
        assert_eq!(c.snapshot(), CounterSnapshot::default());
    }
}
