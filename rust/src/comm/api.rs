//! The `Comm` trait: the communicator surface the simulation is written
//! against.
//!
//! Every algorithm in this repo (spike exchange, Barnes–Hut formation,
//! deletion notification, migration, snapshot capture) talks to its
//! peers through exactly this surface. Backends differ only in *how*
//! bytes move — shared-memory slots between threads (`ThreadComm`) or
//! length-prefixed frames over Unix domain sockets between processes
//! (`SocketComm`) — never in who-talks-to-whom, message counts, or byte
//! volumes. That invariant is what makes `CommCounters` accounting and
//! simulation trajectories bit-identical across backends, and it is
//! pinned by the cross-backend differential suite
//! (`rust/tests/integration_comm_backends.rs`).
//!
//! Contract notes (DESIGN.md §11):
//! - `all_to_all` is collective: every rank must call it the same number
//!   of times with one buffer per rank. Self-delivery is free; bytes
//!   between distinct ranks are counted (`add_sent`/`add_recv`), and the
//!   collective itself is counted once on each rank.
//! - `rma_get` is one-sided from the *caller's* accounting perspective:
//!   remotely-fetched bytes are attributed to the requester
//!   (`add_rma`), self-gets are free. Callers synchronize publication
//!   with a collective or `barrier` (like `MPI_Win_fence`).
//! - `barrier`, `window_len`, `counters`, and `all_counters` are
//!   uncounted metadata/synchronization operations.
//! - `poison`/`is_poisoned`: a failing rank marks the communicator so
//!   peers (and the harness) can distinguish "peer crashed" from a local
//!   logic error instead of deadlocking.

use super::counters::{CommCounters, CounterSnapshot};
use super::thread_comm::WindowKey;
use crate::metrics::histogram::CommHistSnapshot;

/// A simulated-MPI communicator endpoint for one rank. See the module
/// docs for the accounting and synchronization contract every backend
/// must satisfy bit-for-bit.
pub trait Comm {
    /// This rank's id in `0..size()`.
    fn rank(&self) -> usize;

    /// Number of ranks in the communicator.
    fn size(&self) -> usize;

    /// Synchronize all ranks (uncounted).
    fn barrier(&self);

    /// Synchronous all-to-all: `sends[d]` is delivered to rank `d`;
    /// returns `recvs[s]` = buffer sent by rank `s`. Bytes moving
    /// between distinct ranks are counted; self-delivery is free.
    fn all_to_all(&self, sends: Vec<Vec<u8>>) -> Vec<Vec<u8>>;

    /// Publish (replace) an RMA window under `key`. Visible to other
    /// ranks after the next synchronization point (caller synchronizes,
    /// like `MPI_Win_fence`).
    fn publish_window(&self, key: WindowKey, data: Vec<u8>);

    /// Remove a published window.
    fn retract_window(&self, key: WindowKey);

    /// One-sided get: copy `len` bytes at `offset` from `target`'s
    /// window. Counted as remotely-accessed bytes on the *calling* rank;
    /// self-gets are free. Panics (with the same message shapes on every
    /// backend) on a missing window, an out-of-range `offset + len`, or
    /// a range that overflows `usize`.
    fn rma_get(&self, target: usize, key: WindowKey, offset: usize, len: usize) -> Vec<u8>;

    /// Size in bytes of `target`'s window (free metadata peek used to
    /// bound fetches; not counted).
    fn window_len(&self, target: usize, key: WindowKey) -> Option<usize>;

    /// This rank's counter handle.
    fn counters(&self) -> &CommCounters;

    /// Snapshot of every rank's counters, indexed by rank (uncounted;
    /// callers quiesce with a `barrier` first when they need a
    /// deterministic cut).
    fn all_counters(&self) -> Vec<CounterSnapshot>;

    /// Snapshot of this rank's comm latency histograms. Every
    /// `all_to_all`, `rma_get` (self-gets included), and `barrier` call
    /// made *through the trait* records one sample, so per-primitive
    /// totals are deterministic call counts identical across backends;
    /// the per-bucket spread is wall-clock and observability-only.
    /// Histogram upkeep never touches `CommCounters`.
    fn comm_hists(&self) -> CommHistSnapshot;

    /// Mark the communicator as failed (a panicking rank sets this so
    /// sibling ranks can be diagnosed instead of deadlocking).
    fn poison(&self);

    fn is_poisoned(&self) -> bool;
}
