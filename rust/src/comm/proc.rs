//! Process-per-rank launcher for the socket backend.
//!
//! `run_entry` re-executes the current binary once per rank with the
//! rendezvous parameters in `ILMI_COMM_*` environment variables; each
//! child calls [`maybe_run_child`] at the top of `main` (or from a
//! dedicated test hook), joins the communicator, runs the named entry
//! function, and reports its result back over a control socket in the
//! rendezvous directory. Entries are looked up by name in a registry the
//! host binary passes in — a plain `fn` table, so the child executes
//! exactly the code the parent named, never arbitrary input.
//!
//! Failure semantics (DESIGN.md §11): a child that panics or errors
//! reports a `CHILD_ERR` frame and exits nonzero; a child that dies
//! without reporting is noticed by the launcher's `try_wait` sweep; a
//! child that hangs is bounded by the launch deadline. On the first
//! failure the launcher kills the remaining children — no partial fleet
//! lingers. Successful entries leave together (a final barrier) so one
//! rank's exit cannot tear its RMA server threads down while a slower
//! peer still needs them.

use std::io::ErrorKind;
use std::os::unix::net::{UnixListener, UnixStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::Path;
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use super::socket_comm::{
    fresh_rendezvous_dir, read_frame, tags, write_frame, RendezvousDirGuard, SocketComm,
};
use super::Comm;
use crate::telemetry::HealthFrame;
use crate::util::wire::{put_u32, Cursor};

/// Entry-function name the child should run (presence marks a child).
pub const ENV_ENTRY: &str = "ILMI_COMM_ENTRY";
pub const ENV_RANK: &str = "ILMI_COMM_RANK";
pub const ENV_SIZE: &str = "ILMI_COMM_SIZE";
pub const ENV_DIR: &str = "ILMI_COMM_DIR";
pub const ENV_TIMEOUT_MS: &str = "ILMI_COMM_TIMEOUT_MS";
/// Extra argv prepended when re-executing the current binary. The `ilmi`
/// binary needs none; a libtest harness sets this to
/// `"<full test name> --exact"` so the child process runs its
/// `maybe_run_child` hook instead of the whole suite.
pub const ENV_CHILD_ARGS: &str = "ILMI_SOCKET_CHILD_ARGS";

/// A named function a rank process can be asked to run.
pub type Entry = fn(&SocketComm, &[u8]) -> Result<Vec<u8>, String>;

/// One process-per-rank launch.
pub struct LaunchSpec<'a> {
    /// Registry name of the entry every rank runs.
    pub entry: &'a str,
    pub ranks: usize,
    /// Opaque argument bytes delivered to every rank's entry.
    pub args: &'a [u8],
    /// Bounds the rendezvous, every peer read in the children, and
    /// (plus a reporting margin) the launch as a whole.
    pub timeout: Duration,
    /// Extra environment variables set on every rank process — the
    /// supervisor ships the attempt's fault plan (`ILMI_FAULT_PLAN`)
    /// and the heartbeat cadence (`ILMI_TELEMETRY_EVERY`) this way so
    /// they arm only inside children, never in the launching process.
    pub env: &'a [(String, String)],
    /// Hang watchdog: a rank that has sent at least one heartbeat and
    /// then stays silent for this many multiples of the largest
    /// inter-beat gap observed so far is declared hung and the launch
    /// fails (routing into supervised recovery). 0 disables; useless
    /// without a heartbeat cadence in `env`.
    pub watchdog_misses: u32,
    /// Called on every heartbeat received (the supervisor folds these
    /// into its live status file). `None` drops them after watchdog
    /// bookkeeping.
    pub on_beat: Option<&'a dyn Fn(&HealthFrame)>,
}

/// How long the launcher keeps draining the control socket after a
/// child exits before declaring its result lost.
const EXIT_GRACE: Duration = Duration::from_millis(500);

fn env_usize(key: &str) -> usize {
    std::env::var(key)
        .unwrap_or_else(|_| panic!("{key} not set in socket child"))
        .parse()
        .unwrap_or_else(|_| panic!("{key} is not a number"))
}

/// Child-side hook: if this process was spawned by `run_entry`, join the
/// communicator, run the named entry from `entries`, report the result,
/// and exit — never returns in that case. A plain invocation (no
/// `ILMI_COMM_ENTRY` in the environment) returns immediately.
pub fn maybe_run_child(entries: &[(&str, Entry)]) {
    let Ok(entry_name) = std::env::var(ENV_ENTRY) else {
        return;
    };
    let rank = env_usize(ENV_RANK);
    let size = env_usize(ENV_SIZE);
    let dir = std::env::var(ENV_DIR).expect("ILMI_COMM_DIR not set in socket child");
    let timeout = Duration::from_millis(env_usize(ENV_TIMEOUT_MS) as u64);
    // Strip the rendezvous variables so nothing the entry spawns — or a
    // nested thread-backend simulation — re-enters the child path.
    for key in [ENV_ENTRY, ENV_RANK, ENV_SIZE, ENV_DIR, ENV_TIMEOUT_MS] {
        std::env::remove_var(key);
    }
    // Arm this rank's injected faults, if the launcher shipped a plan
    // (consumes and removes ILMI_FAULT_PLAN; no-op otherwise), and
    // heartbeat emission, if it shipped a cadence (the control socket
    // lives in the rendezvous dir, captured before the env-strip above).
    crate::fault::arm_from_env(rank);
    crate::telemetry::arm_child_from_env(rank, Path::new(&dir));
    std::process::exit(run_child(&entry_name, entries, rank, size, Path::new(&dir), timeout));
}

fn run_child(
    entry_name: &str,
    entries: &[(&str, Entry)],
    rank: usize,
    size: usize,
    dir: &Path,
    timeout: Duration,
) -> i32 {
    let report = |tag: u8, body: &[u8]| {
        if let Ok(stream) = UnixStream::connect(dir.join("ctl.sock")) {
            let mut framed = Vec::with_capacity(4 + body.len());
            put_u32(&mut framed, rank as u32);
            framed.extend_from_slice(body);
            let _ = write_frame(&stream, tag, &framed);
        }
    };
    let Some(entry) = entries.iter().find(|(n, _)| *n == entry_name).map(|(_, f)| *f) else {
        report(tags::CHILD_ERR, format!("unknown socket entry {entry_name:?}").as_bytes());
        return 1;
    };
    let args = std::fs::read(dir.join("args.bin")).unwrap_or_default();
    let comm = match SocketComm::connect(rank, size, dir, timeout) {
        Ok(c) => c,
        Err(e) => {
            report(tags::CHILD_ERR, format!("rendezvous failed: {e}").as_bytes());
            return 1;
        }
    };
    let result = catch_unwind(AssertUnwindSafe(|| {
        let bytes = entry(&comm, &args)?;
        // Leave together: a rank that exits the moment its own entry
        // returns would tear down the RMA server threads a slower peer
        // is still reading from.
        comm.barrier();
        Ok(bytes)
    }));
    match result {
        Ok(Ok(bytes)) => {
            report(tags::RESULT, &bytes);
            0
        }
        Ok(Err(msg)) => {
            report(tags::CHILD_ERR, msg.as_bytes());
            1
        }
        Err(panic) => {
            let msg = panic_message(panic.as_ref());
            report(tags::CHILD_ERR, format!("panicked: {msg}").as_bytes());
            1
        }
    }
}

fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Launch `spec.ranks` rank processes running `spec.entry` and collect
/// their result bytes in rank order. Fails fast on the first child
/// error, a child death without a report, or the deadline.
pub fn run_entry(spec: &LaunchSpec) -> Result<Vec<Vec<u8>>, String> {
    if std::env::var_os(ENV_ENTRY).is_some() {
        return Err("recursive socket launch: ILMI_COMM_ENTRY is already set".into());
    }
    let exe = std::env::current_exe().map_err(|e| format!("current_exe: {e}"))?;
    let dir = fresh_rendezvous_dir("pc").map_err(|e| format!("rendezvous dir: {e}"))?;
    // Drop guard: the rendezvous dir is removed on every exit path —
    // success, error return, or a panic unwinding through this frame.
    let guard = RendezvousDirGuard(dir);
    launch_in(&exe, &guard.0, spec)
}

fn launch_in(exe: &Path, dir: &Path, spec: &LaunchSpec) -> Result<Vec<Vec<u8>>, String> {
    std::fs::write(dir.join("args.bin"), spec.args)
        .map_err(|e| format!("writing entry args: {e}"))?;
    let ctl = UnixListener::bind(dir.join("ctl.sock"))
        .map_err(|e| format!("binding control socket: {e}"))?;
    ctl.set_nonblocking(true).map_err(|e| format!("control socket: {e}"))?;

    let child_args = child_args_from_env();
    let mut children: Vec<Child> = Vec::with_capacity(spec.ranks);
    for rank in 0..spec.ranks {
        let spawned = Command::new(exe)
            .args(&child_args)
            .env(ENV_ENTRY, spec.entry)
            .env(ENV_RANK, rank.to_string())
            .env(ENV_SIZE, spec.ranks.to_string())
            .env(ENV_DIR, dir.as_os_str())
            .env(ENV_TIMEOUT_MS, spec.timeout.as_millis().to_string())
            .envs(spec.env.iter().map(|(k, v)| (k.as_str(), v.as_str())))
            .stdin(Stdio::null())
            .stdout(Stdio::null())
            .spawn();
        match spawned {
            Ok(c) => children.push(c),
            Err(e) => {
                kill_all(&mut children);
                return Err(format!("spawning rank {rank}: {e}"));
            }
        }
    }

    let launched = Instant::now();
    let deadline = launched + spec.timeout + Duration::from_secs(5);
    let mut results: Vec<Option<Vec<u8>>> = (0..spec.ranks).map(|_| None).collect();
    let mut exited_at: Vec<Option<Instant>> = vec![None; spec.ranks];
    // Watchdog state: when each rank last beat, and the largest
    // inter-beat gap observed fleet-wide (launch → first beat counts,
    // so an expensive init can't trip it). The floor keeps a fast fleet
    // from declaring "hung" over scheduler noise.
    let mut last_beat: Vec<Option<Instant>> = vec![None; spec.ranks];
    let mut max_gap = Duration::from_millis(250);
    let mut failure: Option<String> = None;
    while failure.is_none() && results.iter().any(|r| r.is_none()) {
        // Drain every report queued on the control socket.
        loop {
            match ctl.accept() {
                Ok((stream, _)) => {
                    let _ = stream.set_nonblocking(false);
                    let _ = stream.set_read_timeout(Some(Duration::from_secs(5)));
                    match read_report(&stream, spec.ranks) {
                        Ok(CtlMsg::Result(rank, bytes)) => results[rank] = Some(bytes),
                        Ok(CtlMsg::ChildErr(rank, msg)) => {
                            failure = Some(format!("socket rank {rank} failed: {msg}"));
                        }
                        Ok(CtlMsg::Beat(frame)) => {
                            let rank = frame.rank as usize;
                            let now = Instant::now();
                            let gap = now - last_beat[rank].unwrap_or(launched);
                            max_gap = max_gap.max(gap);
                            last_beat[rank] = Some(now);
                            if let Some(cb) = spec.on_beat {
                                cb(&frame);
                            }
                        }
                        Err(e) => failure = Some(format!("malformed child report: {e}")),
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) => {
                    failure = Some(format!("control socket: {e}"));
                    break;
                }
            }
            if failure.is_some() {
                break;
            }
        }
        if failure.is_some() {
            break;
        }
        // A child that exited without reporting gets a short grace for
        // its queued report to drain, then counts as lost.
        for rank in 0..spec.ranks {
            if results[rank].is_some() {
                continue;
            }
            if let Ok(Some(status)) = children[rank].try_wait() {
                let t = *exited_at[rank].get_or_insert_with(Instant::now);
                if t.elapsed() > EXIT_GRACE {
                    failure = Some(format!(
                        "socket rank {rank} exited with {status} before reporting a result"
                    ));
                    break;
                }
            }
        }
        // Hang watchdog: a rank armed itself by beating once; if it then
        // goes silent for `watchdog_misses` expected gaps while still
        // result-less and alive, the fleet is declared hung. This is
        // what turns an rma_stall/frame_delay hang — invisible to
        // try_wait — into a supervised recovery instead of a launch
        // timeout (DESIGN.md §14).
        if failure.is_none() && spec.watchdog_misses > 0 {
            for rank in 0..spec.ranks {
                let (Some(beat), None) = (last_beat[rank], &results[rank]) else { continue };
                let silent = beat.elapsed();
                if silent > max_gap * spec.watchdog_misses {
                    failure = Some(format!(
                        "watchdog: socket rank {rank} missed ~{} heartbeats \
                         (silent {silent:?}, expected gap ≤{max_gap:?})",
                        spec.watchdog_misses
                    ));
                    break;
                }
            }
        }
        if failure.is_none() && Instant::now() >= deadline {
            failure = Some(format!(
                "socket launch timed out after {:?} waiting for rank results",
                spec.timeout
            ));
        }
        std::thread::sleep(Duration::from_millis(5));
    }

    if let Some(msg) = failure {
        kill_all(&mut children);
        return Err(msg);
    }
    for c in &mut children {
        let _ = c.wait(); // every rank has reported; exits are imminent
    }
    Ok(results.into_iter().map(|r| r.expect("result checked above")).collect())
}

/// One message off the control socket.
enum CtlMsg {
    Result(usize, Vec<u8>),
    ChildErr(usize, String),
    Beat(HealthFrame),
}

fn read_report(stream: &UnixStream, ranks: usize) -> Result<CtlMsg, String> {
    let (tag, payload) = read_frame(stream).map_err(|e| format!("reading frame: {e}"))?;
    let mut c = Cursor::new(&payload, "child report");
    let rank = c.u32("rank")? as usize;
    if rank >= ranks {
        return Err(format!("report from out-of-range rank {rank}"));
    }
    let n = c.remaining();
    let body = c.bytes(n, "report body")?.to_vec();
    match tag {
        tags::RESULT => Ok(CtlMsg::Result(rank, body)),
        tags::CHILD_ERR => Ok(CtlMsg::ChildErr(rank, String::from_utf8_lossy(&body).into_owned())),
        tags::HEARTBEAT => {
            let frame = HealthFrame::decode(&body).map_err(|e| format!("heartbeat: {e}"))?;
            if frame.rank as usize != rank {
                return Err(format!(
                    "heartbeat rank mismatch: envelope {rank}, frame {}",
                    frame.rank
                ));
            }
            Ok(CtlMsg::Beat(frame))
        }
        other => Err(format!("unexpected child report tag {other}")),
    }
}

fn kill_all(children: &mut [Child]) {
    for c in children.iter_mut() {
        let _ = c.kill();
        let _ = c.wait();
    }
}

/// The extra argv `run_entry` passes when re-executing this binary
/// (`ILMI_SOCKET_CHILD_ARGS`, whitespace-split). Empty for the `ilmi`
/// CLI; test harnesses point it at their child hook test.
pub fn child_args_from_env() -> Vec<String> {
    std::env::var(ENV_CHILD_ARGS)
        .map(|s| s.split_whitespace().map(str::to_string).collect())
        .unwrap_or_default()
}
