//! Small shared substrates: PRNG, 3-D vectors, Morton curve, wire codec.

pub mod morton;
pub mod rng;
pub mod vec3;
pub mod wire;

pub use rng::{Rng, RngState};
pub use vec3::Vec3;

/// Round `n` up to the next multiple of `m`.
#[inline]
pub fn round_up(n: usize, m: usize) -> usize {
    debug_assert!(m > 0);
    n.div_ceil(m) * m
}

/// Human-readable byte count using the paper's convention (1 KB = 1024 B,
/// digits after the decimal point are cut — Table I/II caption). The
/// paper's tables only promote to the next unit at >= 10 of it (they
/// print "9908 KB" but "12 MB"), which we follow.
pub fn format_bytes(bytes: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KB", "MB", "GB", "TB"];
    let mut v = bytes;
    let mut unit = 0;
    while v >= 10 * 1024 && unit < UNITS.len() - 1 {
        v /= 1024;
        unit += 1;
    }
    format!("{} {}", v, UNITS[unit])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_up_cases() {
        assert_eq!(round_up(1, 4), 4);
        assert_eq!(round_up(4, 4), 4);
        assert_eq!(round_up(5, 4), 8);
    }

    #[test]
    fn format_bytes_paper_convention() {
        assert_eq!(format_bytes(86 * 1024), "86 KB");
        assert_eq!(format_bytes(1273 * 1024), "1273 KB");
        assert_eq!(format_bytes(9908 * 1024), "9908 KB");
        assert_eq!(format_bytes(12 * 1024 * 1024), "12 MB");
        assert_eq!(format_bytes(1023), "1023 B");
        assert_eq!(format_bytes(5075 * 1024), "5075 KB");
        // digits are cut, not rounded
        assert_eq!(format_bytes(11 * 1024 * 1024 - 1), "10 MB");
    }
}
