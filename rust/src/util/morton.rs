//! Morton (Z-order) space-filling curve.
//!
//! The simulation domain is split into `8^b` subdomains indexed by the
//! Morton curve (paper §III-B0a); each MPI rank owns 1, 2, or 4
//! consecutive subdomains. 21 bits per axis (63-bit codes) is far beyond
//! any branch level we use.

/// Spread the low 21 bits of `v` so each bit occupies every third slot.
#[inline]
fn part1by2(v: u64) -> u64 {
    let mut x = v & 0x1F_FFFF; // 21 bits
    x = (x | (x << 32)) & 0x1F00000000FFFF;
    x = (x | (x << 16)) & 0x1F0000FF0000FF;
    x = (x | (x << 8)) & 0x100F00F00F00F00F;
    x = (x | (x << 4)) & 0x10C30C30C30C30C3;
    x = (x | (x << 2)) & 0x1249249249249249;
    x
}

/// Inverse of `part1by2`.
#[inline]
fn compact1by2(v: u64) -> u64 {
    let mut x = v & 0x1249249249249249;
    x = (x ^ (x >> 2)) & 0x10C30C30C30C30C3;
    x = (x ^ (x >> 4)) & 0x100F00F00F00F00F;
    x = (x ^ (x >> 8)) & 0x1F0000FF0000FF;
    x = (x ^ (x >> 16)) & 0x1F00000000FFFF;
    x = (x ^ (x >> 32)) & 0x1F_FFFF;
    x
}

/// Interleave three 21-bit cell coordinates into a Morton code.
#[inline]
pub fn encode(x: u64, y: u64, z: u64) -> u64 {
    part1by2(x) | (part1by2(y) << 1) | (part1by2(z) << 2)
}

/// Recover the three cell coordinates from a Morton code.
#[inline]
pub fn decode(code: u64) -> (u64, u64, u64) {
    (compact1by2(code), compact1by2(code >> 1), compact1by2(code >> 2))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_small() {
        for x in 0..8 {
            for y in 0..8 {
                for z in 0..8 {
                    assert_eq!(decode(encode(x, y, z)), (x, y, z));
                }
            }
        }
    }

    #[test]
    fn roundtrip_large() {
        let cases = [(0, 0, 0), (1, 2, 3), (0x1F_FFFF, 0x1F_FFFF, 0x1F_FFFF), (12345, 54321, 99999)];
        for &(x, y, z) in &cases {
            assert_eq!(decode(encode(x, y, z)), (x, y, z));
        }
    }

    #[test]
    fn first_octant_ordering() {
        // Morton order of the 8 octants of a cube is exactly the child
        // index used by the octree: bit0 = x, bit1 = y, bit2 = z.
        assert_eq!(encode(0, 0, 0), 0);
        assert_eq!(encode(1, 0, 0), 1);
        assert_eq!(encode(0, 1, 0), 2);
        assert_eq!(encode(1, 1, 0), 3);
        assert_eq!(encode(0, 0, 1), 4);
        assert_eq!(encode(1, 0, 1), 5);
        assert_eq!(encode(0, 1, 1), 6);
        assert_eq!(encode(1, 1, 1), 7);
    }

    #[test]
    fn locality_prefix_property() {
        // Cells sharing the same high bits of the code share an ancestor
        // cube: codes of an 2x2x2 block differ only in the low 3 bits.
        let base = encode(4, 6, 2);
        for dx in 0..2u64 {
            for dy in 0..2u64 {
                for dz in 0..2u64 {
                    let c = encode(4 + dx, 6 + dy, 2 + dz);
                    assert_eq!(c >> 3, base >> 3);
                }
            }
        }
    }
}
