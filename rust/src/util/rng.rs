//! Deterministic pseudo-random number generation.
//!
//! The simulator must be reproducible across algorithm variants and rank
//! counts, so every consumer owns its own stream, forked from the global
//! seed by a stable key (rank id, neuron id, purpose tag). We use
//! xoshiro256++ seeded through SplitMix64 — the standard, well-tested
//! combination — implemented locally because the offline crate set has no
//! `rand`.

/// SplitMix64: used to expand seeds and derive fork keys.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Complete serializable state of an [`Rng`]: the xoshiro256++ word
/// state plus the cached second normal of the polar (Box–Muller-style)
/// pair, so restoring mid-pair reproduces the exact draw sequence.
/// Produced by [`Rng::state`], consumed by [`Rng::from_state`] — the
/// checkpoint/restore subsystem persists these.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RngState {
    pub s: [u64; 4],
    pub spare_normal: Option<f64>,
}

/// xoshiro256++ — the simulator's main PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal from Box–Muller.
    spare_normal: Option<f64>,
}

impl Rng {
    /// Export the complete generator state (for checkpointing).
    pub fn state(&self) -> RngState {
        RngState { s: self.s, spare_normal: self.spare_normal }
    }

    /// Rebuild a generator from an exported state: the returned `Rng`
    /// continues the exact sequence of the generator `state` came from.
    pub fn from_state(state: RngState) -> Rng {
        Rng { s: state.s, spare_normal: state.spare_normal }
    }

    /// Seed via SplitMix64 (never yields the all-zero state).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            spare_normal: None,
        }
    }

    /// Derive an independent stream keyed by `key` (stable fork).
    pub fn fork(&self, key: u64) -> Rng {
        // Mix the current state with the key through SplitMix64 so forks
        // with different keys are decorrelated regardless of parent state.
        let mut sm = SplitMix64::new(self.s[0] ^ key.wrapping_mul(0xA24BAED4963EE407));
        Rng::new(sm.next_u64())
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in [0, n) (Lemire-style rejection-free enough for
    /// simulation use; modulo bias is negligible for n << 2^64 but we use
    /// the widening-multiply trick anyway).
    #[inline]
    pub fn next_below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (((self.next_u64() as u128) * (n as u128)) >> 64) as usize
    }

    /// Standard normal via the Marsaglia polar method (caches the second
    /// draw). Chosen over Box–Muller because `sincos` was ~11% of the
    /// whole-simulation profile (EXPERIMENTS.md §Perf, opt 4); polar
    /// needs one ln + one sqrt per pair.
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u = 2.0 * self.next_f64() - 1.0;
            let v = 2.0 * self.next_f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                self.spare_normal = Some(v * f);
                return u * f;
            }
        }
    }

    /// Normal with the given mean and standard deviation.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Bernoulli draw.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Sample an index proportionally to non-negative `weights`.
    /// Returns `None` if the total weight is zero (or the slice empty).
    pub fn weighted_choice(&mut self, weights: &[f64]) -> Option<usize> {
        let total: f64 = weights.iter().sum();
        if !(total > 0.0) {
            return None;
        }
        let mut x = self.next_f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            x -= w;
            if x < 0.0 {
                return Some(i);
            }
        }
        // Floating-point slack: fall back to the last positive weight.
        weights.iter().rposition(|&w| w > 0.0)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn fork_is_stable_and_decorrelated() {
        let parent = Rng::new(7);
        let mut f1 = parent.fork(3);
        let mut f1b = parent.fork(3);
        let mut f2 = parent.fork(4);
        assert_eq!(f1.next_u64(), f1b.next_u64());
        assert_ne!(f1.next_u64(), f2.next_u64());
    }

    #[test]
    fn uniform_bounds() {
        let mut rng = Rng::new(0);
        for _ in 0..10_000 {
            let x = rng.uniform(2.0, 3.0);
            assert!((2.0..3.0).contains(&x));
        }
    }

    #[test]
    fn next_below_bounds_and_coverage() {
        let mut rng = Rng::new(0);
        let mut seen = [false; 7];
        for _ in 0..10_000 {
            let k = rng.next_below(7);
            assert!(k < 7);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(9);
        let n = 100_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let z = rng.normal();
            sum += z;
            sq += z * z;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn weighted_choice_respects_weights() {
        let mut rng = Rng::new(5);
        let w = [0.0, 1.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[rng.weighted_choice(&w).unwrap()] += 1;
        }
        assert_eq!(counts[0], 0);
        let ratio = counts[2] as f64 / counts[1] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio {ratio}");
    }

    #[test]
    fn weighted_choice_zero_total() {
        let mut rng = Rng::new(5);
        assert_eq!(rng.weighted_choice(&[0.0, 0.0]), None);
        assert_eq!(rng.weighted_choice(&[]), None);
    }

    #[test]
    fn state_roundtrip_continues_exact_sequence() {
        let mut a = Rng::new(123);
        // Burn some state, including a normal pair so internals are hot.
        for _ in 0..17 {
            a.next_u64();
        }
        a.normal();
        let mut b = Rng::from_state(a.state());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        for _ in 0..100 {
            assert_eq!(a.normal().to_bits(), b.normal().to_bits());
        }
    }

    #[test]
    fn state_roundtrip_preserves_spare_normal() {
        // An odd number of normal() calls leaves the polar method's
        // cached second draw pending; the restored generator must
        // return that exact spare first.
        let mut a = Rng::new(77);
        a.normal(); // consumes one of a fresh pair, caches the spare
        let st = a.state();
        assert!(st.spare_normal.is_some(), "expected a cached spare normal");
        let mut b = Rng::from_state(st);
        assert_eq!(a.normal().to_bits(), b.normal().to_bits());
        // And the streams stay locked afterwards.
        for _ in 0..50 {
            assert_eq!(a.normal().to_bits(), b.normal().to_bits());
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn restored_state_is_independent_of_donor() {
        let mut a = Rng::new(5);
        let st = a.state();
        let expected: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        // Advancing `a` must not affect a generator built from `st`.
        let mut b = Rng::from_state(st);
        let got: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_eq!(expected, got);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(11);
        let mut xs: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
