//! Minimal 3-D vector used for neuron positions and octree geometry.

use std::ops::{Add, AddAssign, Div, Mul, Sub};

#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub struct Vec3 {
    pub x: f64,
    pub y: f64,
    pub z: f64,
}

impl Vec3 {
    pub const ZERO: Vec3 = Vec3 { x: 0.0, y: 0.0, z: 0.0 };

    pub fn new(x: f64, y: f64, z: f64) -> Self {
        Self { x, y, z }
    }

    pub fn splat(v: f64) -> Self {
        Self { x: v, y: v, z: v }
    }

    /// Squared Euclidean distance.
    #[inline]
    pub fn dist2(&self, other: &Vec3) -> f64 {
        let dx = self.x - other.x;
        let dy = self.y - other.y;
        let dz = self.z - other.z;
        dx * dx + dy * dy + dz * dz
    }

    #[inline]
    pub fn dist(&self, other: &Vec3) -> f64 {
        self.dist2(other).sqrt()
    }

    /// Componentwise minimum.
    pub fn min(&self, other: &Vec3) -> Vec3 {
        Vec3::new(self.x.min(other.x), self.y.min(other.y), self.z.min(other.z))
    }

    /// Componentwise maximum.
    pub fn max(&self, other: &Vec3) -> Vec3 {
        Vec3::new(self.x.max(other.x), self.y.max(other.y), self.z.max(other.z))
    }

    /// True if `self` lies in the half-open box [lo, hi).
    pub fn in_box(&self, lo: &Vec3, hi: &Vec3) -> bool {
        self.x >= lo.x
            && self.x < hi.x
            && self.y >= lo.y
            && self.y < hi.y
            && self.z >= lo.z
            && self.z < hi.z
    }
}

impl Add for Vec3 {
    type Output = Vec3;
    fn add(self, o: Vec3) -> Vec3 {
        Vec3::new(self.x + o.x, self.y + o.y, self.z + o.z)
    }
}

impl AddAssign for Vec3 {
    fn add_assign(&mut self, o: Vec3) {
        self.x += o.x;
        self.y += o.y;
        self.z += o.z;
    }
}

impl Sub for Vec3 {
    type Output = Vec3;
    fn sub(self, o: Vec3) -> Vec3 {
        Vec3::new(self.x - o.x, self.y - o.y, self.z - o.z)
    }
}

impl Mul<f64> for Vec3 {
    type Output = Vec3;
    fn mul(self, s: f64) -> Vec3 {
        Vec3::new(self.x * s, self.y * s, self.z * s)
    }
}

impl Div<f64> for Vec3 {
    type Output = Vec3;
    fn div(self, s: f64) -> Vec3 {
        Vec3::new(self.x / s, self.y / s, self.z / s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distance() {
        let a = Vec3::new(0.0, 0.0, 0.0);
        let b = Vec3::new(3.0, 4.0, 0.0);
        assert_eq!(a.dist2(&b), 25.0);
        assert_eq!(a.dist(&b), 5.0);
    }

    #[test]
    fn box_membership_half_open() {
        let lo = Vec3::ZERO;
        let hi = Vec3::splat(1.0);
        assert!(Vec3::new(0.0, 0.5, 0.999).in_box(&lo, &hi));
        assert!(!Vec3::new(1.0, 0.5, 0.5).in_box(&lo, &hi));
        assert!(!Vec3::new(-0.1, 0.5, 0.5).in_box(&lo, &hi));
    }

    #[test]
    fn arithmetic() {
        let a = Vec3::new(1.0, 2.0, 3.0);
        let b = Vec3::new(4.0, 5.0, 6.0);
        assert_eq!(a + b, Vec3::new(5.0, 7.0, 9.0));
        assert_eq!(b - a, Vec3::new(3.0, 3.0, 3.0));
        assert_eq!(a * 2.0, Vec3::new(2.0, 4.0, 6.0));
        assert_eq!(b / 2.0, Vec3::new(2.0, 2.5, 3.0));
    }
}
