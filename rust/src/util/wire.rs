//! Fixed-size little-endian wire encoding for inter-rank messages.
//!
//! The paper reports exact message sizes (old synapse request 17 B, new
//! request 42 B, old response 1 B, new response 9 B, spike id 8 B); the
//! byte accounting in `comm::CommCounters` counts exactly what these
//! encoders produce, so Tables I/II are regenerated from the same
//! accounting the paper uses.

/// A message with a fixed wire size.
pub trait Wire: Sized {
    /// Encoded size in bytes.
    const SIZE: usize;
    fn write(&self, out: &mut Vec<u8>);
    fn read(buf: &[u8]) -> Self;
}

/// Encode a slice of messages into a flat byte buffer.
pub fn encode_all<T: Wire>(items: &[T]) -> Vec<u8> {
    let mut out = Vec::with_capacity(items.len() * T::SIZE);
    for it in items {
        it.write(&mut out);
    }
    out
}

/// Decode a flat byte buffer into messages.
pub fn decode_all<T: Wire>(buf: &[u8]) -> Vec<T> {
    assert!(buf.len() % T::SIZE == 0, "buffer not a multiple of message size");
    buf.chunks_exact(T::SIZE).map(T::read).collect()
}

// -- primitive helpers --------------------------------------------------

#[inline]
pub fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

#[inline]
pub fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

#[inline]
pub fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

#[inline]
pub fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

#[inline]
pub fn put_u8(out: &mut Vec<u8>, v: u8) {
    out.push(v);
}

#[inline]
pub fn get_u64(buf: &[u8], at: usize) -> u64 {
    u64::from_le_bytes(buf[at..at + 8].try_into().unwrap())
}

#[inline]
pub fn get_u32(buf: &[u8], at: usize) -> u32 {
    u32::from_le_bytes(buf[at..at + 4].try_into().unwrap())
}

#[inline]
pub fn get_f64(buf: &[u8], at: usize) -> f64 {
    f64::from_le_bytes(buf[at..at + 8].try_into().unwrap())
}

#[inline]
pub fn get_f32(buf: &[u8], at: usize) -> f32 {
    f32::from_le_bytes(buf[at..at + 4].try_into().unwrap())
}

#[inline]
pub fn get_u8(buf: &[u8], at: usize) -> u8 {
    buf[at]
}

#[inline]
pub fn get_i32_at(buf: &[u8], at: usize) -> i32 {
    i32::from_le_bytes(buf[at..at + 4].try_into().unwrap())
}

#[inline]
pub fn get_i64_at(buf: &[u8], at: usize) -> i64 {
    i64::from_le_bytes(buf[at..at + 8].try_into().unwrap())
}

impl Wire for u64 {
    const SIZE: usize = 8;
    fn write(&self, out: &mut Vec<u8>) {
        put_u64(out, *self);
    }
    fn read(buf: &[u8]) -> Self {
        get_u64(buf, 0)
    }
}

impl Wire for f32 {
    const SIZE: usize = 4;
    fn write(&self, out: &mut Vec<u8>) {
        put_f32(out, *self);
    }
    fn read(buf: &[u8]) -> Self {
        get_f32(buf, 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn u64_roundtrip() {
        let xs = vec![0u64, 1, u64::MAX, 0xDEADBEEF];
        let buf = encode_all(&xs);
        assert_eq!(buf.len(), 32);
        assert_eq!(decode_all::<u64>(&buf), xs);
    }

    #[test]
    fn f32_roundtrip() {
        let xs = vec![0.0f32, -1.5, f32::MAX, 1e-20];
        assert_eq!(decode_all::<f32>(&encode_all(&xs)), xs);
    }

    #[test]
    #[should_panic]
    fn decode_rejects_partial_messages() {
        decode_all::<u64>(&[1, 2, 3]);
    }
}
