//! Fixed-size little-endian wire encoding for inter-rank messages.
//!
//! The paper reports exact message sizes (old synapse request 17 B, new
//! request 42 B, old response 1 B, new response 9 B, spike id 8 B); the
//! byte accounting in `comm::CommCounters` counts exactly what these
//! encoders produce, so Tables I/II are regenerated from the same
//! accounting the paper uses.

/// A message with a fixed wire size.
pub trait Wire: Sized {
    /// Encoded size in bytes.
    const SIZE: usize;
    fn write(&self, out: &mut Vec<u8>);
    fn read(buf: &[u8]) -> Self;
}

/// Encode a slice of messages into a flat byte buffer.
pub fn encode_all<T: Wire>(items: &[T]) -> Vec<u8> {
    let mut out = Vec::with_capacity(items.len() * T::SIZE);
    for it in items {
        it.write(&mut out);
    }
    out
}

/// Decode a flat byte buffer into messages.
pub fn decode_all<T: Wire>(buf: &[u8]) -> Vec<T> {
    assert!(buf.len() % T::SIZE == 0, "buffer not a multiple of message size");
    buf.chunks_exact(T::SIZE).map(T::read).collect()
}

// -- primitive helpers --------------------------------------------------

#[inline]
pub fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

#[inline]
pub fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

#[inline]
pub fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

#[inline]
pub fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

#[inline]
pub fn put_u8(out: &mut Vec<u8>, v: u8) {
    out.push(v);
}

#[inline]
pub fn get_u64(buf: &[u8], at: usize) -> u64 {
    u64::from_le_bytes(buf[at..at + 8].try_into().unwrap())
}

#[inline]
pub fn get_u32(buf: &[u8], at: usize) -> u32 {
    u32::from_le_bytes(buf[at..at + 4].try_into().unwrap())
}

#[inline]
pub fn get_f64(buf: &[u8], at: usize) -> f64 {
    f64::from_le_bytes(buf[at..at + 8].try_into().unwrap())
}

#[inline]
pub fn get_f32(buf: &[u8], at: usize) -> f32 {
    f32::from_le_bytes(buf[at..at + 4].try_into().unwrap())
}

#[inline]
pub fn get_u8(buf: &[u8], at: usize) -> u8 {
    buf[at]
}

#[inline]
pub fn get_i32_at(buf: &[u8], at: usize) -> i32 {
    i32::from_le_bytes(buf[at..at + 4].try_into().unwrap())
}

#[inline]
pub fn get_i64_at(buf: &[u8], at: usize) -> i64 {
    i64::from_le_bytes(buf[at..at + 8].try_into().unwrap())
}

/// Checked sequential reader over an encoded buffer — the decoding twin
/// of the `put_*` helpers for variable-length formats (snapshots),
/// where a truncated or corrupt input must produce a descriptive error
/// instead of a panic. `label` names what is being decoded in errors.
pub struct Cursor<'a> {
    buf: &'a [u8],
    at: usize,
    label: &'static str,
}

impl<'a> Cursor<'a> {
    pub fn new(buf: &'a [u8], label: &'static str) -> Cursor<'a> {
        Cursor { buf, at: 0, label }
    }

    /// Current read offset in bytes.
    pub fn position(&self) -> usize {
        self.at
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.at
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8], String> {
        if self.remaining() < n {
            return Err(format!(
                "{}: truncated reading {} at byte {} (need {n}, have {})",
                self.label,
                what,
                self.at,
                self.remaining()
            ));
        }
        let s = &self.buf[self.at..self.at + n];
        self.at += n;
        Ok(s)
    }

    pub fn u8(&mut self, what: &str) -> Result<u8, String> {
        Ok(self.take(1, what)?[0])
    }

    pub fn u32(&mut self, what: &str) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }

    pub fn u64(&mut self, what: &str) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }

    pub fn f32(&mut self, what: &str) -> Result<f32, String> {
        Ok(f32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }

    pub fn f64(&mut self, what: &str) -> Result<f64, String> {
        Ok(f64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }

    pub fn bytes(&mut self, n: usize, what: &str) -> Result<&'a [u8], String> {
        self.take(n, what)
    }

    /// Assert the buffer was consumed exactly (no trailing garbage).
    pub fn finish(&self, what: &str) -> Result<(), String> {
        if self.remaining() != 0 {
            return Err(format!(
                "{}: {} trailing bytes after {}",
                self.label,
                self.remaining(),
                what
            ));
        }
        Ok(())
    }
}

impl Wire for u64 {
    const SIZE: usize = 8;
    fn write(&self, out: &mut Vec<u8>) {
        put_u64(out, *self);
    }
    fn read(buf: &[u8]) -> Self {
        get_u64(buf, 0)
    }
}

impl Wire for f32 {
    const SIZE: usize = 4;
    fn write(&self, out: &mut Vec<u8>) {
        put_f32(out, *self);
    }
    fn read(buf: &[u8]) -> Self {
        get_f32(buf, 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn u64_roundtrip() {
        let xs = vec![0u64, 1, u64::MAX, 0xDEADBEEF];
        let buf = encode_all(&xs);
        assert_eq!(buf.len(), 32);
        assert_eq!(decode_all::<u64>(&buf), xs);
    }

    #[test]
    fn f32_roundtrip() {
        let xs = vec![0.0f32, -1.5, f32::MAX, 1e-20];
        assert_eq!(decode_all::<f32>(&encode_all(&xs)), xs);
    }

    #[test]
    #[should_panic]
    fn decode_rejects_partial_messages() {
        decode_all::<u64>(&[1, 2, 3]);
    }

    #[test]
    fn cursor_reads_back_what_put_wrote() {
        let mut buf = Vec::new();
        put_u64(&mut buf, 0xDEAD_BEEF_1234_5678);
        put_u32(&mut buf, 42);
        put_f64(&mut buf, -1.5);
        put_f32(&mut buf, 0.25);
        put_u8(&mut buf, 7);
        let mut c = Cursor::new(&buf, "test");
        assert_eq!(c.u64("a").unwrap(), 0xDEAD_BEEF_1234_5678);
        assert_eq!(c.u32("b").unwrap(), 42);
        assert_eq!(c.f64("c").unwrap(), -1.5);
        assert_eq!(c.f32("d").unwrap(), 0.25);
        assert_eq!(c.u8("e").unwrap(), 7);
        assert_eq!(c.remaining(), 0);
        c.finish("test payload").unwrap();
    }

    #[test]
    fn cursor_truncation_is_an_error_not_a_panic() {
        let buf = [1u8, 2, 3];
        let mut c = Cursor::new(&buf, "snapshot");
        let err = c.u64("step counter").unwrap_err();
        assert!(err.contains("snapshot"), "{err}");
        assert!(err.contains("step counter"), "{err}");
        assert!(err.contains("truncated"), "{err}");
    }

    #[test]
    fn cursor_rejects_trailing_bytes() {
        let mut buf = Vec::new();
        put_u32(&mut buf, 1);
        put_u32(&mut buf, 2);
        let mut c = Cursor::new(&buf, "section");
        c.u32("x").unwrap();
        assert!(c.finish("section").unwrap_err().contains("trailing"));
    }
}
