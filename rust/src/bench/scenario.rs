//! Scenario definitions and the sweep matrix.
//!
//! One *scenario* (cell) fixes an algorithm generation, a topology, a
//! frequency-exchange epoch Δ, and a firing regime; the *matrix* is the
//! cross product of the axis value lists. Shared run settings (steps,
//! warmup, repetitions, seed) live outside the matrix so every cell
//! measures the same schedule. EXPERIMENTS.md §Bench documents the
//! default matrices and how they map onto the paper's figures.

use crate::config::{ConnectivityAlg, KernelKind, SimConfig, SpikeAlg};

/// Algorithm generation under test: the paper's before/after pair.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AlgGen {
    /// RMA-download Barnes–Hut + per-step spike-id all-to-all.
    Old,
    /// Location-aware Barnes–Hut + frequency approximation.
    New,
}

impl AlgGen {
    pub fn name(self) -> &'static str {
        match self {
            AlgGen::Old => "old",
            AlgGen::New => "new",
        }
    }

    pub fn from_name(name: &str) -> Result<AlgGen, String> {
        match name {
            "old" => Ok(AlgGen::Old),
            "new" => Ok(AlgGen::New),
            other => Err(format!("unknown algorithm generation {other:?}")),
        }
    }

    /// The config pair this generation selects.
    pub fn algorithms(self) -> (ConnectivityAlg, SpikeAlg) {
        match self {
            AlgGen::Old => (ConnectivityAlg::OldRma, SpikeAlg::OldIds),
            AlgGen::New => (ConnectivityAlg::NewLocationAware, SpikeAlg::NewFrequency),
        }
    }
}

/// Firing regime: the background-input level that drives network
/// activity (and with it spike-exchange volume — the old algorithm's
/// cost scales with firing, the new one's does not).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Regime {
    /// Background N(3, 1): sparse firing.
    Quiet,
    /// Background N(5, 1): the paper's §V-D operating point.
    Active,
}

impl Regime {
    pub fn name(self) -> &'static str {
        match self {
            Regime::Quiet => "quiet",
            Regime::Active => "active",
        }
    }

    pub fn from_name(name: &str) -> Result<Regime, String> {
        match name {
            "quiet" => Ok(Regime::Quiet),
            "active" => Ok(Regime::Active),
            other => Err(format!("unknown firing regime {other:?}")),
        }
    }

    pub fn bg_mean(self) -> f64 {
        match self {
            Regime::Quiet => 3.0,
            Regime::Active => 5.0,
        }
    }
}

/// Settings shared by every cell of one matrix run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RunSettings {
    /// Simulation steps per repetition.
    pub steps: usize,
    /// Connectivity-update interval (paper: 100).
    pub plasticity_interval: usize,
    /// Untimed warmup repetitions per cell (page-cache/allocator/branch
    /// predictor settling).
    pub warmup: usize,
    /// Timed repetitions per cell; medians are taken over these.
    pub reps: usize,
    /// Global PRNG seed — fixed, so communication counters are
    /// bit-identical across repetitions and machines.
    pub seed: u64,
}

/// One cell of the sweep matrix.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Scenario {
    pub alg: AlgGen,
    pub ranks: usize,
    pub neurons_per_rank: usize,
    /// Frequency-exchange epoch Δ. Only the new spike algorithm reads
    /// it; sweeping it under `AlgGen::Old` yields control cells that
    /// must time equal (a harness self-test).
    pub delta: usize,
    pub regime: Regime,
    /// Skewed-load cell: start from a deliberately uneven rank → cell
    /// split ([`skewed_init_cells`]) with load balancing enabled, so
    /// the recorded end-of-run `imbalance` demonstrates the migration
    /// subsystem ironing the skew out (EXPERIMENTS.md §Load balancing).
    pub skew: bool,
    /// Neuron-kernel backend executing the activity update. Execution
    /// strategy, not dynamics: every counter the diff drift-checks must
    /// be identical across kernels (the cross-kernel differential suite
    /// pins bit-identical trajectories), so sweeping this axis measures
    /// pure hot-loop speed (EXPERIMENTS.md §Perf, opt 9).
    pub kernel: KernelKind,
}

impl Scenario {
    /// Stable identifier used as the JSON key and in baseline diffs,
    /// e.g. `new_r4_n128_d100_active` (`_skew` suffix for skewed cells,
    /// `_k<kernel>` suffix for non-default kernels — omitted for the
    /// scalar kernel so pre-v6 scenario ids are unchanged).
    pub fn id(&self) -> String {
        format!(
            "{}_r{}_n{}_d{}_{}{}{}",
            self.alg.name(),
            self.ranks,
            self.neurons_per_rank,
            self.delta,
            self.regime.name(),
            if self.skew { "_skew" } else { "" },
            match self.kernel {
                KernelKind::Scalar => String::new(),
                other => format!("_k{}", other.name()),
            }
        )
    }

    /// The simulation config this cell runs.
    pub fn config(&self, settings: &RunSettings) -> SimConfig {
        let (connectivity_alg, spike_alg) = self.alg.algorithms();
        let mut cfg = SimConfig {
            ranks: self.ranks,
            neurons_per_rank: self.neurons_per_rank,
            steps: settings.steps,
            plasticity_interval: settings.plasticity_interval,
            delta: self.delta,
            connectivity_alg,
            spike_alg,
            bg_mean: self.regime.bg_mean(),
            seed: settings.seed,
            kernel: self.kernel,
            // Every cell records an epoch trace at the connectivity-
            // update cadence: the sample/event counts are seed-
            // deterministic, so the runner drift-checks `trace_events`
            // like `spike_lookups` (BENCH schema v5). Recording reads
            // counters only — it never perturbs the trajectory.
            trace_every: settings.plasticity_interval,
            ..SimConfig::default()
        };
        if self.skew {
            cfg.balance_init_cells = skewed_init_cells(self.ranks);
            // Balance epochs must land on both connectivity-update and
            // spike-epoch boundaries (config validation enforces it).
            cfg.balance_every = lcm(settings.plasticity_interval, self.delta);
            cfg.balance_threshold = 1.05;
            cfg.balance_max_moves = 1;
        }
        cfg
    }
}

fn gcd(a: usize, b: usize) -> usize {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}

fn lcm(a: usize, b: usize) -> usize {
    a / gcd(a, b) * b
}

/// A deterministic skewed rank → cell split for `ranks` ranks: every
/// rank after the first gets half its fair share of Morton cells
/// (at least one); rank 0 absorbs the rest. For 2 ranks that is "6,2" —
/// rank 0 starts with 3× rank 1's neurons.
pub fn skewed_init_cells(ranks: usize) -> String {
    let num_cells = crate::octree::DomainDecomposition::new(ranks, 1.0).num_cells;
    let fair = num_cells / ranks;
    let small = (fair / 2).max(1);
    let rest = num_cells - small * (ranks - 1);
    let mut parts = vec![rest.to_string()];
    for _ in 1..ranks {
        parts.push(small.to_string());
    }
    parts.join(",")
}

/// Axis value lists; the matrix is their cross product.
#[derive(Clone, Debug)]
pub struct MatrixSpec {
    pub algs: Vec<AlgGen>,
    pub ranks: Vec<usize>,
    pub neurons: Vec<usize>,
    pub deltas: Vec<usize>,
    pub regimes: Vec<Regime>,
    /// Whether every cell of this matrix runs the skewed-load +
    /// balancing variant (the `smoke-skew` preset).
    pub skew: bool,
    /// Kernel backends to sweep (innermost axis). Presets pin
    /// `[Scalar]`; `ilmi bench --kernel` swaps the single entry, and a
    /// CI matrix job can compare backends cell-for-cell because the
    /// drift-checked counters are kernel-independent.
    pub kernels: Vec<KernelKind>,
}

impl MatrixSpec {
    /// Expand the cross product in a fixed axis order (alg outermost,
    /// kernel innermost) so cell order — and with it the report
    /// fingerprint — is deterministic.
    pub fn cells(&self) -> Vec<Scenario> {
        let mut out = Vec::new();
        for &alg in &self.algs {
            for &ranks in &self.ranks {
                for &neurons_per_rank in &self.neurons {
                    for &delta in &self.deltas {
                        for &regime in &self.regimes {
                            for &kernel in &self.kernels {
                                out.push(Scenario {
                                    alg,
                                    ranks,
                                    neurons_per_rank,
                                    delta,
                                    regime,
                                    skew: self.skew,
                                    kernel,
                                });
                            }
                        }
                    }
                }
            }
        }
        out
    }
}

/// Named matrix presets. `smoke` is the CI gate (2 ranks, seconds to
/// run), `smoke8` its 8-rank sibling (same tiny schedule, wide enough
/// that a multi-rank regression in the exchange routing shows up),
/// `smoke-skew` the load-balancing gate (skewed 48/16 start, migration
/// enabled, end-of-run `imbalance` recorded), `quick` the 16-cell
/// default, `full` the 32-cell sweep that adds the quiet firing
/// regime.
pub fn preset(name: &str) -> Result<(MatrixSpec, RunSettings), String> {
    let both_algs = vec![AlgGen::Old, AlgGen::New];
    match name {
        "smoke8" => Ok((
            MatrixSpec {
                algs: both_algs,
                ranks: vec![8],
                neurons: vec![16],
                deltas: vec![50],
                regimes: vec![Regime::Active],
                skew: false,
                kernels: vec![KernelKind::Scalar],
            },
            RunSettings {
                steps: 100,
                plasticity_interval: 50,
                warmup: 0,
                reps: 2,
                seed: 42,
            },
        )),
        "smoke" => Ok((
            MatrixSpec {
                algs: both_algs,
                ranks: vec![2],
                neurons: vec![32],
                deltas: vec![50],
                regimes: vec![Regime::Active],
                skew: false,
                kernels: vec![KernelKind::Scalar],
            },
            RunSettings {
                steps: 100,
                plasticity_interval: 50,
                warmup: 0,
                reps: 2,
                seed: 42,
            },
        )),
        "smoke-skew" => Ok((
            MatrixSpec {
                algs: both_algs,
                ranks: vec![2],
                neurons: vec![32],
                deltas: vec![50],
                regimes: vec![Regime::Active],
                skew: true,
                kernels: vec![KernelKind::Scalar],
            },
            RunSettings {
                steps: 150,
                plasticity_interval: 50,
                warmup: 0,
                reps: 2,
                seed: 42,
            },
        )),
        "quick" => Ok((
            MatrixSpec {
                algs: both_algs,
                ranks: vec![2, 4],
                neurons: vec![64, 128],
                deltas: vec![50, 100],
                regimes: vec![Regime::Active],
                skew: false,
                kernels: vec![KernelKind::Scalar],
            },
            RunSettings {
                steps: 200,
                plasticity_interval: 50,
                warmup: 1,
                reps: 3,
                seed: 42,
            },
        )),
        "full" => Ok((
            MatrixSpec {
                algs: both_algs,
                ranks: vec![2, 4],
                neurons: vec![64, 128],
                deltas: vec![50, 100],
                regimes: vec![Regime::Quiet, Regime::Active],
                skew: false,
                kernels: vec![KernelKind::Scalar],
            },
            RunSettings {
                steps: 400,
                plasticity_interval: 100,
                warmup: 1,
                reps: 5,
                seed: 42,
            },
        )),
        other => Err(format!(
            "unknown bench preset {other:?} (smoke | smoke8 | smoke-skew | quick | full)"
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_preset_has_at_least_12_cells() {
        let (spec, settings) = preset("quick").unwrap();
        let cells = spec.cells();
        assert!(cells.len() >= 12, "{} cells", cells.len());
        // Every cell yields a valid config and a unique id.
        let mut ids: Vec<String> = cells.iter().map(|c| c.id()).collect();
        for cell in &cells {
            cell.config(&settings).validate().unwrap();
        }
        let n = ids.len();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), n, "duplicate scenario ids");
    }

    #[test]
    fn smoke_preset_is_tiny_and_two_ranked() {
        let (spec, settings) = preset("smoke").unwrap();
        for cell in spec.cells() {
            assert_eq!(cell.ranks, 2);
        }
        assert!(settings.steps <= 200);
        assert!(preset("bogus").is_err());
    }

    #[test]
    fn smoke8_preset_is_tiny_and_eight_ranked() {
        let (spec, settings) = preset("smoke8").unwrap();
        let cells = spec.cells();
        assert_eq!(cells.len(), 2, "old + new only");
        for cell in &cells {
            assert_eq!(cell.ranks, 8);
            cell.config(&settings).validate().unwrap();
        }
        assert!(settings.steps <= 200, "stays a seconds-scale CI gate");
    }

    #[test]
    fn scenario_id_is_stable() {
        let mut sc = Scenario {
            alg: AlgGen::New,
            ranks: 4,
            neurons_per_rank: 128,
            delta: 100,
            regime: Regime::Active,
            skew: false,
            kernel: KernelKind::Scalar,
        };
        assert_eq!(sc.id(), "new_r4_n128_d100_active");
        sc.skew = true;
        assert_eq!(sc.id(), "new_r4_n128_d100_active_skew");
        // Non-default kernels suffix the id; the scalar default stays
        // suffix-free so pre-v6 baselines keep their cell names.
        sc.kernel = KernelKind::Blocked;
        assert_eq!(sc.id(), "new_r4_n128_d100_active_skew_kblocked");
        sc.skew = false;
        sc.kernel = KernelKind::Xla;
        assert_eq!(sc.id(), "new_r4_n128_d100_active_kxla");
    }

    #[test]
    fn config_maps_algorithms_and_regime() {
        let (_, settings) = preset("smoke").unwrap();
        let sc = Scenario {
            alg: AlgGen::Old,
            ranks: 2,
            neurons_per_rank: 32,
            delta: 50,
            regime: Regime::Quiet,
            skew: false,
            kernel: KernelKind::Blocked,
        };
        let cfg = sc.config(&settings);
        assert_eq!(cfg.kernel, KernelKind::Blocked, "cells select their kernel");
        assert_eq!(cfg.connectivity_alg, ConnectivityAlg::OldRma);
        assert_eq!(cfg.spike_alg, SpikeAlg::OldIds);
        assert_eq!(cfg.bg_mean, 3.0);
        assert_eq!(cfg.delta, 50);
        assert_eq!(cfg.steps, settings.steps);
        assert_eq!(cfg.balance_every, 0, "non-skew cells never balance");
        assert_eq!(
            cfg.trace_every, settings.plasticity_interval,
            "every cell records the drift-checked epoch trace"
        );
        assert!(cfg.trace_out.is_empty(), "bench cells never write trace files");
    }

    #[test]
    fn smoke_skew_preset_enables_balancing_with_a_valid_split() {
        let (spec, settings) = preset("smoke-skew").unwrap();
        let cells = spec.cells();
        assert_eq!(cells.len(), 2, "old + new, skewed");
        for cell in &cells {
            assert!(cell.skew);
            assert!(cell.id().ends_with("_skew"), "{}", cell.id());
            let cfg = cell.config(&settings);
            cfg.validate().unwrap();
            assert_eq!(cfg.balance_init_cells, "6,2");
            assert_eq!(cfg.balance_every, settings.plasticity_interval);
        }
    }

    #[test]
    fn skewed_init_cells_sum_to_the_morton_domain() {
        for ranks in [2usize, 3, 4, 8] {
            let split = skewed_init_cells(ranks);
            let parts: Vec<usize> =
                split.split(',').map(|p| p.parse().unwrap()).collect();
            assert_eq!(parts.len(), ranks, "{split}");
            let cells = crate::octree::DomainDecomposition::new(ranks, 1.0).num_cells;
            assert_eq!(parts.iter().sum::<usize>(), cells, "{split}");
            assert!(parts.iter().all(|&p| p >= 1), "{split}");
        }
        assert_eq!(skewed_init_cells(2), "6,2");
    }

    #[test]
    fn kernel_axis_expands_innermost_with_suffixed_ids() {
        let (mut spec, settings) = preset("smoke").unwrap();
        spec.kernels = vec![KernelKind::Scalar, KernelKind::Blocked];
        let cells = spec.cells();
        assert_eq!(cells.len(), 4, "2 algs x 2 kernels");
        let ids: Vec<String> = cells.iter().map(|c| c.id()).collect();
        assert_eq!(
            ids,
            vec![
                "old_r2_n32_d50_active",
                "old_r2_n32_d50_active_kblocked",
                "new_r2_n32_d50_active",
                "new_r2_n32_d50_active_kblocked",
            ]
        );
        for cell in &cells {
            cell.config(&settings).validate().unwrap();
        }
    }

    #[test]
    fn names_roundtrip() {
        for alg in [AlgGen::Old, AlgGen::New] {
            assert_eq!(AlgGen::from_name(alg.name()).unwrap(), alg);
        }
        for regime in [Regime::Quiet, Regime::Active] {
            assert_eq!(Regime::from_name(regime.name()).unwrap(), regime);
        }
        assert!(AlgGen::from_name("direct").is_err());
        assert!(Regime::from_name("loud").is_err());
    }
}
