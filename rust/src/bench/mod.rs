//! Benchmark harness: scenario matrix, runner, statistics, and the
//! versioned `BENCH_*.json` trajectory (paper Figs. 5/10/11 tooling).
//!
//! The paper's headline claims are quantitative — connectivity update
//! ~6× faster, spike exchange cheaper by two orders of magnitude — and
//! EXPERIMENTS.md §Bench is where this repo records them. This module
//! is the measurement loop behind that file:
//!
//! * [`scenario`] — one cell = {algorithm generation} × {ranks} ×
//!   {neurons/rank} × {epoch Δ} × {firing regime}; [`MatrixSpec`]
//!   crosses axis lists, [`preset`] names the standard matrices
//!   (`smoke`: the 2-cell CI gate; `quick`: the 16-cell default;
//!   `full`: 32 cells adding the quiet firing regime).
//! * [`runner`] — warmup + timed repetitions per cell, reusing the
//!   driver's [`crate::metrics::Phase`] timers and
//!   [`crate::comm::CommCounters`]; no bench-only instrumentation.
//! * [`stats`] — median/min/max over repetitions (median: robust to
//!   scheduler noise on the thread-per-rank substrate).
//! * [`report`] — the versioned JSON schema with a workload
//!   fingerprint, a markdown table renderer, and `--baseline` diffing
//!   that flags timing regressions beyond a threshold and *any*
//!   communication-counter drift (counters are seed-deterministic).
//! * [`json`] — the serde-free JSON subset the reports travel through.
//!
//! Timings from the thread-per-rank substrate are *relative* measures
//! (old vs new on the same machine), not absolute cluster predictions —
//! see DESIGN.md §8; counters and collective counts, by contrast, are
//! exact and machine-independent.

pub mod json;
pub mod report;
pub mod runner;
pub mod scenario;
pub mod stats;

pub use report::{BenchReport, DiffReport, ScenarioResult, SCHEMA_VERSION};
pub use runner::{run_matrix, run_matrix_with_backend, run_scenario, run_scenario_with_backend};
pub use scenario::{preset, skewed_init_cells, AlgGen, MatrixSpec, Regime, RunSettings, Scenario};
pub use stats::Summary;
