//! The versioned `BENCH_*.json` report: emit, parse, markdown render,
//! and baseline diffing.
//!
//! Schema (`schema_version` 8):
//!
//! ```json
//! {
//!   "schema_version": 8,
//!   "name": "quick",
//!   "created_unix": 1753500000,
//!   "fingerprint": "9f…16 hex digits…",
//!   "settings": {"steps":…, "plasticity_interval":…, "warmup":…,
//!                "reps":…, "seed":…},
//!   "scenarios": [{
//!     "id": "new_r4_n128_d100_active",
//!     "alg": "new", "ranks": 4, "neurons_per_rank": 128,
//!     "delta": 100, "regime": "active", "skew": false,
//!     "kernel": "scalar", "reps": 3,
//!     "phases": {"spike_exchange": {"median":…,"min":…,"max":…}, …},
//!     "wall": {"median":…,"min":…,"max":…},
//!     "comm": {"bytes_sent":…,"bytes_recv":…,"bytes_rma":…,
//!              "msgs_sent":…,"collectives":…,"rma_gets":…},
//!     "spike_state_bytes": …,
//!     "spike_lookups": …,
//!     "imbalance": …,
//!     "trace_events": …,
//!     "kernel_blocks": …,
//!     "recoveries": …,
//!     "comm_hist_a2a": …,
//!     "comm_hist_rma": …,
//!     "comm_hist_barrier": …
//!   }, …]
//! }
//! ```
//!
//! The *fingerprint* hashes everything that defines workload identity —
//! schedule, seed, and the ordered scenario ids — and deliberately
//! excludes timings and machine state. `diff` refuses two reports whose
//! fingerprints differ: comparing timings of different workloads is a
//! category error, not a regression. Timings are compared on medians
//! with a relative threshold plus an absolute noise floor; communication
//! counters are seeded-deterministic, so any counter difference at equal
//! fingerprints is flagged as drift regardless of the threshold.

use crate::comm::CounterSnapshot;
use crate::config::KernelKind;
use crate::metrics::ALL_PHASES;

use super::json::{obj, parse, Json};
use super::scenario::{AlgGen, Regime, RunSettings, Scenario};
use super::stats::Summary;

/// Version of the `BENCH_*.json` schema this build emits and accepts.
/// v2 added `spike_state_bytes` (per-rank spike-exchange state memory,
/// max across ranks — the EXPERIMENTS.md §Perf opt 7 counter); v3 added
/// `spike_lookups` (remote look-ups summed over ranks, the Fig. 5
/// quantity), drift-checked by the baseline diff so the epoch-compiled
/// delivery plan can never silently change how many look-ups a
/// workload performs (EXPERIMENTS.md §Perf, opt 8); v4 added the
/// `skew` scenario axis and the drift-checked `imbalance` factor
/// (max/mean per-rank step cost at run end — the quantity the
/// load-balancing subsystem drives down, EXPERIMENTS.md §Load
/// balancing); v5 added `trace_events` (the deterministic Chrome
/// trace event count of the epoch-granular telemetry ring,
/// EXPERIMENTS.md §Tracing), drift-checked so a cadence or
/// ring-capacity behavior change can never pass silently; v6 added the
/// `kernel` scenario axis (which `NeuronKernel` backend executed the
/// activity update — execution strategy, not dynamics) and the
/// drift-checked `kernel_blocks` counter (cache-block iterations summed
/// over ranks, `ceil(n/64)` per rank per step), which is
/// kernel-independent by construction so a population-size or schedule
/// change can never hide behind a kernel switch
/// (EXPERIMENTS.md §Perf, opt 9); v7 added the drift-checked
/// `recoveries` counter (supervised checkpoint-restart relaunches,
/// `SimReport::recoveries`, DESIGN.md §13) — bench runs inject no
/// faults, so the expected value is 0 and ANY nonzero value or drift
/// means the launch path silently failed and recovered, which must
/// surface as a behavior change, not vanish into timing noise; v8 added
/// the drift-checked `comm_hist_a2a` / `comm_hist_rma` /
/// `comm_hist_barrier` totals (comm-latency histogram sample counts,
/// `SimReport::total_comm_hists`, DESIGN.md §14) — totals are
/// trait-level call counts, deterministic per workload, so an
/// instrumentation or comm-structure change that alters how often a
/// primitive runs cannot pass silently, while the per-bucket latency
/// spread stays observability-only per the PR 5 nanos convention.
pub const SCHEMA_VERSION: u32 = 8;

/// Timing differences below this many seconds are never regressions —
/// the thread-rank substrate cannot resolve them reliably.
pub const NOISE_FLOOR_SECONDS: f64 = 1e-3;

/// Measured outcome of one scenario cell.
#[derive(Clone, Debug, PartialEq)]
pub struct ScenarioResult {
    pub scenario: Scenario,
    /// Timed repetitions the summaries were taken over.
    pub reps: usize,
    /// Per-phase seconds (max across ranks, summarized over reps),
    /// `ALL_PHASES` order.
    pub phases: [Summary; ALL_PHASES.len()],
    /// Whole-run wall clock, summarized over reps.
    pub wall: Summary,
    /// Communication counters aggregated over ranks. Deterministic for
    /// a fixed seed, hence identical across reps — recorded once.
    pub comm: CounterSnapshot,
    /// Spike-exchange reconstruction-state memory at run end, max
    /// across ranks (12 B per installed remote partner; 0 for the old
    /// algorithm). Seed-deterministic like the counters.
    pub spike_state_bytes: u64,
    /// Remote spike look-ups summed over ranks (the paper's Fig. 5
    /// quantity: one per remote in-edge per step). Seed-deterministic;
    /// any drift at equal fingerprints is a behavior change in the
    /// delivery path.
    pub spike_lookups: u64,
    /// End-of-run load-imbalance factor (max/mean per-rank step cost,
    /// `SimReport::imbalance`). A pure function of the structural
    /// trajectory, hence bit-deterministic and drift-checked.
    pub imbalance: f64,
    /// Chrome-trace event count of the telemetry ring
    /// (`SimReport::trace_events`): every sample emits all seven phase
    /// slices plus three counter points regardless of timing, so the
    /// count is a pure function of seed + config and drift-checked.
    pub trace_events: u64,
    /// Cache-block iterations of the activity update summed over ranks
    /// (`SimReport::total_kernel_blocks`: `ceil(n/64)` per rank per
    /// step). Kernel-independent by construction — the driver counts
    /// blocks from the population size, not from the kernel — so the
    /// kernel axis can never silently change how much work a cell
    /// represents. Drift-checked like the communication counters.
    pub kernel_blocks: u64,
    /// Supervised checkpoint-restart relaunches during the cell's reps
    /// (`SimReport::recoveries`, DESIGN.md §13). Bench scenarios inject
    /// no faults, so this is 0 in a healthy run; drift-checked so a
    /// launch path that starts dying-and-recovering cannot pass as a
    /// mere timing blip.
    pub recoveries: u64,
    /// Comm-latency histogram sample totals summed over ranks
    /// (`SimReport::total_comm_hists`): how many trait-level
    /// `all_to_all` / `rma_get` / `barrier` calls the workload made.
    /// Deterministic call counts — the latency *distribution* is
    /// wall-clock and deliberately not recorded here (PR 5 nanos
    /// convention); any drift in the counts is a comm-structure or
    /// instrumentation change.
    pub comm_hist_a2a: u64,
    pub comm_hist_rma: u64,
    pub comm_hist_barrier: u64,
}

/// One complete benchmark trajectory (a `BENCH_*.json` file in memory).
#[derive(Clone, Debug, PartialEq)]
pub struct BenchReport {
    pub name: String,
    /// Unix timestamp of the run (informational only; not fingerprinted).
    pub created_unix: u64,
    pub settings: RunSettings,
    pub results: Vec<ScenarioResult>,
}

fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

impl BenchReport {
    /// Workload-identity hash: schema version, schedule, seed, and the
    /// ordered scenario ids. Excludes timings, counters, reps, warmup
    /// and timestamps — two runs of the same matrix on different
    /// machines (or days) fingerprint identically and are comparable.
    pub fn fingerprint(&self) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325; // FNV-1a offset basis
        h = fnv1a(h, &SCHEMA_VERSION.to_le_bytes());
        h = fnv1a(h, &(self.settings.steps as u64).to_le_bytes());
        h = fnv1a(h, &(self.settings.plasticity_interval as u64).to_le_bytes());
        h = fnv1a(h, &self.settings.seed.to_le_bytes());
        for r in &self.results {
            h = fnv1a(h, r.scenario.id().as_bytes());
        }
        h
    }

    /// Emit the versioned JSON document (see the module docs for the
    /// schema).
    pub fn to_json(&self) -> String {
        let scenarios: Vec<Json> = self.results.iter().map(scenario_to_json).collect();
        obj(vec![
            ("schema_version", Json::Num(SCHEMA_VERSION as f64)),
            ("name", Json::Str(self.name.clone())),
            ("created_unix", Json::Num(self.created_unix as f64)),
            ("fingerprint", Json::Str(format!("{:016x}", self.fingerprint()))),
            (
                "settings",
                obj(vec![
                    ("steps", Json::Num(self.settings.steps as f64)),
                    (
                        "plasticity_interval",
                        Json::Num(self.settings.plasticity_interval as f64),
                    ),
                    ("warmup", Json::Num(self.settings.warmup as f64)),
                    ("reps", Json::Num(self.settings.reps as f64)),
                    ("seed", Json::Num(self.settings.seed as f64)),
                ]),
            ),
            ("scenarios", Json::Arr(scenarios)),
        ])
        .pretty()
    }

    /// Parse and validate a `BENCH_*.json` document: schema version,
    /// all seven phases per scenario, id/axes consistency, and the
    /// stored fingerprint reproducing from the parsed content.
    pub fn from_json(text: &str) -> Result<BenchReport, String> {
        let root = parse(text)?;
        let version = root.req("schema_version")?.as_u64()?;
        if version != SCHEMA_VERSION as u64 {
            return Err(format!(
                "unsupported bench schema version {version} (this build reads \
                 {SCHEMA_VERSION}); re-record the baseline with this build — \
                 cross-schema trajectories are not comparable"
            ));
        }
        let settings_json = root.req("settings")?;
        let settings = RunSettings {
            steps: settings_json.req("steps")?.as_usize()?,
            plasticity_interval: settings_json.req("plasticity_interval")?.as_usize()?,
            warmup: settings_json.req("warmup")?.as_usize()?,
            reps: settings_json.req("reps")?.as_usize()?,
            seed: settings_json.req("seed")?.as_u64()?,
        };
        let mut results = Vec::new();
        for (i, entry) in root.req("scenarios")?.as_arr()?.iter().enumerate() {
            results
                .push(scenario_from_json(entry).map_err(|e| format!("scenario #{i}: {e}"))?);
        }
        let report = BenchReport {
            name: root.req("name")?.as_str()?.to_string(),
            created_unix: root.req("created_unix")?.as_u64()?,
            settings,
            results,
        };
        let stored = root.req("fingerprint")?.as_str()?.to_string();
        let recomputed = format!("{:016x}", report.fingerprint());
        if stored != recomputed {
            return Err(format!(
                "bench fingerprint mismatch: file says {stored}, content hashes to \
                 {recomputed} (edited or truncated report?)"
            ));
        }
        Ok(report)
    }

    /// Render the per-scenario markdown table (median seconds per phase,
    /// wall clock, and the exact communication counters).
    pub fn markdown_table(&self) -> String {
        let mut out = String::from("| scenario |");
        for p in ALL_PHASES {
            out.push_str(&format!(" {} |", p.name()));
        }
        out.push_str(
            " wall | bytes_sent | bytes_rma | collectives | spike_state | lookups | \
             imbalance | trace_events | kernel_blocks |\n|---|",
        );
        out.push_str(&"---:|".repeat(ALL_PHASES.len() + 9));
        out.push('\n');
        for r in &self.results {
            out.push_str(&format!("| {} |", r.scenario.id()));
            for p in ALL_PHASES {
                out.push_str(&format!(" {:.4} |", r.phases[p.index()].median));
            }
            out.push_str(&format!(
                " {:.4} | {} | {} | {} | {} | {} | {:.3} | {} | {} |\n",
                r.wall.median,
                r.comm.bytes_sent,
                r.comm.bytes_rma,
                r.comm.collectives,
                r.spike_state_bytes,
                r.spike_lookups,
                r.imbalance,
                r.trace_events,
                r.kernel_blocks
            ));
        }
        out
    }

    /// Diff against a baseline report of the SAME workload (equal
    /// fingerprints — anything else is an error, not a regression).
    /// `threshold` is relative (0.2 = +20%); timing rows additionally
    /// need to exceed [`NOISE_FLOOR_SECONDS`] to regress, while counter
    /// drift is flagged on any difference.
    pub fn diff(&self, baseline: &BenchReport, threshold: f64) -> Result<DiffReport, String> {
        if self.fingerprint() != baseline.fingerprint() {
            return Err(format!(
                "baseline fingerprint mismatch: current run is {:016x} but baseline \
                 {:?} is {:016x} — the scenario matrix or schedule differs, so the \
                 timings are not comparable; re-record the baseline with the same \
                 preset/settings",
                self.fingerprint(),
                baseline.name,
                baseline.fingerprint()
            ));
        }
        // Equal fingerprints ⇒ same scenario ids in the same order.
        let mut rows = Vec::new();
        for (cur, base) in self.results.iter().zip(&baseline.results) {
            let id = cur.scenario.id();
            let timing_row = |metric: &str, b: f64, c: f64| DiffRow {
                scenario: id.clone(),
                metric: metric.to_string(),
                baseline: b,
                current: c,
                regressed: c > b * (1.0 + threshold) && c - b > NOISE_FLOOR_SECONDS,
            };
            rows.push(timing_row("wall", base.wall.median, cur.wall.median));
            for p in ALL_PHASES {
                rows.push(timing_row(
                    p.name(),
                    base.phases[p.index()].median,
                    cur.phases[p.index()].median,
                ));
            }
            // One drift row per differing counter field, so the render
            // names the counter that moved and by how much.
            let counter_fields = [
                ("bytes_sent", base.comm.bytes_sent, cur.comm.bytes_sent),
                ("bytes_recv", base.comm.bytes_recv, cur.comm.bytes_recv),
                ("bytes_rma", base.comm.bytes_rma, cur.comm.bytes_rma),
                ("msgs_sent", base.comm.msgs_sent, cur.comm.msgs_sent),
                ("collectives", base.comm.collectives, cur.comm.collectives),
                ("rma_gets", base.comm.rma_gets, cur.comm.rma_gets),
                ("spike_state_bytes", base.spike_state_bytes, cur.spike_state_bytes),
                ("spike_lookups", base.spike_lookups, cur.spike_lookups),
                ("trace_events", base.trace_events, cur.trace_events),
                ("kernel_blocks", base.kernel_blocks, cur.kernel_blocks),
                ("recoveries", base.recoveries, cur.recoveries),
                ("comm_hist_a2a", base.comm_hist_a2a, cur.comm_hist_a2a),
                ("comm_hist_rma", base.comm_hist_rma, cur.comm_hist_rma),
                ("comm_hist_barrier", base.comm_hist_barrier, cur.comm_hist_barrier),
            ];
            for (field, b, c) in counter_fields {
                if b != c {
                    rows.push(DiffRow {
                        scenario: id.clone(),
                        metric: format!("counter_drift:{field}"),
                        baseline: b as f64,
                        current: c as f64,
                        regressed: true,
                    });
                }
            }
            // The imbalance factor is bit-deterministic (pure function
            // of the structural trajectory): any change is drift.
            if base.imbalance.to_bits() != cur.imbalance.to_bits() {
                rows.push(DiffRow {
                    scenario: id.clone(),
                    metric: "counter_drift:imbalance".to_string(),
                    baseline: base.imbalance,
                    current: cur.imbalance,
                    regressed: true,
                });
            }
        }
        Ok(DiffReport { baseline_name: baseline.name.clone(), threshold, rows })
    }
}

/// One compared metric of one scenario.
#[derive(Clone, Debug)]
pub struct DiffRow {
    pub scenario: String,
    /// `wall`, a phase name, or `counter_drift:<field>`.
    pub metric: String,
    pub baseline: f64,
    pub current: f64,
    pub regressed: bool,
}

/// Outcome of `BenchReport::diff`.
#[derive(Clone, Debug)]
pub struct DiffReport {
    pub baseline_name: String,
    pub threshold: f64,
    pub rows: Vec<DiffRow>,
}

impl DiffReport {
    pub fn regressions(&self) -> usize {
        self.rows.iter().filter(|r| r.regressed).count()
    }

    /// Human-readable diff: one wall-clock line per scenario, plus every
    /// regressed metric spelled out.
    pub fn render(&self) -> String {
        let mut out = format!(
            "baseline diff vs {:?} (threshold +{:.0}%, noise floor {} ms)\n",
            self.baseline_name,
            self.threshold * 100.0,
            NOISE_FLOOR_SECONDS * 1e3
        );
        for row in &self.rows {
            let keep = row.metric == "wall" || row.regressed;
            if !keep {
                continue;
            }
            let delta = if row.baseline > 0.0 {
                format!("{:+.1}%", (row.current / row.baseline - 1.0) * 100.0)
            } else {
                "n/a".to_string()
            };
            if let Some(field) = row.metric.strip_prefix("counter_drift:") {
                out.push_str(&format!(
                    "  {}: COUNTER DRIFT {field} {} -> {} (counters are \
                     seed-deterministic, so this is a behavior change)\n",
                    row.scenario, row.baseline, row.current
                ));
            } else {
                out.push_str(&format!(
                    "  {}{} {} {:.4}s -> {:.4}s ({delta})\n",
                    if row.regressed { "REGRESSED " } else { "" },
                    row.scenario,
                    row.metric,
                    row.baseline,
                    row.current
                ));
            }
        }
        out.push_str(&format!("{} regression(s)\n", self.regressions()));
        out
    }
}

fn summary_to_json(s: &Summary) -> Json {
    obj(vec![
        ("median", Json::Num(s.median)),
        ("min", Json::Num(s.min)),
        ("max", Json::Num(s.max)),
    ])
}

fn summary_from_json(v: &Json) -> Result<Summary, String> {
    Ok(Summary {
        median: v.req("median")?.as_f64()?,
        min: v.req("min")?.as_f64()?,
        max: v.req("max")?.as_f64()?,
    })
}

fn scenario_to_json(r: &ScenarioResult) -> Json {
    let phases: Vec<(String, Json)> = ALL_PHASES
        .iter()
        .map(|p| (p.name().to_string(), summary_to_json(&r.phases[p.index()])))
        .collect();
    obj(vec![
        ("id", Json::Str(r.scenario.id())),
        ("alg", Json::Str(r.scenario.alg.name().to_string())),
        ("ranks", Json::Num(r.scenario.ranks as f64)),
        ("neurons_per_rank", Json::Num(r.scenario.neurons_per_rank as f64)),
        ("delta", Json::Num(r.scenario.delta as f64)),
        ("regime", Json::Str(r.scenario.regime.name().to_string())),
        ("skew", Json::Bool(r.scenario.skew)),
        ("kernel", Json::Str(r.scenario.kernel.name().to_string())),
        ("reps", Json::Num(r.reps as f64)),
        ("phases", Json::Obj(phases)),
        ("wall", summary_to_json(&r.wall)),
        (
            "comm",
            obj(vec![
                ("bytes_sent", Json::Num(r.comm.bytes_sent as f64)),
                ("bytes_recv", Json::Num(r.comm.bytes_recv as f64)),
                ("bytes_rma", Json::Num(r.comm.bytes_rma as f64)),
                ("msgs_sent", Json::Num(r.comm.msgs_sent as f64)),
                ("collectives", Json::Num(r.comm.collectives as f64)),
                ("rma_gets", Json::Num(r.comm.rma_gets as f64)),
            ]),
        ),
        ("spike_state_bytes", Json::Num(r.spike_state_bytes as f64)),
        ("spike_lookups", Json::Num(r.spike_lookups as f64)),
        ("imbalance", Json::Num(r.imbalance)),
        ("trace_events", Json::Num(r.trace_events as f64)),
        ("kernel_blocks", Json::Num(r.kernel_blocks as f64)),
        ("recoveries", Json::Num(r.recoveries as f64)),
        ("comm_hist_a2a", Json::Num(r.comm_hist_a2a as f64)),
        ("comm_hist_rma", Json::Num(r.comm_hist_rma as f64)),
        ("comm_hist_barrier", Json::Num(r.comm_hist_barrier as f64)),
    ])
}

fn scenario_from_json(v: &Json) -> Result<ScenarioResult, String> {
    let scenario = Scenario {
        alg: AlgGen::from_name(v.req("alg")?.as_str()?)?,
        ranks: v.req("ranks")?.as_usize()?,
        neurons_per_rank: v.req("neurons_per_rank")?.as_usize()?,
        delta: v.req("delta")?.as_usize()?,
        regime: Regime::from_name(v.req("regime")?.as_str()?)?,
        skew: v.req("skew")?.as_bool()?,
        kernel: {
            let name = v.req("kernel")?.as_str()?;
            KernelKind::from_name(name)
                .ok_or_else(|| format!("unknown kernel backend {name:?}"))?
        },
    };
    let id = v.req("id")?.as_str()?;
    if id != scenario.id() {
        return Err(format!(
            "scenario id {id:?} does not match its axes (expected {:?})",
            scenario.id()
        ));
    }
    let phases_json = v.req("phases")?;
    let mut phases = [Summary::default(); ALL_PHASES.len()];
    for p in ALL_PHASES {
        phases[p.index()] = summary_from_json(
            phases_json
                .get(p.name())
                .ok_or_else(|| format!("{id}: missing phase {:?}", p.name()))?,
        )?;
    }
    let comm_json = v.req("comm")?;
    Ok(ScenarioResult {
        scenario,
        reps: v.req("reps")?.as_usize()?,
        phases,
        wall: summary_from_json(v.req("wall")?)?,
        comm: CounterSnapshot {
            bytes_sent: comm_json.req("bytes_sent")?.as_u64()?,
            bytes_recv: comm_json.req("bytes_recv")?.as_u64()?,
            bytes_rma: comm_json.req("bytes_rma")?.as_u64()?,
            msgs_sent: comm_json.req("msgs_sent")?.as_u64()?,
            collectives: comm_json.req("collectives")?.as_u64()?,
            rma_gets: comm_json.req("rma_gets")?.as_u64()?,
        },
        spike_state_bytes: v.req("spike_state_bytes")?.as_u64()?,
        spike_lookups: v.req("spike_lookups")?.as_u64()?,
        imbalance: v.req("imbalance")?.as_f64()?,
        trace_events: v.req("trace_events")?.as_u64()?,
        kernel_blocks: v.req("kernel_blocks")?.as_u64()?,
        recoveries: v.req("recoveries")?.as_u64()?,
        comm_hist_a2a: v.req("comm_hist_a2a")?.as_u64()?,
        comm_hist_rma: v.req("comm_hist_rma")?.as_u64()?,
        comm_hist_barrier: v.req("comm_hist_barrier")?.as_u64()?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Phase;

    fn sample_result(alg: AlgGen, ranks: usize) -> ScenarioResult {
        let mut phases = [Summary::default(); ALL_PHASES.len()];
        for (i, s) in phases.iter_mut().enumerate() {
            *s = Summary {
                median: 0.01 * (i + 1) as f64,
                min: 0.009 * (i + 1) as f64,
                max: 0.011 * (i + 1) as f64,
            };
        }
        ScenarioResult {
            scenario: Scenario {
                alg,
                ranks,
                neurons_per_rank: 64,
                delta: 50,
                regime: Regime::Active,
                skew: false,
                kernel: KernelKind::Scalar,
            },
            reps: 3,
            phases,
            wall: Summary { median: 0.5, min: 0.45, max: 0.55 },
            comm: CounterSnapshot {
                bytes_sent: 123_456,
                bytes_recv: 123_456,
                bytes_rma: 789,
                msgs_sent: 42,
                collectives: 17,
                rma_gets: 5,
            },
            spike_state_bytes: 1_212,
            spike_lookups: 98_765,
            imbalance: 1.25,
            trace_events: 42,
            kernel_blocks: 400,
            recoveries: 0,
            comm_hist_a2a: 600,
            comm_hist_rma: 35,
            comm_hist_barrier: 200,
        }
    }

    fn sample_report() -> BenchReport {
        BenchReport {
            name: "unit".to_string(),
            created_unix: 1_753_500_000,
            settings: RunSettings {
                steps: 200,
                plasticity_interval: 50,
                warmup: 1,
                reps: 3,
                seed: 42,
            },
            results: vec![sample_result(AlgGen::Old, 2), sample_result(AlgGen::New, 2)],
        }
    }

    #[test]
    fn schema_roundtrip_is_exact() {
        let report = sample_report();
        let text = report.to_json();
        let back = BenchReport::from_json(&text).unwrap();
        assert_eq!(back, report);
        // Emitted text is a fixpoint too.
        assert_eq!(back.to_json(), text);
    }

    #[test]
    fn all_seven_phases_are_emitted_and_required() {
        let report = sample_report();
        let text = report.to_json();
        for p in ALL_PHASES {
            assert!(text.contains(&format!("\"{}\"", p.name())), "{} missing", p.name());
        }
        // Deleting one phase key must fail the parse.
        let broken = text.replace("\"spike_lookup\"", "\"spike_lookup_gone\"");
        let err = BenchReport::from_json(&broken).unwrap_err();
        assert!(err.contains("spike_lookup"), "{err}");
    }

    #[test]
    fn tampered_fingerprint_is_rejected() {
        let text = sample_report().to_json();
        // Change workload content without updating the fingerprint.
        let tampered = text.replace("\"steps\": 200", "\"steps\": 300");
        let err = BenchReport::from_json(&tampered).unwrap_err();
        assert!(err.contains("fingerprint mismatch"), "{err}");
    }

    #[test]
    fn unsupported_schema_version_is_rejected() {
        let text = sample_report().to_json().replace(
            "\"schema_version\": 8",
            "\"schema_version\": 99",
        );
        let err = BenchReport::from_json(&text).unwrap_err();
        assert!(err.contains("schema version"), "{err}");
        // The previous schema generation is refused too — a v7 baseline
        // has no comm_hist_* totals to drift-check against, so
        // cross-schema trajectories are not comparable.
        let text = sample_report().to_json().replace(
            "\"schema_version\": 8",
            "\"schema_version\": 7",
        );
        let err = BenchReport::from_json(&text).unwrap_err();
        assert!(err.contains("schema version"), "{err}");
    }

    #[test]
    fn diff_refuses_mismatched_workloads() {
        let a = sample_report();
        let mut b = sample_report();
        b.settings.seed = 7; // different workload
        let err = a.diff(&b, 0.2).unwrap_err();
        assert!(err.contains("fingerprint mismatch"), "{err}");
        assert_ne!(a.fingerprint(), b.fingerprint());
    }

    #[test]
    fn diff_flags_regressions_and_counter_drift() {
        let base = sample_report();
        let mut cur = sample_report();
        // Identical content: no regressions.
        let clean = cur.diff(&base, 0.2).unwrap();
        assert_eq!(clean.regressions(), 0);

        // +50% on one phase (well above floor) regresses at +20%.
        cur.results[0].phases[Phase::BarnesHut.index()].median *= 1.5;
        // Counter drift on the other scenario.
        cur.results[1].comm.bytes_sent += 1;
        let diff = cur.diff(&base, 0.2).unwrap();
        assert_eq!(diff.regressions(), 2);
        let rendered = diff.render();
        assert!(rendered.contains("REGRESSED"), "{rendered}");
        assert!(rendered.contains("barnes_hut"), "{rendered}");
        // The drift row names the counter that moved.
        assert!(rendered.contains("COUNTER DRIFT bytes_sent"), "{rendered}");
    }

    #[test]
    fn spike_state_drift_is_flagged_and_field_is_required() {
        let base = sample_report();
        let mut cur = sample_report();
        cur.results[0].spike_state_bytes += 12;
        let diff = cur.diff(&base, 0.2).unwrap();
        assert_eq!(diff.regressions(), 1);
        assert!(diff.render().contains("COUNTER DRIFT spike_state_bytes"));
        // The v2 schema requires the field on every scenario.
        let text = base.to_json();
        assert!(text.contains("\"spike_state_bytes\""));
        let broken = text.replace("\"spike_state_bytes\"", "\"spike_state_gone\"");
        let err = BenchReport::from_json(&broken).unwrap_err();
        assert!(err.contains("spike_state_bytes"), "{err}");
    }

    #[test]
    fn spike_lookup_drift_is_flagged_and_field_is_required() {
        let base = sample_report();
        let mut cur = sample_report();
        cur.results[1].spike_lookups += 1;
        let diff = cur.diff(&base, 0.2).unwrap();
        assert_eq!(diff.regressions(), 1);
        assert!(diff.render().contains("COUNTER DRIFT spike_lookups"));
        // The v3 schema requires the field on every scenario.
        let text = base.to_json();
        assert!(text.contains("\"spike_lookups\""));
        let broken = text.replace("\"spike_lookups\"", "\"spike_lookups_gone\"");
        let err = BenchReport::from_json(&broken).unwrap_err();
        assert!(err.contains("spike_lookups"), "{err}");
    }

    #[test]
    fn sub_floor_slowdowns_are_not_regressions() {
        // Timings are not fingerprinted, so both sides can be adjusted
        // to craft a big relative / tiny absolute slowdown: +400% but
        // only 0.4 ms — below the 1 ms noise floor, not a regression.
        let mut base = sample_report();
        let mut cur = sample_report();
        base.results[0].phases[Phase::SpikeExchange.index()].median = 1e-4;
        cur.results[0].phases[Phase::SpikeExchange.index()].median = 5e-4;
        let diff = cur.diff(&base, 0.2).unwrap();
        assert_eq!(diff.regressions(), 0);
    }

    #[test]
    fn markdown_table_lists_every_scenario_and_phase() {
        let md = sample_report().markdown_table();
        assert!(md.contains("old_r2_n64_d50_active"), "{md}");
        assert!(md.contains("new_r2_n64_d50_active"), "{md}");
        for p in ALL_PHASES {
            assert!(md.contains(p.name()), "{md}");
        }
        assert!(md.contains("spike_state"), "{md}");
        assert!(md.contains("lookups"), "{md}");
        assert!(md.contains("imbalance"), "{md}");
        assert!(md.contains("1.250"), "{md}");
        assert!(md.contains("trace_events"), "{md}");
        assert!(md.contains("kernel_blocks"), "{md}");
        assert!(md.contains("| 400 |"), "{md}");
        assert_eq!(md.lines().count(), 2 + 2); // header + separator + 2 rows
    }

    #[test]
    fn imbalance_drift_is_flagged_and_field_is_required() {
        let base = sample_report();
        let mut cur = sample_report();
        cur.results[0].imbalance += 0.125;
        let diff = cur.diff(&base, 0.2).unwrap();
        assert_eq!(diff.regressions(), 1);
        assert!(diff.render().contains("COUNTER DRIFT imbalance"));
        // The v4 schema requires the field (and the skew axis) on every
        // scenario.
        let text = base.to_json();
        assert!(text.contains("\"imbalance\""));
        assert!(text.contains("\"skew\""));
        let broken = text.replace("\"imbalance\"", "\"imbalance_gone\"");
        let err = BenchReport::from_json(&broken).unwrap_err();
        assert!(err.contains("imbalance"), "{err}");
        let broken = text.replace("\"skew\"", "\"skew_gone\"");
        let err = BenchReport::from_json(&broken).unwrap_err();
        assert!(err.contains("skew"), "{err}");
    }

    #[test]
    fn trace_event_drift_is_flagged_and_field_is_required() {
        let base = sample_report();
        let mut cur = sample_report();
        cur.results[0].trace_events += 10;
        let diff = cur.diff(&base, 0.2).unwrap();
        assert_eq!(diff.regressions(), 1);
        assert!(diff.render().contains("COUNTER DRIFT trace_events"));
        // The v5 schema requires the field on every scenario.
        let text = base.to_json();
        assert!(text.contains("\"trace_events\""));
        let broken = text.replace("\"trace_events\"", "\"trace_events_gone\"");
        let err = BenchReport::from_json(&broken).unwrap_err();
        assert!(err.contains("trace_events"), "{err}");
    }

    #[test]
    fn kernel_blocks_drift_is_flagged_and_v6_fields_are_required() {
        let base = sample_report();
        let mut cur = sample_report();
        cur.results[0].kernel_blocks += 64;
        let diff = cur.diff(&base, 0.2).unwrap();
        assert_eq!(diff.regressions(), 1);
        assert!(diff.render().contains("COUNTER DRIFT kernel_blocks"));
        // The v6 schema requires both the counter and the kernel axis
        // on every scenario.
        let text = base.to_json();
        assert!(text.contains("\"kernel_blocks\""));
        assert!(text.contains("\"kernel\": \"scalar\""));
        let broken = text.replace("\"kernel_blocks\"", "\"kernel_blocks_gone\"");
        let err = BenchReport::from_json(&broken).unwrap_err();
        assert!(err.contains("kernel_blocks"), "{err}");
        let broken = text.replace("\"kernel\": \"scalar\"", "\"kernel\": \"simd\"");
        let err = BenchReport::from_json(&broken).unwrap_err();
        assert!(err.contains("kernel"), "{err}");
    }

    #[test]
    fn recovery_drift_is_flagged_and_v7_field_is_required() {
        let base = sample_report();
        let mut cur = sample_report();
        // A launch path that silently died and recovered once: counter
        // drift, regardless of how the timings look.
        cur.results[0].recoveries = 1;
        let diff = cur.diff(&base, 0.2).unwrap();
        assert_eq!(diff.regressions(), 1);
        assert!(diff.render().contains("COUNTER DRIFT recoveries"));
        // The v7 schema requires the field on every scenario.
        let text = base.to_json();
        assert!(text.contains("\"recoveries\""));
        let broken = text.replace("\"recoveries\"", "\"recoveries_gone\"");
        let err = BenchReport::from_json(&broken).unwrap_err();
        assert!(err.contains("recoveries"), "{err}");
    }

    #[test]
    fn comm_hist_drift_is_flagged_and_v8_fields_are_required() {
        let base = sample_report();
        let mut cur = sample_report();
        // An extra barrier slipped into the step loop: totals are call
        // counts, so this is drift no matter what the latencies were.
        cur.results[0].comm_hist_barrier += 1;
        let diff = cur.diff(&base, 0.2).unwrap();
        assert_eq!(diff.regressions(), 1);
        assert!(diff.render().contains("COUNTER DRIFT comm_hist_barrier"));
        // The v8 schema requires all three totals on every scenario.
        let text = base.to_json();
        for field in ["comm_hist_a2a", "comm_hist_rma", "comm_hist_barrier"] {
            assert!(text.contains(&format!("\"{field}\"")), "{field} missing");
            let broken = text.replace(&format!("\"{field}\""), "\"hist_gone\"");
            let err = BenchReport::from_json(&broken).unwrap_err();
            assert!(err.contains(field), "{err}");
        }
    }

    #[test]
    fn kernel_axis_feeds_the_scenario_id_roundtrip() {
        // A non-default kernel suffixes the id; the JSON id/axes
        // consistency check must accept the suffixed form and reject a
        // mismatched one.
        let mut report = sample_report();
        report.results[1].scenario.kernel = KernelKind::Blocked;
        let text = report.to_json();
        assert!(text.contains("new_r2_n64_d50_active_kblocked"), "{text}");
        let back = BenchReport::from_json(&text).unwrap();
        assert_eq!(back, report);
        let broken = text.replace("\"kernel\": \"blocked\"", "\"kernel\": \"xla\"");
        let err = BenchReport::from_json(&broken).unwrap_err();
        assert!(err.contains("does not match its axes"), "{err}");
    }
}
