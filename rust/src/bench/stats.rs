//! Repetition statistics for the bench harness.
//!
//! The harness reports the median over measured repetitions (robust
//! against one-off scheduler noise on a thread-per-rank substrate) plus
//! min/max as the observed spread — see DESIGN.md §8 for why medians
//! and not means.

/// Median/min/max over one scenario's measured repetitions.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Summary {
    pub median: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    /// Summarize a non-empty sample set. The median of an even count is
    /// the mean of the two middle order statistics.
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "cannot summarize zero samples");
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("non-finite sample"));
        let n = sorted.len();
        let median = if n % 2 == 1 {
            sorted[n / 2]
        } else {
            (sorted[n / 2 - 1] + sorted[n / 2]) / 2.0
        };
        Summary { median, min: sorted[0], max: sorted[n - 1] }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn odd_and_even_medians() {
        let s = Summary::of(&[3.0, 1.0, 2.0]);
        assert_eq!(s, Summary { median: 2.0, min: 1.0, max: 3.0 });
        let s = Summary::of(&[4.0, 1.0, 3.0, 2.0]);
        assert_eq!(s, Summary { median: 2.5, min: 1.0, max: 4.0 });
        let s = Summary::of(&[7.0]);
        assert_eq!(s, Summary { median: 7.0, min: 7.0, max: 7.0 });
    }

    #[test]
    #[should_panic]
    fn empty_samples_panic() {
        Summary::of(&[]);
    }
}
