//! Matrix execution: warmup + timed repetitions per cell, reusing the
//! driver's `metrics::Phase` timers and `comm::CommCounters` — the
//! bench harness adds no instrumentation of its own, so what it reports
//! is exactly what `ilmi simulate` and `ilmi compare` report.

use anyhow::Result;

use crate::comm::CounterSnapshot;
use crate::config::CommBackend;
use crate::coordinator::run_simulation;
use crate::metrics::ALL_PHASES;

use super::report::{BenchReport, ScenarioResult};
use super::scenario::{MatrixSpec, RunSettings, Scenario};
use super::stats::Summary;

/// Run one scenario cell: `warmup` untimed runs, then `reps` timed ones.
/// Per-phase values are the max across ranks per repetition (the slowest
/// rank gates every synchronization point, exactly as `SimReport`
/// aggregates them), summarized over repetitions. Counters come from the
/// last repetition; with a fixed seed they must be identical across
/// repetitions — any drift is a determinism bug and errors the run
/// (a hard check, not a debug assertion: benches run `--release`).
pub fn run_scenario(scenario: &Scenario, settings: &RunSettings) -> Result<ScenarioResult> {
    run_scenario_with_backend(scenario, settings, CommBackend::Thread)
}

/// [`run_scenario`] on an explicit communication backend. The backend is
/// transport, not dynamics: every recorded number except wall/phase
/// seconds must be identical across backends (the differential suite
/// pins this), so scenario ids and the report schema carry no backend
/// tag — a socket report diffs cleanly against a thread baseline.
pub fn run_scenario_with_backend(
    scenario: &Scenario,
    settings: &RunSettings,
    backend: CommBackend,
) -> Result<ScenarioResult> {
    let mut cfg = scenario.config(settings);
    cfg.comm_backend = backend;
    for _ in 0..settings.warmup {
        run_simulation(&cfg)?;
    }
    let mut phase_samples = vec![Vec::with_capacity(settings.reps); ALL_PHASES.len()];
    let mut wall_samples = Vec::with_capacity(settings.reps);
    let mut comm = CounterSnapshot::default();
    let mut spike_state_bytes = 0u64;
    let mut spike_lookups = 0u64;
    let mut imbalance = 1.0f64;
    let mut trace_events = 0u64;
    let mut kernel_blocks = 0u64;
    let mut recoveries = 0u64;
    let mut comm_hists = crate::metrics::CommHistSnapshot::default();
    for rep in 0..settings.reps.max(1) {
        let report = run_simulation(&cfg)?;
        for p in ALL_PHASES {
            phase_samples[p.index()].push(report.phase_max(p));
        }
        wall_samples.push(report.wall_seconds);
        let total = report.total_comm();
        if rep > 0 && total != comm {
            anyhow::bail!(
                "counters drifted between repetitions of {} ({:?} then {:?}) — \
                 determinism bug; the trajectory would be meaningless",
                scenario.id(),
                comm,
                total
            );
        }
        comm = total;
        // The exchange-state size is seed-deterministic too (it is a
        // function of the connectome at the last epoch boundary).
        let state = report.max_spike_state_bytes();
        if rep > 0 && state != spike_state_bytes {
            anyhow::bail!(
                "spike-exchange state drifted between repetitions of {} ({} then {} \
                 bytes) — determinism bug",
                scenario.id(),
                spike_state_bytes,
                state
            );
        }
        spike_state_bytes = state;
        // Remote look-ups are a pure function of the (seeded) topology
        // trajectory: one per remote in-edge per step, whatever the
        // lookup's implementation — the schema-v3 field the baseline
        // diff drift-checks.
        let lookups = report.total_lookups();
        if rep > 0 && lookups != spike_lookups {
            anyhow::bail!(
                "spike lookups drifted between repetitions of {} ({} then {}) — \
                 determinism bug in the delivery path",
                scenario.id(),
                spike_lookups,
                lookups
            );
        }
        spike_lookups = lookups;
        // The end-of-run imbalance factor is a pure function of the
        // (seeded) structural trajectory — neurons, edges, partners —
        // so it must repeat exactly too, migrations included.
        let imb = report.imbalance();
        if rep > 0 && imb.to_bits() != imbalance.to_bits() {
            anyhow::bail!(
                "imbalance drifted between repetitions of {} ({} then {}) — \
                 determinism bug in the load-balancing path",
                scenario.id(),
                imbalance,
                imb
            );
        }
        imbalance = imb;
        // Trace sample/event counts are deterministic by construction
        // (all seven phase slices are emitted per sample regardless of
        // timing) — the schema-v5 field the baseline diff drift-checks.
        let events = report.trace_events();
        if rep > 0 && events != trace_events {
            anyhow::bail!(
                "trace events drifted between repetitions of {} ({} then {}) — \
                 determinism bug in the telemetry path",
                scenario.id(),
                trace_events,
                events
            );
        }
        trace_events = events;
        // Kernel-block counts are a pure function of the per-rank
        // population-size trajectory (`ceil(n/64)` per step, counted by
        // the driver independent of the kernel backend) — the schema-v6
        // field the baseline diff drift-checks.
        let blocks = report.total_kernel_blocks();
        if rep > 0 && blocks != kernel_blocks {
            anyhow::bail!(
                "kernel blocks drifted between repetitions of {} ({} then {}) — \
                 determinism bug in the activity-update scheduling",
                scenario.id(),
                kernel_blocks,
                blocks
            );
        }
        kernel_blocks = blocks;
        // Bench scenarios inject no faults, so supervised relaunches
        // must not happen at all — a nonzero or drifting count means
        // the launch path is dying and silently recovering, which is a
        // behavior change the schema-v7 field pins, not timing noise.
        let rec = report.recoveries;
        if rep > 0 && rec != recoveries {
            anyhow::bail!(
                "recovery count drifted between repetitions of {} ({} then {}) — \
                 the launch path is failing nondeterministically",
                scenario.id(),
                recoveries,
                rec
            );
        }
        recoveries = rec;
        // Histogram totals are trait-level call counts — deterministic
        // like the comm counters (the per-bucket spread is wall-clock
        // and never recorded here) — the schema-v8 fields the baseline
        // diff drift-checks.
        let hists = report.total_comm_hists();
        if rep > 0
            && (hists.a2a.total() != comm_hists.a2a.total()
                || hists.rma.total() != comm_hists.rma.total()
                || hists.barrier.total() != comm_hists.barrier.total())
        {
            anyhow::bail!(
                "comm-histogram totals drifted between repetitions of {} \
                 (a2a/rma/barrier {}/{}/{} then {}/{}/{}) — determinism bug in \
                 the comm instrumentation",
                scenario.id(),
                comm_hists.a2a.total(),
                comm_hists.rma.total(),
                comm_hists.barrier.total(),
                hists.a2a.total(),
                hists.rma.total(),
                hists.barrier.total()
            );
        }
        comm_hists = hists;
    }
    let mut phases = [Summary::default(); ALL_PHASES.len()];
    for p in ALL_PHASES {
        phases[p.index()] = Summary::of(&phase_samples[p.index()]);
    }
    Ok(ScenarioResult {
        scenario: *scenario,
        reps: settings.reps.max(1),
        phases,
        wall: Summary::of(&wall_samples),
        comm,
        spike_state_bytes,
        spike_lookups,
        imbalance,
        trace_events,
        kernel_blocks,
        recoveries,
        comm_hist_a2a: comm_hists.a2a.total(),
        comm_hist_rma: comm_hists.rma.total(),
        comm_hist_barrier: comm_hists.barrier.total(),
    })
}

/// Run every cell of the matrix and assemble the report. `progress` is
/// called once per cell before it runs (the CLI prints it; library
/// callers pass `|_| {}`).
pub fn run_matrix(
    name: &str,
    spec: &MatrixSpec,
    settings: &RunSettings,
    progress: impl FnMut(&str),
) -> Result<BenchReport> {
    run_matrix_with_backend(name, spec, settings, CommBackend::Thread, progress)
}

/// [`run_matrix`] on an explicit communication backend (what
/// `ilmi bench --comm socket` runs).
pub fn run_matrix_with_backend(
    name: &str,
    spec: &MatrixSpec,
    settings: &RunSettings,
    backend: CommBackend,
    mut progress: impl FnMut(&str),
) -> Result<BenchReport> {
    let cells = spec.cells();
    let mut results = Vec::with_capacity(cells.len());
    for (i, cell) in cells.iter().enumerate() {
        progress(&format!(
            "[{}/{}] {} ({} warmup + {} reps x {} steps)",
            i + 1,
            cells.len(),
            cell.id(),
            settings.warmup,
            settings.reps.max(1),
            settings.steps
        ));
        results.push(run_scenario_with_backend(cell, settings, backend)?);
    }
    let created_unix = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    Ok(BenchReport { name: name.to_string(), created_unix, settings: *settings, results })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::scenario::{AlgGen, Regime};
    use crate::config::KernelKind;

    fn tiny_settings() -> RunSettings {
        RunSettings { steps: 60, plasticity_interval: 30, warmup: 0, reps: 2, seed: 42 }
    }

    #[test]
    fn scenario_runs_and_counts_deterministically() {
        let sc = Scenario {
            alg: AlgGen::New,
            ranks: 2,
            neurons_per_rank: 16,
            delta: 30,
            regime: Regime::Active,
            skew: false,
            kernel: KernelKind::Scalar,
        };
        let settings = tiny_settings();
        let a = run_scenario(&sc, &settings).unwrap();
        let b = run_scenario(&sc, &settings).unwrap();
        // Counters are seed-deterministic across whole harness runs too.
        assert_eq!(a.comm, b.comm);
        assert!(a.comm.collectives > 0);
        // New algorithms never touch RMA.
        assert_eq!(a.comm.bytes_rma, 0);
        assert_eq!(a.reps, 2);
        assert!(a.wall.min <= a.wall.median && a.wall.median <= a.wall.max);
        // Exchange-state memory is recorded, deterministic, and sparse:
        // whole 12 B records bounded by the remote-neuron count.
        assert_eq!(a.spike_state_bytes, b.spike_state_bytes);
        assert_eq!(a.spike_state_bytes % 12, 0);
        assert!(a.spike_state_bytes <= 16 * 12, "more state than remote neurons");
        // Lookup counts are recorded and seed-deterministic too (one
        // per remote in-edge per step; an active 2-rank net has some).
        assert_eq!(a.spike_lookups, b.spike_lookups);
        assert!(a.spike_lookups > 0, "active cross-rank net must look up spikes");
        // The imbalance factor records and repeats exactly.
        assert_eq!(a.imbalance.to_bits(), b.imbalance.to_bits());
        assert!(a.imbalance >= 1.0 && a.imbalance.is_finite());
        // Trace event counts record, repeat exactly, and match the
        // closed form: 2 samples x 2 ranks x 10 events + 2 aligned
        // imbalance points (steps 60 / interval 30).
        assert_eq!(a.trace_events, b.trace_events);
        assert_eq!(a.trace_events, 2 * 2 * 10 + 2);
        // Kernel-block counts match the closed form: 60 steps x 2 ranks
        // x ceil(16/64) = 1 block per rank per step.
        assert_eq!(a.kernel_blocks, b.kernel_blocks);
        assert_eq!(a.kernel_blocks, 120);
        // No faults injected, so no supervised relaunches.
        assert_eq!(a.recoveries, 0);
        // Histogram totals are call counts: deterministic across whole
        // harness runs, nonzero on an exchanging net, and RMA-free for
        // the new algorithm (it never downloads subtrees).
        assert_eq!(a.comm_hist_a2a, b.comm_hist_a2a);
        assert_eq!(a.comm_hist_barrier, b.comm_hist_barrier);
        assert!(a.comm_hist_a2a > 0, "exchanging net must time all_to_all");
        assert!(a.comm_hist_barrier > 0);
        assert_eq!(a.comm_hist_rma, 0);
    }

    #[test]
    fn blocked_kernel_cell_matches_scalar_counters() {
        // The kernel axis is execution strategy, not dynamics: every
        // drift-checked number must be identical across kernels, so a
        // blocked-kernel report row is comparable to its scalar twin.
        let settings = tiny_settings();
        let mut sc = Scenario {
            alg: AlgGen::New,
            ranks: 2,
            neurons_per_rank: 16,
            delta: 30,
            regime: Regime::Active,
            skew: false,
            kernel: KernelKind::Scalar,
        };
        let scalar = run_scenario(&sc, &settings).unwrap();
        sc.kernel = KernelKind::Blocked;
        let blocked = run_scenario(&sc, &settings).unwrap();
        assert_eq!(blocked.scenario.id(), "new_r2_n16_d30_active_kblocked");
        assert_eq!(scalar.comm, blocked.comm);
        assert_eq!(scalar.spike_state_bytes, blocked.spike_state_bytes);
        assert_eq!(scalar.spike_lookups, blocked.spike_lookups);
        assert_eq!(scalar.imbalance.to_bits(), blocked.imbalance.to_bits());
        assert_eq!(scalar.trace_events, blocked.trace_events);
        assert_eq!(scalar.kernel_blocks, blocked.kernel_blocks);
    }

    #[test]
    fn skewed_scenario_rebalances_below_its_unbalanced_twin() {
        // The headline demo in miniature: the same skewed start WITHOUT
        // balancing ends measurably more imbalanced than the skewed
        // cell (which migrates boundary cells until even).
        let settings =
            RunSettings { steps: 150, plasticity_interval: 50, warmup: 0, reps: 1, seed: 42 };
        let skewed = Scenario {
            alg: AlgGen::New,
            ranks: 2,
            neurons_per_rank: 32,
            delta: 50,
            regime: Regime::Active,
            skew: true,
            kernel: KernelKind::Scalar,
        };
        let balanced = run_scenario(&skewed, &settings).unwrap();
        // Control: identical skewed start, balancing forced off.
        let mut control_cfg = skewed.config(&settings);
        control_cfg.balance_every = 0;
        let control = run_simulation(&control_cfg).unwrap();
        assert!(
            balanced.imbalance < control.imbalance(),
            "balancing must beat the frozen skew: {} vs {}",
            balanced.imbalance,
            control.imbalance()
        );
        // The frozen 48/16 skew reads clearly imbalanced; the balanced
        // run ends near even.
        assert!(control.imbalance() > 1.3, "control {}", control.imbalance());
        assert!(balanced.imbalance < 1.2, "balanced {}", balanced.imbalance);
    }

    #[test]
    fn matrix_produces_one_result_per_cell_in_order() {
        let spec = MatrixSpec {
            algs: vec![AlgGen::Old, AlgGen::New],
            ranks: vec![2],
            neurons: vec![16],
            deltas: vec![30],
            regimes: vec![Regime::Active],
            skew: false,
            kernels: vec![KernelKind::Scalar],
        };
        let mut seen = Vec::new();
        let report =
            run_matrix("unit", &spec, &tiny_settings(), |msg| seen.push(msg.to_string()))
                .unwrap();
        assert_eq!(report.results.len(), 2);
        assert_eq!(seen.len(), 2);
        let ids: Vec<String> = report.results.iter().map(|r| r.scenario.id()).collect();
        assert_eq!(ids, vec!["old_r2_n16_d30_active", "new_r2_n16_d30_active"]);
        // The old generation pays RMA bytes, the new one does not.
        assert!(report.results[0].comm.bytes_rma > 0);
        assert_eq!(report.results[1].comm.bytes_rma, 0);
        // Only the new generation holds frequency-reconstruction state.
        assert_eq!(report.results[0].spike_state_bytes, 0);
        // The assembled report round-trips through the JSON schema.
        let back = BenchReport::from_json(&report.to_json()).unwrap();
        assert_eq!(back, report);
    }
}
